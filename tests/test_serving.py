"""Oracle suite for online top-k query serving (ISSUE 6 acceptance).

The contract under test: ``QueryEngine(stream).query(batch)`` returns, for
every query trajectory, exactly the top-k world rows by brute-force MSS
over the WHOLE resident world — matches require ``mss > rho`` (per-query),
order is (mss descending, row id ascending), empty slots are
``(PAD_ID, -1.0)`` — and the answer is bit-identical across
{host, device} delta_join x {1, 2, 4, 8} shards x
{wavefront, fused-interpret}, with and without REPOSE-style per-shard
pruning.  Whole-world recall is made airtight by ``EngineConfig(k=1)``:
hierarchy means any pair with mss > 0 shares a coarsest-level type, so
1-shingles surface every possible match.

Also pins the production-shape claims:
* queries NEVER mutate the world (read-only probe protocol: the bucket
  index is probed, not inserted into, and updates interleave freely);
* >= 10 consecutive query micro-batches reuse ONE compiled program pair —
  zero steady-state recompiles, proven by trace-counter hooks;
* pruning never changes results, and on a world engineered with one
  long-row shard it really skips the hopeless shards.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import run_subprocess
from repro.api import EngineConfig, ExecutionPlan, QueryEngine, StreamingEngine
from repro.core.types import PAD_ID, TrajectoryBatch
from repro.data import synthetic_setup

RHO = 1.0


def make_batch(places, lengths):
    places = np.asarray(places, np.int32)
    lengths = np.asarray(lengths, np.int32)
    return TrajectoryBatch(
        places=jnp.asarray(places), lengths=jnp.asarray(lengths),
        user_id=jnp.arange(places.shape[0], dtype=jnp.int32),
    )


def brute_topk(stream, q_places, q_lengths, k_vec, rho_vec):
    """Whole-world brute force: score every query against every resident
    row (no candidate generation at all) and take the top-k above rho with
    the deterministic (mss desc, row asc) order."""
    from repro.core.encoding import encode_codes
    from repro.core.similarity import mss_scores, multi_level_lcs

    n = stream.n
    if stream._mesh_world:
        S = stream.plan.n_shards
        cap_l = stream._cap // S
        g = np.arange(n)
        phys = np.asarray(stream._places_dev)[(g % S) * cap_l + g // S]
        codes = np.asarray(encode_codes(jnp.asarray(phys), stream.tables))
    else:
        codes = np.asarray(stream._codes_dev)[:n]
    lens = np.sum(codes[:, 0, :] >= 0, axis=-1).astype(np.int32)
    qc = np.asarray(encode_codes(jnp.asarray(
        np.asarray(q_places, np.int32)), stream.tables))
    out = []
    for q in range(qc.shape[0]):
        if n == 0 or k_vec[q] == 0:
            out.append([])
            continue
        lvl = multi_level_lcs(
            jnp.asarray(np.repeat(qc[q:q + 1], n, 0)),
            jnp.asarray(np.repeat(np.asarray(q_lengths)[q:q + 1], n)),
            jnp.asarray(codes), jnp.asarray(lens),
        )
        mss = np.asarray(mss_scores(lvl, stream.betas))
        order = sorted(range(n), key=lambda r: (-mss[r], r))
        out.append([(r, np.float32(mss[r])) for r in order
                    if mss[r] > rho_vec[q]][:int(k_vec[q])])
    return out


def result_lists(res):
    return [
        [(int(r), m) for r, m in zip(ids, mss) if r != PAD_ID]
        for ids, mss in zip(res.match_ids, res.mss)
    ]


def world(seed=0, n=24):
    return synthetic_setup(
        n, num_types=5, classes_per_type=3, num_places=30,
        min_len=2, max_len=8, seed=seed,
    )


# ---------------------------------------------------------------------------
# the oracle property, single device (in-process)
# ---------------------------------------------------------------------------
def test_topk_matches_whole_world_brute_force():
    batch, forest = world()
    st = StreamingEngine(forest, EngineConfig(rho=RHO, k=1))
    st.update(batch)
    qe = QueryEngine(st, k=5)
    qb = make_batch(np.asarray(batch.places)[3:9],
                    np.asarray(batch.lengths)[3:9])
    res = qe.query(qb)
    want = brute_topk(st, qb.places, qb.lengths,
                      np.full(6, 5), np.full(6, RHO, np.float32))
    assert result_lists(res) == want
    # result shape contract: PAD_ID / -1.0 in every unused slot
    pad = res.match_ids == PAD_ID
    assert np.all(res.mss[pad] == np.float32(-1.0))


def test_ties_break_toward_smaller_row_id():
    """Duplicate world rows score identically; the smaller id wins every
    tie, and both duplicates appear (dedup drops copies of the same row,
    never distinct rows with equal scores)."""
    batch, forest = world(seed=3, n=8)
    p = np.asarray(batch.places)
    ln = np.asarray(batch.lengths)
    # rows i and i+8 are identical trajectories with distinct ids
    st = StreamingEngine(forest, EngineConfig(rho=RHO, k=1))
    st.update(make_batch(np.concatenate([p, p]), np.concatenate([ln, ln])))
    qe = QueryEngine(st, k=6)
    res = qe.query(make_batch(p[:4], ln[:4]))
    want = brute_topk(st, p[:4], ln[:4],
                      np.full(4, 6), np.full(4, RHO, np.float32))
    got = result_lists(res)
    assert got == want
    for q in range(4):
        # the query's own duplicate pair (q, q+8) ties at the top with
        # the smaller id first
        top = [r for r, _ in got[q][:2]]
        assert top == [q, q + 8], got[q]


def test_k_exceeding_world_and_per_query_k_rho():
    batch, forest = world(n=10)
    st = StreamingEngine(forest, EngineConfig(rho=RHO, k=1))
    st.update(batch)
    qe = QueryEngine(st, k=3)
    qp = np.asarray(batch.places)[:4]
    ql = np.asarray(batch.lengths)[:4]
    k_vec = np.array([50, 0, 1, 3])        # k > |world|, k = 0, mixed
    rho_vec = np.array([RHO, RHO, 1e9, 0.5], np.float32)  # unmatchable rho
    res = qe.query(make_batch(qp, ql), k=k_vec, rho=rho_vec)
    want = brute_topk(st, qp, ql, k_vec, rho_vec)
    assert result_lists(res) == want
    assert want[0]                       # k=50 returns everything above rho
    assert want[1] == [] and want[2] == []
    # padded width is max(k_vec); rows with smaller k are PAD beyond it
    assert res.match_ids.shape == (4, 50)
    assert np.all(res.match_ids[1] == PAD_ID)
    assert np.all(res.match_ids[3][3:] == PAD_ID)


def test_empty_and_keyless_queries():
    batch, forest = world(n=12)
    st = StreamingEngine(forest, EngineConfig(rho=RHO, k=1))
    st.update(batch)
    qe = QueryEngine(st, k=3)
    # zero queries
    res = qe.query(make_batch(np.zeros((0, 4), np.int32),
                              np.zeros((0,), np.int32)))
    assert res.match_ids.shape[0] == 0
    # a keyless (zero-length) query mixed with normal ones: it gets no
    # candidates and an all-PAD row, the others are unaffected
    qp = np.asarray(batch.places)[:3].copy()
    ql = np.asarray(batch.lengths)[:3].copy()
    qp[1] = 0
    ql[1] = 0
    res = qe.query(make_batch(qp, ql))
    want = brute_topk(st, qp, ql, np.full(3, 3),
                      np.full(3, RHO, np.float32))
    assert result_lists(res) == want
    assert want[1] == []
    # all queries keyless: the early path, still well-shaped
    res = qe.query(make_batch(np.zeros((2, 4), np.int32),
                              np.zeros((2,), np.int32)))
    assert np.all(res.match_ids == PAD_ID)


def test_queries_interleave_with_updates_and_never_mutate():
    """Queries are read-only: the bucket index is never inserted into,
    stream state is untouched, and update -> query -> update -> query
    sees exactly the world as of each call."""
    import repro.core.stream_index as stream_index

    batch, forest = world(n=20)
    p = np.asarray(batch.places)
    ln = np.asarray(batch.lengths)
    st = StreamingEngine(forest, EngineConfig(rho=RHO, k=1))
    st.update(make_batch(p[:12], ln[:12]))
    qe = QueryEngine(st, k=4)
    qb = make_batch(p[2:6], ln[2:6])

    inserts = []
    real = stream_index.BucketIndex.insert
    stream_index.BucketIndex.insert = \
        lambda self, *a, **kw: (inserts.append(1), real(self, *a, **kw))[1]
    try:
        before = (st.n, st._index.num_rows, st._index.pairs_examined_total)
        res1 = qe.query(qb)
        assert not inserts       # probe only, never insert
        assert (st.n, st._index.num_rows,
                st._index.pairs_examined_total) == before
        assert result_lists(res1) == brute_topk(
            st, qb.places, qb.lengths, np.full(4, 4),
            np.full(4, RHO, np.float32))
        st.update(make_batch(p[12:], ln[12:]))   # world grows
        assert len(inserts) == 1
        res2 = qe.query(qb)
        assert result_lists(res2) == brute_topk(
            st, qb.places, qb.lengths, np.full(4, 4),
            np.full(4, RHO, np.float32))
        assert res2.stats["world_size"] == 20
    finally:
        stream_index.BucketIndex.insert = real


def test_prune_never_changes_results_and_really_skips():
    """A world engineered so shard 0 holds the only long rows (ids = 0
    mod 8 are long for every shard count in {1,2,4,8}): with k=1 a query
    identical to a long row saturates its kth-best on the first (longest)
    shard and every other shard's length bound is hopeless — skipped
    without scoring, results identical."""
    from repro.core.types import PAD_PLACE

    rng = np.random.default_rng(0)
    _, forest = world()
    n, Llong, Lshort = 24, 8, 3
    places = rng.integers(0, 30, size=(n, Llong)).astype(np.int32)
    lengths = np.full((n,), Lshort, np.int32)
    lengths[::8] = Llong
    places = np.where(np.arange(Llong)[None, :] < lengths[:, None],
                      places, PAD_PLACE)
    st = StreamingEngine(forest, EngineConfig(rho=RHO, k=1))
    st.update(make_batch(places, lengths))
    qb = make_batch(places[8:9], lengths[8:9])  # == resident long row 8
    plain = QueryEngine(st, k=1, serve_prune=False).query(qb)
    pruned = QueryEngine(st, k=1, serve_prune=True).query(qb)
    assert result_lists(plain) == result_lists(pruned)
    assert np.array_equal(plain.match_ids, pruned.match_ids)
    assert np.array_equal(plain.mss, pruned.mss)
    assert pruned.stats["rounds_run"] >= 1
    # single device = one world shard: nothing to skip here; the
    # multi-shard skip proof runs in the subprocess matrix below
    assert plain.stats["rounds_skipped"] == 0


def test_local_topk_matches_numpy_reference():
    """Property test for the in-mesh segmented top-k primitive against a
    plain numpy reference, including duplicates, ties and overfull runs."""
    from repro.api.serving import _local_topk

    rng = np.random.default_rng(1)
    q_cap, k_cap, m = 8, 4, 64
    for trial in range(5):
        qid = rng.integers(0, q_cap, size=m).astype(np.int32)
        row = rng.integers(0, 10, size=m).astype(np.int32)
        mss = (rng.integers(0, 5, size=m) / 2.0).astype(np.float32)
        pad = rng.random(m) < 0.3
        row[pad] = PAD_ID
        rho = np.full(q_cap, 0.4, np.float32)
        # duplicates of the same (qid, row) must carry the same score
        key = qid.astype(np.int64) * 1000 + row
        uniq, first = np.unique(key, return_index=True)
        mss = mss[first][np.searchsorted(uniq, key)]
        t_row, t_neg = _local_topk(
            jnp.asarray(qid), jnp.asarray(row), jnp.asarray(mss),
            q_cap=q_cap, k_cap=k_cap, rho_vec=jnp.asarray(rho),
        )
        t_row, t_neg = np.asarray(t_row), np.asarray(t_neg)
        for q in range(q_cap):
            cand = {int(r): float(s) for qi, r, s in zip(qid, row, mss)
                    if qi == q and r != PAD_ID and s > rho[q]}
            want = sorted(cand.items(), key=lambda kv: (-kv[1], kv[0]))
            want = want[:k_cap]
            got = [(int(r), float(-s)) for r, s in zip(t_row[q], t_neg[q])
                   if r != PAD_ID]
            assert got == want, (trial, q, got, want)


# ---------------------------------------------------------------------------
# the serving matrix + zero-recompile proofs (subprocess, 8 devices)
# ---------------------------------------------------------------------------
SERVE_MATRIX_CODE = r"""
import numpy as np
import jax.numpy as jnp
from repro.api import EngineConfig, ExecutionPlan, QueryEngine, StreamingEngine
from repro.core.encoding import encode_codes
from repro.core.similarity import mss_scores, multi_level_lcs
from repro.core.types import PAD_ID, PAD_PLACE, TrajectoryBatch
from repro.data import synthetic_setup

RHO = 1.0
batch, forest = synthetic_setup(24, num_types=5, classes_per_type=3,
                                num_places=30, min_len=2, max_len=8, seed=0)
P = np.asarray(batch.places); Ln = np.asarray(batch.lengths)
# shard 0 keeps the only long rows for every shard count in {1,2,4,8};
# keep the PAD-beyond-length invariant every data source maintains
rng0 = np.random.default_rng(5)
P = np.where(P == PAD_PLACE, rng0.integers(0, 30, P.shape), P)
Ln = np.minimum(Ln, 4); Ln[::8] = P.shape[1]
P = np.where(np.arange(P.shape[1])[None, :] < Ln[:, None], P, PAD_PLACE)
P = P.astype(np.int32)

def mk(p, l):
    return TrajectoryBatch(places=jnp.asarray(p.astype(np.int32)),
                           lengths=jnp.asarray(l.astype(np.int32)),
                           user_id=jnp.arange(p.shape[0], dtype=jnp.int32))

qp, ql = P[6:12], Ln[6:12]

def brute(st, k):
    n = st.n
    codes = np.asarray(encode_codes(jnp.asarray(P[:n]), st.tables))
    cl = np.sum(codes[:, 0, :] >= 0, -1)
    qc = np.asarray(encode_codes(jnp.asarray(qp), st.tables))
    out = []
    for q in range(qp.shape[0]):
        lvl = multi_level_lcs(jnp.asarray(np.repeat(qc[q:q+1], n, 0)),
                              jnp.asarray(np.repeat(ql[q:q+1], n)),
                              jnp.asarray(codes), jnp.asarray(cl))
        mss = np.asarray(mss_scores(lvl, st.betas))
        order = sorted(range(n), key=lambda r: (-mss[r], r))
        out.append([(r, np.float32(mss[r])) for r in order
                    if mss[r] > RHO][:k])
    return out

def lists(res):
    return [[(int(r), m) for r, m in zip(ids, mss) if r != PAD_ID]
            for ids, mss in zip(res.match_ids, res.mss)]

ref = {}
for impl in ("wavefront", "fused-interpret"):
    cfg = EngineConfig(rho=RHO, k=1, lcs_impl=impl)
    for dj in ("host", "device"):
        for S in (1, 2, 4, 8):
            for prune in (False, True):
                st = StreamingEngine(
                    forest, cfg, ExecutionPlan(n_shards=S, delta_join=dj))
                st.update(mk(P[:16], Ln[:16]))
                qe = QueryEngine(st, k=3, serve_prune=prune)
                res = qe.query(mk(qp, ql))
                cell = (impl, dj, S, prune)
                if ("ids", impl) not in ref:
                    assert lists(res) == brute(st, 3), cell
                    ref[("ids", impl)] = res.match_ids
                    ref[("mss", impl)] = res.mss
                # bit-identical across delta_join x shards x prune
                assert np.array_equal(res.match_ids, ref[("ids", impl)]), cell
                assert np.array_equal(res.mss, ref[("mss", impl)]), cell
                # interleaved update, then query the grown world
                st.update(mk(P[16:], Ln[16:]))
                res2 = qe.query(mk(qp, ql))
                if ("ids2", impl) not in ref:
                    assert lists(res2) == brute(st, 3), cell
                    ref[("ids2", impl)] = res2.match_ids
                    ref[("mss2", impl)] = res2.mss
                assert np.array_equal(res2.match_ids, ref[("ids2", impl)]), cell
                assert np.array_equal(res2.mss, ref[("mss2", impl)]), cell

# scores agree bit-exactly ACROSS impls too (integer LCS + one epilogue)
assert np.array_equal(ref[("mss", "wavefront")],
                      ref[("mss", "fused-interpret")])

# the engineered skip: query = the long resident row 8 with k=1; every
# shard but the long one is hopeless once its kth-best saturates
for S in (2, 4, 8):
    st = StreamingEngine(forest, EngineConfig(rho=RHO, k=1),
                         ExecutionPlan(n_shards=S))
    st.update(mk(P, Ln))
    qb = mk(P[8:9], Ln[8:9])
    plain = QueryEngine(st, k=1, serve_prune=False).query(qb)
    pruned = QueryEngine(st, k=1, serve_prune=True).query(qb)
    assert np.array_equal(plain.match_ids, pruned.match_ids), S
    assert np.array_equal(plain.mss, pruned.mss), S
    assert pruned.stats["rounds_skipped"] >= S - 1, (S, pruned.stats)
    assert pruned.stats["rounds_run"] <= 1 + (S
        - pruned.stats["rounds_skipped"]), (S, pruned.stats)
print("OK serve matrix")
"""


def test_serving_matrix():
    """The ISSUE 6 acceptance matrix: {host, device} x {1, 2, 4, 8}
    shards x {wavefront, fused-interpret} x {prune on/off} serve
    bit-identical top-k results, equal to whole-world brute force, with
    interleaved updates — plus a real per-shard skip proof."""
    out = run_subprocess(SERVE_MATRIX_CODE, devices=8)
    assert "OK serve matrix" in out


SERVE_RECOMPILE_CODE = r"""
import numpy as np
import jax.numpy as jnp
from repro.api import EngineConfig, ExecutionPlan, QueryEngine, StreamingEngine
from repro.core.types import TrajectoryBatch
from repro.data import synthetic_setup

batch, forest = synthetic_setup(32, num_types=5, classes_per_type=3,
                                num_places=30, min_len=4, max_len=8, seed=2)
P = np.asarray(batch.places); Ln = np.asarray(batch.lengths)

def mk(p, l):
    return TrajectoryBatch(places=jnp.asarray(p.astype(np.int32)),
                           lengths=jnp.asarray(l.astype(np.int32)),
                           user_id=jnp.arange(p.shape[0], dtype=np.int32))

rng = np.random.default_rng(7)
sels = [rng.integers(0, P.shape[0], size=4) for _ in range(12)]
for dj in ("host", "device"):
    st = StreamingEngine(forest, EngineConfig(rho=1.0, k=1),
                         ExecutionPlan(n_shards=4, delta_join=dj))
    st.update(mk(P, Ln))
    qe = QueryEngine(st, k=3, serve_prune=True)
    # pass 1 warms: compiles the program pair and ratchets the pow2-sticky
    # caps up to the max any batch in the cycle needs
    for sel in sels:
        qe.query(mk(P[sel], Ln[sel]))
    warm = (qe.serve_traces[0], qe.probe_traces[0])
    # pass 2 replays the same 12 varying-content, steady-shape batches:
    # every per-batch plan is already covered by the sticky plan, so >= 10
    # CONSECUTIVE micro-batches reuse the pair verbatim — ZERO recompiles
    for sel in sels:
        res = qe.query(mk(P[sel], Ln[sel]))
    assert warm[0] >= 1, (dj, warm)
    assert (qe.serve_traces[0], qe.probe_traces[0]) == warm, (
        dj, warm, qe.serve_traces, qe.probe_traces)
    assert qe.runner_builds <= 5, (dj, qe.runner_builds)
    # only [Q, k]-scale data plus the query batch transits the driver
    assert res.stats["driver_bytes_in"] < 64 * 1024, res.stats
print("OK serve recompile")
"""


def test_query_micro_batches_share_one_compiled_program():
    """>= 10 consecutive query micro-batches of steady shape reuse one
    compiled probe + score program pair (trace counters frozen across a
    replayed batch cycle) on both the host and device index paths."""
    out = run_subprocess(SERVE_RECOMPILE_CODE, devices=4)
    assert "OK serve recompile" in out


def test_device_probe_never_touches_bucket_index():
    """Protocol dispatch proof: serving over a device-resident world goes
    through the in-mesh probe program — the driver BucketIndex is never
    probed — while the host path really routes through BucketIndex.probe."""
    import repro.core.stream_index as stream_index

    batch, forest = world(n=12)
    probes = []
    real = stream_index.BucketIndex.probe
    stream_index.BucketIndex.probe = \
        lambda self, *a, **kw: (probes.append(1), real(self, *a, **kw))[1]
    try:
        qb = make_batch(np.asarray(batch.places)[:3],
                        np.asarray(batch.lengths)[:3])
        dev = StreamingEngine(forest, EngineConfig(rho=RHO, k=1),
                              ExecutionPlan(delta_join="device"))
        dev.update(batch)
        r_dev = QueryEngine(dev, k=3).query(qb)
        assert not probes
        host = StreamingEngine(forest, EngineConfig(rho=RHO, k=1))
        host.update(batch)
        r_host = QueryEngine(host, k=3).query(qb)
        assert len(probes) == 1
        assert np.array_equal(r_dev.match_ids, r_host.match_ids)
        assert np.array_equal(r_dev.mss, r_host.mss)
    finally:
        stream_index.BucketIndex.probe = real
