"""End-to-end behaviour tests for the whole system (paper pipeline + LM
training integration), small-scale."""
import numpy as np
import pytest

from repro.core import (
    AnotherMeConfig, qa1, qa2, run_anotherme, maximal_cliques,
    centralized_similar_pairs, encode_batch, forest_tables,
)
from repro.data import geolife_surrogate, synthetic_setup


def test_end_to_end_synthetic():
    """Full 4-phase pipeline on the paper's synthetic setup (scaled down):
    communities found, 100% of centralized truth recovered."""
    batch, forest = synthetic_setup(
        400, num_types=10, classes_per_type=5, num_places=300, seed=21
    )
    res = run_anotherme(batch, forest, AnotherMeConfig())
    assert res.stats["num_candidates"] > 0
    assert res.stats["join_overflow"] == 0
    assert len(res.communities) > 0
    enc = encode_batch(batch, forest_tables(forest))
    cl, cr, _ = centralized_similar_pairs(enc, rho=2.0)
    cen = {(int(a), int(b)) for a, b in zip(cl, cr)}
    assert qa2(res.similar_pairs, cen) == 1.0
    assert qa1(res.communities, maximal_cliques(cen)) == 1.0


def test_end_to_end_geolife_surrogate():
    """The 'real dataset' round (Figs. 11-12) on the GeoLife surrogate:
    AnotherMe == centralized, and communities align with user behaviour."""
    batch, forest = geolife_surrogate(num_users=30, num_traj=300, seed=5)
    res = run_anotherme(batch, forest, AnotherMeConfig(rho=3.0))
    enc = encode_batch(batch, forest_tables(forest))
    cl, cr, _ = centralized_similar_pairs(enc, rho=3.0)
    cen = {(int(a), int(b)) for a, b in zip(cl, cr)}
    assert qa2(res.similar_pairs, cen) == 1.0
    # behavioural signal: same-user trajectory pairs should be similar far
    # more often than cross-user pairs (home/work anchors recur)
    users = np.asarray(batch.user_id)
    if res.similar_pairs:
        same_user = np.mean([users[a] == users[b] for a, b in res.similar_pairs])
        n_users = 30
        assert same_user > 1.5 / n_users


def test_find_another_me_scenario():
    """Paper Fig. 1: Carol (Sydney) and Dave (Chicago/Paris) are frequent
    flyers with zero geographic overlap but similar semantic trajectories —
    the pipeline must pair them across 'the world'."""
    import jax.numpy as jnp
    from repro.core.encoding import SemanticForest
    from repro.core.types import PAD_PLACE, TrajectoryBatch

    # hand-built forest: types {0:lodging, 1:transportation, 2:business, 3:dining}
    # classes: 0:apartment 1:hotel 2:airport 3:station 4:company 5:fastfood 6:fine
    class_to_type = np.array([0, 0, 1, 1, 2, 3, 3], np.int32)
    # names: 0:maris_apt 1:windy_apt 2:sydney_apt2 3:sydney_air 4:ohare_air
    # 5:tokyo_air 6:cdg_air 7:fb_japan 8:msft_france 9:kfc 10:resto_goude
    name_to_class = np.array([0, 0, 0, 2, 2, 2, 2, 4, 4, 5, 6], np.int32)
    forest = SemanticForest(
        parents=(class_to_type, name_to_class), sizes=(4, 7, 11)
    )
    carol = [0, 3, 4, 5, 7, 9, 5, 3, 0]       # maris->syd->ohare->tokyo->fb->kfc->tokyo->syd->maris
    dave = [1, 4, 6, 8, 10, 6, 4, 1]          # windy->ohare->cdg->msft->resto->cdg->ohare->windy
    homebody = [2, 9, 2, 9, 2]                # never flies
    L = 10
    rows = []
    lens = []
    for t in (carol, dave, homebody):
        rows.append(t + [PAD_PLACE] * (L - len(t)))
        lens.append(len(t))
    batch = TrajectoryBatch(
        places=jnp.asarray(np.asarray(rows, np.int32)),
        lengths=jnp.asarray(np.asarray(lens, np.int32)),
        user_id=jnp.arange(3, dtype=jnp.int32),
    )
    # Carol~Dave MSS = (8+7+1)/3 = 5.33; Carol~homebody = (3+3+1)/3 = 2.33
    # (the homebody shares the lodging->dining->lodging motif, so rho must
    # sit between the two — threshold choice is application-level, IV.3)
    res = run_anotherme(batch, forest, AnotherMeConfig(rho=3.0))
    assert (0, 1) in res.similar_pairs          # Carol ~ Dave, across the world
    assert (0, 2) not in res.similar_pairs      # Carol !~ homebody
    assert any({0, 1} <= set(c) for c in res.communities)
