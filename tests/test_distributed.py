"""Distributed (shard_map) AnotherMe == single-device, on 8 virtual devices.

Runs in a subprocess because XLA's host device count must be fixed before
jax initializes.
"""
from conftest import run_subprocess

CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import *
from repro.core.distributed import (
    make_distributed_anotherme, plan_capacities, gather_similar_pairs,
    pad_to_shards)
from repro.core.encoding import encode_types, forest_tables
from repro.core.shingling import shingles_from_types
from repro.core.types import TrajectoryBatch
from repro.data import synthetic_setup

assert len(jax.devices()) == 8
batch, forest = synthetic_setup(296, num_types=10, classes_per_type=5,
                                num_places=200, seed=3)
tables = forest_tables(forest)
n_shards = 8
places, lengths = pad_to_shards(
    np.asarray(batch.places), np.asarray(batch.lengths), n_shards)
bp = TrajectoryBatch(jnp.asarray(places), jnp.asarray(lengths),
                     jnp.arange(places.shape[0]))
keys_np = np.asarray(shingles_from_types(
    encode_types(bp.places, tables), bp.lengths, k=3,
    num_types=forest.num_types))
plan = plan_capacities(keys_np, n_shards)
from repro.core import compat
mesh = compat.make_mesh((n_shards,), ("ex",))
run = make_distributed_anotherme(
    mesh, plan, tables=tables, k=3, num_types=forest.num_types,
    betas=default_betas(3))
out = run(bp.places, bp.lengths)
assert int(np.asarray(out["overflow"]).sum()) == 0, "capacity overflow"
dist_pairs = gather_similar_pairs(out, rho=2.0)
res = run_anotherme(batch, forest, AnotherMeConfig())
assert dist_pairs == res.similar_pairs, (
    len(dist_pairs - res.similar_pairs), len(res.similar_pairs - dist_pairs))
print("OK", len(dist_pairs))
"""


def test_distributed_matches_single_device():
    out = run_subprocess(CODE, devices=8)
    assert "OK" in out


CODE_SHUFFLE = CODE.replace(
    'make_distributed_anotherme(\n    mesh, plan, tables=tables, k=3, num_types=forest.num_types,\n    betas=default_betas(3))',
    'make_distributed_anotherme(\n    mesh, plan, tables=tables, k=3, num_types=forest.num_types,\n    betas=default_betas(3), score_mode="shuffle")',
).replace(
    'plan = plan_capacities(keys_np, n_shards)',
    'plan = plan_capacities(keys_np, n_shards, score_mode="shuffle")',
)


def test_distributed_shuffle_scoring_matches():
    """score_mode='shuffle': codes stay sharded, pairs are routed to their
    owners' shards (two extra all_to_all) — per-device memory O(N/shards).
    Must be bit-identical to the replicate mode and the single device."""
    assert 'score_mode="shuffle"' in CODE_SHUFFLE  # guard the replace
    out = run_subprocess(CODE_SHUFFLE, devices=8)
    assert "OK" in out


CODE_COMPRESSED_PSUM = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.train.compression import compressed_psum

from repro.core import compat
mesh = compat.make_mesh((8,), ("dp",))
rng = np.random.default_rng(0)
x = rng.normal(size=(8, 4, 300)).astype(np.float32)

def f(xl):
    return compressed_psum(xl, "dp")

out = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=P("dp", None, None),
              out_specs=P("dp", None, None)))(jnp.asarray(x))
want = x.sum(axis=0, keepdims=True)
got = np.asarray(out)[0:1]
rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
assert rel < 0.05, rel   # int8 quantization error bound
print("OK", rel)
"""


def test_compressed_psum_collective():
    out = run_subprocess(CODE_COMPRESSED_PSUM, devices=8)
    assert "OK" in out
