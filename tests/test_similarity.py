import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.encoding import PAD_CODE_A, PAD_CODE_B
from repro.core.similarity import (
    default_betas, lcs_ref, lcs_wavefront, mss_scores, multi_level_lcs, repad,
)


def py_lcs(a, b):
    la, lb = len(a), len(b)
    dp = [[0] * (lb + 1) for _ in range(la + 1)]
    for i in range(1, la + 1):
        for j in range(1, lb + 1):
            dp[i][j] = (
                dp[i - 1][j - 1] + 1
                if a[i - 1] == b[j - 1]
                else max(dp[i - 1][j], dp[i][j - 1])
            )
    return dp[la][lb]


def _pad(seqs, L, pad):
    out = np.full((len(seqs), L), pad, np.int32)
    for i, s in enumerate(seqs):
        out[i, : len(s)] = s
    return jnp.asarray(out)


@pytest.mark.parametrize("impl", [lcs_ref, lcs_wavefront])
def test_lcs_against_python(impl):
    rng = np.random.default_rng(0)
    L = 12
    seqs_a = [rng.integers(0, 5, size=rng.integers(1, L + 1)).tolist() for _ in range(64)]
    seqs_b = [rng.integers(0, 5, size=rng.integers(1, L + 1)).tolist() for _ in range(64)]
    a = _pad(seqs_a, L, PAD_CODE_A)
    b = _pad(seqs_b, L, PAD_CODE_B)
    got = np.asarray(impl(a, b))
    want = np.array([py_lcs(x, y) for x, y in zip(seqs_a, seqs_b)])
    np.testing.assert_array_equal(got, want)


def test_lcs_wavefront_property():
    """Property test (seeded generator): wavefront LCS == python DP on
    random short sequences, batched in one call; invariants hold."""
    rng = np.random.default_rng(42)
    L = 10
    seqs_a = [rng.integers(0, 5, size=rng.integers(0, L + 1)).tolist()
              for _ in range(200)]
    seqs_b = [rng.integers(0, 5, size=rng.integers(0, L + 1)).tolist()
              for _ in range(200)]
    seqs_b[0] = list(seqs_a[0])  # include the a == b case
    seqs_b[1] = []               # and an empty side
    got = np.asarray(lcs_wavefront(
        _pad(seqs_a, L, PAD_CODE_A), _pad(seqs_b, L, PAD_CODE_B)
    ))
    for g, a, b in zip(got, seqs_a, seqs_b):
        assert g == py_lcs(a, b)
        assert g <= min(len(a), len(b))
        if a == b:
            assert g == len(a)


def test_lcs_monotone_under_append():
    """LCS(a, a+[x]) == len(a) -- appending never reduces the match."""
    rng = np.random.default_rng(7)
    L = 9
    for _ in range(100):
        a = rng.integers(0, 4, size=rng.integers(1, 9)).tolist()
        x = int(rng.integers(0, 4))
        pa = _pad([a], L, PAD_CODE_A)
        pb = _pad([a + [x]], L, PAD_CODE_B)
        assert int(lcs_wavefront(pa, pb)[0]) == len(a)


def test_multi_level_hierarchy_monotonicity():
    """|M_typ| >= |M_cls| >= |M_p| (paper section IV.3): coarser levels can
    only match MORE, because levels are tree-consistent."""
    rng = np.random.default_rng(1)
    P, L = 128, 10
    # build tree-consistent random codes: place -> class = p//4 -> type = p//16
    pa = rng.integers(0, 64, size=(P, L)).astype(np.int32)
    pb = rng.integers(0, 64, size=(P, L)).astype(np.int32)
    la = rng.integers(1, L + 1, size=P).astype(np.int32)
    lb = rng.integers(1, L + 1, size=P).astype(np.int32)
    codes_a = np.stack([pa // 16, pa // 4, pa], axis=1)
    codes_b = np.stack([pb // 16, pb // 4, pb], axis=1)
    lv = np.asarray(
        multi_level_lcs(jnp.asarray(codes_a), jnp.asarray(la),
                        jnp.asarray(codes_b), jnp.asarray(lb))
    )
    assert (lv[:, 0] >= lv[:, 1]).all()
    assert (lv[:, 1] >= lv[:, 2]).all()


def test_paper_fig6_example():
    """The worked example: |M_typ|=7, |M_cls|=3, |M_p|=1 with betas
    (0.2, 0.3, 0.5) gives MSS = 2.8."""
    lv = jnp.asarray([[7, 3, 1]], jnp.int32)
    betas = jnp.asarray([0.2, 0.3, 0.5], jnp.float32)
    assert float(mss_scores(lv, betas)[0]) == pytest.approx(2.8)


def test_repetition_awareness():
    """Frequent flyer vs occasional traveler: repeated visits raise the
    similarity only when BOTH trajectories repeat (the paper's key point
    against set-based similarity)."""
    L = 8
    freq_a = _pad([[1, 2, 1, 2, 1, 2]], L, PAD_CODE_A)
    freq_b = _pad([[1, 2, 1, 2, 1, 2]], L, PAD_CODE_B)
    once_b = _pad([[1, 2]], L, PAD_CODE_B)
    assert int(lcs_wavefront(freq_a, freq_b)[0]) == 6
    assert int(lcs_wavefront(freq_a, once_b)[0]) == 2  # set-based would say "same"


def test_repad():
    x = jnp.asarray(np.arange(12, dtype=np.int32).reshape(2, 6))
    out = repad(x, jnp.asarray([2, 6], jnp.int32), -7)
    assert np.asarray(out)[0].tolist() == [0, 1, -7, -7, -7, -7]
    assert np.asarray(out)[1].tolist() == [6, 7, 8, 9, 10, 11]
