"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs ref.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import PAD_KEY


class TestLCS:
    @pytest.mark.parametrize("L", [8, 10, 16, 32])
    @pytest.mark.parametrize("B", [256, 600])
    def test_sweep(self, L, B):
        from repro.kernels.lcs.ops import lcs
        from repro.kernels.lcs.ref import lcs as ref

        rng = np.random.default_rng(L * 1000 + B)
        la = rng.integers(1, L + 1, size=B)
        lb = rng.integers(1, L + 1, size=B)
        a = rng.integers(0, 6, size=(B, L)).astype(np.int32)
        b = rng.integers(0, 6, size=(B, L)).astype(np.int32)
        a[np.arange(L)[None, :] >= la[:, None]] = -1
        b[np.arange(L)[None, :] >= lb[:, None]] = -2
        got = np.asarray(lcs(jnp.asarray(a), jnp.asarray(b), block_b=256))
        want = np.asarray(ref(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_array_equal(got, want)

    def test_raw_pallas_path(self):
        from repro.kernels.lcs.kernel import lcs_pallas
        from repro.kernels.lcs.ref import lcs as ref

        rng = np.random.default_rng(0)
        B, L = 512, 16
        a = rng.integers(0, 4, size=(B, L)).astype(np.int32)
        b = rng.integers(0, 4, size=(B, L)).astype(np.int32)
        got = np.asarray(
            lcs_pallas(jnp.asarray(a), jnp.asarray(b), block_b=128, interpret=True)
        )
        want = np.asarray(ref(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_array_equal(got, want)


class TestShingle:
    @pytest.mark.parametrize("L,k,Q", [(10, 3, 30), (16, 3, 300), (12, 4, 30), (8, 2, 10)])
    def test_sweep(self, L, k, Q):
        from repro.core.shingling import shingles_from_types
        from repro.kernels.shingle.ops import shingle_keys

        rng = np.random.default_rng(k * 7 + Q)
        N = 300
        lengths = rng.integers(k, L + 1, size=N).astype(np.int32)
        types = rng.integers(0, Q, size=(N, L)).astype(np.int32)
        got = np.asarray(
            shingle_keys(jnp.asarray(types), jnp.asarray(lengths), k=k, num_types=Q)
        )
        want = np.asarray(
            shingles_from_types(jnp.asarray(types), jnp.asarray(lengths), k=k, num_types=Q)
        )
        for i in range(N):
            g = set(got[i][got[i] != PAD_KEY].tolist())
            w = set(want[i][want[i] != PAD_KEY].tolist())
            assert g == w, i


class TestMinhash:
    @pytest.mark.parametrize("L,Q,P", [(10, 30, 16), (16, 300, 32), (12, 10, 8)])
    def test_sweep(self, L, Q, P):
        from repro.kernels.minhash.ops import minhash_signatures as kern
        from repro.kernels.minhash.ref import minhash_signatures as ref

        rng = np.random.default_rng(L + Q + P)
        N = 513
        lengths = rng.integers(1, L + 1, size=N).astype(np.int32)
        types = rng.integers(0, Q, size=(N, L)).astype(np.int32)
        got = np.asarray(kern(jnp.asarray(types), jnp.asarray(lengths),
                              num_perm=P, block_b=256))
        want = np.asarray(ref(jnp.asarray(types), jnp.asarray(lengths), num_perm=P))
        np.testing.assert_array_equal(got, want)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "B,Sq,H,KH,D,causal",
        [(2, 128, 4, 2, 64, True), (1, 256, 8, 8, 32, True),
         (2, 128, 4, 1, 64, False), (3, 64, 6, 2, 128, True)],
    )
    def test_sweep(self, B, Sq, H, KH, D, causal):
        from repro.kernels.attention.ops import flash_attention
        from repro.kernels.attention.ref import attention as ref

        rng = np.random.default_rng(B * Sq + H)
        q = jnp.asarray(rng.normal(size=(B, Sq, H, D)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, Sq, KH, D)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, Sq, KH, D)).astype(np.float32))
        got = np.asarray(flash_attention(q, k, v, causal=causal, blk_q=64, blk_k=64))
        want = np.asarray(ref(q, k, v, causal=causal))
        np.testing.assert_allclose(got, want, atol=3e-5)

    def test_bf16(self):
        from repro.kernels.attention.ops import flash_attention
        from repro.kernels.attention.ref import attention as ref

        rng = np.random.default_rng(7)
        q = jnp.asarray(rng.normal(size=(2, 128, 4, 64)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(2, 128, 2, 64)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(2, 128, 2, 64)), jnp.bfloat16)
        got = np.asarray(flash_attention(q, k, v, blk_q=64, blk_k=64), np.float32)
        want = np.asarray(ref(q, k, v), np.float32)
        np.testing.assert_allclose(got, want, atol=3e-2)


class TestSSD:
    @pytest.mark.parametrize(
        "B,S,H,P,N,chunk",
        [(2, 64, 4, 32, 16, 16), (1, 128, 8, 64, 32, 32), (2, 96, 2, 16, 8, 48)],
    )
    def test_sweep(self, B, S, H, P, N, chunk):
        from repro.kernels.ssd.ops import ssd_chunked as kern
        from repro.kernels.ssd.ref import ssd_chunked as ref

        rng = np.random.default_rng(S + H)
        x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
        dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, S, H)).astype(np.float32))
        A = jnp.asarray(-rng.uniform(0.5, 4.0, size=(H,)).astype(np.float32))
        B_ = jnp.asarray(rng.normal(size=(B, S, 1, N)).astype(np.float32))
        C_ = jnp.asarray(rng.normal(size=(B, S, 1, N)).astype(np.float32))
        D = jnp.asarray(rng.normal(size=(H,)).astype(np.float32))
        y1, s1 = kern(x, dt, A, B_, C_, D, chunk=chunk)
        y2, s2 = ref(x, dt, A, B_, C_, D, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)
