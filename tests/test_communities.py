import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.communities import (
    UnionFind, components_after_deletion, components_as_sets,
    connected_components, maximal_cliques, pairs_to_set, qa1, qa2,
)
from repro.core.types import PAD_ID


def union_find_components(n, edges):
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in edges:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    groups = {}
    for i in range(n):
        groups.setdefault(find(i), set()).add(i)
    return {frozenset(g) for g in groups.values() if len(g) >= 2}


@pytest.mark.parametrize("seed", range(60))
def test_cc_matches_union_find(seed):
    """Property test (seeded generator): connected_components on random
    edge lists must match a host union-find oracle."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 41))
    m = int(rng.integers(0, 81))
    raw = rng.integers(0, 40, size=(m, 2))
    edges = [(int(a) % n, int(b) % n) for a, b in raw if a % n != b % n]
    cap = max(len(edges), 1)
    left = np.full(cap, PAD_ID, np.int32)
    right = np.full(cap, PAD_ID, np.int32)
    for i, (a, b) in enumerate(edges):
        left[i], right[i] = a, b
    labels = connected_components(
        jnp.asarray(left), jnp.asarray(right), num_nodes=n
    )
    got = components_as_sets(np.asarray(labels))
    assert got == union_find_components(n, edges)


def _edges_to_arrays(edges, cap=None):
    cap = cap or max(len(edges), 1)
    left = np.full(cap, PAD_ID, np.int32)
    right = np.full(cap, PAD_ID, np.int32)
    for i, (a, b) in enumerate(edges):
        left[i], right[i] = a, b
    return jnp.asarray(left), jnp.asarray(right)


@pytest.mark.parametrize("seed", range(20))
def test_cc_warm_start_converges_to_cold_fixpoint(seed):
    """Incremental warm start (ISSUE 4): seeding min-label propagation with
    the stale fixpoint of any edge-prefix must converge to the exact same
    labels as a cold start over the full edge list."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 30))
    m = int(rng.integers(1, 50))
    edges = [(int(a), int(b)) for a, b in rng.integers(0, n, size=(m, 2))
             if a != b]
    cut = int(rng.integers(0, len(edges) + 1))
    l1, r1 = _edges_to_arrays(edges[:cut])
    stale = connected_components(l1, r1, num_nodes=n)
    l2, r2 = _edges_to_arrays(edges)
    cold = connected_components(l2, r2, num_nodes=n)
    warm = connected_components(l2, r2, num_nodes=n, init_labels=stale)
    np.testing.assert_array_equal(np.asarray(warm), np.asarray(cold))


def test_cc_warm_start_pad_only_and_zero_edge_update():
    """PAD_ID-only edge lists and a zero-edge update: the stale labels ARE
    the fixpoint and must come back unchanged."""
    n = 7
    l0, r0 = _edges_to_arrays([(0, 3), (4, 5)])
    stale = connected_components(l0, r0, num_nodes=n)
    pad_l, pad_r = _edges_to_arrays([], cap=4)  # all PAD_ID
    again = connected_components(pad_l, pad_r, num_nodes=n,
                                 init_labels=stale)
    np.testing.assert_array_equal(np.asarray(again), np.asarray(stale))
    # cold PAD-only with a warm seed of arange stays identity
    iden = connected_components(pad_l, pad_r, num_nodes=n,
                                init_labels=jnp.arange(n, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(iden), np.arange(n))


def test_cc_warm_start_bridge_merges_components():
    """A bridge edge arriving later must merge two previously disjoint
    components under the warm start, via the star edges of the stale
    labels (the streaming engine always feeds (label[v], v) stars)."""
    n = 6
    l0, r0 = _edges_to_arrays([(0, 1), (1, 2), (3, 4), (4, 5)])
    stale = np.asarray(connected_components(l0, r0, num_nodes=n))
    assert components_as_sets(stale) == {frozenset({0, 1, 2}),
                                         frozenset({3, 4, 5})}
    # streaming-style update: stars of the stale fixpoint + the bridge
    stars = [(int(stale[v]), v) for v in range(n)]
    l1, r1 = _edges_to_arrays(stars + [(2, 3)])
    warm = connected_components(l1, r1, num_nodes=n,
                                init_labels=jnp.asarray(stale))
    assert components_as_sets(np.asarray(warm)) == {
        frozenset(range(6))
    }
    np.testing.assert_array_equal(np.asarray(warm), np.zeros(n, np.int32))


@pytest.mark.parametrize("seed", range(20))
def test_union_find_matches_connected_components(seed):
    """The host union-find oracle (path compression + union by size) must
    produce the identical canonical labeling as the jit min-label
    propagation, for edges arriving in any micro-batch order."""
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(2, 40))
    m = int(rng.integers(0, 70))
    edges = [(int(a), int(b)) for a, b in rng.integers(0, n, size=(m, 2))
             if a != b]
    # nodes arrive in random increments (streaming-style); an edge is
    # unioned as soon as both endpoints exist
    uf = UnionFind()
    pending = [edges[i] for i in rng.permutation(len(edges))]
    while uf.num_nodes < n:
        uf.add(int(rng.integers(1, n - uf.num_nodes + 1)))
        ready = [e for e in pending if max(e) < uf.num_nodes]
        pending = [e for e in pending if max(e) >= uf.num_nodes]
        for a, b in ready:
            uf.union(a, b)
    assert not pending
    l, r = _edges_to_arrays(edges, cap=max(len(edges), 1))
    want = np.asarray(connected_components(l, r, num_nodes=n))
    np.testing.assert_array_equal(uf.labels(), want)
    assert uf.components() == components_as_sets(want)


def test_union_find_matches_bron_kerbosch_pair_membership():
    """QA2 unchanged: every Bron-Kerbosch-side similar pair keeps both
    endpoints in one union-find component (the components are exactly the
    unions of overlapping cliques), so the recovered pair set is 100%."""
    rng = np.random.default_rng(0)
    n = 24
    edges = {(int(a), int(b)) if a < b else (int(b), int(a))
             for a, b in rng.integers(0, n, size=(60, 2)) if a != b}
    uf = UnionFind(n)
    for a, b in edges:
        uf.union(a, b)
    labels = uf.labels()
    cliques = maximal_cliques(edges)
    # each maximal clique sits inside exactly one component
    for clique in cliques:
        assert len({int(labels[v]) for v in clique}) == 1
    # pair membership via the component labeling recovers every similar
    # pair: QA2 == 1.0 exactly
    pairs_in_components = {(a, b) for a, b in edges
                           if labels[a] == labels[b]}
    assert qa2(pairs_in_components, edges) == 1.0
    # and the clique vertex set partitions into the components
    covered = {v for c in cliques for v in c}
    comp_members = {v for c in uf.components() for v in c}
    assert covered == comp_members


def test_maximal_cliques_triangle_plus_edge():
    edges = [(0, 1), (1, 2), (0, 2), (2, 3)]
    cliques = maximal_cliques(edges)
    assert cliques == {frozenset({0, 1, 2}), frozenset({2, 3})}


def test_maximal_cliques_k4():
    import itertools

    edges = list(itertools.combinations(range(4), 2))
    assert maximal_cliques(edges) == {frozenset({0, 1, 2, 3})}


def test_qa_metrics():
    cen = {frozenset({1, 2}), frozenset({3, 4, 5})}
    dis_perfect = set(cen)
    dis_half = {frozenset({1, 2})}
    assert qa1(dis_perfect, cen) == 1.0
    assert qa1(dis_half, cen) == 0.5
    p_cen = {(1, 2), (3, 4)}
    assert qa2({(1, 2)}, p_cen) == 0.5
    assert qa2(p_cen, p_cen) == 1.0
    assert qa1(set(), set()) == 1.0


def test_pairs_to_set_ignores_padding():
    left = jnp.asarray([2, PAD_ID, 5], jnp.int32)
    right = jnp.asarray([1, PAD_ID, 7], jnp.int32)
    assert pairs_to_set(left, right) == {(1, 2), (5, 7)}


# ---------------------------------------------------------------------------
# edge expiry / deletion (ISSUE 8): communities must UN-merge
# ---------------------------------------------------------------------------
def test_bridge_deletion_splits_component():
    """Deleting the bridge node of a path splits the component — the case
    no incremental label update can discover (labels only merge downward
    under edge addition)."""
    l, r = _edges_to_arrays([(0, 1), (1, 2), (2, 3), (3, 4)])
    labels = np.asarray(connected_components(l, r, num_nodes=5))
    assert components_as_sets(labels) == {frozenset(range(5))}
    got = components_after_deletion(labels, [2], [(0, 1), (3, 4)])
    assert components_as_sets(got) == {frozenset({0, 1}), frozenset({3, 4})}
    np.testing.assert_array_equal(got, [0, 0, 2, 3, 3])


@pytest.mark.parametrize("seed", range(20))
def test_components_after_deletion_matches_cold_fixpoint(seed):
    """Property test: the warm re-solve (only touched components recompute)
    must be bit-identical to a cold fixpoint over the surviving edges, and
    ``reset_from_labels`` must re-enter the incremental path losslessly."""
    rng = np.random.default_rng(300 + seed)
    n = int(rng.integers(4, 32))
    m = int(rng.integers(0, 60))
    edges = [(int(a), int(b)) for a, b in rng.integers(0, n, size=(m, 2))
             if a != b]
    dead = sorted({int(x) for x in
                   rng.integers(0, n, size=int(rng.integers(1, n // 2 + 1)))})
    surviving = [e for e in edges if e[0] not in dead and e[1] not in dead]
    l, r = _edges_to_arrays(edges, cap=max(len(edges), 1))
    labels = np.asarray(connected_components(l, r, num_nodes=n))
    got = components_after_deletion(labels, dead, surviving)
    ls, rs = _edges_to_arrays(surviving, cap=max(len(surviving), 1))
    cold = np.asarray(connected_components(ls, rs, num_nodes=n))
    np.testing.assert_array_equal(got, cold)
    # warm-start under deletion: the union-find restored from the warm
    # labels stays in lockstep with a cold union-find on future unions
    uf_warm = UnionFind()
    uf_warm.reset_from_labels(got)
    uf_cold = UnionFind(n)
    for a, b in surviving:
        uf_cold.union(a, b)
    np.testing.assert_array_equal(uf_warm.labels(), uf_cold.labels())
    extra = [(int(a), int(b)) for a, b in rng.integers(0, n, size=(8, 2))
             if a != b]
    for a, b in extra:
        uf_warm.union(a, b)
        uf_cold.union(a, b)
    np.testing.assert_array_equal(uf_warm.labels(), uf_cold.labels())


def _bridge_world():
    """Five trajectories over an 8-place alphabet: two 'A' rows, a bridge
    'B', two 'C' rows.  A and C share NO places; B overlaps both — so at a
    rho between the cross-group MSS and the bridge MSS, the similarity
    graph is exactly A1-A2, A*-B, B-C*, C1-C2: one component held together
    by B alone."""
    from repro.data import synthetic_setup

    A = [1, 2, 3, 4]
    B = [3, 4, 5, 6]
    C = [5, 6, 7, 8]
    places = np.asarray([A, A, B, C, C], np.int32)
    lengths = np.full((5,), 4, np.int32)
    _, forest = synthetic_setup(
        5, num_types=3, classes_per_type=3, num_places=12,
        min_len=4, max_len=4, seed=2,
    )
    return places, lengths, forest


def _pick_bridge_rho(places, lengths, forest):
    """Compute every pair's MSS at rho ~ 0 and place rho strictly between
    the worst cross-group pair and the weakest edge we must keep."""
    from repro.api import AnotherMeEngine, EngineConfig
    from tests.test_streaming import make_batch, score_map

    # shingle order 2: the A/B and B/C overlaps are 2-place runs
    probe = AnotherMeEngine(
        forest, EngineConfig(rho=1e-6, k=2, community_mode="components")
    ).run(make_batch(places, lengths))
    mss = {pair: v[0] for pair, v in score_map(probe).items()}
    keep = [(0, 1), (0, 2), (1, 2), (2, 3), (2, 4), (3, 4)]
    cross = [(0, 3), (0, 4), (1, 3), (1, 4)]
    lo = max((mss.get(p, 0.0) for p in cross), default=0.0)
    hi = min(mss[p] for p in keep)
    assert lo < hi, f"bridge premise violated: cross {lo} >= keep {hi}"
    return (lo + hi) / 2.0


@pytest.mark.parametrize("components_impl", ("unionfind", "jit"))
def test_engine_bridge_expiry_splits_then_reforms(components_impl):
    """Engine-level bridge property: retiring the bridge trajectory splits
    the community; re-ingesting an identical trajectory re-forms it, and
    the rebuilt world matches a fresh engine over the live rows."""
    from repro.api import EngineConfig, StreamingEngine
    from tests.test_streaming import make_batch, score_map

    places, lengths, forest = _bridge_world()
    rho = _pick_bridge_rho(places, lengths, forest)
    cfg = EngineConfig(rho=rho, k=2, community_mode="components")
    stream = StreamingEngine(forest, cfg, components_impl=components_impl)
    res = stream.update(make_batch(places, lengths))
    assert res.similar_pairs == {(0, 1), (0, 2), (1, 2), (2, 3), (2, 4),
                                 (3, 4)}
    assert res.communities == {frozenset(range(5))}
    # expire the bridge: one community must SPLIT into two
    assert stream.retire([2]) == 1
    res = stream.update(make_batch(np.zeros((0, 1), np.int32),
                                   np.zeros((0,), np.int32)))
    assert res.communities == {frozenset({0, 1}), frozenset({3, 4})}
    assert res.similar_pairs == {(0, 1), (3, 4)}
    # re-ingest an identical bridge (fresh id 5): the community re-forms
    res = stream.update(make_batch(places[2:3], lengths[2:3]))
    assert res.communities == {frozenset({0, 1, 3, 4, 5})}
    # expire-then-reinsert == fresh: identical to an engine that only ever
    # saw the surviving rows (ids translated 3->2, 4->3, 5->4)
    fresh_places = np.concatenate([places[:2], places[3:], places[2:3]])
    fresh_lengths = np.concatenate([lengths[:2], lengths[3:], lengths[2:3]])
    fresh = StreamingEngine(
        forest, cfg, components_impl=components_impl
    ).update(make_batch(fresh_places, fresh_lengths))
    trans = {0: 0, 1: 1, 3: 2, 4: 3, 5: 4}
    got_pairs = {(trans[a], trans[b]): v
                 for (a, b), v in score_map(res).items()}
    assert got_pairs == score_map(fresh)
    assert {frozenset(trans[v] for v in c) for c in res.communities} \
        == fresh.communities
