import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.communities import (
    UnionFind, components_as_sets, connected_components, maximal_cliques,
    pairs_to_set, qa1, qa2,
)
from repro.core.types import PAD_ID


def union_find_components(n, edges):
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in edges:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    groups = {}
    for i in range(n):
        groups.setdefault(find(i), set()).add(i)
    return {frozenset(g) for g in groups.values() if len(g) >= 2}


@pytest.mark.parametrize("seed", range(60))
def test_cc_matches_union_find(seed):
    """Property test (seeded generator): connected_components on random
    edge lists must match a host union-find oracle."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 41))
    m = int(rng.integers(0, 81))
    raw = rng.integers(0, 40, size=(m, 2))
    edges = [(int(a) % n, int(b) % n) for a, b in raw if a % n != b % n]
    cap = max(len(edges), 1)
    left = np.full(cap, PAD_ID, np.int32)
    right = np.full(cap, PAD_ID, np.int32)
    for i, (a, b) in enumerate(edges):
        left[i], right[i] = a, b
    labels = connected_components(
        jnp.asarray(left), jnp.asarray(right), num_nodes=n
    )
    got = components_as_sets(np.asarray(labels))
    assert got == union_find_components(n, edges)


def _edges_to_arrays(edges, cap=None):
    cap = cap or max(len(edges), 1)
    left = np.full(cap, PAD_ID, np.int32)
    right = np.full(cap, PAD_ID, np.int32)
    for i, (a, b) in enumerate(edges):
        left[i], right[i] = a, b
    return jnp.asarray(left), jnp.asarray(right)


@pytest.mark.parametrize("seed", range(20))
def test_cc_warm_start_converges_to_cold_fixpoint(seed):
    """Incremental warm start (ISSUE 4): seeding min-label propagation with
    the stale fixpoint of any edge-prefix must converge to the exact same
    labels as a cold start over the full edge list."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 30))
    m = int(rng.integers(1, 50))
    edges = [(int(a), int(b)) for a, b in rng.integers(0, n, size=(m, 2))
             if a != b]
    cut = int(rng.integers(0, len(edges) + 1))
    l1, r1 = _edges_to_arrays(edges[:cut])
    stale = connected_components(l1, r1, num_nodes=n)
    l2, r2 = _edges_to_arrays(edges)
    cold = connected_components(l2, r2, num_nodes=n)
    warm = connected_components(l2, r2, num_nodes=n, init_labels=stale)
    np.testing.assert_array_equal(np.asarray(warm), np.asarray(cold))


def test_cc_warm_start_pad_only_and_zero_edge_update():
    """PAD_ID-only edge lists and a zero-edge update: the stale labels ARE
    the fixpoint and must come back unchanged."""
    n = 7
    l0, r0 = _edges_to_arrays([(0, 3), (4, 5)])
    stale = connected_components(l0, r0, num_nodes=n)
    pad_l, pad_r = _edges_to_arrays([], cap=4)  # all PAD_ID
    again = connected_components(pad_l, pad_r, num_nodes=n,
                                 init_labels=stale)
    np.testing.assert_array_equal(np.asarray(again), np.asarray(stale))
    # cold PAD-only with a warm seed of arange stays identity
    iden = connected_components(pad_l, pad_r, num_nodes=n,
                                init_labels=jnp.arange(n, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(iden), np.arange(n))


def test_cc_warm_start_bridge_merges_components():
    """A bridge edge arriving later must merge two previously disjoint
    components under the warm start, via the star edges of the stale
    labels (the streaming engine always feeds (label[v], v) stars)."""
    n = 6
    l0, r0 = _edges_to_arrays([(0, 1), (1, 2), (3, 4), (4, 5)])
    stale = np.asarray(connected_components(l0, r0, num_nodes=n))
    assert components_as_sets(stale) == {frozenset({0, 1, 2}),
                                         frozenset({3, 4, 5})}
    # streaming-style update: stars of the stale fixpoint + the bridge
    stars = [(int(stale[v]), v) for v in range(n)]
    l1, r1 = _edges_to_arrays(stars + [(2, 3)])
    warm = connected_components(l1, r1, num_nodes=n,
                                init_labels=jnp.asarray(stale))
    assert components_as_sets(np.asarray(warm)) == {
        frozenset(range(6))
    }
    np.testing.assert_array_equal(np.asarray(warm), np.zeros(n, np.int32))


@pytest.mark.parametrize("seed", range(20))
def test_union_find_matches_connected_components(seed):
    """The host union-find oracle (path compression + union by size) must
    produce the identical canonical labeling as the jit min-label
    propagation, for edges arriving in any micro-batch order."""
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(2, 40))
    m = int(rng.integers(0, 70))
    edges = [(int(a), int(b)) for a, b in rng.integers(0, n, size=(m, 2))
             if a != b]
    # nodes arrive in random increments (streaming-style); an edge is
    # unioned as soon as both endpoints exist
    uf = UnionFind()
    pending = [edges[i] for i in rng.permutation(len(edges))]
    while uf.num_nodes < n:
        uf.add(int(rng.integers(1, n - uf.num_nodes + 1)))
        ready = [e for e in pending if max(e) < uf.num_nodes]
        pending = [e for e in pending if max(e) >= uf.num_nodes]
        for a, b in ready:
            uf.union(a, b)
    assert not pending
    l, r = _edges_to_arrays(edges, cap=max(len(edges), 1))
    want = np.asarray(connected_components(l, r, num_nodes=n))
    np.testing.assert_array_equal(uf.labels(), want)
    assert uf.components() == components_as_sets(want)


def test_union_find_matches_bron_kerbosch_pair_membership():
    """QA2 unchanged: every Bron-Kerbosch-side similar pair keeps both
    endpoints in one union-find component (the components are exactly the
    unions of overlapping cliques), so the recovered pair set is 100%."""
    rng = np.random.default_rng(0)
    n = 24
    edges = {(int(a), int(b)) if a < b else (int(b), int(a))
             for a, b in rng.integers(0, n, size=(60, 2)) if a != b}
    uf = UnionFind(n)
    for a, b in edges:
        uf.union(a, b)
    labels = uf.labels()
    cliques = maximal_cliques(edges)
    # each maximal clique sits inside exactly one component
    for clique in cliques:
        assert len({int(labels[v]) for v in clique}) == 1
    # pair membership via the component labeling recovers every similar
    # pair: QA2 == 1.0 exactly
    pairs_in_components = {(a, b) for a, b in edges
                           if labels[a] == labels[b]}
    assert qa2(pairs_in_components, edges) == 1.0
    # and the clique vertex set partitions into the components
    covered = {v for c in cliques for v in c}
    comp_members = {v for c in uf.components() for v in c}
    assert covered == comp_members


def test_maximal_cliques_triangle_plus_edge():
    edges = [(0, 1), (1, 2), (0, 2), (2, 3)]
    cliques = maximal_cliques(edges)
    assert cliques == {frozenset({0, 1, 2}), frozenset({2, 3})}


def test_maximal_cliques_k4():
    import itertools

    edges = list(itertools.combinations(range(4), 2))
    assert maximal_cliques(edges) == {frozenset({0, 1, 2, 3})}


def test_qa_metrics():
    cen = {frozenset({1, 2}), frozenset({3, 4, 5})}
    dis_perfect = set(cen)
    dis_half = {frozenset({1, 2})}
    assert qa1(dis_perfect, cen) == 1.0
    assert qa1(dis_half, cen) == 0.5
    p_cen = {(1, 2), (3, 4)}
    assert qa2({(1, 2)}, p_cen) == 0.5
    assert qa2(p_cen, p_cen) == 1.0
    assert qa1(set(), set()) == 1.0


def test_pairs_to_set_ignores_padding():
    left = jnp.asarray([2, PAD_ID, 5], jnp.int32)
    right = jnp.asarray([1, PAD_ID, 7], jnp.int32)
    assert pairs_to_set(left, right) == {(1, 2), (5, 7)}
