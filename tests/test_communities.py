import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.communities import (
    components_as_sets, connected_components, maximal_cliques, pairs_to_set,
    qa1, qa2,
)
from repro.core.types import PAD_ID


def union_find_components(n, edges):
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in edges:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    groups = {}
    for i in range(n):
        groups.setdefault(find(i), set()).add(i)
    return {frozenset(g) for g in groups.values() if len(g) >= 2}


@pytest.mark.parametrize("seed", range(60))
def test_cc_matches_union_find(seed):
    """Property test (seeded generator): connected_components on random
    edge lists must match a host union-find oracle."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 41))
    m = int(rng.integers(0, 81))
    raw = rng.integers(0, 40, size=(m, 2))
    edges = [(int(a) % n, int(b) % n) for a, b in raw if a % n != b % n]
    cap = max(len(edges), 1)
    left = np.full(cap, PAD_ID, np.int32)
    right = np.full(cap, PAD_ID, np.int32)
    for i, (a, b) in enumerate(edges):
        left[i], right[i] = a, b
    labels = connected_components(
        jnp.asarray(left), jnp.asarray(right), num_nodes=n
    )
    got = components_as_sets(np.asarray(labels))
    assert got == union_find_components(n, edges)


def test_maximal_cliques_triangle_plus_edge():
    edges = [(0, 1), (1, 2), (0, 2), (2, 3)]
    cliques = maximal_cliques(edges)
    assert cliques == {frozenset({0, 1, 2}), frozenset({2, 3})}


def test_maximal_cliques_k4():
    import itertools

    edges = list(itertools.combinations(range(4), 2))
    assert maximal_cliques(edges) == {frozenset({0, 1, 2, 3})}


def test_qa_metrics():
    cen = {frozenset({1, 2}), frozenset({3, 4, 5})}
    dis_perfect = set(cen)
    dis_half = {frozenset({1, 2})}
    assert qa1(dis_perfect, cen) == 1.0
    assert qa1(dis_half, cen) == 0.5
    p_cen = {(1, 2), (3, 4)}
    assert qa2({(1, 2)}, p_cen) == 0.5
    assert qa2(p_cen, p_cen) == 1.0
    assert qa1(set(), set()) == 1.0


def test_pairs_to_set_ignores_padding():
    left = jnp.asarray([2, PAD_ID, 5], jnp.int32)
    right = jnp.asarray([1, PAD_ID, 7], jnp.int32)
    assert pairs_to_set(left, right) == {(1, 2), (5, 7)}
