"""Bounded-memory retirement suite (ISSUE 8 tentpole + satellites).

Pins every layer of the deletion machinery in isolation, below the
end-to-end differential harness (tests/test_stream_join_differential.py):

  * tombstone kernels — ``mark_dead_rows``, drop-mode ``compact_slab``
    (jitted + vmapped, with row-id rebasing), and the tombstone-masked
    ``probe_pairs`` / ``probe_rows`` — against their numpy references,
    including the contract that tombstoned slots are EXAMINED (the exact
    work accounting survives deletion) but never EMITTED;
  * TTL / sliding-window timing: a row ingested at update U with ttl T is
    gone at the start of update U + T, and ``window=N`` ceilings any ttl;
  * ``retire`` validation + idempotency;
  * admission control: ``CapacityExceeded`` refuses an over-budget update
    BEFORE any mutation — the world is bit-identical afterwards, and the
    same batch succeeds once the budget is lifted (satellite 1);
  * the host ``BucketIndex`` hot-bucket lists stay bounded by LIVE
    membership under a sliding window, with results still exact
    (satellite 2 — the regression for the unbounded-driver-list wall);
  * the planning mirrors: ``StreamJoinStats`` retire/compact ledger,
    ``ShardSummaries.rebuild``, ``UnionFind.reset_from_labels``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    AnotherMeEngine, CapacityExceeded, EngineConfig, ExecutionPlan,
    StreamingEngine,
)
from repro.core.communities import UnionFind
from repro.core.device_index import (
    StreamJoinStats, ShardSummaries, compact_slab, compact_slab_ref,
    mark_dead_rows, probe_pairs, probe_pairs_ref, probe_rows, probe_rows_ref,
)
from repro.core.types import PAD_ID, PAD_KEY, TrajectoryBatch
from repro.data import synthetic_setup

from tests.test_streaming import make_batch, score_map, random_world


def empty_batch():
    return make_batch(np.zeros((0, 1), np.int32), np.zeros((0,), np.int32))


# ---------------------------------------------------------------------------
# kernel golden tests
# ---------------------------------------------------------------------------
def make_slab(entries, cap):
    """Sorted slab from (key, row) pairs; tombstones keep their key with
    row == PAD_ID, exactly the post-``mark_dead_rows`` state."""
    entries = sorted(entries, key=lambda kr: kr[0])
    kk = np.full((cap,), PAD_KEY, np.int32)
    rr = np.full((cap,), PAD_ID, np.int32)
    for i, (k, r) in enumerate(entries):
        kk[i], rr[i] = k, r
    return kk, rr


def pad_flat(vals, cap, pad):
    out = np.full((cap,), pad, np.int32)
    out[: len(vals)] = vals
    return out


def test_mark_dead_rows_matches_reference():
    rng = np.random.default_rng(7)
    for trial in range(20):
        cap = int(rng.integers(4, 64))
        n_live = int(rng.integers(0, cap))
        kk, rr = make_slab(
            [(int(rng.integers(0, 9)), 100 + i) for i in range(n_live)], cap
        )
        dead = rng.choice(np.arange(100, 100 + max(n_live, 1)),
                          size=int(rng.integers(0, n_live + 1)),
                          replace=False)
        dead_cap = 1 << max(int(np.ceil(np.log2(max(dead.size, 1)))), 2)
        dead_sorted = pad_flat(np.sort(dead).tolist(), dead_cap, PAD_ID)
        got = np.asarray(mark_dead_rows(jnp.asarray(rr),
                                        jnp.asarray(dead_sorted)))
        dead_set = set(dead.tolist())
        want = np.array(
            [PAD_ID if r in dead_set else r for r in rr.tolist()], np.int32
        )
        np.testing.assert_array_equal(got, want)
        # idempotent: marking again changes nothing
        np.testing.assert_array_equal(
            np.asarray(mark_dead_rows(jnp.asarray(got),
                                      jnp.asarray(dead_sorted))), want
        )


@pytest.mark.parametrize("out_cap_mode", ("same", "shrink", "grow", "tight"))
def test_compact_slab_matches_reference(out_cap_mode):
    rng = np.random.default_rng(11)
    compact_j = jax.jit(compact_slab, static_argnames=("out_cap",))
    for trial in range(12):
        cap = int(rng.integers(8, 64))
        n_ent = int(rng.integers(0, cap))
        entries = []
        for i in range(n_ent):
            row = 100 + i if rng.random() > 0.4 else PAD_ID  # tombstone
            entries.append((int(rng.integers(0, 9)), row))
        kk, rr = make_slab(entries, cap)
        live = int(np.sum(rr != PAD_ID))
        shift = int(rng.integers(0, 50))
        out_cap = {
            "same": cap, "shrink": max(cap // 2, 1), "grow": cap + 8,
            "tight": max(live, 1),
        }[out_cap_mode]
        ko, ro, lv, ov = compact_j(
            jnp.asarray(kk), jnp.asarray(rr),
            jnp.asarray(shift, jnp.int32), out_cap=out_cap,
        )
        wk, wr, wlive, wov = compact_slab_ref(kk, rr, shift, out_cap)
        np.testing.assert_array_equal(np.asarray(ko), wk)
        np.testing.assert_array_equal(np.asarray(ro), wr)
        assert int(lv) == wlive == live
        assert int(ov) == wov == max(live - out_cap, 0)


def test_compact_slab_vmapped_over_shards():
    """The engine's actual call shape: vmap over the shard axis with one
    broadcast shift operand."""
    rng = np.random.default_rng(13)
    cap, n_sh = 16, 4
    kks, rrs = [], []
    for _ in range(n_sh):
        ent = [(int(rng.integers(0, 6)),
                200 + i if rng.random() > 0.5 else PAD_ID)
               for i in range(int(rng.integers(0, cap)))]
        kk, rr = make_slab(ent, cap)
        kks.append(kk)
        rrs.append(rr)
    fn = jax.jit(
        jax.vmap(
            lambda k, r, s: compact_slab(k, r, s, out_cap=cap),
            in_axes=(0, 0, None),
        )
    )
    ko, ro, lv, ov = fn(jnp.asarray(np.stack(kks)), jnp.asarray(np.stack(rrs)),
                        jnp.asarray(100, jnp.int32))
    for s in range(n_sh):
        wk, wr, wlive, wov = compact_slab_ref(kks[s], rrs[s], 100, cap)
        np.testing.assert_array_equal(np.asarray(ko[s]), wk)
        np.testing.assert_array_equal(np.asarray(ro[s]), wr)
        assert int(lv[s]) == wlive and int(ov[s]) == wov == 0


def test_probe_pairs_tombstones_examined_not_emitted():
    """The deletion contract pinned exactly: a key run holding 2 live rows
    and 1 tombstone costs 3 examined slots per probe but emits 2 pairs."""
    kk, rr = make_slab([(5, 10), (5, PAD_ID), (5, 12)], cap=8)
    keys = pad_flat([5], 4, PAD_KEY)
    rows = pad_flat([20], 4, PAD_ID)
    lo, hi, examined, overflow = probe_pairs(
        jnp.asarray(kk), jnp.asarray(rr), jnp.asarray(keys),
        jnp.asarray(rows), nn_cap=4, no_cap=8,
    )
    got = sorted(
        (int(a), int(b)) for a, b in
        zip(np.asarray(lo), np.asarray(hi)) if a != PAD_ID
    )
    assert got == [(10, 20), (12, 20)]
    assert int(examined) == 3  # the tombstone slot is still examined
    assert int(overflow) == 0


def test_probe_pairs_matches_reference_under_tombstones():
    rng = np.random.default_rng(17)
    for trial in range(10):
        cap = 64
        ent = [(int(rng.integers(0, 7)),
                100 + i if rng.random() > 0.3 else PAD_ID)
               for i in range(int(rng.integers(0, 40)))]
        kk, rr = make_slab(ent, cap)
        nq = int(rng.integers(0, 12))
        keys = pad_flat([int(rng.integers(0, 7)) for _ in range(nq)],
                        16, PAD_KEY)
        rows = pad_flat([500 + i for i in range(nq)], 16, PAD_ID)
        lo, hi, examined, overflow = probe_pairs(
            jnp.asarray(kk), jnp.asarray(rr), jnp.asarray(keys),
            jnp.asarray(rows), nn_cap=256, no_cap=256,
        )
        want_pairs, want_examined = probe_pairs_ref(kk, rr, keys, rows)
        got = sorted(
            (int(a), int(b)) for a, b in
            zip(np.asarray(lo), np.asarray(hi)) if a != PAD_ID
        )
        assert got == sorted(want_pairs)
        assert int(examined) == want_examined
        assert int(overflow) == 0


def test_probe_rows_matches_reference_under_tombstones():
    rng = np.random.default_rng(19)
    for trial in range(10):
        ent = [(int(rng.integers(0, 6)),
                100 + i if rng.random() > 0.3 else PAD_ID)
               for i in range(int(rng.integers(0, 30)))]
        kk, rr = make_slab(ent, 48)
        nq = int(rng.integers(0, 10))
        keys = pad_flat([int(rng.integers(0, 6)) for _ in range(nq)],
                        16, PAD_KEY)
        payload = pad_flat(list(range(nq)), 16, PAD_ID)
        rows, out_pay, examined, overflow = probe_rows(
            jnp.asarray(kk), jnp.asarray(rr), jnp.asarray(keys),
            jnp.asarray(payload), cap=256,
        )
        want_matches, want_examined = probe_rows_ref(kk, rr, keys, payload)
        got = sorted(
            (int(m), int(p)) for m, p in
            zip(np.asarray(rows), np.asarray(out_pay)) if m != PAD_ID
        )
        assert got == sorted(want_matches)
        assert int(examined) == want_examined
        assert int(overflow) == 0


# ---------------------------------------------------------------------------
# TTL / sliding-window timing semantics
# ---------------------------------------------------------------------------
def small_world(n=8, seed=0):
    batch, forest = random_world(seed, n=n)
    return batch, forest


def test_ttl_row_expires_at_start_of_ttl_th_update():
    """A row ingested at update U with ttl T is retired at the START of
    update U + T — it survives exactly T - 1 further updates."""
    batch, forest = small_world()
    stream = StreamingEngine(forest, EngineConfig(rho=2.0))
    stream.update(batch, ttl=2)
    d = batch.num_trajectories
    assert stream.live_size == d
    stream.update(empty_batch())         # update 1: still inside the ttl
    assert stream.live_size == d
    res = stream.update(empty_batch())   # update 2 = U + T: swept on entry
    assert stream.live_size == 0
    assert res.stats["num_expired"] == d
    assert stream.retired_total == d


def test_window_ceilings_any_ttl():
    """``window=N`` caps every row's residency at N updates, even when an
    explicit longer ttl is passed (and supplies the default when none is)."""
    batch, forest = small_world()
    d = batch.num_trajectories
    stream = StreamingEngine(forest, EngineConfig(rho=2.0), window=1)
    stream.update(batch, ttl=5)          # ceiling: min(5, 1) = 1
    assert stream.live_size == d
    stream.update(empty_batch())
    assert stream.live_size == 0
    stream2 = StreamingEngine(forest, EngineConfig(rho=2.0), window=2)
    stream2.update(batch)                # no ttl: the window is the default
    stream2.update(empty_batch())
    assert stream2.live_size == d
    stream2.update(empty_batch())
    assert stream2.live_size == 0


def test_no_ttl_no_window_never_expires():
    batch, forest = small_world()
    stream = StreamingEngine(forest, EngineConfig(rho=2.0))
    stream.update(batch)
    for _ in range(4):
        stream.update(empty_batch())
    assert stream.live_size == batch.num_trajectories
    assert stream.retired_total == 0


# ---------------------------------------------------------------------------
# retire(): validation + idempotency
# ---------------------------------------------------------------------------
def test_retire_validates_and_is_idempotent():
    batch, forest = small_world()
    stream = StreamingEngine(forest, EngineConfig(rho=2.0))
    stream.update(batch)
    n = stream.world_size
    with pytest.raises(ValueError, match="cannot retire"):
        stream.retire([n])
    with pytest.raises(ValueError, match="cannot retire"):
        stream.retire([-1])
    assert stream.live_size == n  # refused calls changed nothing
    assert stream.retire([0, 1]) == 2
    assert stream.live_size == n - 2
    assert stream.retire([0, 1]) == 0   # already dead: idempotent no-op
    assert stream.retire([1, 2]) == 1   # mixed: only the live one counts
    assert stream.retired_total == 3


# ---------------------------------------------------------------------------
# satellite 1: admission control refuses BEFORE mutating
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("delta_join", ("host", "device"))
def test_admission_refusal_leaves_world_untouched(delta_join):
    batch, forest = random_world(3, n=24)
    cfg = EngineConfig(rho=2.0, community_mode="components")
    plan = ExecutionPlan(delta_join=delta_join)
    small = make_batch(np.asarray(batch.places)[:4],
                       np.asarray(batch.lengths)[:4])
    big = make_batch(np.asarray(batch.places)[4:],
                     np.asarray(batch.lengths)[4:])
    stream = StreamingEngine(forest, cfg, plan)
    twin = StreamingEngine(forest, cfg, plan)
    stream.update(small)
    twin.update(small)
    # budget == current residency: any growth must be refused
    stream.max_resident_bytes = stream.resident_bytes()
    snap = (
        stream.world_size, stream.live_size, stream.updates, stream._base,
        stream.resident_bytes(), stream._acc_n,
        stream._alive_np.copy(), stream._expiry_np.copy(),
    )
    with pytest.raises(CapacityExceeded) as exc:
        stream.update(big)
    assert exc.value.needed_bytes > exc.value.budget_bytes
    after = (
        stream.world_size, stream.live_size, stream.updates, stream._base,
        stream.resident_bytes(), stream._acc_n,
        stream._alive_np.copy(), stream._expiry_np.copy(),
    )
    for a, b in zip(snap, after):
        np.testing.assert_array_equal(a, b)
    # lift the budget: the SAME batch goes through, and the refusal left
    # no residue — the stream matches a twin that was never refused
    stream.max_resident_bytes = None
    got = stream.update(big)
    want = twin.update(big)
    assert score_map(got) == score_map(want)
    assert got.communities == want.communities


def test_admission_refusal_at_construction_budget():
    """A budget too small for even the first batch refuses update #1 and
    the engine stays empty and usable."""
    batch, forest = small_world()
    stream = StreamingEngine(
        forest, EngineConfig(rho=2.0), max_resident_bytes=8,
    )
    with pytest.raises(CapacityExceeded):
        stream.update(batch)
    assert stream.world_size == 0 and stream.updates == 0
    stream.max_resident_bytes = None
    res = stream.update(batch)
    want = AnotherMeEngine(forest, EngineConfig(rho=2.0)).run(batch)
    assert score_map(res) == score_map(want)


# ---------------------------------------------------------------------------
# satellite 2: hot buckets stay bounded under a sliding window
# ---------------------------------------------------------------------------
def test_hot_bucket_bounded_by_live_membership_under_window():
    """A pathological world — every row produces the SAME keys — grows one
    driver bucket list linearly in total ingested rows (the documented
    quadratic wall).  Under ``window=2`` the eager host eviction keeps the
    bucket at LIVE membership: the list plateaus instead of growing, and
    the join stays exact."""
    d, updates = 5, 6
    places = np.tile(np.asarray([[3, 4, 5, 6]], np.int32), (d, 1))
    lengths = np.full((d,), 4, np.int32)
    _, forest = synthetic_setup(
        d, num_types=4, classes_per_type=3, num_places=16,
        min_len=4, max_len=4, seed=9,
    )
    cfg = EngineConfig(rho=2.0, community_mode="components")
    stream = StreamingEngine(forest, cfg, window=2)
    unbounded = StreamingEngine(forest, cfg)
    peaks, peaks_unbounded = [], []
    res = None
    for u in range(updates):
        res = stream.update(make_batch(places, lengths))
        unbounded.update(make_batch(places, lengths))
        assert stream._index.max_bucket_len() <= stream.live_size
        peaks.append(stream._index.max_bucket_len())
        peaks_unbounded.append(unbounded._index.max_bucket_len())
    # bounded: the windowed peak plateaus at the steady-state live count
    assert peaks[-1] == peaks[1] == 2 * d
    # ...while the unwindowed engine's hot bucket keeps growing
    assert peaks_unbounded[-1] == updates * d
    # and the windowed world is still EXACT: final result == one-shot
    # over the rows still inside the window
    span = stream.n - stream._base
    live = np.nonzero(stream._alive_np[:span])[0] + stream._base
    assert live.size == 2 * d and np.all(np.diff(live) == 1)
    want = AnotherMeEngine(forest, cfg).run(make_batch(
        np.tile(places, (2, 1)), np.tile(lengths, 2),
    ))
    got_pairs = {
        (int(a) - int(live[0]), int(b) - int(live[0]))
        for (a, b) in score_map(res)
    }
    assert got_pairs == set(score_map(want))


# ---------------------------------------------------------------------------
# planning mirrors
# ---------------------------------------------------------------------------
def test_stream_join_stats_retire_compact_ledger():
    st = StreamJoinStats(2)
    k = np.asarray([5, 5, 9, 9, 9], np.int32)
    o = np.asarray([1, 1, 0, 0, 0], np.int32)
    st.commit(k, o)
    assert st.counts == {5: 2, 9: 3}
    np.testing.assert_array_equal(st.owner_entries, [3, 2])
    assert st.dead_fraction() == 0.0
    # retire one row's occurrences: counts stay (tombstones still occupy
    # and are examined), only the dead ledger grows
    st.retire(np.asarray([9, 9, 9], np.int32), np.asarray([0, 0, 0], np.int32))
    assert st.counts == {5: 2, 9: 3}
    np.testing.assert_array_equal(st.owner_entries, [3, 2])
    assert st.dead_counts == {9: 3}
    assert st.dead_fraction() == pytest.approx(1.0)  # owner 0 fully dead
    # a fresh arrival under tombstones plans against the UNREclaimed
    # counts — new-vs-old covers the tombstoned slots it will examine
    nvo, nvn, ent = st.plan_update(
        np.asarray([9], np.int32), np.asarray([0], np.int32)
    )
    assert nvo[0] == 3 and nvn[0] == 0 and ent[0] == 1
    # compaction reclaims: emptied keys drop, partial keys shrink
    st.retire(np.asarray([5], np.int32), np.asarray([1], np.int32))
    st.compact()
    assert st.counts == {5: 1}
    assert st.dead_counts == {}
    np.testing.assert_array_equal(st.owner_entries, [0, 1])
    np.testing.assert_array_equal(st.owner_dead, [0, 0])
    assert st.dead_fraction() == 0.0


def test_bucket_index_full_join_size_is_live_not_lifetime():
    """``full_join_size()`` tracks the LIVE ``sum_buckets C(|b|, 2)``
    under interleaved insert/retire, while ``pairs_examined_total`` stays
    the monotone lifetime count (ISSUE 10 satellite: the two coincided in
    insert-only worlds and silently diverged once ``retire`` landed —
    lifetime overstates the one-shot bound of the current world)."""
    from repro.core.stream_index import BucketIndex

    rng = np.random.default_rng(7)
    idx = BucketIndex(hot_bucket_warn=None)
    kept: dict[int, np.ndarray] = {}
    next_id = 0

    def brute_live() -> int:
        return sum(
            len(m) * (len(m) - 1) // 2 for m in idx._buckets.values()
        )

    for step in range(6):
        d = int(rng.integers(2, 6))
        keys = rng.integers(0, 9, size=(d, 4)).astype(np.int32)
        keys[rng.random(size=keys.shape) < 0.25] = PAD_KEY
        idx.insert(keys)
        for r in range(d):
            kept[next_id] = keys[r]
            next_id += 1
        assert idx.full_join_size() == brute_live()
        if step == 0:
            # insert-only world: live == lifetime by construction
            assert idx.full_join_size() == idx.pairs_examined_total

        live_ids = sorted(kept)
        ret = rng.choice(live_ids, size=min(2, len(live_ids)), replace=False)
        ret_keys = np.stack([kept.pop(int(i)) for i in ret])
        lifetime_before = idx.pairs_examined_total
        idx.retire(ret, ret_keys)
        # retire evicts live pairs but never rewrites the work ledger
        assert idx.pairs_examined_total == lifetime_before
        assert idx.full_join_size() == brute_live()
        idx.retire(ret, ret_keys)  # idempotent: no double decrement
        assert idx.full_join_size() == brute_live()

    # the live count equals a FRESH index built over only the live rows
    fresh = BucketIndex(hot_bucket_warn=None)
    fresh.insert(np.stack([kept[i] for i in sorted(kept)]))
    assert fresh.full_join_size() == idx.full_join_size()
    assert fresh.pairs_examined_total == fresh.full_join_size()
    # lifetime is a (strict, here) upper bound on the live join size
    assert idx.pairs_examined_total > idx.full_join_size()


def test_shard_summaries_rebuild_matches_bruteforce():
    rng = np.random.default_rng(23)
    for n_sh in (1, 2, 4):
        for trial in range(5):
            n = int(rng.integers(0, 40))
            first = int(rng.integers(0, 3)) * n_sh  # base stays owner-aligned
            lengths = rng.integers(1, 12, size=n).astype(np.int64)
            alive = rng.random(n) > 0.4
            s = ShardSummaries(n_sh)
            s.rebuild(first, lengths, alive)
            rows = np.zeros(n_sh, np.int64)
            max_len = np.zeros(n_sh, np.int64)
            for i in range(n):
                if alive[i]:
                    sh = (first + i) % n_sh
                    rows[sh] += 1
                    max_len[sh] = max(max_len[sh], lengths[i])
            np.testing.assert_array_equal(s.rows, rows)
            np.testing.assert_array_equal(s.max_len, max_len)


def test_union_find_reset_from_labels_roundtrip():
    rng = np.random.default_rng(29)
    n = 24
    uf = UnionFind(n)
    edges = [(int(a), int(b)) for a, b in rng.integers(0, n, size=(20, 2))]
    for a, b in edges:
        uf.union(a, b)
    labels = uf.labels()
    uf2 = UnionFind()
    uf2.reset_from_labels(labels)
    np.testing.assert_array_equal(uf2.labels(), labels)
    # the restored forest keeps working incrementally in lockstep
    more = [(int(a), int(b)) for a, b in rng.integers(0, n, size=(10, 2))]
    for a, b in more:
        assert uf.union(a, b) == uf2.union(a, b)
    np.testing.assert_array_equal(uf2.labels(), uf.labels())
    uf2.add(4)  # growth after a reset stays consistent
    uf.add(4)
    uf.union(n, n + 3)
    uf2.union(n, n + 3)
    np.testing.assert_array_equal(uf2.labels(), uf.labels())
