"""Data plane: synthetic generator stats, GeoLife surrogate (incl. the GPS
round-trip through stay-point detection), SSH token dedup recall."""
import numpy as np

from repro.core.types import PAD_PLACE
from repro.data.geolife import _stay_points, geolife_surrogate
from repro.data.synthetic import synthetic_setup, synthetic_trajectories
from repro.data.tokens import TokenDataset, ssh_dedup, synthetic_corpus, vocab_forest


def test_synthetic_matches_paper_spec():
    batch, forest = synthetic_setup(500, seed=0)
    assert forest.sizes == (30, 300, 10_000)
    lengths = np.asarray(batch.lengths)
    assert lengths.min() >= 5 and lengths.max() <= 10
    places = np.asarray(batch.places)
    valid = places[places != PAD_PLACE]
    assert valid.min() >= 0 and valid.max() < 10_000


def test_synthetic_repetition():
    batch = synthetic_trajectories(500, repeat_prob=0.5, seed=1)
    places = np.asarray(batch.places)
    reps = 0
    for i in range(places.shape[0]):
        row = places[i][places[i] != PAD_PLACE]
        reps += int((row[1:] == row[:-1]).sum())
    assert reps > 100  # stay-duration repetition present


def test_stay_point_detector():
    # two dwells 1km apart with a fast transit between them
    t = []
    xy = []
    clock = 0.0
    for center in ((0.0, 0.0), (1000.0, 0.0)):
        for _ in range(8):
            xy.append([center[0] + np.random.default_rng(len(xy)).uniform(-30, 30),
                       center[1]])
            t.append(clock)
            clock += 300.0
        clock += 60.0
    sp = _stay_points(np.asarray(xy), np.asarray(t))
    assert sp.shape[0] == 2
    assert abs(sp[0][0]) < 100 and abs(sp[1][0] - 1000) < 100


def test_geolife_surrogate_shape():
    batch, forest = geolife_surrogate(num_users=20, num_traj=200, seed=0)
    assert batch.places.shape[0] == 200
    users = np.asarray(batch.user_id)
    assert users.max() < 20
    # behavioural recurrence: home appears at start and end
    places = np.asarray(batch.places)
    lengths = np.asarray(batch.lengths)
    same = sum(
        places[i, 0] == places[i, lengths[i] - 1] for i in range(200)
    )
    assert same > 150


def test_geolife_gps_roundtrip():
    batch, forest = geolife_surrogate(num_users=5, num_traj=64, seed=1, fast=False)
    lengths = np.asarray(batch.lengths)
    assert (lengths > 0).all()


def test_vocab_forest_consistency():
    f = vocab_forest(32_000)
    maps = f.level_maps()
    assert len(maps) == 3
    np.testing.assert_array_equal(f.parents[0][maps[1]], maps[0])


def test_ssh_dedup_recall():
    corpus, dup_source = synthetic_corpus(
        256, 257, 32_000, dup_fraction=0.2, edit_prob=0.05, seed=0
    )
    keep, stats = ssh_dedup(corpus, vocab_size=32_000)
    planted = dup_source >= 0
    # near-dupes overwhelmingly detected; originals overwhelmingly kept
    dup_dropped = (~keep[planted]).mean()
    orig_kept = keep[~planted].mean()
    assert dup_dropped > 0.9, dup_dropped
    assert orig_kept > 0.95, orig_kept


def test_token_dataset_deterministic():
    corpus, _ = synthetic_corpus(64, 33, 1000, seed=0)
    ds1 = TokenDataset(corpus, global_batch=8, seed=3)
    ds2 = TokenDataset(corpus, global_batch=8, seed=3)
    b1, b2 = ds1.batch(17), ds2.batch(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # sharded batches partition the global batch
    shard0 = TokenDataset(corpus, global_batch=8, n_shards=2, shard=0, seed=3).batch(17)
    shard1 = TokenDataset(corpus, global_batch=8, n_shards=2, shard=1, seed=3).batch(17)
    both = np.concatenate([np.asarray(shard0["tokens"]), np.asarray(shard1["tokens"])])
    np.testing.assert_array_equal(both, np.asarray(b1["tokens"]))
