"""Launch plane: production mesh construction (512 virtual devices,
subprocess), HLO collective parsing, input/cache specs, dry-run cell
enumeration and skip rules."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import run_subprocess


MESH_CODE = r"""
import os
assert os.environ["XLA_FLAGS"].endswith("512")
import jax
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.shape == {"data": 16, "model": 16}, m1.shape
m2 = make_production_mesh(multi_pod=True)
assert m2.shape == {"pod": 2, "data": 16, "model": 16}
assert m2.size == 512
print("OK")
"""


def test_production_mesh_512():
    assert "OK" in run_subprocess(MESH_CODE, devices=512)


def test_collective_parser_on_real_hlo():
    """Compile a program with known collectives on a virtual mesh and check
    parsed byte counts against hand-computed values."""
    code = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_analysis import collective_bytes
from repro.core import compat
mesh = compat.make_mesh((8,), ("x",))

def f(a):
    return jax.lax.psum(a, "x")

fn = compat.shard_map(f, mesh=mesh, in_specs=P("x", None),
                      out_specs=P(None, None))
a = jax.ShapeDtypeStruct((8, 128), jnp.float32,
                         sharding=NamedSharding(mesh, P("x", None)))
comp = jax.jit(fn).lower(a).compile()
cb = collective_bytes(comp.as_text())
assert cb["counts"]["all-reduce"] >= 1, cb
# operand is the [1,128] f32 local shard = 512 bytes per all-reduce
assert cb["bytes"]["all-reduce"] >= 512, cb
print("OK", cb["total_bytes"])
"""
    assert "OK" in run_subprocess(code, devices=8)


def test_input_specs_all_cells():
    from repro.configs import SHAPES, all_archs, get_config, shape_applicable
    from repro.launch.inputs import input_specs, train_input_shapes

    for arch in all_archs():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            if not ok or shape.kind == "decode":
                continue
            specs = input_specs(cfg, shape)
            for name, sds in specs.items():
                assert sds.shape[0] == shape.global_batch, (arch, sname, name)


def test_cache_specs_families():
    from repro.configs import get_config
    from repro.serve.kvcache import cache_shapes, cache_bytes

    gqa = cache_shapes(get_config("granite-3-8b"), 4, 128)
    assert set(gqa) == {"pos", "k", "v"}
    mla = cache_shapes(get_config("deepseek-v2-236b"), 4, 128)
    assert set(mla) == {"pos", "c_kv", "k_rope"}
    ssm = cache_shapes(get_config("mamba2-1.3b"), 4, 128)
    assert set(ssm) == {"pos", "conv_x", "conv_bc", "ssm"}
    hyb = cache_shapes(get_config("zamba2-2.7b"), 4, 128)
    assert set(hyb) == {"pos", "conv_x", "conv_bc", "ssm", "sk", "sv"}
    # MLA latent cache is dramatically smaller than full GQA KV would be
    ds = get_config("deepseek-v2-236b")
    full_kv_bytes = 2 * ds.num_layers * 4 * 128 * ds.num_heads * (ds.qk_nope_head_dim + ds.qk_rope_head_dim) * 2
    assert cache_bytes(ds, 4, 128) < full_kv_bytes / 10


def test_ssm_cache_constant_in_context():
    from repro.configs import get_config
    from repro.serve.kvcache import cache_bytes

    m = get_config("mamba2-1.3b")
    assert cache_bytes(m, 1, 32_768) == cache_bytes(m, 1, 524_288)


def test_roofline_math():
    from repro.launch.hlo_analysis import Roofline

    r = Roofline(
        compute_s=2.0, memory_s=1.0, collective_s=0.5,
        hlo_flops=1e12, hlo_bytes=1e9, coll_bytes=1e8,
        model_flops=4e14, chips=256,
    )
    assert r.dominant == "compute"
    assert r.step_time_bound_s if hasattr(r, "step_time_bound_s") else True
    assert r.step_time_s == 2.0
    assert 0 < r.mfu < 1
    d = r.as_dict()
    assert d["dominant"] == "compute"
