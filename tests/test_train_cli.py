"""End-to-end driver integration: launch/train.py with SSH dedup +
checkpointing + resume, via its CLI surface."""
import pathlib

from repro.launch.train import build_parser, train


def test_train_cli_with_resume(tmp_path):
    common = [
        "--arch", "tiny-100m", "--global-batch", "4", "--seq-len", "64",
        "--num-docs", "128", "--lr", "1e-3", "--warmup", "2",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "5", "--log-every", "50",
    ]
    # phase 1: 6 steps, checkpoints at 5 and 6
    args = build_parser().parse_args(common + ["--steps", "6"])
    out1 = train(args)
    assert len(out1["losses"]) == 6
    ckpts = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert ckpts, "no checkpoint written"

    # phase 2: resume to 10 steps — continues from the saved step
    args = build_parser().parse_args(common + ["--steps", "10", "--resume"])
    out2 = train(args)
    assert len(out2["losses"]) < 10  # only the remaining steps ran


def test_train_cli_grad_accum_and_compression(tmp_path):
    args = build_parser().parse_args([
        "--arch", "tiny-100m", "--steps", "4", "--global-batch", "4",
        "--seq-len", "64", "--num-docs", "64", "--grad-accum", "2",
        "--compress-grads", "--dedup", "none", "--log-every", "50",
    ])
    out = train(args)
    assert len(out["losses"]) == 4
    assert all(l == l for l in out["losses"])  # no NaNs
