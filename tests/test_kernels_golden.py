"""Golden tests: Pallas interpret mode vs ref.py on odd shapes.

The allclose sweeps in test_kernels.py cover friendly shapes; these pin the
edge geometry the sharded pipeline actually produces — length-1 sequences,
batches that are not a multiple of the block size (shard-local pair buffers
are capacity-planned, not tile-aligned), and degenerate all-identical
inputs — for the trajectory kernels {lcs, minhash, shingle} and the
sorted-slab probe/merge kernels of the in-mesh streaming join.

The LCS cases force ``mode="interpret"`` so the kernel body really executes
(the "auto" dispatch would route tiny batches to the wavefront).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import PAD_KEY


def _sentinel_pad(a, b, la, lb):
    L = a.shape[1]
    a = a.copy()
    b = b.copy()
    a[np.arange(L)[None, :] >= la[:, None]] = -1
    b[np.arange(L)[None, :] >= lb[:, None]] = -2
    return a, b


class TestLCSGolden:
    def _check(self, a, b, block_b=64):
        from repro.kernels.lcs.ops import lcs
        from repro.kernels.lcs.ref import lcs as ref

        got = np.asarray(
            lcs(jnp.asarray(a), jnp.asarray(b), block_b=block_b,
                mode="interpret")
        )
        want = np.asarray(ref(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("B", [1, 3, 257])
    def test_length_one_sequences(self, B):
        rng = np.random.default_rng(B)
        L = 8
        a = rng.integers(0, 5, size=(B, L)).astype(np.int32)
        b = rng.integers(0, 5, size=(B, L)).astype(np.int32)
        a, b = _sentinel_pad(a, b, np.ones(B, int), np.ones(B, int))
        self._check(a, b)

    def test_max_len_one(self):
        # L == 1: the rolling window degenerates to a single lane
        a = np.asarray([[2], [3], [4]], np.int32)
        b = np.asarray([[2], [5], [4]], np.int32)
        self._check(a, b, block_b=2)

    @pytest.mark.parametrize("B", [5, 130, 300])
    def test_non_multiple_of_block_batches(self, B):
        rng = np.random.default_rng(B * 3)
        L = 12
        la = rng.integers(1, L + 1, size=B)
        lb = rng.integers(1, L + 1, size=B)
        a = rng.integers(0, 6, size=(B, L)).astype(np.int32)
        b = rng.integers(0, 6, size=(B, L)).astype(np.int32)
        a, b = _sentinel_pad(a, b, la, lb)
        self._check(a, b, block_b=128)

    def test_all_identical_inputs(self):
        B, L = 64, 10
        a = np.full((B, L), 7, np.int32)
        b = np.full((B, L), 7, np.int32)
        self._check(a, b)          # LCS == L for every row
        la = np.arange(B) % L + 1
        a2, b2 = _sentinel_pad(a, b, la, np.full(B, L, int))
        self._check(a2, b2)        # LCS == la: prefix vs full repeat


class TestLCSBlockPad:
    """The lcs_pallas wrapper auto-pads non-block-multiple batches (ISSUE 3
    satellite: the hard ``B %% block_b == 0`` assert is gone)."""

    @pytest.mark.parametrize("B,block_b", [(1, 4), (5, 4), (7, 8), (130, 64)])
    def test_direct_kernel_any_batch(self, B, block_b):
        from repro.kernels.lcs.kernel import lcs_pallas
        from repro.kernels.lcs.ref import lcs as ref

        rng = np.random.default_rng(B)
        L = 10
        la = rng.integers(1, L + 1, size=B)
        lb = rng.integers(1, L + 1, size=B)
        a = rng.integers(0, 6, size=(B, L)).astype(np.int32)
        b = rng.integers(0, 6, size=(B, L)).astype(np.int32)
        a, b = _sentinel_pad(a, b, la, lb)
        got = np.asarray(
            lcs_pallas(jnp.asarray(a), jnp.asarray(b), block_b=block_b,
                       interpret=True)
        )
        want = np.asarray(ref(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_array_equal(got, want)


class TestBlockFor:
    """ops._block_for picks the pow2 tile minimizing padded batch (ISSUE 9
    satellite: 513 rows under block_b=512 used to pad to 1024 — one whole
    wasted block — instead of 5 x 128 = 640)."""

    def test_waste_minimization(self):
        from repro.kernels.lcs.ops import _block_for

        assert _block_for(513, 512) == 128   # 640 padded, not 1024
        assert _block_for(512, 512) == 512   # exact fit keeps the big tile
        assert _block_for(1024, 512) == 512  # ties resolve to the largest
        assert _block_for(640, 512) == 128   # 640 exact under 128
        assert _block_for(100, 512) == 128   # floor: one 128 block

    def test_block_b_is_a_cap(self):
        from repro.kernels.lcs.ops import _block_for

        # a small explicit cap (e.g. a tuned value) lowers the floor too
        assert _block_for(1000, 64) == 64
        assert _block_for(3, 4) == 4
        assert _block_for(1, 1) == 1

    @pytest.mark.parametrize("B", [513, 640, 1000])
    def test_golden_at_non_pow2_batches(self, B):
        # the waste-minimized tile must stay bit-identical to the reference
        from repro.kernels.lcs.ops import lcs
        from repro.kernels.lcs.ref import lcs as ref

        rng = np.random.default_rng(B)
        L = 10
        la = rng.integers(1, L + 1, size=B)
        lb = rng.integers(1, L + 1, size=B)
        a = rng.integers(0, 6, size=(B, L)).astype(np.int32)
        b = rng.integers(0, 6, size=(B, L)).astype(np.int32)
        a, b = _sentinel_pad(a, b, la, lb)
        got = np.asarray(
            lcs(jnp.asarray(a), jnp.asarray(b), block_b=512,
                mode="interpret")
        )
        want = np.asarray(ref(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_array_equal(got, want)


class TestFusedGolden:
    """The fused gather-and-score kernel vs its jnp gather-then-score
    oracle: bit-identical level_lcs AND mss on the edge geometry."""

    def _world(self, N, H, L, P, seed=0):
        rng = np.random.default_rng(seed)
        lengths = rng.integers(1, L + 1, size=N).astype(np.int32)
        codes = rng.integers(0, 6, size=(N, H, L)).astype(np.int32)
        # the table carries PAD_CODE_A pads, as encode_codes produces
        pad = np.arange(L)[None, None, :] >= lengths[:, None, None]
        codes = np.where(pad, -1, codes)
        left = rng.integers(0, N, size=P).astype(np.int32)
        right = rng.integers(0, N, size=P).astype(np.int32)
        betas = rng.random(H).astype(np.float32)
        return tuple(map(jnp.asarray, (codes, lengths, left, right, betas)))

    def _check(self, codes, lengths, left, right, betas,
               codes_b=None, lengths_b=None):
        from repro.kernels.lcs.fused import (
            fused_gather_score, fused_score, fused_score_ref,
        )

        tb = codes if codes_b is None else codes_b
        lb = lengths if lengths_b is None else lengths_b
        want_lvl, want_mss = fused_score_ref(
            codes, lengths, tb, lb, left, right, betas
        )
        # the dispatch wrapper (the pipeline's path): bit-identical mss
        got_lvl, got_mss = fused_score(
            codes, lengths, tb, lb, left, right, betas, mode="interpret"
        )
        np.testing.assert_array_equal(np.asarray(got_lvl), np.asarray(want_lvl))
        np.testing.assert_array_equal(np.asarray(got_mss), np.asarray(want_mss))
        # the raw kernel's fused MSS epilogue: integer levels identical,
        # float epilogue within 1 ulp of the XLA lowering (FMA contraction)
        raw_lvl, raw_mss = fused_gather_score(
            codes, lengths, tb, lb, left, right, betas, interpret=True
        )
        np.testing.assert_array_equal(np.asarray(raw_lvl), np.asarray(want_lvl))
        np.testing.assert_allclose(
            np.asarray(raw_mss), np.asarray(want_mss), rtol=1e-6
        )

    @pytest.mark.parametrize("P", [1, 3, 37])
    def test_odd_pair_counts(self, P):
        self._check(*self._world(N=11, H=3, L=9, P=P, seed=P))

    @pytest.mark.parametrize("H", [1, 2, 4])
    def test_level_counts(self, H):
        self._check(*self._world(N=9, H=H, L=8, P=13, seed=H))

    def test_length_one_rows(self):
        codes, lengths, left, right, betas = self._world(8, 3, 7, 16, seed=2)
        lengths = jnp.ones_like(lengths)
        codes = jnp.where(
            jnp.arange(7)[None, None, :] < 1, codes, -1
        )
        self._check(codes, lengths, left, right, betas)

    def test_all_identical_rows(self):
        N, H, L, P = 6, 2, 8, 10
        codes = jnp.full((N, H, L), 4, jnp.int32)
        lengths = jnp.full((N,), L, jnp.int32)
        left = jnp.arange(P, dtype=jnp.int32) % N
        right = (jnp.arange(P, dtype=jnp.int32) + 1) % N
        betas = jnp.asarray([0.25, 0.75], jnp.float32)
        self._check(codes, lengths, left, right, betas)
        lvl, _ = __import__(
            "repro.kernels.lcs.fused", fromlist=["fused_gather_score"]
        ).fused_gather_score(
            codes, lengths, codes, lengths, left, right, betas, interpret=True
        )
        assert (np.asarray(lvl) == L).all()

    def test_two_distinct_tables_iota_indices(self):
        """The shuffle-mode calling convention: two operand stacks with
        iota indices instead of one shared table with pair indices."""
        codes_a, len_a, left, right, betas = self._world(14, 3, 9, 14, seed=5)
        codes_b, len_b, _, _, _ = self._world(14, 3, 9, 14, seed=6)
        iota = jnp.arange(14, dtype=jnp.int32)
        self._check(codes_a, len_a, iota, iota, betas,
                    codes_b=codes_b, lengths_b=len_b)


class TestMinhashGolden:
    def _check(self, types, lengths, num_perm=8):
        from repro.kernels.minhash.ops import minhash_signatures as kern
        from repro.kernels.minhash.ref import minhash_signatures as ref

        got = np.asarray(kern(jnp.asarray(types), jnp.asarray(lengths),
                              num_perm=num_perm, block_b=64))
        want = np.asarray(ref(jnp.asarray(types), jnp.asarray(lengths),
                              num_perm=num_perm))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("N", [1, 67, 130])
    def test_non_multiple_of_block_batches(self, N):
        rng = np.random.default_rng(N)
        L = 10
        lengths = rng.integers(1, L + 1, size=N).astype(np.int32)
        types = rng.integers(0, 30, size=(N, L)).astype(np.int32)
        self._check(types, lengths)

    def test_length_one_sequences(self):
        N, L = 33, 12
        rng = np.random.default_rng(9)
        types = rng.integers(0, 30, size=(N, L)).astype(np.int32)
        self._check(types, np.ones(N, np.int32))

    def test_all_identical_inputs(self):
        N, L = 50, 8
        types = np.full((N, L), 4, np.int32)
        lengths = np.full((N,), L, np.int32)
        self._check(types, lengths)
        # identical sets => identical signatures across rows
        from repro.kernels.minhash.ops import minhash_signatures as kern

        sig = np.asarray(kern(jnp.asarray(types), jnp.asarray(lengths),
                              num_perm=8, block_b=64))
        assert (sig == sig[0]).all()


class TestShingleGolden:
    def _sets(self, keys):
        return [set(row[row != PAD_KEY].tolist()) for row in np.asarray(keys)]

    def _check(self, types, lengths, k=3, Q=30):
        from repro.core.shingling import shingles_from_types
        from repro.kernels.shingle.ops import shingle_keys

        got = shingle_keys(jnp.asarray(types), jnp.asarray(lengths),
                           k=k, num_types=Q, block_b=32)
        want = shingles_from_types(jnp.asarray(types), jnp.asarray(lengths),
                                   k=k, num_types=Q)
        assert self._sets(got) == self._sets(want)

    @pytest.mark.parametrize("N", [1, 33, 70])
    def test_non_multiple_of_block_batches(self, N):
        rng = np.random.default_rng(N * 7)
        L = 10
        lengths = rng.integers(1, L + 1, size=N).astype(np.int32)
        types = rng.integers(0, 30, size=(N, L)).astype(np.int32)
        self._check(types, lengths)

    def test_below_shingle_order_yields_empty(self):
        # length < k: no k-shingle exists; both sides must agree on "empty"
        N, L = 17, 8
        rng = np.random.default_rng(3)
        types = rng.integers(0, 30, size=(N, L)).astype(np.int32)
        lengths = np.full((N,), 2, np.int32)   # k = 3 below
        from repro.kernels.shingle.ops import shingle_keys

        got = shingle_keys(jnp.asarray(types), jnp.asarray(lengths),
                           k=3, num_types=30, block_b=32)
        assert all(s == set() for s in self._sets(got))
        self._check(types, lengths)

    def test_all_identical_inputs(self):
        # one distinct symbol -> exactly one distinct shingle after dedup
        N, L = 21, 9
        types = np.full((N, L), 5, np.int32)
        lengths = np.full((N,), L, np.int32)
        self._check(types, lengths)
        from repro.kernels.shingle.ops import shingle_keys

        keys = shingle_keys(jnp.asarray(types), jnp.asarray(lengths),
                            k=3, num_types=30, block_b=32)
        assert all(len(s) == 1 for s in self._sets(keys))


class TestSortedSlabGolden:
    """Golden shapes for the sorted-merge probe/insert kernels backing the
    in-mesh streaming join (core/device_index.py), pinned to the numpy
    bucket-semantics references on the geometries the shard program
    actually produces: PAD-only route buffers, a single-key world (every
    entry in one bucket), an exactly-full slab at the capacity boundary,
    and overflow-drop accounting."""

    def _slab(self, entries, cap):
        from repro.core.types import PAD_ID

        k = np.full((cap,), PAD_KEY, np.int32)
        r = np.full((cap,), PAD_ID, np.int32)
        for i, (key, rid) in enumerate(sorted(entries)):
            k[i], r[i] = key, rid
        return k, r

    def _check_probe(self, slab_k, slab_r, keys, rows, nn_cap=64, no_cap=64):
        from repro.core.device_index import probe_pairs, probe_pairs_ref
        from repro.core.types import PAD_ID

        lo, hi, examined, ovf = probe_pairs(
            jnp.asarray(slab_k), jnp.asarray(slab_r),
            jnp.asarray(keys), jnp.asarray(rows),
            nn_cap=nn_cap, no_cap=no_cap,
        )
        lo, hi = np.asarray(lo), np.asarray(hi)
        got = sorted((int(a), int(b))
                     for a, b in zip(lo, hi) if a != PAD_ID)
        want, examined_want = probe_pairs_ref(slab_k, slab_r, keys, rows)
        assert int(ovf) == 0
        assert got == sorted(want)
        assert int(examined) == examined_want
        return examined_want

    def _check_merge(self, slab_k, slab_r, keys, rows):
        from repro.core.device_index import merge_insert, merge_insert_ref

        mk, mr, ovf = merge_insert(
            jnp.asarray(slab_k), jnp.asarray(slab_r),
            jnp.asarray(keys), jnp.asarray(rows),
        )
        rk, rr, rovf = merge_insert_ref(slab_k, slab_r, keys, rows,
                                        slab_k.shape[0])
        np.testing.assert_array_equal(np.asarray(mk), rk)
        np.testing.assert_array_equal(np.asarray(mr), rr)
        assert int(ovf) == rovf
        return int(ovf)

    def test_pad_only_rows(self):
        # an all-PAD route buffer (an update whose keys all went to other
        # shards): no pairs, no examined work, slab unchanged
        from repro.core.types import PAD_ID

        slab_k, slab_r = self._slab([(3, 0), (5, 1), (5, 2)], cap=16)
        keys = np.full((8,), PAD_KEY, np.int32)
        rows = np.full((8,), PAD_ID, np.int32)
        assert self._check_probe(slab_k, slab_r, keys, rows) == 0
        assert self._check_merge(slab_k, slab_r, keys, rows) == 0
        # and on a still-empty slab
        empty_k, empty_r = self._slab([], cap=16)
        assert self._check_probe(empty_k, empty_r, keys, rows) == 0

    def test_single_key_world(self):
        # every resident entry and every incoming row shares ONE key: the
        # bucket spans the whole slab, probe must emit old*new + C(new, 2)
        from repro.core.types import PAD_ID

        old = 6
        slab_k, slab_r = self._slab([(7, i) for i in range(old)], cap=16)
        new = 5
        keys = np.full((new,), 7, np.int32)
        rows = (old + np.arange(new)).astype(np.int32)
        examined = self._check_probe(slab_k, slab_r, keys, rows,
                                     nn_cap=32, no_cap=64)
        assert examined == old * new + new * (new - 1) // 2
        self._check_merge(slab_k, slab_r, keys, rows)

    def test_cap_boundary_insert_exactly_full(self):
        # merging into a slab that lands EXACTLY at capacity: no overflow,
        # no dropped entry, sorted invariant preserved
        cap = 8
        slab_k, slab_r = self._slab([(2, 0), (4, 1), (9, 2)], cap=cap)
        keys = np.asarray([1, 4, 4, 9, 11], np.int32)
        rows = np.asarray([10, 11, 12, 13, 14], np.int32)
        assert self._check_merge(slab_k, slab_r, keys, rows) == 0
        from repro.core.device_index import merge_insert

        mk, _, ovf = merge_insert(jnp.asarray(slab_k), jnp.asarray(slab_r),
                                  jnp.asarray(keys), jnp.asarray(rows))
        mk = np.asarray(mk)
        assert int(ovf) == 0
        assert (mk != PAD_KEY).sum() == cap  # exactly full
        assert (np.diff(mk) >= 0).all()      # still sorted

    def test_overflow_drop_accounting(self):
        # one entry too many: the drop is COUNTED (the engine regrows and
        # retries; a committed drop never happens), and the probe's pair
        # buffers report their own overflow the same way
        cap = 4
        slab_k, slab_r = self._slab([(2, 0), (4, 1), (9, 2)], cap=cap)
        keys = np.asarray([1, 4], np.int32)
        rows = np.asarray([10, 11], np.int32)
        assert self._check_merge(slab_k, slab_r, keys, rows) == 1
        from repro.core.device_index import probe_pairs

        # 5 incoming rows of one key against 3 residents of the same key:
        # 15 old-new + 10 new-new collisions vs caps (8, 8)
        slab_k, slab_r = self._slab([(7, 0), (7, 1), (7, 2)], cap=8)
        keys = np.full((5,), 7, np.int32)
        rows = (3 + np.arange(5)).astype(np.int32)
        lo, hi, examined, ovf = probe_pairs(
            jnp.asarray(slab_k), jnp.asarray(slab_r),
            jnp.asarray(keys), jnp.asarray(rows), nn_cap=8, no_cap=8,
        )
        assert int(examined) == 15 + 10      # exact even when overflowing
        assert int(ovf) == (10 - 8) + (15 - 8)

    def test_randomized_vs_reference(self):
        # seeded sweep over mixed shapes (the differential harness pins
        # the end-to-end join; this pins the kernels in isolation)
        from repro.core.types import PAD_ID

        rng = np.random.default_rng(0)
        for trial in range(10):
            cap = int(rng.integers(8, 40))
            n_old = int(rng.integers(0, cap // 2 + 1))
            ent = sorted(
                (int(k), i)
                for i, k in enumerate(rng.integers(0, 9, n_old))
            )
            slab_k, slab_r = self._slab(ent, cap=cap)
            r = int(rng.integers(1, 20))
            keys = rng.integers(0, 9, r).astype(np.int32)
            rows = (100 + np.arange(r)).astype(np.int32)
            drop = rng.random(r) < 0.3
            keys[drop] = PAD_KEY
            rows[drop] = PAD_ID
            self._check_probe(slab_k, slab_r, keys, rows,
                              nn_cap=256, no_cap=256)
            self._check_merge(slab_k, slab_r, keys, rows)
