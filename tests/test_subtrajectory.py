"""Subtrajectory "another me" (ISSUE 10 tentpole): windowed candidates
with (traj, offset) coordinates, pinned bit-identical to a numpy
brute-force windowed oracle.

* ``EngineConfig(subtraj_window=W, subtraj_stride=s)`` turns every
  backend's join into a join over sliding windows; the engine's scored
  output (max-over-windows per trajectory pair, deterministic tie-break)
  must EQUAL the oracle restricted to that backend's candidate window
  pairs — bit-identical level_lcs AND mss — for all of
  {ssh, minhash, brp, udf}.
* For the lossless backends (ssh/udf) with ``rho >= (k-1) * sum(betas)``
  the similar set must equal the TRUE oracle's (any window pair above rho
  has type-LCS >= k, hence shares a shingle, hence is a candidate).
* ``W >= L`` degenerates to the whole-trajectory engine bit-exactly;
  ``stride > 1`` restricts the oracle's offsets and still matches.
* The windowed kernels (``lcs_windowed``, ``fused_windowed_score``) match
  the numpy DP / the jnp reference exactly.
* The capacity planners accept window-id coordinates
  (``windows_per_row``) with per-TRAJECTORY shard ownership.
* ``StreamingEngine`` rejects subtrajectory mode loudly (a growing world
  max-length would re-number resident window ids).

The sharded {2, 4, 8} x {replicate, shuffle} x backend sweep lives in
``test_api_parity_matrix.py::test_subtraj_parity_matrix`` (slow).
"""
import numpy as np
import pytest

from repro.api import AnotherMeEngine, EngineConfig, StreamingEngine
from repro.api.backends import BackendContext, get_backend
from repro.core.encoding import encode_codes
from repro.core.subtraj import (
    aggregate_window_pairs, num_windows, window_lengths,
)
from repro.core.types import PAD_ID
from repro.data import synthetic_setup

BACKENDS = ("ssh", "minhash", "brp", "udf")
W, STRIDE, K = 5, 1, 2


# ---------------------------------------------------------------------------
# numpy oracle
# ---------------------------------------------------------------------------

def lcs_np(a, b):
    la, lb = len(a), len(b)
    dp = np.zeros((la + 1, lb + 1), np.int32)
    for i in range(la):
        for j in range(lb):
            dp[i + 1, j + 1] = (
                dp[i, j] + 1 if a[i] == b[j] else max(dp[i, j + 1], dp[i + 1, j])
            )
    return int(dp[la, lb])


@pytest.fixture(scope="module")
def world():
    batch, forest = synthetic_setup(
        28, num_types=8, classes_per_type=4, num_places=60, seed=3
    )
    eng = AnotherMeEngine(forest, EngineConfig(k=K))
    codes = np.asarray(encode_codes(batch.places, eng.tables))
    lengths = np.asarray(batch.lengths)
    betas = np.asarray(eng.betas, np.float32)
    return batch, forest, codes, lengths, betas


@pytest.fixture(scope="module")
def oracle_table(world):
    """Every window pair's exact (level_lcs, mss): the brute-force oracle.

    Keyed (a, b, ja, jb) over trajectories a < b and window indices; the
    per-backend tests restrict it to candidate window pairs, the
    completeness test maxes it over everything.
    """
    _, _, codes, lengths, betas = world
    N, H, L = codes.shape
    Weff = min(W, L)
    nw = num_windows(L, W, STRIDE)
    table = {}
    for a in range(N):
        for b in range(a + 1, N):
            for ja in range(nw):
                oa = ja * STRIDE
                wla = max(0, min(int(lengths[a]) - oa, Weff))
                for jb in range(nw):
                    ob = jb * STRIDE
                    wlb = max(0, min(int(lengths[b]) - ob, Weff))
                    lvl = tuple(
                        lcs_np(codes[a, h, oa:oa + wla], codes[b, h, ob:ob + wlb])
                        for h in range(H)
                    )
                    mss = np.float32(np.sum(
                        betas * np.asarray(lvl, np.float32), dtype=np.float32
                    ))
                    table[(a, b, ja, jb)] = (lvl, mss)
    return table, nw


def oracle_max(table, nw, candidate=None):
    """Max-over-windows per trajectory pair with the engine's tie-break:
    highest mss, then smallest (window_lo_id, window_hi_id)."""
    best = {}
    for (a, b, ja, jb), (lvl, mss) in table.items():
        if candidate is not None and not candidate(a, b, ja, jb):
            continue
        key = (a * nw + ja, b * nw + jb)
        cur = best.get((a, b))
        if cur is None or mss > cur[1] or (mss == cur[1] and key < cur[2]):
            best[(a, b)] = (lvl, mss, key)
    return {p: (lvl, mss) for p, (lvl, mss, _) in best.items()}


def score_map(res):
    sc = res.scored
    cnt = int(sc.count)
    left = np.asarray(sc.left)[:cnt]
    right = np.asarray(sc.right)[:cnt]
    mss = np.asarray(sc.mss)[:cnt]
    lvl = np.asarray(sc.level_lcs)[:cnt]
    return {
        (int(a), int(b)): (tuple(int(x) for x in lv), np.float32(m))
        for a, b, m, lv in zip(left, right, mss, lvl)
    }


def backend_candidate_fn(backend, codes, lengths, forest, nw):
    """Candidate predicate from the backend's OWN windowed join keys:
    window pair (a*nw+ja, b*nw+jb) is a candidate iff the key rows share
    any non-PAD key — exactly the engine's sort-merge join."""
    import jax.numpy as jnp

    from repro.core.types import PAD_KEY

    ctx = BackendContext(
        k=K, num_types=forest.num_types, window=W, stride=STRIDE,
    )
    from types import SimpleNamespace

    enc = SimpleNamespace(
        codes=jnp.asarray(codes), lengths=jnp.asarray(lengths)
    )
    keys = np.asarray(
        get_backend(backend).join_keys(enc, None, ctx)
    )  # [N*nw, S]
    key_sets = [set(row[row != PAD_KEY].tolist()) for row in keys]

    def candidate(a, b, ja, jb):
        return bool(key_sets[a * nw + ja] & key_sets[b * nw + jb])

    return candidate


# ---------------------------------------------------------------------------
# engine vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_matches_windowed_oracle(world, oracle_table, backend):
    """Scored output == the oracle restricted to the backend's candidate
    window pairs: same pair set, bit-identical level_lcs and mss."""
    batch, forest, codes, lengths, betas = world
    table, nw = oracle_table
    rho = float((K - 1) * betas.sum()) + 0.05
    res = AnotherMeEngine(forest, EngineConfig(
        backend=backend, k=K, rho=rho,
        subtraj_window=W, subtraj_stride=STRIDE,
    )).run(batch)
    cand = backend_candidate_fn(backend, codes, lengths, forest, nw)
    want = oracle_max(table, nw, candidate=cand)
    assert score_map(res) == want, backend
    want_sim = {p for p, (_, m) in want.items() if m > np.float32(rho)}
    assert res.similar_pairs == want_sim, backend


@pytest.mark.parametrize("backend", ("ssh", "udf"))
def test_lossless_backends_complete_vs_true_oracle(world, oracle_table,
                                                   backend):
    """rho >= (k-1)*sum(betas) makes the shingle join COMPLETE on the
    similar set: any window pair above rho has type-level LCS >= k, so it
    shares a k-shingle and must be a candidate — the engine's similar set
    equals the UNRESTRICTED oracle's."""
    batch, forest, _, _, betas = world
    table, nw = oracle_table
    rho = float((K - 1) * betas.sum()) + 0.05
    res = AnotherMeEngine(forest, EngineConfig(
        backend=backend, k=K, rho=rho,
        subtraj_window=W, subtraj_stride=STRIDE,
    )).run(batch)
    true_max = oracle_max(table, nw)
    want_sim = {p for p, (_, m) in true_max.items() if m > np.float32(rho)}
    assert res.similar_pairs == want_sim, backend
    # and every similar pair's reported score IS the true maximum
    got = score_map(res)
    for p in want_sim:
        assert got[p] == true_max[p], (backend, p)


def test_w_ge_l_degenerates_to_whole_trajectory(world):
    """subtraj_window >= L is the whole-trajectory engine bit-exactly
    (nw == 1, offset 0, window length == trajectory length)."""
    batch, forest, codes, _, _ = world
    L = codes.shape[2]
    whole = AnotherMeEngine(forest, EngineConfig(k=K, rho=1.05)).run(batch)
    win = AnotherMeEngine(forest, EngineConfig(
        k=K, rho=1.05, subtraj_window=L + 7,
    )).run(batch)
    assert score_map(win) == score_map(whole)
    assert win.similar_pairs == whole.similar_pairs
    assert win.communities == whole.communities


def test_stride_gt_one_matches_strided_oracle(world):
    """stride=2 restricts both the key windows and the oracle's offsets."""
    batch, forest, codes, lengths, betas = world
    N, H, L = codes.shape
    stride = 2
    nw = num_windows(L, W, stride)
    Weff = min(W, L)
    rho = float((K - 1) * betas.sum()) + 0.05
    res = AnotherMeEngine(forest, EngineConfig(
        k=K, rho=rho, subtraj_window=W, subtraj_stride=stride,
    )).run(batch)
    table = {}
    for a in range(N):
        for b in range(a + 1, N):
            for ja in range(nw):
                oa = ja * stride
                wla = max(0, min(int(lengths[a]) - oa, Weff))
                for jb in range(nw):
                    ob = jb * stride
                    wlb = max(0, min(int(lengths[b]) - ob, Weff))
                    lvl = tuple(
                        lcs_np(codes[a, h, oa:oa + wla],
                               codes[b, h, ob:ob + wlb])
                        for h in range(H)
                    )
                    table[(a, b, ja, jb)] = (lvl, np.float32(np.sum(
                        betas * np.asarray(lvl, np.float32), dtype=np.float32
                    )))
    want_sim = {
        p for p, (_, m) in oracle_max(table, nw).items()
        if m > np.float32(rho)
    }
    assert res.similar_pairs == want_sim


# ---------------------------------------------------------------------------
# windowed kernels vs numpy
# ---------------------------------------------------------------------------

def test_lcs_windowed_matches_numpy_dp():
    import jax.numpy as jnp

    from repro.kernels.lcs.ops import lcs_windowed

    rng = np.random.default_rng(0)
    B, L, window = 33, 12, 5
    a = rng.integers(0, 4, size=(B, L)).astype(np.int32)
    b = rng.integers(0, 4, size=(B, L)).astype(np.int32)
    len_a = rng.integers(0, L + 1, size=B).astype(np.int32)
    len_b = rng.integers(0, L + 1, size=B).astype(np.int32)
    off_a = rng.integers(0, L, size=B).astype(np.int32)
    off_b = rng.integers(0, L, size=B).astype(np.int32)
    want = np.array([
        lcs_np(
            a[i, off_a[i]:off_a[i] + max(0, min(len_a[i] - off_a[i], window))],
            b[i, off_b[i]:off_b[i] + max(0, min(len_b[i] - off_b[i], window))],
        )
        for i in range(B)
    ], np.int32)
    for mode in ("wavefront", "interpret"):
        got = np.asarray(lcs_windowed(
            jnp.asarray(a), jnp.asarray(b),
            jnp.asarray(off_a), jnp.asarray(off_b),
            jnp.asarray(len_a), jnp.asarray(len_b),
            window=window, mode=mode,
        ))
        np.testing.assert_array_equal(got, want, err_msg=mode)


def test_fused_windowed_kernel_matches_ref():
    """The in-register window masking of the fused kernel (sentinels
    outside [off, off+wlen)) equals the gather-then-score reference —
    bit-identical integer level_lcs, identical exact-mss epilogue."""
    import jax.numpy as jnp

    from repro.kernels.lcs.fused import (
        fused_windowed_score, fused_windowed_score_ref,
    )

    rng = np.random.default_rng(1)
    N, H, L, P, window = 10, 3, 11, 65, 4
    codes = rng.integers(0, 5, size=(N, H, L)).astype(np.int32)
    lengths = rng.integers(1, L + 1, size=N).astype(np.int32)
    for i in range(N):  # table padding: sentinel past each row's length
        codes[i, :, lengths[i]:] = -1
    left = rng.integers(0, N, size=P).astype(np.int32)
    right = rng.integers(0, N, size=P).astype(np.int32)
    off_a = rng.integers(0, L, size=P).astype(np.int32)
    off_b = rng.integers(0, L, size=P).astype(np.int32)
    betas = jnp.asarray([1.0, 0.5, 0.25], jnp.float32)
    args = (jnp.asarray(codes), jnp.asarray(lengths),
            jnp.asarray(codes), jnp.asarray(lengths),
            jnp.asarray(left), jnp.asarray(right),
            jnp.asarray(off_a), jnp.asarray(off_b), betas)
    lvl_ref, mss_ref = fused_windowed_score_ref(*args, window=window)
    lvl_k, mss_k = fused_windowed_score(*args, window=window,
                                        mode="interpret")
    np.testing.assert_array_equal(np.asarray(lvl_k), np.asarray(lvl_ref))
    np.testing.assert_array_equal(np.asarray(mss_k), np.asarray(mss_ref))


# ---------------------------------------------------------------------------
# coordinate plumbing units
# ---------------------------------------------------------------------------

def test_num_windows_edges():
    assert num_windows(10, 4, 1) == 7
    assert num_windows(10, 4, 2) == 4
    assert num_windows(10, 4, 3) == 3
    assert num_windows(3, 8, 1) == 1    # W >= L degenerates to one window
    assert num_windows(4, 4, 1) == 1
    with pytest.raises(ValueError):
        num_windows(10, 0, 1)
    with pytest.raises(ValueError):
        num_windows(10, 4, 0)


def test_window_lengths_matches_loop():
    lengths = np.array([0, 3, 7, 10], np.int32)
    got = window_lengths(lengths, max_len=10, window=4, stride=2)
    nw = num_windows(10, 4, 2)
    want = np.array([
        max(0, min(int(l) - j * 2, 4))
        for l in lengths for j in range(nw)
    ], np.int32)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_aggregate_window_pairs_tie_break_and_filtering():
    nw = 3
    # window ids: traj = id // 3.  Rows: a PAD row, a same-traj pair
    # (dropped), and three window pairs of trajectories (1, 2) with a tie
    # at mss=2.0 — the SMALLEST (window_lo, window_hi) must win.
    left = np.array([PAD_ID, 3, 5, 4, 3], np.int32)
    right = np.array([0, 4, 6, 7, 8], np.int32)
    lvl = np.array([[9], [5], [4], [2], [1]], np.int32)
    mss = np.array([9.0, 1.0, 2.0, 2.0, 1.5], np.float32)
    tl, tr, tlvl, tmss = aggregate_window_pairs(
        left, right, lvl, mss, nw=nw
    )
    np.testing.assert_array_equal(tl, [1])
    np.testing.assert_array_equal(tr, [2])
    # tied mss=2.0 between window pairs (5, 6) lvl [4] and (4, 7) lvl [2]:
    # the smaller window_lo (4) wins, so the reported lvl row is [2]
    np.testing.assert_array_equal(tlvl, [[2]])
    np.testing.assert_array_equal(tmss, np.float32(2.0))


def test_plan_capacities_windowed_ownership_is_per_trajectory():
    from repro.api.sharded import plan_capacities

    nw, n_shards = 2, 2
    # 4 trajectories x 2 windows; every window of trajectory t keys on t,
    # so all joins are within-trajectory windows
    keys = np.repeat(np.arange(4, dtype=np.int32), nw)[:, None]
    plan = plan_capacities(keys, n_shards, windows_per_row=nw)
    assert plan.local_n == 2  # TRAJECTORY units: ceil(4 / 2)
    plain = plan_capacities(keys[::nw], n_shards)
    assert plain.local_n == 2

    # shuffle-mode owner loads must also be in trajectory units: identical
    # plans for window ids g = t * nw and plain trajectory ids t
    lengths_w = np.full(4 * nw, 6, np.int32)
    pw = plan_capacities(
        keys, n_shards, score_mode="shuffle", windows_per_row=nw,
        lengths_np=lengths_w, prune_tau=0.5, betas_sum=1.0,
    )
    assert pw.owner_route_cap > 0 and pw.local_n == 2


def test_plan_stream_capacities_windows_per_row():
    from repro.api.sharded import plan_stream_capacities

    rng = np.random.default_rng(7)
    nw = 4
    lo_t = rng.integers(0, 16, size=40).astype(np.int64)
    hi_t = rng.integers(0, 16, size=40).astype(np.int64)
    # window ids of the SAME trajectories must plan identically to the
    # plain trajectory ids: ownership is (id // nw) % n_shards
    jw = rng.integers(0, nw, size=40)
    plain = plan_stream_capacities(lo_t, hi_t, 4, 64, score_mode="shuffle")
    windowed = plan_stream_capacities(
        lo_t * nw + jw, hi_t * nw + jw, 4, 64, score_mode="shuffle",
        windows_per_row=nw,
    )
    assert windowed == plain


def test_streaming_engine_rejects_subtraj(world):
    _, forest, _, _, _ = world
    with pytest.raises(NotImplementedError, match="subtraj"):
        StreamingEngine(forest, EngineConfig(subtraj_window=4))


def test_keyless_backend_rejects_subtraj(world):
    _, forest, _, _, _ = world
    from repro.api.backends import CallableBackend, register_backend

    register_backend("test-callable", lambda: CallableBackend(lambda e, b: None))
    try:
        with pytest.raises(ValueError, match="subtraj"):
            AnotherMeEngine(forest, EngineConfig(
                backend="test-callable", subtraj_window=4,
            ))
    finally:
        from repro.api.backends import _REGISTRY

        _REGISTRY.pop("test-callable", None)


def test_shingle_budget_guard_suggests_windowed_mode():
    from repro.core.shingling import MAX_SHINGLE_COMBOS, shingle_indices

    with pytest.raises(ValueError, match="subtraj_window"):
        shingle_indices(200, 5)  # C(200, 5) >> MAX_SHINGLE_COMBOS
    assert MAX_SHINGLE_COMBOS >= 2_000_000
