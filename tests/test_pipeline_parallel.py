"""GPipe pipeline parallelism == sequential stage application (4 virtual
pipeline stages, subprocess)."""
from conftest import run_subprocess

CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.train.pipeline import pipeline_apply

n_stages, n_micro, mb, d = 4, 6, 2, 16
from repro.core import compat
mesh = compat.make_mesh((n_stages,), ("stage",))
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.normal(scale=0.3, size=(n_stages, d, d)).astype(np.float32))
bs = jnp.asarray(rng.normal(scale=0.1, size=(n_stages, d)).astype(np.float32))
x = jnp.asarray(rng.normal(size=(n_micro, mb, d)).astype(np.float32))

def stage_fn(p, h):
    W, b = p
    return jnp.tanh(h @ W + b)

out = pipeline_apply(stage_fn, (Ws, bs), x, mesh)

# oracle: apply all stages sequentially to every microbatch
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ Ws[s] + bs[s])
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-5, err
# differentiability through the pipeline (grads flow via ppermute transpose)
loss = lambda Ws: (pipeline_apply(stage_fn, (Ws, bs), x, mesh) ** 2).sum()
g = jax.grad(loss)(Ws)
assert jnp.isfinite(g).all() and float(jnp.abs(g).max()) > 0
print("OK", err)
"""


def test_gpipe_matches_sequential():
    out = run_subprocess(CODE, devices=4)
    assert "OK" in out
