"""Cross-backend parity matrix for the device-resident sharded pipeline.

Pins the contract of ISSUEs 2 and 3: every cell of

    {ssh, minhash, brp, udf} x {1, 2, 4 shards} x {replicate, shuffle}
                 x {wavefront, pallas-interpret, fused-interpret}

produces identical similar pairs, identical communities and bit-identical
per-pair scores to the single-device engine (and, at n_shards=1, to the
legacy ``run_anotherme``).  Sharded cells run in a subprocess (device count
binds at jax init); one subprocess per backend keeps the matrix affordable
while still compiling every (shards, mode, impl) program.

Also proves the structural claims:
* with n_shards>1 the engine has NO host EncodeStage (encoding runs inside
  the shard_map program) and reports no ``t_encode`` phase;
* ``lcs_impl="pallas-interpret"`` really dispatches ``lcs_pallas`` inside
  the shard_map score stage (counted via monkeypatch at trace time);
* ``lcs_impl="fused-interpret"`` really dispatches the gather-free
  ``fused_gather_score`` kernel, on the single-device AND sharded paths.

ISSUE 4 adds the STREAMING axis: {1, 2, 4 shards} x {replicate, shuffle}
x {wavefront, fused-interpret} micro-batched ``StreamingEngine`` runs must
be bit-identical to the single-device streaming reference (itself pinned
to one-shot ``engine.run``), and equal-shape updates must reuse the cached
sharded runner — zero per-update recompiles, asserted through a trace-time
compilation-counting hook plus a fused-kernel dispatch counter.

ISSUE 5 adds the DELTA_JOIN axis: {host, device} x {replicate, shuffle}
x {wavefront, fused-interpret} streaming runs must produce bit-identical
``EngineResult``s, and a real-dispatch proof (``BucketIndex.insert``
monkeypatched with a counter) shows the device path keeps the join state
in-mesh: the driver-resident bucket table is NEVER consulted.

ISSUE 9 adds the AUTOTUNE + OVERLAP axis: a tuning table with NON-default
parameters (block_b=128, int32 diagonals) plus ``overlap_chunks`` in
{2, 4} must stay bit-identical to the untuned serial defaults across
{wavefront, fused-interpret} x SHARDS x {replicate, shuffle}, one-shot
and streaming — with a real-dispatch proof that the tuned record reaches
``lcs_impl_fn`` — and the chunked shuffle runner's per-update trace
history must EQUAL the unchunked one (hop/score overlap adds zero
steady-state recompiles).

ISSUE 10 adds the SUBTRAJECTORY axis: every backend x SHARDS x
{replicate, shuffle} x {wavefront, fused-interpret} run with
``subtraj_window`` set must be bit-identical to the single-device
subtrajectory engine (itself pinned to the brute-force windowed oracle in
``test_subtrajectory.py``), and a re-run of the same batch must reuse the
cached sharded runner — zero steady-state recompiles in windowed mode.

All subprocess sweeps here are marked ``slow`` (tier-1 deselects them via
pytest.ini's ``-m "not slow"``); CI runs them in a dedicated full-matrix
step.
"""
import os

import pytest

from conftest import run_subprocess

BACKENDS = ("ssh", "minhash", "brp", "udf")

# CI widens the shard axis to 8 (REPRO_MAX_SHARDS=8 with
# --xla_force_host_platform_device_count=8); the local default stays at 4
# so the matrix remains affordable on laptops.
_MAX_SHARDS = int(os.environ.get("REPRO_MAX_SHARDS", "4"))
SHARDS = tuple(s for s in (1, 2, 4, 8) if s <= _MAX_SHARDS)
DEVICES = max(_MAX_SHARDS, 4)

MATRIX_CODE = r"""
import numpy as np
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.core import AnotherMeConfig, run_anotherme
from repro.core.types import PAD_ID
from repro.data import fig1_world

backend = "%(backend)s"
batch, forest = fig1_world()
RHO = 3.0
IMPLS = ("wavefront", "pallas-interpret", "fused-interpret")


def score_map(res):
    left = np.asarray(res.scored.left)
    right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss)
    lvl = np.asarray(res.scored.level_lcs)
    keep = left != PAD_ID
    return {
        (int(a), int(b)): (float(m), tuple(int(x) for x in lv))
        for a, b, m, lv in zip(left[keep], right[keep], mss[keep], lvl[keep])
    }


base = {}
for impl in IMPLS:
    cfg = EngineConfig(backend=backend, rho=RHO, lcs_impl=impl)
    base[impl] = AnotherMeEngine(forest, cfg).run(batch)

# engine vs engine across impls: integer LCS (and a fixed-order float32
# MSS epilogue in the fused kernel) => bit-identical scores
assert score_map(base["wavefront"]) == score_map(base["pallas-interpret"])
assert score_map(base["wavefront"]) == score_map(base["fused-interpret"])

# engine vs legacy (single device, ssh/udf share the lossless shingle join)
if backend in ("ssh", "udf"):
    legacy = run_anotherme(batch, forest, AnotherMeConfig(rho=RHO))
    assert base["wavefront"].similar_pairs == legacy.similar_pairs
    assert base["wavefront"].communities == legacy.communities

for impl in IMPLS:
    cfg = EngineConfig(backend=backend, rho=RHO, lcs_impl=impl)
    want_pairs = base[impl].similar_pairs
    want_comms = base[impl].communities
    want_scores = score_map(base[impl])
    for n_shards in %(shards)s:
        modes = ("replicate", "shuffle") if n_shards > 1 else ("replicate",)
        for mode in modes:
            res = AnotherMeEngine(
                forest, cfg,
                ExecutionPlan(n_shards=n_shards, score_mode=mode),
            ).run(batch)
            cell = (backend, n_shards, mode, impl)
            assert res.similar_pairs == want_pairs, cell
            assert res.communities == want_comms, cell
            assert score_map(res) == want_scores, cell
print("OK", backend)
"""


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_matrix(backend):
    out = run_subprocess(
        MATRIX_CODE % {"backend": backend, "shards": SHARDS},
        devices=DEVICES,
    )
    assert f"OK {backend}" in out


PALLAS_DISPATCH_CODE = r"""
import numpy as np
import repro.kernels.lcs.ops as lcs_ops
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.data import fig1_world

calls = []
real = lcs_ops.lcs_pallas

def counting(*args, **kwargs):
    calls.append(kwargs.get("interpret"))
    return real(*args, **kwargs)

lcs_ops.lcs_pallas = counting
batch, forest = fig1_world()
cfg = EngineConfig(rho=3.0)
single = AnotherMeEngine(forest, cfg).run(batch)
assert not calls  # default wavefront impl never touches the kernel

sharded = AnotherMeEngine(
    forest, cfg, ExecutionPlan(n_shards=4, lcs_impl="pallas-interpret"),
).run(batch)
# traced (and therefore executed) inside the shard_map score stage
assert calls and all(interp is True for interp in calls), calls
assert sharded.similar_pairs == single.similar_pairs
assert sharded.communities == single.communities
print("OK", len(calls))
"""


@pytest.mark.slow
def test_sharded_pallas_dispatch_is_real():
    """ExecutionPlan(lcs_impl=...) must route the Pallas kernel into the
    shard_map score stage — not silently fall back to the wavefront."""
    out = run_subprocess(PALLAS_DISPATCH_CODE, devices=4)
    assert "OK" in out


FUSED_DISPATCH_CODE = r"""
import numpy as np
import repro.kernels.lcs.fused as fused
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.data import fig1_world

calls = []
real = fused.fused_gather_score

def counting(*args, **kwargs):
    calls.append(kwargs.get("interpret"))
    return real(*args, **kwargs)

fused.fused_gather_score = counting
batch, forest = fig1_world()
cfg = EngineConfig(rho=3.0)
single = AnotherMeEngine(forest, cfg).run(batch)
assert not calls  # default wavefront impl never touches the fused kernel

fused_single = AnotherMeEngine(
    forest, EngineConfig(rho=3.0, lcs_impl="fused-interpret"),
).run(batch)
assert calls and all(interp is True for interp in calls), calls
n_single = len(calls)

sharded = AnotherMeEngine(
    forest, cfg, ExecutionPlan(n_shards=4, lcs_impl="fused-interpret"),
).run(batch)
# traced (and therefore executed) inside the shard_map score stage too
assert len(calls) > n_single and all(i is True for i in calls), calls
assert fused_single.similar_pairs == single.similar_pairs
assert sharded.similar_pairs == single.similar_pairs
assert sharded.communities == single.communities
print("OK", len(calls))
"""


@pytest.mark.slow
def test_fused_dispatch_is_real():
    """lcs_impl="fused-interpret" must route the gather-free fused kernel
    into BOTH score paths — not silently fall back to the gather+wavefront
    reference."""
    out = run_subprocess(FUSED_DISPATCH_CODE, devices=4)
    assert "OK" in out


STREAM_MATRIX_CODE = r"""
import numpy as np
import jax.numpy as jnp
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan, StreamingEngine
from repro.core.types import PAD_ID, TrajectoryBatch
from repro.data import synthetic_setup

batch, forest = synthetic_setup(24, num_types=6, classes_per_type=3,
                                num_places=40, seed=3)
RHO = 2.0
IMPLS = ("wavefront", "fused-interpret")


def split(batch, k):
    P = np.asarray(batch.places); Ln = np.asarray(batch.lengths)
    cuts = np.linspace(0, P.shape[0], k + 1).astype(int)
    return [TrajectoryBatch(places=jnp.asarray(P[a:b]),
                            lengths=jnp.asarray(Ln[a:b]),
                            user_id=jnp.arange(b - a, dtype=jnp.int32))
            for a, b in zip(cuts[:-1], cuts[1:])]


def score_map(res):
    left = np.asarray(res.scored.left)
    right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss)
    lvl = np.asarray(res.scored.level_lcs)
    keep = left != PAD_ID
    return {
        (int(a), int(b)): (float(m), tuple(int(x) for x in lv))
        for a, b, m, lv in zip(left[keep], right[keep], mss[keep], lvl[keep])
    }


for impl in IMPLS:
    cfg = EngineConfig(rho=RHO, lcs_impl=impl, community_mode="components")
    # the single-device STREAMING run is the reference; it must itself
    # match the one-shot engine bit-exactly
    ref = StreamingEngine(forest, cfg).update_many(split(batch, 3))
    one = AnotherMeEngine(forest, cfg).run(batch)
    assert score_map(ref) == score_map(one), impl
    assert ref.similar_pairs == one.similar_pairs
    assert ref.communities == one.communities
    for n_shards in %(shards)s:
        modes = ("replicate", "shuffle") if n_shards > 1 else ("replicate",)
        for mode in modes:
            st = StreamingEngine(
                forest, cfg,
                ExecutionPlan(n_shards=n_shards, score_mode=mode),
            )
            res = st.update_many(split(batch, 3))
            cell = (n_shards, mode, impl)
            assert res.similar_pairs == ref.similar_pairs, cell
            assert res.communities == ref.communities, cell
            assert score_map(res) == score_map(ref), cell
print("OK stream matrix")
"""


@pytest.mark.slow
def test_streaming_parity_matrix():
    """Streaming axis of the parity matrix: SHARDS x
    {replicate, shuffle} x {wavefront, fused-interpret} micro-batched runs
    are bit-identical to the single-device streaming reference (which is
    itself pinned to the one-shot engine)."""
    out = run_subprocess(STREAM_MATRIX_CODE % {"shards": SHARDS},
                         devices=DEVICES)
    assert "OK stream matrix" in out


STREAM_RECOMPILE_CODE = r"""
import numpy as np
import jax.numpy as jnp
import repro.kernels.lcs.fused as fused
from repro.api import EngineConfig, ExecutionPlan, StreamingEngine
from repro.core.encoding import SemanticForest
from repro.core.types import TrajectoryBatch

calls = []
real = fused.fused_gather_score

def counting(*args, **kwargs):
    calls.append(kwargs.get("interpret"))
    return real(*args, **kwargs)

fused.fused_gather_score = counting

# identity 2-level forest; every update draws places from its own type
# block, so the per-update delta work is constant and the compiled runner
# must be reused verbatim
T = 64
forest = SemanticForest(parents=(np.arange(T, dtype=np.int32),),
                        sizes=(T, T))
B, L, K = 8, 6, 6

def block_batch(u):
    rng = np.random.default_rng(5)  # same relative pattern every update
    places = (u * 8 + rng.integers(0, 8, size=(B, L))).astype(np.int32)
    return TrajectoryBatch(places=jnp.asarray(places),
                           lengths=jnp.asarray(np.full((B,), L, np.int32)),
                           user_id=jnp.arange(B, dtype=jnp.int32))

for mode in ("replicate", "shuffle"):
    st = StreamingEngine(
        forest, EngineConfig(rho=2.0, lcs_impl="fused-interpret"),
        ExecutionPlan(n_shards=2, score_mode=mode),
        world_capacity=B * K,
    )
    traces = []
    n_calls = []
    for u in range(K):
        res = st.update(block_batch(u))
        traces.append(res.stats["score_traces"])
        n_calls.append(len(calls))
    # the first update compiles the streaming runner (the fused kernel is
    # really dispatched inside it: trace-time call with interpret=True)...
    assert traces[0] == 1 and n_calls[0] >= 1, (mode, traces, n_calls)
    assert all(i is True for i in calls), calls
    # ...and every later update reuses it: NO new trace, NO new kernel
    # dispatch registration — per-update cost is pure execution
    assert traces[-1] == traces[0], (mode, traces)
    assert n_calls[-1] == n_calls[0], (mode, n_calls)
    assert st.runner_builds == 1, (mode, st.runner_builds)

# hop/score overlap adds ZERO recompiles: the chunked shuffle runner's
# full per-update trace history (and runner-build count) must EQUAL the
# unchunked one — any world-growth recompile the serial path takes is
# allowed, any EXTRA trace from chunking is not
hist = {}
for oc in (1, 2):
    st = StreamingEngine(
        forest, EngineConfig(rho=2.0, lcs_impl="fused-interpret"),
        ExecutionPlan(n_shards=2, score_mode="shuffle", overlap_chunks=oc),
        world_capacity=B * K,
    )
    hist[oc] = ([st.update(block_batch(u)).stats["score_traces"]
                 for u in range(K)], st.runner_builds)
assert hist[1] == hist[2], hist
print("OK stream recompile", traces, len(calls), hist[2])
"""


@pytest.mark.slow
def test_streaming_updates_reuse_cached_sharded_runner():
    """Real-dispatch proof for streaming: the fused kernel is traced into
    the sharded streaming runner exactly once (compilation-counting hook =
    trace-time side effects), and k subsequent equal-shape updates reuse
    the cached runner with zero recompiles."""
    out = run_subprocess(STREAM_RECOMPILE_CODE, devices=4)
    assert "OK stream recompile" in out


AUTOTUNE_OVERLAP_MATRIX_CODE = r"""
import os
import tempfile

import numpy as np
import repro.api.stages as stages
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.core.types import PAD_ID
from repro.data import fig1_world

# a throwaway tuning table with NON-default parameters: block_b=128
# (default cap 512) and int32 diagonals (env default int8) — parity must
# hold precisely because tuned values may only change throughput
os.environ.pop("REPRO_LCS_DTYPE", None)
os.environ["REPRO_TUNING_PATH"] = os.path.join(
    tempfile.mkdtemp(), "TUNING.json"
)
from repro.perf import LCSTuning, TuningTable

batch, forest = fig1_world()
L = int(np.asarray(batch.places).shape[1])
TUNED = LCSTuning(block_b=128, wavefront_dtype="int32")
table = TuningTable()
table.record(1024, forest.num_levels, L, TUNED)  # nearest-P covers all P
table.save()

seen = []
real = stages.lcs_impl_fn

def recording(name, tuning=None):
    seen.append(tuning)
    return real(name, tuning)

stages.lcs_impl_fn = recording

RHO = 3.0


def score_map(res):
    left = np.asarray(res.scored.left)
    right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss)
    lvl = np.asarray(res.scored.level_lcs)
    keep = left != PAD_ID
    return {
        (int(a), int(b)): (float(m), tuple(int(x) for x in lv))
        for a, b, m, lv in zip(left[keep], right[keep], mss[keep], lvl[keep])
    }


for impl in ("wavefront", "fused-interpret"):
    cfg = EngineConfig(backend="ssh", rho=RHO, lcs_impl=impl)
    seen.clear()
    want = AnotherMeEngine(forest, cfg).run(batch)
    # untuned runs never see a tuning record (autotune=False never probes)
    assert all(t is None for t in seen), seen
    for n_shards in %(shards)s:
        modes = ("replicate", "shuffle") if n_shards > 1 else ("replicate",)
        for mode in modes:
            for oc in ((2, 4) if mode == "shuffle" else (4,)):
                seen.clear()
                res = AnotherMeEngine(
                    forest, cfg,
                    ExecutionPlan(n_shards=n_shards, score_mode=mode,
                                  autotune=True, overlap_chunks=oc),
                ).run(batch)
                cell = (impl, n_shards, mode, oc)
                assert res.similar_pairs == want.similar_pairs, cell
                assert res.communities == want.communities, cell
                assert score_map(res) == score_map(want), cell
                if impl == "wavefront" and n_shards > 1:
                    # real-dispatch proof: the tuned record reached the
                    # impl closure (not silently missed to defaults)
                    assert TUNED in seen, (cell, seen)
print("OK autotune overlap matrix")
"""


@pytest.mark.slow
def test_autotune_overlap_parity_matrix():
    """Autotune + overlap axis: non-default tuned kernel parameters and
    chunked hop/score overlap stay bit-identical to the untuned serial
    defaults across the full one-shot matrix, with a real-dispatch proof
    that the tuned record reaches the impl closure."""
    out = run_subprocess(
        AUTOTUNE_OVERLAP_MATRIX_CODE % {"shards": SHARDS}, devices=DEVICES
    )
    assert "OK autotune overlap matrix" in out


STREAM_AUTOTUNE_OVERLAP_CODE = r"""
import os
import tempfile

import numpy as np
import jax.numpy as jnp
from repro.api import EngineConfig, ExecutionPlan, StreamingEngine
from repro.core.types import PAD_ID, TrajectoryBatch
from repro.data import synthetic_setup

os.environ.pop("REPRO_LCS_DTYPE", None)
os.environ["REPRO_TUNING_PATH"] = os.path.join(
    tempfile.mkdtemp(), "TUNING.json"
)
from repro.perf import LCSTuning, TuningTable

batch, forest = synthetic_setup(24, num_types=6, classes_per_type=3,
                                num_places=40, seed=3)
L = int(np.asarray(batch.places).shape[1])
table = TuningTable()
table.record(1024, forest.num_levels, L,
             LCSTuning(block_b=128, wavefront_dtype="int32"))
table.save()

RHO = 2.0


def split(batch, k):
    P = np.asarray(batch.places); Ln = np.asarray(batch.lengths)
    cuts = np.linspace(0, P.shape[0], k + 1).astype(int)
    return [TrajectoryBatch(places=jnp.asarray(P[a:b]),
                            lengths=jnp.asarray(Ln[a:b]),
                            user_id=jnp.arange(b - a, dtype=jnp.int32))
            for a, b in zip(cuts[:-1], cuts[1:])]


def score_map(res):
    left = np.asarray(res.scored.left)
    right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss)
    keep = left != PAD_ID
    return {(int(a), int(b)): float(m)
            for a, b, m in zip(left[keep], right[keep], mss[keep])}


for impl in ("wavefront", "fused-interpret"):
    cfg = EngineConfig(rho=RHO, lcs_impl=impl, community_mode="components")
    ref = StreamingEngine(forest, cfg).update_many(split(batch, 3))
    for dj in ("host", "device"):
        for oc in (2, 4):
            st = StreamingEngine(
                forest, cfg,
                ExecutionPlan(n_shards=2, score_mode="shuffle",
                              delta_join=dj, autotune=True,
                              overlap_chunks=oc),
            )
            res = st.update_many(split(batch, 3))
            cell = (impl, dj, oc)
            assert res.similar_pairs == ref.similar_pairs, cell
            assert res.communities == ref.communities, cell
            assert score_map(res) == score_map(ref), cell
print("OK stream autotune overlap")
"""


@pytest.mark.slow
def test_streaming_autotune_overlap_parity():
    """Streaming axis of the autotune + overlap matrix: tuned parameters
    plus chunked shuffle scoring stay bit-identical to the single-device
    streaming reference across both delta_join paths."""
    out = run_subprocess(STREAM_AUTOTUNE_OVERLAP_CODE, devices=4)
    assert "OK stream autotune overlap" in out


DELTA_JOIN_MATRIX_CODE = r"""
import numpy as np
import jax.numpy as jnp
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan, StreamingEngine
from repro.core.types import PAD_ID, TrajectoryBatch
from repro.data import synthetic_setup

batch, forest = synthetic_setup(24, num_types=6, classes_per_type=3,
                                num_places=40, seed=3)
RHO = 2.0
IMPLS = ("wavefront", "fused-interpret")


def split(batch, k):
    P = np.asarray(batch.places); Ln = np.asarray(batch.lengths)
    cuts = np.linspace(0, P.shape[0], k + 1).astype(int)
    return [TrajectoryBatch(places=jnp.asarray(P[a:b]),
                            lengths=jnp.asarray(Ln[a:b]),
                            user_id=jnp.arange(b - a, dtype=jnp.int32))
            for a, b in zip(cuts[:-1], cuts[1:])]


def score_map(res):
    left = np.asarray(res.scored.left)
    right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss)
    lvl = np.asarray(res.scored.level_lcs)
    keep = left != PAD_ID
    return {
        (int(a), int(b)): (float(m), tuple(int(x) for x in lv))
        for a, b, m, lv in zip(left[keep], right[keep], mss[keep], lvl[keep])
    }


for impl in IMPLS:
    cfg = EngineConfig(rho=RHO, lcs_impl=impl, community_mode="components")
    one = AnotherMeEngine(forest, cfg).run(batch)
    for mode in ("replicate", "shuffle"):
        results = {}
        for dj in ("host", "device"):
            st = StreamingEngine(
                forest, cfg,
                ExecutionPlan(n_shards=2, score_mode=mode, delta_join=dj),
            )
            results[dj] = st.update_many(split(batch, 3))
        cell = (impl, mode)
        # end-to-end EngineResult bit-identity across the delta_join axis,
        # and against the one-shot engine
        assert score_map(results["device"]) == score_map(results["host"]), cell
        assert score_map(results["device"]) == score_map(one), cell
        assert results["device"].similar_pairs == results["host"].similar_pairs, cell
        assert results["device"].communities == results["host"].communities, cell
        assert results["device"].communities == one.communities, cell
        assert (results["device"].stats["full_world_pairs"]
                == results["host"].stats["full_world_pairs"]), cell
print("OK delta_join matrix")
"""


@pytest.mark.slow
def test_streaming_delta_join_parity_matrix():
    """delta_join axis of the parity matrix: {host, device} x
    {replicate, shuffle} x {wavefront, fused-interpret} streaming runs are
    bit-identical to each other and to the one-shot engine."""
    out = run_subprocess(DELTA_JOIN_MATRIX_CODE, devices=4)
    assert "OK delta_join matrix" in out


DEVICE_JOIN_DISPATCH_CODE = r"""
import numpy as np
import jax.numpy as jnp
import repro.core.stream_index as stream_index
from repro.api import EngineConfig, ExecutionPlan, StreamingEngine
from repro.core.types import TrajectoryBatch
from repro.data import synthetic_setup

calls = []
real = stream_index.BucketIndex.insert

def counting(self, *args, **kwargs):
    calls.append(args)
    return real(self, *args, **kwargs)

stream_index.BucketIndex.insert = counting

batch, forest = synthetic_setup(16, num_types=6, classes_per_type=3,
                                num_places=40, seed=1)

def split(batch, k):
    P = np.asarray(batch.places); Ln = np.asarray(batch.lengths)
    cuts = np.linspace(0, P.shape[0], k + 1).astype(int)
    return [TrajectoryBatch(places=jnp.asarray(P[a:b]),
                            lengths=jnp.asarray(Ln[a:b]),
                            user_id=jnp.arange(b - a, dtype=jnp.int32))
            for a, b in zip(cuts[:-1], cuts[1:])]

cfg = EngineConfig(rho=2.0, community_mode="components")
dev = StreamingEngine(
    forest, cfg, ExecutionPlan(n_shards=2, delta_join="device"),
).update_many(split(batch, 4))
# the device path NEVER consults the driver-resident bucket table
assert not calls, f"device path called BucketIndex.insert {len(calls)}x"

host = StreamingEngine(
    forest, cfg, ExecutionPlan(n_shards=2, delta_join="host"),
).update_many(split(batch, 4))
# ...while the host path really does (the counter is live)
assert len(calls) == 4, len(calls)
assert dev.similar_pairs == host.similar_pairs
assert dev.communities == host.communities
print("OK device join dispatch", len(calls))
"""


@pytest.mark.slow
def test_device_join_never_calls_bucket_index():
    """Real-dispatch proof for delta_join="device": the join state lives
    in-mesh — BucketIndex.insert (the driver-side join) is never invoked,
    while the monkeypatched counter confirms the host path still routes
    through it."""
    out = run_subprocess(DEVICE_JOIN_DISPATCH_CODE, devices=4)
    assert "OK device join dispatch" in out


def test_sharded_engine_has_no_host_encode_stage():
    """n_shards>1 folds Encode into the fused shard_map stage: no host
    EncodeStage, so the code table never materializes replicated."""
    from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
    from repro.data import fig1_world

    _, forest = fig1_world()
    eng = AnotherMeEngine(forest, EngineConfig(), ExecutionPlan(n_shards=4))
    names = [s.name for s in eng._stages]
    assert "encode" not in names
    assert names[0] == "sharded_encode_join_score"


def test_plan_lcs_impl_override_folds_into_config():
    from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
    from repro.data import fig1_world

    _, forest = fig1_world()
    eng = AnotherMeEngine(
        forest, EngineConfig(lcs_impl="wavefront"),
        ExecutionPlan(lcs_impl="pallas"),
    )
    assert eng.config.lcs_impl == "pallas"
    import pytest as _pytest

    with _pytest.raises(ValueError, match="lcs_impl"):
        AnotherMeEngine(forest, EngineConfig(),
                        ExecutionPlan(lcs_impl="no-such-impl"))


SUBTRAJ_MATRIX_CODE = r"""
import numpy as np
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.data import synthetic_setup

backend = "%(backend)s"
batch, forest = synthetic_setup(48, num_types=8, classes_per_type=4,
                                num_places=60, seed=3)
RHO = 1.05
IMPLS = ("wavefront", "fused-interpret")


def score_map(res):
    sc = res.scored
    cnt = int(sc.count)
    left = np.asarray(sc.left)[:cnt]
    right = np.asarray(sc.right)[:cnt]
    mss = np.asarray(sc.mss)[:cnt]
    lvl = np.asarray(sc.level_lcs)[:cnt]
    return {
        (int(a), int(b)): (float(m), tuple(int(x) for x in lv))
        for a, b, m, lv in zip(left, right, mss, lvl)
    }


for impl in IMPLS:
    cfg = EngineConfig(backend=backend, k=2, rho=RHO, lcs_impl=impl,
                       subtraj_window=5, subtraj_stride=1)
    # the single-device subtrajectory engine is the reference; it is
    # itself pinned to the brute-force windowed oracle in
    # test_subtrajectory.py
    want = AnotherMeEngine(forest, cfg).run(batch)
    for n_shards in %(shards)s:
        modes = ("replicate", "shuffle") if n_shards > 1 else ("replicate",)
        for mode in modes:
            eng = AnotherMeEngine(
                forest, cfg,
                ExecutionPlan(n_shards=n_shards, score_mode=mode),
            )
            res = eng.run(batch)
            cell = (backend, n_shards, mode, impl)
            assert res.similar_pairs == want.similar_pairs, cell
            assert res.communities == want.communities, cell
            assert score_map(res) == score_map(want), cell
            if n_shards > 1:
                # steady state: a same-shape re-run must reuse the ONE
                # cached compiled runner — zero recompiles in windowed mode
                res2 = eng.run(batch)
                assert len(eng._runner_cache) == 1, cell
                assert score_map(res2) == score_map(res), cell
print("OK subtraj", backend)
"""


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_subtraj_parity_matrix(backend):
    """Subtrajectory axis of the parity matrix: SHARDS x
    {replicate, shuffle} x {wavefront, fused-interpret} windowed runs are
    bit-identical to the single-device subtrajectory engine, and re-runs
    reuse the cached sharded runner (zero steady-state recompiles)."""
    out = run_subprocess(SUBTRAJ_MATRIX_CODE % {"backend": backend,
                                                "shards": SHARDS},
                         devices=DEVICES)
    assert f"OK subtraj {backend}" in out
