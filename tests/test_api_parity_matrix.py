"""Cross-backend parity matrix for the device-resident sharded pipeline.

Pins the contract of ISSUEs 2 and 3: every cell of

    {ssh, minhash, brp, udf} x {1, 2, 4 shards} x {replicate, shuffle}
                 x {wavefront, pallas-interpret, fused-interpret}

produces identical similar pairs, identical communities and bit-identical
per-pair scores to the single-device engine (and, at n_shards=1, to the
legacy ``run_anotherme``).  Sharded cells run in a subprocess (device count
binds at jax init); one subprocess per backend keeps the matrix affordable
while still compiling every (shards, mode, impl) program.

Also proves the structural claims:
* with n_shards>1 the engine has NO host EncodeStage (encoding runs inside
  the shard_map program) and reports no ``t_encode`` phase;
* ``lcs_impl="pallas-interpret"`` really dispatches ``lcs_pallas`` inside
  the shard_map score stage (counted via monkeypatch at trace time);
* ``lcs_impl="fused-interpret"`` really dispatches the gather-free
  ``fused_gather_score`` kernel, on the single-device AND sharded paths.
"""
import pytest

from conftest import run_subprocess

BACKENDS = ("ssh", "minhash", "brp", "udf")

MATRIX_CODE = r"""
import numpy as np
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.core import AnotherMeConfig, run_anotherme
from repro.core.types import PAD_ID
from repro.data import fig1_world

backend = "%(backend)s"
batch, forest = fig1_world()
RHO = 3.0
IMPLS = ("wavefront", "pallas-interpret", "fused-interpret")


def score_map(res):
    left = np.asarray(res.scored.left)
    right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss)
    lvl = np.asarray(res.scored.level_lcs)
    keep = left != PAD_ID
    return {
        (int(a), int(b)): (float(m), tuple(int(x) for x in lv))
        for a, b, m, lv in zip(left[keep], right[keep], mss[keep], lvl[keep])
    }


base = {}
for impl in IMPLS:
    cfg = EngineConfig(backend=backend, rho=RHO, lcs_impl=impl)
    base[impl] = AnotherMeEngine(forest, cfg).run(batch)

# engine vs engine across impls: integer LCS (and a fixed-order float32
# MSS epilogue in the fused kernel) => bit-identical scores
assert score_map(base["wavefront"]) == score_map(base["pallas-interpret"])
assert score_map(base["wavefront"]) == score_map(base["fused-interpret"])

# engine vs legacy (single device, ssh/udf share the lossless shingle join)
if backend in ("ssh", "udf"):
    legacy = run_anotherme(batch, forest, AnotherMeConfig(rho=RHO))
    assert base["wavefront"].similar_pairs == legacy.similar_pairs
    assert base["wavefront"].communities == legacy.communities

for impl in IMPLS:
    cfg = EngineConfig(backend=backend, rho=RHO, lcs_impl=impl)
    want_pairs = base[impl].similar_pairs
    want_comms = base[impl].communities
    want_scores = score_map(base[impl])
    for n_shards in (1, 2, 4):
        modes = ("replicate", "shuffle") if n_shards > 1 else ("replicate",)
        for mode in modes:
            res = AnotherMeEngine(
                forest, cfg,
                ExecutionPlan(n_shards=n_shards, score_mode=mode),
            ).run(batch)
            cell = (backend, n_shards, mode, impl)
            assert res.similar_pairs == want_pairs, cell
            assert res.communities == want_comms, cell
            assert score_map(res) == want_scores, cell
print("OK", backend)
"""


@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_matrix(backend):
    out = run_subprocess(MATRIX_CODE % {"backend": backend}, devices=4)
    assert f"OK {backend}" in out


PALLAS_DISPATCH_CODE = r"""
import numpy as np
import repro.kernels.lcs.ops as lcs_ops
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.data import fig1_world

calls = []
real = lcs_ops.lcs_pallas

def counting(*args, **kwargs):
    calls.append(kwargs.get("interpret"))
    return real(*args, **kwargs)

lcs_ops.lcs_pallas = counting
batch, forest = fig1_world()
cfg = EngineConfig(rho=3.0)
single = AnotherMeEngine(forest, cfg).run(batch)
assert not calls  # default wavefront impl never touches the kernel

sharded = AnotherMeEngine(
    forest, cfg, ExecutionPlan(n_shards=4, lcs_impl="pallas-interpret"),
).run(batch)
# traced (and therefore executed) inside the shard_map score stage
assert calls and all(interp is True for interp in calls), calls
assert sharded.similar_pairs == single.similar_pairs
assert sharded.communities == single.communities
print("OK", len(calls))
"""


def test_sharded_pallas_dispatch_is_real():
    """ExecutionPlan(lcs_impl=...) must route the Pallas kernel into the
    shard_map score stage — not silently fall back to the wavefront."""
    out = run_subprocess(PALLAS_DISPATCH_CODE, devices=4)
    assert "OK" in out


FUSED_DISPATCH_CODE = r"""
import numpy as np
import repro.kernels.lcs.fused as fused
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.data import fig1_world

calls = []
real = fused.fused_gather_score

def counting(*args, **kwargs):
    calls.append(kwargs.get("interpret"))
    return real(*args, **kwargs)

fused.fused_gather_score = counting
batch, forest = fig1_world()
cfg = EngineConfig(rho=3.0)
single = AnotherMeEngine(forest, cfg).run(batch)
assert not calls  # default wavefront impl never touches the fused kernel

fused_single = AnotherMeEngine(
    forest, EngineConfig(rho=3.0, lcs_impl="fused-interpret"),
).run(batch)
assert calls and all(interp is True for interp in calls), calls
n_single = len(calls)

sharded = AnotherMeEngine(
    forest, cfg, ExecutionPlan(n_shards=4, lcs_impl="fused-interpret"),
).run(batch)
# traced (and therefore executed) inside the shard_map score stage too
assert len(calls) > n_single and all(i is True for i in calls), calls
assert fused_single.similar_pairs == single.similar_pairs
assert sharded.similar_pairs == single.similar_pairs
assert sharded.communities == single.communities
print("OK", len(calls))
"""


def test_fused_dispatch_is_real():
    """lcs_impl="fused-interpret" must route the gather-free fused kernel
    into BOTH score paths — not silently fall back to the gather+wavefront
    reference."""
    out = run_subprocess(FUSED_DISPATCH_CODE, devices=4)
    assert "OK" in out


def test_sharded_engine_has_no_host_encode_stage():
    """n_shards>1 folds Encode into the fused shard_map stage: no host
    EncodeStage, so the code table never materializes replicated."""
    from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
    from repro.data import fig1_world

    _, forest = fig1_world()
    eng = AnotherMeEngine(forest, EngineConfig(), ExecutionPlan(n_shards=4))
    names = [s.name for s in eng._stages]
    assert "encode" not in names
    assert names[0] == "sharded_encode_join_score"


def test_plan_lcs_impl_override_folds_into_config():
    from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
    from repro.data import fig1_world

    _, forest = fig1_world()
    eng = AnotherMeEngine(
        forest, EngineConfig(lcs_impl="wavefront"),
        ExecutionPlan(lcs_impl="pallas"),
    )
    assert eng.config.lcs_impl == "pallas"
    import pytest as _pytest

    with _pytest.raises(ValueError, match="lcs_impl"):
        AnotherMeEngine(forest, EngineConfig(),
                        ExecutionPlan(lcs_impl="no-such-impl"))
