import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.encoding import (
    PAD_CODE_A, SemanticForest, encode_batch, forest_tables,
    make_random_forest, type_codes,
)
from repro.core.types import PAD_PLACE, TrajectoryBatch


def make_batch(places, lengths):
    places = np.asarray(places, np.int32)
    return TrajectoryBatch(
        places=jnp.asarray(places),
        lengths=jnp.asarray(np.asarray(lengths, np.int32)),
        user_id=jnp.arange(places.shape[0], dtype=jnp.int32),
    )


def test_forest_sizes_and_surjectivity():
    f = make_random_forest(30, 10, 10_000, seed=0)
    assert f.sizes == (30, 300, 10_000)
    maps = f.level_maps()
    assert len(maps) == 3
    # every type and class appears (surjective parents)
    assert set(maps[0].tolist()) == set(range(30))
    assert set(maps[1].tolist()) == set(range(300))


@pytest.mark.parametrize("n_levels", [2, 3, 4, 5, 6])
def test_forest_n_levels(n_levels):
    f = make_random_forest(30, 10, 5_000, n_levels=n_levels, seed=1)
    assert f.num_levels == n_levels
    assert f.sizes[0] == 30 and f.sizes[-1] == 5_000
    maps = f.level_maps()
    assert len(maps) == n_levels
    # coarse levels are functions of fine levels (tree consistency)
    for l in range(n_levels - 1):
        via_parent = f.parents[l][maps[l + 1]]
        np.testing.assert_array_equal(via_parent, maps[l])


def test_encode_batch_matches_manual():
    f = make_random_forest(5, 3, 50, seed=2)
    tables = forest_tables(f)
    places = [[3, 7, 3, PAD_PLACE], [10, 11, 12, 13]]
    batch = make_batch(places, [3, 4])
    enc = encode_batch(batch, tables)
    assert enc.codes.shape == (2, 3, 4)
    maps = f.level_maps()
    for lvl in range(3):
        assert int(enc.codes[0, lvl, 0]) == int(maps[lvl][3])
        assert int(enc.codes[0, lvl, 1]) == int(maps[lvl][7])
    # padding gets the sentinel at every level
    assert (np.asarray(enc.codes[0, :, 3]) == PAD_CODE_A).all()
    # repetition preserved: same place -> same code
    assert int(enc.codes[0, 0, 0]) == int(enc.codes[0, 0, 2])


def test_type_codes_view():
    f = make_random_forest(5, 3, 50, seed=3)
    batch = make_batch([[1, 2, 3, 4]], [4])
    enc = encode_batch(batch, forest_tables(f))
    tc = type_codes(enc)
    assert tc.shape == (1, 4)
    assert (np.asarray(tc) < 5).all()
