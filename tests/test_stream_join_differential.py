"""Differential streaming-join harness (ISSUE 5 acceptance).

Pins the in-mesh incremental delta join (``delta_join="device"``,
core/device_index.py + the shard_map join program) bit-identical to the
host ``BucketIndex`` oracle (``delta_join="host"``) and to one-shot
``engine.run`` over the concatenation, across every backend, shard count,
and adversarial update schedule:

* seeded randomized splits plus the degenerate schedules — empty updates,
  singleton updates, a skewed all-colliding-key world (every trajectory
  shares one bucket), and duplicate-trajectory batches;
* per-UPDATE equivalence, not just final: after every update the two
  engines' accumulated scored sets (bit-identical MSS + level LCS per
  pair), similar sets and community partitions match;
* exact work accounting: the per-update ``pairs_examined`` counts of the
  device join partition the full-world pre-dedup join size, verified
  against an independent per-key C(n, 2) oracle built from the backend's
  own keys;
* driver-transfer accounting: the device path ships NO pair list
  (``driver_pair_rows == 0``), holds NO bucket-table state on the driver
  (``host_index_entries == 0``; its only residual driver state is the
  per-distinct-key COUNT mirror surfaced as ``driver_mirror_keys``),
  and its per-update host->device bytes stay delta-sized while the
  world grows;
* zero steady-state recompiles: the join program's trace counter
  plateaus under constant-shape updates (compiles happen only at pow2
  capacity crossings, like the world buffer's amortized doubling).

Shard counts {2, 4} (and the shuffle score mode) bind the device count at
jax init, so those cells run in subprocesses; the {1 shard} axis runs
in-process across the full backend x schedule grid.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess

from repro.api import (
    AnotherMeEngine, EngineConfig, ExecutionPlan, StreamingEngine,
    get_backend,
)
from repro.api.backends import BackendContext
from repro.core.encoding import encode_types, forest_tables
from repro.core.types import PAD_ID, PAD_KEY, TrajectoryBatch
from repro.data import synthetic_setup

# heavy differential sweeps: excluded from tier-1 (pytest.ini deselects
# the slow marker); CI runs this module in the dedicated full-matrix step
pytestmark = pytest.mark.slow

BACKENDS = ("ssh", "minhash", "brp", "udf")


def make_batch(places, lengths):
    return TrajectoryBatch(
        places=jnp.asarray(np.asarray(places, np.int32)),
        lengths=jnp.asarray(np.asarray(lengths, np.int32)),
        user_id=jnp.arange(np.asarray(places).shape[0], dtype=jnp.int32),
    )


def split_batch(batch, cuts):
    places = np.asarray(batch.places)
    lengths = np.asarray(batch.lengths)
    bounds = [0] + sorted(cuts) + [places.shape[0]]
    out = []
    for a, b in zip(bounds[:-1], bounds[1:]):
        p, ln = places[a:b], lengths[a:b]
        w = max(int(ln.max()), 1) if ln.size else 1
        out.append(make_batch(p[:, :w], ln))
    return out


def score_map(res):
    left = np.asarray(res.scored.left)
    right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss)
    lvl = np.asarray(res.scored.level_lcs)
    keep = left != PAD_ID
    return {
        (int(a), int(b)): (float(m), tuple(int(x) for x in lv))
        for a, b, m, lv in zip(left[keep], right[keep], mss[keep], lvl[keep])
    }


def oracle_full_join(batch, forest, backend_name):
    """Independent pre-dedup join-size oracle: sum_key C(|rows(key)|, 2)
    over the backend's own per-row-deduped keys."""
    from collections import Counter

    backend = get_backend(backend_name)
    ctx = BackendContext(k=3, num_types=forest.num_types)
    tables = forest_tables(forest)
    types = encode_types(batch.places, tables)
    from repro.core.types import EncodedBatch

    view = EncodedBatch(codes=types[:, None, :], lengths=batch.lengths)
    keys = np.asarray(backend.join_keys(view, batch, ctx))
    per_key = Counter()
    for row in keys:
        for k in set(row[row != PAD_KEY].tolist()):
            per_key[k] += 1
    return sum(c * (c - 1) // 2 for c in per_key.values())


# ---------------------------------------------------------------------------
# adversarial update schedules
# ---------------------------------------------------------------------------
def schedule_random(seed):
    batch, forest = synthetic_setup(
        16, num_types=6, classes_per_type=3, num_places=40, min_len=2,
        max_len=8, seed=seed,
    )
    rng = np.random.default_rng(100 + seed)
    cuts = sorted(rng.choice(np.arange(0, 17), size=3).tolist())
    return split_batch(batch, cuts), batch, forest


def schedule_empty_and_singleton(seed):
    """Empty first / mid / trailing updates plus singleton updates."""
    batch, forest = synthetic_setup(
        8, num_types=5, classes_per_type=3, num_places=30, min_len=2,
        max_len=6, seed=seed,
    )
    pieces = split_batch(batch, [0, 1, 4, 4, 7, 8])
    assert min(p.num_trajectories for p in pieces) == 0
    assert 1 in {p.num_trajectories for p in pieces}
    return pieces, batch, forest


def schedule_hotkey(seed):
    """Skewed all-colliding-key world: every trajectory is the same place
    repeated, so every backend maps the whole world into ONE bucket —
    maximal per-owner skew for the key-sharded slab."""
    _, forest = synthetic_setup(
        4, num_types=5, classes_per_type=3, num_places=30, seed=seed,
    )
    n, L = 12, 5
    places = np.full((n, L), 7, np.int32)
    lengths = np.full((n,), L, np.int32)
    batch = make_batch(places, lengths)
    return split_batch(batch, [3, 7, 9]), batch, forest


def schedule_duplicates(seed):
    """Duplicate-trajectory batches: the same rows recur within one update
    and across updates (distinct ids, identical keys)."""
    base, forest = synthetic_setup(
        5, num_types=5, classes_per_type=3, num_places=30, min_len=3,
        max_len=6, seed=seed,
    )
    p = np.asarray(base.places)
    ln = np.asarray(base.lengths)
    places = np.concatenate([p, p[:2], p, p[4:]])
    lengths = np.concatenate([ln, ln[:2], ln, ln[4:]])
    batch = make_batch(places, lengths)
    return split_batch(batch, [4, 7, 12]), batch, forest


SCHEDULES = {
    "random": schedule_random,
    "empty_singleton": schedule_empty_and_singleton,
    "hotkey": schedule_hotkey,
    "duplicates": schedule_duplicates,
}


# ---------------------------------------------------------------------------
# the differential property, 1-shard axis (full backend x schedule grid)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("schedule", sorted(SCHEDULES))
def test_device_join_differential(backend, schedule):
    pieces, batch, forest = SCHEDULES[schedule](seed=0)
    cfg = EngineConfig(backend=backend, rho=2.0,
                       community_mode="components")
    host = StreamingEngine(forest, cfg)
    dev = StreamingEngine(forest, cfg, ExecutionPlan(delta_join="device"))
    examined = []
    prev_pairs: set = set()
    for i, piece in enumerate(pieces):
        rh = host.update(piece)
        rd = dev.update(piece)
        cell = (backend, schedule, i)
        # per-update equivalence of the whole accumulated state
        assert score_map(rd) == score_map(rh), cell
        assert rd.similar_pairs == rh.similar_pairs, cell
        assert rd.communities == rh.communities, cell
        # the device join emits exactly the host oracle's delta pairs:
        # same accumulated pair set, disjoint per-update increments
        pairs_now = set(score_map(rd))
        delta = pairs_now - prev_pairs
        assert len(prev_pairs) + len(delta) == len(pairs_now), cell
        assert rd.stats["num_delta_pairs"] == rh.stats["num_delta_pairs"], cell
        prev_pairs = pairs_now
        # exact work accounting, update by update
        assert rd.stats["pairs_examined"] == rh.stats["pairs_examined"], cell
        examined.append(rd.stats["pairs_examined"])
        # transfer accounting: no pair list through the driver, no
        # bucket-table state on the driver (only the count mirror, which
        # is surfaced — not hidden — by its own stat)
        assert rd.stats["driver_pair_rows"] == 0, cell
        assert rd.stats["host_index_entries"] == 0, cell
        assert rd.stats["driver_mirror_keys"] <= rh.stats["host_index_entries"], cell
        assert rh.stats["driver_key_rows"] == 0, cell
        assert rh.stats["driver_mirror_keys"] == 0, cell
    # final state == one-shot over the concatenation
    one = AnotherMeEngine(forest, cfg).run(batch)
    assert score_map(rd) == score_map(one), (backend, schedule)
    assert rd.similar_pairs == one.similar_pairs
    assert rd.communities == one.communities
    # the per-update examined counts partition the full-world pre-dedup
    # join size — pinned against an independent per-key C(n, 2) oracle
    full = oracle_full_join(batch, forest, backend)
    assert sum(examined) == full, (backend, schedule)
    assert rd.stats["full_world_pairs"] == full
    assert rh.stats["full_world_pairs"] == full


SCORE_CAP_CODE = r"""
import numpy as np
import jax.numpy as jnp
from repro.api import EngineConfig, ExecutionPlan, StreamingEngine
from repro.core.types import TrajectoryBatch
from repro.data import synthetic_setup

base, forest = synthetic_setup(5, num_types=5, classes_per_type=3,
                               num_places=30, min_len=3, max_len=6, seed=0)
p = np.asarray(base.places); ln = np.asarray(base.lengths)
places = np.concatenate([p, p[:2], p, p[4:]])
lengths = np.concatenate([ln, ln[:2], ln, ln[4:]])

def mk(lo, hi):
    return TrajectoryBatch(
        places=jnp.asarray(places[lo:hi].astype(np.int32)),
        lengths=jnp.asarray(lengths[lo:hi].astype(np.int32)),
        user_id=jnp.arange(hi - lo, dtype=jnp.int32),
    )

cuts = [0, 4, 7, 12, places.shape[0]]
cfg = EngineConfig(rho=2.0, community_mode="components")
for n_shards in (1, 2):
    st = StreamingEngine(
        forest, cfg, ExecutionPlan(n_shards=n_shards, delta_join="device"))
    caps = []
    for lo, hi in zip(cuts, cuts[1:]):
        res = st.update(mk(lo, hi))
        caps.append((res.stats["score_pair_cap"],
                     res.stats["join_pair_cap"]))
    # the score cap never exceeds the join emission cap, both are
    # pow2-sticky (monotone), ...
    for sc, jc in caps:
        assert sc <= jc, caps
    assert [c[0] for c in caps] == sorted(c[0] for c in caps), caps
    assert [c[1] for c in caps] == sorted(c[1] for c in caps), caps
    # ...and on this schedule (identical rows sharing MANY keys, so each
    # pair is emitted once per shared key pre-dedup) the final score cap
    # is strictly tighter
    assert caps[-1][0] < caps[-1][1], (n_shards, caps)
print("OK score cap")
"""


def test_device_join_score_cap_is_post_dedup():
    """The score stage's pair buffer is sized from the POST-dedup
    candidate count (the in-mesh pmax of per-shard dedup survivors), not
    the join stage's pre-dedup emission bound — on duplicate-heavy
    streams the two diverge and the score program must compile against
    the tighter cap."""
    out = run_subprocess(SCORE_CAP_CODE, devices=2)
    assert "OK score cap" in out


def test_device_join_prune_differential():
    """score_prune runs IN-MESH on the device path (the pairs never visit
    the host to be pruned there) and must keep the surviving scored set
    bit-identical to host-side pruning and to the unpruned similar set."""
    pieces, batch, forest = schedule_random(seed=2)
    cfg = EngineConfig(rho=2.0, score_prune=True,
                       community_mode="components")
    host = StreamingEngine(forest, cfg).update_many(pieces)
    dev = StreamingEngine(
        forest, cfg, ExecutionPlan(delta_join="device")
    ).update_many(pieces)
    one = AnotherMeEngine(forest, cfg).run(batch)
    assert score_map(dev) == score_map(host) == score_map(one)
    assert dev.similar_pairs == host.similar_pairs == one.similar_pairs
    assert dev.communities == host.communities
    assert dev.stats["num_pruned"] == host.stats["num_pruned"]


def test_device_join_transfer_stays_delta_sized():
    """Constant-shape updates into a growing world: per-update
    host->device bytes and key rows must stay bounded by the DELTA (the
    world's keys and the pair list never transit the driver)."""
    from repro.core.encoding import SemanticForest

    T = 128
    forest = SemanticForest(parents=(np.arange(T, dtype=np.int32),),
                            sizes=(T, T))
    B, L, K = 6, 5, 8

    def block_batch(u):
        rng = np.random.default_rng(9)
        places = (u * 8 + rng.integers(0, 8, size=(B, L))).astype(np.int32)
        return make_batch(places, np.full((B,), L, np.int32))

    st = StreamingEngine(
        forest, EngineConfig(rho=2.0), ExecutionPlan(delta_join="device"),
        world_capacity=B * K, join_slab_capacity=B * K * 8,
    )
    bytes_in, key_rows, traces = [], [], []
    for u in range(K):
        res = st.update(block_batch(u))
        bytes_in.append(res.stats["driver_bytes_in"])
        key_rows.append(res.stats["driver_key_rows"])
        traces.append(res.stats["join_traces"])
        assert res.stats["driver_pair_rows"] == 0
        assert res.stats["host_index_entries"] == 0
    # steady state (after the first compile/allocation): constant
    # per-update transfer while the world grows 8x
    assert len(set(bytes_in[1:])) == 1, bytes_in
    assert len(set(key_rows[1:])) == 1, key_rows
    # ...and the compiled join program is reused: the trace counter
    # plateaus (recompiles happen only at pow2 capacity crossings)
    assert traces[-1] == traces[-2] == traces[-3], traces
    assert traces[-1] <= 3, traces


def test_device_join_rejects_bad_plan():
    _, forest = synthetic_setup(4, num_types=5, classes_per_type=3,
                                num_places=30, seed=0)
    with pytest.raises(ValueError, match="delta_join"):
        StreamingEngine(forest, EngineConfig(),
                        ExecutionPlan(delta_join="nope"))


# ---------------------------------------------------------------------------
# sharded axis: {2, 4 shards} x {replicate, shuffle} in a subprocess
# ---------------------------------------------------------------------------
SHARDED_DIFFERENTIAL_CODE = r"""
import numpy as np
import jax.numpy as jnp
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan, StreamingEngine
from repro.core.types import PAD_ID, TrajectoryBatch
from repro.data import synthetic_setup

def split(places, lengths, cuts):
    bounds = [0] + sorted(cuts) + [places.shape[0]]
    out = []
    for a, b in zip(bounds[:-1], bounds[1:]):
        p, ln = places[a:b], lengths[a:b]
        w = max(int(ln.max()), 1) if ln.size else 1
        out.append(TrajectoryBatch(places=jnp.asarray(p[:, :w]),
                                   lengths=jnp.asarray(ln),
                                   user_id=jnp.arange(b - a, dtype=jnp.int32)))
    return out

def score_map(res):
    left = np.asarray(res.scored.left)
    right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss)
    lvl = np.asarray(res.scored.level_lcs)
    keep = left != PAD_ID
    return {
        (int(a), int(b)): (float(m), tuple(int(x) for x in lv))
        for a, b, m, lv in zip(left[keep], right[keep], mss[keep], lvl[keep])
    }

backends = ("ssh", "minhash", "brp", "udf")
for seed, backend in enumerate(backends):
    batch, forest = synthetic_setup(16, num_types=6, classes_per_type=3,
                                    num_places=40, min_len=2, max_len=8,
                                    seed=seed)
    places = np.asarray(batch.places); lengths = np.asarray(batch.lengths)
    rng = np.random.default_rng(50 + seed)
    cuts = sorted(rng.choice(np.arange(0, 17), size=3).tolist())
    pieces = split(places, lengths, cuts)
    cfg = EngineConfig(backend=backend, rho=2.0, community_mode="components")
    # the host-join streaming engine is the oracle; itself pinned to
    # one-shot engine.run by tests/test_streaming.py
    want = StreamingEngine(forest, cfg).update_many(pieces)
    one = AnotherMeEngine(forest, cfg).run(batch)
    assert score_map(want) == score_map(one), backend
    for n_shards in (2, 4):
        for mode in ("replicate", "shuffle"):
            st = StreamingEngine(
                forest, cfg,
                ExecutionPlan(n_shards=n_shards, score_mode=mode,
                              delta_join="device"),
            )
            ex_total = 0
            for piece in pieces:
                res = st.update(piece)
                ex_total += res.stats["pairs_examined"]
                assert res.stats["driver_pair_rows"] == 0
                assert res.stats["host_index_entries"] == 0
            cell = (backend, n_shards, mode)
            assert score_map(res) == score_map(want), cell
            assert res.similar_pairs == want.similar_pairs, cell
            assert res.communities == want.communities, cell
            assert ex_total == want.stats["full_world_pairs"], cell
print("OK sharded differential")
"""


def test_device_join_differential_sharded():
    out = run_subprocess(SHARDED_DIFFERENTIAL_CODE, devices=4)
    assert "OK sharded differential" in out


# ---------------------------------------------------------------------------
# windowed / deletion differential: TTL, retire, sliding window
# ---------------------------------------------------------------------------
def wsched_hotkey_expire(seed):
    """Hot key dominates then expires: the first updates are all-colliding
    rows riding a window=2, later updates are diverse rows — the skewed
    owner's slab fills with tombstones and must compact back down."""
    batch, forest = synthetic_setup(
        12, num_types=6, classes_per_type=3, num_places=30, min_len=2,
        max_len=6, seed=seed,
    )
    div_p = np.asarray(batch.places)
    div_l = np.asarray(batch.lengths)
    hot_p = np.full((8, div_p.shape[1]), 7, np.int32)
    hot_l = np.full((8,), min(5, div_p.shape[1]), np.int32)
    places = np.concatenate([hot_p, div_p])
    lengths = np.concatenate([hot_l, div_l])
    actions = [("update", 0, 4, None), ("update", 4, 8, None),
               ("update", 8, 14, None), ("update", 14, 20, None)]
    return dict(window=2, compact_watermark=0.5), actions, places, lengths, forest


def wsched_interleaved(seed):
    """Interleaved insert/retire: explicit retires between updates, a
    per-batch TTL riding on top, no engine window."""
    batch, forest = synthetic_setup(
        20, num_types=6, classes_per_type=3, num_places=40, min_len=2,
        max_len=8, seed=seed,
    )
    places = np.asarray(batch.places)
    lengths = np.asarray(batch.lengths)
    actions = [
        ("update", 0, 6, None),
        ("retire", [0, 2, 4]),
        ("update", 6, 12, 2),       # TTL: gone at the start of update 4
        ("retire", [7, 5]),
        ("update", 12, 16, None),
        ("update", 16, 20, None),   # the TTL batch expires here
        ("update", 20, 20, None),   # empty trailing update
    ]
    return dict(compact_watermark=0.4), actions, places, lengths, forest


def wsched_retire_everything(seed):
    """Retire the whole world, then keep streaming into the empty shell."""
    batch, forest = synthetic_setup(
        16, num_types=5, classes_per_type=3, num_places=30, min_len=2,
        max_len=6, seed=seed,
    )
    places = np.asarray(batch.places)
    lengths = np.asarray(batch.lengths)
    actions = [
        ("update", 0, 8, None),
        ("retire", list(range(8))),
        ("update", 8, 12, None),
        ("update", 12, 16, None),
    ]
    return dict(), actions, places, lengths, forest


WINDOWED_SCHEDULES = {
    "hotkey_expire": wsched_hotkey_expire,
    "interleaved": wsched_interleaved,
    "retire_everything": wsched_retire_everything,
}


def run_actions(stream, actions, places, lengths):
    """Drive one engine through a schedule; returns per-update results."""
    results = []
    for act in actions:
        if act[0] == "update":
            _, lo, hi, ttl = act
            p, ln = places[lo:hi], lengths[lo:hi]
            w = max(int(ln.max()), 1) if ln.size else 1
            results.append(stream.update(make_batch(p[:, :w], ln), ttl=ttl))
        else:
            stream.retire(act[1])
    return results


def live_reference(stream, cfg, forest, places, lengths):
    """One-shot run over the SURVIVING window, translated to global ids
    (order-preserving: survivor i of the fresh run is global id
    ``alive[i]``) — the equivalence target for windowed streaming."""
    span = stream.n - stream._base
    alive = np.nonzero(stream._alive_np[:span])[0] + stream._base
    if alive.size == 0:
        return {}, set(), set()
    p, ln = places[alive], lengths[alive]
    w = max(int(ln.max()), 1) if ln.size else 1
    ref = AnotherMeEngine(forest, cfg).run(make_batch(p[:, :w], ln))
    g = {i: int(x) for i, x in enumerate(alive.tolist())}
    smap = {(g[a], g[b]): v for (a, b), v in score_map(ref).items()}
    sim = {(g[a], g[b]) for (a, b) in ref.similar_pairs}
    comms = {frozenset(g[i] for i in s) for s in ref.communities}
    return smap, sim, comms


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("schedule", sorted(WINDOWED_SCHEDULES))
def test_windowed_deletion_differential(backend, schedule):
    """Windowed streaming == one-shot over the surviving window, device
    join bit-identical to the host oracle at every update, through TTL
    expiry, explicit retirement, tombstone compaction and base rebases.

    NOTE: ``pairs_examined`` parity is deliberately NOT asserted here —
    the host oracle evicts buckets eagerly while the device slab defers
    reclamation behind tombstones (tombstoned slots still count as
    examined until a compaction), so under deletion the two paths agree
    on RESULTS, not on probe work.
    """
    kwargs, actions, places, lengths, forest = \
        WINDOWED_SCHEDULES[schedule](seed=0)
    cfg = EngineConfig(backend=backend, rho=2.0,
                       community_mode="components")
    host = StreamingEngine(forest, cfg, **kwargs)
    dev = StreamingEngine(forest, cfg, ExecutionPlan(delta_join="device"),
                          **kwargs)
    rh_all = run_actions(host, actions, places, lengths)
    rd_all = run_actions(dev, actions, places, lengths)
    for i, (rh, rd) in enumerate(zip(rh_all, rd_all)):
        cell = (backend, schedule, i)
        assert score_map(rd) == score_map(rh), cell
        assert rd.similar_pairs == rh.similar_pairs, cell
        assert rd.communities == rh.communities, cell
        # deletion must not reintroduce driver-resident pair/bucket state
        assert rd.stats["driver_pair_rows"] == 0, cell
        assert rd.stats["host_index_entries"] == 0, cell
        # the BENCH_stream v3 bounded-memory counters ride every update
        for k in ("world_live", "num_expired", "retired_total",
                  "resident_bytes", "dead_fraction", "compactions",
                  "compact_ms_total"):
            assert k in rd.stats, (cell, k)
    assert host.live_size == dev.live_size, (backend, schedule)
    assert host.retired_total == dev.retired_total, (backend, schedule)
    # final state == one-shot over the survivors (global-id translated)
    smap, sim, comms = live_reference(dev, cfg, forest, places, lengths)
    assert score_map(rd_all[-1]) == smap, (backend, schedule)
    assert rd_all[-1].similar_pairs == sim, (backend, schedule)
    assert rd_all[-1].communities == comms, (backend, schedule)
    if schedule == "hotkey_expire":
        # the expiring hot prefix must actually have tripped a compaction
        assert dev.compactions >= 1, (backend, dev.compactions)
        assert dev._base > 0


def test_windowed_fault_injection_differential(monkeypatch):
    """REPRO_FAULT_INJECT=1 derates every fresh plan to tiny caps, forcing
    the overflow -> compact -> retry recovery deterministically; results
    must stay bit-identical to the unfaulted host oracle."""
    kwargs, actions, places, lengths, forest = wsched_hotkey_expire(seed=1)
    cfg = EngineConfig(rho=2.0, community_mode="components")
    host = StreamingEngine(forest, cfg, **kwargs)
    rh_all = run_actions(host, actions, places, lengths)
    monkeypatch.setenv("REPRO_FAULT_INJECT", "1")
    dev = StreamingEngine(forest, cfg, ExecutionPlan(delta_join="device"),
                          **kwargs)
    rd_all = run_actions(dev, actions, places, lengths)
    for i, (rh, rd) in enumerate(zip(rh_all, rd_all)):
        assert score_map(rd) == score_map(rh), i
        assert rd.similar_pairs == rh.similar_pairs, i
        assert rd.communities == rh.communities, i
    # the derated caps must actually have exercised the recovery path
    assert dev.compactions >= 1


SHARDED_WINDOWED_CODE = r"""
import os
import numpy as np
import jax.numpy as jnp
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan, StreamingEngine
from repro.core.types import PAD_ID, TrajectoryBatch
from repro.data import synthetic_setup

def mk(p, ln):
    w = max(int(ln.max()), 1) if ln.size else 1
    return TrajectoryBatch(places=jnp.asarray(p[:, :w].astype(np.int32)),
                           lengths=jnp.asarray(ln.astype(np.int32)),
                           user_id=jnp.arange(p.shape[0], dtype=np.int32))

def score_map(res):
    left = np.asarray(res.scored.left); right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss); lvl = np.asarray(res.scored.level_lcs)
    keep = left != PAD_ID
    return {(int(a), int(b)): (float(m), tuple(int(x) for x in lv))
            for a, b, m, lv in zip(left[keep], right[keep], mss[keep], lvl[keep])}

def run_actions(stream, actions, places, lengths):
    out = []
    for act in actions:
        if act[0] == "update":
            _, lo, hi, ttl = act
            out.append(stream.update(mk(places[lo:hi], lengths[lo:hi]), ttl=ttl))
        else:
            stream.retire(act[1])
    return out

shards = [int(s) for s in os.environ["TEST_SHARDS"].split(",")]
for seed, backend in enumerate(("ssh", "minhash", "brp", "udf")):
    batch, forest = synthetic_setup(20, num_types=6, classes_per_type=3,
                                    num_places=40, min_len=2, max_len=8,
                                    seed=seed)
    places = np.asarray(batch.places); lengths = np.asarray(batch.lengths)
    actions = [("update", 0, 6, None), ("retire", [0, 2, 4]),
               ("update", 6, 12, 2), ("retire", [7, 5]),
               ("update", 12, 16, None), ("update", 16, 20, None)]
    cfg = EngineConfig(backend=backend, rho=2.0, community_mode="components")
    kwargs = dict(window=3, compact_watermark=0.4)
    want_all = run_actions(StreamingEngine(forest, cfg, **kwargs),
                           actions, places, lengths)
    for n_shards in shards:
        st = StreamingEngine(
            forest, cfg,
            ExecutionPlan(n_shards=n_shards, delta_join="device"), **kwargs)
        got_all = run_actions(st, actions, places, lengths)
        for i, (want, got) in enumerate(zip(want_all, got_all)):
            cell = (backend, n_shards, i)
            assert score_map(got) == score_map(want), cell
            assert got.similar_pairs == want.similar_pairs, cell
            assert got.communities == want.communities, cell
            assert got.stats["driver_pair_rows"] == 0, cell
print("OK sharded windowed")
"""


def test_windowed_deletion_differential_sharded(monkeypatch):
    import os

    shards = "2,4"
    devices = 4
    if int(os.environ.get("REPRO_MAX_SHARDS", "0") or "0") >= 8:
        shards, devices = "2,4,8", 8
    monkeypatch.setenv("TEST_SHARDS", shards)
    out = run_subprocess(SHARDED_WINDOWED_CODE, devices=devices)
    assert "OK sharded windowed" in out


def test_device_join_refuses_lossy_commit(monkeypatch):
    """If the join still overflows after the retry budget (only reachable
    when the exact-planning invariant is broken — forced here with a
    deliberately undersized plan), the engine must RAISE rather than
    commit a slab whose merge dropped entries: a lossy bucket state would
    silently miss pairs forever."""
    from repro.api.capacity import CapacityPlanner
    from repro.api.sharded import StreamJoinPlan

    pieces, _, forest = schedule_hotkey(seed=0)

    def tiny(self, keys_flat, n_shards, stats, *, floor_pow2=4):
        return StreamJoinPlan(
            n_shards=n_shards, slab_cap=4, key_in_cap=256,
            key_route_cap=4, nn_cap=4, no_cap=4,
            pair_route_cap=4, pair_cap=4,
        )

    monkeypatch.setattr(CapacityPlanner, "plan_stream_join", tiny)
    st = StreamingEngine(forest, EngineConfig(rho=2.0, max_retries=0),
                         ExecutionPlan(delta_join="device"))
    with pytest.raises(RuntimeError, match="refusing to commit"):
        for piece in pieces:
            st.update(piece)
