"""MoE block correctness: the shard_map sort-dispatch path must equal the
dense per-token mixture reference when capacity is not binding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import init_params
from repro.models.moe import moe_block

CFG = ModelConfig(
    name="moe-test", family="moe", num_layers=1, d_model=32, num_heads=2,
    num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128, attn="gqa",
    num_experts=8, experts_per_token=2, moe_d_ff=16,
    capacity_factor=8.0,  # never drop
)


def dense_moe_reference(x, p, cfg):
    """Every token through every expert, weighted by normalized top-k gates."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate = gate / gate.sum(-1, keepdims=True)
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    # [T, E, f]
    h = jnp.einsum("td,edf->tef", xf, w_gate.astype(xf.dtype))
    u = jnp.einsum("td,edf->tef", xf, w_up.astype(xf.dtype))
    y = jnp.einsum("tef,efd->ted",
                   jax.nn.silu(h.astype(jnp.float32)).astype(xf.dtype) * u,
                   w_down.astype(xf.dtype))
    mask = jnp.zeros((T, cfg.num_experts), jnp.float32)
    mask = jax.vmap(lambda m, i, g: m.at[i].add(g))(mask, idx, gate)
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), mask)
    return out.reshape(B, S, d).astype(x.dtype)


def _params():
    full = init_params(
        dataclasses.replace(CFG), jax.random.PRNGKey(0), dtype=jnp.float32
    )
    return jax.tree.map(lambda a: a[0], full["blocks"]["moe"])


def test_moe_matches_dense_reference(smoke_mesh):
    p = _params()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 32)).astype(np.float32))
    got, aux = moe_block(x, p, CFG, smoke_mesh)
    want = dense_moe_reference(x, p, CFG)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    assert float(aux) > 0  # load-balance loss populated


def test_moe_capacity_drops_reported_softly(smoke_mesh):
    """With capacity_factor << 1 tokens get dropped (outputs shrink toward
    zero) but nothing crashes and shapes hold — GShard semantics."""
    cfg = dataclasses.replace(CFG, capacity_factor=0.05)
    p = _params()
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 16, 32)).astype(np.float32))
    got, _ = moe_block(x, p, cfg, smoke_mesh)
    full = dense_moe_reference(x, p, CFG)
    assert got.shape == x.shape
    assert float(jnp.abs(got).mean()) < float(jnp.abs(full).mean())


def test_moe_grads_flow(smoke_mesh):
    p = _params()
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 8, 32)).astype(np.float32))

    def loss(p):
        out, aux = moe_block(x, p, CFG, smoke_mesh)
        return (out ** 2).sum() + aux

    g = jax.grad(loss)(p)
    norms = {k: float(jnp.abs(v).max()) for k, v in g.items()}
    assert all(np.isfinite(list(norms.values())))
    assert norms["w_gate"] > 0 and norms["router"] > 0
