"""Property tests for the MSS upper-bound pruning pass.

The contract (ISSUE 3): pruned-then-scored results equal
score-everything-then-threshold.  Pruning drops pairs whose free bound
``sum_h beta_h * min(len_a, len_b)`` cannot clear ``rho`` BEFORE exact
scoring — so the scored buffer shrinks, but the similar-pair set, the
communities, and every surviving pair's exact scores are unchanged,
bit-for-bit, on the single-device and the sharded path alike.

Worlds are random and length-skewed (seeded generators, same idiom as the
other property tests): a heavy head of short trajectories makes the bound
actually bite.  The all-pairs-pruned and nothing-pruned edges are pinned
explicitly.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess

from repro.api import AnotherMeEngine, EngineConfig
from repro.core.encoding import make_random_forest
from repro.core.types import CandidatePairs, PAD_ID, TrajectoryBatch


def _skewed_world(seed, n=40, max_len=12, num_places=200):
    """A random world with a skewed length distribution (many short rows)."""
    rng = np.random.default_rng(seed)
    forest = make_random_forest(6, 4, num_places, seed=seed + 1)
    lengths = rng.choice(
        np.arange(3, max_len + 1),
        size=n,
        p=_skew_probs(max_len - 2),
    ).astype(np.int32)
    places = rng.integers(0, num_places, size=(n, max_len)).astype(np.int32)
    places[np.arange(max_len)[None, :] >= lengths[:, None]] = -1
    batch = TrajectoryBatch(
        places=jnp.asarray(places), lengths=jnp.asarray(lengths),
        user_id=jnp.arange(n, dtype=jnp.int32),
    )
    return batch, forest


def _skew_probs(k):
    w = 1.0 / np.arange(1, k + 1)
    return w / w.sum()


def _score_map(res):
    left = np.asarray(res.scored.left)
    right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss)
    lvl = np.asarray(res.scored.level_lcs)
    keep = left != PAD_ID
    return {
        (int(a), int(b)): (float(m), tuple(int(x) for x in lv))
        for a, b, m, lv in zip(left[keep], right[keep], mss[keep], lvl[keep])
    }


def _assert_prune_equiv(pruned_res, full_res, rho):
    """pruned-then-scored == score-everything-then-threshold."""
    assert pruned_res.similar_pairs == full_res.similar_pairs
    assert pruned_res.communities == full_res.communities
    pm, fm = _score_map(pruned_res), _score_map(full_res)
    # survivors are a subset of the full scored set, bit-identical per pair
    for pair, scores in pm.items():
        assert fm[pair] == scores, pair
    # and no pair that clears the threshold was pruned
    for pair, (mss, _) in fm.items():
        if mss > rho:
            assert pair in pm, pair


@pytest.mark.parametrize("seed,rho", [(0, 4.0), (1, 5.0), (2, 6.0), (3, 7.5)])
@pytest.mark.parametrize("impl", ["wavefront", "fused-interpret"])
def test_prune_equals_threshold(seed, rho, impl):
    batch, forest = _skewed_world(seed)
    full = AnotherMeEngine(
        forest, EngineConfig(rho=rho, lcs_impl=impl)
    ).run(batch)
    pruned = AnotherMeEngine(
        forest, EngineConfig(rho=rho, lcs_impl=impl, score_prune=True)
    ).run(batch)
    _assert_prune_equiv(pruned, full, rho)
    n_full = len(_score_map(full))
    n_kept = len(_score_map(pruned))
    assert pruned.stats["num_pruned"] == n_full - n_kept
    assert int(np.asarray(pruned.scored.overflow)) == 0


def test_all_pairs_pruned_edge():
    """rho above the best possible bound: every candidate is pruned, the
    similar set is empty on both runs, and nothing is scored."""
    batch, forest = _skewed_world(5, max_len=10)
    rho = 10.0 + 1.0  # ub <= max_len * sum(betas) = 10 < rho
    full = AnotherMeEngine(forest, EngineConfig(rho=rho)).run(batch)
    pruned = AnotherMeEngine(
        forest, EngineConfig(rho=rho, score_prune=True)
    ).run(batch)
    _assert_prune_equiv(pruned, full, rho)
    assert full.similar_pairs == set()
    assert len(_score_map(pruned)) == 0
    assert pruned.stats["num_pruned"] == len(_score_map(full))


def test_nothing_pruned_edge():
    """rho below every bound: pruning keeps everything and the scored
    buffers agree pair-for-pair."""
    batch, forest = _skewed_world(6)
    rho = 0.5  # every pair has ub >= min length (3) * sum(betas) = 3
    full = AnotherMeEngine(forest, EngineConfig(rho=rho)).run(batch)
    pruned = AnotherMeEngine(
        forest, EngineConfig(rho=rho, score_prune=True)
    ).run(batch)
    _assert_prune_equiv(pruned, full, rho)
    assert pruned.stats["num_pruned"] == 0
    assert _score_map(pruned) == _score_map(full)


def test_prune_candidates_unit():
    """Direct unit test of the compaction: PAD slots stay out, survivors
    compact to the front, exact-threshold ties are kept (scored, then
    rejected by the strict > rho test), and the planner sizes the buffer."""
    from repro.api.capacity import CapacityPlanner
    from repro.api.stages import prune_candidates

    lengths = jnp.asarray([10, 2, 10, 5], jnp.int32)
    left = jnp.asarray([0, 1, 2, PAD_ID], jnp.int32)
    right = jnp.asarray([2, 0, 3, PAD_ID], jnp.int32)
    cand = CandidatePairs(
        left=left, right=right,
        count=jnp.asarray(3, jnp.int32), overflow=jnp.asarray(0, jnp.int32),
    )
    betas = jnp.asarray([0.5, 0.5], jnp.float32)  # betas_sum = 1.0
    planner = CapacityPlanner(floor_pow2=2)
    # tau = 5.0: (0,2) ub=10 kept; (1,0) ub=2 pruned; (2,3) ub=5 == tau ->
    # cannot exceed tau but the eps guard keeps the tie on the scored side
    pruned, n = prune_candidates(cand, lengths, betas, 5.0, planner)
    got = np.asarray(pruned.left)
    assert n == 1
    assert int(pruned.count) == 2
    assert got[0] == 0 and got[1] == 2
    assert (got[2:] == PAD_ID).all()
    # tau just above the tie: the length-5 pair is pruned too
    pruned2, n2 = prune_candidates(cand, lengths, betas, 5.01, planner)
    assert n2 == 2 and int(pruned2.count) == 1


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_shuffle_prune_plan_covers_dedup_shard_survivors(seed):
    """In shuffle mode the post-prune buffer first holds survivors
    compacted AT the dedup shard (before the owner hops), then the resting
    loads at owner(right) — so shuffle-mode pruned_cap must be at least the
    replicate-mode sizing (which is exactly the dedup-shard survivor
    skew)."""
    from repro.api.sharded import plan_capacities

    rng = np.random.default_rng(seed)
    n = 64
    # heavy key skew: a few hot keys concentrate pairs on few dedup shards
    keys = rng.choice([5, 5, 5, 7, 11, 13], size=(n, 4)).astype(np.int32)
    lengths = rng.choice([3, 4, 10, 12], size=n).astype(np.int32)
    kw = dict(lengths_np=lengths, prune_tau=6.0, betas_sum=1.0)
    rep = plan_capacities(keys, 4, score_mode="replicate", **kw)
    shf = plan_capacities(keys, 4, score_mode="shuffle", **kw)
    assert shf.pruned_cap >= rep.pruned_cap
    assert rep.pruned_cap > 0


SHARDED_PRUNE_CODE = r"""
import numpy as np
import jax.numpy as jnp
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.core.encoding import make_random_forest
from repro.core.types import PAD_ID, TrajectoryBatch

rng = np.random.default_rng(11)
n, L = 48, 12
forest = make_random_forest(6, 4, 200, seed=2)
lengths = rng.choice([3, 4, 5, 10, 11, 12], size=n).astype(np.int32)
places = rng.integers(0, 200, size=(n, L)).astype(np.int32)
places[np.arange(L)[None, :] >= lengths[:, None]] = -1
batch = TrajectoryBatch(places=jnp.asarray(places),
                        lengths=jnp.asarray(lengths),
                        user_id=jnp.arange(n, dtype=jnp.int32))
RHO = 6.0


def score_map(res):
    left = np.asarray(res.scored.left)
    right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss)
    keep = left != PAD_ID
    return {(int(a), int(b)): float(m)
            for a, b, m in zip(left[keep], right[keep], mss[keep])}


full = AnotherMeEngine(forest, EngineConfig(rho=RHO)).run(batch)
fm = score_map(full)
want_pruned = None
for impl in ("wavefront", "fused-interpret"):
    for n_shards, mode in ((1, "replicate"), (2, "replicate"),
                           (2, "shuffle"), (4, "shuffle")):
        res = AnotherMeEngine(
            forest,
            EngineConfig(rho=RHO, lcs_impl=impl, score_prune=True),
            ExecutionPlan(n_shards=n_shards, score_mode=mode),
        ).run(batch)
        cell = (impl, n_shards, mode)
        assert res.similar_pairs == full.similar_pairs, cell
        assert res.communities == full.communities, cell
        pm = score_map(res)
        assert all(fm[k] == v for k, v in pm.items()), cell
        assert all(k in pm for k, v in fm.items() if v > RHO), cell
        got_pruned = res.stats["num_pruned"]
        assert got_pruned > 0, cell
        if want_pruned is None:
            want_pruned = got_pruned
        # every path prunes the exact same pair set
        assert got_pruned == want_pruned, cell
print("OK", want_pruned)
"""


def test_sharded_prune_parity():
    """The in-mesh pruning pass drops the same pairs on every
    {shards} x {score_mode} x {impl} cell as the single-device pass, and
    the thresholded results match the unpruned run."""
    out = run_subprocess(SHARDED_PRUNE_CODE, devices=4)
    assert "OK" in out
