"""The paper's headline claims, as tests:

* AnotherMe == centralized ground truth: QA1 = QA2 = 100%  (Figs. 10/12)
* the UDF implementation is logic-identical                 (section V.1)
* MinHash / BRP lose accuracy                               (Figs. 10/12)
* SSH completeness: every pair with MSS > rho shares a k-shingle for
  k <= floor(rho)+1                                         (section IV.3)
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AnotherMeConfig, centralized_similar_pairs, default_betas, encode_batch,
    forest_tables, maximal_cliques, minhash_candidates, qa1, qa2,
    run_anotherme, type_codes, udf_pipeline, brp_candidates,
)
from repro.core.shingling import shingles_from_types
from repro.core.similarity import multi_level_lcs
from repro.core.types import PAD_KEY
from repro.data import synthetic_setup


@pytest.fixture(scope="module")
def small_world():
    batch, forest = synthetic_setup(
        250, num_types=10, classes_per_type=5, num_places=200, seed=7
    )
    enc = encode_batch(batch, forest_tables(forest))
    cl, cr, _ = centralized_similar_pairs(enc, rho=2.0)
    cen_pairs = {(int(a), int(b)) for a, b in zip(cl, cr)}
    cen_comms = maximal_cliques(cen_pairs)
    return batch, forest, enc, cen_pairs, cen_comms


def test_anotherme_100_percent_accuracy(small_world):
    batch, forest, enc, cen_pairs, cen_comms = small_world
    res = run_anotherme(batch, forest, AnotherMeConfig())
    assert qa2(res.similar_pairs, cen_pairs) == 1.0
    assert res.similar_pairs == cen_pairs          # not just recall: exact
    assert qa1(res.communities, cen_comms) == 1.0
    assert res.communities == cen_comms


def test_udf_identical_logic(small_world):
    batch, forest, enc, cen_pairs, _ = small_world
    similar_udf, scores = udf_pipeline(
        np.asarray(batch.places), np.asarray(batch.lengths), forest
    )
    assert similar_udf == cen_pairs


def test_minhash_loses_accuracy(small_world):
    batch, forest, enc, cen_pairs, cen_comms = small_world
    res = run_anotherme(
        batch, forest, AnotherMeConfig(),
        candidate_fn=lambda e, b: minhash_candidates(
            type_codes(e), b.lengths, num_perm=16, bands=4,
            pair_capacity=1 << 18,
        ),
    )
    acc = qa2(res.similar_pairs, cen_pairs)
    assert acc < 0.9  # the paper reports large drops; exact value is data-dependent


def test_brp_worst_accuracy(small_world):
    batch, forest, enc, cen_pairs, cen_comms = small_world
    res_brp = run_anotherme(
        batch, forest, AnotherMeConfig(),
        candidate_fn=lambda e, b: brp_candidates(
            type_codes(e), b.lengths, num_types=forest.num_types,
            pair_capacity=1 << 18,
        ),
    )
    res_mh = run_anotherme(
        batch, forest, AnotherMeConfig(),
        candidate_fn=lambda e, b: minhash_candidates(
            type_codes(e), b.lengths, num_perm=16, bands=4,
            pair_capacity=1 << 18,
        ),
    )
    assert qa2(res_brp.similar_pairs, cen_pairs) <= qa2(res_mh.similar_pairs, cen_pairs)


def test_kernel_backed_pipeline_identical(small_world):
    batch, forest, enc, cen_pairs, _ = small_world
    res = run_anotherme(batch, forest, AnotherMeConfig(lcs_impl="kernel"))
    assert res.similar_pairs == cen_pairs


@pytest.mark.parametrize("seed", range(30))
def test_ssh_completeness_theorem(seed):
    """Section IV.3: for threshold rho with n = floor(rho), any pair with
    MSS > rho has |M_typ| >= n+1, hence shares a (n+1)-sequential shingle.
    With k = 3 and rho = 2 every similar pair is SSH-recoverable."""
    rng = np.random.default_rng(seed)
    L, Q = 8, 6
    la, lb = rng.integers(3, L + 1, size=2)
    ta = rng.integers(0, Q, size=(1, L)).astype(np.int32)
    tb = rng.integers(0, Q, size=(1, L)).astype(np.int32)
    # single-level (type) world: betas = [1.0]
    lv = multi_level_lcs(
        jnp.asarray(ta[:, None, :]), jnp.asarray([la]),
        jnp.asarray(tb[:, None, :]), jnp.asarray([lb]),
    )
    mss = float(lv[0, 0])
    rho, k = 2.0, 3
    if mss > rho:
        ka = shingles_from_types(jnp.asarray(ta), jnp.asarray([la]), k=k, num_types=Q)
        kb = shingles_from_types(jnp.asarray(tb), jnp.asarray([lb]), k=k, num_types=Q)
        sa = set(np.asarray(ka)[0][np.asarray(ka)[0] != PAD_KEY].tolist())
        sb = set(np.asarray(kb)[0][np.asarray(kb)[0] != PAD_KEY].tolist())
        assert sa & sb, "similar pair missed by SSH — completeness violated"


def test_semantic_levels_2_to_6():
    """Fig. 15: accuracy stays 100% for 2..6-level hierarchies."""
    for n_levels in (2, 3, 4, 5, 6):
        batch, forest = synthetic_setup(
            120, num_types=8, classes_per_type=4, num_places=100,
            n_levels=n_levels, seed=11,
        )
        enc = encode_batch(batch, forest_tables(forest))
        cl, cr, _ = centralized_similar_pairs(enc, rho=2.0)
        cen_pairs = {(int(a), int(b)) for a, b in zip(cl, cr)}
        res = run_anotherme(batch, forest, AnotherMeConfig())
        assert res.similar_pairs == cen_pairs, f"n_levels={n_levels}"
