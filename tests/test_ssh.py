import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ssh import dedup_pairs, exact_pair_count, pairs_from_rows, ssh_candidates
from repro.core.types import PAD_ID, PAD_KEY


def brute_force_join(keys_2d):
    """Oracle: all unordered trajectory pairs sharing >=1 key."""
    n = keys_2d.shape[0]
    sets = [set(r[r != PAD_KEY].tolist()) for r in keys_2d]
    out = set()
    for i, j in itertools.combinations(range(n), 2):
        if sets[i] & sets[j]:
            out.add((i, j))
    return out


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_join_matches_bruteforce(seed):
    rng = np.random.default_rng(seed)
    n, s = 60, 12
    keys = rng.integers(0, 40, size=(n, s)).astype(np.int32)
    # dedup per row + pad like the shingler does
    for i in range(n):
        row = np.unique(keys[i])
        keys[i] = PAD_KEY
        keys[i, : len(row)] = row
    cand = ssh_candidates(jnp.asarray(keys), pair_capacity=1 << 14)
    got = {
        (int(a), int(b))
        for a, b in zip(np.asarray(cand.left), np.asarray(cand.right))
        if a != PAD_ID
    }
    assert int(cand.overflow) == 0
    assert got == brute_force_join(keys)
    assert int(cand.count) == len(got)


def test_exact_pair_count():
    keys = np.array([[1, 2], [1, 3], [1, 4], [5, PAD_KEY]], np.int32)
    # key 1 shared by rows 0,1,2 -> C(3,2)=3 raw pairs
    assert exact_pair_count(jnp.asarray(keys)) == 3


def test_overflow_reported_not_silent():
    keys = np.full((40, 1), 7, np.int32)  # one run of 40 -> 780 pairs
    cand = ssh_candidates(jnp.asarray(keys), pair_capacity=128)
    assert int(cand.overflow) == 780 - 128


def test_pair_dedup_scores_once():
    """Two trajectories sharing MANY shingles must appear exactly once
    (paper section IV.3: 'calculated only once')."""
    keys = np.array([[10, 11, 12, 13], [10, 11, 12, 13]], np.int32)
    cand = ssh_candidates(jnp.asarray(keys), pair_capacity=64)
    valid = np.asarray(cand.left) != PAD_ID
    assert valid.sum() == 1
    assert int(cand.count) == 1


@pytest.mark.parametrize("seed", range(50))
def test_join_property(seed):
    """Property test (seeded generator): the sort-merge join equals the
    brute-force oracle on random small key sets."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 25))
    data = [
        rng.integers(0, 9, size=rng.integers(1, 6)).tolist() for _ in range(n)
    ]
    s = 5
    keys = np.full((n, s), PAD_KEY, np.int32)
    for i, row in enumerate(data):
        u = sorted(set(row))
        keys[i, : len(u)] = u
    cand = ssh_candidates(jnp.asarray(keys), pair_capacity=1 << 12)
    got = {
        (int(a), int(b))
        for a, b in zip(np.asarray(cand.left), np.asarray(cand.right))
        if a != PAD_ID
    }
    assert got == brute_force_join(keys)


def test_dedup_pairs_idempotent_and_canonical():
    lo = jnp.asarray([5, 1, 5, PAD_ID, 2], jnp.int32)
    hi = jnp.asarray([3, 2, 3, PAD_ID, 2], jnp.int32)  # (2,2) self-pair dropped
    out = dedup_pairs(jnp.minimum(lo, hi), jnp.maximum(lo, hi))
    pairs = {
        (int(a), int(b))
        for a, b in zip(np.asarray(out.left), np.asarray(out.right))
        if a != PAD_ID
    }
    assert pairs == {(1, 2), (3, 5)}
    assert int(out.count) == 2
