"""Training substrate: loss decreases, grad-accum equivalence, 8-bit
optimizer, EF gradient compression, straggler watchdog."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.inputs import make_inputs
from repro.models.model import init_params
from repro.train.optimizer import (
    OptConfig, adamw_update, dequantize_block_int8, init_opt_state,
    quantize_block_int8,
)
from repro.train.compression import ef_compress, init_residuals
from repro.train.straggler import StragglerWatchdog
from repro.train.train_step import TrainConfig, make_train_state, make_train_step

TINY = ModelConfig(
    name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, attn="gqa",
)
SHAPE = ShapeConfig("t", 32, 8, "train")


def _fixed_batch(seed=0):
    return make_inputs(TINY, SHAPE, seed=seed)


def test_loss_decreases(smoke_mesh):
    params = init_params(TINY, jax.random.PRNGKey(0), dtype=jnp.float32)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=5))
    state = make_train_state(params, tcfg)
    step = jax.jit(make_train_step(TINY, tcfg, smoke_mesh), donate_argnums=(0, 1))
    batch = _fixed_batch()
    losses = []
    for _ in range(30):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[::10]


def test_grad_accum_equivalence(smoke_mesh):
    """accum=1 vs accum=4 produce (nearly) identical updates."""
    params = init_params(TINY, jax.random.PRNGKey(1), dtype=jnp.float32)
    batch = _fixed_batch()
    outs = {}
    for accum in (1, 4):
        tcfg = TrainConfig(opt=OptConfig(lr=1e-3), grad_accum=accum)
        state = make_train_state(params, tcfg)
        step = jax.jit(make_train_step(TINY, tcfg, smoke_mesh))
        p2, _, m = step(params, state, batch)
        outs[accum] = p2
    flat1 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(outs[1])])
    flat4 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(outs[4])])
    assert float(jnp.max(jnp.abs(flat1 - flat4))) < 1e-4


def test_block_int8_roundtrip():
    rng = np.random.default_rng(0)
    for shape in [(7,), (300,), (4, 515), (3, 2, 256)]:
        x = (rng.normal(size=shape) * rng.uniform(0.01, 10)).astype(np.float32)
        q = quantize_block_int8(jnp.asarray(x))
        deq = np.asarray(dequantize_block_int8(q, shape))
        assert deq.shape == shape
        blockmax = np.abs(x).max()
        assert np.abs(deq - x).max() <= blockmax / 127.0 * 1.01


def test_adamw_8bit_close_to_fp32(smoke_mesh):
    params = init_params(TINY, jax.random.PRNGKey(2), dtype=jnp.float32)
    grads = jax.tree.map(
        lambda p: jnp.asarray(
            np.random.default_rng(0).normal(size=p.shape, scale=0.01), p.dtype
        ),
        params,
    )
    outs = {}
    for bits in (32, 8):
        cfg = OptConfig(lr=1e-3, state_bits=bits)
        st = init_opt_state(params, cfg)
        p2, st2, _ = adamw_update(params, grads, st, cfg)
        outs[bits] = p2
    f32 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(outs[32])])
    f8 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(outs[8])])
    base = jnp.concatenate([x.ravel() for x in jax.tree.leaves(params)])
    upd32 = f32 - base
    upd8 = f8 - base
    # updates agree in direction and magnitude within quantization noise
    cos = float(jnp.sum(upd32 * upd8) / (jnp.linalg.norm(upd32) * jnp.linalg.norm(upd8) + 1e-12))
    assert cos > 0.98, cos


def test_ef_compression_bias_vanishes():
    """Error feedback: the RUNNING SUM of decompressed grads tracks the true
    sum (compression bias does not accumulate)."""
    rng = np.random.default_rng(3)
    g_true_sum = np.zeros((1000,), np.float32)
    g_seen_sum = np.zeros((1000,), np.float32)
    grads = {"w": jnp.zeros((1000,), jnp.float32)}
    resid = init_residuals(grads)
    for step in range(50):
        g = rng.normal(size=1000).astype(np.float32) * 0.1
        g_true_sum += g
        out, resid = ef_compress({"w": jnp.asarray(g)}, resid)
        g_seen_sum += np.asarray(out["w"])
    # without EF the per-step quantization error would accumulate ~sqrt(50)x
    err = np.abs(g_seen_sum - g_true_sum).max()
    single_step_err = np.abs(0.1 * 3) / 127  # ~1 block scale
    assert err < 5 * single_step_err, err


def test_compressed_training_still_learns(smoke_mesh):
    params = init_params(TINY, jax.random.PRNGKey(4), dtype=jnp.float32)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=5), compress_grads=True)
    state = make_train_state(params, tcfg)
    assert "ef_residual" in state
    step = jax.jit(make_train_step(TINY, tcfg, smoke_mesh), donate_argnums=(0, 1))
    batch = _fixed_batch()
    losses = []
    for _ in range(30):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0


def test_straggler_watchdog():
    events = []
    wd = StragglerWatchdog(threshold=5.0, on_event=events.append)
    jitter = [0.0, 0.002, -0.002, 0.001, -0.001, 0.003, -0.003, 0.0]
    for step in range(60):
        for host in range(4):
            dur = 0.10 + jitter[(step + host) % len(jitter)]
            if host == 2 and step >= 35:
                dur = 0.50  # host 2 degrades persistently at step 35
            wd.observe(step, host, dur)
    big = [ev for ev in events if ev.duration > 0.4]
    assert big and big[0].host == 2 and big[0].step == 35
    assert all(ev.host == 2 for ev in big)
    # after sustained degradation, host 2 ranks slowest by median
    assert wd.slowest_hosts(1)[0][0] == 2
