"""CapacityPlanner policy + exact sharded capacity planning under skew.

* seeded property tests for ``run_with_retry``: the no-overflow fast path,
  overflow-triggered doubling, and the doubling bound after max_retries;
* ``plan_capacities`` regression with deliberately skewed key
  distributions: the old uniform-hash bound undersized the pair-dedup
  shuffle and the shuffle-mode owner hops; the plan must now cover the
  exact per-bucket loads (computed here by brute force with the device's
  own hash functions);
* an end-to-end engine run on a skewed world in ``score_mode="shuffle"``
  that must succeed on the FIRST capacity attempt (no retry doubling).
"""
import dataclasses
import itertools

import numpy as np
import pytest

from conftest import run_subprocess
from repro.api.capacity import CapacityPlanner
from repro.api.sharded import (
    _pair_hash_np, _positive_hash_np, plan_capacities,
)
from repro.core.types import CandidatePairs, PAD_KEY


def _fake_build(true_total, calls):
    """A candidate builder whose overflow mirrors ssh_candidates': the join
    has ``true_total`` pairs; capacity below that overflows by the rest."""

    def build(capacity):
        calls.append(capacity)
        return CandidatePairs(
            left=None, right=None,
            count=min(capacity, true_total),
            overflow=max(true_total - capacity, 0),
        )

    return build


class TestRunWithRetry:
    def test_no_overflow_fast_path(self):
        rng = np.random.default_rng(0)
        planner = CapacityPlanner(max_retries=3)
        for _ in range(50):
            total = int(rng.integers(0, 1 << 16))
            cap = total + int(rng.integers(1, 1 << 10))
            calls = []
            cand, final = planner.run_with_retry(_fake_build(total, calls), cap)
            assert calls == [cap]          # exactly one build, no retries
            assert final == cap
            assert int(cand.overflow) == 0

    def test_overflow_doubles_until_it_fits(self):
        rng = np.random.default_rng(1)
        planner = CapacityPlanner(max_retries=6)
        for _ in range(100):
            total = int(rng.integers(1, 1 << 20))
            cap = int(rng.integers(1, total + 1))
            calls = []
            cand, final = planner.run_with_retry(_fake_build(total, calls), cap)
            # doublings: smallest k with cap * 2**k >= total (capped below)
            k = 0
            c = cap
            while c < total and k < planner.max_retries:
                c *= 2
                k += 1
            assert calls == [cap * 2**i for i in range(k + 1)]
            assert final == cap * 2**k
            if final >= total:
                assert int(cand.overflow) == 0
            else:   # persistent overflow is surfaced, never dropped
                assert int(cand.overflow) == total - final

    def test_doubling_bound_after_max_retries(self):
        rng = np.random.default_rng(2)
        for _ in range(50):
            retries = int(rng.integers(0, 5))
            planner = CapacityPlanner(max_retries=retries)
            cap = int(rng.integers(1, 64))
            calls = []
            cand, final = planner.run_with_retry(
                _fake_build(1 << 30, calls), cap
            )
            assert final == cap * 2**retries      # hard doubling bound
            assert len(calls) == retries + 1
            assert int(cand.overflow) > 0

    def test_initial_capacity_power_of_two_floor(self):
        planner = CapacityPlanner(slack=1.1, floor_pow2=10)
        assert planner.initial_capacity(0) == 1 << 10
        cap = planner.initial_capacity(3000)
        assert cap >= 3300 and cap & (cap - 1) == 0


def _brute_force_loads(keys, n_shards):
    """Reference per-bucket loads from first principles (itertools), using
    the device hash functions to place rows/pairs on shards."""
    n, _ = keys.shape
    local_n = -(-n // n_shards)
    by_key = {}
    for i in range(n):
        for key in keys[i]:
            if key != PAD_KEY:
                by_key.setdefault(int(key), []).append(i)
    pre = []        # (lo, hi, join_shard) incl. duplicates across keys
    for key, members in by_key.items():
        shard = int(_positive_hash_np(np.int32(key)) % n_shards)
        for a, b in itertools.combinations(members, 2):
            pre.append((min(a, b), max(a, b), shard))
    load2 = np.zeros((n_shards, n_shards), np.int64)
    for lo, hi, src in pre:
        dst = int(_pair_hash_np(np.int32(lo), np.int32(hi)) % n_shards)
        load2[src, dst] += 1
    uniq = sorted({(lo, hi) for lo, hi, _ in pre if lo != hi})
    per_dedup = np.zeros(n_shards, np.int64)
    h1 = np.zeros((n_shards, n_shards), np.int64)
    h2 = np.zeros((n_shards, n_shards), np.int64)
    per_owner_hi = np.zeros(n_shards, np.int64)
    for lo, hi in uniq:
        ded = int(_pair_hash_np(np.int32(lo), np.int32(hi)) % n_shards)
        per_dedup[ded] += 1
        h1[ded, lo // local_n] += 1
        h2[lo // local_n, hi // local_n] += 1
        per_owner_hi[hi // local_n] += 1
    return {
        "pair_route": int(load2.max()),
        "scored": int(per_dedup.max()),
        "owner_hop": int(max(h1.max(), h2.max())),
        "owner_hi": int(per_owner_hi.max()),
        "total_pre": len(pre),
    }


def _skewed_keys(n=64, s=8, hot_fraction=0.75):
    """Most rows share one hot key (a celebrity shingle); every other key
    is globally unique — the uniform-hash bound undersizes every pair stage
    here because all pre-dedup pairs come from ONE join shard."""
    keys = np.full((n, s), PAD_KEY, np.int32)
    n_hot = int(n * hot_fraction)
    keys[:n_hot, 0] = 12345
    uniq = np.arange(n * (s - 1), dtype=np.int32) * 7919 + 65537
    keys[:, 1:] = uniq.reshape(n, s - 1)
    return keys


class TestSkewedPlanning:
    N_SHARDS = 4

    def test_pair_shuffle_caps_cover_skewed_loads(self):
        keys = _skewed_keys()
        truth = _brute_force_loads(keys, self.N_SHARDS)
        plan = plan_capacities(keys, self.N_SHARDS, slack=1.1)
        assert plan.pair_route_cap >= truth["pair_route"]
        assert plan.scored_cap >= truth["scored"]
        # the old uniform-hash bound demonstrably undersized the dedup
        # shuffle for this distribution (all pairs from one join shard)
        uniform_cap3 = int(np.ceil(
            truth["total_pre"] / self.N_SHARDS**2 * 1.1 * 2)) + 64
        assert truth["pair_route"] > uniform_cap3

    def test_shuffle_mode_plans_per_owner_loads(self):
        # star skew: row 0 shares a distinct key with every other row, so
        # every deduped pair has owner(left) == shard 0
        n, n_shards = 64, self.N_SHARDS
        keys = np.full((n, n), PAD_KEY, np.int32)
        for i in range(1, n):
            keys[0, i] = i
            keys[i, 0] = i
        truth = _brute_force_loads(keys, n_shards)
        plan = plan_capacities(keys, n_shards, slack=1.1,
                               score_mode="shuffle")
        assert plan.owner_route_cap >= truth["owner_hop"]
        assert plan.scored_cap >= max(truth["scored"], truth["owner_hi"])
        # replicate-mode plans don't pay for the hops
        rep = plan_capacities(keys, n_shards, slack=1.1)
        assert rep.owner_route_cap == 0

    def test_exact_pair_limit_falls_back_to_uniform_bound(self):
        keys = _skewed_keys()
        plan = plan_capacities(keys, self.N_SHARDS, slack=1.1,
                               exact_pair_limit=1)
        assert plan.owner_route_cap == 0
        assert plan.pair_route_cap > 0 and plan.scored_cap > 0


SKEWED_ENGINE_CODE = r"""
import dataclasses
import numpy as np, jax.numpy as jnp
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.api.sharded import plan_capacities
from repro.core import encode_types, forest_tables, make_random_forest
from repro.core.shingling import shingles_from_types
from repro.core.types import TrajectoryBatch

rng = np.random.default_rng(11)
forest = make_random_forest(6, 3, 60, seed=5)
n, L = 48, 8
places = rng.integers(0, 60, size=(n, L)).astype(np.int32)
places[: n // 2] = places[0]     # half the world walks the same route
lengths = np.full((n,), L, np.int32)
batch = TrajectoryBatch(jnp.asarray(places), jnp.asarray(lengths),
                        jnp.arange(n, dtype=jnp.int32))

cfg = EngineConfig(rho=2.0)
single = AnotherMeEngine(forest, cfg).run(batch)
for mode in ("replicate", "shuffle"):
    eng = AnotherMeEngine(forest, cfg,
                          ExecutionPlan(n_shards=4, score_mode=mode))
    res = eng.run(batch)
    assert res.similar_pairs == single.similar_pairs, mode
    assert res.communities == single.communities, mode
    assert res.stats["join_overflow"] == 0, mode
    # first-attempt success: the recorded plan equals the exact plan with
    # NO retry doublings applied
    tables = forest_tables(forest)
    keys_np = np.asarray(shingles_from_types(
        encode_types(batch.places, tables), batch.lengths, k=3,
        num_types=forest.num_types))
    expected = plan_capacities(keys_np, 4, slack=1.3, score_mode=mode)
    assert res.stats["shard_plan"] == dataclasses.asdict(expected), mode
print("OK")
"""


def test_skewed_world_shuffle_mode_first_attempt():
    out = run_subprocess(SKEWED_ENGINE_CODE, devices=4)
    assert "OK" in out
