"""Streaming-vs-oneshot equivalence suite (ISSUE 4 acceptance).

For random worlds split into 1..k micro-batches — including singleton and
empty updates — the final scored edge set and community partition from
``StreamingEngine.update`` must be identical (as sets, and bit-identical
MSS per surviving pair) to a single ``engine.run`` over the concatenated
batch, across {ssh, minhash, brp, udf} x {score_prune on/off}.  Also pins
the delta-only contract: per-update pair generation examines strictly
fewer pairs than the full-world join would, and the per-update examined
counts sum exactly to the full-world pre-dedup join size.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import AnotherMeEngine, EngineConfig, StreamingEngine
from repro.api.capacity import CapacityPlanner
from repro.core.stream_index import BucketIndex
from repro.core.types import PAD_ID, PAD_KEY, PAD_PLACE, TrajectoryBatch
from repro.data import synthetic_setup

BACKENDS = ("ssh", "minhash", "brp", "udf")


def make_batch(places: np.ndarray, lengths: np.ndarray) -> TrajectoryBatch:
    return TrajectoryBatch(
        places=jnp.asarray(places.astype(np.int32)),
        lengths=jnp.asarray(lengths.astype(np.int32)),
        user_id=jnp.arange(places.shape[0], dtype=jnp.int32),
    )


def split_batch(batch: TrajectoryBatch, cuts) -> list[TrajectoryBatch]:
    """Split rows at ``cuts``; each piece is re-padded to its OWN max
    length so the streaming world's width has to grow across updates."""
    places = np.asarray(batch.places)
    lengths = np.asarray(batch.lengths)
    bounds = [0] + sorted(cuts) + [places.shape[0]]
    out = []
    for a, b in zip(bounds[:-1], bounds[1:]):
        p, ln = places[a:b], lengths[a:b]
        w = max(int(ln.max()), 1) if ln.size else 1
        out.append(make_batch(p[:, :w], ln))
    return out


def score_map(res):
    left = np.asarray(res.scored.left)
    right = np.asarray(res.scored.right)
    mss = np.asarray(res.scored.mss)
    lvl = np.asarray(res.scored.level_lcs)
    keep = left != PAD_ID
    return {
        (int(a), int(b)): (float(m), tuple(int(x) for x in lv))
        for a, b, m, lv in zip(left[keep], right[keep], mss[keep], lvl[keep])
    }


def random_world(seed, n=18):
    rng = np.random.default_rng(seed)
    return synthetic_setup(
        n, num_types=int(rng.integers(4, 8)), classes_per_type=3,
        num_places=int(rng.integers(20, 60)), min_len=2, max_len=8,
        seed=seed,
    )


def random_cuts(seed, n, k):
    rng = np.random.default_rng(1000 + seed)
    cuts = sorted(rng.choice(np.arange(0, n + 1), size=k - 1).tolist())
    return cuts  # duplicates / 0 / n produce EMPTY micro-batches


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("prune", (False, True))
def test_streaming_matches_oneshot(backend, prune):
    """The acceptance property, across backends x prune x random splits."""
    for seed in (0, 1, 2):
        batch, forest = random_world(seed)
        cfg = EngineConfig(
            backend=backend, rho=2.0, score_prune=prune,
            community_mode="components",
        )
        want = AnotherMeEngine(forest, cfg).run(batch)
        k = 2 + seed  # 2..4 micro-batches
        pieces = split_batch(batch, random_cuts(seed, batch.num_trajectories, k))
        stream = StreamingEngine(forest, cfg)
        examined = []
        for piece in pieces:
            res = stream.update(piece)
            examined.append(res.stats["pairs_examined"])
        cell = (backend, prune, seed)
        assert res.similar_pairs == want.similar_pairs, cell
        assert res.communities == want.communities, cell
        assert score_map(res) == score_map(want), cell
        # delta-only accounting: the per-update collisions partition the
        # full-world pre-dedup join exactly — each pair is examined in the
        # one update where its later member arrives, and never again
        full = res.stats["full_world_pairs"]
        assert sum(examined) == full, cell
        if full and sum(1 for e in examined if e) > 1:
            assert max(examined) < full, cell


def test_streaming_every_prefix_matches_oneshot():
    """Equivalence holds at EVERY update, not just the last: the result
    after update i equals one-shot over the concatenation of batches
    0..i."""
    batch, forest = random_world(7)
    cfg = EngineConfig(rho=2.0, community_mode="components")
    places = np.asarray(batch.places)
    lengths = np.asarray(batch.lengths)
    cuts = [4, 9, 9, 14]
    stream = StreamingEngine(forest, cfg)
    for piece, end in zip(split_batch(batch, cuts),
                          sorted(cuts) + [batch.num_trajectories]):
        res = stream.update(piece)
        want = AnotherMeEngine(forest, cfg).run(
            make_batch(places[:end], lengths[:end])
        )
        assert res.similar_pairs == want.similar_pairs, end
        assert res.communities == want.communities, end
        assert score_map(res) == score_map(want), end


def test_singleton_and_empty_updates():
    """Explicit degenerate splits: empty first update, singletons, empty
    mid-stream update, trailing empty update."""
    batch, forest = random_world(3, n=8)
    cfg = EngineConfig(rho=2.0)
    want = AnotherMeEngine(forest, cfg).run(batch)
    # cuts at 0 and n make empty pieces; adjacent cuts make singletons
    pieces = split_batch(batch, [0, 1, 4, 4, 7, 8])
    assert min(p.num_trajectories for p in pieces) == 0
    assert 1 in {p.num_trajectories for p in pieces}
    stream = StreamingEngine(forest, cfg)
    res = stream.update_many(pieces)
    assert res.similar_pairs == want.similar_pairs
    assert res.communities == want.communities
    assert score_map(res) == score_map(want)
    assert stream.world_size == batch.num_trajectories


def test_streaming_components_jit_matches_unionfind():
    """The two incremental community paths agree with each other and with
    the one-shot partition after every update."""
    batch, forest = random_world(11)
    cfg = EngineConfig(rho=1.5, community_mode="components")
    pieces = split_batch(batch, [5, 11])
    uf = StreamingEngine(forest, cfg, components_impl="unionfind")
    jit = StreamingEngine(forest, cfg, components_impl="jit")
    for piece in pieces:
        r_uf = uf.update(piece)
        r_jit = jit.update(piece)
        assert r_uf.communities == r_jit.communities
        # the maintained labels are interchangeable fixpoints
        np.testing.assert_array_equal(uf._labels, jit._labels)
    want = AnotherMeEngine(forest, cfg).run(batch)
    assert r_uf.communities == want.communities


def test_streaming_lcs_impls_and_cliques_bit_identical():
    """lcs_impl routes the same dispatch as the one-shot stage; cliques
    mode re-runs the Bron-Kerbosch oracle over the accumulated edges."""
    batch, forest = random_world(5)
    for impl in ("wavefront", "fused-interpret", "pallas-interpret"):
        cfg = EngineConfig(rho=2.0, lcs_impl=impl)  # cliques mode default
        want = AnotherMeEngine(forest, cfg).run(batch)
        res = StreamingEngine(forest, cfg).update_many(
            split_batch(batch, [6, 12])
        )
        assert score_map(res) == score_map(want), impl
        assert res.communities == want.communities, impl


def test_streaming_validates_inputs():
    _, forest = random_world(0, n=4)
    with pytest.raises(ValueError, match="components_impl"):
        StreamingEngine(forest, components_impl="nope")
    with pytest.raises(ValueError, match="micro-batch"):
        StreamingEngine(forest).update_many([])


# ---------------------------------------------------------------------------
# the incremental pieces in isolation
# ---------------------------------------------------------------------------
def test_bucket_index_partitions_oneshot_join():
    """Union over updates == one-shot pairs; each pair exactly once; the
    examined counts sum to the full-world pre-dedup join size."""
    rng = np.random.default_rng(0)
    n, s = 30, 4
    keys = rng.integers(0, 9, size=(n, s)).astype(np.int32)
    keys[rng.random(size=(n, s)) < 0.3] = PAD_KEY
    row_keys = [set(keys[i][keys[i] != PAD_KEY].tolist()) for i in range(n)]
    want = set()
    for i in range(n):
        for j in range(i + 1, n):
            if row_keys[i] & row_keys[j]:
                want.add((i, j))
    # independent oracle for the pre-dedup join size: sum_k C(|rows(k)|, 2)
    from collections import Counter

    per_key = Counter(k for ks in row_keys for k in ks)
    oracle_full = sum(c * (c - 1) // 2 for c in per_key.values())
    for cuts in ([n], [7, 19], [1, 2, 3, 29], list(range(n + 1))):
        index = BucketIndex()
        got: set = set()
        examined_total = 0
        prev = 0
        for c in sorted(set(cuts + [n])):
            lo, hi, examined = index.insert(keys[prev:c], first_id=prev)
            examined_total += examined
            delta = set(zip(lo.tolist(), hi.tolist()))
            assert not (got & delta), "pair emitted twice"
            got |= delta
            prev = c
        assert got == want, cuts
        assert examined_total == oracle_full, cuts
        assert index.full_join_size() == oracle_full, cuts


def test_bucket_index_rejects_out_of_order_rows():
    index = BucketIndex()
    index.insert(np.full((3, 1), PAD_KEY, np.int32))
    with pytest.raises(ValueError, match="in order"):
        index.insert(np.full((2, 1), PAD_KEY, np.int32), first_id=99)


def test_capacity_planner_growth_policy():
    p = CapacityPlanner()
    # amortized doubling: unchanged while covered, then the smallest
    # power-of-two multiple of current that covers
    assert p.grow_capacity(64, 10) == 64
    assert p.grow_capacity(64, 65) == 128
    assert p.grow_capacity(64, 400) == 512
    assert p.grow_capacity(0, 1) == 1
    # update caps quantize to pow2 with a small floor
    assert p.update_capacity(0) == 16
    assert p.update_capacity(100) == 128
    caps = {p.update_capacity(k) for k in range(40, 58)}
    assert caps == {64}, "similar update sizes must share one jit cache"


def test_streaming_world_growth_and_preallocation():
    """Amortized doubling: ingesting N rows in k updates reallocates
    O(log N) times; a world_capacity hint pre-sizes the buffers."""
    batch, forest = synthetic_setup(64, num_types=6, classes_per_type=3,
                                    num_places=50, seed=0)
    pieces = split_batch(batch, list(range(4, 64, 4)))
    st = StreamingEngine(forest, EngineConfig(rho=2.0))
    caps = []
    for piece in pieces:
        st.update(piece)
        caps.append(st._cap)
    assert len(set(caps)) <= 1 + int(np.ceil(np.log2(64 / 16))) + 1
    assert caps[-1] >= 64
    pre = StreamingEngine(forest, EngineConfig(rho=2.0), world_capacity=64)
    for piece in pieces:
        pre.update(piece)
    assert pre._cap == pre._cap_floor  # never reallocated


def test_bucket_index_hot_key_warns_but_stays_exact():
    """ISSUE 5 fix: hot buckets grow unboundedly on the driver — crossing
    the per-bucket cap must WARN (once per key), never truncate: a
    pathological single-key world still completes with exact
    pairs_examined accounting."""
    n = 30
    keys = np.zeros((n, 1), np.int32)  # every row shares ONE key
    index = BucketIndex(hot_bucket_warn=8)
    examined_total = 0
    pairs: set = set()
    with pytest.warns(RuntimeWarning, match="bucket for key 0"):
        for start in range(0, n, 5):
            lo, hi, examined = index.insert(keys[start : start + 5],
                                            first_id=start)
            examined_total += examined
            pairs |= set(zip(lo.tolist(), hi.tolist()))
    assert examined_total == n * (n - 1) // 2       # exact partition
    assert index.full_join_size() == examined_total
    assert pairs == {(i, j) for i in range(n) for j in range(i + 1, n)}
    # warned exactly once for the one hot key
    assert index._warned_keys == {0}
    # default cap is high enough that ordinary worlds never warn
    import warnings as _warnings

    quiet = BucketIndex()
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        quiet.insert(keys[:20])


def test_streaming_hot_key_world_completes_exactly():
    """Engine-level regression: an all-colliding-key world with a tiny
    warn cap completes, warns, and the examined counts still partition
    the C(n, 2) full join."""
    _, forest = random_world(0, n=4)
    n, L = 12, 4
    places = np.full((n, L), 3, np.int32)
    lengths = np.full((n,), L, np.int32)
    batch = make_batch(places, lengths)
    want = AnotherMeEngine(forest, EngineConfig(rho=2.0)).run(batch)
    stream = StreamingEngine(forest, EngineConfig(rho=2.0))
    stream._index = BucketIndex(hot_bucket_warn=4)
    examined = []
    with pytest.warns(RuntimeWarning, match="delta_join"):
        for piece in split_batch(batch, [5, 9]):
            res = stream.update(piece)
            examined.append(res.stats["pairs_examined"])
    assert res.similar_pairs == want.similar_pairs
    assert res.communities == want.communities
    assert score_map(res) == score_map(want)
    assert sum(examined) == res.stats["full_world_pairs"]
