"""Unit tests for the repro.perf autotuning table (ISSUE 9).

The table's whole safety story is (a) stale tables degrade to untuned
defaults, never to wrong tiles — so every invalidation path must return
an EMPTY table, and (b) tuned values can change throughput but never
results — so validation rejects any cell that could diverge (non-pow2
blocks, unknown dtypes, int8 diagonals at L >= 127) and the env
reproducibility pin outranks the tuned dtype.
"""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.perf import (
    LCSTuning, SCHEMA, TuningTable, quantize_pairs, resolve_wavefront_dtype,
    tuning_path,
)


def _table_with(key_cells):
    t = TuningTable()
    for (pairs, levels, length), tuning in key_cells.items():
        t.record(pairs, levels, length, tuning)
    return t


class TestQuantize:
    def test_ceiling_pow2(self):
        assert quantize_pairs(1) == 1
        assert quantize_pairs(2) == 2
        assert quantize_pairs(3) == 4
        assert quantize_pairs(4096) == 4096
        assert quantize_pairs(4097) == 8192

    def test_degenerate(self):
        assert quantize_pairs(0) == 1


class TestLCSTuningValidation:
    def test_rejects_non_pow2_block(self):
        with pytest.raises(ValueError, match="power of two"):
            LCSTuning(block_b=96, wavefront_dtype="int32")

    def test_rejects_unknown_dtype(self):
        with pytest.raises(ValueError, match="wavefront_dtype"):
            LCSTuning(block_b=128, wavefront_dtype="float32")

    def test_record_rejects_int8_at_long_lengths(self):
        # int8 diagonals saturate at 127: recording one for L >= 127 could
        # make a tuned run diverge from the int32 default
        t = TuningTable()
        with pytest.raises(ValueError, match="unsafe"):
            t.record(1024, 3, 127, LCSTuning(128, "int8"))
        t.record(1024, 3, 127, LCSTuning(128, "int32"))  # int32 fine
        t.record(1024, 3, 126, LCSTuning(128, "int8"))   # short L fine


class TestRoundTrip:
    def test_save_load(self, tmp_path):
        path = tmp_path / "TUNING.json"
        t = _table_with({
            (4096, 3, 32): LCSTuning(256, "int8", pairs_per_sec=1e5),
            (1024, 3, 16): LCSTuning(512, "int32"),
        })
        t.save(path)
        back = TuningTable.load(path)
        assert back.entries == t.entries
        assert back.lookup(4096, 3, 32) == LCSTuning(256, "int8", 1e5)

    def test_env_path_override(self, tmp_path, monkeypatch):
        p = tmp_path / "elsewhere.json"
        monkeypatch.setenv("REPRO_TUNING_PATH", str(p))
        assert tuning_path() == p
        _table_with({(64, 3, 16): LCSTuning(128, "int32")}).save()
        assert p.exists()
        assert TuningTable.load().lookup(64, 3, 16) is not None


class TestInvalidation:
    """Every mismatch degrades to the EMPTY table, never a partial one."""

    def _saved(self, tmp_path):
        path = tmp_path / "TUNING.json"
        _table_with({(4096, 3, 32): LCSTuning(256, "int8")}).save(path)
        return path

    def test_missing_file(self, tmp_path):
        assert TuningTable.load(tmp_path / "nope.json").entries == {}

    def test_corrupt_json(self, tmp_path):
        path = tmp_path / "TUNING.json"
        path.write_text("{not json")
        assert TuningTable.load(path).entries == {}

    @pytest.mark.parametrize("field,value", [
        ("schema", "repro-tuning/v0"),
        ("jax_version", "0.0.1"),
        ("backend", "not-a-backend"),
    ])
    def test_header_mismatch(self, tmp_path, field, value):
        path = self._saved(tmp_path)
        raw = json.loads(path.read_text())
        assert raw["schema"] == SCHEMA
        raw[field] = value
        path.write_text(json.dumps(raw))
        assert TuningTable.load(path).entries == {}

    def test_corrupt_cell_discards_whole_table(self, tmp_path):
        path = self._saved(tmp_path)
        raw = json.loads(path.read_text())
        key = next(iter(raw["entries"]))
        raw["entries"]["P64-H3-L16-cpu"] = {"block_b": 96,
                                            "wavefront_dtype": "int32"}
        path.write_text(json.dumps(raw))
        t = TuningTable.load(path)
        assert t.entries == {}          # the GOOD cell is gone too
        assert key not in t.entries


class TestLookup:
    def test_exact_hit_is_p_quantized(self):
        t = _table_with({(4096, 3, 32): LCSTuning(256, "int8")})
        # 3000 quantizes to the same P4096 buffer the planner would pad to
        assert t.lookup(3000, 3, 32) == LCSTuning(256, "int8")

    def test_nearest_p_fallback(self):
        t = _table_with({
            (1024, 3, 32): LCSTuning(128, "int8"),
            (65536, 3, 32): LCSTuning(512, "int8"),
        })
        assert t.lookup(2048, 3, 32) == LCSTuning(128, "int8")
        assert t.lookup(32768, 3, 32) == LCSTuning(512, "int8")

    def test_miss_on_different_shape(self):
        t = _table_with({(4096, 3, 32): LCSTuning(256, "int8")})
        assert t.lookup(4096, 5, 32) is None   # H differs
        assert t.lookup(4096, 3, 64) is None   # L differs


class TestDtypeResolution:
    def test_untuned_falls_back_to_env_default(self, monkeypatch):
        monkeypatch.delenv("REPRO_LCS_DTYPE", raising=False)
        from repro.core.similarity import wavefront_dtype_from_env

        assert resolve_wavefront_dtype(None) == wavefront_dtype_from_env()

    def test_tuned_dtype_wins_when_unpinned(self, monkeypatch):
        monkeypatch.delenv("REPRO_LCS_DTYPE", raising=False)
        assert resolve_wavefront_dtype(LCSTuning(128, "int32")) == jnp.int32
        assert resolve_wavefront_dtype(LCSTuning(128, "int8")) == jnp.int8

    def test_env_pin_outranks_tuned(self, monkeypatch):
        # the reproducibility knob beats the performance knob
        monkeypatch.setenv("REPRO_LCS_DTYPE", "int32")
        assert resolve_wavefront_dtype(LCSTuning(128, "int8")) == jnp.int32
        monkeypatch.setenv("REPRO_LCS_DTYPE", "int8")
        assert resolve_wavefront_dtype(LCSTuning(128, "int32")) == jnp.int8


class TestPlannerPlumbing:
    def test_autotune_off_returns_none(self, tmp_path, monkeypatch):
        from repro.api import CapacityPlanner

        # even with a live table on disk: plans must not probe it unasked
        monkeypatch.setenv("REPRO_TUNING_PATH", str(tmp_path / "T.json"))
        _table_with({(4096, 3, 32): LCSTuning(256, "int8")}).save()
        assert CapacityPlanner().plan_tuning(4096, 3, 32) is None

    def test_autotune_on_reads_table(self, tmp_path, monkeypatch):
        from repro.api import CapacityPlanner

        monkeypatch.setenv("REPRO_TUNING_PATH", str(tmp_path / "T.json"))
        _table_with({(4096, 3, 32): LCSTuning(256, "int8")}).save()
        planner = CapacityPlanner(autotune=True)
        assert planner.plan_tuning(4096, 3, 32) == LCSTuning(256, "int8")
        assert planner.plan_tuning(4096, 9, 32) is None  # miss -> defaults

    def test_execution_plan_flags(self):
        from repro.api import ExecutionPlan

        assert ExecutionPlan().autotune is False
        assert ExecutionPlan().overlap_chunks == 1
        ExecutionPlan(overlap_chunks=4)     # pow2 accepted
        with pytest.raises(ValueError, match="power of two"):
            ExecutionPlan(overlap_chunks=3)
        with pytest.raises(ValueError, match="power of two"):
            ExecutionPlan(overlap_chunks=0)


class TestTunedDispatchParity:
    def test_tuned_lcs_bit_identical(self):
        """A tuned (block_b, dtype) through ops.lcs matches the default."""
        import numpy as np

        from repro.kernels.lcs import ops as lcs_ops

        rng = np.random.default_rng(0)
        B, L = 300, 12
        a = rng.integers(0, 6, size=(B, L)).astype(np.int32)
        b = rng.integers(0, 6, size=(B, L)).astype(np.int32)
        base = np.asarray(lcs_ops.lcs(jnp.asarray(a), jnp.asarray(b)))
        for t in (LCSTuning(128, "int8"), LCSTuning(256, "int32")):
            got = np.asarray(lcs_ops.lcs(
                jnp.asarray(a), jnp.asarray(b), block_b=t.block_b,
                wavefront_dtype=resolve_wavefront_dtype(t),
            ))
            np.testing.assert_array_equal(got, base)
