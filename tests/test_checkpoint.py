"""Checkpointing: atomic roundtrip, corruption tolerance, async writer,
resume determinism, elastic resharding onto a different device count."""
import json
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.train.checkpoint import (
    AsyncCheckpointer, latest_step, restore_checkpoint, save_checkpoint,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32)),
            "blocks": {"a": jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))},
        },
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    out = restore_checkpoint(tmp_path, 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_manifest_ignored(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 5, tree)
    # simulate a crash mid-write at step 9: files but no manifest
    broken = tmp_path / "step_0000000009"
    (broken / "arrays").mkdir(parents=True)
    np.save(broken / "arrays" / "params.w.npy", np.zeros((8, 16)))
    assert latest_step(tmp_path) == 5  # the torn checkpoint is invisible


def test_orphan_tmp_garbage_collected(tmp_path):
    tree = _tree()
    orphan = tmp_path / ".tmp_step_0000000001_123"
    orphan.mkdir(parents=True)
    save_checkpoint(tmp_path, 2, tree)
    assert not orphan.exists()


def test_keep_last_k(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, tree, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_0000000004", "step_0000000005"]


def test_shape_mismatch_rejected(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 1, tree)
    bad = {
        "params": {"w": jnp.zeros((9, 16)), "blocks": {"a": jnp.zeros((4, 8))}},
        "opt": {"step": jnp.asarray(0, jnp.int32)},
    }
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(tmp_path, 1, bad)


def test_async_checkpointer(tmp_path):
    tree = _tree()
    ck = AsyncCheckpointer(tmp_path)
    ck.save(3, tree)
    ck.wait()
    assert latest_step(tmp_path) == 3
    out = restore_checkpoint(tmp_path, 3, tree)
    np.testing.assert_array_equal(
        np.asarray(out["params"]["w"]), np.asarray(tree["params"]["w"])
    )


def test_resume_determinism(tmp_path, smoke_mesh):
    """train(10) == train(5) -> checkpoint -> resume -> train(5)."""
    from repro.configs.base import ModelConfig, ShapeConfig
    from repro.launch.inputs import make_inputs
    from repro.models.model import init_params
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import TrainConfig, make_train_state, make_train_step
    from repro.data.tokens import TokenDataset, synthetic_corpus

    cfg = ModelConfig(
        name="t", family="dense", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128, attn="gqa",
    )
    corpus, _ = synthetic_corpus(64, 33, cfg.vocab_size, seed=0)
    ds = TokenDataset(corpus, global_batch=4, seed=0)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3))

    def run(n_steps, start_params, start_state, start=0):
        step = jax.jit(make_train_step(cfg, tcfg, smoke_mesh))
        p, s = start_params, start_state
        for i in range(start, n_steps):
            p, s, _ = step(p, s, ds.batch(i))
        return p, s

    p0 = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    s0 = make_train_state(p0, tcfg)
    pa, _ = run(10, p0, s0)

    pb, sb = run(5, p0, s0)
    save_checkpoint(tmp_path, 5, {"params": pb, "state": sb})
    rest = restore_checkpoint(tmp_path, 5, {"params": pb, "state": sb})
    pc, _ = run(10, rest["params"], rest["state"], start=5)

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


ELASTIC_CODE = r"""
import jax, jax.numpy as jnp, numpy as np, sys
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.train.checkpoint import save_checkpoint, restore_checkpoint, latest_step

ckpt_dir = sys.argv[1] if len(sys.argv) > 1 else "%CKPT%"
n = len(jax.devices())
from repro.core import compat
mesh = compat.make_mesh((n,), ("data",))
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
step = latest_step(ckpt_dir)
if step is None:
    # phase 1 (8 devices): shard, save
    sh = NamedSharding(mesh, P("data", None))
    tree = {"w": jax.device_put(tree["w"], sh)}
    save_checkpoint(ckpt_dir, 1, tree)
    print("SAVED", n)
else:
    # phase 2 (different device count): restore + reshard
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = restore_checkpoint(ckpt_dir, step, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(64, dtype=np.float32).reshape(8, 8))
    assert out["w"].sharding.num_devices == n
    print("RESTORED", n)
"""


def test_elastic_reshard_across_device_counts(tmp_path):
    code = ELASTIC_CODE.replace("%CKPT%", str(tmp_path))
    out1 = run_subprocess(code, devices=8)
    assert "SAVED 8" in out1
    out2 = run_subprocess(code, devices=2)   # simulate losing 6 hosts
    assert "RESTORED 2" in out2
