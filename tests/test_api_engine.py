"""The redesigned public API (repro.api): engine/backends/plan contracts.

* AnotherMeEngine output (similar_pairs, communities) is identical to the
  legacy run_anotherme for every registered backend (single device).
* ExecutionPlan(n_shards>1) is identical to n_shards=1 and to the legacy
  shard_map path, for all four backends, on the Fig. 1 example world
  (subprocess: device count binds at jax init).
* The backend registry rejects unknown names with the list of valid keys.
* lcs_impl="ref" really runs (and unknown impl names raise).
* Candidate timing is reported as t_candidates in both branches.
"""
import numpy as np
import pytest

from conftest import run_subprocess
from repro.api import (
    AnotherMeEngine, EngineConfig, ExecutionPlan, available_backends,
    get_backend,
)
from repro.core import (
    AnotherMeConfig, brp_candidates, minhash_candidates, run_anotherme,
    type_codes, udf_pipeline,
)
from repro.data import fig1_world, synthetic_setup

BACKENDS = ("ssh", "minhash", "brp", "udf")


@pytest.fixture(scope="module")
def world():
    return synthetic_setup(
        150, num_types=10, classes_per_type=5, num_places=200, seed=7
    )


def legacy_result(batch, forest, backend, config=AnotherMeConfig()):
    """The pre-redesign equivalent of each registry backend."""
    if backend in ("ssh", "udf"):  # udf: same logic as ssh, black box
        return run_anotherme(batch, forest, config)
    if backend == "minhash":
        fn = lambda e, b: minhash_candidates(
            type_codes(e), b.lengths, num_perm=16, bands=4,
            pair_capacity=1 << 18,
        )
    else:
        fn = lambda e, b: brp_candidates(
            type_codes(e), b.lengths, num_types=forest.num_types,
            pair_capacity=1 << 18,
        )
    return run_anotherme(batch, forest, config, candidate_fn=fn)


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_matches_legacy_per_backend(world, backend):
    batch, forest = world
    res = AnotherMeEngine(forest, EngineConfig(backend=backend)).run(batch)
    ref = legacy_result(batch, forest, backend)
    assert res.similar_pairs == ref.similar_pairs
    assert res.communities == ref.communities


def test_udf_backend_matches_udf_pipeline(world):
    batch, forest = world
    res = AnotherMeEngine(forest, EngineConfig(backend="udf")).run(batch)
    similar_udf, _ = udf_pipeline(
        np.asarray(batch.places), np.asarray(batch.lengths), forest
    )
    assert res.similar_pairs == similar_udf


def test_engine_fig1_all_backends():
    """Fig. 1: every backend runs by registry name on the worked example;
    SSH/UDF (lossless) must pair Carol with Dave."""
    batch, forest = fig1_world()
    cfg_rho = 3.0
    for backend in BACKENDS:
        res = AnotherMeEngine(
            forest, EngineConfig(backend=backend, rho=cfg_rho)
        ).run(batch)
        ref = legacy_result(batch, forest, backend, AnotherMeConfig(rho=cfg_rho))
        assert res.similar_pairs == ref.similar_pairs, backend
        assert res.communities == ref.communities, backend
    ssh = AnotherMeEngine(forest, EngineConfig(rho=cfg_rho)).run(batch)
    assert (0, 1) in ssh.similar_pairs


def test_registry_unknown_backend_lists_valid_keys():
    with pytest.raises(ValueError) as ei:
        get_backend("no-such-hash")
    msg = str(ei.value)
    assert "no-such-hash" in msg
    for name in BACKENDS:
        assert name in msg


def test_registry_lists_all_four():
    assert set(BACKENDS) <= set(available_backends())


def test_backend_options_forwarded(world):
    batch, forest = world
    res16 = AnotherMeEngine(
        forest, EngineConfig(backend="minhash",
                             backend_options={"num_perm": 16, "bands": 4})
    ).run(batch)
    res4 = AnotherMeEngine(
        forest, EngineConfig(backend="minhash",
                             backend_options={"num_perm": 4, "bands": 2})
    ).run(batch)
    ref = legacy_result(batch, forest, "minhash")
    assert res16.similar_pairs == ref.similar_pairs
    # different banding => different candidate set (sanity that options bite)
    assert res4.stats["num_candidates"] != res16.stats["num_candidates"]


def test_lcs_impl_ref_runs_and_matches(world):
    batch, forest = world
    wave = AnotherMeEngine(forest, EngineConfig(lcs_impl="wavefront")).run(batch)
    ref = AnotherMeEngine(forest, EngineConfig(lcs_impl="ref")).run(batch)
    assert ref.similar_pairs == wave.similar_pairs
    legacy = run_anotherme(batch, forest, AnotherMeConfig(lcs_impl="ref"))
    assert legacy.similar_pairs == wave.similar_pairs


def test_lcs_impl_unknown_raises(world):
    batch, forest = world
    with pytest.raises(ValueError, match="wavefront"):
        AnotherMeEngine(forest, EngineConfig(lcs_impl="diagonal"))
    with pytest.raises(ValueError, match="lcs_impl"):
        run_anotherme(batch, forest, AnotherMeConfig(lcs_impl="diagonal"))


def test_candidate_timing_reported_in_both_branches(world):
    batch, forest = world
    direct = run_anotherme(batch, forest, AnotherMeConfig())
    baseline = legacy_result(batch, forest, "minhash")
    for res in (direct, baseline):
        assert res.stats["t_candidates"] > 0.0
        assert res.stats["t_candidates"] == pytest.approx(
            res.stats["t_keys"] + res.stats["t_join"]
        )
    # the baseline's hash cost must NOT be booked under the shingle phase
    # (a key-less backend leaves only context-manager noise there)
    assert baseline.stats["t_shingle"] < baseline.stats["t_join"]


SHARDED_CODE = r"""
import jax
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.data import fig1_world, synthetic_setup

assert len(jax.devices()) == 8

# Fig. 1 example world: all four backends, sharded == single-device
batch, forest = fig1_world()
for backend in ("ssh", "minhash", "brp", "udf"):
    cfg = EngineConfig(backend=backend, rho=3.0)
    single = AnotherMeEngine(forest, cfg).run(batch)
    sharded = AnotherMeEngine(forest, cfg, ExecutionPlan(n_shards=8)).run(batch)
    assert sharded.similar_pairs == single.similar_pairs, backend
    assert sharded.communities == single.communities, backend
ssh = AnotherMeEngine(forest, EngineConfig(rho=3.0),
                      ExecutionPlan(n_shards=8)).run(batch)
assert (0, 1) in ssh.similar_pairs

# a denser world: ssh + minhash, sharded == single == legacy shard_map
import numpy as np, jax.numpy as jnp
from repro.core import compat, default_betas, encode_types, forest_tables
from repro.core.distributed import (
    gather_similar_pairs, make_distributed_anotherme, pad_to_shards,
    plan_capacities)
from repro.core.shingling import shingles_from_types
from repro.core.types import TrajectoryBatch

batch, forest = synthetic_setup(120, num_types=10, classes_per_type=5,
                                num_places=150, seed=3)
for backend in ("ssh", "minhash"):
    cfg = EngineConfig(backend=backend)
    single = AnotherMeEngine(forest, cfg).run(batch)
    sharded = AnotherMeEngine(forest, cfg, ExecutionPlan(n_shards=8)).run(batch)
    assert sharded.similar_pairs == single.similar_pairs, backend
    assert sharded.communities == single.communities, backend

places, lengths = pad_to_shards(
    np.asarray(batch.places), np.asarray(batch.lengths), 8)
bp = TrajectoryBatch(jnp.asarray(places), jnp.asarray(lengths),
                     jnp.arange(places.shape[0]))
tables = forest_tables(forest)
keys_np = np.asarray(shingles_from_types(
    encode_types(bp.places, tables), bp.lengths, k=3,
    num_types=forest.num_types))
mesh = compat.make_mesh((8,), ("ex",))
legacy = make_distributed_anotherme(
    mesh, plan_capacities(keys_np, 8), tables=tables, k=3,
    num_types=forest.num_types, betas=default_betas(3))
out = legacy(bp.places, bp.lengths)
ssh_single = AnotherMeEngine(forest, EngineConfig()).run(batch)
assert gather_similar_pairs(out, rho=2.0) == ssh_single.similar_pairs
print("OK")
"""


def test_sharded_engine_parity():
    out = run_subprocess(SHARDED_CODE, devices=8)
    assert "OK" in out


def test_callable_backend_rejects_sharded_plan(world):
    from repro.api import CallableBackend

    batch, forest = world
    fn = lambda e, b: minhash_candidates(
        type_codes(e), b.lengths, num_perm=16, bands=4, pair_capacity=1 << 18
    )
    with pytest.raises(ValueError, match="n_shards=1"):
        AnotherMeEngine(
            forest, EngineConfig(), ExecutionPlan(n_shards=2),
            backend=CallableBackend(fn),
        )
