"""Per-arch smoke tests (reduced configs): one fwd/train step on CPU,
output shapes + no NaNs; decode consistency; published param counts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config, SHAPES, shape_applicable
from repro.configs.base import ShapeConfig
from repro.launch.inputs import make_inputs
from repro.models.model import (
    forward, init_params, loss_fn, param_count, active_param_count,
    padded_vocab,
)

SMOKE = ShapeConfig("smoke", 64, 2, "train")
KEY = jax.random.PRNGKey(0)

# published totals (active for MoE), rounded; our configs must land close
EXPECTED_PARAMS = {
    "mamba2-1.3b": (1.45e9, 0.25),
    "kimi-k2-1t-a32b": (1.04e12, 0.10),
    "deepseek-v2-236b": (236e9, 0.10),
    "zamba2-2.7b": (2.4e9, 0.25),
    "granite-3-8b": (8.4e9, 0.15),
    "mistral-nemo-12b": (12.2e9, 0.10),
    "minicpm3-4b": (4.3e9, 0.15),
    "qwen1.5-110b": (111e9, 0.10),
    "hubert-xlarge": (1.26e9, 0.35),
    "internvl2-76b": (70e9, 0.15),
}


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_train_step(arch, smoke_mesh):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY, dtype=jnp.float32)
    inputs = make_inputs(cfg, SMOKE)
    logits, aux = forward(params, inputs, cfg, smoke_mesh)
    S = SMOKE.seq_len if cfg.frontend != "vision" else SMOKE.seq_len
    exp_s = inputs.get("tokens", inputs.get("features")).shape[1]
    if cfg.frontend == "vision":
        exp_s += cfg.vis_tokens
    assert logits.shape == (SMOKE.global_batch, exp_s, padded_vocab(cfg))
    assert bool(jnp.isfinite(logits).all())
    loss, metrics = jax.jit(lambda p, i: loss_fn(p, i, cfg, smoke_mesh))(params, inputs)
    assert np.isfinite(float(loss))
    # CE at init should be ~ln(V)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab_size)) < 1.0


@pytest.mark.parametrize("arch", all_archs())
def test_param_count_matches_published(arch):
    cfg = get_config(arch)
    want, tol = EXPECTED_PARAMS[arch]
    got = param_count(cfg)
    assert abs(got - want) / want < tol, f"{got/1e9:.2f}B vs {want/1e9:.2f}B"


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    active = active_param_count(cfg)
    assert 25e9 < active < 45e9  # "a32b"
    cfg2 = get_config("deepseek-v2-236b")
    assert 15e9 < active_param_count(cfg2) < 30e9  # 21B active


@pytest.mark.parametrize("arch", ["granite-3-8b", "minicpm3-4b", "mamba2-1.3b", "zamba2-2.7b", "kimi-k2-1t-a32b"])
def test_decode_matches_forward(arch, smoke_mesh):
    from repro.serve.serve_step import make_decode_step, prefill_with_cache

    cfg = get_config(arch).reduced()
    if cfg.frontend == "vision":
        cfg = dataclasses.replace(cfg, frontend="none")
    params = init_params(cfg, KEY, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    S, B, MAX = 12, 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    logits_full, _ = forward(params, {"tokens": tokens}, cfg, smoke_mesh)
    lp, cache = prefill_with_cache(params, tokens[:, : S - 2], cfg, smoke_mesh, MAX)
    dstep = jax.jit(make_decode_step(cfg, smoke_mesh))
    errs = [float(jnp.max(jnp.abs(
        lp[:, -1, : cfg.vocab_size] - logits_full[:, S - 3, : cfg.vocab_size])))]
    c = cache
    for t in (S - 2, S - 1):
        ld, c = dstep(params, c, tokens[:, t : t + 1])
        errs.append(float(jnp.max(jnp.abs(
            ld[:, 0, : cfg.vocab_size] - logits_full[:, t, : cfg.vocab_size]))))
    assert max(errs) < 5e-2, errs  # bf16 compute tolerance


def test_shape_skip_rules():
    rules = {
        (a, s): shape_applicable(get_config(a), SHAPES[s])[0]
        for a in all_archs() for s in SHAPES
    }
    assert not rules[("hubert-xlarge", "decode_32k")]
    assert not rules[("hubert-xlarge", "long_500k")]
    assert not rules[("qwen1.5-110b", "long_500k")]
    assert rules[("mamba2-1.3b", "long_500k")]
    assert rules[("zamba2-2.7b", "long_500k")]
    runnable = sum(rules.values())
    assert runnable == 31  # documented in DESIGN.md


def test_unroll_matches_scan(smoke_mesh):
    cfg = get_config("granite-3-8b").reduced()
    params = init_params(cfg, KEY, dtype=jnp.float32)
    inputs = make_inputs(cfg, SMOKE)
    l1, _ = forward(params, inputs, cfg, smoke_mesh)
    l2, _ = forward(params, inputs, cfg, smoke_mesh, unroll=True)
    assert float(jnp.max(jnp.abs(l1 - l2))) < 2e-2  # bf16 fusion-order noise
