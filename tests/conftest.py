import os
import subprocess
import sys

import pytest

# Tests run on the default single CPU device; multi-device tests spawn
# subprocesses with XLA_FLAGS (dryrun.py is the only in-process user of
# forced host device counts, and it is never imported here).
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 900):
    """Run a python snippet under a forced host-device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture
def smoke_mesh():
    from repro.core import compat

    return compat.make_mesh((1, 1), ("data", "model"))
