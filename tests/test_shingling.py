import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.shingling import (
    expected_collision_rate, num_shingles, pack_keys, shingle_indices,
    shingles_from_types,
)
from repro.core.types import PAD_KEY


def brute_force_shingles(types, k, Q):
    """Oracle: distinct order-preserving k-subsequences, base-Q packed."""
    out = set()
    for combo in itertools.combinations(types, k):
        key = 0
        for c in combo:
            key = key * Q + c
        out.add(key)
    return out


@pytest.mark.parametrize("k,Q,L", [(3, 30, 10), (3, 300, 8), (2, 10, 6), (4, 30, 9)])
def test_shingles_match_bruteforce(k, Q, L):
    rng = np.random.default_rng(0)
    n = 50
    lengths = rng.integers(k, L + 1, size=n).astype(np.int32)
    types = rng.integers(0, Q, size=(n, L)).astype(np.int32)
    keys = np.asarray(
        shingles_from_types(jnp.asarray(types), jnp.asarray(lengths), k=k, num_types=Q)
    )
    for i in range(n):
        got = set(keys[i][keys[i] != PAD_KEY].tolist())
        want = brute_force_shingles(types[i, : lengths[i]].tolist(), k, Q)
        assert got == want


def test_shingle_count_is_binomial():
    from math import comb

    assert num_shingles(10, 3) == comb(10, 3)
    assert shingle_indices(10, 3).shape == (comb(10, 3), 3)
    # indices strictly increasing
    idx = shingle_indices(10, 3)
    assert (np.diff(idx, axis=1) > 0).all()


def test_pack_keys_bijective():
    Q, k = 30, 3
    codes = np.stack(
        np.meshgrid(*[np.arange(Q)] * k, indexing="ij"), axis=-1
    ).reshape(-1, k)[:5000]
    keys = np.asarray(pack_keys(jnp.asarray(codes), Q))
    assert len(set(keys.tolist())) == len(keys)  # perfect hash


def test_pack_overflow_guard():
    with pytest.raises(ValueError):
        pack_keys(jnp.zeros((1, 4), jnp.int32), 2000)  # 2000^4 > 2^31


def test_collision_rate_model():
    """Paper section IV.2: collision rate ~ C(L,k)/Q^k; empirically the
    fraction of populated buckets tracks the model's order of magnitude."""
    from math import comb

    rate = expected_collision_rate(8, 3, 30)
    assert rate == comb(8, 3) / 30**3
    rng = np.random.default_rng(1)
    n, L, Q = 2000, 8, 30
    types = rng.integers(0, Q, size=(n, L)).astype(np.int32)
    lengths = np.full(n, L, np.int32)
    keys = np.asarray(
        shingles_from_types(jnp.asarray(types), jnp.asarray(lengths), k=3, num_types=Q)
    )
    valid = keys[keys != PAD_KEY]
    distinct_frac = len(np.unique(valid)) / Q**3
    # every trajectory contributes ~C(L,3)/Q^3 of the key space
    assert 0.1 * rate * n > 0 and distinct_frac < min(1.0, rate * n)
