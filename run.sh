#!/usr/bin/env bash
# Reproducible perf environment for benchmarks and CI (ISSUE 9).
#
# Every recorded number (BENCH_score.json, roofline tuning sweeps) and the
# perf-regression CI step run under THIS wrapper so two runs differ only in
# the code, never in the allocator, XLA runtime knobs, device layout or the
# LCS diagonal dtype:
#
#   tcmalloc         LD_PRELOADed when present — the glibc allocator's
#                    page-level churn adds multi-percent noise to the
#                    gather-heavy score stage.  Gated on file existence:
#                    absent (as in the slim CI image) the run proceeds
#                    on glibc, it is never an error.
#   XLA_FLAGS        on CPU, fake an 8-device host platform so the
#                    shard_map paths (sharded parity tests, the overlap
#                    benchmark section) exercise real collectives.
#                    An inherited XLA_FLAGS wins — real accelerators
#                    must not be forced onto the host platform.
#   REPRO_LCS_DTYPE  pinned (default int8) so the wavefront's diagonal
#                    carry dtype is an explicit, recorded choice rather
#                    than the env-probe default.  Inherited values win.
#
# Usage:  ./run.sh <python args...>        e.g.
#         ./run.sh -m benchmarks.bench_score --smoke
#         ./run.sh -m benchmarks.roofline --tune --smoke
#         ./run.sh -m pytest -x -q
set -euo pipefail

cd "$(dirname "$0")"

for so in /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
          /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
          /usr/lib/libtcmalloc_minimal.so.4; do
    if [ -e "$so" ]; then
        export LD_PRELOAD="$so${LD_PRELOAD:+:$LD_PRELOAD}"
        # keep huge-alloc spam out of benchmark stdout
        export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=10737418240
        break
    fi
done

# silence absl/XLA chatter that would interleave with benchmark output
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# fake 8 host devices unless XLA_FLAGS is already pinned or a non-CPU
# platform is selected (never force host devices onto an accelerator)
case "${JAX_PLATFORMS:-cpu}" in
    cpu|"")
        if [ -z "${XLA_FLAGS:-}" ]; then
            export XLA_FLAGS="--xla_force_host_platform_device_count=8"
        fi
        ;;
esac

export REPRO_LCS_DTYPE="${REPRO_LCS_DTYPE:-int8}"
export PYTHONPATH="$PWD/src${PYTHONPATH:+:$PYTHONPATH}"

exec python "$@"
