from repro.serve.kvcache import cache_shapes, init_cache, cache_shardings
from repro.serve.serve_step import make_decode_step, prefill_with_cache
