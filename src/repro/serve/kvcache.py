"""KV / state caches for serving, with scale-aware sharding.

Cache layouts per family (leading [L] = per-layer stacked, consumed by the
decode scan):

  gqa    : k, v          [L, B, Smax, KH, hd]   (bf16)
  mla    : c_kv          [L, B, Smax, kv_lora]  — the compressed latent;
           k_rope        [L, B, Smax, dr]         93%+ smaller than full KV
  ssm    : conv_x [L,B,W-1,din], conv_bc [L,B,W-1,2GN], ssm [L,B,H,P,N] f32
           (O(1) in context length — why long_500k is SSM-only)
  hybrid : ssm caches + shared-attn sk/sv [n_inv, B, Smax, KH, hd]

Sharding: sequence dim over 'model' (split-K / flash-decoding style: each
model-rank attends over its sequence slice; XLA's partitioner emits the
logsumexp-combine psum).  Batch over (pod, data) when divisible; for
long_500k's batch=1 the resolver drops it and KV heads shard over 'data'
instead — the rule table lives in resolve (below).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, dp_axes, resolve_spec


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """{name: (shape, dtype, axes)} — axes feed the divisibility resolver."""
    nl = cfg.num_layers
    out: dict = {"pos": ((), jnp.int32, ())}
    dp = ("pod", "data")  # resolver drops absent names

    def attn_axes(bdim):
        # batch over dp when divisible, else KV-heads over data (long_500k)
        return (None, dp, "model", None, None)

    if cfg.family in ("ssm", "hybrid"):
        din = cfg.ssm_d_inner
        H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        W, G = cfg.ssm_conv, cfg.ssm_groups
        out["conv_x"] = ((nl, batch, W - 1, din), COMPUTE_DTYPE,
                         (None, dp, None, "model"))
        out["conv_bc"] = ((nl, batch, W - 1, 2 * G * N), COMPUTE_DTYPE,
                          (None, dp, None, None))
        out["ssm"] = ((nl, batch, H, Pd, N), jnp.float32,
                      (None, dp, "model", None, None))
    if cfg.family == "hybrid":
        n_inv = cfg.num_layers // cfg.shared_attn_every
        KH, hd = cfg.num_kv_heads, cfg.head_dim
        out["sk"] = ((n_inv, batch, max_len, KH, hd), COMPUTE_DTYPE,
                     (None, dp, "model", "data" if batch == 1 else None, None))
        out["sv"] = out["sk"]
    elif cfg.attn == "mla":
        out["c_kv"] = ((nl, batch, max_len, cfg.kv_lora_rank), COMPUTE_DTYPE,
                       (None, dp, "model", None))
        out["k_rope"] = ((nl, batch, max_len, cfg.qk_rope_head_dim),
                         COMPUTE_DTYPE, (None, dp, "model", None))
    elif cfg.attn == "gqa" and cfg.family not in ("ssm",):
        KH, hd = cfg.num_kv_heads, cfg.head_dim
        out["k"] = ((nl, batch, max_len, KH, hd), COMPUTE_DTYPE,
                    attn_axes(batch))
        out["v"] = out["k"]
    return out


def cache_shape_structs(cfg, batch, max_len, mesh: Mesh | None = None) -> dict:
    shapes = cache_shapes(cfg, batch, max_len)
    out = {}
    for name, (shp, dt, axes) in shapes.items():
        if mesh is not None:
            sh = NamedSharding(mesh, resolve_spec(mesh, shp, axes))
            out[name] = jax.ShapeDtypeStruct(shp, dt, sharding=sh)
        else:
            out[name] = jax.ShapeDtypeStruct(shp, dt)
    return out


def cache_shardings(cfg, batch, max_len, mesh: Mesh) -> dict:
    shapes = cache_shapes(cfg, batch, max_len)
    return {
        name: NamedSharding(mesh, resolve_spec(mesh, shp, axes))
        for name, (shp, dt, axes) in shapes.items()
    }


def init_cache(cfg, batch, max_len, mesh: Mesh | None = None) -> dict:
    shapes = cache_shapes(cfg, batch, max_len)
    return {
        name: jnp.zeros(shp, dt) for name, (shp, dt, _) in shapes.items()
    }


def cache_bytes(cfg, batch, max_len) -> int:
    shapes = cache_shapes(cfg, batch, max_len)
    total = 0
    for name, (shp, dt, _) in shapes.items():
        total += int(jnp.dtype(dt).itemsize) * int(jnp.prod(jnp.array(shp)))
    return total
