"""Serving steps: batched single-token decode + cache-building prefill.

``make_decode_step`` builds the jitted serve_step the dry-run lowers for
decode_32k / long_500k: one new token per sequence against the cache, layers
consumed by a lax.scan over stacked (params, cache) slices.

``prefill_with_cache`` is the host-side (unrolled-layer) prefill used by the
serving example and the decode-vs-forward consistency tests — it fills the
cache from a prompt so that greedy decode continues exactly where a plain
forward pass would.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import (
    attention_block, gqa_decode, mla_decode,
)
from repro.models.mamba import mamba_block, mamba_decode_step
from repro.models.moe import moe_block
from repro.models.model import padded_vocab


def _ffn_decode(x, lp, cfg, mesh, aux):
    """Post-attention FFN for one decode token (dense or MoE)."""
    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        mo, a = moe_block(h, lp["moe"], cfg, mesh)
        return x + mo, aux + a
    return x + L.swiglu_mlp(
        h, lp["mlp"], mesh=mesh, dp=L.dp_axes(mesh) if mesh else ("data",),
    ), aux


def _scan_or_unroll(body, carry, xs, unroll: bool):
    """lax.scan over a dict of stacked xs, or the python-unrolled twin."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, sl)
        ys.append(y)
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked


def make_decode_step(cfg: ModelConfig, mesh, *, unroll: bool = False):
    """Returns decode_step(params, cache, tokens [B,1]) -> (logits, cache).

    Hybrid archs scan over GROUPS (``every`` mamba layers + the shared
    attention block); the shared block's per-invocation KV slice rides the
    scan as xs/ys, so there is no lax.cond or dynamic cache indexing."""

    def decode_step(params, cache, tokens):
        pos = cache["pos"]
        x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]  # [B,1,d]
        aux0 = jnp.zeros((), jnp.float32)

        if cfg.family in ("ssm", "hybrid"):
            shared = params.get("shared")
            every = max(cfg.shared_attn_every, 1)

            def group(a):  # [L, ...] -> [G, every, ...] for hybrid
                if cfg.family != "hybrid":
                    return a
                return a.reshape((a.shape[0] // every, every) + a.shape[1:])

            xs = {
                "blocks": jax.tree.map(group, params["blocks"]),
                "conv_x": group(cache["conv_x"]),
                "conv_bc": group(cache["conv_bc"]),
                "ssm": group(cache["ssm"]),
            }
            if cfg.family == "hybrid":
                xs["sk"] = cache["sk"]
                xs["sv"] = cache["sv"]

            def body(x, sl):
                steps = every if cfg.family == "hybrid" else 1
                new_states = {"conv_x": [], "conv_bc": [], "ssm": []}
                for j in range(steps):
                    take = (lambda a: a[j]) if cfg.family == "hybrid" else (lambda a: a)
                    lp = jax.tree.map(take, sl["blocks"])
                    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
                    state = {k: take(sl[k]) for k in ("conv_x", "conv_bc", "ssm")}
                    y, ns = mamba_decode_step(h, state, lp["mamba"], cfg)
                    x = x + y
                    for k in new_states:
                        new_states[k].append(ns[k])
                if cfg.family == "hybrid":
                    out_states = {
                        k: jnp.stack(v) for k, v in new_states.items()
                    }
                    h = L.rmsnorm(x, shared["ln1"], cfg.norm_eps)
                    o, ki, vi = gqa_decode(
                        h, shared["attn"], cfg, sl["sk"], sl["sv"], pos
                    )
                    x = x + o
                    h = L.rmsnorm(x, shared["ln2"], cfg.norm_eps)
                    x = x + L.swiglu_mlp(
                        h, shared["mlp"], mesh=mesh,
                        dp=L.dp_axes(mesh) if mesh else ("data",),
                    )
                    out_states["sk"] = ki
                    out_states["sv"] = vi
                else:
                    out_states = {k: v[0] for k, v in new_states.items()}
                return x, out_states

            x, new_states = _scan_or_unroll(body, x, xs, unroll)

            def ungroup(a):
                if cfg.family != "hybrid":
                    return a
                return a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:])

            cache = {**cache}
            for k in ("conv_x", "conv_bc", "ssm"):
                cache[k] = ungroup(new_states[k])
            if cfg.family == "hybrid":
                cache["sk"] = new_states["sk"]
                cache["sv"] = new_states["sv"]
        else:
            if cfg.attn == "mla":
                xs = {
                    "blocks": params["blocks"],
                    "c_kv": cache["c_kv"], "k_rope": cache["k_rope"],
                }

                def body(carry, sl):
                    x, aux = carry
                    lp = sl["blocks"]
                    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
                    o, ck, kr = mla_decode(
                        h, lp["attn"], cfg, sl["c_kv"], sl["k_rope"], pos
                    )
                    x, aux = _ffn_decode(x + o, lp, cfg, mesh, aux)
                    return (x, aux), {"c_kv": ck, "k_rope": kr}

                (x, _), new_kv = _scan_or_unroll(body, (x, aux0), xs, unroll)
                cache = {**cache, **new_kv}
            else:
                xs = {"blocks": params["blocks"], "k": cache["k"], "v": cache["v"]}

                def body(carry, sl):
                    x, aux = carry
                    lp = sl["blocks"]
                    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
                    o, k, v = gqa_decode(h, lp["attn"], cfg, sl["k"], sl["v"], pos)
                    x, aux = _ffn_decode(x + o, lp, cfg, mesh, aux)
                    return (x, aux), {"k": k, "v": v}

                (x, _), new_kv = _scan_or_unroll(body, (x, aux0), xs, unroll)
                cache = {**cache, **new_kv}

        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum(
            "bsd,dv->bsv", x, params["lm_head"].astype(x.dtype)
        ).astype(jnp.float32)
        vp = padded_vocab(cfg)
        if vp != cfg.vocab_size:
            logits = jnp.where(
                (jnp.arange(vp) < cfg.vocab_size)[None, None, :], logits, -1e30
            )
        cache = {**cache, "pos": pos + 1}
        return logits, cache

    return decode_step


# ---------------------------------------------------------------------------
# cache-building prefill (unrolled layers; small-scale serving + tests)
# ---------------------------------------------------------------------------
def prefill_with_cache(params, tokens, cfg: ModelConfig, mesh, max_len: int):
    """Run the prompt through the model, returning (last-token logits, cache
    positioned at prompt length).  Python-unrolled layers so per-layer KV can
    be captured without restructuring the scan."""
    from repro.serve.kvcache import init_cache
    from repro.models.attention import mla_attention, gqa_attention
    from repro.models.layers import rmsnorm

    B, S = tokens.shape
    x = params["embed"].astype(L.COMPUTE_DTYPE)[tokens]
    positions = jnp.arange(S, dtype=jnp.int32)
    cache = init_cache(cfg, B, max_len, mesh)
    nl = cfg.num_layers
    shared = params.get("shared")
    every = cfg.shared_attn_every

    for i in range(nl):
        lp = jax.tree.map(lambda a: a[i], params["blocks"])
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        if cfg.family in ("ssm", "hybrid"):
            from repro.models.mamba import mamba_prefill
            y, st = mamba_prefill(h, lp["mamba"], cfg, mesh)
            x = x + y
            for k in ("conv_x", "conv_bc", "ssm"):
                cache[k] = cache[k].at[i].set(st[k])
            if cfg.family == "hybrid" and (i % every) == (every - 1):
                inv = i // every
                h = rmsnorm(x, shared["ln1"], cfg.norm_eps)
                o, kf, vf = _attn_with_kv(h, shared["attn"], cfg, mesh, positions)
                x = x + o
                cache["sk"] = jax.lax.dynamic_update_slice(
                    cache["sk"], kf[None], (inv, 0, 0, 0, 0))
                cache["sv"] = jax.lax.dynamic_update_slice(
                    cache["sv"], vf[None], (inv, 0, 0, 0, 0))
                h = rmsnorm(x, shared["ln2"], cfg.norm_eps)
                x = x + L.swiglu_mlp(
                    h, shared["mlp"], mesh=mesh,
                    dp=L.dp_axes(mesh) if mesh else ("data",))
        elif cfg.attn == "mla":
            o, ck, kr = _mla_with_kv(h, lp["attn"], cfg, mesh, positions)
            x = x + o
            cache["c_kv"] = jax.lax.dynamic_update_slice(
                cache["c_kv"], ck[None], (i, 0, 0, 0))
            cache["k_rope"] = jax.lax.dynamic_update_slice(
                cache["k_rope"], kr[None], (i, 0, 0, 0))
            x, _ = _ffn_decode(x, lp, cfg, mesh, jnp.zeros((), jnp.float32))
        else:
            o, kf, vf = _attn_with_kv(h, lp["attn"], cfg, mesh, positions)
            x = x + o
            cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], kf[None], (i, 0, 0, 0, 0))
            cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], vf[None], (i, 0, 0, 0, 0))
            x, _ = _ffn_decode(x, lp, cfg, mesh, jnp.zeros((), jnp.float32))

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", x[:, -1:], params["lm_head"].astype(x.dtype)
    ).astype(jnp.float32)
    vp = padded_vocab(cfg)
    if vp != cfg.vocab_size:
        logits = jnp.where(
            (jnp.arange(vp) < cfg.vocab_size)[None, None, :], logits, -1e30
        )
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return logits, cache


def _attn_with_kv(x, p, cfg, mesh, positions):
    """GQA attention that also returns padded (k, v) for the cache."""
    from repro.models.layers import rope, chunked_attention
    from repro.models.attention import _qkv_proj

    B, S, _ = x.shape
    H, KH, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _qkv_proj(x, p, cfg)
    q = rope(q.reshape(B, S, H, D), positions, cfg.rope_theta)
    k = rope(k.reshape(B, S, KH, D), positions, cfg.rope_theta)
    v = v.reshape(B, S, KH, D)
    o = chunked_attention(q, k, v, causal=cfg.causal)
    o = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * D), p["wo"].astype(x.dtype))
    return o, k, v


def _mla_with_kv(x, p, cfg, mesh, positions):
    """MLA attention returning (out, c_kv, k_rope) for the latent cache."""
    from repro.models.layers import rope, chunked_attention, rmsnorm

    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    if cfg.q_lora_rank:
        cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype)),
                     p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rh->bsh", cq, p["wq_b"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    c_kv = rmsnorm(ckv[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = rope(ckv[..., r:][:, :, None, :], positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,rh->bsh", c_kv, p["wk_b"].astype(x.dtype)).reshape(B, S, H, dn)
    v = jnp.einsum("bsr,rh->bsh", c_kv, p["wv_b"].astype(x.dtype)).reshape(B, S, H, dv)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
    if dv < dn + dr:
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
    else:
        v_pad = v
    o = chunked_attention(q_full, k_full, v_pad, causal=cfg.causal)[..., :dv]
    o = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * dv), p["wo"].astype(x.dtype))
    return o, c_kv, k_rope[:, :, 0, :]
