"""Candidate-generation backends behind a string-keyed registry.

Phase (ii) of the paper's pipeline — "which trajectory pairs are worth
scoring?" — is the only phase the paper varies across its five approaches.
Each variant is a :class:`CandidateBackend`; benchmarks and the engine
select one purely by registry name:

  "ssh"      k-sequential-shingle hashing (the paper's AnotherMe join;
             lossless, hence the 100% QA1/QA2 rows of Figs. 10/12)
  "minhash"  MinHashLSH over the type presence *set* (Spark's built-in;
             discards order and repetition — loses accuracy)
  "brp"      Bucketed Random Projection of the type *count* vector
             (discards order entirely — worst accuracy)
  "udf"      the paper's "user-defined" black box: the same shingle logic
             as "ssh" but computed row-at-a-time in host Python, opaque
             to XLA (the systems baseline of Fig. 7)

Every backend reduces to PAD_KEY-padded int32 join keys ``[N, S]`` — pairs
sharing any key become candidates via the same sort-merge join — so one
capacity planner and one sharded shuffle serve all of them.  Backends that
cannot express themselves as keys (e.g. legacy ``candidate_fn`` callables)
override :meth:`CandidateBackend.candidates` wholesale.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.brp import brp_bucket_keys
from repro.core.encoding import type_codes
from repro.core.minhash import minhash_band_keys, minhash_signatures
from repro.core.ssh import exact_pair_count, ssh_candidates
from repro.core.types import CandidatePairs, EncodedBatch, PAD_KEY, TrajectoryBatch


@dataclasses.dataclass(frozen=True)
class BackendContext:
    """Static pipeline facts a backend may need (from config + forest).

    ``window``/``stride`` carry the subtrajectory mode
    (``EngineConfig(subtraj_window=W, subtraj_stride=s)``): when ``window``
    is set, every backend keys the SLIDING WINDOWS of each trajectory
    instead of the whole row — key row ``t * nw + j`` holds window j of
    trajectory t (see :mod:`repro.core.subtraj`), so the join emits
    candidate pairs in (traj, offset) window coordinates.
    """

    k: int
    num_types: int
    window: int | None = None
    stride: int = 1


def _windowed_view(types, lengths, ctx: BackendContext):
    """The key-input view: windows-as-virtual-rows when subtraj is on.

    [N, L] type codes -> [N*nw, W] window rows + [N*nw] window lengths
    (identity when ``ctx.window`` is None), shared by every registered
    backend so the windowed key layout cannot drift between them.
    """
    if ctx.window is None:
        return types, lengths
    from repro.core.shingling import windowed_types

    return windowed_types(types, lengths, window=ctx.window, stride=ctx.stride)


class CandidateBackend:
    """Protocol/base for candidate generation.

    Subclasses implement :meth:`join_keys` (preferred: enables the shared
    join, capacity planner, and sharded execution) or override
    :meth:`candidates` directly.  :meth:`shard_key_fn` optionally returns a
    jax-traceable per-shard key function so keys are built on-device inside
    ``shard_map``; returning None makes the engine build keys host-side and
    shuffle them in as a sharded input.
    """

    name: str = "?"
    # key-producing backends run under shard_map (on-device key_fn or
    # host keys shuffled in); key-less ones are single-device only
    supports_sharded: bool = True

    def join_keys(
        self, encoded: EncodedBatch, batch: TrajectoryBatch, ctx: BackendContext
    ) -> jnp.ndarray:
        """PAD_KEY-padded int32 join keys [N, S].

        Sharded note: for capacity planning the engine calls this with a
        *coarsest-level view* — ``encoded.codes`` is [N, 1, L] holding only
        the type codes (the full table stays device-resident).  Keys must
        therefore derive from ``type_codes(encoded)`` + lengths, which is
        what every registered backend does; the on-device ``shard_key_fn``
        then rebuilds the identical keys from the in-mesh encodings.
        """
        raise NotImplementedError

    def expected_pairs(self, keys: jnp.ndarray) -> int:
        """Exact pre-dedup join cardinality, for capacity planning."""
        return exact_pair_count(keys)

    def candidates(
        self,
        encoded: EncodedBatch,
        batch: TrajectoryBatch,
        ctx: BackendContext,
        *,
        pair_capacity: int,
    ) -> CandidatePairs:
        keys = self.join_keys(encoded, batch, ctx)
        return ssh_candidates(jnp.asarray(keys), pair_capacity=pair_capacity)

    def shard_key_fn(self, ctx: BackendContext) -> Callable | None:
        """(local_type_codes [n, L], local_lengths [n]) -> keys [n, S].

        Runs per shard inside the shard_map program; the type codes it
        consumes are encoded in-mesh from the shard's own places, so a
        key-producing backend never touches host-side encodings at all.
        """
        return None


@dataclasses.dataclass(frozen=True)
class SSHBackend(CandidateBackend):
    """The paper's Semantic Sequential Hashing join (Algorithm 2)."""

    dedup: bool = True
    name: str = dataclasses.field(default="ssh", init=False)

    def join_keys(self, encoded, batch, ctx):
        from repro.core.shingling import shingles_from_types

        types, lengths = _windowed_view(
            type_codes(encoded), encoded.lengths, ctx
        )
        return shingles_from_types(
            types, lengths,
            k=ctx.k, num_types=ctx.num_types, dedup=self.dedup,
        )

    def shard_key_fn(self, ctx):
        from repro.core.shingling import shingles_from_types

        def key_fn(local_types, local_lengths):
            types, lengths = _windowed_view(local_types, local_lengths, ctx)
            return shingles_from_types(
                types, lengths,
                k=ctx.k, num_types=ctx.num_types, dedup=self.dedup,
            )

        return key_fn


@dataclasses.dataclass(frozen=True)
class MinHashBackend(CandidateBackend):
    """MinHashLSH over type presence sets (Spark's built-in; section V.1)."""

    num_perm: int = 16
    bands: int = 4
    seed: int = 0
    name: str = dataclasses.field(default="minhash", init=False)

    def join_keys(self, encoded, batch, ctx):
        types, lengths = _windowed_view(
            type_codes(encoded), encoded.lengths, ctx
        )
        sig = minhash_signatures(
            types, lengths, num_perm=self.num_perm, seed=self.seed,
        )
        return minhash_band_keys(sig, bands=self.bands)

    def shard_key_fn(self, ctx):
        def key_fn(local_types, local_lengths):
            types, lengths = _windowed_view(local_types, local_lengths, ctx)
            sig = minhash_signatures(
                types, lengths, num_perm=self.num_perm, seed=self.seed,
            )
            return minhash_band_keys(sig, bands=self.bands)

        return key_fn


@dataclasses.dataclass(frozen=True)
class BRPBackend(CandidateBackend):
    """Bucketed Random Projection of type count vectors (section V.1)."""

    num_proj: int = 4
    bucket_length: float = 2.0
    seed: int = 0
    name: str = dataclasses.field(default="brp", init=False)

    def join_keys(self, encoded, batch, ctx):
        types, lengths = _windowed_view(
            type_codes(encoded), encoded.lengths, ctx
        )
        return brp_bucket_keys(
            types, lengths,
            num_types=ctx.num_types, num_proj=self.num_proj,
            bucket_length=self.bucket_length, seed=self.seed,
        )

    def shard_key_fn(self, ctx):
        def key_fn(local_types, local_lengths):
            types, lengths = _windowed_view(local_types, local_lengths, ctx)
            return brp_bucket_keys(
                types, lengths,
                num_types=ctx.num_types, num_proj=self.num_proj,
                bucket_length=self.bucket_length, seed=self.seed,
            )

        return key_fn


@dataclasses.dataclass(frozen=True)
class UDFBackend(CandidateBackend):
    """The "user-defined" black box: shingle keys built row-at-a-time in
    host Python (same base-Q perfect hash as "ssh", so the results are
    bit-identical), invisible to XLA.  ``shard_key_fn`` is None: in sharded
    mode the engine computes these keys on the driver (from the
    coarsest-level planning view) and shuffles them in, mirroring how a
    Spark UDF forces data through the driver-side bytecode wall the paper
    measures in Fig. 7 — encoding itself still runs in-mesh even here.
    """

    name: str = dataclasses.field(default="udf", init=False)

    def join_keys(self, encoded, batch, ctx):
        q, k = ctx.num_types, ctx.k
        if q**k >= 2**31:
            raise ValueError(
                f"Q**k = {q}**{k} overflows int32; use a smaller k or Q."
            )
        types = np.asarray(type_codes(encoded))
        lengths = np.asarray(encoded.lengths)
        if ctx.window is not None:
            # host-side windows-as-virtual-rows (the black box stays a
            # row-at-a-time loop; only its input view changes)
            from repro.core.subtraj import num_windows

            L = types.shape[1]
            W, s = min(ctx.window, L), ctx.stride
            nw = num_windows(L, ctx.window, s)
            offs = np.arange(nw, dtype=np.int32) * s
            pos = np.clip(offs[:, None] + np.arange(W), 0, L - 1)
            types = types[:, pos].reshape(-1, W)
            lengths = (lengths[:, None] - offs[None, :]).clip(0, W).reshape(-1)
        per_row: list[set[int]] = []
        for i in range(types.shape[0]):
            row = types[i, : lengths[i]].tolist()
            keys = set()
            for combo in itertools.combinations(row, k):
                key = 0
                for c in combo:
                    key = key * q + int(c)
                keys.add(key)
            per_row.append(keys)
        s = max(1, max((len(r) for r in per_row), default=1))
        out = np.full((types.shape[0], s), PAD_KEY, np.int32)
        for i, keys in enumerate(per_row):
            out[i, : len(keys)] = sorted(keys)
        return jnp.asarray(out)


class CallableBackend(CandidateBackend):
    """Adapter for legacy ``candidate_fn`` callables (deprecated escape
    hatch of ``run_anotherme``); key-less, single-device only."""

    name = "callable"
    supports_sharded = False

    def __init__(self, fn: Callable):
        self._fn = fn

    def join_keys(self, encoded, batch, ctx):
        return None

    def candidates(self, encoded, batch, ctx, *, pair_capacity):
        return self._fn(encoded, batch)


_REGISTRY: dict[str, Callable[..., CandidateBackend]] = {}


def register_backend(name: str, factory: Callable[..., CandidateBackend]):
    """Register a backend factory under ``name`` (replaces any previous)."""
    _REGISTRY[name] = factory
    return factory


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str, **options) -> CandidateBackend:
    """Instantiate a registered backend by name.

    ``options`` are forwarded to the backend factory (e.g.
    ``get_backend("minhash", num_perm=32, bands=8)``).
    """
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown candidate backend {name!r}; registered backends: "
            f"{list(available_backends())}"
        ) from None
    return factory(**options)


register_backend("ssh", SSHBackend)
register_backend("minhash", MinHashBackend)
register_backend("brp", BRPBackend)
register_backend("udf", UDFBackend)
