"""Online top-k "find another me" serving over the resident world.

    from repro.api import QueryEngine, StreamingEngine

    stream = StreamingEngine(forest, config, plan)
    for batch in feed:
        stream.update(batch)
    serve = QueryEngine(stream, k=5)
    res = serve.query(query_batch)       # QueryResult
    res.match_ids[q], res.mss[q]         # top-k world rows per query

PRs 1-5 built ingestion: a device-resident world (single-device code
table or round-robin sharded places slabs) plus an incremental join index
(host ``BucketIndex`` or the key-sharded device slabs).  This module adds
the product surface the paper's title promises — pose a trajectory
against that resident world and get the top-k most-similar users back —
as the first subsystem where LATENCY, not throughput, is the scoreboard:

* queries are NOT ingested: the index is probed through the shared
  read-only ``probe(keys)`` protocol (``BucketIndex.probe`` on the host,
  :func:`~repro.core.device_index.probe_rows` in-mesh) and the world
  state is untouched, so queries commute with ``StreamingEngine.update``
  calls and concurrent queries commute with each other;
* concurrent queries micro-batch through ONE shared compiled program
  with pow2-sticky capacities (:class:`QueryPlan`, planned by
  ``CapacityPlanner.plan_query`` from exact candidate cardinalities) —
  steady-state query traffic never recompiles, proven by the
  ``serve_traces`` / ``probe_traces`` trace-counter hooks;
* candidates score off the resident world codes through the same
  ``lcs_impl`` dispatch as ingestion (fused Pallas kernel included: the
  kernel's two-table form takes the query codes as table A and the
  resident world as table B), then reduce IN-MESH through a segmented
  per-query top-k — sort by (query, -mss, row), rank-in-run scatter to
  ``[Q, k]`` per shard, all_gather, k-way merge — so only ``[Q, k]``
  ids+scores ever transit the driver;
* results are deterministic: matches require ``mss > rho`` (per-query
  ``rho``), are ordered by (mss descending, row id ascending), and empty
  slots hold ``(PAD_ID, -1.0)``;
* with ``serve_prune=True`` a REPOSE-style per-shard pass walks world
  shards in descending resident-length order
  (:class:`~repro.core.device_index.ShardSummaries`, maintained on
  insert) and skips every (query, shard) cell whose free MSS bound
  ``betas_sum * min(len_q, max_len[shard])`` cannot beat the query's
  ``rho`` — or, once k matches exist, its running kth-best.  Skipping
  never changes results: a skipped shard's candidates are strictly
  below the current kth-best, so they cannot enter the top-k even
  through the row-id tie-break.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.errors import CapacityExceeded
from repro.api.sharded import (
    _positive_hash, _positive_hash_np, _pow2, _route,
)
from repro.core import compat
from repro.core.encoding import encode_codes
from repro.core.similarity import (
    PRUNE_EPS, mss_scores, mss_upper_bound, multi_level_lcs,
    wavefront_dtype_from_env,
)
from repro.core.types import PAD_ID, PAD_KEY, PAD_PLACE

# Empty top-k slots: (NO_MATCH, NO_MATCH_MSS) — PAD_ID can never be a row
# id of a match (world ids are dense from 0) and -1.0 is below any real
# MSS (level LCS counts are non-negative).
NO_MATCH = PAD_ID
NO_MATCH_MSS = np.float32(-1.0)


# ---------------------------------------------------------------------------
# capacity planning (pow2-sticky, the PR 4/5 discipline)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Static shapes of one compiled query-serving program pair.

    Shapes quantize to powers of two and the engine keeps them sticky
    (monotone max while the world shape holds), so consecutive query
    micro-batches of similar size reuse both compiled programs verbatim
    — the serving analogue of the streaming zero-recompile contract.
    """

    n_shards: int
    cap_local: int      # resident world rows per shard (world cap if 1)
    L_pad: int          # scoring width: max(world L, longest query)
    q_cap: int          # padded queries per micro-batch
    k_cap: int          # padded top-k slots per query
    cand_cap: int       # candidate (row, query) slots per shard
    key_in_cap: int = 0     # query key occurrences per source shard
    key_route_cap: int = 0  # rows per (src, dst) bucket in the key route


def plan_query_capacities(
    num_queries: int,
    k_max: int,
    *,
    n_shards: int,
    cap_local: int,
    world_L: int,
    q_len_max: int,
    cand_total: int | None = None,
    keys_flat: np.ndarray | None = None,
    stats=None,
    floor_pow2: int = 2,
) -> QueryPlan:
    """Exact capacity plan for ONE query micro-batch.

    Two probe modes, matching the two resident index forms:

    * host (``cand_total``): the BucketIndex probe already ran, so the
      candidate count is exact — buffers hold contiguous per-shard
      chunks of it;
    * device (``keys_flat`` + ``stats``): the
      :class:`~repro.core.device_index.StreamJoinStats` count mirror
      yields the exact per-owner resident-match counts of the query
      keys under the device's own hash (the ``plan_stream_join``
      discipline, new-vs-old only — queries never pair with each
      other), sizing the key route and the probe output without the
      pair list ever touching the driver.
    """
    q_cap = _pow2(num_queries, floor_pow2)
    k_cap = _pow2(max(k_max, 1), floor_pow2)
    L_pad = max(int(world_L), int(q_len_max), 1)
    if cand_total is not None:
        chunk = -(-int(cand_total) // n_shards) if cand_total else 0
        return QueryPlan(
            n_shards=n_shards, cap_local=cap_local, L_pad=L_pad,
            q_cap=q_cap, k_cap=k_cap,
            cand_cap=_pow2(chunk, floor_pow2),
        )
    k = int(keys_flat.shape[0])
    owners = _positive_hash_np(keys_flat) % n_shards if k else \
        np.zeros((0,), np.int64)
    nvo, _, _ = stats.plan_update(keys_flat, owners)
    chunk = -(-k // n_shards) if k else 0
    if k:
        src = np.arange(k, dtype=np.int64) // max(chunk, 1)
        load = np.zeros((n_shards, n_shards), np.int64)
        np.add.at(load, (src, owners), 1)
        route_need = int(load.max())
    else:
        route_need = 1
    return QueryPlan(
        n_shards=n_shards, cap_local=cap_local, L_pad=L_pad,
        q_cap=q_cap, k_cap=k_cap,
        cand_cap=_pow2(int(nvo.max()), floor_pow2),
        key_in_cap=_pow2(chunk, floor_pow2),
        key_route_cap=_pow2(route_need, floor_pow2),
    )


def sticky_query_plan(
    plan: QueryPlan, prev: QueryPlan | None
) -> QueryPlan:
    """Monotone max over every capacity while the world shape holds.

    A world reshape (growth reallocated the resident buffers, so
    ``cap_local`` moved) invalidates the compiled programs anyway — the
    sticky state resets rather than pinning stale capacities forever.
    """
    if prev is None or prev.n_shards != plan.n_shards \
            or prev.cap_local != plan.cap_local:
        return plan
    return QueryPlan(
        n_shards=plan.n_shards, cap_local=plan.cap_local,
        L_pad=max(plan.L_pad, prev.L_pad),
        q_cap=max(plan.q_cap, prev.q_cap),
        k_cap=max(plan.k_cap, prev.k_cap),
        cand_cap=max(plan.cand_cap, prev.cand_cap),
        key_in_cap=max(plan.key_in_cap, prev.key_in_cap),
        key_route_cap=max(plan.key_route_cap, prev.key_route_cap),
    )


# ---------------------------------------------------------------------------
# in-mesh segmented top-k (the [Q, k] reduction)
# ---------------------------------------------------------------------------
def _local_topk(qid, row, mss, *, q_cap, k_cap, rho_vec):
    """Segmented per-query top-k over one device's scored candidates.

    Sort by (query, -mss, row): each query's candidates become a run,
    best first, ties broken toward the smaller row id.  Adjacent
    duplicate (query, row) slots — the same candidate probed through
    several shared keys, scored to the identical mss — are dropped, the
    survivors ranked within their run, and the first ``k_cap`` scattered
    into a ``[q_cap, k_cap]`` table.  Scores are carried NEGATED
    (ascending sort order everywhere, ``+inf`` = empty slot).
    """
    qsafe = jnp.clip(qid, 0, q_cap - 1)
    valid = (row != PAD_ID) & (mss > rho_vec[qsafe])
    qk = jnp.where(valid, qid, q_cap).astype(jnp.int32)
    neg = jnp.where(valid, -mss, jnp.inf).astype(jnp.float32)
    rk = jnp.where(valid, row, PAD_ID)
    qs, ns, rs = jax.lax.sort((qk, neg, rk), num_keys=3)
    dup = jnp.concatenate([
        jnp.zeros((1,), bool),
        (qs[1:] == qs[:-1]) & (rs[1:] == rs[:-1]) & (qs[1:] < q_cap),
    ])
    nd = (~dup) & (qs < q_cap)
    idx = jnp.arange(qs.shape[0], dtype=jnp.int32)
    start = jnp.concatenate([jnp.ones((1,), bool), qs[1:] != qs[:-1]])
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(start, idx, 0)
    )
    c = jnp.cumsum(nd.astype(jnp.int32))
    base = jnp.where(run_start > 0, c[jnp.maximum(run_start - 1, 0)], 0)
    rank = c - base - 1  # rank among this run's distinct survivors
    keep = nd & (rank < k_cap)
    flat = jnp.where(keep, qs * k_cap + rank, q_cap * k_cap)
    top_row = jnp.full((q_cap * k_cap,), PAD_ID, jnp.int32) \
        .at[flat].set(rs, mode="drop").reshape(q_cap, k_cap)
    top_neg = jnp.full((q_cap * k_cap,), jnp.inf, jnp.float32) \
        .at[flat].set(ns, mode="drop").reshape(q_cap, k_cap)
    return top_row, top_neg


def _merge_topk(rows2d, negs2d, *, k_cap):
    """K-way merge of per-query top-k columns from several sources.

    Sort each query's row by (negated mss, row id), drop adjacent
    duplicate rows (the same candidate surfacing from two shards carries
    a bit-identical score, so copies sort adjacent), re-sort the gaps to
    the end, keep the best ``k_cap``.
    """
    valid = rows2d != PAD_ID
    neg = jnp.where(valid, negs2d, jnp.inf)
    rows = jnp.where(valid, rows2d, PAD_ID)
    ns, rs = jax.lax.sort((neg, rows), num_keys=2, dimension=1)
    dup = jnp.concatenate([
        jnp.zeros_like(rs[:, :1], dtype=bool),
        (rs[:, 1:] == rs[:, :-1]) & (rs[:, 1:] != PAD_ID),
    ], axis=1)
    ns = jnp.where(dup, jnp.inf, ns)
    rs = jnp.where(dup, PAD_ID, rs)
    ns, rs = jax.lax.sort((ns, rs), num_keys=2, dimension=1)
    return rs[:, :k_cap], ns[:, :k_cap]


def _serve_score_block(
    codes_all, w_len, cand_row, cand_qid, q_places, rho_vec, active,
    tables, *, plan, betas, fused_mode, impl, phys_of,
):
    """Shared per-device serving stage: encode queries, gate candidates
    by the per-round (query, world-shard) prune mask, score them off the
    resident table, and reduce to this device's [q_cap, k_cap] top-k."""
    if codes_all.shape[-1] < plan.L_pad:
        codes_all = jnp.pad(
            codes_all,
            ((0, 0), (0, 0), (0, plan.L_pad - codes_all.shape[-1])),
            constant_values=-1,  # stays a non-matching sentinel column
        )
    q_codes = encode_codes(q_places, tables)  # [q_cap, H, L_pad]
    q_len = jnp.sum(q_codes[:, 0, :] >= 0, axis=-1).astype(jnp.int32)
    valid = cand_row != PAD_ID
    qsafe = jnp.clip(cand_qid, 0, plan.q_cap - 1)
    shard = jnp.where(valid, cand_row % plan.n_shards, 0)
    row = jnp.where(valid & active[qsafe, shard], cand_row, PAD_ID)
    alive = row != PAD_ID
    ri = phys_of(jnp.where(alive, row, 0))
    if fused_mode is not None:
        from repro.kernels.lcs.fused import fused_score

        _, mss = fused_score(
            q_codes, q_len, codes_all, w_len, qsafe, ri, betas,
            mode=fused_mode,
        )
    else:
        lvl = multi_level_lcs(
            q_codes[qsafe], q_len[qsafe], codes_all[ri], w_len[ri],
            impl=impl,
        )
        mss = mss_scores(lvl, betas)
    mss = jnp.where(alive, mss, jnp.float32(NO_MATCH_MSS))
    return _local_topk(
        cand_qid, row, mss, q_cap=plan.q_cap, k_cap=plan.k_cap,
        rho_vec=rho_vec,
    )


# ---------------------------------------------------------------------------
# compiled program builders
# ---------------------------------------------------------------------------
def make_query_score_pipeline(
    mesh,
    plan: QueryPlan,
    *,
    betas,
    axis_name: str = "ex",
    lcs_impl: str = "wavefront",
    trace_counter: list | None = None,
):
    """Build the shared compiled query score + in-mesh top-k program.

    ``mesh=None`` builds the single-device form (the world is the
    resident ``[cap, H, L]`` code table); with a mesh, each shard encodes
    its own round-robin places slab in-mesh, all_gathers the encodings
    (serving is the ~10M-row replicate regime: latency beats table
    locality), scores its resting candidates, and reduces its local
    per-query top-k; an all_gather of the tiny ``[q_cap, k_cap]`` tables
    plus a k-way merge then leaves only [Q, k] results to read.

    Mesh call signature::

      fn(places [S * cap_local, Lw], cand_row [S * cand_cap] (global
         world ids), cand_qid [S * cand_cap], q_places [q_cap, L_pad],
         rho_vec [q_cap] f32, active [q_cap, S] bool,
         prev_row/prev_neg [q_cap, k_cap] (the carried top-k state),
         tables)
        -> dict: top_row / top_neg [q_cap, k_cap] (merged with prev)

    Single-device signature replaces ``places`` with the resident
    ``codes [cap, H, Lw]`` + ``w_len [cap]`` (no encode, no collectives).
    ``trace_counter`` increments at TRACE time only — the serving
    zero-steady-state-recompile proof hook.
    """
    from jax.sharding import PartitionSpec as P

    from repro.api.stages import FUSED_MODES, lcs_impl_fn

    # resolved HERE, at the eager call boundary (wavefront_dtype_from_env
    # must never run inside a traced body)
    fused_mode = FUSED_MODES.get(lcs_impl)
    impl = None if fused_mode is not None else lcs_impl_fn(lcs_impl)

    if mesh is None:

        @jax.jit
        def run_single(codes, w_len, cand_row, cand_qid, q_places,
                       rho_vec, active, prev_row, prev_neg, tables):
            if trace_counter is not None:
                trace_counter[0] += 1  # per compile, not per query batch
            t_row, t_neg = _serve_score_block(
                codes, w_len, cand_row, cand_qid, q_places, rho_vec,
                active, tables, plan=plan, betas=betas,
                fused_mode=fused_mode, impl=impl, phys_of=lambda g: g,
            )
            m_row, m_neg = _merge_topk(
                jnp.concatenate([t_row, prev_row], axis=1),
                jnp.concatenate([t_neg, prev_neg], axis=1),
                k_cap=plan.k_cap,
            )
            return {"top_row": m_row, "top_neg": m_neg}

        return run_single

    n_shards = plan.n_shards

    def shard_fn(places, cand_row, cand_qid, q_places, rho_vec, active,
                 prev_row, prev_neg, tables):
        if trace_counter is not None:
            trace_counter[0] += 1  # per compile, not per query batch
        codes = encode_codes(places, tables)  # own slab, in-mesh
        codes_all = jax.lax.all_gather(codes, axis_name, axis=0,
                                       tiled=True)
        w_len = jnp.sum(codes_all[:, 0, :] >= 0, axis=-1) \
            .astype(jnp.int32)

        def phys_of(g):  # round-robin world layout
            return (g % n_shards) * plan.cap_local + g // n_shards

        t_row, t_neg = _serve_score_block(
            codes_all, w_len, cand_row, cand_qid, q_places, rho_vec,
            active, tables, plan=plan, betas=betas,
            fused_mode=fused_mode, impl=impl, phys_of=phys_of,
        )
        g_row = jax.lax.all_gather(t_row, axis_name)  # [S, q_cap, k_cap]
        g_neg = jax.lax.all_gather(t_neg, axis_name)
        rows2d = jnp.concatenate(
            [jnp.moveaxis(g_row, 0, 1).reshape(plan.q_cap, -1), prev_row],
            axis=1,
        )
        negs2d = jnp.concatenate(
            [jnp.moveaxis(g_neg, 0, 1).reshape(plan.q_cap, -1), prev_neg],
            axis=1,
        )
        return _merge_topk(rows2d, negs2d, k_cap=plan.k_cap)

    spec_in = (P(axis_name, None), P(axis_name), P(axis_name),
               P(None, None), P(None), P(None, None),
               P(None, None), P(None, None), P(None, None))
    spec_out = (P(axis_name, None), P(axis_name, None))
    fn = compat.shard_map(
        shard_fn, mesh=mesh, in_specs=spec_in, out_specs=spec_out
    )

    @jax.jit
    def run(places, cand_row, cand_qid, q_places, rho_vec, active,
            prev_row, prev_neg, tables):
        m_row, m_neg = fn(places, cand_row, cand_qid, q_places, rho_vec,
                          active, prev_row, prev_neg, tables)
        # every shard computed the identical merge; read one replica
        return {
            "top_row": m_row.reshape(n_shards, plan.q_cap, plan.k_cap)[0],
            "top_neg": m_neg.reshape(n_shards, plan.q_cap, plan.k_cap)[0],
        }

    return run


def make_query_probe_pipeline(
    mesh,
    plan: QueryPlan,
    *,
    axis_name: str = "ex",
    trace_counter: list | None = None,
):
    """Build the in-mesh READ-ONLY candidate probe program.

    The serving twin of :func:`make_streaming_join_pipeline` stages (1)
    and (2) with everything mutable removed: query key occurrences route
    to their owner shard, :func:`~repro.core.device_index.probe_rows`
    range-probes the resident slab — no new-vs-new stage, no
    ``merge_insert``, the slabs are pure inputs — and the (world row,
    query) candidates come to rest on the key-owner shard, deduped
    locally (copies via several same-owner shared keys sort adjacent;
    cross-owner copies collapse later in the top-k merge, where their
    bit-identical scores make them adjacent again).

    ``fn(slab_keys [S * slab_cap], slab_rows, keys [S * key_in_cap],
    qids) -> dict: cand_row / cand_qid [S, cand_cap], count [S],
    examined [S], overflow [S]``.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.device_index import probe_rows

    n_shards = plan.n_shards

    def shard_fn(slab_k, slab_r, keys, qids):
        if trace_counter is not None:
            trace_counter[0] += 1  # per compile, not per query batch
        valid = keys != PAD_KEY
        dest = _positive_hash(keys) % n_shards
        (rk, rq), o1 = _route(
            (keys, qids), dest, valid,
            n_shards=n_shards, capacity=plan.key_route_cap,
            pads=(PAD_KEY, PAD_ID), axis_name=axis_name,
        )
        row, qid, examined, o2 = probe_rows(
            slab_k, slab_r, rk, rq, cap=plan.cand_cap
        )
        row_s, qid_s = jax.lax.sort((row, qid), num_keys=2)
        dup = jnp.concatenate([
            jnp.zeros((1,), bool),
            (row_s[1:] == row_s[:-1]) & (qid_s[1:] == qid_s[:-1])
            & (row_s[1:] != PAD_ID),
        ])
        row_d = jnp.where(dup, PAD_ID, row_s)
        qid_d = jnp.where(dup, PAD_ID, qid_s)
        count = jnp.sum(row_d != PAD_ID).astype(jnp.int32)
        return (row_d, qid_d, count.reshape(1), examined.reshape(1),
                (o1 + o2).astype(jnp.int32).reshape(1))

    spec_in = (P(axis_name), P(axis_name), P(axis_name), P(axis_name))
    spec_out = (P(axis_name), P(axis_name), P(axis_name), P(axis_name),
                P(axis_name))
    fn = compat.shard_map(
        shard_fn, mesh=mesh, in_specs=spec_in, out_specs=spec_out
    )

    @jax.jit
    def run(slab_keys, slab_rows, keys, qids):
        row, qid, count, examined, overflow = fn(
            slab_keys, slab_rows, keys, qids
        )
        return {
            "cand_row": row.reshape(n_shards, -1),
            "cand_qid": qid.reshape(n_shards, -1),
            "count": count.reshape(n_shards),
            "examined": examined.reshape(n_shards),
            "overflow": overflow.reshape(n_shards),
        }

    return run


# ---------------------------------------------------------------------------
# the read-only probe protocol adapters (no branching in the engine)
# ---------------------------------------------------------------------------
class _HostProber:
    """Candidate probe against the driver-resident ``BucketIndex``."""

    def __init__(self, engine: "QueryEngine"):
        self.engine = engine

    def prepare(self, keys_np, k_flat, q_flat):
        qidx, rows, examined = self.engine.stream._index.probe(keys_np)
        return {
            "qidx": qidx, "rows": rows, "examined": int(examined),
            "plan_kwargs": {"cand_total": int(qidx.shape[0])},
        }

    def finish(self, pre, qplan: QueryPlan):
        e = self.engine
        S, cap = qplan.n_shards, qplan.cand_cap
        qidx, rows = pre["qidx"], pre["rows"]
        # the BucketIndex speaks global ids; the score program gathers
        # world slots by LOCAL index (slot = id - base), so translate
        # before shipping — query() adds the base back to the results
        rows = rows - np.int32(e.stream._base)
        total = int(qidx.shape[0])
        buf_r = np.full((S, cap), PAD_ID, np.int32)
        buf_q = np.full((S, cap), PAD_ID, np.int32)
        chunk = -(-total // S) if total else 0
        for s in range(S):
            seg = slice(s * chunk, (s + 1) * chunk)
            buf_r[s, : rows[seg].shape[0]] = rows[seg]
            buf_q[s, : qidx[seg].shape[0]] = qidx[seg]
        e._xfer_bytes += buf_r.nbytes + buf_q.nbytes
        stats = {"candidates": total, "probe_examined": pre["examined"]}
        return (jnp.asarray(buf_r.reshape(-1)),
                jnp.asarray(buf_q.reshape(-1)), qplan, stats)


class _SlabProber:
    """Candidate probe against the device-resident key-sharded slabs.

    Only the query key occurrences transit the driver; the candidate
    list is born in-mesh and stays there, resting in the exact buffers
    the score program consumes.
    """

    def __init__(self, engine: "QueryEngine"):
        self.engine = engine

    def prepare(self, keys_np, k_flat, q_flat):
        return {
            "k_flat": k_flat, "q_flat": q_flat,
            "plan_kwargs": {
                "keys_flat": k_flat,
                "stats": self.engine.stream._join_stats,
            },
        }

    def finish(self, pre, qplan: QueryPlan):
        e = self.engine
        stream = e.stream
        k_flat, q_flat = pre["k_flat"], pre["q_flat"]
        S = qplan.n_shards
        out = None
        for _ in range(e.planner.max_retries + 1):
            chunk = -(-k_flat.shape[0] // S)
            in_k = np.full((S, qplan.key_in_cap), PAD_KEY, np.int32)
            in_q = np.full((S, qplan.key_in_cap), PAD_ID, np.int32)
            for s in range(S):
                seg = slice(s * chunk, (s + 1) * chunk)
                in_k[s, : k_flat[seg].shape[0]] = k_flat[seg]
                in_q[s, : q_flat[seg].shape[0]] = q_flat[seg]
            e._xfer_bytes += in_k.nbytes + in_q.nbytes
            out = e._probe_runner(qplan)(
                stream._slab_keys, stream._slab_rows,
                jnp.asarray(in_k.reshape(-1)),
                jnp.asarray(in_q.reshape(-1)),
            )
            if int(np.asarray(out["overflow"]).sum()) == 0:
                break
            # exact planning makes this unreachable; belt-and-braces
            qplan = dataclasses.replace(
                qplan, cand_cap=qplan.cand_cap * 2,
                key_route_cap=qplan.key_route_cap * 2,
            )
        if int(np.asarray(out["overflow"]).sum()):
            # a truncated candidate list would silently drop matches —
            # refuse the query instead (typed, so callers can shed load)
            raise CapacityExceeded(
                "query probe still overflowed after "
                f"{e.planner.max_retries} retries (per-shard overflow "
                f"{np.asarray(out['overflow']).tolist()}); refusing to "
                "serve a truncated candidate set"
            )
        stats = {
            "candidates": int(np.asarray(out["count"]).sum()),
            "probe_examined": int(np.asarray(out["examined"]).sum()),
        }
        return (out["cand_row"].reshape(-1), out["cand_qid"].reshape(-1),
                qplan, stats)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Per-query top-k matches against the resident world.

    match_ids: int32 [Q, k_max] world row ids, best first (mss
        descending, row id ascending), ``PAD_ID`` in empty slots.
    mss: float32 [Q, k_max] matching scores, ``-1.0`` in empty slots.
    stats: one dict of serving counters for this micro-batch.
    """

    match_ids: np.ndarray
    mss: np.ndarray
    stats: dict


class QueryEngine:
    """Top-k query serving over a :class:`StreamingEngine`'s world.

    Constructed FROM the streaming engine, never owning its state: every
    ``query`` reads the world as it stands (queries interleave freely
    with ``update`` calls) and mutates nothing — the read-only probe
    protocol guarantees the index is untouched.

    k: default result count (per-query override via ``query(k=...)``).
    serve_prune: enable the REPOSE-style per-shard pruning pass (module
        docstring); results are identical either way.
    """

    def __init__(self, stream, *, k: int = 10, serve_prune: bool = False):
        self.stream = stream
        self.default_k = int(k)
        self.serve_prune = bool(serve_prune)
        self.planner = stream.planner
        self.betas = stream.betas
        self.config = stream.config
        self.plan = stream.plan
        self.serve_traces = [0]  # score-program compile counter (the
        #                          zero-steady-state-recompile proof)
        self.probe_traces = [0]  # probe-program compile counter
        self.runner_builds = 0
        self.queries_served = 0
        self._qplan: QueryPlan | None = None
        self._compactions_seen = stream.compactions
        self._runner_cache: dict = {}
        self._probe_cache: dict = {}
        self._xfer_bytes = 0
        # the probe protocol adapter: both expose prepare()/finish(),
        # so query() below never branches on the world's index form
        self._prober = (_SlabProber(self)
                        if stream.delta_join == "device"
                        else _HostProber(self))

    # -- public entry point --------------------------------------------------

    def query(self, batch, *, k=None, rho=None) -> QueryResult:
        """Top-k matches for one micro-batch of query trajectories.

        batch: a :class:`TrajectoryBatch` (or anything with ``places``
            [Q, L] and ``lengths`` [Q]).
        k: result count — an int for all queries or an [Q] array.
        rho: similarity threshold (matches require ``mss > rho``) — a
            float for all queries or an [Q] array; defaults to
            ``config.rho``.
        """
        places = np.asarray(batch.places, np.int32)
        if places.ndim != 2:
            places = places.reshape((places.shape[0], -1) if places.size
                                    else (0, 1))
        lengths = np.asarray(batch.lengths, np.int32).reshape(-1)
        Q = places.shape[0]
        k_vec = np.broadcast_to(
            np.asarray(self.default_k if k is None else k, np.int32), (Q,)
        ).copy()
        k_vec = np.maximum(k_vec, 0)
        rho_vec = np.broadcast_to(np.asarray(
            self.config.rho if rho is None else rho, np.float32), (Q,)
        ).copy()
        k_max = int(k_vec.max()) if Q else 0
        self._xfer_bytes = 0
        # the sticky plan may shrink ONLY at a compaction boundary — the
        # serving analogue of the streaming shrink rule (between
        # boundaries caps are monotone, so traffic never recompiles)
        if self.stream.compactions != self._compactions_seen:
            self._qplan = None
            self._compactions_seen = self.stream.compactions
        stats = {
            "queries": Q, "world_size": self.stream.n,
            "world_live": self.stream.live_size, "candidates": 0,
            "probe_examined": 0, "rounds_run": 0, "rounds_skipped": 0,
            "cells_skipped": 0,
        }
        if Q == 0 or self.stream.n == 0:
            return self._finish_result(
                np.full((Q, max(k_max, 0)), PAD_ID, np.int32),
                np.full((Q, max(k_max, 0)), NO_MATCH_MSS, np.float32),
                k_vec, k_max, stats,
            )
        keys_np = self.stream._new_row_keys(places, lengths)
        k_flat, q_flat = _flat_row_keys(keys_np)
        if k_flat.size == 0:
            return self._finish_result(
                np.full((Q, k_max), PAD_ID, np.int32),
                np.full((Q, k_max), NO_MATCH_MSS, np.float32),
                k_vec, k_max, stats,
            )
        pre = self._prober.prepare(keys_np, k_flat, q_flat)
        S = self._world_shards()
        qplan = sticky_query_plan(
            self.planner.plan_query(
                Q, k_max, n_shards=S, cap_local=self._world_cap() // S,
                world_L=self.stream.L,
                q_len_max=int(lengths.max()) if Q else 1,
                **pre["plan_kwargs"],
            ),
            self._qplan,
        )
        cand_row, cand_qid, qplan, probe_stats = self._prober.finish(
            pre, qplan
        )
        self._qplan = qplan
        stats.update(probe_stats)
        if stats["candidates"] == 0:
            return self._finish_result(
                np.full((Q, k_max), PAD_ID, np.int32),
                np.full((Q, k_max), NO_MATCH_MSS, np.float32),
                k_vec, k_max, stats,
            )
        top_row, top_neg = self._run_rounds(
            qplan, cand_row, cand_qid, places, lengths, k_vec, rho_vec,
            stats,
        )
        rows_np = np.asarray(top_row)[:Q]
        negs_np = np.asarray(top_neg)[:Q]
        ids = rows_np[:, :k_max] if k_max else rows_np[:, :0]
        neg = negs_np[:, :k_max] if k_max else negs_np[:, :0]
        mss = np.where(ids != PAD_ID, -neg, NO_MATCH_MSS) \
            .astype(np.float32)
        # device programs speak local slots; matches surface as global ids
        ids = np.where(ids != PAD_ID, ids + np.int32(self.stream._base),
                       PAD_ID)
        return self._finish_result(ids.copy(), mss, k_vec, k_max, stats)

    # -- internals -----------------------------------------------------------

    def _world_shards(self) -> int:
        return self.plan.n_shards if self.stream._mesh_world else 1

    def _world_cap(self) -> int:
        return self.stream._cap

    def _finish_result(self, ids, mss, k_vec, k_max, stats):
        if k_max:
            cols = np.arange(k_max, dtype=np.int32)[None, :]
            drop = cols >= k_vec[:, None]
            ids = np.where(drop, PAD_ID, ids)
            mss = np.where(drop, NO_MATCH_MSS, mss).astype(np.float32)
        self.queries_served += int(stats["queries"])
        stats.update(
            serve_traces=self.serve_traces[0],
            probe_traces=self.probe_traces[0],
            runner_builds=self.runner_builds,
            driver_bytes_in=self._xfer_bytes,
        )
        return QueryResult(match_ids=ids, mss=mss, stats=dict(stats))

    def _run_rounds(self, qplan, cand_row, cand_qid, places, lengths,
                    k_vec, rho_vec, stats):
        """Execute the shared score program once (no pruning) or once per
        surviving world shard (REPOSE rounds), carrying the [q_cap, k_cap]
        top-k state in-mesh between rounds."""
        Q = places.shape[0]
        S = qplan.n_shards
        q_places = np.full((qplan.q_cap, qplan.L_pad), PAD_PLACE, np.int32)
        w = min(places.shape[1], qplan.L_pad)
        q_places[:Q, :w] = places[:, :w]
        # positions past each query's length must be the PAD sentinel —
        # encode_codes derives in-program lengths from it
        cols = np.arange(qplan.L_pad, dtype=np.int32)[None, :]
        q_places[:Q] = np.where(cols < lengths[:, None], q_places[:Q],
                                PAD_PLACE)
        rho_pad = np.full((qplan.q_cap,), np.inf, np.float32)
        rho_pad[:Q] = rho_vec
        self._xfer_bytes += q_places.nbytes + rho_pad.nbytes
        q_places_dev = jnp.asarray(q_places)
        rho_dev = jnp.asarray(rho_pad)
        prev_row = jnp.full((qplan.q_cap, qplan.k_cap), PAD_ID, jnp.int32)
        prev_neg = jnp.full((qplan.q_cap, qplan.k_cap), jnp.inf,
                            jnp.float32)
        runner = self._score_runner(qplan)
        world_args = self._world_args()

        def run_round(active_np, prow, pneg):
            active = jnp.asarray(active_np)
            self._xfer_bytes += active_np.nbytes
            out = runner(*world_args, cand_row, cand_qid, q_places_dev,
                         rho_dev, active, prow, pneg,
                         self.stream.tables)
            stats["rounds_run"] += 1
            return out["top_row"], out["top_neg"]

        if not self.serve_prune:
            return run_round(
                np.ones((qplan.q_cap, S), bool), prev_row, prev_neg
            )
        # REPOSE rounds: shards in descending resident-length order; a
        # (query, shard) cell is skipped when the free MSS bound cannot
        # beat rho, or — once k matches exist — the running kth-best.
        # Both tests keep the extra PRUNE_EPS margin on the KEEP side,
        # so a skipped cell is strictly unable to alter the top-k.
        summ = self.stream.shard_summaries
        bsum = float(np.asarray(self.betas, np.float32).sum())
        ub = mss_upper_bound(
            np.minimum(lengths, qplan.L_pad)[:, None],
            np.broadcast_to(summ.max_len[None, :], (Q, S)), bsum,
        )  # f32 [Q, S]
        order = np.argsort(-summ.max_len, kind="stable")
        kth = np.full((Q,), -np.inf, np.float32)
        have_k = k_vec == 0
        kth[have_k] = np.inf
        row_state, neg_state = prev_row, prev_neg
        ran_any = False
        for pos, s in enumerate(order.tolist()):
            act = ub[:, s] > rho_vec - PRUNE_EPS
            act &= ~have_k | (ub[:, s] > kth - PRUNE_EPS)
            if not act.any():
                # ub is monotone in the shard's max_len and kth only
                # grows, so every remaining shard is skippable too
                stats["rounds_skipped"] += len(order) - pos
                stats["cells_skipped"] += (len(order) - pos) * Q
                break
            stats["cells_skipped"] += int(Q - act.sum())
            active = np.zeros((qplan.q_cap, S), bool)
            active[:Q, s] = act
            row_state, neg_state = run_round(active, row_state, neg_state)
            ran_any = True
            mss_state = -np.asarray(neg_state)[:Q]  # sorted best-first
            found = np.asarray(row_state)[:Q] != PAD_ID
            counts = found.sum(axis=1)
            have_k = counts >= np.maximum(k_vec, 1)
            have_k |= k_vec == 0
            idx = np.clip(np.maximum(k_vec, 1) - 1, 0,
                          qplan.k_cap - 1)
            kth = np.where(
                have_k, mss_state[np.arange(Q), idx], -np.inf
            ).astype(np.float32)
            kth[k_vec == 0] = np.inf
        if not ran_any:
            return prev_row, prev_neg
        return row_state, neg_state

    def _world_args(self):
        stream = self.stream
        if stream._mesh_world:
            return (stream._places_dev,)
        return (stream._codes_dev, stream._len_dev)

    def _score_runner(self, qplan: QueryPlan):
        key = (qplan, self.config.lcs_impl, wavefront_dtype_from_env(),
               self.stream._H)
        runner = self._runner_cache.get(key)
        if runner is None:
            mesh = self.stream._eng.mesh() if self.stream._mesh_world \
                else None
            runner = make_query_score_pipeline(
                mesh, qplan, betas=self.betas,
                axis_name=self.plan.axis_name,
                lcs_impl=self.config.lcs_impl,
                trace_counter=self.serve_traces,
            )
            self._runner_cache[key] = runner
            self.runner_builds += 1
        return runner

    def _probe_runner(self, qplan: QueryPlan):
        runner = self._probe_cache.get(qplan)
        if runner is None:
            runner = make_query_probe_pipeline(
                self.stream._eng.mesh(), qplan,
                axis_name=self.plan.axis_name,
                trace_counter=self.probe_traces,
            )
            self._probe_cache[qplan] = runner
            self.runner_builds += 1
        return runner


def _flat_row_keys(keys_np: np.ndarray):
    """Per-row-deduped flat (key, row-index) occurrences — the same
    vectorized discipline as the streaming device join's key flattening,
    with query indices standing in for world row ids."""
    ks = np.sort(np.asarray(keys_np), axis=1)
    valid = ks != PAD_KEY
    valid[:, 1:] &= ks[:, 1:] != ks[:, :-1]
    row_idx, col_idx = np.nonzero(valid)
    return (ks[row_idx, col_idx].astype(np.int32),
            row_idx.astype(np.int32))
