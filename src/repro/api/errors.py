"""Typed capacity errors: admission control for streaming/serving.

Fixed-shape buffers turn "out of memory" from a crash into a plannable
event: every stage knows, before it runs, how much capacity a retry
doubling would allocate.  :class:`CapacityExceeded` is the typed refusal —
raised when a single update/query cannot fit within ``max_retries``
doublings or the engine's ``max_resident_bytes`` budget, WITHOUT mutating
the world state, so the caller can shed load / widen the budget / retire
rows and re-submit the same batch.

It subclasses ``RuntimeError`` so pre-existing ``except RuntimeError``
overflow handling keeps working.
"""
from __future__ import annotations


class CapacityExceeded(RuntimeError):
    """A single update/query exceeded its capacity budget and was refused.

    Attributes:
        needed_bytes:  resident bytes the operation would have required
                       (0 when the refusal is retry-count based).
        budget_bytes:  the configured ``max_resident_bytes`` (0 = retries).
    """

    def __init__(self, message: str, *, needed_bytes: int = 0,
                 budget_bytes: int = 0):
        super().__init__(message)
        self.needed_bytes = int(needed_bytes)
        self.budget_bytes = int(budget_bytes)
