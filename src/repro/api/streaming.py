"""`StreamingEngine`: micro-batch ingestion with incremental maintenance.

    from repro.api import StreamingEngine, EngineConfig, ExecutionPlan

    stream = StreamingEngine(forest, EngineConfig(backend="ssh", rho=2.0))
    for micro_batch in feed:
        result = stream.update(micro_batch)   # EngineResult, same type as
                                              # AnotherMeEngine.run

The one-shot engine re-encodes, re-joins, re-scores and re-clusters the
full world on every call; the motivating workloads (friend recommendation
over continuously collected LBS trajectories) are incremental, so this
layer makes per-update cost proportional to the DELTA instead of the world:

* world state is device-resident and append-only — the [N, H, L] code
  table (single-device) or the round-robin sharded places slabs (sharded)
  grow by amortized doubling (:meth:`CapacityPlanner.grow_capacity`), and
  each update transfers only the new rows;
* candidate generation is incremental: every backend's join keys are a
  pure per-row function, so a :class:`~repro.core.stream_index.BucketIndex`
  inserts the new rows' keys and emits exactly the pairs whose LATER member
  arrived in this update (new-vs-(old ∪ new) bucket collisions) — the
  union over updates equals the one-shot join over the concatenated batch;
* with ``ExecutionPlan(delta_join="device")`` the bucket state itself
  leaves the driver: it becomes key-sharded device-resident sorted slabs
  (``core/device_index.py``), each update ships only the new rows' key
  occurrences into a shard_map program that routes them to their owner
  shard, probes/merges the resident slab, and emits the deduped delta
  pairs in-mesh, feeding the score program directly — neither the world's
  keys nor the pair list ever materializes on the driver (the per-update
  ``driver_*`` stats account for every byte that does transfer).  The
  host ``BucketIndex`` path (``delta_join="host"``, the default) is kept
  as the oracle the differential harness pins the device join against;
* scoring runs the existing ``lcs_impl`` dispatch over the delta pairs
  only (``score_prune`` prunes the delta first), against the resident
  world table;
* communities are maintained incrementally: surviving edges fold into a
  host :class:`~repro.core.communities.UnionFind` (the exact oracle path)
  or into a resumable jit ``connected_components`` seeded with the
  previous fixpoint via star edges ``(label[v], v)`` (the device path);
  both yield the identical partition a one-shot run would produce.

The streaming-vs-oneshot equivalence suite (tests/test_streaming.py and
the streaming axis of tests/test_api_parity_matrix.py) pins all of this
bit-exactly: for ANY split of a batch into micro-batches, the final scored
edge set, per-pair MSS, and community partition match one ``engine.run``
over the concatenation, on the single-device and sharded paths alike.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.api.engine import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.api.errors import CapacityExceeded
from repro.api.instrumentation import Instrumentation
from repro.api.sharded import (
    StreamShardPlan, _positive_hash_np, _pow2, make_streaming_join_pipeline,
    make_streaming_score_pipeline, plan_stream_capacities, plan_stream_join,
    sticky_join_plan,
)
from repro.api.stages import _KERNEL_MODES, _score_with_kernel
from repro.core import communities as comm
from repro.core.device_index import (
    ShardSummaries, StreamJoinStats, compact_slab, mark_dead_rows,
)
from repro.core.encoding import encode_codes, encode_types
from repro.core.pipeline import AnotherMeResult as EngineResult
from repro.core.similarity import (
    PRUNE_EPS, mss_upper_bound, score_pairs, wavefront_dtype_from_env,
)
from repro.core.stream_index import BucketIndex
from repro.core.types import (
    EncodedBatch, PAD_ID, PAD_KEY, PAD_PLACE, ScoredPairs, TrajectoryBatch,
)

COMPONENTS_IMPLS = ("unionfind", "jit")
DELTA_JOINS = ("host", "device")

# a row with no TTL never expires on its own
NEVER_EXPIRES = np.iinfo(np.int64).max

# REPRO_FAULT_INJECT=1 derates every fresh join/score plan to artificially
# tiny caps, forcing the overflow -> compact -> retry recovery path on
# every run (CI exercises it deterministically; results stay bit-identical
# because overflowed runs are never committed).  Read per-call so tests
# can flip it with monkeypatch.setenv.
def _fault_inject() -> bool:
    return bool(int(os.environ.get("REPRO_FAULT_INJECT", "0") or "0"))


def _derate_cap(cap: int) -> int:
    """Fault-injection derating: shrink a planned capacity hard enough to
    force overflow retries, but keep it a power of two >= 4 so the retry
    doubling converges within the extra fault-injection retry budget."""
    return max(4, _pow2(max(cap // 8, 1)))


class StreamingEngine:
    """Incremental AnotherMe over a fixed semantic forest.

    One instance owns the growing world state; :meth:`update` ingests one
    micro-batch and returns the CURRENT world's :class:`EngineResult` —
    accumulated scored pairs, the full similar set, and the maintained
    communities — so the final update's result is directly comparable to
    a one-shot ``AnotherMeEngine.run`` over the concatenated batches.

    ``components_impl`` selects the community maintenance path used when
    ``config.community_mode == "components"``: ``"unionfind"`` (host,
    exact, amortized O(alpha) per edge) or ``"jit"`` (device min-label
    propagation resumed from the previous labels).  ``"cliques"`` mode
    re-runs the Bron-Kerbosch oracle over the accumulated edge set —
    labels there are exact but not incremental (DESIGN.md discusses when
    each is appropriate).
    """

    def __init__(
        self,
        forest,
        config: EngineConfig = EngineConfig(),
        plan: ExecutionPlan = ExecutionPlan(),
        *,
        components_impl: str = "unionfind",
        world_capacity: int | None = None,
        join_slab_capacity: int | None = None,
        window: int | None = None,
        max_resident_bytes: int | None = None,
        compact_watermark: float = 0.5,
    ):
        if components_impl not in COMPONENTS_IMPLS:
            raise ValueError(
                f"unknown components_impl {components_impl!r}; valid: "
                f"{list(COMPONENTS_IMPLS)}"
            )
        if plan.delta_join not in DELTA_JOINS:
            raise ValueError(
                f"unknown delta_join {plan.delta_join!r}; valid: "
                f"{list(DELTA_JOINS)}"
            )
        if config.subtraj_window is not None:
            # Window ids are t * nw + j with nw derived from the world max
            # length L — but the streaming world's L GROWS across updates,
            # which would re-number every window id already resident in the
            # bucket index / join slabs.  Subtrajectory streaming needs a
            # fixed-L world contract first (ROADMAP); reject loudly rather
            # than silently joining stale coordinates.
            raise NotImplementedError(
                "subtraj_window is not supported by StreamingEngine: the "
                "streaming world's max length grows across updates, which "
                "would invalidate resident window ids.  Use the batch "
                "AnotherMeEngine for subtrajectory search."
            )
        # the one-shot engine validates config/plan and owns the shared
        # pieces: forest tables, betas, backend, planner, mesh
        self._eng = AnotherMeEngine(forest, config, plan)
        self.forest = forest
        self.config = self._eng.config  # plan.lcs_impl already folded in
        self.plan = plan
        self.tables = self._eng.tables
        self.betas = self._eng.betas
        self.backend = self._eng.backend
        self.backend_ctx = self._eng.backend_ctx
        self.planner = self._eng.planner
        self.components_impl = components_impl
        H = int(self.tables.shape[0])
        self._H = H
        # world state (global-order host mirror + device-resident tables)
        self.n = 0               # trajectories arrived (global ids 0..n-1)
        self.L = 1               # world max trajectory length (grows)
        self._cap = 0            # world buffer capacity (amortized doubling)
        # bounded-memory state: the resident buffers hold ONLY the id
        # window [base, n) — slot i is global id base + i.  ``base`` only
        # moves at compaction (prefix rebase: every id below it is dead),
        # and is kept a multiple of n_shards so the round-robin owner
        # ``g % n_shards`` is invariant under the shift — device programs
        # operate on LOCAL ids (g - base) and never see the base move.
        self._base = 0
        self._alive_np = np.zeros((0,), bool)     # [cap] liveness, local
        self._expiry_np = np.zeros((0,), np.int64)  # [cap] expiry update
        if window is not None and window <= 0:
            raise ValueError(f"window must be positive, got {window}")
        self.window = window
        self.max_resident_bytes = max_resident_bytes
        if not (0.0 < compact_watermark <= 1.0):
            raise ValueError(
                f"compact_watermark must be in (0, 1], got {compact_watermark}"
            )
        self.compact_watermark = float(compact_watermark)
        self.retired_total = 0   # rows ever retired (TTL + explicit)
        self.compactions = 0     # watermark compactions run
        self.compact_ms_total = 0.0  # cumulative compaction stall latency
        self._cap_floor = max(16, int(world_capacity or 0))  # preallocation
        #   hint: a caller expecting ~N trajectories passes world_capacity=N
        #   so the world buffers never reallocate (and the world-shaped
        #   programs never recompile) below that size
        self._places_np = np.full((0, 1), PAD_PLACE, np.int32)
        self._lengths_np = np.zeros((0,), np.int32)
        self._codes_dev = None   # single-device resident [cap, H, L]
        self._len_dev = None     # single-device resident [cap]
        self._places_dev = None  # sharded resident round-robin [cap, L]
        # delta-join routing: "host" probes the driver-resident BucketIndex
        # (the oracle); "device" keeps the bucket state key-sharded in-mesh
        # and the world lives in the sharded layout even at n_shards=1
        self.delta_join = plan.delta_join
        self._mesh_world = plan.n_shards > 1 or self.delta_join == "device"
        # incremental candidate index (one impl for every backend's keys)
        self._index = BucketIndex()
        # device-resident key-sharded bucket slabs (delta_join="device")
        self._slab_keys = None   # [n_shards * slab_cap] sorted, PAD at end
        self._slab_rows = None   # aligned row ids
        self._slab_cap = 0
        self._join_stats = StreamJoinStats(plan.n_shards)
        self._join_plan = None
        self._score_caps = None  # sticky (pair_cap, rest_cap) of the
        #   device-pair score program, sized from the join's in-mesh
        #   post-dedup count reduction (tighter than the join's own
        #   pre-dedup pair_cap)
        # per-world-shard length summaries, maintained on insert — the
        # serve-time REPOSE prune bounds (api/serving.py reads these; they
        # are world metadata, so the host path keeps them too)
        self.shard_summaries = ShardSummaries(
            plan.n_shards if self._mesh_world else 1
        )
        self._slab_floor = int(join_slab_capacity or 0)  # presize hint: a
        #   caller expecting ~E total resident key occurrences passes
        #   join_slab_capacity=E so the slabs never regrow (and the join
        #   program never recompiles) below that size, like world_capacity
        self._examined_total = 0
        self._join_runner_cache: dict = {}
        self.join_traces = [0]   # join-program compile counter (the
        #                          zero-steady-state-recompile proof hook)
        # per-update driver transfer accounting (the harness asserts the
        # device path ships no pair list and no world keys)
        self._xfer = {"bytes_in": 0, "pair_rows": 0, "key_rows": 0}
        # accumulated scored pairs (amortized-doubling host buffers)
        self._acc_cap = 0
        self._acc_n = 0
        self._acc_left = np.empty((0,), np.int32)
        self._acc_right = np.empty((0,), np.int32)
        self._acc_lvl = np.empty((0, H), np.int32)
        self._acc_mss = np.empty((0,), np.float32)
        self._overflow = 0
        # incremental communities
        self.similar_pairs: set = set()
        self._uf = comm.UnionFind()
        self._labels = np.empty((0,), np.int32)  # jit path fixpoint
        # compiled-program bookkeeping
        self._runner_cache: dict = {}
        self._stream_plan: StreamShardPlan | None = None
        self.score_traces = [0]   # sharded runner trace counter (the
        #                           no-per-update-recompile proof hook)
        self.runner_builds = 0
        self.updates = 0

    # -- public entry points -------------------------------------------------

    def update(self, batch: TrajectoryBatch,
               *, ttl: int | None = None) -> EngineResult:
        """Ingest one micro-batch; return the current world's result.

        ttl: updates this batch's rows stay resident for (they are
        retired at the start of the ``ttl``-th subsequent update).  The
        engine-level ``window=N`` acts as a ceiling: rows expire after
        ``min(ttl, window)`` updates when both are set.
        """
        instr = Instrumentation()
        self._xfer = {"bytes_in": 0, "pair_rows": 0, "key_rows": 0}
        places = np.asarray(batch.places, np.int32)
        if places.ndim != 2:
            places = places.reshape((places.shape[0], -1) if places.size
                                    else (0, 1))
        lengths = np.asarray(batch.lengths, np.int32).reshape(-1)
        d = places.shape[0]
        # sliding-window / TTL sweep FIRST: rows whose window closed must
        # be gone before this update's rows arrive, so an expiring row
        # never pairs with a new one — exactly the one-shot-over-the-
        # window semantics the differential harness pins
        with instr.phase("expire"):
            num_expired = self._expire_due()
        with instr.phase("keys"):
            keys_np = self._new_row_keys(places, lengths) if d else None
        # admission control BEFORE any mutation: if this update cannot fit
        # the resident-byte budget, refuse it with the world untouched
        self._admission_check(d, places.shape[1] if d else 0, keys_np)
        n_old = self.n
        with instr.phase("ingest"):
            if d:
                self._ingest(places, lengths, ttl=ttl)
        num_pruned = 0
        if self.delta_join == "device":
            with instr.phase("delta_join"):
                left_dev, right_dev, num_delta, max_delta, examined = (
                    self._device_delta_join(keys_np, n_old)
                    if d else (None, None, 0, 0, 0)
                )
            with instr.phase("score"):
                if num_delta:
                    (s_left, s_right, s_lvl, s_mss,
                     num_pruned) = self._score_device_pairs(
                        left_dev, right_dev, max_delta, num_delta)
                else:
                    s_left = s_right = np.empty((0,), np.int32)
                    s_lvl = np.empty((0, self._H), np.int32)
                    s_mss = np.empty((0,), np.float32)
                self._accumulate_scored(s_left, s_right, s_lvl, s_mss)
        else:
            with instr.phase("delta_join"):
                if d:
                    lo, hi, examined = self._index.insert(keys_np,
                                                          first_id=n_old)
                else:
                    lo = hi = np.empty((0,), np.int32)
                    examined = 0
            num_delta = int(lo.shape[0])
            if self.config.score_prune and num_delta:
                with instr.phase("prune"):
                    lo, hi, num_pruned = self._prune_delta(lo, hi)
            with instr.phase("score"):
                if lo.shape[0]:
                    s_left, s_right, s_lvl, s_mss = self._score_delta(lo, hi)
                else:
                    s_left = s_right = np.empty((0,), np.int32)
                    s_lvl = np.empty((0, self._H), np.int32)
                    s_mss = np.empty((0,), np.float32)
                self._accumulate_scored(s_left, s_right, s_lvl, s_mss)
        with instr.phase("communities"):
            edge_mask = s_mss > np.float32(self.config.rho)
            new_edges = list(zip(s_left[edge_mask].tolist(),
                                 s_right[edge_mask].tolist()))
            communities = self._fold_edges(new_edges)
        self.updates += 1
        self._examined_total += int(examined)
        instr.record(
            num_new=d, world_size=self.n, world_capacity=self._cap,
            # bounded-memory accounting: the live row count, the resident
            # device footprint, the tombstone fraction awaiting
            # compaction, and the compaction history (count + cumulative
            # stall latency) — the BENCH_stream v3 columns
            world_live=self.live_size, world_base=self._base,
            num_expired=num_expired, retired_total=self.retired_total,
            resident_bytes=self.resident_bytes(),
            dead_fraction=self.dead_fraction(),
            compactions=self.compactions,
            compact_ms_total=self.compact_ms_total,
            pairs_examined=examined, full_world_pairs=self._examined_total,
            num_delta_pairs=num_delta, num_candidates=self._acc_n,
            num_similar=len(self.similar_pairs),
            num_similar_new=len(new_edges),
            num_communities=len(communities),
            score_traces=self.score_traces[0],
            runner_builds=self.runner_builds,
            join_overflow=self._overflow,
            # driver transfer accounting: what actually crossed the
            # host->device boundary this update (the differential harness
            # asserts the device join ships no pair list and holds no
            # world-key state on the driver)
            delta_join=self.delta_join,
            driver_bytes_in=self._xfer["bytes_in"],
            driver_pair_rows=self._xfer["pair_rows"],
            driver_key_rows=self._xfer["key_rows"],
            host_index_entries=self._index.num_keys_inserted,
            # the device path's residual driver state: one COUNT per
            # distinct key (planning statistics — row ids, and therefore
            # pairs, are not reconstructible from it), vs the host
            # index's one entry per (key, row) occurrence above
            driver_mirror_keys=self._join_stats.num_keys,
            join_traces=self.join_traces[0],
        )
        if self.delta_join == "device":
            # the differential harness asserts the score buffers are sized
            # from the in-mesh post-dedup count reduction, never from the
            # join's pre-dedup emission bound
            instr.record(
                join_pair_cap=(self._join_plan.pair_cap
                               if self._join_plan else 0),
                score_pair_cap=(self._score_caps[0]
                                if self._score_caps else 0),
            )
        if self.config.score_prune:
            instr.record(num_pruned=num_pruned)
        return EngineResult(
            scored=self._scored(), similar_pairs=set(self.similar_pairs),
            communities=communities, stats=instr.finalize(),
        )

    def update_many(self, batches) -> EngineResult:
        """Ingest a sequence of micro-batches; return the final result."""
        result = None
        for batch in batches:
            result = self.update(batch)
        if result is None:
            raise ValueError("update_many needs at least one micro-batch")
        return result

    @property
    def world_size(self) -> int:
        return self.n

    @property
    def live_size(self) -> int:
        """Trajectories currently resident and alive."""
        return int(self._alive_np[: self.n - self._base].sum())

    # -- bounded memory: retirement, tombstones, compaction ------------------

    def retire(self, ids) -> int:
        """Retire trajectories by global id; returns how many were live.

        Retired rows leave the logical world immediately: they stop
        emitting candidate pairs (slab tombstones / host bucket eviction),
        their accumulated scored pairs and similarity edges are purged,
        and their communities un-merge — the engine's result equals a
        one-shot run over the surviving rows.  PHYSICAL reclamation is
        deferred: tombstones occupy their slab slots until the dead
        fraction trips ``compact_watermark`` and a compaction repacks the
        resident state.  Already-retired (or already-compacted-away) ids
        are ignored, so the call is idempotent.
        """
        req = sorted({int(i) for i in np.asarray(
            list(ids), dtype=np.int64).reshape(-1).tolist()})
        for i in req:
            if i < 0 or i >= self.n:
                raise ValueError(
                    f"cannot retire id {i}: world holds ids 0..{self.n - 1}"
                )
        base = self._base
        dead = [i for i in req
                if i >= base and self._alive_np[i - base]]
        if not dead:
            return 0
        self._retire(np.asarray(dead, np.int64))
        self._maybe_compact()
        return len(dead)

    def resident_bytes(self) -> int:
        """Bytes of device-resident world state (code/place tables +
        join slabs) — the quantity ``max_resident_bytes`` bounds and
        BENCH_stream v3 tracks."""
        total = 0
        if self._codes_dev is not None:
            total += self._codes_dev.size * 4 + self._len_dev.size * 4
        if self._places_dev is not None:
            total += self._places_dev.size * 4
        if self._slab_keys is not None:
            total += self._slab_keys.size * 4 + self._slab_rows.size * 4
        return int(total)

    def dead_fraction(self) -> float:
        """Tombstone fraction awaiting compaction (max of the row-level
        fraction and, on the device join path, the per-owner slab
        fraction — the watermark input)."""
        span = self.n - self._base
        frac = (span - self.live_size) / span if span else 0.0
        if self.delta_join == "device":
            frac = max(frac, self._join_stats.dead_fraction())
        return float(frac)

    def _resident_bytes_at(self, world_cap: int, slab_cap: int,
                           world_L: int | None = None) -> int:
        """Projected resident bytes at the given capacities (admission)."""
        L = self.L if world_L is None else world_L
        if self._mesh_world:
            world = world_cap * L * 4
        else:
            world = world_cap * self._H * L * 4 + world_cap * 4
        slab = 2 * self.plan.n_shards * slab_cap * 4 \
            if self.delta_join == "device" else 0
        return world + slab

    def _admission_check_bytes(self, projected: int, what: str) -> None:
        if self.max_resident_bytes is None:
            return
        if projected > self.max_resident_bytes:
            raise CapacityExceeded(
                f"{what} needs {projected} resident bytes, over the "
                f"max_resident_bytes budget of {self.max_resident_bytes}; "
                "the update was refused and the world is unchanged — "
                "retire rows, raise the budget, or shrink the batch",
                needed_bytes=projected,
                budget_bytes=self.max_resident_bytes,
            )

    def _admission_check(self, d: int, Lb: int, keys_np) -> None:
        """Pre-flight admission: would this update's buffer growth exceed
        ``max_resident_bytes``?  Mirrors ``_ingest``'s growth arithmetic
        and the join planner's slab sizing, and runs BEFORE any state
        mutation — a refusal leaves the world bit-identical."""
        if self.max_resident_bytes is None or not d:
            return
        new_L = max(self.L, Lb)
        span = self.n - self._base
        n_sh = self.plan.n_shards
        new_cap = self.planner.grow_capacity(
            max(self._cap, self._cap_floor), span + d
        )
        if n_sh > 1:
            new_cap = n_sh * self.planner.grow_capacity(
                1, -(-new_cap // n_sh)
            )
        slab_cap = self._slab_cap
        if self.delta_join == "device" and keys_np is not None:
            ks = np.sort(np.asarray(keys_np), axis=1)
            valid = ks != PAD_KEY
            valid[:, 1:] &= ks[:, 1:] != ks[:, :-1]
            k_flat = ks[valid].astype(np.int32)
            if k_flat.size:
                jplan = self.planner.plan_stream_join(
                    k_flat, n_sh, self._join_stats
                )
                slab_cap = max(slab_cap, jplan.slab_cap)
        self._admission_check_bytes(
            self._resident_bytes_at(new_cap, slab_cap, new_L),
            f"ingesting {d} rows",
        )

    def _expire_due(self) -> int:
        """Retire every live row whose TTL/window closed (expiry update
        <= the current update index).  Runs before ingestion, so an
        expiring row never pairs with an arriving one."""
        span = self.n - self._base
        if not span:
            return 0
        due = np.nonzero(
            self._alive_np[:span]
            & (self._expiry_np[:span] <= self.updates)
        )[0]
        if due.size == 0:
            return 0
        self._retire(due.astype(np.int64) + self._base)
        self._maybe_compact()
        return int(due.size)

    def _retire(self, dead: np.ndarray) -> None:
        """Logically delete ``dead`` (sorted global ids, all live) from
        every layer that caches world state."""
        base = self._base
        dl = (dead - base).astype(np.int64)
        self._alive_np[dl] = False
        self.retired_total += int(dead.size)
        # the rows' join keys are recomputed from the host mirror (keys
        # are a pure per-row function, so they are always recoverable)
        keys_np = self._new_row_keys(
            self._places_np[dl], self._lengths_np[dl]
        )
        if self.delta_join == "device":
            ks = np.sort(np.asarray(keys_np), axis=1)
            valid = ks != PAD_KEY
            valid[:, 1:] &= ks[:, 1:] != ks[:, :-1]
            k_flat = ks[valid].astype(np.int32)
            if k_flat.size:
                owners = _positive_hash_np(k_flat) % self.plan.n_shards
                self._join_stats.retire(k_flat, owners)
            if self._slab_keys is not None:
                # tombstone the slab in place: rows become PAD_ID, keys
                # stay (sort order and examined accounting intact).  The
                # dead list ships PAD-padded at a pow2 cap so repeats of
                # similar size reuse the compiled marker
                m_cap = self.planner.update_capacity(int(dead.size))
                buf = np.full((m_cap,), PAD_ID, np.int32)
                buf[: dead.size] = dl.astype(np.int32)
                self._xfer["bytes_in"] += buf.nbytes
                self._slab_rows = self._mark_dead_runner()(
                    self._slab_rows, jnp.asarray(buf)
                )
        else:
            self._index.retire(dead.tolist(), keys_np)
        # purge accumulated scored pairs and similarity edges touching a
        # dead row (the result contract: == one-shot over the survivors).
        # The purge writes FRESH buffers — results already returned hold
        # (possibly zero-copy) views of the old ones, and the append-only
        # discipline that kept those views valid must survive deletion
        if self._acc_n:
            left = self._acc_left[: self._acc_n]
            right = self._acc_right[: self._acc_n]
            keep = self._alive_np[left - base] & self._alive_np[right - base]
            k = int(keep.sum())
            for name in ("_acc_left", "_acc_right", "_acc_lvl", "_acc_mss"):
                old = getattr(self, name)
                fresh = old.copy()
                fresh[:k] = old[: self._acc_n][keep]
                setattr(self, name, fresh)
            self._acc_n = k
        dead_set = set(int(i) for i in dead.tolist())
        self.similar_pairs = {
            (a, b) for (a, b) in self.similar_pairs
            if a not in dead_set and b not in dead_set
        }
        self._unmerge_communities(dl)
        # serve-prune summaries: a maximum cannot be maintained under
        # deletion — recompute from the live mirror so the REPOSE bounds
        # stay sound AND tight
        span = self.n - base
        self.shard_summaries.rebuild(
            base, self._lengths_np[:span], self._alive_np[:span]
        )

    def _unmerge_communities(self, dead_local: np.ndarray) -> None:
        """Community un-merging: deletion can SPLIT a component, which no
        incremental label update discovers — re-solve only the components
        that contained a dead node, warm-starting from the survivors."""
        if self.config.community_mode == "cliques":
            return  # cliques re-derive from similar_pairs on every fold
        base = self._base
        span = self.n - base
        labels = np.arange(span, dtype=np.int32)
        labels[: min(self._labels.shape[0], span)] = \
            self._labels[: min(self._labels.shape[0], span)]
        edges_local = [(a - base, b - base) for (a, b) in self.similar_pairs]
        if self.components_impl == "unionfind":
            self._labels = comm.components_after_deletion(
                labels, dead_local.tolist(), edges_local
            )
        else:
            # the warm-started jit path: untouched components enter as
            # stars of their stale labels, touched ones dissolve to
            # singletons and re-form from the surviving edges in-device
            lab = labels.astype(np.int64)
            touched = np.unique(lab[dead_local])
            tmask = np.isin(lab, touched)
            idx = np.nonzero(tmask)[0]
            lab[idx] = idx
            tset = set(idx.tolist())
            delta = [e for e in edges_local
                     if e[0] in tset or e[1] in tset]
            cap = max(self._cap, span)
            seed = np.arange(cap, dtype=np.int32)
            seed[:span] = lab
            e_cap = self.planner.update_capacity(len(delta))
            el = np.full((e_cap,), PAD_ID, np.int32)
            er = np.full((e_cap,), PAD_ID, np.int32)
            for i, (a, b) in enumerate(delta):
                el[i], er[i] = a, b
            left = np.concatenate([seed, el])
            right = np.concatenate([np.arange(cap, dtype=np.int32), er])
            out = comm.connected_components(
                jnp.asarray(left), jnp.asarray(right), num_nodes=cap,
                init_labels=jnp.asarray(seed),
            )
            self._labels = np.asarray(out)[:span]
        self._uf.reset_from_labels(self._labels)

    def _maybe_compact(self) -> None:
        if self.dead_fraction() >= self.compact_watermark:
            self._compact()

    def _compact(self) -> None:
        """Watermark compaction: repack the resident state to the live
        window.  The world base advances past the dead prefix (a PREFIX
        rebase: global ids are stable, device programs see only local ids
        and a dynamic shift, so nothing world-shaped recompiles); the
        slabs drop every tombstone and may SHRINK — this is the one
        boundary where capacity plans are allowed to contract, so steady
        state between compactions stays recompile-free."""
        t0 = time.perf_counter()
        base = self._base
        span = self.n - base
        n_sh = self.plan.n_shards if self._mesh_world else 1
        live_idx = np.nonzero(self._alive_np[:span])[0]
        # the base stays a multiple of n_shards so round-robin owners are
        # invariant under the shift
        first = int(live_idx[0]) if live_idx.size else span
        shift = (first // n_sh) * n_sh
        if shift:
            keep = span - shift
            self._places_np[:keep] = self._places_np[shift:span]
            self._lengths_np[:keep] = self._lengths_np[shift:span]
            self._alive_np[:keep] = self._alive_np[shift:span]
            self._expiry_np[:keep] = self._expiry_np[shift:span]
            self._alive_np[keep:span] = False
            self._expiry_np[keep:span] = NEVER_EXPIRES
            sh = jnp.asarray(shift, jnp.int32)
            if self._codes_dev is not None:
                self._codes_dev, self._len_dev = self._roll_single_runner()(
                    self._codes_dev, self._len_dev, sh
                )
            if self._places_dev is not None:
                self._places_dev = self._roll_sharded_runner()(
                    self._places_dev,
                    jnp.asarray(shift // n_sh, jnp.int32),
                )
            if self._labels.shape[0] > shift:
                self._labels = self._labels[shift:] - shift
            else:
                self._labels = np.empty((0,), np.int32)
            self._uf.reset_from_labels(self._labels)
        if self.delta_join == "device":
            if self._slab_keys is not None:
                self._compact_slabs(shift)
            self._join_stats.compact()
        # capacity plans may shrink ONLY here: the next update replans
        # from the post-compaction mirror and compiles fresh programs
        self._join_plan = None
        self._score_caps = None
        self._stream_plan = None
        self._base = base + shift
        self.compactions += 1
        self.compact_ms_total += (time.perf_counter() - t0) * 1e3

    def _compact_slabs(self, shift: int) -> None:
        """Device slab compaction: stable-partition each shard's slab
        (tombstones out, survivors rebased by ``shift``), shrinking the
        per-shard capacity to the post-compaction plan."""
        n_sh = self.plan.n_shards
        live = self._join_stats.owner_entries - self._join_stats.owner_dead
        want = int(max(np.max(live), 1) * self.planner.slack) \
            if live.size else 1
        out_cap = max(4, _pow2(want))
        if self._slab_floor:
            out_cap = max(out_cap, _pow2(-(-self._slab_floor // n_sh)))
        for _ in range(self.planner.max_retries + 1):
            k2 = self._slab_keys.reshape(n_sh, self._slab_cap)
            r2 = self._slab_rows.reshape(n_sh, self._slab_cap)
            keys_o, rows_o, _, ovf = self._compact_slab_runner(
                self._slab_cap, out_cap
            )(k2, r2, jnp.asarray(shift, jnp.int32))
            if int(np.asarray(ovf).sum()) == 0:
                break
            out_cap *= 2  # mirror drift is a bug, but never commit lossily
        self._slab_keys = keys_o.reshape(-1)
        self._slab_rows = rows_o.reshape(-1)
        self._slab_cap = out_cap

    # -- cached jit helpers for the deletion path ----------------------------

    def _mark_dead_runner(self):
        import jax

        if not hasattr(self, "_mark_dead_jit"):
            self._mark_dead_jit = jax.jit(mark_dead_rows)
        return self._mark_dead_jit

    def _compact_slab_runner(self, in_cap: int, out_cap: int):
        import jax

        if not hasattr(self, "_compact_cache"):
            self._compact_cache = {}
        fn = self._compact_cache.get((in_cap, out_cap))
        if fn is None:

            @jax.jit
            def run(k2, r2, shift):
                return jax.vmap(
                    lambda kk, rr: compact_slab(kk, rr, shift,
                                                out_cap=out_cap)
                )(k2, r2)

            self._compact_cache[(in_cap, out_cap)] = fn = run
        return fn

    def _roll_single_runner(self):
        import jax

        if not hasattr(self, "_roll_single_jit"):

            @jax.jit
            def roll(codes, lens, shift):
                cap = codes.shape[0]
                idx = (jnp.arange(cap, dtype=jnp.int32) + shift) % cap
                return jnp.take(codes, idx, axis=0), jnp.take(lens, idx)

            self._roll_single_jit = roll
        return self._roll_single_jit

    def _roll_sharded_runner(self):
        import jax

        n_sh = self.plan.n_shards

        if not hasattr(self, "_roll_sharded_jit"):

            @jax.jit
            def roll(places, shift_local):
                cap, L = places.shape
                cl = cap // n_sh
                p3 = places.reshape(n_sh, cl, L)
                idx = (jnp.arange(cl, dtype=jnp.int32) + shift_local) % cl
                return jnp.take(p3, idx, axis=1).reshape(cap, L)

            self._roll_sharded_jit = roll
        return self._roll_sharded_jit

    # -- ingestion: world growth + device-resident appends -------------------

    def _ingest(self, places: np.ndarray, lengths: np.ndarray,
                *, ttl: int | None = None) -> None:
        d, Lb = places.shape
        a_cap = self.planner.update_capacity(d)
        new_L = max(self.L, Lb)
        span = self.n - self._base  # resident rows (live + tombstoned)
        needed = span + d  # append slab padding rows are drop-scattered,
        #                    so they never force a growth on their own
        n_sh = self.plan.n_shards
        new_cap = self.planner.grow_capacity(
            max(self._cap, self._cap_floor), needed
        )
        if n_sh > 1:  # keep the round-robin slabs uniform
            new_cap = n_sh * self.planner.grow_capacity(
                1, -(-new_cap // n_sh)
            )
        rebuild = (new_L != self.L) or (new_cap != self._cap)
        if rebuild:
            grown = np.full((new_cap, new_L), PAD_PLACE, np.int32)
            grown[:span, : self.L] = self._places_np[:span]
            self._places_np = grown
            glen = np.zeros((new_cap,), np.int32)
            glen[:span] = self._lengths_np[:span]
            self._lengths_np = glen
            galive = np.zeros((new_cap,), bool)
            galive[:span] = self._alive_np[:span]
            self._alive_np = galive
            gexp = np.full((new_cap,), NEVER_EXPIRES, np.int64)
            gexp[:span] = self._expiry_np[:span]
            self._expiry_np = gexp
            self.L, self._cap = new_L, new_cap
        # host mirror append; the mirrors are LOCAL-indexed (slot i holds
        # global id base + i).  Device branches below read self.n as the
        # NEW world size and n0 as the first new row's global id
        n0 = self.n
        n0l = n0 - self._base
        self._places_np[n0l : n0l + d, :Lb] = places
        self._places_np[n0l : n0l + d, Lb:] = PAD_PLACE
        self._lengths_np[n0l : n0l + d] = lengths
        self._alive_np[n0l : n0l + d] = True
        eff_ttl = ttl if self.window is None \
            else (self.window if ttl is None else min(ttl, self.window))
        self._expiry_np[n0l : n0l + d] = (
            NEVER_EXPIRES if eff_ttl is None else self.updates + eff_ttl
        )
        self.n = n0 + d
        self.shard_summaries.insert(n0, lengths)
        # device-resident append: only the new rows transfer.  Each branch
        # below counts exactly the arrays it converts to device buffers,
        # so driver_bytes_in stays an exact transfer ledger
        pad_places = np.full((a_cap, self.L), PAD_PLACE, np.int32)
        pad_places[:d, :Lb] = places
        pad_lengths = np.zeros((a_cap,), np.int32)
        pad_lengths[:d] = lengths
        if not self._mesh_world:
            if rebuild or self._codes_dev is None:
                self._codes_dev = encode_codes(
                    jnp.asarray(self._places_np), self.tables
                )
                self._len_dev = jnp.asarray(self._lengths_np)
                self._xfer["bytes_in"] += (
                    self._places_np.nbytes + self._lengths_np.nbytes
                )
            else:
                idx = np.full((a_cap,), self._cap, np.int32)  # pads drop
                idx[:d] = n0l + np.arange(d, dtype=np.int32)
                self._xfer["bytes_in"] += (
                    pad_places.nbytes + pad_lengths.nbytes + idx.nbytes
                )
                self._codes_dev, self._len_dev = self._append_single(
                    self._codes_dev, self._len_dev,
                    jnp.asarray(pad_places), jnp.asarray(pad_lengths),
                    jnp.asarray(idx),
                )
        else:
            cl = self._cap // n_sh
            if rebuild or self._places_dev is None:
                phys = np.full((self._cap, self.L), PAD_PLACE, np.int32)
                span = self.n - self._base
                g = np.arange(span, dtype=np.int64)
                # local ids preserve the global round-robin owner: base is
                # a multiple of n_shards, so g % n_sh == (g + base) % n_sh
                phys[(g % n_sh) * cl + g // n_sh] = self._places_np[:span]
                self._places_dev = jnp.asarray(phys)
                self._xfer["bytes_in"] += phys.nbytes
            else:
                g = np.arange(n0l, n0l + a_cap, dtype=np.int64)
                idx = (g % n_sh) * cl + g // n_sh
                idx[d:] = self._cap  # out of range -> dropped
                idx = idx.astype(np.int32)
                self._xfer["bytes_in"] += pad_places.nbytes + idx.nbytes
                self._places_dev = self._append_sharded(
                    self._places_dev, jnp.asarray(pad_places),
                    jnp.asarray(idx),
                )

    def _append_single(self, codes_buf, len_buf, new_places, new_lengths,
                       idx):
        import jax

        if not hasattr(self, "_append_single_jit"):
            tables = self.tables

            @jax.jit
            def append(codes_buf, len_buf, new_places, new_lengths, idx):
                new_codes = encode_codes(new_places, tables)
                codes_buf = codes_buf.at[idx].set(new_codes, mode="drop")
                len_buf = len_buf.at[idx].set(new_lengths, mode="drop")
                return codes_buf, len_buf

            self._append_single_jit = append
        return self._append_single_jit(codes_buf, len_buf, new_places,
                                       new_lengths, idx)

    def _append_sharded(self, places_dev, new_places, idx):
        import jax

        if not hasattr(self, "_append_sharded_jit"):

            @jax.jit
            def append(places_dev, new_places, idx):
                return places_dev.at[idx].set(new_places, mode="drop")

            self._append_sharded_jit = append
        return self._append_sharded_jit(places_dev, new_places, idx)

    # -- incremental candidate generation ------------------------------------

    def _new_row_keys(self, places: np.ndarray, lengths: np.ndarray):
        """Join keys of the new rows only, from the coarsest-level view.

        Every registered backend derives its keys from the type codes +
        lengths (the sharded engine's planning contract), and a row's keys
        are independent of the batch it arrives in — so keys computed once
        at arrival stay valid for the lifetime of the index.
        """
        types = encode_types(jnp.asarray(places), self.tables)
        view = EncodedBatch(codes=types[:, None, :],
                            lengths=jnp.asarray(lengths))
        mini = TrajectoryBatch(
            places=jnp.asarray(places), lengths=jnp.asarray(lengths),
            user_id=jnp.arange(places.shape[0], dtype=jnp.int32),
        )
        keys = self.backend.join_keys(view, mini, self.backend_ctx)
        if keys is None:
            raise ValueError(
                f"candidate backend {self.backend.name!r} produces no join "
                "keys; streaming ingestion requires a key-based backend"
            )
        return np.asarray(keys)

    def _prune_delta(self, lo, hi):
        """MSS upper-bound prune of the delta pairs (same f32 test as the
        one-shot pass, so the surviving pair set is identical)."""
        bsum = float(np.asarray(self.betas, np.float32).sum())
        lens = self._lengths_np
        b = self._base
        ub = mss_upper_bound(lens[lo - b], lens[hi - b], bsum)
        keep = ub > np.float32(self.config.rho - PRUNE_EPS)
        return lo[keep], hi[keep], int(lo.shape[0] - keep.sum())

    # -- delta scoring through the existing lcs_impl dispatch ----------------

    def _score_delta(self, lo, hi):
        if not self._mesh_world:
            return self._score_delta_single(lo, hi)
        return self._score_delta_sharded(lo, hi)

    def _pad_pairs(self, lo, hi, cap):
        left = np.full((cap,), PAD_ID, np.int32)
        right = np.full((cap,), PAD_ID, np.int32)
        left[: lo.shape[0]] = lo
        right[: hi.shape[0]] = hi
        return left, right

    def _score_delta_single(self, lo, hi):
        impl = self.config.lcs_impl
        p_cap = self.planner.update_capacity(lo.shape[0])
        left, right = self._pad_pairs(lo, hi, p_cap)
        # the device table is local-indexed: ship LOCAL ids (g - base) so
        # the gather hits the right slot; the returned arrays stay global
        left_l, right_l = self._pad_pairs(
            lo - self._base, hi - self._base, p_cap
        )
        # pair_rows counts the candidate pairs the driver ships (one per
        # (lo, hi) row); bytes_in counts the padded buffers that transfer
        self._xfer["pair_rows"] += int(lo.shape[0])
        self._xfer["bytes_in"] += left_l.nbytes + right_l.nbytes
        jl, jr = jnp.asarray(left_l), jnp.asarray(right_l)
        tuning = self.planner.plan_tuning(p_cap, self._H, self.L)
        if impl in _KERNEL_MODES:
            from repro.core.types import CandidatePairs

            enc = EncodedBatch(codes=self._codes_dev, lengths=self._len_dev)
            cand = CandidatePairs(
                left=jl, right=jr,
                count=jnp.asarray(lo.shape[0], jnp.int32),
                overflow=jnp.asarray(0, jnp.int32),
            )
            lvl, mss = _score_with_kernel(
                enc, cand, self.betas, mode=_KERNEL_MODES[impl],
                tuning=tuning,
            )
        else:
            from repro.perf import resolve_wavefront_dtype

            lvl, mss = score_pairs(
                self._codes_dev, self._len_dev, jl, jr, self.betas,
                impl_name=impl,
                wavefront_dtype=resolve_wavefront_dtype(tuning),
            )
        k = lo.shape[0]
        return (left[:k], right[:k], np.asarray(lvl)[:k],
                np.asarray(mss)[:k])

    def _score_delta_sharded(self, lo, hi):
        n_sh = self.plan.n_shards
        cl = self._cap // n_sh
        # plan AND ship local ids — the plan's per-destination loads must
        # be computed under the same hashes the device program applies
        lo, hi = lo - self._base, hi - self._base
        prev = self._stream_plan
        sticky = prev is not None and prev.cap_local == cl
        # pair_cap_floor: a sticky plan may hold pair_cap above this
        # update's need, which moves the chunk-slice boundaries — the
        # fresh plan must compute its per-chunk loads under the layout
        # the runner will actually use
        splan = plan_stream_capacities(
            lo, hi, n_sh, cl, score_mode=self.plan.score_mode,
            overlap_chunks=self.plan.overlap_chunks,
            pair_cap_floor=prev.pair_cap if sticky else 0,
        )
        if sticky:
            # sticky capacities: monotone max keeps the compiled runner hot
            splan = StreamShardPlan(
                n_shards=n_sh, cap_local=cl,
                pair_cap=max(splan.pair_cap, prev.pair_cap),
                hop_cap=max(splan.hop_cap, prev.hop_cap),
                out_cap=max(splan.out_cap, prev.out_cap),
                n_chunks=splan.n_chunks,
            )
            if self.plan.score_mode == "replicate":
                splan = dataclasses.replace(splan, out_cap=splan.pair_cap)
        for _ in range(self.planner.max_retries + 1):
            out = self._run_stream_runner(splan, lo, hi)
            if int(np.asarray(out["overflow"]).sum()) == 0:
                break
            splan = dataclasses.replace(
                splan, hop_cap=max(splan.hop_cap, 1) * 2,
                out_cap=splan.out_cap * 2,
            )
        self._stream_plan = splan
        self._overflow += int(np.asarray(out["overflow"]).sum())
        return self._collect_scored(out)

    def _collect_scored(self, out):
        left = np.asarray(out["left"]).reshape(-1)
        right = np.asarray(out["right"]).reshape(-1)
        mss = np.asarray(out["mss"]).reshape(-1)
        lvl = np.asarray(out["level_lcs"]).reshape(-1, self._H)
        valid = left != PAD_ID
        # device programs speak local ids; results surface as global
        left = left[valid] + self._base
        right = right[valid] + self._base
        lvl, mss = lvl[valid], mss[valid]
        # canonical order: results come back in shuffle-resting order
        order = np.lexsort((right, left))
        return left[order], right[order], lvl[order], mss[order]

    def _score_runner(self, splan, *, score_prune: bool):
        """One cached streaming score runner per (plan, mode, impl, dtype,
        world shape, prune) — shared by the host-pair and device-pair
        paths so their cache keys cannot drift apart."""
        # tuning resolves eagerly at build time (static kernel args); a
        # miss is None = untuned defaults
        tuning = self.planner.plan_tuning(splan.pair_cap, self._H, self.L)
        key = (splan, self.plan.score_mode, self.config.lcs_impl,
               wavefront_dtype_from_env(), self.L, self._H, score_prune,
               tuning)
        runner = self._runner_cache.get(key)
        if runner is None:
            runner = make_streaming_score_pipeline(
                self._eng.mesh(), splan, betas=self.betas,
                axis_name=self.plan.axis_name,
                score_mode=self.plan.score_mode,
                lcs_impl=self.config.lcs_impl,
                trace_counter=self.score_traces,
                score_prune=score_prune,
                prune_tau=self.config.rho,
                tuning=tuning,
            )
            self._runner_cache[key] = runner
            self.runner_builds += 1
        return runner

    def _run_stream_runner(self, splan, lo, hi):
        # host path: pairs were already pruned host-side, so the score
        # program never prunes
        runner = self._score_runner(splan, score_prune=False)
        n_sh, p = splan.n_shards, int(lo.shape[0])
        chunk = -(-p // n_sh) if p else 0
        left = np.full((n_sh, splan.pair_cap), PAD_ID, np.int32)
        right = np.full((n_sh, splan.pair_cap), PAD_ID, np.int32)
        for s in range(n_sh):
            sl = lo[s * chunk : (s + 1) * chunk]
            left[s, : sl.shape[0]] = sl
            sr = hi[s * chunk : (s + 1) * chunk]
            right[s, : sr.shape[0]] = sr
        self._xfer["pair_rows"] += int(lo.shape[0])
        self._xfer["bytes_in"] += left.nbytes + right.nbytes
        return runner(
            self._places_dev, jnp.asarray(left.reshape(-1)),
            jnp.asarray(right.reshape(-1)), self.tables,
        )

    # -- in-mesh incremental delta join (delta_join="device") ----------------

    def _device_delta_join(self, keys_np, n_old: int):
        """Ship ONLY the new rows' key occurrences into the in-mesh join.

        The resident bucket state (key-sharded sorted slabs) is probed and
        merged on-device; the deduped delta pairs come to rest in-mesh as
        ``[n_shards, pair_cap]`` buffers that feed the score program
        directly.  Returns ``(left_dev, right_dev, num_delta, max_delta,
        examined)`` where ``max_delta`` is the in-mesh pmax of the
        per-shard post-dedup counts — the tight score-buffer bound.

        State is committed functionally: the join program RETURNS the
        merged slabs, and the engine adopts them (and folds the update
        into the planning-count mirror) only after a run with zero
        overflow — so the overflow-retry loop replans and re-runs from
        unchanged state.
        """
        keys_np = np.asarray(keys_np)
        # per-row key SET (vectorized: sort each row, drop PAD and
        # adjacent duplicates), matching BucketIndex.insert's defensive
        # dedup, so the examined count stays the exact per-bucket C(n, 2)
        # partition
        ks = np.sort(keys_np, axis=1)
        valid = ks != PAD_KEY
        valid[:, 1:] &= ks[:, 1:] != ks[:, :-1]
        row_idx, col_idx = np.nonzero(valid)
        k_flat = ks[row_idx, col_idx].astype(np.int32)
        if k_flat.size == 0:
            return None, None, 0, 0, 0
        n_sh = self.plan.n_shards
        fresh = self.planner.plan_stream_join(k_flat, n_sh,
                                              self._join_stats)
        if _fault_inject():
            # derate every stage of the FRESH plan (sticky maxima still
            # apply) so the overflow -> compact -> retry path runs
            fresh = dataclasses.replace(
                fresh,
                key_route_cap=_derate_cap(fresh.key_route_cap),
                nn_cap=_derate_cap(fresh.nn_cap),
                no_cap=_derate_cap(fresh.no_cap),
                pair_route_cap=_derate_cap(fresh.pair_route_cap),
                pair_cap=_derate_cap(fresh.pair_cap),
            )
        jplan = sticky_join_plan(fresh, self._join_plan)
        if self._slab_cap > jplan.slab_cap:
            # the resident arrays only shrink at a compaction boundary
            # (_compact rebuilds them); between boundaries the plan must
            # match their actual allocation
            jplan = dataclasses.replace(jplan, slab_cap=self._slab_cap)
        if self._slab_floor:
            floor = _pow2(-(-self._slab_floor // n_sh))
            if floor > jplan.slab_cap:
                jplan = dataclasses.replace(jplan, slab_cap=floor)
        out = None
        retries = self.planner.max_retries + (4 if _fault_inject() else 0)
        compacted = False
        for _ in range(retries + 1):
            self._ensure_slab(jplan.slab_cap)
            # local row ids (recomputed per attempt: a mid-loop compaction
            # moves the base under us)
            r_flat = (n_old - self._base + row_idx).astype(np.int32)
            chunk = -(-k_flat.shape[0] // n_sh)
            in_k = np.full((n_sh, jplan.key_in_cap), PAD_KEY, np.int32)
            in_r = np.full((n_sh, jplan.key_in_cap), PAD_ID, np.int32)
            for s in range(n_sh):
                seg = slice(s * chunk, (s + 1) * chunk)
                in_k[s, : k_flat[seg].shape[0]] = k_flat[seg]
                in_r[s, : r_flat[seg].shape[0]] = r_flat[seg]
            # key_rows counts the (key, row-id) occurrences the driver
            # ships (one per valid tuple); bytes_in the padded buffers
            self._xfer["key_rows"] += int(k_flat.shape[0])
            self._xfer["bytes_in"] += in_k.nbytes + in_r.nbytes
            out = self._join_runner(jplan)(
                self._slab_keys, self._slab_rows,
                jnp.asarray(in_k.reshape(-1)), jnp.asarray(in_r.reshape(-1)),
            )
            ovf = np.asarray(out["overflow"]).sum(axis=0)
            if int(ovf.sum()) == 0:
                break
            if int(ovf[2]) and not compacted \
                    and int(self._join_stats.owner_dead.sum()):
                # slab overflow with tombstones resident: reclaim the dead
                # slots FIRST and retry at the (possibly smaller) post-
                # compaction plan — growth is the last resort, not the
                # first response to a slab that is mostly tombstones
                self._compact()
                compacted = True
                jplan = self.planner.plan_stream_join(
                    k_flat, n_sh, self._join_stats
                )
                if self._slab_cap > jplan.slab_cap:
                    jplan = dataclasses.replace(
                        jplan, slab_cap=self._slab_cap
                    )
                continue
            # exact planning makes steady-state overflow impossible; this
            # belt-and-braces path doubles whatever stage busted
            jplan = dataclasses.replace(
                jplan,
                key_route_cap=jplan.key_route_cap * 2,
                nn_cap=jplan.nn_cap * 2, no_cap=jplan.no_cap * 2,
                pair_route_cap=jplan.pair_route_cap * 2,
                pair_cap=jplan.pair_cap * 2,
                slab_cap=jplan.slab_cap * (2 if int(ovf[2]) else 1),
            )
            self._admission_check_bytes(
                self._resident_bytes_at(self._cap, jplan.slab_cap),
                "in-mesh delta join retry doubling",
            )
        if int(np.asarray(out["overflow"]).sum()):
            # never adopt a slab whose merge dropped entries: committing it
            # would silently lose every future pair involving the dropped
            # rows.  Exact planning makes this unreachable; reaching it
            # means the planning invariant broke, so fail loudly.
            raise CapacityExceeded(
                "in-mesh delta join still overflowed after "
                f"{retries} retries (per-shard overflow "
                f"{np.asarray(out['overflow']).tolist()}); refusing to "
                "commit a lossy bucket state"
            )
        self._slab_keys = out["slab_keys"]
        self._slab_rows = out["slab_rows"]
        self._join_stats.commit(k_flat, _positive_hash_np(k_flat) % n_sh)
        self._join_plan = jplan
        num_delta = int(np.asarray(out["count"]).sum())
        max_delta = int(np.asarray(out["max_count"])[0])
        examined = int(np.asarray(out["examined"]).sum())
        return out["left"], out["right"], num_delta, max_delta, examined

    def _ensure_slab(self, slab_cap: int) -> None:
        """Allocate or regrow the resident slabs to ``slab_cap`` per shard.

        Regrowth pads each shard's segment at the END (valid entries stay
        compacted at the front, PAD_KEY sorts last) entirely on-device —
        the resident keys never round-trip through the host.
        """
        n_sh = self.plan.n_shards
        if self._slab_keys is None:
            self._slab_cap = slab_cap
            self._slab_keys = jnp.full((n_sh * slab_cap,), PAD_KEY, jnp.int32)
            self._slab_rows = jnp.full((n_sh * slab_cap,), PAD_ID, jnp.int32)
        elif slab_cap > self._slab_cap:
            pad = ((0, 0), (0, slab_cap - self._slab_cap))
            k = self._slab_keys.reshape(n_sh, self._slab_cap)
            r = self._slab_rows.reshape(n_sh, self._slab_cap)
            self._slab_keys = jnp.pad(
                k, pad, constant_values=PAD_KEY).reshape(-1)
            self._slab_rows = jnp.pad(
                r, pad, constant_values=PAD_ID).reshape(-1)
            self._slab_cap = slab_cap

    def _join_runner(self, jplan):
        runner = self._join_runner_cache.get(jplan)
        if runner is None:
            runner = make_streaming_join_pipeline(
                self._eng.mesh(), jplan, axis_name=self.plan.axis_name,
                trace_counter=self.join_traces,
            )
            self._join_runner_cache[jplan] = runner
            self.runner_builds += 1
        return runner

    def _score_device_pairs(self, left_dev, right_dev, max_delta,
                            num_delta):
        """Score the in-mesh delta pairs straight off their device buffers.

        The pairs rest on their pair-hash shard; "replicate" scores them
        in place against the all_gathered in-mesh encodings, "shuffle"
        runs the shared owner hops.  ``score_prune`` is applied IN-MESH by
        the score program (the pairs never visit the host to be pruned
        there).

        The score buffers are sized from the join's in-mesh count
        reduction, NOT from the join plan's pre-dedup emission bound:
        dedup compacts every shard's valid pairs to the front, so the
        resting ``[n_shards, join_pair_cap]`` buffers slice down to
        ``pow2(max_delta)`` columns exactly (replicate scores in place,
        bounded per shard by ``max_delta``; the shuffle hops and resting
        buffers are bounded by the GLOBAL post-dedup count ``num_delta``,
        since a redistribution can pile every pair onto one owner).  Both
        caps are sticky (monotone max) so they inherit the join plan's
        zero-steady-state-recompile property.
        """
        n_sh = self.plan.n_shards
        join_cap = int(left_dev.shape[-1])
        pair_cap = min(_pow2(max_delta), join_cap)
        rest_cap = min(_pow2(num_delta), join_cap)
        if self._score_caps is not None:
            pair_cap = min(max(pair_cap, self._score_caps[0]), join_cap)
            rest_cap = min(max(rest_cap, self._score_caps[1]), join_cap)
        self._score_caps = (pair_cap, rest_cap)
        if pair_cap < join_cap:
            left_dev = left_dev[:, :pair_cap]
            right_dev = right_dev[:, :pair_cap]
        shuffle = self.plan.score_mode == "shuffle"
        splan = StreamShardPlan(
            n_shards=n_sh, cap_local=self._cap // n_sh, pair_cap=pair_cap,
            hop_cap=rest_cap if shuffle else 0,
            out_cap=rest_cap if shuffle else pair_cap,
        )
        for _ in range(self.planner.max_retries + 1):
            out = self._run_device_score(splan, left_dev, right_dev)
            if int(np.asarray(out["overflow"]).sum()) == 0:
                break
            splan = dataclasses.replace(
                splan, hop_cap=max(splan.hop_cap, 1) * 2,
                out_cap=splan.out_cap * 2,
            )
        self._overflow += int(np.asarray(out["overflow"]).sum())
        num_pruned = int(np.asarray(out["pruned"]).sum())
        return (*self._collect_scored(out), num_pruned)

    def _run_device_score(self, splan, left_dev, right_dev):
        # device path: pruning (if configured) runs IN-MESH — the pairs
        # are not on the host to be pruned there
        runner = self._score_runner(splan,
                                    score_prune=self.config.score_prune)
        return runner(self._places_dev, left_dev.reshape(-1),
                      right_dev.reshape(-1), self.tables)

    # -- accumulation + incremental communities ------------------------------

    def _accumulate_scored(self, left, right, lvl, mss):
        k = left.shape[0]
        if self._acc_n + k > self._acc_cap:
            cap = self.planner.grow_capacity(
                max(self._acc_cap, 16), self._acc_n + k
            )
            for name in ("_acc_left", "_acc_right", "_acc_lvl", "_acc_mss"):
                old = getattr(self, name)
                shape = (cap,) + old.shape[1:]
                grown = np.full(shape, PAD_ID, old.dtype) \
                    if old.dtype == np.int32 and old.ndim == 1 \
                    else np.zeros(shape, old.dtype)
                grown[: self._acc_n] = old[: self._acc_n]
                setattr(self, name, grown)
            self._acc_cap = cap
        s = slice(self._acc_n, self._acc_n + k)
        self._acc_left[s] = left
        self._acc_right[s] = right
        self._acc_lvl[s] = lvl
        self._acc_mss[s] = mss
        self._acc_n += k

    def _scored(self) -> ScoredPairs:
        n = self._acc_n
        return ScoredPairs(
            left=jnp.asarray(self._acc_left[:n]),
            right=jnp.asarray(self._acc_right[:n]),
            level_lcs=jnp.asarray(self._acc_lvl[:n]),
            mss=jnp.asarray(self._acc_mss[:n]),
            count=jnp.asarray(n, jnp.int32),
            overflow=jnp.asarray(self._overflow, jnp.int32),
        )

    def _fold_edges(self, new_edges) -> set:
        self.similar_pairs.update(
            (int(a), int(b)) for a, b in new_edges
        )
        # the union-find / label state lives in LOCAL index space (node i
        # = global id base + i) so compaction can slide it with the world
        base = self._base
        self._uf.add(self.n - base - self._uf.num_nodes)
        for a, b in new_edges:
            self._uf.union(int(a) - base, int(b) - base)
        mode = self.config.community_mode
        if mode == "cliques":
            return comm.maximal_cliques(self.similar_pairs)
        if mode != "components":
            raise ValueError(
                f"unknown community_mode {mode!r}; valid modes: "
                "['cliques', 'components']"
            )
        if self.components_impl == "unionfind":
            self._labels = self._uf.labels()
            return self._sets_to_global(
                comm.components_as_sets(self._labels)
            )
        return self._jit_components(new_edges)

    def _sets_to_global(self, sets: set) -> set:
        """Translate local-index community sets to global trajectory ids."""
        base = self._base
        if not base:
            return sets
        return {frozenset(i + base for i in s) for s in sets}

    def _jit_components(self, new_edges) -> set:
        """Resumable min-label propagation: the previous fixpoint becomes
        star edges ``(label[v], v)`` — each old component collapses to a
        star — so only the DELTA edges (plus the stars) run through
        :func:`connected_components`, seeded with the stale labels.  Shapes
        are padded to the world capacity / a power-of-two edge cap so
        steady-state updates reuse the compiled program.
        """
        if self.n <= self._base:
            return set()
        base = self._base
        cap = self._cap
        seed = np.arange(cap, dtype=np.int32)
        seed[: self._labels.shape[0]] = self._labels
        e_cap = self.planner.update_capacity(len(new_edges))
        el = np.full((e_cap,), PAD_ID, np.int32)
        er = np.full((e_cap,), PAD_ID, np.int32)
        for i, (a, b) in enumerate(new_edges):
            el[i], er[i] = a - base, b - base
        left = np.concatenate([seed, el])
        right = np.concatenate([np.arange(cap, dtype=np.int32), er])
        labels = comm.connected_components(
            jnp.asarray(left), jnp.asarray(right), num_nodes=cap,
            init_labels=jnp.asarray(seed),
        )
        self._labels = np.asarray(labels)[: self.n - base]
        return self._sets_to_global(comm.components_as_sets(self._labels))
