"""Sharded AnotherMe: the Spark shuffle mapped onto shard_map collectives.

Every Spark stage of the paper's Fig. 5 has a direct analogue here:

  Spark executors            -> devices on a flat "ex" mesh axis
  semantic encoding (D2->D3) -> in-mesh gather through the replicated
                                forest tables: each shard encodes its OWN
                                rows, so the [N, n_levels, L] code table
                                never materializes on the host
  hash-shuffle on shingle    -> lax.all_to_all of fixed-capacity buckets
    (D4 repartition)            routed by hash(join key) % n_shards
  local sort-merge join      -> ssh.pairs_from_rows on received rows
  shuffle pairs for dedup    -> second all_to_all routed by hash(lo, hi)
    ("score each pair once")    so every pair lands on exactly ONE shard;
                                the local dedup is then globally exact
  executor-local scoring     -> batched LCS on local pairs, through the
                                same ``lcs_impl`` selection as the
                                single-device path (wavefront / ref /
                                Pallas kernel)

What the redesign adds over the original ``core/distributed.py``: the join
key construction is pluggable.  ``key_fn`` (from a registry backend's
``shard_key_fn``) builds keys on-device per shard — shingles for "ssh",
band signatures for "minhash", bucket projections for "brp" — always from
the shard's in-mesh encoded codes.  With ``key_fn=None`` the keys are
precomputed host-side and shuffled in as a sharded input (the "udf"
backend's driver-side wall).  Everything after the keys — route, join,
dedup, score — is one shared implementation.

Static capacities (rows per destination bucket, pairs per shard) are planned
host-side from exact cardinalities (plan_capacities) using the *same* int32
hashes the device program applies, and every stage carries an overflow
counter, so a capacity bust is detected, never silent.

The same code runs on 1 device (n_shards=1 degenerates to the single-device
pipeline) and on the 512-device production mesh in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compat
from repro.core.encoding import encode_codes
from repro.core.shingling import shingles_from_types
from repro.core.similarity import (
    PRUNE_EPS, mss_scores, mss_upper_bound, multi_level_lcs,
)
from repro.core.ssh import _runs, dedup_pairs, pairs_from_rows
from repro.core.types import PAD_ID, PAD_KEY

_MIX = np.int32(np.uint32(2654435761 % (1 << 31)))  # Knuth multiplicative mix


def _positive_hash(x: jnp.ndarray) -> jnp.ndarray:
    h = (x * _MIX) ^ (x >> 13)
    return jnp.abs(h)


def _pair_hash(lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    return jnp.abs(_positive_hash(lo) * np.int32(92821) + _positive_hash(hi))


def _positive_hash_np(x: np.ndarray) -> np.ndarray:
    """Host replica of :func:`_positive_hash` with exact int32 wraparound, so
    capacity planning sees the same shard destinations as the device."""
    x = np.asarray(x).astype(np.int32)
    with np.errstate(over="ignore"):
        h = (x * _MIX) ^ (x >> 13)
    return np.abs(h)


def _pair_hash_np(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):
        h = _positive_hash_np(lo) * np.int32(92821) + _positive_hash_np(hi)
    return np.abs(h)


def _route(
    values: tuple, dest: jnp.ndarray, valid: jnp.ndarray, *, n_shards: int,
    capacity: int, pads: tuple, axis_name: str,
):
    """Scatter rows into [n_shards, capacity] buckets and all_to_all them.

    values: tuple of int32 [R] or [R, W] arrays routed together (rows travel
    with their payload columns); pads: per-array pad value.
    Returns (tuple of [n_shards * capacity(, W)] received rows, overflow).
    """
    dest = jnp.where(valid, dest, n_shards)  # n_shards = drop bucket
    order = jnp.argsort(dest, stable=True)
    dest_s = dest[order]
    rank, _ = _runs(jnp.where(dest_s == n_shards, PAD_KEY, dest_s))
    ok = (dest_s < n_shards) & (rank < capacity)
    slot = jnp.where(ok, dest_s * capacity + rank, n_shards * capacity)
    overflow = jnp.sum((dest_s < n_shards) & (rank >= capacity))
    outs = []
    for v, pad in zip(values, pads):
        width = v.shape[1:] if v.ndim > 1 else ()
        buf = jnp.full((n_shards * capacity,) + width, pad, dtype=v.dtype)
        buf = buf.at[slot].set(v[order], mode="drop")
        buf = buf.reshape((n_shards, capacity) + width)
        recv = jax.lax.all_to_all(
            buf, axis_name, split_axis=0, concat_axis=0, tiled=True
        )
        outs.append(recv.reshape((n_shards * capacity,) + width))
    return tuple(outs), overflow


def _prune_keep(len_l, len_r, betas, prune_tau, valid):
    """The one float32 MSS upper-bound prune test.

    Every prune site — the one-shot in-mesh pass, the streaming replicate
    and shuffle branches, and (via the same ``mss_upper_bound`` +
    ``PRUNE_EPS`` discipline) the host-side ``_prune_delta`` — must agree
    bit-exactly on which pairs survive, so the bound is defined once.
    """
    ub = mss_upper_bound(len_l, len_r, jnp.sum(betas))
    return valid & (ub > prune_tau - PRUNE_EPS)


def _fit(x: jnp.ndarray, cap: int, pad_val) -> jnp.ndarray:
    """Pad or truncate the leading axis of ``x`` to exactly ``cap`` rows.

    Truncation is only safe on buffers whose valid rows are already
    compacted to the front (dedup / argsort upstream); callers surface the
    excess through an overflow counter.
    """
    m = x.shape[0]
    if m >= cap:
        return x[:cap]
    padw = [(0, cap - m)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, padw, constant_values=pad_val)


def _hop_gather_codes(
    left, right, codes_local, *, owner_of, slot_of, n_shards, axis_name,
    hop_cap, out_cap,
):
    """Two-hop pair/code shuffle shared by the one-shot and streaming paths.

    Route pairs to owner(left), attach that shard's code rows, then to
    owner(right), attach, and come to rest wherever owner(right) is (the
    pairs are already globally deduped upstream).  Ownership is pluggable:
    the one-shot pipeline owns rows in blocks (``g // local_n``), the
    streaming world round-robins them (``g % n_shards``) so growth stays
    balanced; ``slot_of`` maps a global id to the owner's local row.
    Received rows sit scattered across per-source buckets, so valid rows are
    compacted to the front before the fit to ``out_cap`` — a plain
    truncation could drop valid pairs while keeping padding.  Returns
    (left, right, left_codes, right_codes, overflow).
    """
    H, L = codes_local.shape[1], codes_local.shape[2]
    local_n = codes_local.shape[0]
    # hop 1: to owner(left)
    (l1, r1), o1 = _route(
        (left, right), owner_of(left), left != PAD_ID,
        n_shards=n_shards, capacity=hop_cap, pads=(PAD_ID, PAD_ID),
        axis_name=axis_name,
    )
    safe = slot_of(jnp.where(l1 == PAD_ID, 0, l1))
    cl = codes_local[jnp.clip(safe, 0, local_n - 1)].reshape(
        l1.shape[0], H * L
    )
    # hop 2: to owner(right), payload = left codes
    (l2, r2, cl2), o2 = _route(
        (l1, r1, cl), owner_of(r1), l1 != PAD_ID,
        n_shards=n_shards, capacity=hop_cap,
        pads=(PAD_ID, PAD_ID, 0), axis_name=axis_name,
    )
    safe_r = slot_of(jnp.where(r2 == PAD_ID, 0, r2))
    cr = codes_local[jnp.clip(safe_r, 0, local_n - 1)]
    cl_rows = cl2.reshape(l2.shape[0], H, L)
    order = jnp.argsort(l2 == PAD_ID, stable=True)
    l2, r2 = l2[order], r2[order]
    cl_rows, cr = cl_rows[order], cr[order]
    n_valid = jnp.sum(l2 != PAD_ID).astype(jnp.int32)
    ovf_fit = jnp.maximum(n_valid - out_cap, 0)
    return (_fit(l2, out_cap, PAD_ID), _fit(r2, out_cap, PAD_ID),
            _fit(cl_rows, out_cap, 0), _fit(cr, out_cap, 0),
            o1 + o2 + ovf_fit)


@dataclasses.dataclass(frozen=True)
class DistributedPlan:
    n_shards: int
    local_n: int          # trajectories per shard
    shingle_route_cap: int  # rows per (src, dst) bucket in shuffle 1
    local_pair_cap: int     # pre-dedup pairs per shard after local join
    pair_route_cap: int     # rows per (src, dst) bucket in shuffle 2
    scored_cap: int         # deduped pairs per shard
    owner_route_cap: int = 0  # rows per (src, dst) bucket in the shuffle-mode
    #                           owner hops; 0 -> uniform fallback
    pruned_cap: int = 0     # post-prune pairs per shard when the MSS
    #                         upper-bound pruning pass runs; 0 -> scored_cap
    n_chunks: int = 1       # shuffle-mode overlap: split the pair buffer
    #                         into this many chunks so chunk i+1's owner
    #                         hops run while chunk i scores; 1 -> the
    #                         original single-pass gather-then-score
    chunk_hop_cap: int = 0  # rows per (src, dst) bucket in ONE chunk's
    #                         owner hops; 0 -> uniform fallback
    chunk_rest_cap: int = 0  # resting pairs per shard for ONE chunk;
    #                          0 -> uniform fallback


def plan_capacities(
    keys_np: np.ndarray,
    n_shards: int,
    *,
    slack: float = 1.3,
    quiet: bool = True,
    score_mode: str = "replicate",
    exact_pair_limit: int = 5_000_000,
    lengths_np: np.ndarray | None = None,
    prune_tau: float | None = None,
    betas_sum: float = 1.0,
    overlap_chunks: int = 1,
    windows_per_row: int = 1,
) -> DistributedPlan:
    """Host-side exact capacity planning from the actual join keys.

    Mirrors what a Spark driver learns from partition statistics; keeps every
    device buffer tight instead of worst-case.  Works for any backend's keys
    (shingles, minhash bands, brp buckets): only PAD_KEY rows are excluded.

    All shard destinations are computed with the device's own int32 hashes
    (:func:`_positive_hash_np` / :func:`_pair_hash_np`), so per-bucket loads
    are exact even for adversarially skewed key distributions — including
    the pair-dedup shuffle and, with ``score_mode="shuffle"``, the per-owner
    loads of the two code-gather hops (ROADMAP "shuffle 1"-style planning
    for every stage).  Above ``exact_pair_limit`` pre-dedup pairs the pair
    list is not materialized and the uniform-hash bound takes over (the
    overflow counters + retry doubling still catch any bust).

    With ``prune_tau`` and ``lengths_np`` set, the plan also sizes
    ``pruned_cap`` — the post-prune pair buffer — from the exact per-shard
    survivor counts of the MSS upper-bound pruning pass
    (``betas_sum * min(len_a, len_b) > tau``), using the same float32 bound
    the device applies.  In ``score_mode="shuffle"`` pruning happens BEFORE
    the owner hops, so the hop buckets and the resting buffer are sized
    from survivors only.

    ``overlap_chunks > 1`` (shuffle mode only) additionally sizes the
    per-chunk hop/resting buffers for the overlapped gather: the pre-hop
    pair buffer is split into that many contiguous slices, and because the
    device buffer layout is DETERMINISTIC — ``dedup_pairs`` sorts by
    (lo, hi) with PAD at the end, and the prune compaction preserves that
    order — the planner can replay exactly which pairs land in which chunk
    slice and size ``chunk_hop_cap`` / ``chunk_rest_cap`` from the actual
    per-(chunk, owner) loads, keeping the overflow accounting exact under
    chunking too.

    ``windows_per_row > 1`` declares subtrajectory keys: ``keys_np`` has one
    row PER WINDOW (``n = n_traj * nw``, window id ``t * nw + j``), while
    shards own whole TRAJECTORIES.  ``local_n`` stays in trajectory units
    and every ownership computation maps a window id to its trajectory
    first (``id // nw``); per-window loads (shuffle 1, the join, the dedup
    shuffle) are still counted exactly per window row.  ``lengths_np``, when
    given, must then be per-WINDOW lengths ``[n_traj * nw]`` so the prune
    replay indexes it with window ids directly.
    """
    n, s = keys_np.shape
    nw = windows_per_row
    local_n = int(np.ceil((n // nw) / n_shards))
    keys_flat = keys_np.reshape(-1)
    ids_flat = np.repeat(np.arange(n, dtype=np.int64), s)
    valid = keys_flat != PAD_KEY
    kf, idf = keys_flat[valid], ids_flat[valid]
    # shuffle 1 loads: rows from one src shard to one dst shard (a window
    # row lives on the shard owning its trajectory)
    src = (idf // nw) // local_n
    dst = _positive_hash_np(kf) % n_shards
    load1 = np.zeros((n_shards, n_shards), np.int64)
    np.add.at(load1, (src, dst), 1)
    cap1 = int(np.ceil(load1.max() * slack)) + 8

    # local join size per dst shard: sum over keys of rank contributions
    order = np.lexsort((idf, kf))
    kf_s, idf_s = kf[order], idf[order]
    dst_s = dst[order]
    run_start = np.ones(kf_s.shape, bool)
    run_start[1:] = kf_s[1:] != kf_s[:-1]
    idx = np.arange(kf_s.shape[0])
    starts = np.maximum.accumulate(np.where(run_start, idx, 0))
    ranks = idx - starts
    pair_count = np.zeros(n_shards, np.int64)
    np.add.at(pair_count, dst_s, ranks)
    cap2 = int(np.ceil(max(pair_count.max(), 1) * slack)) + 64

    total_pairs = int(ranks.sum())
    owner_cap = 0
    pruned_cap = 0
    chunk_hop = chunk_rest = 0
    if total_pairs <= exact_pair_limit:
        # materialize the pre-dedup pair list host-side (the driver's
        # statistics pass): element at sorted position p with in-run rank r
        # pairs with the r earlier members of its key run
        rows = np.repeat(idx, ranks)
        excl = np.cumsum(ranks) - ranks
        t = np.arange(rows.shape[0], dtype=np.int64) - np.repeat(excl, ranks)
        partners = rows - np.repeat(ranks, ranks) + t
        a_ids, b_ids = idf_s[rows], idf_s[partners]
        lo = np.minimum(a_ids, b_ids).astype(np.int32)
        hi = np.maximum(a_ids, b_ids).astype(np.int32)
        # shuffle 2 loads: pairs travel from their join shard to their
        # pair-hash dedup shard (self-pairs still occupy route slots)
        src2 = dst_s[rows]
        dst2 = _pair_hash_np(lo, hi) % n_shards
        load2 = np.zeros((n_shards, n_shards), np.int64)
        np.add.at(load2, (src2, dst2), 1)
        cap3 = int(np.ceil(max(load2.max(), 1) * slack)) + 64
        # deduped pairs per dedup shard (exact scored_cap)
        keep = lo != hi
        uniq = np.unique(
            (lo[keep].astype(np.int64) << 32) | hi[keep].astype(np.int64)
        )
        ulo = (uniq >> 32).astype(np.int32)
        uhi = (uniq & 0xFFFFFFFF).astype(np.int32)
        ded_dst = _pair_hash_np(ulo, uhi) % n_shards
        scored_need = int(np.bincount(ded_dst, minlength=n_shards).max()) \
            if uniq.size else 1
        prune = prune_tau is not None and lengths_np is not None
        if prune and uniq.size:
            # survivors of the MSS upper-bound prune, same f32 test as the
            # device pass; pruning runs after the dedup fit, so scored_cap
            # keeps its pre-prune sizing and pruned_cap sizes what is left
            ub = mss_upper_bound(lengths_np[ulo], lengths_np[uhi], betas_sum)
            surv = ub > np.float32(prune_tau - PRUNE_EPS)
        else:
            surv = np.ones(ulo.shape, bool)
        if score_mode == "shuffle":
            # per-owner loads of the code-gather hops: dedup shard ->
            # owner(left) -> owner(right); pairs come to rest on
            # owner(right).  Pruning happens before the hops, so with it on
            # only survivors travel — hop buckets and the resting buffer
            # are sized from the survivor subset.
            own_lo = ((ulo // nw) // local_n)[surv]
            own_hi = ((uhi // nw) // local_n)[surv]
            h1 = np.zeros((n_shards, n_shards), np.int64)
            np.add.at(h1, (ded_dst[surv], own_lo), 1)
            h2 = np.zeros((n_shards, n_shards), np.int64)
            np.add.at(h2, (own_lo, own_hi), 1)
            owner_cap = int(np.ceil(max(h1.max(), h2.max(), 1) * slack)) + 64
            rest_need = int(np.bincount(own_hi, minlength=n_shards).max()) \
                if own_hi.size else 1
            if prune:
                # the post-prune buffer first holds survivors compacted AT
                # the dedup shard (before the hops), then the resting
                # loads at owner(right) — size for both skews
                surv_need = int(
                    np.bincount(ded_dst[surv], minlength=n_shards).max()
                ) if surv.any() else 1
                pruned_cap = int(
                    np.ceil(max(surv_need, rest_need, 1) * slack)
                ) + 64
            else:
                scored_need = max(scored_need, rest_need)
        elif prune:
            surv_need = int(
                np.bincount(ded_dst[surv], minlength=n_shards).max()
            ) if surv.any() else 1
            pruned_cap = int(np.ceil(max(surv_need, 1) * slack)) + 64
        cap4 = int(np.ceil(max(scored_need, 1) * slack)) + 64
        if score_mode == "shuffle" and overlap_chunks > 1:
            # chunked-overlap planning: replay the deterministic device
            # buffer layout — dedup_pairs sorts by (lo, hi) with PAD at the
            # end (np.unique gives the same global order here) and the
            # prune compaction preserves it — to find which surviving pair
            # occupies which chunk slice of which shard's buffer, then size
            # ONE chunk's hop buckets / resting buffer from the worst chunk
            if prune:
                pruned_cap += (-pruned_cap) % overlap_chunks
                pre_cap = pruned_cap
            else:
                cap4 += (-cap4) % overlap_chunks
                pre_cap = cap4
            sub = pre_cap // overlap_chunks
            sel = np.nonzero(surv)[0]
            d_sel = ded_dst[sel]
            rank = np.zeros(sel.shape[0], np.int64)
            for s in range(n_shards):
                m = d_sel == s
                rank[m] = np.arange(int(m.sum()))
            chunk_of = np.minimum(rank // sub, overlap_chunks - 1)
            olo = (ulo[sel] // nw) // local_n
            ohi = (uhi[sel] // nw) // local_n
            ch1 = np.zeros((overlap_chunks, n_shards, n_shards), np.int64)
            np.add.at(ch1, (chunk_of, d_sel, olo), 1)
            ch2 = np.zeros((overlap_chunks, n_shards, n_shards), np.int64)
            np.add.at(ch2, (chunk_of, olo, ohi), 1)
            crest = np.zeros((overlap_chunks, n_shards), np.int64)
            np.add.at(crest, (chunk_of, ohi), 1)
            chunk_hop = int(np.ceil(max(ch1.max(), ch2.max(), 1) * slack)) + 64
            chunk_rest = int(np.ceil(max(crest.max(), 1) * slack)) + 64
    else:
        # uniform-hash bound with extra slack (skew caught by overflow+retry)
        cap3 = int(
            np.ceil(max(total_pairs / (n_shards * n_shards), 1) * slack * 2)
        ) + 64
        cap4 = int(np.ceil(max(total_pairs / n_shards, 1) * slack * 2)) + 64
        if score_mode == "shuffle" and overlap_chunks > 1:
            cap4 += (-cap4) % overlap_chunks  # device needs even chunk slices
    return DistributedPlan(
        n_shards=n_shards, local_n=local_n, shingle_route_cap=cap1,
        local_pair_cap=cap2, pair_route_cap=cap3, scored_cap=cap4,
        owner_route_cap=owner_cap, pruned_cap=pruned_cap,
        n_chunks=overlap_chunks if score_mode == "shuffle" else 1,
        chunk_hop_cap=chunk_hop, chunk_rest_cap=chunk_rest,
    )


def make_sharded_pipeline(
    mesh: jax.sharding.Mesh,
    plan: DistributedPlan,
    *,
    betas: jnp.ndarray,
    key_fn: Callable | None,
    axis_name: str = "ex",
    score_mode: str = "replicate",
    lcs_impl: str = "wavefront",
    score_prune: bool = False,
    prune_tau: float = 0.0,
    tuning=None,
    subtraj: tuple[int, int, int] | None = None,
):
    """Build the jitted shard_map encode+join+score pipeline.

    key_fn: jax-traceable ``(local_type_codes [n, L], local_lengths [n]) ->
      keys [n, S]`` run per shard (a backend's ``shard_key_fn``) on the
      shard's in-mesh encoded codes, or None, in which case the first input
      of the returned fn carries precomputed keys instead of places.

    Call signature of the returned fn:
      fn(first, places [N, L] int32, lengths [N] int32,
         tables [n_levels, num_places] int32)
        -> dict of per-shard stacked outputs:
           left/right [n, scored_cap], level_lcs [n, scored_cap, H],
           mss [n, scored_cap], overflow [n, 3]

      first: with a key_fn, unused (pass places again); without, [N, S]
      keys precomputed host-side and shuffled in (the "udf" driver wall).

    Encoding runs INSIDE the shard_map program: each shard gathers its own
    rows through the replicated forest ``tables`` (small — the semantic
    forest, [n_levels, num_places]), so the [N, n_levels, L] code table
    never materializes on the host, for either score mode.

    score_mode:
      "replicate" — each shard all_gathers the per-shard encodings into a
        device-resident replica of the table and scores its deduped pairs
        locally (fine to ~10M trajectories: the table is
        N * levels * L * 4 bytes).
      "shuffle"   — the table stays sharded; two extra all_to_all rounds
        route each pair to owner(left) then owner(right), attaching the
        owner's code rows on the way (a Spark broadcast-join vs shuffle-join
        switch).  Per-device memory is then O(N/shards) — the 1000-node
        regime.

    lcs_impl selects the scoring implementation exactly as on the
    single-device path: "wavefront" / "ref" / "kernel" (auto Pallas) /
    "pallas" (forced Pallas) / "pallas-interpret", plus the gather-free
    fused family "fused" / "fused-pallas" / "fused-interpret" — the fused
    kernel scores pairs straight out of the device-resident code table
    ("replicate") or the hop-gathered operand stacks ("shuffle") with the
    MSS epilogue fused in.

    score_prune runs the MSS upper-bound pruning pass IN-MESH, right after
    the pair dedup and before any code row moves for scoring: per-shard
    lengths are all_gathered (an [N] int32 vector, not the code table), the
    free bound ``sum_h beta_h * min(len_a, len_b)`` is tested against
    ``prune_tau``, and survivors are compacted into the planned
    ``pruned_cap`` buffer.  In "shuffle" mode this happens before the owner
    hops, so pruned pairs never travel.

    With ``plan.n_chunks > 1`` (shuffle mode) the pair buffer is split into
    chunks and the owner hops are SOFTWARE-PIPELINED: chunk 0's hops are
    issued, then for each subsequent chunk the next hops are issued BEFORE
    the previous chunk's resting pairs are scored, so the collective for
    chunk i+1 and the LCS compute for chunk i have no data dependence and
    the scheduler is free to overlap them (alpa's comm/compute overlap
    discipline; on a single host the same split pays off as cache blocking
    — one chunk's operands stay resident while it scores).  Chunking only
    reorders WHICH rows travel together; every pair still hops and scores
    exactly once with the same operands, so per-pair scores are
    bit-identical and the overflow accounting stays exact (per-chunk
    buffers come from the same exact-loads planner).  ``n_chunks`` is a
    static plan field, so chunking adds zero steady-state recompiles.

    ``tuning`` (optional :class:`repro.perf.LCSTuning`) is resolved
    EAGERLY here at build time into static kernel args via
    ``lcs_impl_fn`` — never inside the trace.

    ``subtraj=(W, stride, nw)`` switches the pipeline to subtrajectory
    mode: the per-shard key rows are the nw sliding WINDOWS of each local
    trajectory (``key_fn`` windows in-mesh; precomputed ``first`` keys are
    already windowed host-side), every candidate id is a WINDOW id
    ``t * nw + j`` carrying (traj, offset) coordinates end-to-end, shard
    ownership stays per-TRAJECTORY (``plan.local_n`` is in trajectory
    units, see ``plan_capacities(windows_per_row=...)``), the owner hops
    still move the full [H, L] trajectory rows exactly once per pair side,
    and scoring windows them in-register (fused kernel) or via a width-W
    gather (jnp impls).  All three values are static, so subtrajectory
    runs compile their own specialization and ``subtraj=None`` traces are
    byte-identical to the pre-windowing pipeline.
    """
    from jax.sharding import PartitionSpec as P

    from repro.api.stages import FUSED_MODES, lcs_impl_fn

    n_shards = plan.n_shards
    if subtraj is not None:
        W, stride, nw = subtraj
    else:
        W, stride, nw = 0, 1, 1
    fused_mode = FUSED_MODES.get(lcs_impl)
    impl = None if fused_mode is not None else lcs_impl_fn(lcs_impl, tuning)
    out_cap = (plan.pruned_cap or plan.scored_cap) if score_prune \
        else plan.scored_cap
    n_chunks = plan.n_chunks if score_mode == "shuffle" else 1
    if n_chunks > 1:
        if out_cap % n_chunks:
            raise ValueError(
                f"pair buffer ({out_cap}) must divide into n_chunks="
                f"{n_chunks} slices; plan_capacities rounds it up"
            )
        _sub = out_cap // n_chunks
        chunk_hop_cap = plan.chunk_hop_cap or (_sub // n_shards + 64)
        chunk_rest_cap = plan.chunk_rest_cap or _sub
        rest_total = n_chunks * chunk_rest_cap
    else:
        rest_total = out_cap

    def shard_fn(first, places, lengths, tables):
        # first: LOCAL keys rows (key_fn=None mode) or unused; places,
        # lengths: LOCAL rows; tables: the replicated semantic forest.
        me = jax.lax.axis_index(axis_name).astype(jnp.int32)
        gid0 = me * plan.local_n

        # phase (i): in-mesh encoding of OUR rows
        codes = encode_codes(places, tables)  # [local_n, H, L]

        # phase (ii)a: join keys of OUR rows
        if key_fn is not None:
            keys = key_fn(codes[:, 0, :], lengths)  # [local_n, S]
        else:
            keys = first  # [local_n, S] precomputed host-side

        s = keys.shape[1]
        flat_keys = keys.reshape(-1)
        if subtraj is None:
            flat_ids = jnp.repeat(
                jnp.arange(plan.local_n, dtype=jnp.int32) + gid0, s
            )
        else:
            # one key row per WINDOW: global window ids t * nw + j for the
            # local trajectories t in [gid0, gid0 + local_n)
            flat_ids = jnp.repeat(
                jnp.arange(plan.local_n * nw, dtype=jnp.int32) + gid0 * nw, s
            )
        valid = flat_keys != PAD_KEY
        dest = _positive_hash(flat_keys) % n_shards
        (rk, rid), ovf1 = _route(
            (flat_keys, flat_ids), dest, valid,
            n_shards=n_shards, capacity=plan.shingle_route_cap,
            pads=(PAD_KEY, PAD_ID), axis_name=axis_name,
        )

        # local sort-merge join on received rows
        lo, hi, ovf2 = pairs_from_rows(rk, rid, pair_capacity=plan.local_pair_cap)

        # shuffle 2: route pairs by pair hash so dedup is globally exact
        pvalid = lo != PAD_ID
        pdest = _pair_hash(lo, hi) % n_shards
        (rlo, rhi), ovf3 = _route(
            (lo, hi), pdest, pvalid,
            n_shards=n_shards, capacity=plan.pair_route_cap,
            pads=(PAD_ID, PAD_ID), axis_name=axis_name,
        )
        # dedup over the FULL received buffer (valid rows sit scattered in
        # per-source buckets; dedup's sort compacts them to the front), then
        # fit to scored_cap with the excess surfaced as overflow
        cand = dedup_pairs(rlo, rhi)
        left = _fit(cand.left, plan.scored_cap, PAD_ID)
        right = _fit(cand.right, plan.scored_cap, PAD_ID)
        ovf4 = jnp.maximum(cand.count - plan.scored_cap, 0)

        # MSS upper-bound pruning pass: drop pairs that cannot reach tau
        # BEFORE any code row moves for scoring.  Only the [N] lengths
        # vector is gathered (int32, tiny) — never the code table.
        n_pruned = jnp.zeros((), jnp.int32)
        if score_prune:
            lengths_all = jax.lax.all_gather(
                lengths, axis_name, axis=0, tiled=True
            )
            pl_valid = left != PAD_ID
            sl = jnp.where(pl_valid, left, 0)
            sr = jnp.where(pl_valid, right, 0)
            if subtraj is None:
                len_l, len_r = lengths_all[sl], lengths_all[sr]
            else:
                # per-WINDOW lengths from the [N] trajectory lengths
                len_l = jnp.clip(
                    lengths_all[sl // nw] - (sl % nw) * stride, 0, W
                )
                len_r = jnp.clip(
                    lengths_all[sr // nw] - (sr % nw) * stride, 0, W
                )
            keep = _prune_keep(len_l, len_r, betas, prune_tau, pl_valid)
            n_keep = jnp.sum(keep).astype(jnp.int32)
            n_pruned = jnp.sum(pl_valid).astype(jnp.int32) - n_keep
            order = jnp.argsort(jnp.logical_not(keep), stable=True)
            slots = jnp.arange(out_cap, dtype=jnp.int32)
            # out_cap may exceed scored_cap (skewed owners): pad, then mask
            left = jnp.where(
                slots < n_keep, _fit(left[order], out_cap, PAD_ID), PAD_ID
            )
            right = jnp.where(
                slots < n_keep, _fit(right[order], out_cap, PAD_ID), PAD_ID
            )
            ovf4 = ovf4 + jnp.maximum(n_keep - out_cap, 0)

        # phase (iii): scoring, through the selected lcs_impl
        if score_mode == "replicate":
            # on-device replication of the in-mesh encodings (never on host)
            codes_all = jax.lax.all_gather(codes, axis_name, axis=0, tiled=True)
            li = jnp.where(left == PAD_ID, 0, left)
            ri = jnp.where(right == PAD_ID, 0, right)
            if subtraj is not None:
                # window ids -> (traj, offset); score the [H, W] slices
                ta, oa = li // nw, (li % nw) * stride
                tb, ob = ri // nw, (ri % nw) * stride
                len_all = _lengths_of(codes_all)
                if fused_mode is not None:
                    from repro.kernels.lcs.fused import fused_windowed_score

                    level_lcs, mss = fused_windowed_score(
                        codes_all, len_all, codes_all, len_all,
                        ta, tb, oa, ob, betas, window=W, mode=fused_mode,
                    )
                else:
                    from repro.core.similarity import gather_windows

                    level_lcs = multi_level_lcs(
                        gather_windows(codes_all[ta], oa, W),
                        jnp.clip(len_all[ta] - oa, 0, W),
                        gather_windows(codes_all[tb], ob, W),
                        jnp.clip(len_all[tb] - ob, 0, W),
                        impl=impl,
                    )
                    mss = mss_scores(level_lcs, betas)
            elif fused_mode is not None:
                from repro.kernels.lcs.fused import fused_score

                len_all = _lengths_of(codes_all)
                level_lcs, mss = fused_score(
                    codes_all, len_all, codes_all, len_all, li, ri, betas,
                    mode=fused_mode,
                )
            else:
                level_lcs = multi_level_lcs(
                    codes_all[li], _lengths_of(codes_all[li]),
                    codes_all[ri], _lengths_of(codes_all[ri]), impl=impl,
                )
                mss = mss_scores(level_lcs, betas)
            ovf5 = jnp.zeros((), jnp.int32)
        elif n_chunks == 1:
            left, right, codes_l, codes_r, ovf5 = _gather_pair_codes(
                left, right, codes, gid0, plan, n_shards, axis_name, out_cap
            )
            level_lcs, mss = _score_gathered(codes_l, codes_r, out_cap,
                                             left, right)
        else:
            # software-pipelined chunked gather+score: issue the owner hops
            # for chunk i+1 BEFORE scoring chunk i's resting pairs, so the
            # collective and the LCS compute have no data dependence
            def hop(i):
                sl = slice(i * _sub, (i + 1) * _sub)
                return _hop_gather_codes(
                    left[sl], right[sl], codes,
                    owner_of=lambda g: (g if subtraj is None else g // nw)
                    // plan.local_n,
                    slot_of=lambda g: (g if subtraj is None else g // nw)
                    - gid0,
                    n_shards=n_shards, axis_name=axis_name,
                    hop_cap=chunk_hop_cap, out_cap=chunk_rest_cap,
                )

            def score_chunk(p):
                return (
                    p[:2]
                    + _score_gathered(p[2], p[3], chunk_rest_cap, p[0], p[1])
                    + (p[4],)
                )

            parts = []
            pending = hop(0)
            for i in range(1, n_chunks):
                nxt = hop(i)
                parts.append(score_chunk(pending))
                pending = nxt
            parts.append(score_chunk(pending))
            left = jnp.concatenate([p[0] for p in parts])
            right = jnp.concatenate([p[1] for p in parts])
            level_lcs = jnp.concatenate([p[2] for p in parts])
            mss = jnp.concatenate([p[3] for p in parts])
            ovf5 = sum(p[4] for p in parts)
        mss = jnp.where(left == PAD_ID, -1.0, mss)
        overflow = jnp.stack([ovf1 + ovf2, ovf3, ovf4 + ovf5]).astype(jnp.int32)
        return left, right, level_lcs, mss, overflow, n_pruned.reshape(1)

    def _lengths_of(code_rows):
        # lengths reconstructed from the padding sentinel in level 0
        return jnp.sum(code_rows[:, 0, :] >= 0, axis=-1).astype(jnp.int32)

    def _score_gathered(codes_l, codes_r, cap, left=None, right=None):
        """Score one resting operand stack (post-hop) -> (level_lcs, mss).

        The gather already happened via the owner hops, so the fused kernel
        runs level-fused over the operand stacks via iota indices.  In
        subtrajectory mode the hops moved FULL trajectory rows and the
        resting ``left``/``right`` window ids decode each pair's window
        offsets here, at the point of scoring.
        """
        if subtraj is not None:
            oa = (jnp.where(left == PAD_ID, 0, left) % nw) * stride
            ob = (jnp.where(right == PAD_ID, 0, right) % nw) * stride
            la, lb = _lengths_of(codes_l), _lengths_of(codes_r)
            if fused_mode is not None:
                from repro.kernels.lcs.fused import fused_windowed_score

                iota = jnp.arange(cap, dtype=jnp.int32)
                return fused_windowed_score(
                    codes_l, la, codes_r, lb, iota, iota, oa, ob, betas,
                    window=W, mode=fused_mode,
                )
            from repro.core.similarity import gather_windows

            lvl = multi_level_lcs(
                gather_windows(codes_l, oa, W), jnp.clip(la - oa, 0, W),
                gather_windows(codes_r, ob, W), jnp.clip(lb - ob, 0, W),
                impl=impl,
            )
            return lvl, mss_scores(lvl, betas)
        if fused_mode is not None:
            from repro.kernels.lcs.fused import fused_score

            iota = jnp.arange(cap, dtype=jnp.int32)
            return fused_score(
                codes_l, _lengths_of(codes_l), codes_r, _lengths_of(codes_r),
                iota, iota, betas, mode=fused_mode,
            )
        lvl = multi_level_lcs(
            codes_l, _lengths_of(codes_l), codes_r, _lengths_of(codes_r),
            impl=impl,
        )
        return lvl, mss_scores(lvl, betas)

    def _gather_pair_codes(left, right, codes_local, gid0, plan, n, axis,
                           out_cap):
        """Shuffle-mode scoring via the shared two-hop gather
        (:func:`_hop_gather_codes`) with the one-shot BLOCK ownership:
        row g lives on shard ``g // local_n`` at slot ``g - gid0``.  Hop
        buckets are sized from the exactly-planned per-owner loads
        (plan.owner_route_cap); without a plan the uniform fallback applies
        and overflow counters catch skew.  ``out_cap`` is the resting
        buffer size — the post-prune capacity when the pruning pass ran,
        else plan.scored_cap.
        """
        cap = plan.owner_route_cap or (out_cap // n + 64)
        return _hop_gather_codes(
            left, right, codes_local,
            owner_of=lambda g: (g if subtraj is None else g // nw)
            // plan.local_n,
            slot_of=lambda g: (g if subtraj is None else g // nw) - gid0,
            n_shards=n, axis_name=axis, hop_cap=cap, out_cap=out_cap,
        )

    spec_in = (
        P(axis_name, None), P(axis_name, None), P(axis_name), P(None, None),
    )
    spec_out = (P(axis_name), P(axis_name), P(axis_name), P(axis_name),
                P(axis_name), P(axis_name))
    fn = compat.shard_map(
        shard_fn, mesh=mesh, in_specs=spec_in, out_specs=spec_out
    )

    @jax.jit
    def run(first, places, lengths, tables):
        left, right, level_lcs, mss, overflow, pruned = fn(
            first, places, lengths, tables
        )
        return {
            "left": left.reshape(n_shards, -1),
            "right": right.reshape(n_shards, -1),
            "level_lcs": level_lcs.reshape(n_shards, rest_total, -1),
            "mss": mss.reshape(n_shards, -1),
            "overflow": overflow.reshape(n_shards, -1),
            "pruned": pruned.reshape(n_shards),
        }

    return run


@dataclasses.dataclass(frozen=True)
class StreamShardPlan:
    """Static shapes of one streaming sharded score program.

    The streaming world is laid out ROUND-ROBIN: global row g lives on
    shard ``g % n_shards`` at local slot ``g // n_shards``, so appends keep
    every shard within one row of balanced as the world grows (the one-shot
    pipeline's block layout would pile every new row onto the last shard).
    All capacities are powers of two so consecutive updates with similar
    delta sizes hit the same compiled runner.
    """

    n_shards: int
    cap_local: int   # physical world rows per shard (world cap / n_shards)
    pair_cap: int    # delta pairs per shard (host-assigned input slices)
    hop_cap: int     # rows per (src, dst) bucket in the owner hops (shuffle);
    #                  with n_chunks > 1 this is the PER-CHUNK bucket size
    out_cap: int     # resting pairs per shard after the hops (PER CHUNK when
    #                  n_chunks > 1); in "replicate" mode pairs score in
    #                  place: == pair_cap
    n_chunks: int = 1  # shuffle-mode overlap: split each shard's pair slice
    #                    into this many sub-chunks so chunk i+1's owner hops
    #                    run while chunk i scores (power of two; must divide
    #                    pair_cap)


def _pow2(x: int, floor_pow2: int = 4) -> int:
    return 1 << max(floor_pow2, int(np.ceil(np.log2(max(int(x), 1)))))


def plan_stream_capacities(
    lo: np.ndarray,
    hi: np.ndarray,
    n_shards: int,
    cap_local: int,
    *,
    score_mode: str = "replicate",
    floor_pow2: int = 4,
    overlap_chunks: int = 1,
    pair_cap_floor: int = 0,
    windows_per_row: int = 1,
) -> StreamShardPlan:
    """Exact skew-aware capacity plan for ONE micro-batch's delta pairs.

    The delta pairs are already deduped host-side (the bucket index emits
    each pair once), so planning reduces to the score shuffle: pairs are
    assigned to source shards in contiguous chunks, and for
    ``score_mode="shuffle"`` the two owner hops are sized from the actual
    per-(src, dst) loads under round-robin ownership (``owner = id %
    n_shards``) — the same exact-loads discipline as
    :func:`plan_capacities`, just over the delta instead of the world.
    Capacities quantize to powers of two; the streaming engine keeps them
    sticky (monotone max over updates) so steady-state updates reuse the
    compiled runner.

    ``overlap_chunks > 1`` (shuffle mode only) sizes the PER-CHUNK hop and
    resting buffers for the software-pipelined gather: each shard's
    ``pair_cap`` slice is split into that many sub-slices, and because the
    host assigns pairs to slices deterministically (contiguous chunks,
    front slots), the per-(chunk, owner) loads are exact.  Sticky plans may
    hold ``pair_cap`` above this update's need, which MOVES the chunk
    boundaries — ``pair_cap_floor`` (the sticky value) lets a fresh plan
    compute chunk loads under the layout the runner will actually use.

    ``windows_per_row > 1`` declares the delta pair ids to be WINDOW ids
    (``t * nw + j``, see :mod:`repro.core.subtraj`): round-robin ownership
    is then per TRAJECTORY (``owner = (id // nw) % n_shards``), matching
    the resident world layout where only whole trajectory rows are stored.
    (The StreamingEngine itself rejects subtrajectory mode — a growing
    world max-length would re-number every stored window id — but the
    planner stays windows-aware so batch-style callers can size streaming
    score programs over windowed deltas.)
    """
    p = int(lo.shape[0])
    chunk = -(-p // n_shards) if p else 0  # ceil
    pair_cap = max(_pow2(chunk, floor_pow2), pair_cap_floor or 0)
    if score_mode == "replicate":
        return StreamShardPlan(
            n_shards=n_shards, cap_local=cap_local, pair_cap=pair_cap,
            hop_cap=0, out_cap=pair_cap,
        )
    n_chunks = max(int(overlap_chunks), 1)
    sub = pair_cap // n_chunks if n_chunks > 1 else pair_cap
    if p:
        lo = np.asarray(lo, np.int64)
        hi = np.asarray(hi, np.int64)
        idx = np.arange(p, dtype=np.int64)
        src = idx // max(chunk, 1)
        pos = idx - src * max(chunk, 1)    # front slot in the shard's slice
        cidx = np.minimum(pos // max(sub, 1), n_chunks - 1)
        own_lo = (lo // windows_per_row) % n_shards
        own_hi = (hi // windows_per_row) % n_shards
        h1 = np.zeros((n_chunks, n_shards, n_shards), np.int64)
        np.add.at(h1, (cidx, src, own_lo), 1)
        h2 = np.zeros((n_chunks, n_shards, n_shards), np.int64)
        np.add.at(h2, (cidx, own_lo, own_hi), 1)
        rest = np.zeros((n_chunks, n_shards), np.int64)
        np.add.at(rest, (cidx, own_hi), 1)
        hop_need = int(max(h1.max(), h2.max()))
        rest_need = int(rest.max())
    else:
        hop_need = rest_need = 1
    return StreamShardPlan(
        n_shards=n_shards, cap_local=cap_local, pair_cap=pair_cap,
        hop_cap=_pow2(hop_need, floor_pow2),
        out_cap=_pow2(rest_need, floor_pow2),
        n_chunks=n_chunks,
    )


@dataclasses.dataclass(frozen=True)
class StreamJoinPlan:
    """Static shapes of one in-mesh streaming delta-join program.

    The resident bucket state is key-sharded: every (key, row id)
    occurrence lives on shard ``hash(key) % n_shards`` inside a sorted
    slab of ``slab_cap`` slots (core/device_index.py).  Per update only
    the NEW rows' key occurrences enter the mesh — ``key_in_cap`` per
    source shard — are all_to_all'd to their owners (``key_route_cap``
    per (src, dst) bucket), probed against the slab into the
    ``nn_cap``/``no_cap`` pair buffers, pair-hash shuffled for global
    dedup (``pair_route_cap``), and come to rest ``pair_cap`` per shard.
    All capacities quantize to powers of two and the engine keeps them
    sticky (monotone max), so steady-state updates reuse one compiled
    program.
    """

    n_shards: int
    slab_cap: int       # resident (key, row) occurrences per shard
    key_in_cap: int     # incoming key occurrences per source shard
    key_route_cap: int  # rows per (src, dst) bucket in the key route
    nn_cap: int         # new-vs-new pair slots per owner shard
    no_cap: int         # new-vs-old pair slots per owner shard
    pair_route_cap: int  # rows per (src, dst) bucket in the dedup shuffle
    pair_cap: int       # deduped resting delta pairs per shard


def plan_stream_join(
    keys_flat: np.ndarray,
    n_shards: int,
    stats,
    *,
    floor_pow2: int = 4,
) -> StreamJoinPlan:
    """Exact skew-aware capacity plan for ONE update's in-mesh delta join.

    keys_flat: the new rows' per-row-deduped key occurrences (flat, row
    order) — the only join data the driver touches.  ``stats`` is the
    :class:`~repro.core.device_index.StreamJoinStats` count mirror; its
    ``plan_update`` yields the exact per-owner new-vs-old / new-vs-new
    emission counts and slab-entry deltas under the device's own int32
    key hash, so the slab, probe and route buffers are sized from actual
    per-owner loads, not uniform-hash bounds.  The two pair-stage caps the
    driver cannot compute exactly without the pair list itself
    (``pair_route_cap``, ``pair_cap``) use the per-owner / global
    pre-dedup emission totals — safe upper bounds on any post-dedup skew,
    so a steady-state overflow is impossible (the retry-doubling path
    stays as a belt-and-braces check).
    """
    k = int(keys_flat.shape[0])
    owners = _positive_hash_np(keys_flat) % n_shards if k else \
        np.zeros((0,), np.int64)
    nvo, nvn, ent = stats.plan_update(keys_flat, owners)
    chunk = -(-k // n_shards) if k else 0
    if k:
        src = np.arange(k, dtype=np.int64) // max(chunk, 1)
        load = np.zeros((n_shards, n_shards), np.int64)
        np.add.at(load, (src, owners), 1)
        route_need = int(load.max())
    else:
        route_need = 1
    emit = nvo + nvn
    return StreamJoinPlan(
        n_shards=n_shards,
        slab_cap=_pow2(int((stats.owner_entries + ent).max()), floor_pow2),
        key_in_cap=_pow2(chunk, floor_pow2),
        key_route_cap=_pow2(route_need, floor_pow2),
        nn_cap=_pow2(int(nvn.max()), floor_pow2),
        no_cap=_pow2(int(nvo.max()), floor_pow2),
        pair_route_cap=_pow2(int(emit.max()), floor_pow2),
        pair_cap=_pow2(int(emit.sum()), floor_pow2),
    )


def sticky_join_plan(
    plan: StreamJoinPlan, prev: StreamJoinPlan | None
) -> StreamJoinPlan:
    """Monotone max over every capacity: consecutive updates with similar
    delta shapes resolve to the SAME plan, so the compiled join runner is
    reused verbatim (the zero-steady-state-recompile contract)."""
    if prev is None:
        return plan
    return StreamJoinPlan(
        n_shards=plan.n_shards,
        slab_cap=max(plan.slab_cap, prev.slab_cap),
        key_in_cap=max(plan.key_in_cap, prev.key_in_cap),
        key_route_cap=max(plan.key_route_cap, prev.key_route_cap),
        nn_cap=max(plan.nn_cap, prev.nn_cap),
        no_cap=max(plan.no_cap, prev.no_cap),
        pair_route_cap=max(plan.pair_route_cap, prev.pair_route_cap),
        pair_cap=max(plan.pair_cap, prev.pair_cap),
    )


def make_streaming_join_pipeline(
    mesh: jax.sharding.Mesh,
    plan: StreamJoinPlan,
    *,
    axis_name: str = "ex",
    trace_counter: list | None = None,
):
    """Build the jitted shard_map in-mesh delta-join program.

    The device-side replacement for ``BucketIndex.insert``: bucket state
    stays key-sharded and device-resident, the driver ships only the new
    rows' key occurrences, and the deduped delta pairs come to rest
    in-mesh (their device buffers feed the streaming score program
    directly — the pair list never materializes on the host).

    Call signature of the returned fn::

      fn(slab_keys [n_shards * slab_cap] int32,   # resident sorted slabs
         slab_rows [n_shards * slab_cap] int32,
         keys      [n_shards * key_in_cap] int32,  # new occurrences,
         rows      [n_shards * key_in_cap] int32)  # PAD-padded chunks
        -> dict: slab_keys/slab_rows (merged — commit only on success),
                 left/right [n_shards, pair_cap] deduped delta pairs,
                 count [n_shards], max_count [n_shards] (the in-mesh pmax
                 of the post-dedup counts, replicated — the tight score
                 pair cap), examined [n_shards], overflow [n_shards, 4]

    Stages per shard: (1) all_to_all the incoming occurrences to
    ``hash(key) % n_shards`` through the shared :func:`_route` machinery;
    (2) :func:`~repro.core.device_index.probe_pairs` against the resident
    slab (new-vs-old + new-vs-new, exact ``examined`` accounting);
    (3) pair-hash all_to_all + :func:`~repro.core.ssh.dedup_pairs` so
    every delta pair rests on exactly one shard (cross-owner duplicates
    from pairs sharing keys with different owners collapse here);
    (4) :func:`~repro.core.device_index.merge_insert` folds the incoming
    occurrences into the slab (functional: the caller commits the
    returned slabs only when no overflow fired, so retries are safe).

    ``trace_counter`` increments at TRACE time only — the compilation
    counting hook the differential harness asserts on.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.device_index import merge_insert, probe_pairs

    n_shards = plan.n_shards

    def shard_fn(slab_k, slab_r, keys, rows):
        if trace_counter is not None:
            trace_counter[0] += 1  # executes per compile, not per update
        valid = keys != PAD_KEY
        dest = _positive_hash(keys) % n_shards
        (rk, rr), o1 = _route(
            (keys, rows), dest, valid,
            n_shards=n_shards, capacity=plan.key_route_cap,
            pads=(PAD_KEY, PAD_ID), axis_name=axis_name,
        )
        lo, hi, examined, o2 = probe_pairs(
            slab_k, slab_r, rk, rr, nn_cap=plan.nn_cap, no_cap=plan.no_cap
        )
        pvalid = lo != PAD_ID
        pdest = _pair_hash(lo, hi) % n_shards
        (rlo, rhi), o3 = _route(
            (lo, hi), pdest, pvalid,
            n_shards=n_shards, capacity=plan.pair_route_cap,
            pads=(PAD_ID, PAD_ID), axis_name=axis_name,
        )
        cand = dedup_pairs(rlo, rhi)
        left = _fit(cand.left, plan.pair_cap, PAD_ID)
        right = _fit(cand.right, plan.pair_cap, PAD_ID)
        o4 = jnp.maximum(cand.count - plan.pair_cap, 0)
        slab_k2, slab_r2, o5 = merge_insert(slab_k, slab_r, rk, rr)
        count = jnp.minimum(cand.count, plan.pair_cap)
        # in-mesh count reduction: the worst per-shard POST-dedup resting
        # count, replicated to every shard.  The driver sizes the score
        # program's pair buffers from this instead of the pre-dedup
        # emission bound baked into plan.pair_cap (cross-owner duplicates
        # and the global-vs-per-shard gap both vanish), so the resting
        # buffers are sliced down before a single padded pair is scored
        max_count = jax.lax.pmax(count, axis_name)
        overflow = jnp.stack([o1 + o2, o3 + o4, o5,
                              jnp.zeros((), jnp.int32)]).astype(jnp.int32)
        return (slab_k2, slab_r2, left, right, count.reshape(1),
                max_count.reshape(1), examined.reshape(1), overflow)

    spec_in = (P(axis_name), P(axis_name), P(axis_name), P(axis_name))
    spec_out = (P(axis_name), P(axis_name), P(axis_name), P(axis_name),
                P(axis_name), P(axis_name), P(axis_name), P(axis_name))
    fn = compat.shard_map(
        shard_fn, mesh=mesh, in_specs=spec_in, out_specs=spec_out
    )

    @jax.jit
    def run(slab_keys, slab_rows, keys, rows):
        sk, sr, left, right, count, max_count, examined, overflow = fn(
            slab_keys, slab_rows, keys, rows
        )
        return {
            "slab_keys": sk,
            "slab_rows": sr,
            "left": left.reshape(n_shards, -1),
            "right": right.reshape(n_shards, -1),
            "count": count.reshape(n_shards),
            "max_count": max_count.reshape(n_shards),
            "examined": examined.reshape(n_shards),
            "overflow": overflow.reshape(n_shards, -1),
        }

    return run


def make_streaming_score_pipeline(
    mesh: jax.sharding.Mesh,
    plan: StreamShardPlan,
    *,
    betas: jnp.ndarray,
    axis_name: str = "ex",
    score_mode: str = "replicate",
    lcs_impl: str = "wavefront",
    trace_counter: list | None = None,
    score_prune: bool = False,
    prune_tau: float = 0.0,
    tuning=None,
):
    """Build the jitted shard_map DELTA score program for streaming updates.

    Unlike :func:`make_sharded_pipeline` there is no join here: candidate
    generation is incremental (the host bucket index emits only
    new-vs-world pairs), so the device program just encodes each shard's
    resident world rows in-mesh and scores the already-deduped delta pairs
    through the selected ``lcs_impl``.

    Call signature of the returned fn::

      fn(places [n_shards * cap_local, L] int32,   # round-robin physical
         left   [n_shards * pair_cap] int32,       # global ids, PAD_ID pad
         right  [n_shards * pair_cap] int32,
         tables [n_levels, num_places] int32)
        -> dict: left/right [n, out_cap], level_lcs [n, out_cap, H],
                 mss [n, out_cap], overflow [n]

    Row lengths are reconstructed in-mesh from the encoding sentinels, so
    the world state a shard holds is exactly its places slab — the code
    table never materializes on the host, matching the one-shot invariant.

    score_mode "replicate" all_gathers the per-shard encodings and scores
    each pair slice in place (output slot == input slot); "shuffle" keeps
    the table sharded and runs the shared two-hop owner gather
    (:func:`_hop_gather_codes`) under round-robin ownership, with hop
    buckets sized by :func:`plan_stream_capacities`.

    ``trace_counter`` is a single-element list incremented at TRACE time
    (the Python body runs only when XLA compiles a new program) — the
    compilation-counting hook the no-recompile regression tests assert on.

    ``score_prune`` runs the MSS upper-bound pruning pass IN-MESH (the
    host delta-join path prunes host-side before the pairs ship; the
    device delta-join path never sees the pairs on the host, so pruning
    happens here): lengths are reconstructed from the encoding sentinels,
    the same float32 bound as the one-shot pass is tested against
    ``prune_tau``, and hopeless pairs are masked to PAD — in "shuffle"
    mode BEFORE the owner hops (only the [N] lengths vector is gathered,
    and masked pairs are invalid to the router, so they never travel or
    gather code rows).  The surviving scored set is bit-identical to
    pruning host-side; the per-shard prune count returns as ``pruned``.

    With ``plan.n_chunks > 1`` (shuffle mode) each shard's pair slice is
    split into sub-chunks and the owner hops software-pipeline against
    scoring exactly as in :func:`make_sharded_pipeline`: chunk i+1's hops
    are issued before chunk i scores, per-pair results stay bit-identical,
    and ``n_chunks`` is static in the plan so the zero-steady-state-
    recompile contract (``trace_counter``) is untouched.

    ``tuning`` (optional :class:`repro.perf.LCSTuning`) resolves eagerly
    at build time into static kernel args via ``lcs_impl_fn``.
    """
    from jax.sharding import PartitionSpec as P

    from repro.api.stages import FUSED_MODES, lcs_impl_fn

    n_shards = plan.n_shards
    fused_mode = FUSED_MODES.get(lcs_impl)
    impl = None if fused_mode is not None else lcs_impl_fn(lcs_impl, tuning)
    out_cap = plan.out_cap
    n_chunks = plan.n_chunks if score_mode == "shuffle" else 1
    if n_chunks > 1:
        if plan.pair_cap % n_chunks:
            raise ValueError(
                f"pair_cap ({plan.pair_cap}) must divide into n_chunks="
                f"{n_chunks} slices (both are powers of two)"
            )
        _sub = plan.pair_cap // n_chunks
        rest_total = n_chunks * out_cap   # out_cap is PER CHUNK here
    else:
        rest_total = out_cap

    def _lengths_of(code_rows):
        # lengths reconstructed from the padding sentinel in level 0
        return jnp.sum(code_rows[:, 0, :] >= 0, axis=-1).astype(jnp.int32)

    def _score_gathered(codes_l, codes_r, cap):
        """Score one resting operand stack (post-hop) -> (level_lcs, mss)."""
        if fused_mode is not None:
            from repro.kernels.lcs.fused import fused_score

            iota = jnp.arange(cap, dtype=jnp.int32)
            return fused_score(
                codes_l, _lengths_of(codes_l), codes_r, _lengths_of(codes_r),
                iota, iota, betas, mode=fused_mode,
            )
        lvl = multi_level_lcs(
            codes_l, _lengths_of(codes_l), codes_r, _lengths_of(codes_r),
            impl=impl,
        )
        return lvl, mss_scores(lvl, betas)

    def _phys(g, valid):
        # physical index of global id g in the round-robin world layout:
        # (g % n) * cap_local + g // n
        safe = jnp.where(valid, g, 0)
        return (safe % n_shards) * plan.cap_local + safe // n_shards

    def shard_fn(places, left, right, tables):
        if trace_counter is not None:
            trace_counter[0] += 1  # executes per compile, not per update
        codes = encode_codes(places, tables)  # [cap_local, H, L]
        n_pruned = jnp.zeros((), jnp.int32)
        if score_mode == "replicate":
            codes_all = jax.lax.all_gather(codes, axis_name, axis=0,
                                           tiled=True)
            valid = left != PAD_ID
            li = _phys(left, valid)
            ri = _phys(right, valid)
            if score_prune:
                len_all = _lengths_of(codes_all)
                keep = _prune_keep(len_all[li], len_all[ri], betas,
                                   prune_tau, valid)
                n_pruned = (jnp.sum(valid) - jnp.sum(keep)).astype(jnp.int32)
                left = jnp.where(keep, left, PAD_ID)
                right = jnp.where(keep, right, PAD_ID)
            if fused_mode is not None:
                from repro.kernels.lcs.fused import fused_score

                len_all = _lengths_of(codes_all)
                level_lcs, mss = fused_score(
                    codes_all, len_all, codes_all, len_all, li, ri, betas,
                    mode=fused_mode,
                )
            else:
                level_lcs = multi_level_lcs(
                    codes_all[li], _lengths_of(codes_all[li]),
                    codes_all[ri], _lengths_of(codes_all[ri]), impl=impl,
                )
                mss = mss_scores(level_lcs, betas)
            out_l, out_r = left, right
            ovf = jnp.zeros((), jnp.int32)
        else:
            if score_prune:
                # prune BEFORE the owner hops (the one-shot discipline):
                # only the [N] int32 lengths vector is gathered — never a
                # code row — and pruned pairs, masked to PAD, are invalid
                # to _route, so they never travel or gather codes
                len_all = jax.lax.all_gather(
                    _lengths_of(codes), axis_name, axis=0, tiled=True
                )
                valid = left != PAD_ID
                keep = _prune_keep(len_all[_phys(left, valid)],
                                   len_all[_phys(right, valid)],
                                   betas, prune_tau, valid)
                n_pruned = (jnp.sum(valid) - jnp.sum(keep)).astype(jnp.int32)
                left = jnp.where(keep, left, PAD_ID)
                right = jnp.where(keep, right, PAD_ID)
            def hop(l_part, r_part):
                return _hop_gather_codes(
                    l_part, r_part, codes,
                    owner_of=lambda g: g % n_shards,
                    slot_of=lambda g: g // n_shards,
                    n_shards=n_shards, axis_name=axis_name,
                    hop_cap=plan.hop_cap, out_cap=out_cap,
                )

            if n_chunks == 1:
                out_l, out_r, codes_l, codes_r, ovf = hop(left, right)
                level_lcs, mss = _score_gathered(codes_l, codes_r, out_cap)
            else:
                # software pipeline: issue chunk i+1's owner hops BEFORE
                # scoring chunk i's resting pairs (no data dependence
                # between them, so the scheduler may overlap)
                parts = []
                pending = hop(left[:_sub], right[:_sub])
                for i in range(1, n_chunks):
                    sl = slice(i * _sub, (i + 1) * _sub)
                    nxt = hop(left[sl], right[sl])
                    parts.append(
                        pending[:2]
                        + _score_gathered(pending[2], pending[3], out_cap)
                        + (pending[4],)
                    )
                    pending = nxt
                parts.append(
                    pending[:2]
                    + _score_gathered(pending[2], pending[3], out_cap)
                    + (pending[4],)
                )
                out_l = jnp.concatenate([p[0] for p in parts])
                out_r = jnp.concatenate([p[1] for p in parts])
                level_lcs = jnp.concatenate([p[2] for p in parts])
                mss = jnp.concatenate([p[3] for p in parts])
                ovf = sum(p[4] for p in parts)
        mss = jnp.where(out_l == PAD_ID, -1.0, mss)
        return (out_l, out_r, level_lcs, mss,
                ovf.reshape(1).astype(jnp.int32), n_pruned.reshape(1))

    spec_in = (P(axis_name, None), P(axis_name), P(axis_name), P(None, None))
    spec_out = (P(axis_name), P(axis_name), P(axis_name), P(axis_name),
                P(axis_name), P(axis_name))
    fn = compat.shard_map(
        shard_fn, mesh=mesh, in_specs=spec_in, out_specs=spec_out
    )

    @jax.jit
    def run(places, left, right, tables):
        out_l, out_r, level_lcs, mss, overflow, pruned = fn(
            places, left, right, tables
        )
        return {
            "left": out_l.reshape(n_shards, -1),
            "right": out_r.reshape(n_shards, -1),
            "level_lcs": level_lcs.reshape(n_shards, rest_total, -1),
            "mss": mss.reshape(n_shards, -1),
            "overflow": overflow.reshape(n_shards),
            "pruned": pruned.reshape(n_shards),
        }

    return run


def make_distributed_anotherme(
    mesh: jax.sharding.Mesh,
    plan: DistributedPlan,
    *,
    tables: jnp.ndarray,
    k: int,
    num_types: int,
    betas: jnp.ndarray,
    axis_name: str = "ex",
    dedup: bool = True,
    score_mode: str = "replicate",
    lcs_impl: str = "wavefront",
):
    """Legacy entry point: the SSH-shingle sharded pipeline.

    Thin adapter over :func:`make_sharded_pipeline` with the shingle key_fn;
    prefer ``AnotherMeEngine`` with ``ExecutionPlan(n_shards=...)``.  The
    forest ``tables`` are required because encoding runs in-mesh; the
    returned fn takes ``(places [N, L], lengths [N])``.
    """

    def key_fn(local_types, local_lengths):
        return shingles_from_types(
            local_types, local_lengths, k=k, num_types=num_types, dedup=dedup
        )

    inner = make_sharded_pipeline(
        mesh, plan, betas=betas, key_fn=key_fn,
        axis_name=axis_name, score_mode=score_mode, lcs_impl=lcs_impl,
    )
    tables = jnp.asarray(tables)

    def run(places, lengths):
        return inner(places, places, lengths, tables)

    return run


def gather_similar_pairs(out: dict, rho: float) -> set[tuple[int, int]]:
    """Host-side collection of the globally-deduped similar pair set."""
    left = np.asarray(out["left"]).reshape(-1)
    right = np.asarray(out["right"]).reshape(-1)
    mss = np.asarray(out["mss"]).reshape(-1)
    keep = (left != PAD_ID) & (mss > rho)
    return {(int(a), int(b)) for a, b in zip(left[keep], right[keep])}


def pad_to_shards(places: np.ndarray, lengths: np.ndarray, n_shards: int):
    """Pad N up to a multiple of n_shards with empty trajectories."""
    n = places.shape[0]
    n_pad = (-n) % n_shards
    if n_pad:
        places = np.concatenate(
            [places, np.full((n_pad, places.shape[1]), -1, places.dtype)]
        )
        lengths = np.concatenate([lengths, np.zeros((n_pad,), lengths.dtype)])
    return places, lengths
