"""Public API of the AnotherMe semantic-trajectory engine.

    from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan

    engine = AnotherMeEngine(forest, EngineConfig(backend="ssh"))
    result = engine.run(batch)        # .similar_pairs / .communities / .stats

Components (all replaceable independently):

  AnotherMeEngine / EngineConfig / ExecutionPlan   one entry point,
      single-device jit or shard_map selected by ExecutionPlan(n_shards=...)
  StreamingEngine                                  micro-batch ingestion:
      engine.update(batch) appends into a device-resident world and scores
      only the delta pairs, with incremental community maintenance
  get_backend / register_backend / available_backends
      string-keyed candidate-backend registry ("ssh", "minhash", "brp", "udf")
  CandidateBackend / BackendContext                backend protocol
  EncodeStage / CandidateStage / ScoreStage / CommunitiesStage
      the typed stage pipeline the engine composes
  QueryEngine                                      online top-k serving:
      QueryEngine(stream).query(batch) probes the resident index read-only
      and returns per-query top-k (match id, mss) without mutating the world
  CapacityPlanner                                  buffer sizing + overflow retry
  CapacityExceeded                                 typed admission refusal: an
      update/query over the max_resident_bytes budget (or past the retry
      doublings) is refused with the world state untouched
  Instrumentation                                  phase timing/stats wrapper
  make_sharded_pipeline / plan_capacities / DistributedPlan
      the shard_map building blocks (for dry-runs and custom meshes)

The legacy ``repro.core.run_anotherme`` / ``AnotherMeConfig`` remain as a
deprecation shim over this API.
"""
from repro.api.backends import (
    BackendContext, BRPBackend, CallableBackend, CandidateBackend,
    MinHashBackend, SSHBackend, UDFBackend, available_backends, get_backend,
    register_backend,
)
from repro.api.capacity import CapacityPlanner
from repro.api.engine import (
    AnotherMeEngine, EngineConfig, EngineResult, ExecutionPlan,
)
from repro.api.errors import CapacityExceeded
from repro.api.instrumentation import Instrumentation
from repro.api.sharded import (
    DistributedPlan, StreamJoinPlan, StreamShardPlan, gather_similar_pairs,
    make_distributed_anotherme, make_sharded_pipeline,
    make_streaming_join_pipeline, make_streaming_score_pipeline,
    pad_to_shards, plan_capacities, plan_stream_capacities,
    plan_stream_join, sticky_join_plan,
)
from repro.api.serving import (
    QueryEngine, QueryPlan, QueryResult, make_query_probe_pipeline,
    make_query_score_pipeline, plan_query_capacities, sticky_query_plan,
)
from repro.api.stages import (
    LCS_IMPLS, CandidateStage, CommunitiesStage, EncodeStage, PipelineContext,
    ScoreStage, Stage, lcs_impl_fn, validate_lcs_impl,
)
from repro.api.streaming import StreamingEngine
