"""`AnotherMeEngine`: one entry point for the whole pipeline.

    from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan

    engine = AnotherMeEngine(forest, EngineConfig(backend="ssh", rho=2.0))
    result = engine.run(batch)                       # single-device jit

    engine = AnotherMeEngine(forest, EngineConfig(backend="minhash"),
                             ExecutionPlan(n_shards=8))
    result = engine.run(batch)                       # shard_map execution

The engine composes the typed stages of api/stages.py — Encode, Candidate,
Score, Communities — and selects single-device jit or shard_map execution
from a single :class:`ExecutionPlan` instead of two divergent code paths:
with ``n_shards > 1`` the Encode+Candidate+Score stages are replaced by one
fused device-resident shard_map stage (api/sharded.py) while Communities is
shared verbatim — raw trajectories are sharded once, encoding runs in-mesh,
and the code table never materializes replicated on the host.  Candidate
generation is chosen by registry name (api/backends.py) and capacity policy
lives in the shared CapacityPlanner (api/capacity.py); phase timing is
collected by the instrumentation wrapper so the stage logic itself stays
pure and jit-cacheable across repeated runs with identical static shapes.

``lcs_impl`` (EngineConfig, overridable per ExecutionPlan) selects the LCS
implementation on BOTH paths: the Pallas kernel runs inside shard_map
exactly as it does under single-device jit.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.backends import (
    BackendContext, CandidateBackend, get_backend,
)
from repro.api.capacity import CapacityPlanner
from repro.api.instrumentation import Instrumentation
from repro.api.sharded import (
    gather_similar_pairs, make_sharded_pipeline, pad_to_shards,
)
from repro.api.stages import (
    CandidateStage, CommunitiesStage, EncodeStage, PipelineContext, ScoreStage,
    validate_lcs_impl,
)
from repro.core import compat
from repro.core.encoding import SemanticForest, encode_types, forest_tables
from repro.core.pipeline import AnotherMeResult as EngineResult
from repro.core.similarity import default_betas
from repro.core.types import EncodedBatch, PAD_ID, ScoredPairs, TrajectoryBatch


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Algorithm parameters (paper defaults; section V.1)."""

    k: int = 3                      # shingle order
    rho: float = 2.0                # similarity threshold
    betas: tuple | None = None      # level weights; None -> uniform 1/n
    backend: str = "ssh"            # candidate backend registry name
    backend_options: Mapping | None = None  # kwargs for the backend factory
    lcs_impl: str = "wavefront"     # "wavefront" | "ref" | "kernel" |
    #                                 "pallas" | "pallas-interpret" |
    #                                 "fused" | "fused-pallas" |
    #                                 "fused-interpret"
    score_prune: bool = False       # MSS upper-bound pruning before exact
    #                                 scoring (tau = rho); changes the
    #                                 scored buffer (hopeless pairs are
    #                                 dropped) but never the similar set
    pair_capacity: int | None = None  # None -> plan from exact join size
    capacity_slack: float = 1.10
    community_mode: str = "cliques"  # "cliques" | "components"
    max_retries: int = 3
    subtraj_window: int | None = None  # subtrajectory mode: key + score
    #                                 sliding windows of width W instead of
    #                                 whole trajectories; candidate pairs
    #                                 carry (traj, offset) window ids and a
    #                                 host max-over-windows reduction folds
    #                                 scores back to trajectory pairs
    #                                 (core/subtraj.py).  W >= L degenerates
    #                                 to whole-trajectory results.
    subtraj_stride: int = 1         # window start stride s (offsets 0, s,
    #                                 2s, ...); ignored unless
    #                                 subtraj_window is set


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Where and how the pipeline executes.

    n_shards=1 runs the jitted single-device stages; n_shards>1 runs the
    shard_map pipeline on the first n_shards devices (or ``devices``),
    padding the batch to a multiple of n_shards with empty trajectories.
    """

    n_shards: int = 1
    score_mode: str = "replicate"   # "replicate" | "shuffle" (sharded only)
    axis_name: str = "ex"
    devices: tuple | None = None    # default: jax.devices()[:n_shards]
    shard_slack: float = 1.3        # slack for the sharded capacity plan
    lcs_impl: str | None = None     # override EngineConfig.lcs_impl (both
    #                                 execution paths); None -> use config
    delta_join: str = "host"        # streaming only: "host" keeps the
    #                                 incremental bucket table on the driver
    #                                 (core/stream_index.py — the oracle);
    #                                 "device" key-shards it into resident
    #                                 slabs and joins in-mesh, so neither
    #                                 world keys nor the pair list transit
    #                                 the driver (core/device_index.py);
    #                                 ignored by AnotherMeEngine.run
    autotune: bool = False          # consult the cached repro.perf tuning
    #                                 table (TUNING.json) for score-stage
    #                                 kernel parameters; resolved eagerly,
    #                                 bit-identical results guaranteed
    overlap_chunks: int = 1         # shuffle-mode gather/score overlap:
    #                                 split the pair buffer into this many
    #                                 chunks (power of two) so chunk i+1's
    #                                 owner hops run while chunk i scores;
    #                                 ignored in "replicate" mode and on
    #                                 the delta_join="device" scoring path
    #                                 (its pairs rest in-mesh under the
    #                                 join plan's layout, which the exact
    #                                 per-chunk planner cannot see)

    def __post_init__(self):
        oc = self.overlap_chunks
        if oc < 1 or (oc & (oc - 1)):
            raise ValueError(
                f"overlap_chunks must be a power of two >= 1, got {oc}"
            )


class AnotherMeEngine:
    """Composable AnotherMe pipeline over a fixed semantic forest.

    One engine instance owns the forest tables, the candidate backend, the
    capacity planner, and (for sharded plans) a cache of compiled shard_map
    runners, so repeated ``run`` calls with identical static shapes reuse
    every jit cache.
    """

    def __init__(
        self,
        forest: SemanticForest,
        config: EngineConfig = EngineConfig(),
        plan: ExecutionPlan = ExecutionPlan(),
        *,
        backend: CandidateBackend | None = None,
    ):
        if plan.lcs_impl is not None:
            # the plan's override folds into the config so every stage —
            # single-device ScoreStage or the fused shard_map stage — reads
            # one authoritative lcs_impl
            config = dataclasses.replace(config, lcs_impl=plan.lcs_impl)
        validate_lcs_impl(config.lcs_impl)
        if plan.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {plan.n_shards}")
        oc = plan.overlap_chunks
        if oc < 1 or (oc & (oc - 1)):
            raise ValueError(
                f"overlap_chunks must be a power of two >= 1, got {oc}"
            )
        self.forest = forest
        self.config = config
        self.plan = plan
        self.tables = forest_tables(forest)
        self.betas = (
            jnp.asarray(config.betas, jnp.float32)
            if config.betas is not None
            else default_betas(forest.num_levels)
        )
        self.backend = backend if backend is not None else get_backend(
            config.backend, **dict(config.backend_options or {})
        )
        if plan.n_shards > 1 and not self.backend.supports_sharded:
            raise ValueError(
                f"candidate backend {self.backend.name!r} produces no join "
                "keys and only supports ExecutionPlan(n_shards=1); use a "
                "registered key-based backend for sharded execution"
            )
        if config.subtraj_window is not None:
            if config.subtraj_window < 1:
                raise ValueError(
                    f"subtraj_window must be positive, got "
                    f"{config.subtraj_window}"
                )
            if config.subtraj_stride < 1:
                raise ValueError(
                    f"subtraj_stride must be positive, got "
                    f"{config.subtraj_stride}"
                )
            if not self.backend.supports_sharded:
                raise ValueError(
                    f"candidate backend {self.backend.name!r} produces no "
                    "join keys; the subtrajectory mode needs key-based "
                    "candidates to carry (traj, offset) window coordinates"
                )
        self.backend_ctx = BackendContext(
            k=config.k, num_types=forest.num_types,
            window=config.subtraj_window, stride=config.subtraj_stride,
        )
        self.planner = CapacityPlanner(
            slack=config.capacity_slack, max_retries=config.max_retries,
            autotune=plan.autotune,
        )
        if plan.n_shards == 1:
            self._stages = (
                EncodeStage(), CandidateStage(), ScoreStage(), CommunitiesStage(),
            )
        else:
            # encoding folds into the shard_map program: no host EncodeStage
            self._stages = (
                _ShardedEncodeJoinScoreStage(self), CommunitiesStage(),
            )
        self._mesh = None
        self._runner_cache: dict = {}
        self._plan_cache: dict = {}

    # -- public entry point --------------------------------------------------

    def run(self, batch: TrajectoryBatch) -> EngineResult:
        """Run the full pipeline on one batch; same signature either way."""
        if self.plan.n_shards > 1:
            batch = self._padded(batch)
        ctx = PipelineContext(
            batch=batch, forest=self.forest, tables=self.tables,
            betas=self.betas, config=self.config, backend=self.backend,
            backend_ctx=self.backend_ctx, planner=self.planner,
            instr=Instrumentation(),
        )
        for stage in self._stages:
            stage.run(ctx)
        return EngineResult(
            scored=ctx.scored, similar_pairs=ctx.similar_pairs,
            communities=ctx.communities, stats=ctx.instr.finalize(),
        )

    # -- sharded-execution plumbing ------------------------------------------

    def _padded(self, batch: TrajectoryBatch) -> TrajectoryBatch:
        places, lengths = pad_to_shards(
            np.asarray(batch.places), np.asarray(batch.lengths),
            self.plan.n_shards,
        )
        if places.shape[0] == batch.num_trajectories:
            return batch
        return TrajectoryBatch(
            places=jnp.asarray(places), lengths=jnp.asarray(lengths),
            user_id=jnp.arange(places.shape[0], dtype=jnp.int32),
        )

    def mesh(self) -> jax.sharding.Mesh:
        if self._mesh is None:
            n = self.plan.n_shards
            devices = self.plan.devices or tuple(jax.devices())[:n]
            if len(devices) < n:
                raise ValueError(
                    f"ExecutionPlan(n_shards={n}) needs {n} devices, "
                    f"have {len(jax.devices())}"
                )
            self._mesh = compat.make_mesh(
                (n,), (self.plan.axis_name,), devices=devices
            )
        return self._mesh

    def _sharded_runner(self, dplan, key_fn, shapes, subtraj=None):
        from repro.core.similarity import wavefront_dtype_from_env

        # tuning resolves HERE — eagerly, at runner-build time — into
        # static kernel args (never inside the trace); a miss (autotune
        # off, no table, no matching cell) is None = untuned defaults
        tuning = self.planner.plan_tuning(
            dplan.pruned_cap or dplan.scored_cap,
            self.forest.num_levels, shapes[1][1],
        )
        # the runner build resolves REPRO_LCS_DTYPE (lcs_impl_fn); keying
        # the cache on the resolved dtype AND the tuning record keeps the
        # A/B probe and the tuning table live across runs of one engine,
        # matching the single-device path
        cache_key = (
            dplan, self.plan.score_mode, self.config.lcs_impl,
            self.config.score_prune, key_fn is None, shapes,
            wavefront_dtype_from_env(), tuning, subtraj,
        )
        runner = self._runner_cache.get(cache_key)
        if runner is None:
            runner = make_sharded_pipeline(
                self.mesh(), dplan, betas=self.betas, key_fn=key_fn,
                axis_name=self.plan.axis_name, score_mode=self.plan.score_mode,
                lcs_impl=self.config.lcs_impl,
                score_prune=self.config.score_prune,
                prune_tau=self.config.rho,
                tuning=tuning,
                subtraj=subtraj,
            )
            self._runner_cache[cache_key] = runner
        return runner


class _ShardedEncodeJoinScoreStage:
    """Encode + Candidate + Score fused into one shard_map program (Fig. 5).

    The device program is fully resident: raw places are sharded once,
    encoding runs in-mesh, and the code table never transits the host.
    Capacity planning works from the coarsest-level ("type") view only — a
    single [N, L] host gather, the driver's statistics pass — from which the
    backend's actual join keys are built (plan_sharded); key-producing
    backends rebuild keys on-device per shard, key-less ones ("udf") have
    their host keys shuffled in.  A capacity bust retries with doubled
    buffers, like the single-device planner.
    """

    name = "sharded_encode_join_score"

    def __init__(self, engine: AnotherMeEngine):
        self.engine = engine

    def run(self, ctx: PipelineContext) -> None:
        eng = self.engine
        plan, config, instr = eng.plan, eng.config, ctx.instr

        # subtrajectory mode: (window, stride, nw) from the PADDED length —
        # static shape facts every layer below keys its caches on
        subtraj = None
        if config.subtraj_window is not None:
            from repro.core.subtraj import num_windows

            L = int(ctx.batch.places.shape[1])
            subtraj = (
                min(config.subtraj_window, L), config.subtraj_stride,
                num_windows(L, config.subtraj_window, config.subtraj_stride),
            )

        with instr.phase("keys"):
            # coarsest-level view for planning only: [N, L], not the
            # [N, n_levels, L] code table (which stays device-resident)
            types = encode_types(ctx.batch.places, ctx.tables)
            plan_encoded = EncodedBatch(codes=types[:, None, :],
                                        lengths=ctx.batch.lengths)
            keys = ctx.backend.join_keys(plan_encoded, ctx.batch,
                                         ctx.backend_ctx)
            keys_np = np.asarray(keys)
        ctx.keys = keys

        # plan capacities host-side once per distinct key matrix; warm runs
        # (same data) skip the numpy planning pass and any retry doublings
        with instr.phase("plan"):
            plan_key = (keys_np.shape, hash(keys_np.tobytes()),
                        plan.score_mode, subtraj)
            dplan = eng._plan_cache.get(plan_key)
            if dplan is None:
                prune_kw = {}
                if config.score_prune:
                    # windowed pairs prune on per-WINDOW lengths: the key
                    # matrix has one row per window, and the MSS bound of a
                    # window pair is betas_sum * min of the window lengths
                    if subtraj is None:
                        lengths_np = np.asarray(ctx.batch.lengths)
                    else:
                        from repro.core.subtraj import window_lengths

                        lengths_np = window_lengths(
                            np.asarray(ctx.batch.lengths),
                            max_len=int(ctx.batch.places.shape[1]),
                            window=subtraj[0], stride=subtraj[1],
                        )
                    prune_kw = dict(
                        lengths_np=lengths_np,
                        prune_tau=config.rho,
                        betas_sum=float(np.asarray(eng.betas, np.float32).sum()),
                    )
                dplan = eng.planner.plan_sharded(
                    keys_np, plan.n_shards, slack=plan.shard_slack,
                    score_mode=plan.score_mode,
                    overlap_chunks=plan.overlap_chunks,
                    windows_per_row=1 if subtraj is None else subtraj[2],
                    **prune_kw,
                )
        key_fn = ctx.backend.shard_key_fn(ctx.backend_ctx)

        with instr.phase("execute"):
            out, dplan = self._execute(ctx, dplan, key_fn, keys_np, subtraj)
        eng._plan_cache[plan_key] = dplan
        instr.record(
            shard_plan=dataclasses.asdict(dplan),
            join_overflow=int(np.asarray(out["overflow"]).sum()),
        )
        if config.score_prune:
            instr.record(num_pruned=int(np.asarray(out["pruned"]).sum()))

        left = np.asarray(out["left"]).reshape(-1)
        right = np.asarray(out["right"]).reshape(-1)
        mss = np.asarray(out["mss"]).reshape(-1)
        level_lcs = np.asarray(out["level_lcs"])
        level_lcs = level_lcs.reshape(-1, level_lcs.shape[-1])
        valid = left != PAD_ID
        if subtraj is not None:
            # fold scored window pairs to trajectory pairs (max-over-
            # windows) before anything downstream sees them — communities,
            # similar_pairs, and the returned scored buffer all speak
            # trajectory ids
            from repro.core.subtraj import aggregate_window_pairs

            tl, tr, tlvl, tmss = aggregate_window_pairs(
                left, right, level_lcs, mss, nw=subtraj[2]
            )
            ctx.scored = ScoredPairs(
                left=jnp.asarray(tl), right=jnp.asarray(tr),
                level_lcs=jnp.asarray(tlvl), mss=jnp.asarray(tmss),
                count=jnp.asarray(tl.shape[0], jnp.int32),
                overflow=jnp.asarray(
                    int(np.asarray(out["overflow"]).sum()), jnp.int32),
            )
            ctx.similar_pairs = {
                (int(a), int(b))
                for a, b, m in zip(tl, tr, tmss)
                if m > np.float32(config.rho)
            }
            instr.record(
                num_candidates=int(valid.sum()),
                num_window_pairs=int(valid.sum()),
                num_traj_pairs=int(tl.shape[0]),
                num_similar=len(ctx.similar_pairs),
                subtraj_windows=subtraj[2],
            )
            return
        ctx.scored = ScoredPairs(
            left=jnp.asarray(left), right=jnp.asarray(right),
            level_lcs=jnp.asarray(level_lcs), mss=jnp.asarray(mss),
            count=jnp.asarray(int(valid.sum()), jnp.int32),
            overflow=jnp.asarray(int(np.asarray(out["overflow"]).sum()), jnp.int32),
        )
        ctx.similar_pairs = gather_similar_pairs(out, rho=config.rho)
        instr.record(
            num_candidates=int(valid.sum()),
            num_similar=len(ctx.similar_pairs),
        )

    def _execute(self, ctx, dplan, key_fn, keys_np, subtraj=None):
        eng = self.engine
        batch = ctx.batch
        first = jnp.asarray(keys_np) if key_fn is None else batch.places
        shapes = (first.shape, batch.places.shape, ctx.tables.shape)
        for attempt in range(eng.planner.max_retries + 1):
            runner = eng._sharded_runner(dplan, key_fn, shapes, subtraj)
            out = runner(first, batch.places, batch.lengths, ctx.tables)
            out["mss"].block_until_ready()
            if int(np.asarray(out["overflow"]).sum()) == 0:
                break
            if attempt < eng.planner.max_retries:
                dplan = dataclasses.replace(
                    dplan,
                    shingle_route_cap=dplan.shingle_route_cap * 2,
                    local_pair_cap=dplan.local_pair_cap * 2,
                    pair_route_cap=dplan.pair_route_cap * 2,
                    scored_cap=dplan.scored_cap * 2,
                    owner_route_cap=dplan.owner_route_cap * 2,
                    pruned_cap=dplan.pruned_cap * 2,
                    chunk_hop_cap=dplan.chunk_hop_cap * 2,
                    chunk_rest_cap=dplan.chunk_rest_cap * 2,
                )
        return out, dplan
