"""Shared capacity planning for fixed-shape candidate buffers.

Every candidate join in the engine writes into a static ``pair_capacity``
buffer (DESIGN.md: Spark's dynamic memory traded for deterministic
compilable shapes).  The policy — size from the exact join cardinality with
slack, round to a power of two so jit caches hit across batches, retry with
doubled capacity on overflow — used to live inline in ``run_anotherme``;
it is now one object shared by every backend and both execution modes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.types import CandidatePairs


@dataclasses.dataclass(frozen=True)
class CapacityPlanner:
    """Capacity sizing + overflow-retry policy for candidate buffers.

    slack:       multiplicative headroom over the expected pair count.
    floor_pow2:  minimum capacity is ``2**floor_pow2`` (keeps tiny worlds
                 from generating one jit cache entry per batch size).
    max_retries: doubling retries after an overflow before giving up.
    autotune:    consult the cached :mod:`repro.perf` tuning table when
                 planning score-stage kernel parameters (block sizes,
                 diagonal dtypes).  Off by default: with no table on disk
                 the lookup is a silent no-op, but plans should not even
                 probe the filesystem unless asked.
    """

    slack: float = 1.10
    floor_pow2: int = 10
    max_retries: int = 3
    autotune: bool = False

    def initial_capacity(self, expected_pairs: int) -> int:
        """Power-of-two capacity covering ``expected_pairs`` with slack."""
        want = max(int(expected_pairs * self.slack), 1)
        return 1 << max(self.floor_pow2, int(np.ceil(np.log2(want))))

    def update_capacity(self, count: int, *, floor_pow2: int = 4) -> int:
        """Power-of-two capacity for one streaming micro-batch's buffers.

        Like :meth:`initial_capacity` but with a small floor: per-update
        delta buffers (new rows to append, delta pairs to score) should cost
        O(delta), not O(2**floor_pow2) of the world-sized policy — while
        still quantizing to powers of two so consecutive updates of similar
        size reuse every jit cache.
        """
        want = max(int(max(count, 1) * self.slack), 1)
        return 1 << max(floor_pow2, int(np.ceil(np.log2(want))))

    def grow_capacity(self, current: int, needed: int) -> int:
        """Amortized-doubling growth plan for an append-only world buffer.

        Returns ``current`` unchanged while it covers ``needed``; otherwise
        the smallest power-of-two doubling of ``current`` that does.  Every
        grow at least doubles, so N appended rows trigger O(log N)
        reallocations (and O(log N) recompilations of the world-shaped
        programs) with total copy cost O(N) — the classic dynamic-array
        amortization, applied to device-resident buffers where each
        reallocation also invalidates a jit cache entry.
        """
        cap = max(current, 1)
        while cap < needed:
            cap *= 2
        return cap

    def run_with_retry(
        self, build: Callable[[int], CandidatePairs], capacity: int
    ) -> tuple[CandidatePairs, int]:
        """Call ``build(capacity)``, doubling capacity while it overflows.

        Returns (candidates, final_capacity).  A persistent overflow after
        ``max_retries`` doublings is returned as-is — the overflow counter
        stays nonzero so the caller can surface it, never silently drop it.
        """
        cand = build(capacity)
        for _ in range(self.max_retries):
            if int(cand.overflow) == 0:
                break
            capacity *= 2
            cand = build(capacity)
        return cand, capacity

    def plan_tuning(self, pairs: int, levels: int, length: int):
        """Tuned LCS kernel parameters for a score stage of this shape.

        Returns the cached :class:`repro.perf.LCSTuning` for the
        ``(pairs, levels, length)`` cell (nearest-P fallback) when
        ``autotune=True`` and the table has a usable entry, else ``None``
        — callers keep their defaults.  Like every tuning consultation
        this resolves EAGERLY at plan/build time, never inside a trace:
        the result becomes static kernel arguments, so autotuning can
        change throughput but never shapes, traces, or results.
        """
        if not self.autotune:
            return None
        from repro.perf import TuningTable

        return TuningTable.load().lookup(pairs, levels, length)

    def plan_sharded(
        self,
        keys_np,
        n_shards: int,
        *,
        slack: float | None = None,
        score_mode: str = "replicate",
        lengths_np=None,
        prune_tau: float | None = None,
        betas_sum: float = 1.0,
        overlap_chunks: int = 1,
        windows_per_row: int = 1,
    ):
        """Exact per-bucket capacity plan for the sharded (shard_map) path.

        Delegates to :func:`repro.api.sharded.plan_capacities`, which sizes
        every stage — shuffle 1, the local join, the pair-dedup shuffle and
        (for ``score_mode="shuffle"``) the per-owner code-gather hops — from
        actual per-destination loads under the device's own hashes, not a
        uniform-hash bound.  ``slack`` defaults to this planner's slack.

        With ``prune_tau``/``lengths_np`` the plan additionally sizes the
        post-prune pair buffer (``DistributedPlan.pruned_cap``) from the
        exact per-shard survivor counts of the MSS upper-bound pruning
        pass.

        ``windows_per_row > 1`` declares subtrajectory keys (one key row
        per sliding window, ``nw`` windows per trajectory): loads stay
        per-window, shard ownership stays per-trajectory, and
        ``lengths_np`` must then be per-window lengths.
        """
        from repro.api.sharded import plan_capacities

        return plan_capacities(
            keys_np, n_shards,
            slack=self.slack if slack is None else slack,
            score_mode=score_mode,
            lengths_np=lengths_np, prune_tau=prune_tau, betas_sum=betas_sum,
            overlap_chunks=overlap_chunks, windows_per_row=windows_per_row,
        )

    def plan_stream_join(
        self, keys_flat, n_shards: int, stats, *, floor_pow2: int = 4
    ):
        """Exact per-owner capacity plan for the in-mesh streaming delta
        join (``delta_join="device"``).

        Delegates to :func:`repro.api.sharded.plan_stream_join`: the
        bucket-slab, key-route and probe buffers are sized from the exact
        per-owner loads the :class:`~repro.core.device_index.StreamJoinStats`
        count mirror derives under the device's own key hash, and the two
        pair-stage buffers from the pre-dedup emission totals (a safe
        bound on post-dedup skew).  Capacities quantize to powers of two;
        the streaming engine keeps them sticky across updates so the
        compiled join program is reused — zero steady-state recompiles.
        """
        from repro.api.sharded import plan_stream_join

        return plan_stream_join(
            keys_flat, n_shards, stats, floor_pow2=floor_pow2
        )

    def plan_query(
        self,
        num_queries: int,
        k_max: int,
        *,
        n_shards: int,
        cap_local: int,
        world_L: int,
        q_len_max: int,
        cand_total=None,
        keys_flat=None,
        stats=None,
        floor_pow2: int = 2,
    ):
        """Exact capacity plan for one query-serving micro-batch.

        Delegates to :func:`repro.api.serving.plan_query_capacities`: the
        query, top-k and candidate buffers are sized from the exact
        candidate cardinality — the host BucketIndex probe's count
        (``cand_total``) or, for device-resident worlds, the per-owner
        new-vs-old loads the :class:`~repro.core.device_index.StreamJoinStats`
        mirror derives from ``keys_flat``/``stats``.  Capacities quantize
        to powers of two; :class:`QueryEngine` keeps them sticky across
        micro-batches so both compiled serving programs are reused —
        zero steady-state recompiles under query traffic.
        """
        from repro.api.serving import plan_query_capacities

        return plan_query_capacities(
            num_queries, k_max, n_shards=n_shards, cap_local=cap_local,
            world_L=world_L, q_len_max=q_len_max, cand_total=cand_total,
            keys_flat=keys_flat, stats=stats, floor_pow2=floor_pow2,
        )
