"""Phase timing/stats collection, separated from phase logic.

The legacy driver interleaved ``time.perf_counter()`` stamps with the phase
code itself, which made the phases impossible to reuse (and misattributed
baseline hash cost to the shingle phase — ISSUE 1).  The engine's stages are
pure; all wall timing goes through this wrapper, so the same stage objects
are jit-cacheable across repeated ``engine.run`` calls with identical
static shapes.

Stats key conventions (superset of the legacy ``run_anotherme`` keys):

  t_encode       phase (i)   semantic encoding
  t_keys         phase (ii)a join-key construction (shingles / signatures /
                             projections; 0 for callable backends)
  t_join         phase (ii)b sort-merge join + dedup (+ overflow retries)
  t_candidates   t_keys + t_join — the full candidate-generation cost,
                 correct for every backend (fixes the Fig. 9 misattribution)
  t_score        phase (iii) similarity scoring
  t_communities  phase (iv)  community detection
  t_total        sum of every t_* phase above
  t_shingle      legacy alias of t_keys (kept for old consumers)

Sharded runs fuse the join and score phases into one shard_map program;
they record ``t_plan`` (host capacity planning) and ``t_execute`` (the fused
device program) instead of ``t_join``/``t_score``, and ``t_candidates``
then covers keys + plan + execute (``t_score`` reads 0.0 — the score cost
is inside ``t_execute`` and cannot be split without extra device syncs).
"""
from __future__ import annotations

import contextlib
import time


class Instrumentation:
    """Collects per-phase wall times and scalar stats for one run."""

    def __init__(self) -> None:
        self.stats: dict = {}

    @contextlib.contextmanager
    def phase(self, name: str):
        """Time a phase; re-entering the same name accumulates."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            key = f"t_{name}"
            self.stats[key] = self.stats.get(key, 0.0) + time.perf_counter() - t0

    def record(self, **values) -> None:
        self.stats.update(values)

    def finalize(self) -> dict:
        """Derive the composite keys and return the stats dict."""
        s = self.stats
        s.setdefault("t_keys", 0.0)
        if "t_join" in s:
            s["t_candidates"] = s["t_keys"] + s["t_join"]
        elif "t_execute" in s:  # sharded: join+score fused into one program
            s["t_candidates"] = (
                s["t_keys"] + s.get("t_plan", 0.0) + s["t_execute"]
            )
            s.setdefault("t_score", 0.0)
        s["t_shingle"] = s["t_keys"]  # legacy alias
        s["t_total"] = sum(
            v for k, v in s.items()
            if k.startswith("t_") and k not in ("t_total", "t_candidates", "t_shingle")
        )
        return s
