"""Typed pipeline stages: Encode -> Candidate -> Score -> Communities.

Each stage is a small object with a ``run(ctx)`` method that reads and
writes one :class:`PipelineContext`.  Stages hold no timing code (that is
the instrumentation wrapper's job) and no capacity policy (that is the
planner's), so the same stage objects serve the single-device engine, the
sharded engine (which swaps the middle stages for a fused shard_map stage,
see api/sharded.py), and any future composition.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol

import jax.numpy as jnp
import numpy as np

from repro.api.backends import BackendContext, CandidateBackend
from repro.api.capacity import CapacityPlanner
from repro.api.instrumentation import Instrumentation
import repro.core.communities as comm
from repro.core.encoding import (
    PAD_CODE_A, PAD_CODE_B, SemanticForest, encode_batch,
)
from repro.core.similarity import (
    PRUNE_EPS, mss_scores, mss_upper_bound, repad, score_pairs,
    wavefront_dtype_from_env,
)
from repro.core.ssh import ssh_candidates
from repro.core.types import (
    CandidatePairs, EncodedBatch, PAD_ID, ScoredPairs, TrajectoryBatch,
)
from repro.kernels.lcs.fused import FUSED_IMPL_MODES

LCS_IMPLS = (
    "wavefront", "ref", "kernel", "pallas", "pallas-interpret",
    "fused", "fused-pallas", "fused-interpret",
)

# kernel-family impls map to a dispatch mode of kernels/lcs/ops.py:
#   "kernel"           auto (wavefront for tiny batches off-TPU)
#   "pallas"           forced Pallas dispatch (interpret off-TPU)
#   "pallas-interpret" forced Pallas dispatch, interpreter everywhere
_KERNEL_MODES = {"kernel": "auto", "pallas": "pallas", "pallas-interpret": "interpret"}

# fused-family impls map to a dispatch mode of kernels/lcs/fused.py: the
# gather-free scalar-prefetch kernel that scores pairs straight out of the
# resident code table (no [P, H, L] operand materialization).  The mapping
# lives with the kernel (one place to add a variant); this is a re-export.
FUSED_MODES = FUSED_IMPL_MODES


def validate_lcs_impl(name: str) -> str:
    if name not in LCS_IMPLS:
        raise ValueError(
            f"unknown lcs_impl {name!r}; valid implementations: {list(LCS_IMPLS)}"
        )
    return name


def lcs_impl_fn(name: str, tuning=None):
    """jax-traceable batched LCS ``(a [B,L], b [B,L]) -> [B]`` for an impl name.

    Shared by the single-device score stage and the sharded shard_map score
    stage, so ``lcs_impl`` selects the same implementation on both paths.
    The fused family takes the code table plus pair indices rather than
    gathered operands, so it has no pairwise form — callers route it through
    ``kernels/lcs/fused.fused_score`` (see FUSED_MODES) instead.

    ``tuning`` is an optional :class:`repro.perf.LCSTuning` record (from
    ``CapacityPlanner.plan_tuning``), resolved HERE — at the call boundary,
    eagerly, exactly like the REPRO_LCS_DTYPE probe — into static kernel
    arguments (``block_b`` cap, wavefront dtype).  The returned closure
    carries only static values, so a tuned impl traces identically to an
    untuned one modulo those constants.
    """
    validate_lcs_impl(name)
    if name in FUSED_MODES:
        raise ValueError(
            f"lcs_impl {name!r} is table-indexed (gather-free); it has no "
            "pairwise (a, b) form — dispatch through "
            "repro.kernels.lcs.fused.fused_score"
        )
    if name in _KERNEL_MODES:
        from repro.kernels.lcs import ops as lcs_ops
        from repro.perf import resolve_wavefront_dtype

        mode = _KERNEL_MODES[name]
        dt = resolve_wavefront_dtype(tuning)  # env pin > tuned > default
        kwargs = {} if tuning is None else {"block_b": tuning.block_b}
        return lambda a, b: lcs_ops.lcs(
            a, b, mode=mode, wavefront_dtype=dt, **kwargs
        )
    from repro.core.similarity import lcs_ref, lcs_wavefront
    from repro.perf import resolve_wavefront_dtype

    if name == "ref":
        return lcs_ref
    dt = resolve_wavefront_dtype(tuning)
    return lambda a, b: lcs_wavefront(a, b, dtype=dt)


@dataclasses.dataclass
class PipelineContext:
    """Mutable blackboard the stages read from / write to."""

    batch: TrajectoryBatch
    forest: SemanticForest
    tables: Any
    betas: jnp.ndarray
    config: Any                   # EngineConfig (kept untyped: no cycle)
    backend: CandidateBackend
    backend_ctx: BackendContext
    planner: CapacityPlanner
    instr: Instrumentation
    # stage outputs
    encoded: EncodedBatch | None = None
    keys: jnp.ndarray | None = None
    candidates: CandidatePairs | None = None
    scored: ScoredPairs | None = None
    similar_pairs: set | None = None
    communities: set | None = None


class Stage(Protocol):
    name: str

    def run(self, ctx: PipelineContext) -> None: ...


class EncodeStage:
    """Phase (i): multi-level semantic encoding of the batch."""

    name = "encode"

    def run(self, ctx: PipelineContext) -> None:
        with ctx.instr.phase("encode"):
            ctx.encoded = encode_batch(ctx.batch, ctx.tables)
            ctx.encoded.codes.block_until_ready()


class CandidateStage:
    """Phase (ii): join keys + candidate pairs via the configured backend.

    Key-based backends go through the shared sort-merge join with planned
    capacity and overflow retries; key-less backends (legacy callables)
    produce CandidatePairs directly.
    """

    name = "candidates"

    def run(self, ctx: PipelineContext) -> None:
        backend, instr = ctx.backend, ctx.instr
        with instr.phase("keys"):
            keys = backend.join_keys(ctx.encoded, ctx.batch, ctx.backend_ctx)
            if keys is not None:
                keys = jnp.asarray(keys)
                keys.block_until_ready()
        ctx.keys = keys

        with instr.phase("join"):
            if keys is None:
                cap = ctx.config.pair_capacity or 0
                cand = backend.candidates(
                    ctx.encoded, ctx.batch, ctx.backend_ctx, pair_capacity=cap
                )
            else:
                cap = ctx.config.pair_capacity
                if cap is None:
                    cap = ctx.planner.initial_capacity(backend.expected_pairs(keys))
                cand, cap = ctx.planner.run_with_retry(
                    lambda c: ssh_candidates(keys, pair_capacity=c), cap
                )
            cand.left.block_until_ready()
        ctx.candidates = cand
        instr.record(
            pair_capacity=int(cand.left.shape[0]) if keys is None else cap,
            num_candidates=int(cand.count),
            join_overflow=int(cand.overflow),
        )


class ScoreStage:
    """Phase (iii): multi-level LCS + MSS scoring, then the rho threshold.

    With ``config.score_prune`` the stage first runs the MSS upper-bound
    pruning pass (REPOSE-style): pairs whose free bound
    ``sum_h beta_h * min(len_a, len_b)`` cannot clear ``rho`` are compacted
    away before exact scoring, into a buffer the CapacityPlanner sizes from
    the survivor count — the pruned pairs never touch a kernel.
    """

    name = "score"

    def run(self, ctx: PipelineContext) -> None:
        cfg, cand = ctx.config, ctx.candidates
        impl = validate_lcs_impl(cfg.lcs_impl)
        L = int(ctx.encoded.codes.shape[2])
        subtraj = _subtraj_of(cfg, L)
        if getattr(cfg, "score_prune", False):
            with ctx.instr.phase("prune"):
                if subtraj is None:
                    prune_lengths = ctx.encoded.lengths
                else:
                    # windowed candidates index per-WINDOW lengths: the MSS
                    # bound of a window pair is betas_sum * min(wlen_a, wlen_b)
                    from repro.core.subtraj import window_lengths

                    prune_lengths = window_lengths(
                        np.asarray(ctx.encoded.lengths), max_len=L,
                        window=subtraj[0], stride=subtraj[1],
                    )
                cand, num_pruned = prune_candidates(
                    cand, prune_lengths, ctx.betas, cfg.rho, ctx.planner
                )
            ctx.candidates = cand
            ctx.instr.record(
                num_pruned=num_pruned,
                post_prune_capacity=int(cand.left.shape[0]),
            )
        with ctx.instr.phase("score"):
            # tuning is consulted HERE — eager, outside any trace — and
            # becomes static kernel args; None keeps the untuned defaults
            P = int(cand.left.shape[0])
            H = int(ctx.encoded.codes.shape[1])
            tuning = ctx.planner.plan_tuning(P, H, L)
            if subtraj is not None:
                level_lcs, mss = _score_windowed(
                    ctx.encoded, cand, ctx.betas, impl, subtraj, tuning
                )
            elif impl in _KERNEL_MODES:
                level_lcs, mss = _score_with_kernel(
                    ctx.encoded, cand, ctx.betas,
                    mode=_KERNEL_MODES[impl], tuning=tuning,
                )
            else:
                from repro.perf import resolve_wavefront_dtype

                level_lcs, mss = score_pairs(
                    ctx.encoded.codes, ctx.encoded.lengths,
                    cand.left, cand.right, ctx.betas, impl_name=impl,
                    wavefront_dtype=resolve_wavefront_dtype(tuning),
                )
            mss.block_until_ready()

        if subtraj is not None:
            # fold scored window pairs to trajectory pairs (max-over-
            # windows); downstream stages and the result speak traj ids
            from repro.core.subtraj import aggregate_window_pairs

            tl, tr, tlvl, tmss = aggregate_window_pairs(
                cand.left, cand.right, level_lcs, mss, nw=subtraj[2]
            )
            ctx.similar_pairs = {
                (int(a), int(b))
                for a, b, m in zip(tl, tr, tmss)
                if m > np.float32(cfg.rho)
            }
            ctx.scored = ScoredPairs(
                left=jnp.asarray(tl), right=jnp.asarray(tr),
                level_lcs=jnp.asarray(tlvl), mss=jnp.asarray(tmss),
                count=jnp.asarray(tl.shape[0], jnp.int32),
                overflow=cand.overflow,
            )
            ctx.instr.record(
                num_window_pairs=int(cand.count),
                num_traj_pairs=int(tl.shape[0]),
                num_similar=len(ctx.similar_pairs),
                subtraj_windows=subtraj[2],
            )
            return

        left_np = np.asarray(cand.left)
        right_np = np.asarray(cand.right)
        similar_mask = (left_np != PAD_ID) & (np.asarray(mss) > cfg.rho)
        ctx.similar_pairs = {
            (int(a), int(b))
            for a, b in zip(left_np[similar_mask], right_np[similar_mask])
        }
        ctx.scored = ScoredPairs(
            left=cand.left, right=cand.right, level_lcs=level_lcs, mss=mss,
            count=cand.count, overflow=cand.overflow,
        )
        ctx.instr.record(num_similar=len(ctx.similar_pairs))


class CommunitiesStage:
    """Phase (iv): communities of interest from the similar-pair graph.

    Operates on the host-side similar-pair set, so it is shared verbatim by
    the single-device and sharded execution paths.
    """

    name = "communities"

    def run(self, ctx: PipelineContext) -> None:
        cfg = ctx.config
        pairs = ctx.similar_pairs
        with ctx.instr.phase("communities"):
            if cfg.community_mode == "cliques":
                ctx.communities = comm.maximal_cliques(pairs)
            elif cfg.community_mode == "components":
                if pairs:
                    sl, sr = map(np.asarray, zip(*sorted(pairs)))
                else:
                    sl = sr = np.empty((0,), np.int32)
                labels = comm.connected_components(
                    jnp.asarray(sl, jnp.int32), jnp.asarray(sr, jnp.int32),
                    num_nodes=ctx.batch.num_trajectories,
                )
                ctx.communities = comm.components_as_sets(np.asarray(labels))
            else:
                raise ValueError(
                    f"unknown community_mode {cfg.community_mode!r}; "
                    "valid modes: ['cliques', 'components']"
                )
        ctx.instr.record(num_communities=len(ctx.communities))


def prune_candidates(
    cand: CandidatePairs,
    lengths,
    betas,
    tau: float,
    planner: CapacityPlanner,
) -> tuple[CandidatePairs, int]:
    """MSS upper-bound pruning: drop pairs that cannot reach ``tau``.

    The bound is free — ``sum_h beta_h * min(len_a, len_b)`` needs lengths
    only — and safe: ``MSS <= bound``, so a dropped pair can never satisfy
    ``mss > tau`` (a PRUNE_EPS of slack keeps exact-threshold ties on the
    scored side).  Survivors are compacted to the front of a fresh buffer
    sized by the planner from the survivor count, so the exact-scoring
    kernel downstream runs over the post-prune pair set, not the full
    candidate buffer.  Returns (compacted candidates, number pruned).
    """
    left = np.asarray(cand.left)
    right = np.asarray(cand.right)
    lengths = np.asarray(lengths)
    valid = left != PAD_ID
    safe_l = np.where(valid, left, 0)
    safe_r = np.where(valid, right, 0)
    bsum = float(np.asarray(betas, np.float32).sum())
    ub = mss_upper_bound(lengths[safe_l], lengths[safe_r], bsum)
    keep = valid & (ub > np.float32(tau - PRUNE_EPS))
    idx = np.nonzero(keep)[0]
    cap = planner.initial_capacity(len(idx))
    new_left = np.full((cap,), PAD_ID, np.int32)
    new_right = np.full((cap,), PAD_ID, np.int32)
    new_left[: len(idx)] = left[idx]
    new_right[: len(idx)] = right[idx]
    pruned = CandidatePairs(
        left=jnp.asarray(new_left), right=jnp.asarray(new_right),
        count=jnp.asarray(len(idx), jnp.int32), overflow=cand.overflow,
    )
    return pruned, int(valid.sum()) - len(idx)


def _subtraj_of(cfg, max_len: int):
    """``(window, stride, nw)`` of the subtrajectory mode, or None.

    The effective window caps at the padded length (W >= L degenerates to
    whole-trajectory) and ``nw`` derives from the PADDED length, so the
    triple is a static shape fact (see repro.core.subtraj)."""
    if getattr(cfg, "subtraj_window", None) is None:
        return None
    from repro.core.subtraj import num_windows

    return (
        min(cfg.subtraj_window, max_len), cfg.subtraj_stride,
        num_windows(max_len, cfg.subtraj_window, cfg.subtraj_stride),
    )


def _score_windowed(encoded, cand, betas, impl, subtraj, tuning):
    """Windowed dispatch: pair ids are window ids; every impl family
    scores the windowed [H, W] slices (fused masks in-kernel, the kernel
    family slices via ``lcs_windowed``, jnp impls gather windows)."""
    from repro.perf import resolve_wavefront_dtype

    if impl in _KERNEL_MODES:
        return _score_windowed_with_kernel(
            encoded, cand, betas, subtraj=subtraj,
            mode=_KERNEL_MODES[impl], tuning=tuning,
        )
    from repro.core.similarity import score_windowed_pairs

    W, stride, nw = subtraj
    return score_windowed_pairs(
        encoded.codes, encoded.lengths, cand.left, cand.right, betas,
        nw=nw, window=W, stride=stride, impl_name=impl,
        wavefront_dtype=resolve_wavefront_dtype(tuning),
    )


def _score_windowed_with_kernel(encoded, cand, betas, *, subtraj,
                                mode="auto", tuning=None):
    """Windowed twin of :func:`_score_with_kernel`: decode (traj, offset)
    from the window ids and run the batched kernel over the sliced
    ``[P*H, W]`` windows (``kernels/lcs/ops.lcs_windowed``)."""
    from repro.kernels.lcs import ops as lcs_ops
    from repro.perf import resolve_wavefront_dtype

    W, stride, nw = subtraj
    li = jnp.where(cand.left == PAD_ID, 0, cand.left)
    ri = jnp.where(cand.right == PAD_ID, 0, cand.right)
    ta, tb = li // nw, ri // nw
    oa = (li % nw).astype(jnp.int32) * stride
    ob = (ri % nw).astype(jnp.int32) * stride
    P = li.shape[0]
    H, L = encoded.codes.shape[1], encoded.codes.shape[2]
    rep = lambda x: jnp.repeat(x, H)
    kwargs = {} if tuning is None else {"block_b": tuning.block_b}
    level_lcs = lcs_ops.lcs_windowed(
        encoded.codes[ta].reshape(P * H, L),
        encoded.codes[tb].reshape(P * H, L),
        rep(oa), rep(ob),
        rep(encoded.lengths[ta]), rep(encoded.lengths[tb]),
        window=W, mode=mode,
        wavefront_dtype=resolve_wavefront_dtype(tuning), **kwargs,
    ).reshape(P, H)
    return level_lcs, mss_scores(level_lcs, betas)


def _score_with_kernel(encoded, cand, betas, *, mode="auto", tuning=None):
    """Score candidates with the Pallas LCS kernel (kernels/lcs).

    ``tuning`` (an optional LCSTuning) supplies a tuned ``block_b`` cap and
    wavefront dtype as static dispatch args; None keeps the defaults.
    """
    from repro.kernels.lcs import ops as lcs_ops
    from repro.perf import resolve_wavefront_dtype

    li = jnp.where(cand.left == PAD_ID, 0, cand.left)
    ri = jnp.where(cand.right == PAD_ID, 0, cand.right)
    P = li.shape[0]
    H, L = encoded.codes.shape[1], encoded.codes.shape[2]
    a = repad(encoded.codes[li], encoded.lengths[li], PAD_CODE_A).reshape(P * H, L)
    b = repad(encoded.codes[ri], encoded.lengths[ri], PAD_CODE_B).reshape(P * H, L)
    kwargs = {} if tuning is None else {"block_b": tuning.block_b}
    level_lcs = lcs_ops.lcs(
        a, b, mode=mode, wavefront_dtype=resolve_wavefront_dtype(tuning), **kwargs
    ).reshape(P, H)
    return level_lcs, mss_scores(level_lcs, betas)
