"""Architecture registry: --arch <id> -> ModelConfig.

Every assigned architecture from the public pool, with the exact published
hyperparameters from the assignment table ([source] given per config file).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


def _load_all():
    import repro.configs.mamba2_1p3b      # noqa: F401
    import repro.configs.kimi_k2_1t_a32b  # noqa: F401
    import repro.configs.deepseek_v2_236b # noqa: F401
    import repro.configs.zamba2_2p7b      # noqa: F401
    import repro.configs.granite_3_8b     # noqa: F401
    import repro.configs.mistral_nemo_12b # noqa: F401
    import repro.configs.minicpm3_4b      # noqa: F401
    import repro.configs.qwen1p5_110b     # noqa: F401
    import repro.configs.hubert_xlarge    # noqa: F401
    import repro.configs.internvl2_76b    # noqa: F401
