"""zamba2-2.7b [hybrid] — Mamba-2 backbone + shared attention block
[arXiv:2411.15242; hf].

54 Mamba-2 layers, d_model=2560, ssm_state=64, with a parameter-shared
attention+MLP block (32 MHA heads, d_ff=10240) applied every 6 layers.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10_240,
        vocab_size=32_000,
        attn="gqa",
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        shared_attn_every=6,
    )
)
