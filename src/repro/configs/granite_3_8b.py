"""granite-3-8b [dense] — GQA [hf:ibm-granite/granite-3.0-8b-base].

40L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=12800 vocab=49155.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="granite-3-8b",
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12_800,
        vocab_size=49_155,
        attn="gqa",
    )
)
