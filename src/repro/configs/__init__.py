from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable
from repro.configs.registry import get_config, all_archs
