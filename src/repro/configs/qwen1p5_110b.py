"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5-110B].

80L d_model=8192 64H (GQA kv=8, head_dim=128) d_ff=49152 vocab=152064.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=49_152,
        vocab_size=152_064,
        attn="gqa",
        qkv_bias=True,
    )
)
