"""hubert-xlarge [audio] — encoder-only [arXiv:2106.07447].

48L d_model=1280 16H (MHA kv=16, head_dim=80) d_ff=5120 vocab=504
(masked-prediction cluster targets).  The conv waveform frontend is a STUB:
input_specs() provides precomputed frame embeddings [B, S, d_model].
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        attn="gqa",
        causal=False,
        frontend="audio",
    )
)
