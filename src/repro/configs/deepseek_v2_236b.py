"""deepseek-v2-236b [moe] — MLA + fine-grained MoE [arXiv:2405.04434; hf].

60L d_model=5120 128H, MLA kv_lora_rank=512 (q_lora 1536, qk_nope 128,
qk_rope 64, v_head 128), d_ff=1536 per routed expert, vocab=102400,
MoE 2 shared + 160 routed top-6.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=0,
        vocab_size=102_400,
        attn="mla",
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        num_experts=160,
        experts_per_token=6,
        num_shared_experts=2,
        moe_d_ff=1536,
    )
)
