"""kimi-k2-1t-a32b [moe] — Kimi K2, trillion-param MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8, head_dim=112) d_ff=2048 per expert,
vocab=163840, MoE 384 experts top-8 (+1 shared, per the K2 report).
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        head_dim=112,
        d_ff=0,
        vocab_size=163_840,
        attn="gqa",
        num_experts=384,
        experts_per_token=8,
        num_shared_experts=1,
        moe_d_ff=2048,
    )
)
