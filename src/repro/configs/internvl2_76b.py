"""internvl2-76b [vlm] — InternViT + LLM backbone [arXiv:2404.16821].

The assigned config specifies the 80L d_model=8192 64H (GQA kv=8,
head_dim=128) d_ff=28672 vocab=128256 transformer BACKBONE (Llama-3-70B
shaped); the InternViT frontend is a STUB: input_specs() provides
precomputed patch embeddings [B, vis_tokens, d_model] prepended to the
token sequence.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="internvl2-76b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28_672,
        vocab_size=128_256,
        attn="gqa",
        frontend="vision",
        vis_tokens=256,
        rope_theta=500_000.0,
    )
)
