"""Config system: architecture + run-shape dataclasses.

One ``ModelConfig`` per assigned architecture lives in configs/<arch>.py with
the exact published hyperparameters; ``reduced()`` derives the CPU-smoke
variant of the same family (fewer/narrower layers, tiny vocab) used by the
per-arch smoke tests.  ``ShapeConfig`` encodes the assigned input-shape set.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention flavour
    attn: str = "gqa"              # gqa | mla | none
    qkv_bias: bool = False
    causal: bool = True
    # MLA (DeepSeek-V2 / MiniCPM3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1
    # hybrid (Zamba-2): shared attn+MLP block applied every k SSM layers
    shared_attn_every: int = 0
    # modality frontend stub
    frontend: str = "none"         # none | audio | vision
    vis_tokens: int = 256          # VLM: patch embeddings prepended
    # numerics / position
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # beyond-paper perf knobs (§Perf iteration 2): fused projections mean
    # ONE backward dx all-reduce per block path instead of 2-3.
    # fused_gate_up uses a shard-aligned [d, 2, ff] layout (always safe);
    # fused_qkv packs [q|k|v] columns, whose split is only shard-aligned
    # for MHA-shaped configs — default off, enabled per-arch in §Perf.
    fused_qkv: bool = False
    fused_gate_up: bool = True
    # SSD knobs (§Perf iteration on the hybrid/ssm cells): chunk length of
    # the intra-chunk quadratic, and bf16 for the decay/score matrices
    ssm_chunk: int = 128
    ssm_bf16_intra: bool = False

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_state else 0

    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant: same family/topology, tiny dims."""
        def rd(x, lo, d):
            return max(lo, x // d)

        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 4),
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=(
                min(max(1, self.num_kv_heads * 4 // self.num_heads), 4)
                if self.num_heads else 0
            ),
            head_dim=32 if self.num_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            q_lora_rank=64 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_head_dim=16 if self.qk_nope_head_dim else 0,
            qk_rope_head_dim=16 if self.qk_rope_head_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            num_experts=8 if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_d_ff=64 if self.moe_d_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            shared_attn_every=2 if self.shared_attn_every else 0,
            vis_tokens=8 if self.frontend == "vision" else self.vis_tokens,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(
            self, seq_len=min(self.seq_len, 64), global_batch=min(self.global_batch, 4)
        )


# The assigned input-shape set (same four for every LM arch).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(config: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The skip rules recorded in DESIGN.md section Arch-applicability."""
    if shape.kind == "decode" and config.is_encoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and config.family not in ("ssm", "hybrid"):
        return False, "long_500k needs sub-quadratic attention (SSM/hybrid only)"
    return True, ""
