"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048, attention-free, d_ff=0, vocab=50280, ssm_state=128.
Mamba-2 defaults: expand=2 (d_inner=4096), headdim=64 (64 SSD heads),
conv width 4, 1 B/C group.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register

CONFIG = register(
    ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50_280,
        attn="none",
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_conv=4,
        ssm_groups=1,
    )
)
