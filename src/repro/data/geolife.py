"""GeoLife surrogate: GPS traces -> stay points -> semantic trajectories.

The real GeoLife dataset (17,621 trajectories, 182 users; Zheng et al.) is
not redistributable offline, so we generate a statistically-matched
surrogate and run the SAME preprocessing the paper describes (section V.1):

1. synthesize GPS traces as POI-anchored random walks: each user has a home/
   work anchor set drawn from a city POI grid, moves between POIs, and dwells
   at them (dwell > tau  => stay point);
2. stay-point detection (Li et al. 2008): a maximal window of fixes within
   ``dist_thresh`` meters spanning more than ``time_thresh`` seconds becomes
   a stay point at the window centroid;
3. map stay points to the nearest POI -> semantic place name.

The output is a TrajectoryBatch + SemanticForest shaped like GeoLife after
semantic conversion, preserving the properties that matter to AnotherMe:
heavy-tailed POI popularity, strong home/work recurrence (repetition!), and
user-specific behavioural motifs.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.encoding import SemanticForest, make_random_forest
from repro.core.types import PAD_PLACE, TrajectoryBatch

EARTH_M_PER_DEG = 111_320.0


def _stay_points(
    fixes_xy: np.ndarray,
    fixes_t: np.ndarray,
    *,
    dist_thresh: float = 200.0,
    time_thresh: float = 20 * 60.0,
) -> np.ndarray:
    """Li et al. stay-point detection on one trace. Returns centroids [M, 2]."""
    pts = []
    i, n = 0, len(fixes_t)
    while i < n:
        j = i + 1
        while j < n:
            d = np.linalg.norm(fixes_xy[j] - fixes_xy[i])
            if d > dist_thresh:
                break
            j += 1
        if fixes_t[min(j, n) - 1] - fixes_t[i] > time_thresh and j - i >= 2:
            pts.append(fixes_xy[i:j].mean(axis=0))
            i = j
        else:
            i += 1
    return np.asarray(pts).reshape(-1, 2)


def geolife_surrogate(
    *,
    num_users: int = 182,
    num_traj: int = 17_621,
    num_pois: int = 800,
    num_types: int = 30,
    classes_per_type: int = 10,
    max_len_pad: int = 16,
    seed: int = 0,
    fast: bool = True,
) -> tuple[TrajectoryBatch, SemanticForest]:
    """Generate the surrogate.  ``fast=True`` (default) synthesizes stay
    points directly from the behavioural model; ``fast=False`` additionally
    round-trips every trajectory through raw GPS fixes + stay-point
    detection (used by tests to validate the detector)."""
    rng = np.random.default_rng(seed)
    forest = make_random_forest(num_types, classes_per_type, num_pois, seed=seed)

    # city POI grid with Zipf popularity
    poi_xy = rng.uniform(0, 20_000, size=(num_pois, 2))
    popularity = 1.0 / np.arange(1, num_pois + 1)
    popularity /= popularity.sum()

    # per-user anchors: home, work + a few favourites (behavioural motifs)
    homes = rng.integers(0, num_pois, size=num_users)
    works = rng.integers(0, num_pois, size=num_users)
    favs = rng.integers(0, num_pois, size=(num_users, 4))

    traj_user = rng.integers(0, num_users, size=num_traj).astype(np.int32)
    lengths = rng.integers(4, max_len_pad - 2, size=num_traj).astype(np.int32)
    places = np.full((num_traj, max_len_pad), PAD_PLACE, dtype=np.int32)

    for t in range(num_traj):
        u = traj_user[t]
        seq = [homes[u]]
        while len(seq) < lengths[t] - 1:
            r = rng.random()
            if r < 0.30:
                seq.append(works[u])
            elif r < 0.55:
                seq.append(favs[u, rng.integers(0, 4)])
            else:
                seq.append(rng.choice(num_pois, p=popularity))
            # dwell: repeat with prob 0.2 (stay of 2*tau)
            if rng.random() < 0.2 and len(seq) < lengths[t] - 1:
                seq.append(seq[-1])
        seq.append(homes[u])  # day ends at home
        lengths[t] = len(seq)
        places[t, : len(seq)] = seq

    if not fast:
        # validate the GPS round-trip on a sample: emit fixes along the
        # sequence with dwells, run stay-point detection, re-map to POIs
        sample = rng.choice(num_traj, size=min(64, num_traj), replace=False)
        for t in sample:
            seq = places[t, : lengths[t]]
            fixes, times = [], []
            clock = 0.0
            for p in seq:
                for _ in range(6):  # 6 fixes over a 30-min dwell
                    fixes.append(poi_xy[p] + rng.normal(scale=30.0, size=2))
                    times.append(clock)
                    clock += 300.0
                clock += 900.0  # travel gap
            sp = _stay_points(np.asarray(fixes), np.asarray(times))
            # nearest-POI mapping
            if len(sp):
                d = np.linalg.norm(sp[:, None, :] - poi_xy[None], axis=-1)
                mapped = d.argmin(axis=1).astype(np.int32)
                m = min(len(mapped), max_len_pad)
                # collapse immediate duplicates produced by long dwells is NOT
                # done: repetition encodes stay duration (paper section IV.1)
                places[t, :] = PAD_PLACE
                places[t, :m] = mapped[:m]
                lengths[t] = m

    return (
        TrajectoryBatch(
            places=jnp.asarray(places),
            lengths=jnp.asarray(lengths),
            user_id=jnp.asarray(traj_user),
        ),
        forest,
    )
