"""Synthetic trajectory generator matching the paper's setup (section V.1).

"The synthetic dataset contains up to 1 million trajectories.  The length of
each trajectory ... varies from 5 to 10 ... Each location ... randomly
selected from 10,000 places.  The number of synthetic place type is 30 and
the number of classes in each type is 10."  (300 types for the scalability
round.)

Stay-duration repetition (section IV.1: a stay of n*tau appears n times) is
modelled with ``repeat_prob``: each emitted place is repeated with that
probability, preserving the repetition-awareness the similarity metric is
designed to capture.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.encoding import SemanticForest, make_random_forest
from repro.core.types import PAD_PLACE, TrajectoryBatch


def synthetic_trajectories(
    num_traj: int,
    *,
    num_places: int = 10_000,
    min_len: int = 5,
    max_len: int = 10,
    repeat_prob: float = 0.15,
    seed: int = 0,
    max_len_pad: int | None = None,
) -> TrajectoryBatch:
    rng = np.random.default_rng(seed)
    L = max_len_pad or max_len
    lengths = rng.integers(min_len, max_len + 1, size=num_traj).astype(np.int32)
    places = rng.integers(0, num_places, size=(num_traj, L)).astype(np.int32)
    # stay-duration repetition: copy the previous place forward with prob p
    if repeat_prob > 0:
        rep = rng.random(size=(num_traj, L)) < repeat_prob
        rep[:, 0] = False
        for j in range(1, L):
            places[:, j] = np.where(rep[:, j], places[:, j - 1], places[:, j])
    mask = np.arange(L)[None, :] < lengths[:, None]
    places = np.where(mask, places, PAD_PLACE)
    return TrajectoryBatch(
        places=jnp.asarray(places),
        lengths=jnp.asarray(lengths),
        user_id=jnp.arange(num_traj, dtype=jnp.int32),
    )


def synthetic_setup(
    num_traj: int,
    *,
    num_types: int = 30,
    classes_per_type: int = 10,
    num_places: int = 10_000,
    n_levels: int = 3,
    seed: int = 0,
    **traj_kwargs,
) -> tuple[TrajectoryBatch, SemanticForest]:
    """Paper section V.1 defaults: (trajectories, forest)."""
    forest = make_random_forest(
        num_types, classes_per_type, num_places, n_levels=n_levels, seed=seed
    )
    batch = synthetic_trajectories(
        num_traj, num_places=num_places, seed=seed + 1, **traj_kwargs
    )
    return batch, forest
