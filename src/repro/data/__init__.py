from repro.data.synthetic import synthetic_trajectories, synthetic_setup
from repro.data.geolife import geolife_surrogate
