from repro.data.synthetic import synthetic_trajectories, synthetic_setup
from repro.data.geolife import geolife_surrogate
from repro.data.fig1 import fig1_world
