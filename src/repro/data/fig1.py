"""The paper's Fig. 1 example world, as data.

Carol lives in Sydney, Dave in Chicago; their trajectories never overlap
geographically, yet both are frequent flyers visiting
lodging -> airports -> company -> dining -> airports -> lodging.  The
pipeline must place them in the same community while keeping the
stay-at-home neighbour out.  Shared by examples/find_another_me.py and the
API parity tests (the acceptance world for the engine redesign).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.encoding import SemanticForest
from repro.core.types import PAD_PLACE, TrajectoryBatch

TYPES = ["lodging", "transportation", "business", "dining"]
CLASSES = ["apartment", "hotel", "airport", "station", "company",
           "fast_food", "fine_dinner"]
NAMES = ["Maris Apartment", "Windy Apartment", "Beach House",
         "Sydney Airport", "O'Hare Airport", "Tokyo Airport",
         "Paris-CDG", "Facebook Japan", "Microsoft France", "KFC Tokyo",
         "Restaurant Goude"]
CLASS_TO_TYPE = np.array([0, 0, 1, 1, 2, 3, 3], np.int32)
NAME_TO_CLASS = np.array([0, 0, 0, 2, 2, 2, 2, 4, 4, 5, 6], np.int32)

PEOPLE = {
    "Carol (Sydney)": ["Maris Apartment", "Sydney Airport", "O'Hare Airport",
                       "Tokyo Airport", "Facebook Japan", "KFC Tokyo",
                       "Tokyo Airport", "Sydney Airport", "Maris Apartment"],
    "Dave (Chicago)": ["Windy Apartment", "O'Hare Airport", "Paris-CDG",
                       "Microsoft France", "Restaurant Goude", "Paris-CDG",
                       "O'Hare Airport", "Windy Apartment"],
    "Homebody": ["Beach House", "KFC Tokyo", "Beach House", "KFC Tokyo",
                 "Beach House"],
}


def fig1_world() -> tuple[TrajectoryBatch, SemanticForest]:
    """(batch, forest) for the Fig. 1 scenario; row order follows PEOPLE."""
    forest = SemanticForest(
        parents=(CLASS_TO_TYPE, NAME_TO_CLASS),
        sizes=(len(TYPES), len(CLASSES), len(NAMES)),
    )
    name_id = {n: i for i, n in enumerate(NAMES)}
    L = max(len(t) for t in PEOPLE.values())
    rows, lens = [], []
    for traj in PEOPLE.values():
        ids = [name_id[p] for p in traj]
        rows.append(ids + [PAD_PLACE] * (L - len(ids)))
        lens.append(len(ids))
    batch = TrajectoryBatch(
        places=jnp.asarray(np.asarray(rows, np.int32)),
        lengths=jnp.asarray(np.asarray(lens, np.int32)),
        user_id=jnp.arange(len(PEOPLE), dtype=jnp.int32),
    )
    return batch, forest
