"""LM token data pipeline with SSH near-duplicate detection (the paper's
technique as a first-class training-data feature).

The bridge: a token sequence IS a semantic trajectory.  We take W anchor
tokens per document (uniform stride), map them through a 3-level vocabulary
hierarchy (token -> cluster -> supercluster, mirroring name -> class ->
type), and run the exact AnotherMe pipeline: k-sequential shingling at the
coarsest level, SSH join, multi-level LCS similarity, communities.  Each
community of near-duplicate documents is downsampled to one representative
— shingle-based dedup as used for LM corpora, but ORDER- and
REPETITION-aware, which plain MinHash dedup is not (paper section IV.2).

Batches are deterministic in (step, shard): restarts and elastic resizes
replay the exact stream (fault-tolerance requirement).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.encoding import SemanticForest
from repro.core.pipeline import AnotherMeConfig, run_anotherme
from repro.core.types import TrajectoryBatch


def vocab_forest(vocab_size: int, *, num_types: int = 300,
                 classes_per_type: int = 10) -> SemanticForest:
    # 300 types (the paper's scalability setting): with W=16 anchors the
    # SSH collision rate C(16,3)/300^3 ~ 2e-5 keeps random-doc candidate
    # pairs near-linear while near-duplicates still share ~all shingles
    """Deterministic 3-level hierarchy over the token vocabulary.

    name level = min(vocab, 10k) hash buckets of token ids; class/type by
    modular fold.  (A production system would plug in k-means over
    embeddings; the pipeline only needs SOME consistent hierarchy.)
    """
    num_names = min(vocab_size, 10_000)
    n_classes = num_types * classes_per_type
    name_to_class = (
        np.arange(num_names, dtype=np.int64) * 2654435761 % n_classes
    ).astype(np.int32)
    class_to_type = (np.arange(n_classes, dtype=np.int32) % num_types).astype(np.int32)
    # ensure surjectivity at each level
    name_to_class[:n_classes] = np.arange(n_classes)
    class_to_type[:num_types] = np.arange(num_types)
    return SemanticForest(
        parents=(class_to_type, name_to_class),
        sizes=(num_types, n_classes, num_names),
    )


def anchors(corpus: np.ndarray, num_anchors: int = 16) -> np.ndarray:
    """[N, S] token docs -> [N, W] anchor tokens (uniform stride)."""
    n, s = corpus.shape
    idx = np.linspace(0, s - 1, num_anchors).astype(np.int64)
    return corpus[:, idx]


@dataclasses.dataclass
class DedupStats:
    num_docs: int
    num_similar_pairs: int
    num_communities: int
    num_dropped: int


def ssh_dedup(
    corpus: np.ndarray,
    *,
    vocab_size: int,
    num_anchors: int = 16,
    rho: float = 8.0,
    k: int = 3,
) -> tuple[np.ndarray, DedupStats]:
    """Returns (keep_mask [N] bool, stats).  rho is on the 0..W MSS scale."""
    forest = vocab_forest(vocab_size)
    a = anchors(corpus, num_anchors)
    num_names = forest.sizes[-1]
    places = (a % num_names).astype(np.int32)
    n, w = places.shape
    batch = TrajectoryBatch(
        places=jnp.asarray(places),
        lengths=jnp.full((n,), w, jnp.int32),
        user_id=jnp.arange(n, dtype=jnp.int32),
    )
    res = run_anotherme(
        batch, forest,
        AnotherMeConfig(k=k, rho=rho, community_mode="components"),
    )
    keep = np.ones(n, bool)
    dropped = 0
    for comm in res.communities:
        members = sorted(comm)
        for m in members[1:]:
            keep[m] = False
            dropped += 1
    return keep, DedupStats(
        num_docs=n,
        num_similar_pairs=len(res.similar_pairs),
        num_communities=len(res.communities),
        num_dropped=dropped,
    )


def synthetic_corpus(
    num_docs: int, seq_len: int, vocab_size: int, *,
    dup_fraction: float = 0.2, edit_prob: float = 0.05, seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Docs with planted near-duplicates.  Returns (corpus, dup_source):
    dup_source[i] = j if doc i is a near-copy of doc j else -1."""
    rng = np.random.default_rng(seed)
    corpus = rng.integers(0, vocab_size, size=(num_docs, seq_len)).astype(np.int32)
    dup_source = np.full(num_docs, -1, np.int64)
    n_dup = int(num_docs * dup_fraction)
    originals = rng.integers(0, max(1, num_docs - n_dup), size=n_dup)
    for i, src in enumerate(originals):
        tgt = num_docs - n_dup + i
        doc = corpus[src].copy()
        edits = rng.random(seq_len) < edit_prob
        doc[edits] = rng.integers(0, vocab_size, size=edits.sum())
        corpus[tgt] = doc
        dup_source[tgt] = src
    return corpus, dup_source


class TokenDataset:
    """Deterministic sharded batch stream over a (deduped) corpus."""

    def __init__(self, corpus: np.ndarray, *, global_batch: int,
                 n_shards: int = 1, shard: int = 0, seed: int = 0):
        assert global_batch % n_shards == 0
        self.corpus = corpus
        self.global_batch = global_batch
        self.n_shards = n_shards
        self.shard = shard
        self.seed = seed

    def batch(self, step: int) -> dict:
        """{tokens, labels} for this shard at this step (replayable)."""
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, self.corpus.shape[0], size=self.global_batch)
        per = self.global_batch // self.n_shards
        mine = idx[self.shard * per : (self.shard + 1) * per]
        docs = self.corpus[mine]
        return {
            "tokens": jnp.asarray(docs[:, :-1]),
            "labels": jnp.asarray(docs[:, 1:].astype(np.int32)),
        }
