"""Performance autotuning for the score stage (see perf/tuning.py)."""
from repro.perf.tuning import (  # noqa: F401
    DEFAULT_PATH,
    LCSTuning,
    SCHEMA,
    TuningTable,
    quantize_pairs,
    resolve_wavefront_dtype,
    tuning_path,
)
