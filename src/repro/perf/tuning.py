"""Cached autotune table for the LCS score stage.

The score stage's free parameters — the Pallas wavefront's batch tile
``block_b`` and the anti-diagonal carry dtype (int8 rolling diagonals vs
int32) — were guessed until now.  This module stores measured winners in a
small JSON table keyed per ``(P, H, L, backend)`` so the engine can look
them up instead, the same discipline REPOSE applies to its distributed
top-k search layout: tune once against the roofline harness, replay the
winner everywhere.

Three rules keep the table safe to consult from the hot path:

1. **Eager resolution only.**  Lookups happen at call boundaries (the
   engine building a runner, ``lcs_impl_fn`` closing over static args) —
   never inside a jitted trace — exactly like
   ``similarity.wavefront_dtype_from_env``.  A tuned value becomes a
   *static* kernel argument, so tuning can never introduce trace-time
   data dependence or steady-state recompiles (the runner cache keys on
   the resolved values).
2. **Bit-identical candidates only.**  Every candidate the sweep measures
   produces bit-identical scores by construction (``block_b`` only changes
   tiling; int8 vs int32 diagonals agree for L < 127, asserted at record
   time), so consulting the table can change throughput but never results.
3. **Environment pins win.**  An explicit ``REPRO_LCS_DTYPE`` pin
   overrides the tuned dtype — the reproducibility knob outranks the
   performance knob.

Keys quantize ``P`` (the pair-buffer size) to its ceiling power of two
because that is the granularity the capacity planner pads buffers to: two
workloads the planner maps to the same padded buffer get the same tuned
parameters.  Misses fall back to the nearest recorded ``P`` for the same
``(H, L, backend)`` (tile choice varies slowly in P), then to ``None`` —
callers keep their current defaults on a total miss.

The table is populated by ``python -m benchmarks.roofline --tune`` and
invalidated wholesale when the schema, jax version, or backend it was
measured on changes — a stale table silently tuning a different machine is
worse than no table.
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path

import jax

from repro.core.compat import backend_name

SCHEMA = "repro-tuning/v1"

# default on-disk location; override with REPRO_TUNING_PATH
DEFAULT_PATH = Path(__file__).resolve().parents[3] / "TUNING.json"

_ENV_PATH = "REPRO_TUNING_PATH"

_DTYPES = ("int8", "int32")


def tuning_path() -> Path:
    """The table location: $REPRO_TUNING_PATH or <repo-root>/TUNING.json."""
    override = os.environ.get(_ENV_PATH)
    return Path(override) if override else DEFAULT_PATH


def quantize_pairs(pairs: int) -> int:
    """Ceiling power of two — the planner's buffer-padding granularity."""
    p = 1
    while p < max(1, pairs):
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class LCSTuning:
    """Measured winner for one (P, H, L, backend) cell.

    ``block_b``           batch-tile cap handed to kernels/lcs/ops.lcs
                          (the waste-minimizing rule still applies under it).
    ``wavefront_dtype``   "int8" | "int32" diagonal carry for the jnp
                          wavefront (overridden by REPRO_LCS_DTYPE).
    ``pairs_per_sec``     throughput of the winner when measured — carried
                          for the benchmark report, not consulted at
                          dispatch time.
    """

    block_b: int
    wavefront_dtype: str
    pairs_per_sec: float = 0.0

    def __post_init__(self):
        if self.block_b < 1 or (self.block_b & (self.block_b - 1)):
            raise ValueError(f"block_b must be a power of two, got {self.block_b}")
        if self.wavefront_dtype not in _DTYPES:
            raise ValueError(
                f"wavefront_dtype must be one of {_DTYPES}, "
                f"got {self.wavefront_dtype!r}"
            )


def _key(pairs: int, levels: int, length: int, backend: str) -> str:
    return f"P{quantize_pairs(pairs)}-H{levels}-L{length}-{backend}"


class TuningTable:
    """In-memory view of the JSON tuning table.

    Load with :meth:`load` (returns an EMPTY table on any mismatch —
    missing file, schema bump, different jax version or backend — so a
    stale table degrades to untuned defaults, never to wrong tiles),
    mutate with :meth:`record`, persist with :meth:`save`.
    """

    def __init__(self, entries: dict[str, LCSTuning] | None = None):
        self.entries: dict[str, LCSTuning] = dict(entries or {})

    # -- persistence ------------------------------------------------------

    @classmethod
    def load(cls, path: Path | str | None = None) -> "TuningTable":
        path = Path(path) if path else tuning_path()
        try:
            raw = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError):
            return cls()
        if (
            raw.get("schema") != SCHEMA
            or raw.get("jax_version") != jax.__version__
            or raw.get("backend") != backend_name()
        ):
            return cls()
        entries = {}
        for key, val in raw.get("entries", {}).items():
            try:
                entries[key] = LCSTuning(**val)
            except (TypeError, ValueError):
                return cls()  # corrupt cell -> whole table untrusted
        return cls(entries)

    def save(self, path: Path | None = None) -> Path:
        path = path or tuning_path()
        payload = {
            "schema": SCHEMA,
            "jax_version": jax.__version__,
            "backend": backend_name(),
            "entries": {
                key: dataclasses.asdict(t) for key, t in sorted(self.entries.items())
            },
        }
        path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
        return path

    # -- access -----------------------------------------------------------

    def record(
        self, pairs: int, levels: int, length: int, tuning: LCSTuning
    ) -> None:
        if length >= 127 and tuning.wavefront_dtype == "int8":
            # int8 diagonals saturate at 127; the sweep must never record a
            # dtype that could diverge from int32 results
            raise ValueError(f"int8 diagonals unsafe at L={length} (>= 127)")
        self.entries[_key(pairs, levels, length, backend_name())] = tuning

    def lookup(self, pairs: int, levels: int, length: int) -> LCSTuning | None:
        """Exact (quantized-P) hit, else nearest recorded P for the same
        (H, L, backend), else None (caller keeps its defaults)."""
        backend = backend_name()
        hit = self.entries.get(_key(pairs, levels, length, backend))
        if hit is not None:
            return hit
        want_p = quantize_pairs(pairs)
        suffix = f"-H{levels}-L{length}-{backend}"
        best, best_dist = None, None
        for key, t in self.entries.items():
            if not (key.startswith("P") and key.endswith(suffix)):
                continue
            have_p = int(key[1 : len(key) - len(suffix)].split("-")[0])
            dist = abs(have_p.bit_length() - want_p.bit_length())
            if best_dist is None or dist < best_dist:
                best, best_dist = t, dist
        return best


def resolve_wavefront_dtype(tuning: LCSTuning | None):
    """The dtype the wavefront should actually run with.

    Precedence: explicit REPRO_LCS_DTYPE env pin (reproducibility) >
    tuned dtype (performance) > the env-probe default.  Returns a jnp
    dtype, matching ``wavefront_dtype_from_env``.
    """
    import jax.numpy as jnp

    from repro.core.similarity import wavefront_dtype_from_env

    if os.environ.get("REPRO_LCS_DTYPE"):
        return wavefront_dtype_from_env()
    if tuning is not None:
        return jnp.int32 if tuning.wavefront_dtype == "int32" else jnp.int8
    return wavefront_dtype_from_env()
