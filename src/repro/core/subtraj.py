"""Subtrajectory ("windowed") coordinates: windows as virtual rows.

The paper's "another me" matches whole trajectories; the richer scenario —
users whose *mornings* match, commutes overlapping for an hour — needs
subtrajectory similarity (Tampakis et al.'s distributed subtrajectory
join, PAPERS.md).  The windowed-candidate mode
(``EngineConfig(subtraj_window=W, subtraj_stride=s)``) reduces it to the
existing whole-trajectory machinery by treating every sliding window as a
VIRTUAL ROW:

* trajectory ``t`` (padded length L) owns ``nw`` windows, where ``nw = 1``
  if ``L <= W`` else ``(L - W) // s + 1`` — a STATIC shape quantity derived
  from the padded length, so jit traces never depend on per-row lengths;
  rows shorter than the padding simply own trailing empty windows (window
  length 0) that emit no keys and never pair;
* window ``j`` of trajectory ``t`` is global window id ``w = t * nw + j``,
  covering positions ``[j*s, j*s + W)`` clipped to the row's true length —
  the inverse map ``(traj, offset) = (w // nw, (w % nw) * s)`` is what the
  scoring layer decodes to slice the resident ``[N, H, L]`` table;
* the candidate layers (shingle/hash keys, routing, dedup, capacity
  planning) run UNCHANGED over window ids; a final host-side
  max-over-windows reduction (:func:`aggregate_window_pairs`) folds
  window-pair scores back to trajectory pairs.

``W >= L`` degenerates to ``nw = 1``, offset 0, window length = row length
— bit-identical to the whole-trajectory mode by construction.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import PAD_ID


def num_windows(max_len: int, window: int, stride: int = 1) -> int:
    """Windows per trajectory row, from the PADDED length (shape-static).

    Offsets ``0, s, 2s, ...`` while a window still starts inside the
    padded row's coverage: the last window starts at the largest multiple
    of ``stride`` <= ``max_len - window`` (so every position of a
    full-length row is covered), and ``window >= max_len`` collapses to a
    single window — the whole-trajectory degeneration.
    """
    if window < 1:
        raise ValueError(f"subtraj window must be positive, got {window}")
    if stride < 1:
        raise ValueError(f"subtraj stride must be positive, got {stride}")
    if max_len <= window:
        return 1
    return (max_len - window) // stride + 1


def window_lengths(lengths, *, max_len: int, window: int, stride: int = 1):
    """Per-window valid lengths: [N] -> [N*nw] (np in -> np out, jnp -> jnp).

    Window j of row i holds ``clip(lengths[i] - j*stride, 0, min(W, L))``
    positions — the quantity every masking/pruning layer uses in place of
    the full row length (the MSS upper bound, the kernel repad, the
    capacity planner's prune replay).
    """
    nw = num_windows(max_len, window, stride)
    offs = np.arange(nw, dtype=np.int32) * stride
    w = min(window, max_len)
    wl = (lengths[:, None] - offs[None, :]).clip(0, w)
    return wl.reshape(-1)


def aggregate_window_pairs(left, right, level_lcs, mss, *, nw: int):
    """Fold scored window pairs to trajectory pairs: max-over-windows MSS.

    left/right: int window ids [P] (PAD_ID rows ignored), level_lcs
    [P, H], mss [P] -> ``(tleft, tright, tlevel, tmss)`` numpy arrays with
    ONE row per distinct ``(traj_lo, traj_hi)`` pair.  Same-trajectory
    window pairs (overlapping windows of one user trivially match) are
    dropped; each surviving pair reports the WINNING window pair's integer
    level_lcs row and float32 mss, with mss ties broken to the
    lexicographically smallest ``(window_lo, window_hi)`` — so every
    backend, shard layout, and score mode aggregates to the identical
    result, and the aggregate is invariant to the order pairs were scored
    in.
    """
    left = np.asarray(left).reshape(-1)
    right = np.asarray(right).reshape(-1)
    level_lcs = np.asarray(level_lcs).reshape(left.shape[0], -1)
    mss = np.asarray(mss).reshape(-1)
    ta, tb = left // nw, right // nw
    keep = (left != PAD_ID) & (ta != tb)
    wl, wr = left[keep], right[keep]
    lv, ms = level_lcs[keep], mss[keep]
    lo = np.minimum(ta[keep], tb[keep])
    hi = np.maximum(ta[keep], tb[keep])
    if lo.size == 0:
        return (np.empty(0, np.int32), np.empty(0, np.int32),
                np.empty((0, level_lcs.shape[1]), lv.dtype),
                np.empty(0, np.float32))
    # group by (lo, hi); within a group the winner sorts first:
    # descending mss, then ascending (window_lo, window_hi)
    order = np.lexsort((wr, wl, -ms, hi, lo))
    lo, hi = lo[order], hi[order]
    first = np.ones(lo.shape[0], bool)
    first[1:] = (lo[1:] != lo[:-1]) | (hi[1:] != hi[:-1])
    rows = np.nonzero(first)[0]
    return (lo[rows].astype(np.int32), hi[rows].astype(np.int32),
            lv[order][rows], ms[order][rows])
