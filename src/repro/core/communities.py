"""Phase (iv): communities of common interest + the paper's QA metrics.

The centralized oracle (paper section V.1) forms **maximal cliques** over the
similarity graph (edges = pairs with MSS > rho); we implement Bron-Kerbosch
with pivoting as the exact host-side oracle.  For the scalable distributed
path we additionally provide **connected components** via jit-compiled
min-label propagation with pointer jumping (O(log N) rounds), which is the
standard large-scale community proxy; accuracy experiments (QA1) use the
clique definition on both sides, exactly as the paper does.

QA1 = |communities_dis ∩ communities_cen| / |communities_cen|   (Eq. 2)
QA2 = |pairs_dis ∩ pairs_cen| / |pairs_cen|                      (Eq. 3)
"""
from __future__ import annotations

import functools
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import PAD_ID


# ---------------------------------------------------------------------------
# scalable path: connected components, jit + collective friendly
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("num_nodes", "max_iters"))
def connected_components(
    left: jnp.ndarray,
    right: jnp.ndarray,
    *,
    num_nodes: int,
    max_iters: int = 64,
    init_labels: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Min-label propagation over an edge list (PAD_ID edges ignored).

    Returns int32 [num_nodes] component labels (the min node id reachable).
    Convergence in O(diameter) rounds, accelerated by pointer jumping; the
    while_loop exits early on fixpoint.

    ``init_labels`` (int32 [num_nodes]) warm-starts the propagation — the
    streaming engine seeds it with the previous update's fixpoint, so one
    micro-batch of new edges converges in O(log delta) rounds instead of
    O(log N).  The seed contract: ``init_labels[v]`` must be a node id in
    ``v``'s component under the CURRENT edge list with
    ``init_labels[v] <= v`` — any stale fixpoint of a sub-graph of the
    current graph satisfies this (labels only merge downward as edges are
    added), and the result is then the exact same fixpoint as a cold start.
    Seeds are clamped to ``min(init_labels[v], v)`` so a cold-start-shaped
    seed (``arange``) is always valid.
    """
    lo = jnp.where(left == PAD_ID, num_nodes, left)
    hi = jnp.where(right == PAD_ID, num_nodes, right)
    iota = jnp.arange(num_nodes + 1, dtype=jnp.int32)
    if init_labels is None:
        init = iota
    else:
        seed = jnp.minimum(init_labels.astype(jnp.int32), iota[:num_nodes])
        init = jnp.concatenate(
            [seed, jnp.full((1,), num_nodes, jnp.int32)]
        )

    def body(state):
        labels, _, it = state
        m = jnp.minimum(labels[lo], labels[hi])
        new = labels.at[lo].min(m).at[hi].min(m)
        new = new.at[num_nodes].set(num_nodes)
        # pointer jumping: label <- label[label]
        new = jnp.minimum(new, new[new])
        changed = jnp.any(new != labels)
        return new, changed, it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    labels, _, _ = jax.lax.while_loop(
        cond, body, (init, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    )
    return labels[:num_nodes]


def components_as_sets(labels: np.ndarray, min_size: int = 2) -> set[frozenset]:
    """Host conversion: labels -> {frozenset(member ids)} of size >= min_size."""
    labels = np.asarray(labels)
    groups: dict[int, list[int]] = {}
    for node, lab in enumerate(labels):
        groups.setdefault(int(lab), []).append(node)
    return {frozenset(g) for g in groups.values() if len(g) >= min_size}


# ---------------------------------------------------------------------------
# incremental path: union-find over an accumulated edge stream
# ---------------------------------------------------------------------------
class UnionFind:
    """Incremental connected components: union by size + path compression.

    The host-side oracle for streaming ingestion — edges arrive in
    micro-batches and each ``union`` costs amortized ~O(alpha(N)); the
    labeling after any prefix of unions equals ``connected_components`` over
    the same edge set (canonicalized to min-member labels).  Node capacity
    grows on demand (``add``) with amortized-doubling reallocation, matching
    the engine's world-buffer policy.
    """

    def __init__(self, num_nodes: int = 0):
        self._parent = np.arange(num_nodes, dtype=np.int64)
        self._size = np.ones(num_nodes, dtype=np.int64)
        self.num_nodes = num_nodes

    def add(self, num_new: int) -> None:
        """Append ``num_new`` fresh singleton nodes."""
        if num_new <= 0:
            return
        n = self.num_nodes + num_new
        if n > self._parent.shape[0]:
            cap = max(16, 1 << int(np.ceil(np.log2(n))))
            parent = np.arange(cap, dtype=np.int64)
            size = np.ones(cap, dtype=np.int64)
            parent[: self.num_nodes] = self._parent[: self.num_nodes]
            size[: self.num_nodes] = self._size[: self.num_nodes]
            self._parent, self._size = parent, size
        self.num_nodes = n

    def find(self, x: int) -> int:
        """Root of ``x`` with path halving (iterative compression)."""
        p = self._parent
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return int(x)

    def union(self, a: int, b: int) -> bool:
        """Merge the components of ``a`` and ``b``; True if they differed."""
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if self._size[ra] < self._size[rb]:
            ra, rb = rb, ra
        self._parent[rb] = ra
        self._size[ra] += self._size[rb]
        return True

    def labels(self) -> np.ndarray:
        """Canonical int32 [num_nodes] labels: the MIN member id per
        component — bit-compatible with :func:`connected_components`, so the
        streaming engine can hand them over as that function's
        ``init_labels`` seed (and vice versa)."""
        n = self.num_nodes
        roots = np.fromiter(
            (self.find(i) for i in range(n)), dtype=np.int64, count=n
        )
        canon = np.full(n, np.iinfo(np.int64).max, np.int64)
        np.minimum.at(canon, roots, np.arange(n, dtype=np.int64))
        return canon[roots].astype(np.int32) if n else np.empty(0, np.int32)

    def components(self, min_size: int = 2) -> set[frozenset]:
        """{frozenset(member ids)} of size >= min_size, like
        :func:`components_as_sets`."""
        return components_as_sets(self.labels(), min_size=min_size)

    def reset_from_labels(self, labels: np.ndarray) -> None:
        """Reinitialize to the partition encoded by min-member ``labels``.

        ``labels[v]`` must be the min member of ``v``'s component (the
        :meth:`labels` / :func:`connected_components` canonical form) —
        then ``parent[v] = labels[v]`` is a valid depth-1 forest (the min
        member roots itself) and subsequent unions continue incrementally.
        This is how deletion re-enters the incremental path: components
        are re-solved once (``components_after_deletion``) and the
        union-find warm-restarts from the surviving partition instead of
        replaying the entire edge history.
        """
        labels = np.asarray(labels, np.int64).reshape(-1)
        n = labels.shape[0]
        cap = max(16, int(2 ** np.ceil(np.log2(max(n, 1)))))
        self._parent = np.arange(cap, dtype=np.int64)
        self._parent[:n] = labels
        self._size = np.ones(cap, dtype=np.int64)
        if n:
            counts = np.bincount(labels, minlength=n)
            roots = np.nonzero(counts)[0]
            self._size[roots] = counts[roots]
        self.num_nodes = n


def components_after_deletion(
    labels: np.ndarray,
    dead: Sequence[int],
    surviving_edges: Iterable[tuple[int, int]],
) -> np.ndarray:
    """Community *un*-merging: re-label after deleting the ``dead`` nodes.

    Connected components are incrementally maintainable under edge
    ADDITION (labels only merge downward), but deletion can SPLIT a
    component — e.g. expiring the bridge node of a path — which no local
    label update can discover.  The warm re-solve: only components that
    CONTAIN a dead node ("touched") are recomputed, from the surviving
    edges restricted to them; untouched components keep their labels
    verbatim (their min member is alive, so the canonical form is stable).
    Cost O(n + E_touched) instead of replaying the world's edge history.

    labels:          int [n] current min-member labels (nodes 0..n-1).
    dead:            node ids being deleted (become self-labeled
                     singletons; the caller must already have dropped
                     every edge referencing them).
    surviving_edges: the post-deletion edge set (edges inside untouched
                     components are skipped internally).

    Returns the new int32 [n] min-member labels — bit-identical to a cold
    :func:`connected_components` / union-find fixpoint over
    ``surviving_edges``.
    """
    labels = np.asarray(labels, np.int64).copy()
    n = labels.shape[0]
    dead = np.asarray(sorted(set(int(x) for x in dead)), np.int64)
    if dead.size == 0:
        return labels.astype(np.int32)
    touched = np.unique(labels[dead])
    touched_mask = np.isin(labels, touched)
    idx = np.nonzero(touched_mask)[0]
    labels[idx] = idx  # touched components dissolve to singletons...
    uf = UnionFind()
    uf.reset_from_labels(labels)
    touched_nodes = set(idx.tolist())
    for a, b in surviving_edges:  # ...and re-form from surviving edges
        if int(a) in touched_nodes or int(b) in touched_nodes:
            uf.union(int(a), int(b))
    return uf.labels()


# ---------------------------------------------------------------------------
# exact oracle: maximal cliques (Bron-Kerbosch with pivoting)
# ---------------------------------------------------------------------------
def maximal_cliques(edges: Iterable[tuple[int, int]], min_size: int = 2) -> set[frozenset]:
    """All maximal cliques of size >= min_size.  Host-side, exact."""
    adj: dict[int, set[int]] = {}
    for a, b in edges:
        if a == b:
            continue
        adj.setdefault(a, set()).add(b)
        adj.setdefault(b, set()).add(a)
    cliques: set[frozenset] = set()

    def bk(r: set, p: set, x: set):
        if not p and not x:
            if len(r) >= min_size:
                cliques.add(frozenset(r))
            return
        pivot_pool = p | x
        pivot = max(pivot_pool, key=lambda v: len(adj.get(v, ())), default=None)
        for v in list(p - adj.get(pivot, set())):
            bk(r | {v}, p & adj[v], x & adj[v])
            p.remove(v)
            x.add(v)

    bk(set(), set(adj.keys()), set())
    return cliques


# ---------------------------------------------------------------------------
# paper metrics
# ---------------------------------------------------------------------------
def pairs_to_set(left, right) -> set[tuple[int, int]]:
    left = np.asarray(left)
    right = np.asarray(right)
    ok = left != PAD_ID
    return {
        (int(min(a, b)), int(max(a, b)))
        for a, b in zip(left[ok].tolist(), right[ok].tolist())
    }


def qa1(communities_dis: set[frozenset], communities_cen: set[frozenset]) -> float:
    """Eq. 2 — fraction of centralized communities recovered."""
    if not communities_cen:
        return 1.0
    return len(communities_dis & communities_cen) / len(communities_cen)


def qa2(pairs_dis: set[tuple], pairs_cen: set[tuple]) -> float:
    """Eq. 3 — fraction of centralized similar pairs recovered."""
    if not pairs_cen:
        return 1.0
    return len(pairs_dis & pairs_cen) / len(pairs_cen)
