"""Phase (iv): communities of common interest + the paper's QA metrics.

The centralized oracle (paper section V.1) forms **maximal cliques** over the
similarity graph (edges = pairs with MSS > rho); we implement Bron-Kerbosch
with pivoting as the exact host-side oracle.  For the scalable distributed
path we additionally provide **connected components** via jit-compiled
min-label propagation with pointer jumping (O(log N) rounds), which is the
standard large-scale community proxy; accuracy experiments (QA1) use the
clique definition on both sides, exactly as the paper does.

QA1 = |communities_dis ∩ communities_cen| / |communities_cen|   (Eq. 2)
QA2 = |pairs_dis ∩ pairs_cen| / |pairs_cen|                      (Eq. 3)
"""
from __future__ import annotations

import functools
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import PAD_ID


# ---------------------------------------------------------------------------
# scalable path: connected components, jit + collective friendly
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("num_nodes", "max_iters"))
def connected_components(
    left: jnp.ndarray,
    right: jnp.ndarray,
    *,
    num_nodes: int,
    max_iters: int = 64,
) -> jnp.ndarray:
    """Min-label propagation over an edge list (PAD_ID edges ignored).

    Returns int32 [num_nodes] component labels (the min node id reachable).
    Convergence in O(diameter) rounds, accelerated by pointer jumping; the
    while_loop exits early on fixpoint.
    """
    lo = jnp.where(left == PAD_ID, num_nodes, left)
    hi = jnp.where(right == PAD_ID, num_nodes, right)
    init = jnp.arange(num_nodes + 1, dtype=jnp.int32)

    def body(state):
        labels, _, it = state
        m = jnp.minimum(labels[lo], labels[hi])
        new = labels.at[lo].min(m).at[hi].min(m)
        new = new.at[num_nodes].set(num_nodes)
        # pointer jumping: label <- label[label]
        new = jnp.minimum(new, new[new])
        changed = jnp.any(new != labels)
        return new, changed, it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    labels, _, _ = jax.lax.while_loop(
        cond, body, (init, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    )
    return labels[:num_nodes]


def components_as_sets(labels: np.ndarray, min_size: int = 2) -> set[frozenset]:
    """Host conversion: labels -> {frozenset(member ids)} of size >= min_size."""
    labels = np.asarray(labels)
    groups: dict[int, list[int]] = {}
    for node, lab in enumerate(labels):
        groups.setdefault(int(lab), []).append(node)
    return {frozenset(g) for g in groups.values() if len(g) >= min_size}


# ---------------------------------------------------------------------------
# exact oracle: maximal cliques (Bron-Kerbosch with pivoting)
# ---------------------------------------------------------------------------
def maximal_cliques(edges: Iterable[tuple[int, int]], min_size: int = 2) -> set[frozenset]:
    """All maximal cliques of size >= min_size.  Host-side, exact."""
    adj: dict[int, set[int]] = {}
    for a, b in edges:
        if a == b:
            continue
        adj.setdefault(a, set()).add(b)
        adj.setdefault(b, set()).add(a)
    cliques: set[frozenset] = set()

    def bk(r: set, p: set, x: set):
        if not p and not x:
            if len(r) >= min_size:
                cliques.add(frozenset(r))
            return
        pivot_pool = p | x
        pivot = max(pivot_pool, key=lambda v: len(adj.get(v, ())), default=None)
        for v in list(p - adj.get(pivot, set())):
            bk(r | {v}, p & adj[v], x & adj[v])
            p.remove(v)
            x.add(v)

    bk(set(), set(adj.keys()), set())
    return cliques


# ---------------------------------------------------------------------------
# paper metrics
# ---------------------------------------------------------------------------
def pairs_to_set(left, right) -> set[tuple[int, int]]:
    left = np.asarray(left)
    right = np.asarray(right)
    ok = left != PAD_ID
    return {
        (int(min(a, b)), int(max(a, b)))
        for a, b in zip(left[ok].tolist(), right[ok].tolist())
    }


def qa1(communities_dis: set[frozenset], communities_cen: set[frozenset]) -> float:
    """Eq. 2 — fraction of centralized communities recovered."""
    if not communities_cen:
        return 1.0
    return len(communities_dis & communities_cen) / len(communities_cen)


def qa2(pairs_dis: set[tuple], pairs_cen: set[tuple]) -> float:
    """Eq. 3 — fraction of centralized similar pairs recovered."""
    if not pairs_cen:
        return 1.0
    return len(pairs_dis & pairs_cen) / len(pairs_cen)
