"""Centralized baseline (paper section V.1): exact all-pairs MSS.

Scores every C(N,2) pair — no hashing, no partitioning.  This is the ground
truth used for the QA1/QA2 accuracy metrics and the 30x speedup claim.  It
is deliberately single-device; pairs are processed in fixed-size chunks so
memory stays bounded (the paper notes the centralized approach hits memory
explosion at 60k trajectories — our chunking bounds memory but not time).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.encoding import EncodedBatch
from repro.core.similarity import (
    default_betas, score_pairs, wavefront_dtype_from_env,
)


def all_pairs(n: int) -> tuple[np.ndarray, np.ndarray]:
    iu = np.triu_indices(n, k=1)
    return iu[0].astype(np.int32), iu[1].astype(np.int32)


def centralized_similar_pairs(
    encoded: EncodedBatch,
    *,
    rho: float,
    betas: jnp.ndarray | None = None,
    chunk: int = 1 << 16,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Exact similar-pair set: returns (left, right, mss) with mss > rho."""
    n = encoded.codes.shape[0]
    if betas is None:
        betas = default_betas(encoded.num_levels)
    li, ri = all_pairs(n)
    out_l, out_r, out_s = [], [], []
    for s in range(0, li.shape[0], chunk):
        l = jnp.asarray(li[s : s + chunk])
        r = jnp.asarray(ri[s : s + chunk])
        # pad the tail chunk to a stable shape to avoid recompilation
        pad = chunk - l.shape[0]
        if pad:
            l = jnp.concatenate([l, jnp.zeros((pad,), jnp.int32)])
            r = jnp.concatenate([r, jnp.zeros((pad,), jnp.int32)])
        _, mss = score_pairs(encoded.codes, encoded.lengths, l, r, betas,
                             wavefront_dtype=wavefront_dtype_from_env())
        mss = np.asarray(mss)[: chunk - pad if pad else chunk]
        keep = mss > rho
        out_l.append(li[s : s + chunk][keep])
        out_r.append(ri[s : s + chunk][keep])
        out_s.append(mss[keep])
    if not out_l:
        z = np.zeros((0,), np.int32)
        return z, z, np.zeros((0,), np.float32)
    return np.concatenate(out_l), np.concatenate(out_r), np.concatenate(out_s)
