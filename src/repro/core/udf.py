"""The "User-defined" baseline (paper section V.1): same logic, black box.

The paper's key systems finding is that wrapping the whole algorithm in a
Spark UDF — identical four phases, but opaque to the engine's optimizer —
is *slower than the centralized version* at scale.  Our analogue: the same
AnotherMe phases implemented as per-row Python/NumPy loops that XLA never
sees (no jit, no vectorization, no fusion).  It produces bit-identical
results to AnotherMe (it is the same logic) and is used by the Fig. 7/11
timing benchmarks to reproduce that finding on our engine.
"""
from __future__ import annotations

import itertools
from collections import defaultdict

import numpy as np

from repro.core.encoding import SemanticForest


def udf_pipeline(
    places: np.ndarray,
    lengths: np.ndarray,
    forest: SemanticForest,
    *,
    k: int = 3,
    betas: np.ndarray | None = None,
    rho: float = 2.0,
) -> tuple[set[tuple[int, int]], dict[tuple[int, int], float]]:
    """Run all four phases row-at-a-time in pure Python. Returns
    (similar pair set, {pair: mss})."""
    places = np.asarray(places)
    lengths = np.asarray(lengths)
    maps = forest.level_maps()
    n_levels = len(maps)
    if betas is None:
        betas = np.full((n_levels,), 1.0 / n_levels)

    # phase (i): per-row semantic encoding
    encs = []
    for i in range(places.shape[0]):
        row = places[i, : lengths[i]]
        encs.append([tuple(int(m[p]) for p in row) for m in maps])

    # phase (ii): per-row shingling + hash-partition via a dict
    buckets: dict[tuple, list[int]] = defaultdict(list)
    for i, enc in enumerate(encs):
        types = enc[0]
        for combo in set(itertools.combinations(types, k)):
            buckets[combo].append(i)

    candidates: set[tuple[int, int]] = set()
    for members in buckets.values():
        for a, b in itertools.combinations(sorted(set(members)), 2):
            candidates.add((a, b))

    # phase (iii): per-pair multi-level LCS
    def lcs(a, b):
        la, lb = len(a), len(b)
        dp = [[0] * (lb + 1) for _ in range(la + 1)]
        for i in range(1, la + 1):
            for j in range(1, lb + 1):
                if a[i - 1] == b[j - 1]:
                    dp[i][j] = dp[i - 1][j - 1] + 1
                else:
                    dp[i][j] = max(dp[i - 1][j], dp[i][j - 1])
        return dp[la][lb]

    scores: dict[tuple[int, int], float] = {}
    similar: set[tuple[int, int]] = set()
    for a, b in candidates:
        mss = sum(
            float(betas[h]) * lcs(encs[a][h], encs[b][h]) for h in range(n_levels)
        )
        scores[(a, b)] = mss
        if mss > rho:
            similar.add((a, b))
    return similar, scores
