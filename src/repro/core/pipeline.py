"""AnotherMe end-to-end orchestration (paper section IV.4, Fig. 2).

Single-process driver: encode -> shingle -> SSH join -> score -> threshold ->
communities, with host-side capacity planning (static pair buffers sized from
the exact join cardinality, doubled on overflow) and per-phase wall timing so
the benchmark harness can reproduce the paper's Fig. 7/9 breakdowns.

The distributed (shard_map) version lives in core/distributed.py and reuses
the same phase functions.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import communities as comm
from repro.core.encoding import SemanticForest, encode_batch, forest_tables, type_codes
from repro.core.shingling import shingles_from_types
from repro.core.similarity import default_betas, score_pairs
from repro.core.ssh import exact_pair_count, ssh_candidates
from repro.core.types import PAD_ID, ScoredPairs, TrajectoryBatch


@dataclasses.dataclass(frozen=True)
class AnotherMeConfig:
    k: int = 3                      # shingle order (paper default 3)
    rho: float = 2.0                # similarity threshold (paper default 2)
    betas: tuple | None = None      # level weights; None -> uniform 1/n
    lcs_impl: str = "wavefront"     # "wavefront" | "ref" | "kernel"
    pair_capacity: int | None = None  # None -> plan from exact join size
    capacity_slack: float = 1.10
    community_mode: str = "cliques"  # "cliques" | "components"
    max_retries: int = 3


@dataclasses.dataclass
class AnotherMeResult:
    scored: ScoredPairs
    similar_pairs: set
    communities: set
    stats: dict


def _next_pow2(x: int) -> int:
    return 1 << max(10, int(np.ceil(np.log2(max(x, 1)))))


def run_anotherme(
    batch: TrajectoryBatch,
    forest: SemanticForest,
    config: AnotherMeConfig = AnotherMeConfig(),
    *,
    candidate_fn: Callable | None = None,
) -> AnotherMeResult:
    """Run the full pipeline on one device.

    ``candidate_fn`` optionally swaps the SSH join for a baseline hash
    (MinHash / BRP) while keeping every other phase identical — this is how
    the accuracy benchmarks isolate the hash function, as the paper does.
    """
    stats: dict = {}
    tables = forest_tables(forest)
    betas = (
        jnp.asarray(config.betas, jnp.float32)
        if config.betas is not None
        else default_betas(forest.num_levels)
    )

    t0 = time.perf_counter()
    encoded = encode_batch(batch, tables)
    encoded.codes.block_until_ready()
    stats["t_encode"] = time.perf_counter() - t0

    # --- phase (ii): shingling + join --------------------------------------
    t0 = time.perf_counter()
    if candidate_fn is None:
        keys = shingles_from_types(
            type_codes(encoded), batch.lengths, k=config.k,
            num_types=forest.num_types,
        )
        keys.block_until_ready()
        stats["t_shingle"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        cap = config.pair_capacity
        if cap is None:
            cap = _next_pow2(int(exact_pair_count(keys) * config.capacity_slack))
        cand = ssh_candidates(keys, pair_capacity=cap)
        for _ in range(config.max_retries):
            if int(cand.overflow) == 0:
                break
            cap *= 2
            cand = ssh_candidates(keys, pair_capacity=cap)
        stats["pair_capacity"] = cap
    else:
        cand = candidate_fn(encoded, batch)
        stats["t_shingle"] = time.perf_counter() - t0
        t0 = time.perf_counter()
    cand.left.block_until_ready()
    stats["t_join"] = time.perf_counter() - t0
    stats["num_candidates"] = int(cand.count)
    stats["join_overflow"] = int(cand.overflow)

    # --- phase (iii): similarity scoring ------------------------------------
    t0 = time.perf_counter()
    level_lcs, mss = score_pairs(
        encoded.codes, encoded.lengths, cand.left, cand.right, betas,
        impl_name="wavefront" if config.lcs_impl == "ref" else config.lcs_impl,
    ) if config.lcs_impl != "kernel" else _score_with_kernel(
        encoded, cand, betas
    )
    mss.block_until_ready()
    stats["t_score"] = time.perf_counter() - t0

    valid = np.asarray(cand.left) != PAD_ID
    mss_np = np.asarray(mss)
    similar_mask = valid & (mss_np > config.rho)
    left_np = np.asarray(cand.left)
    right_np = np.asarray(cand.right)
    similar_pairs = {
        (int(a), int(b))
        for a, b in zip(left_np[similar_mask], right_np[similar_mask])
    }
    stats["num_similar"] = len(similar_pairs)

    scored = ScoredPairs(
        left=cand.left, right=cand.right, level_lcs=level_lcs, mss=mss,
        count=cand.count, overflow=cand.overflow,
    )

    # --- phase (iv): communities --------------------------------------------
    t0 = time.perf_counter()
    if config.community_mode == "cliques":
        communities = comm.maximal_cliques(similar_pairs)
    else:
        sl = jnp.asarray(left_np[similar_mask])
        sr = jnp.asarray(right_np[similar_mask])
        labels = comm.connected_components(
            sl, sr, num_nodes=batch.num_trajectories
        )
        communities = comm.components_as_sets(np.asarray(labels))
    stats["t_communities"] = time.perf_counter() - t0
    stats["num_communities"] = len(communities)
    stats["t_total"] = sum(v for k, v in stats.items() if k.startswith("t_"))

    return AnotherMeResult(
        scored=scored, similar_pairs=similar_pairs, communities=communities,
        stats=stats,
    )


def _score_with_kernel(encoded, cand, betas):
    """Score candidates with the Pallas LCS kernel (kernels/lcs)."""
    from repro.kernels.lcs import ops as lcs_ops
    from repro.core.similarity import mss_scores
    from repro.core.encoding import PAD_CODE_A, PAD_CODE_B
    from repro.core.similarity import repad

    li = jnp.where(cand.left == PAD_ID, 0, cand.left)
    ri = jnp.where(cand.right == PAD_ID, 0, cand.right)
    P = li.shape[0]
    H, L = encoded.codes.shape[1], encoded.codes.shape[2]
    a = repad(encoded.codes[li], encoded.lengths[li], PAD_CODE_A).reshape(P * H, L)
    b = repad(encoded.codes[ri], encoded.lengths[ri], PAD_CODE_B).reshape(P * H, L)
    level_lcs = lcs_ops.lcs(a, b).reshape(P, H)
    return level_lcs, mss_scores(level_lcs, betas)
