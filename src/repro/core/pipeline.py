"""Legacy AnotherMe entry point — deprecation shim over ``repro.api``.

``run_anotherme`` / ``AnotherMeConfig`` predate the composable engine; they
now delegate to :class:`repro.api.AnotherMeEngine` so there is exactly one
implementation of the pipeline.  New code should use the engine directly:

    from repro.api import AnotherMeEngine, EngineConfig
    result = AnotherMeEngine(forest, EngineConfig()).run(batch)

Behavioural fixes folded into the shim (ISSUE 1 satellites):

* ``lcs_impl="ref"`` now actually runs the reference DP (it used to be
  silently rewritten to "wavefront"), and unknown impl names raise a
  ValueError listing the valid options.
* The ``candidate_fn`` branch reports ``t_candidates`` (and no longer books
  the baseline's hash cost under ``t_shingle``), so Fig. 9-style breakdowns
  attribute hash cost correctly for every approach.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.encoding import SemanticForest
from repro.core.types import ScoredPairs, TrajectoryBatch


@dataclasses.dataclass
class AnotherMeResult:
    """Pipeline output: scored pairs + the paper's two result sets.

    Shared with the new API (``repro.api.EngineResult`` is an alias).
    """

    scored: ScoredPairs
    similar_pairs: set
    communities: set
    stats: dict


@dataclasses.dataclass(frozen=True)
class AnotherMeConfig:
    """Legacy config; maps 1:1 onto :class:`repro.api.EngineConfig`."""

    k: int = 3                      # shingle order (paper default 3)
    rho: float = 2.0                # similarity threshold (paper default 2)
    betas: tuple | None = None      # level weights; None -> uniform 1/n
    lcs_impl: str = "wavefront"     # "wavefront" | "ref" | "kernel" |
    #                                 "pallas" | "pallas-interpret"
    pair_capacity: int | None = None  # None -> plan from exact join size
    capacity_slack: float = 1.10
    community_mode: str = "cliques"  # "cliques" | "components"
    max_retries: int = 3

    def as_engine_config(self, backend: str = "ssh"):
        from repro.api.engine import EngineConfig

        return EngineConfig(
            k=self.k, rho=self.rho, betas=self.betas, backend=backend,
            lcs_impl=self.lcs_impl, pair_capacity=self.pair_capacity,
            capacity_slack=self.capacity_slack,
            community_mode=self.community_mode, max_retries=self.max_retries,
        )


def run_anotherme(
    batch: TrajectoryBatch,
    forest: SemanticForest,
    config: AnotherMeConfig = AnotherMeConfig(),
    *,
    candidate_fn: Callable | None = None,
) -> AnotherMeResult:
    """Run the full pipeline on one device (deprecated shim).

    ``candidate_fn`` optionally swaps the SSH join for a baseline hash while
    keeping every other phase identical.  Prefer the registry instead:
    ``AnotherMeEngine(forest, EngineConfig(backend="minhash"))``.
    """
    from repro.api.backends import CallableBackend
    from repro.api.engine import AnotherMeEngine

    backend = CallableBackend(candidate_fn) if candidate_fn is not None else None
    engine = AnotherMeEngine(
        forest, config.as_engine_config(), backend=backend
    )
    return engine.run(batch)
