"""jax version compatibility (0.4.x .. 0.6+) for meshes and shard_map.

The repo targets the current jax API (``jax.shard_map`` with ``check_vma``,
``jax.make_mesh`` with ``axis_types``); older versions spell these
``jax.experimental.shard_map.shard_map(check_rep=...)`` and have no
``axis_types``/``AxisType``.  Every mesh/shard_map construction in the repo
goes through these two helpers so the whole pipeline runs on either API.
"""
from __future__ import annotations

import jax


def backend_name() -> str:
    """The active jax backend ("cpu" / "tpu" / "gpu").

    The single source of truth for backend probing: the LCS dispatchers
    (kernels/lcs/ops.py, kernels/lcs/fused.py) and the perf tuning table
    (repro.perf) all key off THIS function, so a test that monkeypatches it
    redirects every dispatch decision at once — two independent probes can
    never disagree about where the code is running.
    """
    return jax.default_backend()


def on_tpu() -> bool:
    """True when the default jax backend is a TPU (see :func:`backend_name`)."""
    return backend_name() == "tpu"


def make_mesh(axis_shapes, axis_names, *, devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = list(devices)
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(fn, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` without replication/VMA checking, any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
