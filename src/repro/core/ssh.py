"""Phase (ii) part 2: the SSH candidate join (paper Algorithm 2, Fig. 5).

Spark pipeline:  D3 --explode--> D4 --self-join on shingle--> D5 (pairs).
TPU pipeline:    sort-merge join — one ``lax.sort`` by shingle key, then
*exact compact* pair enumeration over equal-key runs:

  each sorted row r with in-run rank k contributes exactly k pairs (with the
  k earlier members of its run).  An exclusive cumsum of ranks assigns every
  pair a unique output slot; a vectorized ``searchsorted`` inverts slot ->
  (row, partner).  Total work O(R log R + P), zero data-dependent shapes,
  zero wasted slots — the static-shape analogue of Spark's shuffle join.

Pairs appearing under multiple shingles are deduplicated with a second sort
on the canonical (lo, hi) key, honouring the paper's "each pair is scored
exactly once no matter how many shingles it shares" (section IV.3).

Capacity discipline: the pair buffer is a static ``pair_capacity``; if the
true pair count exceeds it we report ``overflow`` and the host-level driver
(pipeline.py) retries with doubled capacity — Spark's dynamic memory traded
for deterministic compilable shapes (DESIGN.md section 2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import CandidatePairs, PAD_ID, PAD_KEY


def _runs(sorted_keys: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row (rank within equal-key run, validity) for ascending keys."""
    r = sorted_keys.shape[0]
    idx = jnp.arange(r, dtype=jnp.int32)
    start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_keys[1:] != sorted_keys[:-1]]
    )
    run_start = jax.lax.cummax(jnp.where(start, idx, -1))
    rank = idx - run_start
    return rank, sorted_keys != PAD_KEY


def pairs_from_rows(
    keys: jnp.ndarray, ids: jnp.ndarray, *, pair_capacity: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Exact-compact pair enumeration over flat (key, id) rows.

    Returns (lo [P_cap], hi [P_cap], overflow) — canonical but NOT deduped
    (the same pair may appear under several shared shingles).  Shared by the
    single-device join and the distributed post-shuffle local join.
    """
    keys, ids = jax.lax.sort((keys, ids), num_keys=1)
    rank, valid = _runs(keys)
    contrib = jnp.where(valid, rank, 0)
    excl = jnp.cumsum(contrib) - contrib  # exclusive prefix
    total = excl[-1] + contrib[-1]

    p = jnp.arange(pair_capacity, dtype=jnp.int32)
    row = jnp.searchsorted(excl, p, side="right").astype(jnp.int32) - 1
    row = jnp.clip(row, 0, keys.shape[0] - 1)
    t = p - excl[row]
    partner = row - rank[row] + t
    partner = jnp.clip(partner, 0, keys.shape[0] - 1)
    ok = p < total
    a = jnp.where(ok, ids[row], PAD_ID)
    b = jnp.where(ok, ids[partner], PAD_ID)
    overflow = jnp.maximum(total - pair_capacity, 0)
    return jnp.minimum(a, b), jnp.maximum(a, b), overflow


@functools.partial(jax.jit, static_argnames=("pair_capacity",))
def ssh_candidates(
    shingle_keys: jnp.ndarray,
    *,
    pair_capacity: int,
    id_offset: jnp.ndarray | int = 0,
) -> CandidatePairs:
    """Candidate pairs from per-trajectory shingle keys.

    shingle_keys: int32 [N, S], PAD_KEY-padded, distinct per row.
    id_offset:    added to local row indices to form global trajectory ids
                  (used by the distributed pipeline's shard-local phase).
    returns CandidatePairs with canonical (left < right) deduplicated pairs.
    """
    n, s = shingle_keys.shape
    keys = shingle_keys.reshape(-1)
    ids = jnp.repeat(
        jnp.arange(n, dtype=jnp.int32) + jnp.asarray(id_offset, jnp.int32), s
    )
    lo, hi, overflow = pairs_from_rows(keys, ids, pair_capacity=pair_capacity)
    return dedup_pairs(lo, hi, overflow=overflow)


@jax.jit
def dedup_pairs(
    lo: jnp.ndarray, hi: jnp.ndarray, overflow: jnp.ndarray | int = 0
) -> CandidatePairs:
    """Canonicalize + deduplicate pair lists (PAD_ID slots sort to the end)."""
    lo, hi = jax.lax.sort((lo, hi), num_keys=2)
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), (lo[1:] == lo[:-1]) & (hi[1:] == hi[:-1])]
    )
    bad = dup | (lo == hi) | (lo == PAD_ID)
    lo = jnp.where(bad, PAD_ID, lo)
    hi = jnp.where(bad, PAD_ID, hi)
    lo, hi = jax.lax.sort((lo, hi), num_keys=2)  # compact valid slots to front
    count = jnp.sum(lo != PAD_ID).astype(jnp.int32)
    return CandidatePairs(
        left=lo, right=hi, count=count, overflow=jnp.asarray(overflow, jnp.int32)
    )


def exact_pair_count(shingle_keys: jnp.ndarray) -> int:
    """Host helper: the true (pre-dedup) join size, for capacity planning."""
    keys = jnp.sort(shingle_keys.reshape(-1))
    rank, valid = _runs(keys)
    return int(jnp.sum(jnp.where(valid, rank, 0)))
