"""Bucketed Random Projection baseline (Spark's BRP LSH; paper section V.1).

Each trajectory's type-level **count vector** (bag of types) is projected
onto random unit vectors; the bucket index floor(proj / bucket_length) is
the hash key.  Like MinHash this discards visiting order entirely and, with
coarse buckets, even most frequency information — the paper observes BRP
"missing almost all the correct communities" (Fig. 10), which we reproduce.

The banded bucket keys feed the same sort-merge join as SSH/MinHash.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ssh import ssh_candidates
from repro.core.types import CandidatePairs


@functools.partial(
    jax.jit, static_argnames=("num_types", "num_proj", "seed", "bucket_length")
)
def brp_bucket_keys(
    type_codes: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    num_types: int,
    num_proj: int = 4,
    bucket_length: float = 2.0,
    seed: int = 0,
) -> jnp.ndarray:
    """int32 [N, num_proj] salted bucket keys of the type-count vectors."""
    n, L = type_codes.shape
    valid = jnp.arange(L, dtype=jnp.int32)[None, :] < lengths[:, None]
    onehot = jax.nn.one_hot(
        jnp.where(valid, type_codes, num_types), num_types + 1, dtype=jnp.float32
    )[..., :num_types]
    counts = onehot.sum(axis=1)  # [N, Q]
    rng = np.random.default_rng(seed)
    r = rng.normal(size=(num_types, num_proj)).astype(np.float32)
    r /= np.linalg.norm(r, axis=0, keepdims=True)
    proj = counts @ jnp.asarray(r)  # [N, num_proj]
    bucket = jnp.floor(proj / bucket_length).astype(jnp.int32)
    # AND-composition (Spark semantics): one composite key per hash table —
    # a candidate must fall in the same bucket for EVERY projection.  This is
    # what makes BRP so lossy on order-sensitive similarity (paper Fig. 10).
    space = 1 << 16
    bucket = jnp.clip(bucket, -(space // 2), space // 2 - 1) + space // 2
    key = jnp.zeros((bucket.shape[0],), jnp.int32)
    for i in range(num_proj):
        key = (key * 1_000_003 + bucket[:, i]) % ((1 << 31) - 1)
    return jnp.abs(key)[:, None]


def brp_candidates(
    type_codes: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    num_types: int,
    num_proj: int = 4,
    bucket_length: float = 2.0,
    pair_capacity: int,
    seed: int = 0,
) -> CandidatePairs:
    keys = brp_bucket_keys(
        type_codes,
        lengths,
        num_types=num_types,
        num_proj=num_proj,
        bucket_length=bucket_length,
        seed=seed,
    )
    return ssh_candidates(keys, pair_capacity=pair_capacity)
