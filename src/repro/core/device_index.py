"""Device-resident bucket state for the in-mesh incremental streaming join.

The host :class:`~repro.core.stream_index.BucketIndex` keeps the whole
key -> [row ids] join state on the driver, so every streaming update
round-trips the world's buckets through host Python — the centralized wall
the paper's distributed hash-join design exists to remove.  This module is
the device-side replacement: the bucket table becomes a **key-sharded
sorted slab** per shard —

  * ``slab_keys`` int32 ``[cap]``: every (key, row) occurrence this shard
    owns (``owner = hash(key) % n_shards``), sorted ascending by key with
    ``PAD_KEY`` (= INT32_MAX) padding at the end, so one ``searchsorted``
    finds any key's bucket as a contiguous run;
  * ``slab_rows`` int32 ``[cap]``: the owning row id of each slot, aligned
    with ``slab_keys`` (``PAD_ID`` in padding slots).

Two pure, jittable kernels operate on one shard's slab (the shard_map
program in ``api/sharded.py`` wraps them with the routing collectives):

  :func:`probe_pairs`   enumerate this update's delta pairs — new-vs-old
                        via a searchsorted range probe of the resident
                        slab, new-vs-new via equal-key run ranks over the
                        sorted incoming rows — into fixed-capacity buffers
                        with exact pre-dedup ``examined`` accounting.
  :func:`merge_insert`  sorted-merge the incoming (key, row) rows into the
                        slab via two ``searchsorted`` position computations
                        (a stable merge by key: old entries keep their
                        order, new entries append after equal keys), with
                        drop-mode overflow accounting — entries beyond the
                        static capacity are counted, never silently lost,
                        and the caller regrows + retries.

Everything is int32 (jax x64 stays off); sorting uses ``lax.sort`` with
two carry keys instead of packed 64-bit composites.  ``probe_pairs_ref``
and ``merge_insert_ref`` are the numpy oracles the golden-shape tests pin
the kernels against.

The one host-side remnant is :class:`StreamJoinStats`: per-key occurrence
COUNTS (never row ids — pairs cannot be reconstructed from it) so the
driver can plan exact skew-aware slab and emitted-pair capacities, the
same "driver learns partition statistics" discipline as
``plan_capacities``.  The join state itself never transits the host.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ssh import _runs
from repro.core.types import PAD_ID, PAD_KEY


def _enumerate_slots(excl: jnp.ndarray, counts: jnp.ndarray, cap: int):
    """Invert slot -> (entry, offset) for run-length pair enumeration.

    excl: non-decreasing exclusive prefix sum of ``counts``.  Slot ``p``
    belongs to the last entry ``e`` with ``excl[e] <= p`` (entries with
    zero count share their successor's prefix value and are never
    selected for valid slots); offset ``t = p - excl[e]``.
    """
    n = excl.shape[0]
    p = jnp.arange(cap, dtype=jnp.int32)
    e = jnp.searchsorted(excl, p, side="right").astype(jnp.int32) - 1
    e = jnp.clip(e, 0, n - 1)
    t = p - excl[e]
    total = excl[-1] + counts[-1]
    return p, e, t, total


def probe_pairs(
    slab_keys: jnp.ndarray,
    slab_rows: jnp.ndarray,
    keys: jnp.ndarray,
    rows: jnp.ndarray,
    *,
    nn_cap: int,
    no_cap: int,
):
    """Delta pairs of one update's incoming (key, row) rows on one shard.

    slab_keys/slab_rows: the resident sorted slab (PAD at the end).
    keys/rows: int32 [R] incoming occurrences, PAD-padded anywhere (the
        post-route buffer); sorted internally.
    nn_cap/no_cap: static capacities of the new-vs-new / new-vs-old pair
        buffers (planned exactly host-side; overflow counted, not dropped
        silently — the caller retries with doubled buffers).

    Returns ``(lo [nn_cap + no_cap], hi, examined, overflow)``: canonical
    (lo < hi possibly unordered until min/max — we emit min/max) pre-dedup
    delta pairs with PAD_ID in unused slots, the exact number of
    collisions examined (new-vs-old + new-vs-new, the same per-bucket
    partition quantity ``BucketIndex.insert`` reports), and the slots that
    did not fit.
    """
    keys_s, rows_s = jax.lax.sort((keys, rows), num_keys=2)
    valid = keys_s != PAD_KEY
    # new-vs-new: rank within equal-key runs of the incoming rows — entry
    # at in-run rank r pairs with the r earlier run members (C(m, 2) per
    # key), exactly the in-batch collisions the host index examines
    rank, _ = _runs(keys_s)
    contrib = jnp.where(valid, rank, 0)
    excl_nn = jnp.cumsum(contrib) - contrib
    p, e, t, nn_total = _enumerate_slots(excl_nn, contrib, nn_cap)
    partner = jnp.clip(e - rank[e] + t, 0, keys_s.shape[0] - 1)
    ok = p < nn_total
    nn_a = jnp.where(ok, rows_s[e], PAD_ID)
    nn_b = jnp.where(ok, rows_s[partner], PAD_ID)
    # new-vs-old: searchsorted range probe of the resident slab — valid
    # slab entries sort before PAD_KEY, so [lo_idx, hi_idx) is exactly the
    # resident bucket of each incoming key
    lo_idx = jnp.searchsorted(slab_keys, keys_s, side="left").astype(jnp.int32)
    hi_idx = jnp.searchsorted(slab_keys, keys_s, side="right").astype(jnp.int32)
    counts = jnp.where(valid, hi_idx - lo_idx, 0)
    excl_no = jnp.cumsum(counts) - counts
    q, f, u, no_total = _enumerate_slots(excl_no, counts, no_cap)
    sidx = jnp.clip(lo_idx[f] + u, 0, slab_keys.shape[0] - 1)
    # tombstones: a retired entry keeps its key (so the searchsorted run
    # and the examined count stay exact) but its row is PAD_ID — it is
    # examined like any resident slot, just never emitted as a pair
    ok2 = (q < no_total) & (slab_rows[sidx] != PAD_ID)
    no_a = jnp.where(ok2, slab_rows[sidx], PAD_ID)
    no_b = jnp.where(ok2, rows_s[f], PAD_ID)
    a = jnp.concatenate([nn_a, no_a])
    b = jnp.concatenate([nn_b, no_b])
    examined = (nn_total + no_total).astype(jnp.int32)
    overflow = (
        jnp.maximum(nn_total - nn_cap, 0) + jnp.maximum(no_total - no_cap, 0)
    ).astype(jnp.int32)
    return jnp.minimum(a, b), jnp.maximum(a, b), examined, overflow


def merge_insert(
    slab_keys: jnp.ndarray,
    slab_rows: jnp.ndarray,
    keys: jnp.ndarray,
    rows: jnp.ndarray,
):
    """Sorted-merge the incoming (key, row) rows into the resident slab.

    A stable merge by key via two ``searchsorted`` position computations:
    old entry ``i`` lands at ``i + |new keys < key_i|``, new entry ``j``
    (after an internal sort) at ``j + |old keys <= key_j|`` — old entries
    keep their relative order and new entries append after equal keys
    (streaming row ids only grow, so the slab stays sorted by (key, id)).
    PAD_KEY sorts last on both sides, so valid entries compact to the
    front and truncating to the static capacity drops padding first; any
    dropped VALID entries are counted in ``overflow`` (the caller regrows
    the slab and retries — the drop is never committed).

    Returns ``(slab_keys', slab_rows', overflow)`` at the same capacity.
    """
    cap = slab_keys.shape[0]
    keys_s, rows_s = jax.lax.sort((keys, rows), num_keys=2)
    r = keys_s.shape[0]
    pos_old = (
        jnp.arange(cap, dtype=jnp.int32)
        + jnp.searchsorted(keys_s, slab_keys, side="left").astype(jnp.int32)
    )
    pos_new = (
        jnp.arange(r, dtype=jnp.int32)
        + jnp.searchsorted(slab_keys, keys_s, side="right").astype(jnp.int32)
    )
    merged_k = (
        jnp.full((cap + r,), PAD_KEY, jnp.int32)
        .at[pos_old].set(slab_keys)
        .at[pos_new].set(keys_s)
    )
    merged_r = (
        jnp.full((cap + r,), PAD_ID, jnp.int32)
        .at[pos_old].set(slab_rows)
        .at[pos_new].set(rows_s)
    )
    entries = jnp.sum(slab_keys != PAD_KEY) + jnp.sum(keys_s != PAD_KEY)
    overflow = jnp.maximum(entries - cap, 0).astype(jnp.int32)
    return merged_k[:cap], merged_r[:cap], overflow


def probe_rows(
    slab_keys: jnp.ndarray,
    slab_rows: jnp.ndarray,
    keys: jnp.ndarray,
    payload: jnp.ndarray,
    *,
    cap: int,
):
    """Read-only range probe: resident rows matching each incoming key.

    The query-serving half of :func:`probe_pairs`: the same searchsorted
    range probe of the resident sorted slab, but nothing else — no
    new-vs-new stage (queries never pair with each other), no merge (the
    slab is never modified), and no min/max canonicalization (the incoming
    ``payload`` ids — query indices — live in a different namespace than
    the resident row ids, so ordering them would conflate the two).

    slab_keys/slab_rows: the resident sorted slab (PAD at the end).
    keys/payload: int32 [R] incoming (key, payload id) occurrences,
        PAD-padded anywhere (the post-route buffer); sorted internally.
    cap: static capacity of the match buffer (planned exactly host-side
        from the count mirror; overflow counted, never silently dropped).

    Returns ``(rows [cap], out_payload [cap], examined, overflow)``:
    every (resident row, payload) match with PAD_ID in unused slots, the
    exact pre-dedup match count, and the slots that did not fit.
    """
    keys_s, pay_s = jax.lax.sort((keys, payload), num_keys=2)
    valid = keys_s != PAD_KEY
    lo_idx = jnp.searchsorted(slab_keys, keys_s, side="left").astype(jnp.int32)
    hi_idx = jnp.searchsorted(slab_keys, keys_s, side="right").astype(jnp.int32)
    counts = jnp.where(valid, hi_idx - lo_idx, 0)
    excl = jnp.cumsum(counts) - counts
    q, f, u, total = _enumerate_slots(excl, counts, cap)
    sidx = jnp.clip(lo_idx[f] + u, 0, slab_keys.shape[0] - 1)
    # tombstoned slots (row == PAD_ID) are examined but never emitted,
    # matching probe_pairs' deletion semantics
    ok = (q < total) & (slab_rows[sidx] != PAD_ID)
    rows = jnp.where(ok, slab_rows[sidx], PAD_ID)
    out_payload = jnp.where(ok, pay_s[f], PAD_ID)
    examined = total.astype(jnp.int32)
    overflow = jnp.maximum(total - cap, 0).astype(jnp.int32)
    return rows, out_payload, examined, overflow


def mark_dead_rows(slab_rows: jnp.ndarray, dead_sorted: jnp.ndarray):
    """Tombstone every slab slot whose row id is in ``dead_sorted``.

    dead_sorted: int32 [R] ascending retired row ids, PAD_ID-padded at the
    end (PAD_ID never matches a live row, and a PAD_ID slab slot matching
    the padding is already dead — the write is idempotent).  Keys are NOT
    touched: the tombstone keeps its key so the sorted-slab searchsorted
    invariant and the exact examined accounting survive; only the row
    becomes PAD_ID, which :func:`probe_pairs`/:func:`probe_rows` mask out
    of emission.  O(cap log R), no collectives — the slab never leaves
    the device.
    """
    idx = jnp.searchsorted(dead_sorted, slab_rows).astype(jnp.int32)
    idx = jnp.clip(idx, 0, dead_sorted.shape[0] - 1)
    hit = dead_sorted[idx] == slab_rows
    return jnp.where(hit, PAD_ID, slab_rows)


def compact_slab(
    slab_keys: jnp.ndarray,
    slab_rows: jnp.ndarray,
    shift: jnp.ndarray,
    *,
    out_cap: int,
):
    """Drop-mode compaction of one shard's slab: reclaim tombstones.

    A stable partition — live slots (row != PAD_ID) keep their (key, id)
    sort order and move to the front, tombstones and padding become
    (PAD_KEY, PAD_ID) at the end — implemented as one ``lax.sort`` on
    (dead flag, original position) carrying keys and rows.  Surviving row
    ids are rebased by ``shift`` (a scalar int32 operand: the world-base
    delta of a prefix-rebase compaction; 0 keeps ids unchanged), so the
    kernel never recompiles when the base moves.

    out_cap: static output capacity — compaction is the one boundary
    where the slab may SHRINK (the planning mirror's post-compaction
    entry counts justify it); live entries beyond ``out_cap`` are counted
    in ``overflow`` and the caller must re-run with a bigger out_cap
    (never committed lossily, same contract as :func:`merge_insert`).

    Returns ``(keys' [out_cap], rows' [out_cap], live, overflow)``.
    """
    cap = slab_keys.shape[0]
    dead = (slab_rows == PAD_ID).astype(jnp.int32)
    pos = jnp.arange(cap, dtype=jnp.int32)
    _, _, keys_c, rows_c = jax.lax.sort(
        (dead, pos, slab_keys, slab_rows), num_keys=2
    )
    live = (cap - jnp.sum(dead)).astype(jnp.int32)
    keep = pos < live
    keys_c = jnp.where(keep, keys_c, PAD_KEY)
    rows_c = jnp.where(keep, rows_c - shift.astype(jnp.int32), PAD_ID)
    if out_cap >= cap:
        pad = ((0, out_cap - cap),)
        keys_o = jnp.pad(keys_c, pad, constant_values=PAD_KEY)
        rows_o = jnp.pad(rows_c, pad, constant_values=PAD_ID)
    else:
        keys_o = keys_c[:out_cap]
        rows_o = rows_c[:out_cap]
    overflow = jnp.maximum(live - out_cap, 0).astype(jnp.int32)
    return keys_o, rows_o, live, overflow


# ---------------------------------------------------------------------------
# numpy references (the golden-shape oracles)
# ---------------------------------------------------------------------------
def probe_pairs_ref(slab_keys, slab_rows, keys, rows):
    """Bucket-semantics oracle for :func:`probe_pairs`: the pre-dedup
    (lo, hi) multiset and the exact examined count, computed from plain
    per-key dict buckets.  Tombstoned slab slots (row == PAD_ID under a
    live key) are examined like any resident member but never emitted."""
    slab_keys = np.asarray(slab_keys)
    slab_rows = np.asarray(slab_rows)
    buckets: dict[int, list[int]] = {}
    for k, rid in zip(slab_keys.tolist(), slab_rows.tolist()):
        if k != PAD_KEY:
            buckets.setdefault(k, []).append(rid)
    order = np.lexsort((np.asarray(rows), np.asarray(keys)))
    pairs = []
    examined = 0
    seen: dict[int, list[int]] = {}
    for i in order:
        k, rid = int(np.asarray(keys)[i]), int(np.asarray(rows)[i])
        if k == PAD_KEY:
            continue
        for m in buckets.get(k, []) + seen.get(k, []):
            examined += 1
            if m != PAD_ID:
                pairs.append((min(m, rid), max(m, rid)))
        seen.setdefault(k, []).append(rid)
    return pairs, examined


def probe_rows_ref(slab_keys, slab_rows, keys, payload):
    """Bucket-semantics oracle for :func:`probe_rows`: the pre-dedup
    (resident row, payload) match multiset and the exact examined count."""
    slab_keys = np.asarray(slab_keys)
    slab_rows = np.asarray(slab_rows)
    buckets: dict[int, list[int]] = {}
    for k, rid in zip(slab_keys.tolist(), slab_rows.tolist()):
        if k != PAD_KEY:
            buckets.setdefault(k, []).append(rid)
    matches = []
    examined = 0
    for k, p in zip(np.asarray(keys).tolist(), np.asarray(payload).tolist()):
        if k == PAD_KEY:
            continue
        for m in buckets.get(k, []):
            examined += 1
            if m != PAD_ID:
                matches.append((m, p))
    return matches, examined


def merge_insert_ref(slab_keys, slab_rows, keys, rows, cap):
    """Stable-merge oracle for :func:`merge_insert`."""
    entries = [
        (int(k), int(r))
        for k, r in zip(np.asarray(slab_keys), np.asarray(slab_rows))
        if k != PAD_KEY
    ]
    new = sorted(
        (int(k), int(r))
        for k, r in zip(np.asarray(keys), np.asarray(rows))
        if k != PAD_KEY
    )
    merged = sorted(entries + new, key=lambda kr: kr[0])
    overflow = max(len(merged) - cap, 0)
    merged = merged[:cap]
    out_k = np.full((cap,), PAD_KEY, np.int32)
    out_r = np.full((cap,), PAD_ID, np.int32)
    for i, (k, r) in enumerate(merged):
        out_k[i], out_r[i] = k, r
    return out_k, out_r, overflow


def compact_slab_ref(slab_keys, slab_rows, shift, out_cap):
    """Stable-partition oracle for :func:`compact_slab`."""
    live = [
        (int(k), int(r) - int(shift))
        for k, r in zip(np.asarray(slab_keys), np.asarray(slab_rows))
        if r != PAD_ID
    ]
    overflow = max(len(live) - out_cap, 0)
    out_k = np.full((out_cap,), PAD_KEY, np.int32)
    out_r = np.full((out_cap,), PAD_ID, np.int32)
    for i, (k, r) in enumerate(live[:out_cap]):
        out_k[i], out_r[i] = k, r
    return out_k, out_r, len(live), overflow


# ---------------------------------------------------------------------------
# host-side planning statistics (counts only — never ids)
# ---------------------------------------------------------------------------
class StreamJoinStats:
    """Per-key occurrence counts for exact device-join capacity planning.

    The driver's only residual join state: ``counts[key]`` — how many rows
    ever produced ``key`` — and the per-owner slab occupancy.  Row ids are
    deliberately NOT kept (the pair set cannot be reconstructed from this
    mirror; the bucket lists that grow unboundedly live on the devices).
    ``plan_update`` computes, per owner shard, the exact pre-dedup
    new-vs-old / new-vs-new emission counts and slab-entry deltas of one
    update; ``commit`` folds the update in once the device run is
    accepted, so overflow retries replan from unchanged statistics.

    Deletion keeps the mirror honest about DEFERRED reclamation: retired
    rows' occurrences stay in ``counts`` (their tombstones still occupy
    slab slots and are still examined by every probe) and are additionally
    tracked in ``dead_counts``/``owner_dead`` until :meth:`compact`
    subtracts them — so capacity plans between compactions cover the
    tombstones, and shrink exactly at the compaction boundary.
    """

    def __init__(self, n_shards: int):
        self.n_shards = n_shards
        self.counts: dict[int, int] = {}
        self.owner_entries = np.zeros((n_shards,), np.int64)
        self.dead_counts: dict[int, int] = {}
        self.owner_dead = np.zeros((n_shards,), np.int64)

    def plan_update(self, keys_flat: np.ndarray, owners_flat: np.ndarray):
        """Exact per-owner loads of inserting ``keys_flat`` (per-row-deduped
        flat key occurrences, in row order) with precomputed owners.

        Returns ``(new_vs_old, new_vs_new, entries_delta)``, each int64
        ``[n_shards]``.
        """
        nvo = np.zeros((self.n_shards,), np.int64)
        nvn = np.zeros((self.n_shards,), np.int64)
        ent = np.zeros((self.n_shards,), np.int64)
        if keys_flat.size == 0:
            return nvo, nvn, ent
        uniq, first = np.unique(keys_flat, return_index=True)
        counts = np.bincount(
            np.searchsorted(uniq, keys_flat), minlength=uniq.shape[0]
        )
        owners = owners_flat[first]
        for k, m, o in zip(uniq.tolist(), counts.tolist(), owners.tolist()):
            old = self.counts.get(k, 0)
            nvo[o] += old * m
            nvn[o] += m * (m - 1) // 2
            ent[o] += m
        return nvo, nvn, ent

    def commit(self, keys_flat: np.ndarray, owners_flat: np.ndarray) -> None:
        if keys_flat.size == 0:
            return
        uniq, first = np.unique(keys_flat, return_index=True)
        counts = np.bincount(
            np.searchsorted(uniq, keys_flat), minlength=uniq.shape[0]
        )
        for k, m in zip(uniq.tolist(), counts.tolist()):
            self.counts[k] = self.counts.get(k, 0) + int(m)
        np.add.at(self.owner_entries, owners_flat, 1)

    def retire(self, keys_flat: np.ndarray, owners_flat: np.ndarray) -> None:
        """Fold one retirement's tombstoned key occurrences into the dead
        ledger.  ``counts``/``owner_entries`` are NOT reduced — the
        tombstones still occupy (and are examined in) their slab slots —
        only :meth:`compact` reclaims them."""
        if keys_flat.size == 0:
            return
        uniq, first = np.unique(keys_flat, return_index=True)
        counts = np.bincount(
            np.searchsorted(uniq, keys_flat), minlength=uniq.shape[0]
        )
        for k, m in zip(uniq.tolist(), counts.tolist()):
            self.dead_counts[k] = self.dead_counts.get(k, 0) + int(m)
        np.add.at(self.owner_dead, owners_flat, 1)

    def compact(self) -> None:
        """Reclaim the dead ledger: subtract tombstoned occurrences from
        the planning counts (dropping emptied keys) and the per-owner
        occupancy — the host mirror of one device slab compaction."""
        for k, m in self.dead_counts.items():
            left = self.counts.get(k, 0) - m
            if left > 0:
                self.counts[k] = left
            else:
                self.counts.pop(k, None)
        self.dead_counts = {}
        self.owner_entries = np.maximum(
            self.owner_entries - self.owner_dead, 0
        )
        self.owner_dead = np.zeros((self.n_shards,), np.int64)

    def dead_fraction(self) -> float:
        """Max per-owner tombstone fraction of the resident slab entries
        (the compaction watermark input)."""
        occ = np.maximum(self.owner_entries, 1)
        return float(np.max(self.owner_dead / occ)) \
            if self.owner_entries.sum() else 0.0

    @property
    def num_keys(self) -> int:
        return len(self.counts)


class ShardSummaries:
    """Per-world-shard length summaries for REPOSE-style serve pruning.

    Maintained on INSERT (O(d) per micro-batch, counts and maxima only —
    never trajectory content): for each round-robin world shard
    (``shard = id % n_shards``) the row count and the maximum trajectory
    length of any resident row.  At query time the free MSS bound
    ``betas_sum * min(len_query, max_len[shard])`` upper-bounds every
    candidate the shard can hold, so a shard whose bound cannot beat the
    query's ``rho`` — or, once k matches exist, its running kth-best —
    is skipped before a single code row is scored (the reference-length
    partition bound of REPOSE, PAPERS.md).
    """

    def __init__(self, n_shards: int):
        self.n_shards = n_shards
        self.rows = np.zeros((n_shards,), np.int64)
        self.max_len = np.zeros((n_shards,), np.int64)

    def insert(self, first_id: int, lengths: np.ndarray) -> None:
        """Fold one micro-batch of rows ``first_id .. first_id + d - 1``."""
        lengths = np.asarray(lengths, np.int64).reshape(-1)
        if lengths.size == 0:
            return
        shard = (first_id + np.arange(lengths.shape[0], dtype=np.int64)) \
            % self.n_shards
        np.add.at(self.rows, shard, 1)
        np.maximum.at(self.max_len, shard, lengths)

    def rebuild(self, first_id: int, lengths: np.ndarray,
                alive: np.ndarray) -> None:
        """Recompute the summaries from the LIVE rows only.

        Maxima cannot be maintained under deletion (removing the longest
        row must LOWER the shard's bound, or ``serve_prune`` keeps
        scanning shards for matches that no longer exist), so eviction
        recomputes from the host length mirror: rows ``first_id ..
        first_id + len - 1`` with ``alive[i]`` true.  O(live) per
        retirement — summaries stay sound and tight."""
        lengths = np.asarray(lengths, np.int64).reshape(-1)
        alive = np.asarray(alive, bool).reshape(-1)
        self.rows = np.zeros((self.n_shards,), np.int64)
        self.max_len = np.zeros((self.n_shards,), np.int64)
        if lengths.size == 0:
            return
        shard = (first_id + np.arange(lengths.shape[0], dtype=np.int64)) \
            % self.n_shards
        np.add.at(self.rows, shard[alive], 1)
        np.maximum.at(self.max_len, shard[alive], lengths[alive])
