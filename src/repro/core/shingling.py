"""Phase (ii) part 1: k-sequential shingling (paper Definition 3, Algorithm 1).

A k-sequential shingle is an order-preserving k-subsequence of the *type*
level codes of a trajectory.  The paper's Algorithm 1 is a triple nested loop
(k=3); on TPU we replace it with a static gather over the precomputed
C(L_max, k) index combinations followed by a base-Q integer pack, one vector
op per combination batch — O(N * C(L,k)) work with zero data-dependent
control flow.  Set semantics (distinct shingles per trajectory) are restored
with an in-row sort + duplicate masking, as the paper dedups shingles before
the self-join.

The packed shingle key is ``sum_i code_i * Q**(k-1-i)`` — a perfect hash of
the shingle (no collisions), which is what lets AnotherMe achieve 100%
accuracy where MinHash/BRP lose information.  We require Q**k < 2**31 and
fall back to a 2-word key above that (not needed for the paper's Q<=300,k=3).
"""
from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import PAD_KEY


# Hard budget on C(max_len, k): the combination table materializes
# eagerly into an unbounded lru_cache, so an oversized (max_len, k) would
# exhaust host memory before any shape error surfaced.  2M combos is
# ~24 MB at k=3 — far above the paper's C(10, 3) = 120 but small enough
# that the failure mode is a clear exception, not an OOM.
MAX_SHINGLE_COMBOS = 2_000_000


@functools.lru_cache(maxsize=None)
def shingle_indices(max_len: int, k: int) -> np.ndarray:
    """All C(max_len, k) strictly-increasing index k-tuples, int32 [S, k]."""
    from math import comb

    n_combos = comb(max_len, k) if max_len >= k >= 0 else 0
    if n_combos > MAX_SHINGLE_COMBOS:
        raise ValueError(
            f"C({max_len}, {k}) = {n_combos} shingle combinations exceeds "
            f"the budget of {MAX_SHINGLE_COMBOS}; shingling the full "
            "trajectory at this length would exhaust host memory.  Use the "
            "windowed subtrajectory mode instead — "
            "EngineConfig(subtraj_window=W) shingles C(W, k) combinations "
            "per sliding window."
        )
    combos = np.array(list(itertools.combinations(range(max_len), k)), dtype=np.int32)
    if combos.size == 0:
        combos = combos.reshape(0, k)
    return combos


def num_shingles(max_len: int, k: int) -> int:
    return shingle_indices(max_len, k).shape[0]


def expected_collision_rate(avg_len: float, k: int, num_types: int) -> float:
    """The paper's collision-rate model: C(L, k) / Q**k (section IV.2)."""
    from math import comb

    return comb(int(avg_len), k) / float(num_types) ** k


def pack_keys(codes: jnp.ndarray, num_types: int) -> jnp.ndarray:
    """Base-Q pack of [..., k] type codes into one int32 key."""
    k = codes.shape[-1]
    if num_types**k >= 2**31:
        raise ValueError(
            f"Q**k = {num_types}**{k} overflows int32; use a smaller k or Q "
            "(the paper uses Q<=300, k=3)."
        )
    key = jnp.zeros(codes.shape[:-1], dtype=jnp.int32)
    for i in range(k):
        key = key * num_types + codes[..., i]
    return key


@functools.partial(jax.jit, static_argnames=("k", "num_types", "dedup"))
def shingles_from_types(
    type_codes: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    k: int,
    num_types: int,
    dedup: bool = True,
) -> jnp.ndarray:
    """Distinct k-sequential shingle keys per trajectory.

    type_codes: int32 [N, L] (coarsest-level codes, padding may be negative)
    lengths:    int32 [N]
    returns:    int32 [N, S] ascending-sorted keys, PAD_KEY padded,
                S = C(L, k).
    """
    n, L = type_codes.shape
    idx = jnp.asarray(shingle_indices(L, k))  # [S, k]
    # gather: [N, S, k]
    gathered = type_codes[:, idx]
    # a combination is valid iff its last (largest) index < length
    valid = idx[:, -1][None, :] < lengths[:, None]  # [N, S]
    safe = jnp.where(valid[..., None], gathered, 0)
    keys = pack_keys(safe, num_types)
    keys = jnp.where(valid, keys, PAD_KEY)
    if dedup:
        keys = jnp.sort(keys, axis=-1)
        dup = jnp.concatenate(
            [jnp.zeros((n, 1), dtype=bool), keys[:, 1:] == keys[:, :-1]], axis=1
        )
        keys = jnp.where(dup, PAD_KEY, keys)
        keys = jnp.sort(keys, axis=-1)
    return keys


def shingles(encoded_codes: jnp.ndarray, lengths: jnp.ndarray, *, k: int,
             num_types: int, level: int = 0, dedup: bool = True) -> jnp.ndarray:
    """Convenience wrapper taking EncodedBatch.codes [N, n_levels, L]."""
    return shingles_from_types(
        encoded_codes[:, level, :], lengths, k=k, num_types=num_types, dedup=dedup
    )


def windowed_types(
    type_codes: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    window: int,
    stride: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sliding-window view for the subtrajectory mode: [N, L] -> [N*nw, W].

    Window j of row i (j < nw, see :func:`repro.core.subtraj.num_windows`)
    starts at offset ``j * stride`` and holds
    ``clip(lengths[i] - j*stride, 0, W)`` valid positions; every window of
    row i becomes its own virtual row ``i * nw + j``, so downstream key
    machinery (``shingles_from_types``, MinHash, BRP) runs UNCHANGED over
    the windowed view — a window's keys are ``S = C(W, k)`` combinations
    instead of ``C(L, k)``.  Positions past a window's valid length gather
    clamped garbage; callers mask by the returned window lengths exactly
    as they mask full rows by ``lengths`` (both shingling and the hash
    backends already do).
    """
    from repro.core.subtraj import num_windows

    n, L = type_codes.shape
    W = min(window, L)
    nw = num_windows(L, window, stride)
    offs = jnp.arange(nw, dtype=jnp.int32) * stride           # [nw]
    pos = offs[:, None] + jnp.arange(W, dtype=jnp.int32)      # [nw, W]
    win = type_codes[:, jnp.clip(pos, 0, L - 1)]              # [N, nw, W]
    wlen = jnp.clip(lengths[:, None] - offs[None, :], 0, W)   # [N, nw]
    return win.reshape(n * nw, W), wlen.reshape(n * nw)
