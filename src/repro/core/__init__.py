"""AnotherMe: large-scale semantic trajectory analysis (the paper's core).

Public API:
    SemanticForest / make_random_forest / encode_batch     (phase i)
    shingles_from_types / ssh_candidates                   (phase ii)
    multi_level_lcs / mss_scores / score_pairs             (phase iii)
    maximal_cliques / connected_components / qa1 / qa2     (phase iv)
    run_anotherme / AnotherMeConfig                        (end-to-end)
    baselines: centralized_similar_pairs, minhash_candidates,
               brp_candidates, udf_pipeline
"""
from repro.core.types import (
    TrajectoryBatch, EncodedBatch, CandidatePairs, ScoredPairs,
    PAD_PLACE, PAD_KEY, PAD_ID,
)
from repro.core.encoding import (
    SemanticForest, make_random_forest, forest_tables, encode_batch,
    encode_codes, encode_types, type_codes,
)
from repro.core.shingling import (
    shingles_from_types, shingle_indices, num_shingles, expected_collision_rate,
)
from repro.core.similarity import (
    lcs_ref, lcs_wavefront, multi_level_lcs, mss_scores, score_pairs,
    default_betas,
)
from repro.core.ssh import ssh_candidates, dedup_pairs, exact_pair_count
from repro.core.communities import (
    connected_components, components_as_sets, maximal_cliques,
    pairs_to_set, qa1, qa2,
)
from repro.core.pipeline import AnotherMeConfig, AnotherMeResult, run_anotherme
from repro.core.centralized import centralized_similar_pairs
from repro.core.minhash import minhash_candidates, minhash_signatures
from repro.core.brp import brp_candidates
from repro.core.udf import udf_pipeline
