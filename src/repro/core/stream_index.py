"""Incremental bucket tables for streaming candidate generation.

The one-shot join (core/ssh.py) re-sorts the whole world's (key, id) rows on
every run; streaming ingestion instead maintains the join state — one bucket
per distinct key holding the ids of every row that produced it — and probes
only the NEW rows' keys per micro-batch.  The delta pair set it emits is
exactly the set of candidate pairs whose later member arrived in this
update, so the union over updates equals the one-shot join over the
concatenated batch (each pair is generated in exactly one update: the one
in which ``max(i, j)`` arrives).

Every registered backend reduces to PAD_KEY-padded int32 keys ``[N, S]``
(shingles for "ssh"/"udf", band signatures for "minhash", bucket
projections for "brp"), and a row's keys are a pure function of that row
alone — so one index implementation serves all backends, and inserting a
row once keeps its buckets valid forever.

Work accounting: ``insert`` reports the number of (existing member, new
row) collisions it examined — the pre-dedup delta join size.  This is the
quantity the streaming acceptance bound pins: for any update after the
first, pairs examined < the full-world pre-dedup join size that a one-shot
re-run would enumerate.
"""
from __future__ import annotations

import warnings

import numpy as np

from repro.core.types import PAD_KEY

# Bucket lists grow UNBOUNDEDLY for hot keys: a key shared by n rows holds
# an n-entry list and its (n+1)-th arrival examines n collisions, so a
# pathological single-key world costs O(n) driver memory and O(n^2) total
# probe work.  The index stays exact regardless (the warning never changes
# results) — crossing this many members per bucket just surfaces a
# RuntimeWarning, once per key, pointing at the quadratic wall and at
# ``delta_join="device"``, where the bucket state is sharded off the
# driver.
HOT_BUCKET_WARN = 10_000


class BucketIndex:
    """key -> [row ids] bucket table, grown one micro-batch at a time.

    hot_bucket_warn: per-bucket member count past which a RuntimeWarning
    fires (once per key); None disables the check.  Results are exact
    either way — the cap warns, it never truncates.
    """

    def __init__(self, hot_bucket_warn: int | None = HOT_BUCKET_WARN) -> None:
        self._buckets: dict[int, list[int]] = {}
        self.hot_bucket_warn = hot_bucket_warn
        self._warned_keys: set[int] = set()
        self.num_rows = 0
        self.num_keys_inserted = 0
        # LIFETIME pre-dedup collision count (monotone; what `insert`
        # examined, never decremented — the work-accounting series)
        self.pairs_examined_total = 0
        # LIVE sum_buckets C(|bucket|, 2), maintained incrementally by
        # insert/retire — the join size a one-shot run over the CURRENT
        # world would enumerate.  Before `retire` existed these two
        # coincided; under TTL/eviction only this one stays exact.
        self.live_join_size = 0

    @property
    def num_buckets(self) -> int:
        return len(self._buckets)

    def insert(
        self, keys_np: np.ndarray, first_id: int | None = None
    ) -> tuple[np.ndarray, np.ndarray, int]:
        """Insert new rows' keys; return their deduped delta pairs.

        keys_np:  int32 [d, S], PAD_KEY-padded — the join keys of the d new
                  rows, exactly as the backend's ``join_keys`` builds them
                  (S may differ between updates; only non-PAD entries
                  matter).
        first_id: global id of the first new row (defaults to the current
                  world size; rows get ids first_id .. first_id + d - 1).

        Returns ``(lo, hi, examined)``: canonical (lo < hi) deduplicated
        int32 delta pairs — every pair of rows sharing at least one key
        whose LATER member is one of the d new rows — plus the number of
        pre-dedup collisions examined.  Rows are inserted in id order, so
        new-vs-new pairs within the batch are found when the second member
        probes its buckets.
        """
        keys_np = np.asarray(keys_np)
        d = keys_np.shape[0]
        if first_id is None:
            first_id = self.num_rows
        if first_id != self.num_rows:
            raise ValueError(
                f"rows must arrive in order: next id is {self.num_rows}, "
                f"got first_id={first_id}"
            )
        buckets = self._buckets
        lo_out: list[int] = []
        hi_out: list[int] = []
        examined = 0
        for r in range(d):
            rid = first_id + r
            row = keys_np[r]
            # per-row key SET: every backend's keys are distinct per row
            # already (ssh dedups shingles, bands are salted, brp emits one
            # key), but dedup defensively so the examined count stays the
            # exact per-bucket C(n, 2) partition
            row = np.unique(row[row != PAD_KEY])
            for key in row.tolist():
                members = buckets.get(key)
                if members is None:
                    buckets[key] = [rid]
                    continue
                for m in members:
                    if m != rid:  # a repeated in-row key would self-pair
                        examined += 1
                        lo_out.append(m)
                        hi_out.append(rid)
                if members[-1] != rid:  # keep each id once per bucket
                    # the bucket grows |m| -> |m|+1: C(|m|+1, 2) - C(|m|, 2)
                    # new live pairs, i.e. one per existing member
                    self.live_join_size += len(members)
                    members.append(rid)
                    if (self.hot_bucket_warn is not None
                            and len(members) == self.hot_bucket_warn
                            and key not in self._warned_keys):
                        self._warned_keys.add(key)
                        warnings.warn(
                            f"BucketIndex bucket for key {key} reached "
                            f"{len(members)} members; its list grows "
                            "unboundedly on the driver and each further "
                            "arrival examines O(members) collisions. "
                            "Results stay exact, but consider "
                            'delta_join="device" to shard the bucket '
                            "state off the driver.",
                            RuntimeWarning, stacklevel=2,
                        )
            self.num_keys_inserted += row.shape[0]
        self.num_rows = first_id + d
        self.pairs_examined_total += examined
        if not lo_out:
            empty = np.empty(0, np.int32)
            return empty, empty.copy(), examined
        lo = np.asarray(lo_out, np.int64)
        hi = np.asarray(hi_out, np.int64)
        # canonicalize + dedup (a pair sharing several keys appears once),
        # matching dedup_pairs' exactly-once contract
        packed = np.unique(
            (np.minimum(lo, hi) << 32) | np.maximum(lo, hi)
        )
        return (
            (packed >> 32).astype(np.int32),
            (packed & 0xFFFFFFFF).astype(np.int32),
            examined,
        )

    def retire(self, ids, keys_np: np.ndarray) -> None:
        """Evict rows from their buckets — exact removal, host-side.

        ids:     int [d] global row ids being retired (any order; ids
                 absent from their buckets are ignored, so the call is
                 idempotent and safe after a prior eviction).
        keys_np: int32 [d, S] PAD_KEY-padded join keys of those rows,
                 recomputed by the caller from its host mirror (keys are a
                 pure per-row function, so they are always recoverable).

        Unlike the device slab — which defers reclamation behind
        tombstones until a watermark compaction — the host oracle evicts
        EAGERLY: each bucket list shrinks the moment a member retires, so
        a pathological hot bucket under TTL/eviction is bounded by its
        LIVE membership (the satellite fix for the unbounded driver lists
        past ``hot_bucket_warn``), and every subsequent ``insert`` probes
        exactly the live world.  O(bucket length) per (key, id).
        """
        keys_np = np.asarray(keys_np)
        removed = 0
        for r, rid in enumerate(np.asarray(ids).tolist()):
            row = np.unique(keys_np[r][keys_np[r] != PAD_KEY])
            for key in row.tolist():
                members = self._buckets.get(key)
                if members is None:
                    continue
                try:
                    members.remove(rid)
                    removed += 1
                    # the bucket shrinks |m| -> |m|-1: the evicted member
                    # contributed one live pair per REMAINING member
                    self.live_join_size -= len(members)
                except ValueError:
                    continue
                if not members:
                    del self._buckets[key]
                    self._warned_keys.discard(key)
        self.num_keys_inserted -= removed

    def max_bucket_len(self) -> int:
        """Largest live bucket (the hot-bucket boundedness probe)."""
        return max((len(m) for m in self._buckets.values()), default=0)

    def probe(
        self, keys_np: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, int]:
        """Read-only probe: resident rows sharing >= 1 key per query row.

        The serving half of :meth:`insert` — the same bucket lookups, but
        the probing rows are NEVER inserted (queries are not part of the
        world, so the index is left untouched and concurrent queries
        commute with updates).  This is the host implementation of the
        read-only ``probe(keys)`` protocol that
        :func:`repro.core.device_index.probe_rows` implements for the
        device-resident slab index — the query engine works against either
        without branching.

        keys_np: int32 [Q, S] PAD_KEY-padded join keys of the Q query
        rows, exactly as the backend's ``join_keys`` builds them.

        Returns ``(qidx, rows, examined)``: deduplicated int32 (query
        index, resident row id) candidate pairs (a pair sharing several
        keys appears once) plus the exact pre-dedup collision count.
        """
        keys_np = np.asarray(keys_np)
        buckets = self._buckets
        q_out: list[int] = []
        r_out: list[int] = []
        examined = 0
        for q in range(keys_np.shape[0]):
            row = keys_np[q]
            row = np.unique(row[row != PAD_KEY])
            seen: set[int] = set()
            for key in row.tolist():
                for m in buckets.get(key, ()):
                    examined += 1
                    if m not in seen:
                        seen.add(m)
                        q_out.append(q)
                        r_out.append(m)
        return (
            np.asarray(q_out, np.int32),
            np.asarray(r_out, np.int32),
            examined,
        )

    def full_join_size(self) -> int:
        """The pre-dedup pair count a one-shot join over the CURRENT world
        would enumerate: ``sum_buckets C(|bucket|, 2)``.  O(1): insert
        adds each new collision to the live counter when the later member
        arrives, and ``retire`` subtracts each evicted member's remaining
        per-bucket contributions — so the counter tracks the live sum
        exactly under TTL/windowed eviction (the partition property the
        equivalence suite pins against an independent per-key oracle).
        ``pairs_examined_total`` stays the LIFETIME examined count; before
        the first retire the two coincide."""
        return self.live_join_size
