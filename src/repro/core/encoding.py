"""Phase (i): semantic encoding via the semantic forest (paper section IV.1).

The semantic forest organises places into ``n_levels`` granularities, finest
(place name) to coarsest (place type).  A place name id is mapped to its code
at every level through composed parent lookups, producing the paper's
``E_type.E_class.E_name`` encoding as an int32 tensor ``[N, n_levels, L]``.

The forest is represented densely: ``parents[l]`` maps a level-(l+1) id to its
level-l parent id (level 0 = coarsest).  This is the array analogue of the
WordNet-derived ontology the paper describes, and generalises to any number of
levels (used by the Fig. 15 experiment, levels 2..6).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import EncodedBatch, TrajectoryBatch, PAD_PLACE

# Padding sentinels for encoded codes.  Using two *different* negative values
# for the two sides of a comparison guarantees padded positions never match
# (similarity.py relies on this).
PAD_CODE_A = -1
PAD_CODE_B = -2


@dataclasses.dataclass(frozen=True)
class SemanticForest:
    """A dense n-level semantic forest.

    parents[l][child_id] -> parent id at level l, for l in [0, n_levels-2];
    parents[l] maps level-(l+1) ids into level-l ids.
    sizes[l] = number of distinct codes at level l (coarsest first).
    """

    parents: tuple  # tuple of np.ndarray[int32]
    sizes: tuple    # tuple of int

    @property
    def num_levels(self) -> int:
        return len(self.sizes)

    @property
    def num_types(self) -> int:
        """Vocabulary size at the coarsest ("type") level — the SSH alphabet Q."""
        return self.sizes[0]

    @property
    def num_places(self) -> int:
        return self.sizes[-1]

    def level_maps(self) -> list[np.ndarray]:
        """For each level l, an array mapping place (name) id -> level-l code."""
        maps = [np.arange(self.sizes[-1], dtype=np.int32)]
        # walk from finest to coarsest, composing parent lookups
        for l in range(self.num_levels - 2, -1, -1):
            maps.append(self.parents[l][maps[-1]])
        maps.reverse()  # coarsest first
        return maps


def make_random_forest(
    num_types: int,
    classes_per_type: int,
    num_places: int,
    *,
    n_levels: int = 3,
    seed: int = 0,
) -> SemanticForest:
    """Generate a random semantic forest matching the paper's synthetic setup
    (30 types x 10 classes, 10,000 place names; 300 types for scalability).

    For ``n_levels != 3`` the intermediate levels are built by repeated
    uniform fan-out so Fig. 15's 2..6-level hierarchies are reproducible.
    """
    rng = np.random.default_rng(seed)
    if n_levels == 2:
        sizes = [num_types, num_places]
    elif n_levels == 3:
        sizes = [num_types, num_types * classes_per_type, num_places]
    else:
        # geometric interpolation of level sizes between types and places
        ratio = (num_places / num_types) ** (1.0 / (n_levels - 1))
        sizes = [max(1, int(round(num_types * ratio**i))) for i in range(n_levels)]
        sizes[0], sizes[-1] = num_types, num_places
        for i in range(1, n_levels):  # enforce monotone growth
            sizes[i] = max(sizes[i], sizes[i - 1])
    parents = []
    for l in range(len(sizes) - 1):
        # each level-(l+1) id gets a uniformly random level-l parent, but we
        # guarantee every parent has at least one child by round-robin seeding
        child_n, parent_n = sizes[l + 1], sizes[l]
        p = rng.integers(0, parent_n, size=child_n).astype(np.int32)
        p[:parent_n] = np.arange(parent_n, dtype=np.int32)
        rng.shuffle(p)
        parents.append(p)
    return SemanticForest(parents=tuple(parents), sizes=tuple(sizes))


def forest_tables(forest: SemanticForest) -> jnp.ndarray:
    """Stack the level maps into one int32 [n_levels, num_places] table."""
    return jnp.asarray(np.stack(forest.level_maps(), axis=0))


def encode_codes(
    places: jnp.ndarray,
    tables: jnp.ndarray,
    *,
    pad_code: int = PAD_CODE_A,
) -> jnp.ndarray:
    """Raw-array encoding: place ids [N, L] -> codes [N, n_levels, L].

    jit-friendly (a single gather per level, one fused gather in XLA) and
    jax-traceable on raw arrays, so the sharded pipeline can run it *inside*
    the shard_map program on each shard's local rows — the full code table
    then never materializes replicated on the host.
    """
    safe = jnp.where(places == PAD_PLACE, 0, places)
    # tables: [n_levels, P]; gather -> [n_levels, N, L] -> [N, n_levels, L]
    codes = tables[:, safe]
    codes = jnp.transpose(codes, (1, 0, 2)).astype(jnp.int32)
    return jnp.where((places == PAD_PLACE)[:, None, :], pad_code, codes)


def encode_types(
    places: jnp.ndarray,
    tables: jnp.ndarray,
    *,
    pad_code: int = PAD_CODE_A,
) -> jnp.ndarray:
    """Coarsest-level ("type") codes only: place ids [N, L] -> int32 [N, L].

    The driver-side view the sharded engine uses for capacity planning: join
    keys derive from level 0, so planning needs one [N, L] gather — not the
    [N, n_levels, L] code table, which stays device-resident.
    """
    safe = jnp.where(places == PAD_PLACE, 0, places)
    types = tables[0, safe].astype(jnp.int32)
    return jnp.where(places == PAD_PLACE, pad_code, types)


def encode_batch(
    batch: TrajectoryBatch,
    tables: jnp.ndarray,
    *,
    pad_code: int = PAD_CODE_A,
) -> EncodedBatch:
    """Map each place id through every forest level: [N, L] -> [N, n_levels, L].

    Padded positions become ``pad_code``.
    """
    codes = encode_codes(batch.places, tables, pad_code=pad_code)
    return EncodedBatch(codes=codes, lengths=batch.lengths)


def type_codes(encoded: EncodedBatch) -> jnp.ndarray:
    """The coarsest-level view used by SSH: int32 [N, L]."""
    return encoded.codes[:, 0, :]


def encode_places(place_ids: Sequence[int], tables: np.ndarray) -> list[str]:
    """Human-readable dotted encodings ("E_type.E_class.E_name") for demos."""
    out = []
    tables = np.asarray(tables)
    for p in place_ids:
        out.append(".".join(str(int(tables[l, p])) for l in range(tables.shape[0])))
    return out
