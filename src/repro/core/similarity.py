"""Phase (iii): multi-level semantic trajectory similarity (Definitions 2,4,5).

``|M_h|`` is the length of the longest common subsequence (LCS) of the two
trajectories' level-h encodings — repetition-aware, unlike set-based prior
work (paper section IV.3).  ``MSS = sum_h beta_h * |M_h|``.

Two implementations of the batched LCS:

* ``lcs_ref``      — textbook row DP via nested ``lax.scan`` (the oracle;
                     O(La*Lb) sequential steps, used in tests only).
* ``lcs_wavefront``— anti-diagonal wavefront: 2L-1 vectorized steps keeping
                     two rolling diagonals.  This is the TPU-native rewrite
                     of the paper's CPU DP (see DESIGN.md) and the jnp
                     fallback for the Pallas kernel in kernels/lcs.

Padding convention: pad side A with PAD_CODE_A (-1) and side B with
PAD_CODE_B (-2); padded tails never match so LCS(full padded) == LCS(true
prefixes).  Callers gathering both sides from the same EncodedBatch must
re-pad one side (see ``repad``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.encoding import PAD_CODE_A, PAD_CODE_B


def repad(codes: jnp.ndarray, lengths: jnp.ndarray, pad_code: int) -> jnp.ndarray:
    """Set padded positions (>= length) of [..., L] codes to ``pad_code``."""
    L = codes.shape[-1]
    pos = jnp.arange(L, dtype=jnp.int32)
    mask = pos[None, :] < jnp.reshape(lengths, (-1, 1))
    mask = mask.reshape(lengths.shape + (L,))
    # broadcast mask over any intermediate dims (e.g. levels)
    while mask.ndim < codes.ndim:
        mask = mask[..., None, :]
    return jnp.where(mask, codes, pad_code)


def lcs_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Oracle LCS, batched: a [B, La], b [B, Lb] -> int32 [B].

    Classic row-major DP; rows via lax.scan, columns via inner lax.scan.
    """
    B, La = a.shape
    Lb = b.shape[1]

    def row_step(prev_row, ai):  # prev_row [B, Lb+1], ai [B]
        def col_step(left, inputs):
            up, diag, bj = inputs  # each [B]
            match = (ai == bj) & (ai >= 0)
            val = jnp.where(match, diag + 1, jnp.maximum(up, left))
            return val, val

        ups = prev_row[:, 1:]      # dp[i-1, j]     j=1..Lb  -> [B, Lb]
        diags = prev_row[:, :-1]   # dp[i-1, j-1]
        _, cols = jax.lax.scan(
            col_step,
            jnp.zeros((B,), jnp.int32),
            (ups.T, diags.T, b.T),
        )
        new_row = jnp.concatenate([jnp.zeros((B, 1), jnp.int32), cols.T], axis=1)
        return new_row, None

    row0 = jnp.zeros((B, Lb + 1), jnp.int32)
    final, _ = jax.lax.scan(row_step, row0, a.T)
    return final[:, -1]


def wavefront_dtype_from_env() -> jnp.dtype:
    """Resolve the REPRO_LCS_DTYPE A/B probe at a *call boundary*.

    Must run in eager Python (a stage, an ops wrapper, a benchmark), never
    inside a jitted body: the dtype is a static jit argument downstream, so
    resolving it here keeps the env var out of every trace cache.
    """
    import os

    return jnp.int32 if os.environ.get("REPRO_LCS_DTYPE") == "int32" else jnp.int8


@functools.partial(jax.jit, static_argnames=("dtype",))
def lcs_wavefront(
    a: jnp.ndarray, b: jnp.ndarray, *, dtype: jnp.dtype = jnp.int8
) -> jnp.ndarray:
    """Anti-diagonal wavefront LCS, batched: a [B, La], b [B, Lb] -> int32 [B].

    dp[i, j] laid out along diagonals t = i + j; diagonal t stored as
    d_t[i] = dp[i, t - i] over the full i range [0, La] (out-of-range j
    entries are never read by valid cells — see DESIGN.md).  2 rolling
    diagonals, La + Lb - 1 steps of pure vector ops.

    The diagonals are carried in ``dtype`` — int8 by default (LCS values
    <= L < 127; §Perf anotherme/v2: the scan carry crosses fusion/HBM
    boundaries every step, so carry width sets the memory term).  ``dtype``
    is a static argument so the choice is part of the jit cache key;
    callers honouring the REPRO_LCS_DTYPE probe thread
    :func:`wavefront_dtype_from_env` in from eager code.
    """
    cdt = dtype
    B, La = a.shape
    Lb = b.shape[1]
    assert La < 127 and Lb < 127

    def step(carry, t):
        d_prev2, d_prev1 = carry  # [B, La+1] each: diagonals t-2, t-1
        i = jnp.arange(La + 1, dtype=jnp.int32)  # dp row index
        j = t - i
        # shifted views: x[i-1] with x[-1] := 0
        shift = lambda d: jnp.concatenate(
            [jnp.zeros((B, 1), cdt), d[:, :-1]], axis=1
        )
        up = d_prev1            # dp[i, j-1]  (diag t-1, same i)
        left = shift(d_prev1)   # dp[i-1, j]  (diag t-1, i-1)
        diag = shift(d_prev2)   # dp[i-1, j-1] (diag t-2, i-1)
        # match check: a[i-1] vs b[j-1]; clamp indices, mask validity
        ai = a[:, jnp.clip(i - 1, 0, La - 1)]
        bj = jnp.take_along_axis(
            b, jnp.broadcast_to(jnp.clip(j - 1, 0, Lb - 1)[None, :], (B, La + 1)),
            axis=1,
        )
        valid = (i >= 1) & (j >= 1) & (j <= Lb)
        match = (ai == bj) & valid[None, :]
        new = jnp.where(match, diag + jnp.ones((), cdt), jnp.maximum(up, left))
        new = jnp.where(valid[None, :], new, jnp.zeros((), cdt))
        return (d_prev1, new), None

    d0 = jnp.zeros((B, La + 1), cdt)
    (d_prev2, d_prev1), _ = jax.lax.scan(
        step, (d0, d0), jnp.arange(2, La + Lb + 1, dtype=jnp.int32)
    )
    # final diagonal t = La + Lb holds dp[La, Lb] at i = La
    return d_prev1[:, La].astype(jnp.int32)


def multi_level_lcs(
    codes_a: jnp.ndarray,
    len_a: jnp.ndarray,
    codes_b: jnp.ndarray,
    len_b: jnp.ndarray,
    *,
    impl=None,
) -> jnp.ndarray:
    """|M_h| for every level: [P, n_levels, L] x2 -> int32 [P, n_levels].

    Levels are folded into the batch dimension — the LCS recurrence is
    level-independent, so one batched kernel invocation covers all levels.
    """
    if impl is None:
        impl = lcs_wavefront
    P, H, L = codes_a.shape
    a = repad(codes_a, len_a, PAD_CODE_A).reshape(P * H, L)
    b = repad(codes_b, len_b, PAD_CODE_B).reshape(P * H, L)
    return impl(a, b).reshape(P, H)


def gather_windows(codes: jnp.ndarray, off: jnp.ndarray, window: int) -> jnp.ndarray:
    """Slice per-row windows out of gathered code rows.

    codes [P, H, L], off [P] window start offsets -> [P, H, W] with
    W = min(window, L).  Positions past ``L - 1`` clamp to the last column
    (garbage); callers mask by the window's valid length — for any valid
    position ``i < clip(len - off, 0, W)`` we have ``off + i < len <= L``,
    so the clamp never corrupts a valid entry.
    """
    L = codes.shape[-1]
    W = min(window, L)
    pos = off[:, None, None] + jnp.arange(W, dtype=jnp.int32)
    pos = jnp.clip(pos, 0, L - 1)
    return jnp.take_along_axis(
        codes, jnp.broadcast_to(pos, codes.shape[:-1] + (W,)), axis=-1
    )


@functools.partial(
    jax.jit,
    static_argnames=("nw", "window", "stride", "impl_name", "wavefront_dtype"),
)
def score_windowed_pairs(
    codes: jnp.ndarray,
    lengths: jnp.ndarray,
    left: jnp.ndarray,
    right: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    nw: int,
    window: int,
    stride: int = 1,
    impl_name: str = "wavefront",
    wavefront_dtype: jnp.dtype | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Windowed ``score_pairs``: pair ids are WINDOW ids, not row ids.

    codes [N, H, L], lengths [N], left/right [P] global window ids
    (``traj = w // nw``, ``offset = (w % nw) * stride``) -> (level_lcs
    [P, H], mss [P]) of the windowed slices.  The fused impls route to the
    offset-aware fused kernel (the slices never materialize); the jnp
    impls gather the [P, H, W] windows and reuse the batched LCS over
    length-W rows (2W-1 wavefront steps instead of 2L-1).
    """
    from repro.core.types import PAD_ID

    li = jnp.where(left == PAD_ID, 0, left)
    ri = jnp.where(right == PAD_ID, 0, right)
    ta, tb = li // nw, ri // nw
    oa = (li % nw).astype(jnp.int32) * stride
    ob = (ri % nw).astype(jnp.int32) * stride
    if impl_name.startswith("fused"):
        from repro.kernels.lcs import fused

        mode = fused.FUSED_IMPL_MODES[impl_name]
        return fused.fused_windowed_score(
            codes, lengths, codes, lengths, ta, tb, oa, ob, betas,
            window=window, mode=mode,
        )
    L = codes.shape[-1]
    W = min(window, L)
    wla = jnp.clip(lengths[ta] - oa, 0, W)
    wlb = jnp.clip(lengths[tb] - ob, 0, W)
    if impl_name == "wavefront":
        dt = jnp.int8 if wavefront_dtype is None else wavefront_dtype
        impl = functools.partial(lcs_wavefront, dtype=dt)
    else:
        impl = {"ref": lcs_ref}[impl_name]
    lv = multi_level_lcs(
        gather_windows(codes[ta], oa, window), wla,
        gather_windows(codes[tb], ob, window), wlb, impl=impl,
    )
    return lv, mss_scores(lv, betas)


def mss_scores(level_lcs: jnp.ndarray, betas: jnp.ndarray) -> jnp.ndarray:
    """MSS = sum_h beta_h * |M_h| (Definition 4). level_lcs [P, H] -> [P]."""
    return jnp.einsum("ph,h->p", level_lcs.astype(jnp.float32), betas)


def default_betas(n_levels: int) -> jnp.ndarray:
    """Paper default: equal weights 1/n (section V.1)."""
    return jnp.full((n_levels,), 1.0 / n_levels, dtype=jnp.float32)


def mss_upper_bound(len_a, len_b, betas_sum):
    """The free MSS upper bound: ``sum_h beta_h * min(len_a, len_b)``.

    Every level's LCS is at most ``min(len_a, len_b)`` (lengths are shared
    across levels), so ``MSS <= betas_sum * min(len_a, len_b)`` — computable
    from lengths alone, before any code row is touched.  Traceable on jnp
    arrays and exact on np arrays; float32 either way so the device pruning
    pass and the host capacity planner agree on the bound.
    """
    import numpy as np

    if isinstance(len_a, np.ndarray):
        return np.minimum(len_a, len_b).astype(np.float32) * np.float32(betas_sum)
    return jnp.minimum(len_a, len_b).astype(jnp.float32) * betas_sum


# Pruning keeps a pair when its upper bound clears ``tau - PRUNE_EPS``: the
# hair of slack only ever keeps extra pairs (which then get scored exactly),
# guarding against the bound and the float32 MSS rounding in opposite
# directions around an exact-threshold tie.
PRUNE_EPS = 1e-5


@functools.partial(jax.jit, static_argnames=("impl_name", "wavefront_dtype"))
def score_pairs(
    codes: jnp.ndarray,
    lengths: jnp.ndarray,
    left: jnp.ndarray,
    right: jnp.ndarray,
    betas: jnp.ndarray,
    impl_name: str = "wavefront",
    wavefront_dtype: jnp.dtype | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Gather + score candidate pairs against the encoded table.

    codes [N, H, L], lengths [N], left/right [P] -> (level_lcs [P, H], mss [P]).
    Invalid slots (PAD_ID) are clamped to row 0; callers mask by pair validity.

    ``impl_name="fused"`` (and the forced "fused-pallas"/"fused-interpret"
    variants) routes to the gather-free fused Pallas kernel
    (kernels/lcs/fused.py), which never materializes the [P, H, L] operand
    copies this gather path builds.
    """
    from repro.core.types import PAD_ID

    li = jnp.where(left == PAD_ID, 0, left)
    ri = jnp.where(right == PAD_ID, 0, right)
    if impl_name.startswith("fused"):
        from repro.kernels.lcs import fused

        mode = fused.FUSED_IMPL_MODES[impl_name]
        return fused.fused_score(
            codes, lengths, codes, lengths, li, ri, betas, mode=mode
        )
    if impl_name == "wavefront":
        dt = jnp.int8 if wavefront_dtype is None else wavefront_dtype
        impl = functools.partial(lcs_wavefront, dtype=dt)
    else:
        impl = {"ref": lcs_ref}[impl_name]
    lv = multi_level_lcs(codes[li], lengths[li], codes[ri], lengths[ri], impl=impl)
    return lv, mss_scores(lv, betas)
