"""MinHashLSH baseline (Spark's built-in hash, reproduced; paper section V.1).

Faithful to the paper's description: each trajectory is encoded at the type
level into a **binary presence vector** (order and repetition are discarded —
this is exactly the information loss that costs MinHash its accuracy in
Figs. 10/12), minhash signatures are computed with universal hashing
h_i(x) = (a_i * x + b_i) mod p, and banding groups trajectories whose band
signatures collide.  Candidate pairs are then scored with the same MSS
(Definition 4), mirroring the paper's experimental protocol.

The banded join reuses the same sort-merge join machinery as SSH
(core/ssh.py), so the accuracy comparison is apples-to-apples: both hashes
pay the same join cost, only the hash differs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ssh import ssh_candidates
from repro.core.types import CandidatePairs, PAD_KEY

_MERSENNE = (1 << 31) - 1


def _hash_params(num_perm: int, seed: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    a = rng.integers(1, _MERSENNE, size=num_perm, dtype=np.int64)
    b = rng.integers(0, _MERSENNE, size=num_perm, dtype=np.int64)
    return jnp.asarray(a), jnp.asarray(b)


@functools.partial(jax.jit, static_argnames=("num_perm", "seed"))
def minhash_signatures(
    type_codes: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    num_perm: int = 16,
    seed: int = 0,
) -> jnp.ndarray:
    """Minhash signatures of the type-level presence *sets*.

    type_codes int32 [N, L] -> int32 [N, num_perm].
    Computed in int64-free fashion: (a*x + b) mod p with p = 2^31-1 done in
    float64-free integer math via jnp.uint64 emulation is unnecessary here —
    a*x fits in 62 bits, so we use jnp.int64 only if enabled, else split-mod
    in int32.  For portability we use the split 16-bit trick.
    """
    n, L = type_codes.shape
    a, b = _hash_params(num_perm, seed)
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)
    x = type_codes.astype(jnp.int32)
    valid = jnp.arange(L, dtype=jnp.int32)[None, :] < lengths[:, None]

    # (a * x + b) mod p with p = 2^31 - 1, computed via 16-bit limb split so
    # everything stays in int32:  a*x = (a_hi*x)<<16 + a_lo*x, and
    # 2^16 mod p handled by folding ((v mod p) * 2^16) mod p.
    def mod_p(v):  # v in [0, 2^31-1 + something small) after folds
        return jnp.where(v >= _MERSENNE, v - _MERSENNE, v)

    def affine(ai_hi, ai_lo, bi, xv):
        lo = (ai_lo * xv) % _MERSENNE
        hi = (ai_hi * xv) % _MERSENNE
        # hi * 2^16 mod p, done in two 8-bit shifts to stay in range
        hi = (hi * 256) % _MERSENNE
        hi = (hi * 256) % _MERSENNE
        return mod_p(mod_p(lo + hi) + bi)

    a_hi = (a32 >> 16).astype(jnp.int32)
    a_lo = (a32 & 0xFFFF).astype(jnp.int32)
    sig = []
    for i in range(num_perm):
        h = affine(a_hi[i], a_lo[i], b32[i], x)  # [N, L]
        h = jnp.where(valid, h, jnp.iinfo(jnp.int32).max)
        sig.append(jnp.min(h, axis=1))
    return jnp.stack(sig, axis=1)


def minhash_band_keys(
    signatures: jnp.ndarray, *, bands: int, key_space: int | None = None
) -> jnp.ndarray:
    """LSH banding: hash each band of the signature into one int32 key.

    Bands are salted so keys from different bands never collide; output
    int32 [N, bands] plugs directly into ssh_candidates' sort-merge join.
    """
    n, num_perm = signatures.shape
    assert num_perm % bands == 0, "num_perm must be divisible by bands"
    if key_space is None:
        key_space = (2**31 - 2) // bands  # salted keys stay within int32
    rows = num_perm // bands
    sig = signatures.reshape(n, bands, rows)
    key = jnp.zeros((n, bands), jnp.int32)
    for r in range(rows):
        key = (key * 1_000_003 + sig[:, :, r]) % key_space
    key = jnp.abs(key) + jnp.arange(bands, dtype=jnp.int32)[None, :] * key_space
    # salt keeps band-b keys in [b*key_space, (b+1)*key_space) c [0, 2^31-2]
    assert bands * key_space < 2**31
    return key


def minhash_candidates(
    type_codes: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    num_perm: int = 16,
    bands: int = 4,
    pair_capacity: int,
    seed: int = 0,
) -> CandidatePairs:
    sig = minhash_signatures(type_codes, lengths, num_perm=num_perm, seed=seed)
    keys = minhash_band_keys(sig, bands=bands)
    return ssh_candidates(keys, pair_capacity=pair_capacity)
