"""Core data types for the AnotherMe semantic-trajectory engine.

All structures are fixed-shape, padded, and registered as pytrees so every
phase of the pipeline is jit/shard_map compatible.  Padding conventions:

* trajectories: place ids are int32 >= 0; padding slot = ``PAD_PLACE`` (-1).
* shingle keys: valid keys are int32 in [0, Q**k); padding = ``PAD_KEY``
  (INT32_MAX) so that an ascending sort pushes padding to the end and padding
  never joins with a real key.
* pair slots: invalid pair = (PAD_ID, PAD_ID) with PAD_ID = INT32_MAX.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PAD_PLACE = -1
PAD_KEY = jnp.iinfo(jnp.int32).max
PAD_ID = jnp.iinfo(jnp.int32).max


def _pytree_dataclass(cls):
    """Register a dataclass as a jax pytree (all fields are leaves)."""
    fields = [f.name for f in dataclasses.fields(cls)]

    def flatten(obj):
        return tuple(getattr(obj, name) for name in fields), None

    def unflatten(_, children):
        return cls(*children)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


@_pytree_dataclass
@dataclasses.dataclass
class TrajectoryBatch:
    """A batch of semantic trajectories (Definition 1 of the paper).

    places:  int32 [N, L_max]  place (name-level) ids, PAD_PLACE-padded.
             Repeated places encode stay duration (paper section IV.1).
    lengths: int32 [N]         true number of places per trajectory.
    user_id: int32 [N]         owning user (trajectory id == row index).
    """

    places: Any
    lengths: Any
    user_id: Any

    @property
    def num_trajectories(self) -> int:
        return self.places.shape[0]

    @property
    def max_len(self) -> int:
        return self.places.shape[1]

    def valid_mask(self) -> jnp.ndarray:
        pos = jnp.arange(self.max_len, dtype=jnp.int32)[None, :]
        return pos < self.lengths[:, None]


@_pytree_dataclass
@dataclasses.dataclass
class EncodedBatch:
    """Multi-level semantic encodings of a TrajectoryBatch.

    codes:   int32 [N, n_levels, L_max]  per-place code at each level.
             Level 0 is the COARSEST ("type"), level n-1 the finest ("name").
             Padded positions carry distinct negative sentinels per side so
             padding never matches anything (see similarity.py).
    lengths: int32 [N].
    """

    codes: Any
    lengths: Any

    @property
    def num_levels(self) -> int:
        return self.codes.shape[1]


@_pytree_dataclass
@dataclasses.dataclass
class CandidatePairs:
    """Output of the SSH join: candidate similar pairs, exactly-once.

    left/right: int32 [P_cap]  trajectory ids, PAD_ID in unused slots.
    count:      int32 []       number of valid pairs.
    overflow:   int32 []       pairs dropped because P_cap was too small
                               (the host retries with doubled capacity).
    """

    left: Any
    right: Any
    count: Any
    overflow: Any

    def valid_mask(self) -> jnp.ndarray:
        return self.left != PAD_ID


@_pytree_dataclass
@dataclasses.dataclass
class ScoredPairs:
    """Candidate pairs with multi-level similarity scores (Definition 4)."""

    left: Any
    right: Any
    level_lcs: Any  # int32 [P_cap, n_levels]  |M_h| per level
    mss: Any        # float32 [P_cap]          sum_h beta_h * |M_h|
    count: Any
    overflow: Any

    def valid_mask(self) -> jnp.ndarray:
        return self.left != PAD_ID
