"""Deprecated location — the sharded pipeline moved to ``repro.api.sharded``.

This module re-exports the old names so existing imports keep working.
``make_distributed_anotherme`` is now a thin adapter over
:func:`repro.api.sharded.make_sharded_pipeline` with the SSH-shingle key
function; prefer ``AnotherMeEngine`` with ``ExecutionPlan(n_shards=...)``,
which also supports the "minhash"/"brp"/"udf" candidate backends on the
same shard_map machinery.
"""
from repro.api.sharded import (  # noqa: F401
    DistributedPlan,
    _pair_hash,
    _positive_hash,
    _route,
    gather_similar_pairs,
    make_distributed_anotherme,
    make_sharded_pipeline,
    pad_to_shards,
    plan_capacities,
)
