"""Straggler detection & mitigation hooks (host-side).

At 1000+ nodes the common failure mode is not a crash but a slow host
(thermal throttle, ECC retries, network degradation).  The watchdog keeps a
robust running estimate of step time (median + MAD) and flags steps (or
per-host heartbeats) that exceed ``threshold`` deviations.  Mitigation is
policy-driven via callbacks:

  * "log"       — record the event (always on)
  * "checkpoint"— force an early async checkpoint so a kill/reschedule of
                  the slow host loses no work
  * "evict"     — signal the caller to rebuild the mesh without the host
                  (elastic resume path; exercised in tests by resharding a
                  checkpoint onto a smaller device count)

The per-host heartbeat API mirrors what a real multi-controller deployment
reports; the single-process environment feeds it synthetic timings in
tests.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    host: int
    duration: float
    median: float
    mad: float


class StragglerWatchdog:
    def __init__(self, window: int = 32, threshold: float = 5.0,
                 on_event: Callable[[StragglerEvent], None] | None = None):
        self.window = window
        self.threshold = threshold
        self.on_event = on_event
        self._durations: collections.deque = collections.deque(maxlen=window)
        self._host_durations: dict[int, collections.deque] = {}
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None

    # --- step timing (single-controller view) ---------------------------
    def step_start(self):
        self._t0 = time.perf_counter()

    def step_end(self, step: int) -> bool:
        assert self._t0 is not None
        dur = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(step, 0, dur)

    # --- generic observation (per-host heartbeats) -----------------------
    def observe(self, step: int, host: int, duration: float) -> bool:
        dq = self._host_durations.setdefault(host, collections.deque(maxlen=self.window))
        flagged = False
        if len(dq) >= 8:
            med = _median(dq)
            mad = _median([abs(d - med) for d in dq]) or med * 0.05 or 1e-3
            if duration > med + self.threshold * mad:
                ev = StragglerEvent(step, host, duration, med, mad)
                self.events.append(ev)
                if self.on_event:
                    self.on_event(ev)
                flagged = True
        dq.append(duration)
        self._durations.append(duration)
        return flagged

    def slowest_hosts(self, k: int = 3) -> list[tuple[int, float]]:
        meds = {
            h: _median(dq) for h, dq in self._host_durations.items() if dq
        }
        return sorted(meds.items(), key=lambda kv: -kv[1])[:k]


def _median(xs) -> float:
    xs = sorted(xs)
    n = len(xs)
    if n == 0:
        return 0.0
    return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])
