"""AdamW in raw JAX with optional 8-bit (block-quantized) moments.

Optimizer state inherits the parameter sharding (ZeRO: m/v live sharded over
(data, model) exactly like their parameter), so optimizer memory scales down
with the mesh.  The 8-bit mode stores m and v as int8 codes with per-block
(block=256 along the last axis) absmax scales — the dynamic-range trick of
8-bit Adam [Dettmers 2021], which is what brings the 1T-param MoE's
optimizer bytes within reach (EXPERIMENTS.md section Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_bits: int = 32          # 32 (f32 moments) or 8 (block-int8)
    warmup_steps: int = 100


# ---------------------------------------------------------------------------
# block int8 quantization
# ---------------------------------------------------------------------------
def _blocked_shape(shape):
    last = shape[-1] if shape else 1
    return shape[:-1] + (-(-last // BLOCK),)


def quantize_block_int8(x: jnp.ndarray) -> dict:
    shape = x.shape
    last = shape[-1]
    pad = (-last) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xp.reshape(shape[:-1] + (-1, BLOCK))
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(xp.shape), "scale": scale[..., 0].astype(jnp.float32)}


def dequantize_block_int8(state: dict, shape) -> jnp.ndarray:
    q = state["q"].astype(jnp.float32)
    qb = q.reshape(shape[:-1] + (-1, BLOCK))
    x = qb * state["scale"][..., None]
    return x.reshape(q.shape)[..., : shape[-1]]


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def init_opt_state(params, cfg: OptConfig):
    def init_leaf(p):
        if cfg.state_bits == 8:
            z = jnp.zeros(p.shape, jnp.float32)
            return {"m": quantize_block_int8(z), "v": quantize_block_int8(z)}
        return {"m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "step": jnp.zeros((), jnp.int32),
        "moments": jax.tree.map(init_leaf, params),
    }


def _lr_schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / cfg.warmup_steps, 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(params, grads, opt_state, cfg: OptConfig):
    """-> (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mom):
        g = g.astype(jnp.float32) * clip
        if cfg.state_bits == 8:
            m = dequantize_block_int8(mom["m"], p.shape)
            v = dequantize_block_int8(mom["v"], p.shape)
        else:
            m, v = mom["m"], mom["v"]
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (update + cfg.weight_decay * p.astype(jnp.float32))
        if cfg.state_bits == 8:
            new_mom = {"m": quantize_block_int8(m), "v": quantize_block_int8(v)}
        else:
            new_mom = {"m": m, "v": v}
        return new_p.astype(p.dtype), new_mom

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = treedef.flatten_up_to(opt_state["moments"])
    out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_moments = jax.tree.unflatten(treedef, [o[1] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"step": step, "moments": new_moments}, metrics
