"""GPipe-style pipeline parallelism over a 'stage' mesh axis (optional
module — the assigned production mesh has no stage axis, so PP is exercised
in tests only; the data axis can be re-folded into (stage, data) when a
deployment wants depth partitioning; see DESIGN.md §5).

Schedule: classic GPipe fill-drain.  With S stages and M microbatches the
loop runs T = M + S - 1 ticks; at every tick each stage applies its layer
slice to the activation it holds and forwards the result to stage s+1 via
``lax.ppermute`` — the canonical point-to-point pipeline collective.
Bubble fraction = (S-1)/T, the standard GPipe overhead.

The whole schedule is a static python loop inside one shard_map, so XLA
sees a fixed sequence of compute + collective-permute ops it can overlap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn, stage_params, x_micro, mesh: Mesh,
                   axis: str = "stage"):
    """Run microbatches through a linear pipeline of stages.

    stage_fn: (params_slice, x [mb, ...]) -> y [mb, ...] (same shape).
    stage_params: pytree with leading [n_stages] dim (stage s owns slice s).
    x_micro: [n_micro, mb, ...] microbatched input.
    Returns [n_micro, mb, ...] outputs (replicated over the stage axis).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def shard_fn(params_local, x_all):
        s = jax.lax.axis_index(axis)
        params_me = jax.tree.map(lambda a: a[0], params_local)
        buf = jnp.zeros_like(x_all[0])  # activation held by this stage
        out = jnp.zeros_like(x_all)

        for t in range(ticks):
            mb = t - s  # microbatch index this stage works on at tick t
            # stage 0 injects a fresh microbatch; others use the buffer
            inj = x_all[jnp.clip(t, 0, n_micro - 1)]
            inp = jnp.where(s == 0, inj, buf)
            y = stage_fn(params_me, inp)
            valid = (mb >= 0) & (mb < n_micro)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            # last stage writes its finished microbatch into the output
            is_last = s == n_stages - 1
            write_idx = jnp.clip(mb, 0, n_micro - 1)
            out = jax.lax.dynamic_update_index_in_dim(
                out,
                jnp.where(valid & is_last, y, out[write_idx]),
                write_idx, 0,
            )
            # hand activations downstream
            buf = jax.lax.ppermute(y, axis, perm)
        # outputs live on the last stage only; replicate for the caller
        return jax.lax.psum(
            jnp.where(s == n_stages - 1, out, jnp.zeros_like(out)), axis
        )

    from repro.core import compat

    fn = compat.shard_map(
        shard_fn, mesh=mesh, in_specs=(P(axis), P()), out_specs=P()
    )
    return fn(stage_params, x_micro)
