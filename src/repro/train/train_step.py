"""The jitted train step: loss -> grads -> (optional EF compression) ->
AdamW, with microbatched gradient accumulation.

Gradient accumulation splits the global batch into ``grad_accum``
microbatches consumed by a lax.scan, so activation memory scales with the
microbatch while arithmetic (and the roofline's compute term) is unchanged —
this is the first knob the §Perf hillclimb reaches for when the memory term
dominates.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import loss_fn
from repro.train import compression as comp
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    grad_accum: int = 1
    compress_grads: bool = False   # int8 error-feedback DP sync numerics


def make_train_state(params, tcfg: TrainConfig):
    state = {"opt": init_opt_state(params, tcfg.opt)}
    if tcfg.compress_grads:
        state["ef_residual"] = comp.init_residuals(params)
    return state


def _split_microbatches(inputs: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by grad_accum {n}"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(split, inputs)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh, *, unroll: bool = False):
    """Returns train_step(params, state, inputs) -> (params, state, metrics)."""

    def grads_of(params, inputs):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, inputs, cfg, mesh, unroll=unroll), has_aux=True
        )(params)
        return loss, metrics, grads

    def train_step(params, state, inputs):
        if tcfg.grad_accum == 1:
            loss, metrics, grads = grads_of(params, inputs)
        else:
            micro = _split_microbatches(inputs, tcfg.grad_accum)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                loss, _, grads = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (g_acc, l_acc + loss), None

            (g_sum, l_sum), _ = jax.lax.scan(
                acc_step, (zero, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, g_sum)
            loss = l_sum / tcfg.grad_accum
            metrics = {}

        if tcfg.compress_grads:
            grads, new_resid = comp.ef_compress(grads, state["ef_residual"])

        params, opt_state, opt_metrics = adamw_update(
            params, grads, state["opt"], tcfg.opt
        )
        new_state = {"opt": opt_state}
        if tcfg.compress_grads:
            new_state["ef_residual"] = new_resid
        out_metrics = {"loss": loss, **opt_metrics}
        return params, new_state, out_metrics

    return train_step
