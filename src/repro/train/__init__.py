from repro.train.optimizer import OptConfig, init_opt_state, adamw_update
from repro.train.train_step import TrainConfig, make_train_step
