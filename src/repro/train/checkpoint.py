"""Fault-tolerant checkpointing: atomic manifests, async writes, elastic
resume.

Layout:  <dir>/step_<N>/
            arrays/<flat.key.path>.npy     one file per leaf
            MANIFEST.json                  written LAST -> atomicity marker

* A checkpoint is valid iff MANIFEST.json exists and lists every leaf file
  with matching shape/dtype; a crash mid-write leaves no manifest and the
  directory is garbage-collected on the next save.
* Arrays are stored UNSHARDED (gathered), so a checkpoint written on a
  (16,16) mesh restores onto (2,16,16), (4,), or a single device — this is
  the elastic-scaling path: resume re-shards every leaf to the new mesh's
  NamedShardings via device_put.  (At true multi-host scale the same
  manifest format holds per-shard files per host; the single-controller
  dry-run environment is fully addressable so we write whole arrays.)
* ``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
  writes files on a background thread, overlapping I/O with the next step —
  ``wait()`` joins before the next save or at exit.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}

    def walk(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(node[k], path + (str(k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (str(i),))
        else:
            flat[".".join(path)] = node

    walk(tree, ())
    return flat


def _unflatten_like(template, flat: dict[str, Any]):
    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(node[k], path + (str(k),)) for k in node}
        if isinstance(node, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(t)
        return flat[".".join(path)]

    return walk(template, ())


def save_checkpoint(ckpt_dir, step: int, tree, *, keep: int = 3) -> pathlib.Path:
    """Synchronous atomic save. Returns the checkpoint path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:010d}"
    tmp = ckpt_dir / f".tmp_step_{step:010d}_{int(time.time()*1e6)}"
    arrays = tmp / "arrays"
    arrays.mkdir(parents=True, exist_ok=True)
    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": {}}
    for key, val in flat.items():
        arr = np.asarray(jax.device_get(val))
        dtype_name = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype_name == "bfloat16":
            # numpy cannot round-trip ml_dtypes (bf16/f8): store as f32,
            # which represents every bf16 exactly; restore re-casts to the
            # template dtype
            arr = arr.astype(np.float32)
        np.save(arrays / f"{key}.npy", arr)
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": dtype_name
        }
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
    if step_dir.exists():
        shutil.rmtree(step_dir)
    tmp.rename(step_dir)
    _gc(ckpt_dir, keep)
    return step_dir


def _gc(ckpt_dir: pathlib.Path, keep: int):
    # drop orphaned temp dirs (crashed writes) and old steps
    for p in ckpt_dir.glob(".tmp_step_*"):
        shutil.rmtree(p, ignore_errors=True)
    steps = sorted(ckpt_dir.glob("step_*"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    best = None
    for p in sorted(ckpt_dir.glob("step_*")):
        if (p / "MANIFEST.json").exists():
            best = int(p.name.split("_")[1])
    return best


def restore_checkpoint(ckpt_dir, step: int, template, shardings=None):
    """Restore into the structure of ``template``; reshard onto
    ``shardings`` (same tree of NamedSharding) if given — the elastic path."""
    step_dir = pathlib.Path(ckpt_dir) / f"step_{step:010d}"
    manifest = json.loads((step_dir / "MANIFEST.json").read_text())
    flat_t = _flatten_with_paths(template)
    missing = set(flat_t) - set(manifest["leaves"])
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")
    flat_sh = _flatten_with_paths(shardings) if shardings is not None else {}
    out = {}
    for key in flat_t:
        arr = np.load(step_dir / "arrays" / f"{key}.npy")
        want = flat_t[key]
        if hasattr(want, "shape") and tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {want.shape}"
            )
        if key in flat_sh and flat_sh[key] is not None:
            out[key] = jax.device_put(arr, flat_sh[key])
        else:
            dtype = want.dtype if hasattr(want, "dtype") else arr.dtype
            out[key] = jax.numpy.asarray(arr, dtype=dtype)
    return _unflatten_like(template, out)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write files on a background thread."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.ckpt_dir, step, host_tree),
            kwargs={"keep": self.keep},
            daemon=True,
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
