"""Int8 error-feedback gradient compression for the DP all-reduce.

At multi-pod scale the pod-level gradient sync crosses DCN, the slowest
link; 4x compression there is the standard distributed-optimization trick.
Two pieces:

* ``compressed_psum`` — the actual collective: quantize (block-int8, absmax
  scales) -> psum the int32-accumulated codes + scales over the named axis
  -> dequantize.  Exposed for shard_map use and unit-tested on a virtual
  8-device axis.
* ``ef_compress`` — error-feedback wrapper used inside train_step: the
  quantization residual is carried in the optimizer state and re-added next
  step, so the compression bias vanishes asymptotically (Karimireddy et al.
  2019).  Numerically this is exactly what the compressed pod-sync does to
  the gradients; the wire-format saving itself is a deployment property.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train.optimizer import BLOCK, dequantize_block_int8, quantize_block_int8


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Quantize-then-psum over a named axis (for use inside shard_map).

    A SHARED per-block scale (pmax of local absmaxes — a tiny metadata
    collective, <1% of payload) makes the int8 codes directly summable:
    psum the int32-accumulated codes, then dequantize once.  Error is pure
    quantization noise (<= absmax/127 per element), no scale-mismatch bias."""
    shape = x.shape
    pad = (-shape[-1]) % BLOCK
    xp = jnp.pad(x.astype(jnp.float32), [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xp.reshape(shape[:-1] + (-1, BLOCK))
    local_max = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jax.lax.pmax(local_max, axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int32)
    codes = jax.lax.psum(q, axis_name)
    out = (codes.astype(jnp.float32) * scale).reshape(xp.shape)[..., : shape[-1]]
    return out.astype(x.dtype)


def ef_compress(grads, residuals):
    """Error-feedback int8 round-trip: returns (decompressed grads, new
    residuals).  residuals pytree matches grads (f32)."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q = quantize_block_int8(g32)
        deq = dequantize_block_int8(q, g32.shape)
        return deq.astype(g.dtype), g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in out]),
        jax.tree.unflatten(treedef, [o[1] for o in out]),
    )


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
