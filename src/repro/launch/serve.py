"""Serving driver CLI: batched prefill + greedy decode on a (reduced or
full) arch config.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --reduced
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models.model import init_params
from repro.serve.kvcache import cache_bytes
from repro.serve.serve_step import make_decode_step, prefill_with_cache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.frontend != "none":
        import dataclasses

        cfg = dataclasses.replace(cfg, frontend="none")
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    mesh = make_smoke_mesh()
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    print(f"{cfg.name}: cache {cache_bytes(cfg, args.batch, args.max_len)/1e6:.2f} MB")
    logits, cache = prefill_with_cache(params, prompts, cfg, mesh, args.max_len)
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    step = jax.jit(make_decode_step(cfg, mesh))
    out = [tok]
    for _ in range(args.gen_len - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, :, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(tok)
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    for b in range(args.batch):
        print(f"  seq {b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
