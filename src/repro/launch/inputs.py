"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero device allocation — the dry-run lowers
train_step/serve_step against these.  ``make_inputs`` materializes real
random arrays of the same shapes for smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.layers import COMPUTE_DTYPE, dp_axes, resolve_spec


def train_input_shapes(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio":
        return {
            "features": ((B, S, cfg.d_model), COMPUTE_DTYPE),
            "labels": ((B, S), jnp.int32),
        }
    if cfg.frontend == "vision":
        st = S - cfg.vis_tokens
        return {
            "tokens": ((B, st), jnp.int32),
            "vis_embed": ((B, cfg.vis_tokens, cfg.d_model), COMPUTE_DTYPE),
            "labels": ((B, st), jnp.int32),
        }
    return {
        "tokens": ((B, S), jnp.int32),
        "labels": ((B, S), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh | None = None) -> dict:
    """ShapeDtypeStructs (with shardings when a mesh is given)."""
    shapes = train_input_shapes(cfg, shape)
    out = {}
    for name, (shp, dt) in shapes.items():
        if mesh is not None:
            axes = (dp_axes(mesh),) + (None,) * (len(shp) - 1)
            sh = NamedSharding(mesh, resolve_spec(mesh, shp, axes))
            out[name] = jax.ShapeDtypeStruct(shp, dt, sharding=sh)
        else:
            out[name] = jax.ShapeDtypeStruct(shp, dt)
    return out


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    shapes = train_input_shapes(cfg, shape)
    out = {}
    for name, (shp, _) in shapes.items():
        axes = (dp_axes(mesh),) + (None,) * (len(shp) - 1)
        out[name] = NamedSharding(mesh, resolve_spec(mesh, shp, axes))
    return out


def make_inputs(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Real random arrays (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, (shp, dt) in train_input_shapes(cfg, shape).items():
        if name in ("tokens", "labels"):
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=shp), jnp.int32
            )
        else:
            out[name] = jnp.asarray(
                rng.normal(scale=0.5, size=shp).astype(np.float32), dt
            )
    return out
