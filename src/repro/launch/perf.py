import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: measure hypothesis -> change -> before/after on
the three designated cells (EXPERIMENTS.md §Perf).

Each variant re-lowers the cell with a config/env delta and re-derives the
three roofline terms via the same unrolled-probe methodology as the
baseline dry-run, so before/after numbers are directly comparable.

  PYTHONPATH=src python -m repro.launch.perf            # all variants
  PYTHONPATH=src python -m repro.launch.perf --only kimi
"""
import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp

VARIANTS = [
    # ---- target 1: kimi-k2 train_4k (most collective-bound cell) ---------
    dict(name="kimi/v0-baseline", arch="kimi-k2-1t-a32b", shape="train_4k",
         env={"REPRO_RMSNORM": "ref"},
         cfg={"fused_gate_up": False}, grad_accum=8,
         hypothesis="baseline: autodiff rmsnorm leaks f32 cotangents -> "
                     "f32 TP all-reduces; split gate/up -> 2 dx psums"),
    dict(name="kimi/v1-fused-rmsnorm", arch="kimi-k2-1t-a32b", shape="train_4k",
         env={"REPRO_RMSNORM": "fused"},
         cfg={"fused_gate_up": False}, grad_accum=8,
         hypothesis="custom-VJP rmsnorm keeps f32 local -> residual psums "
                     "drop to bf16: ~2x less all-reduce + less HBM traffic"),
    dict(name="kimi/v2-fused-gateup", arch="kimi-k2-1t-a32b", shape="train_4k",
         env={"REPRO_RMSNORM": "fused"},
         cfg={"fused_gate_up": True}, grad_accum=8,
         hypothesis="fused [d,2,f] gate-up: one column matmul -> one dx "
                     "psum instead of two on the shared-expert path"),
    dict(name="kimi/v3-accum2", arch="kimi-k2-1t-a32b", shape="train_4k",
         env={"REPRO_RMSNORM": "fused"},
         cfg={"fused_gate_up": True}, grad_accum=2,
         hypothesis="4x fewer microbatches -> 4x fewer FSDP expert-weight "
                     "gathers + re-reads; activation memory grows 4x"),
    dict(name="kimi/v4-remat-dots", arch="kimi-k2-1t-a32b", shape="train_4k",
         env={"REPRO_RMSNORM": "fused", "REPRO_REMAT": "dots"},
         cfg={"fused_gate_up": True}, grad_accum=2,
         hypothesis="save matmul outputs instead of recomputing the whole "
                     "layer: HBM bytes + FLOPs of the remat-forward drop; "
                     "per-device live memory grows"),
    # ---- target 2: zamba2 train_4k (worst MFU-bound train cell) ----------
    dict(name="zamba2/v0-baseline", arch="zamba2-2.7b", shape="train_4k",
         env={"REPRO_RMSNORM": "ref"},
         cfg={"fused_gate_up": False, "ssm_chunk": 128,
              "ssm_bf16_intra": False}, grad_accum=8,
         hypothesis="baseline: SSD intra-chunk f32 [H,Q,Q] decay/score "
                     "matrices dominate HBM bytes (prop. to S*Q)"),
    dict(name="zamba2/v1-fused-rmsnorm", arch="zamba2-2.7b", shape="train_4k",
         env={"REPRO_RMSNORM": "fused"},
         cfg={"fused_gate_up": False, "ssm_chunk": 128,
              "ssm_bf16_intra": False}, grad_accum=8,
         hypothesis="bf16 residual cotangents (as kimi/v1)"),
    dict(name="zamba2/v2-chunk64", arch="zamba2-2.7b", shape="train_4k",
         env={"REPRO_RMSNORM": "fused"},
         cfg={"fused_gate_up": True, "ssm_chunk": 64,
              "ssm_bf16_intra": False}, grad_accum=8,
         hypothesis="Q 128->64 halves intra-chunk quadratic bytes "
                     "(S*Q scaling); inter-chunk scan depth doubles "
                     "(cheap: states are [H,P,N])"),
    dict(name="zamba2/v3-bf16-intra", arch="zamba2-2.7b", shape="train_4k",
         env={"REPRO_RMSNORM": "fused"},
         cfg={"fused_gate_up": True, "ssm_chunk": 64,
              "ssm_bf16_intra": True}, grad_accum=8,
         hypothesis="bf16 decay/score matrices halve the remaining "
                     "intra-chunk bytes; log-cumsum stays f32 so decay "
                     "precision is preserved"),
    dict(name="zamba2/v4-no-head-repeat", arch="zamba2-2.7b", shape="train_4k",
         env={"REPRO_RMSNORM": "fused"},
         cfg={"fused_gate_up": True, "ssm_chunk": 64,
              "ssm_bf16_intra": True}, grad_accum=8,
         hypothesis="v2/v3 were near-refuted: the f32 jnp.repeat of B/C to "
                     "80 heads dominated HBM bytes, not the Q^2 matrices. "
                     "Compute group scores once and let H enter only via "
                     "the decay -> the [.,H,N] repeats vanish"),
    # ---- target 3: the paper's own workload --------------------------------
    dict(name="anotherme/v0-baseline", arch="anotherme", shape="N=1M",
         env={}, cfg={"dedup": True, "lcs": "wavefront"}, grad_accum=1,
         hypothesis="baseline: per-row shingle dedup costs two [N_loc,560] "
                     "sorts per shard before the join"),
    dict(name="anotherme/v1-nodedup", arch="anotherme", shape="N=1M",
         env={}, cfg={"dedup": False, "lcs": "wavefront"}, grad_accum=1,
         hypothesis="skip per-row dedup: the pair-level dedup already "
                     "guarantees exactly-once scoring; join runs grow "
                     "slightly (repeated shingles are rare at L=16,Q=300) "
                     "but two full sorts disappear"),
    dict(name="anotherme/v2-int8-lcs", arch="anotherme", shape="N=1M",
         env={"REPRO_LCS_DTYPE": "int8"}, cfg={"dedup": True,
         "lcs": "wavefront"}, grad_accum=1,
         hypothesis="the LCS wavefront's scan carry ([P*levels, L+1] "
                     "diagonals x 63 steps) crosses the scan boundary each "
                     "step; int8 diagonals (LCS <= L < 127) cut that term "
                     "4x vs int32"),
    dict(name="zamba2/v5-remat-dots", arch="zamba2-2.7b", shape="train_4k",
         env={"REPRO_RMSNORM": "fused", "REPRO_REMAT": "dots"},
         cfg={"fused_gate_up": True, "ssm_chunk": 64,
              "ssm_bf16_intra": True}, grad_accum=8,
         hypothesis="zamba2 has 11GB/dev headroom: save matmul outputs "
                     "instead of full-layer recompute — the "
                     "rematted_computation re-reads (~10% of bytes) and "
                     "their FLOPs disappear"),
]


def probe_lm(arch, shape_name, cfg_over, grad_accum):
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import _lower_step, _probe_costs
    from repro.launch.mesh import make_production_mesh
    from repro.launch import hlo_analysis as H

    cfg = dataclasses.replace(get_config(arch), **cfg_over)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    probe = _probe_costs(cfg, shape, mesh, grad_accum)
    # production compile for the memory estimate
    lowered = _lower_step(cfg, shape, mesh, unroll=False,
                          grad_accum=grad_accum, with_opt=True)
    compiled = lowered.compile()
    mem = H.memory_summary(compiled)
    return {
        "compute_s": probe["flops"] / H.PEAK_FLOPS,
        "memory_s": probe["bytes"] / H.HBM_BW,
        "collective_s": probe["coll"] / H.ICI_BW,
        "coll_by_kind": {k: v * grad_accum for k, v in probe["coll_by_kind"].items()},
        "mem_per_dev": mem["peak_bytes_est"],
    }


def probe_anotherme(cfg_over):
    from repro.core.distributed import DistributedPlan, make_distributed_anotherme
    from repro.core.similarity import default_betas
    from repro.launch.mesh import make_executor_mesh
    from repro.launch import hlo_analysis as H
    from jax.sharding import NamedSharding, PartitionSpec as P

    n_traj, L = 1_048_576, 16
    mesh = make_executor_mesh(256)
    n_shards = mesh.size
    local_n = n_traj // n_shards
    plan = DistributedPlan(
        n_shards=n_shards, local_n=local_n,
        shingle_route_cap=int(local_n * 560 / n_shards * 1.3) + 64,
        local_pair_cap=1 << 18, pair_route_cap=1 << 12, scored_cap=1 << 18,
    )
    # real forest tables (paper scale: 300 types, 10k places): the adapter
    # closes over them and encoding runs in-mesh — no code-table input
    from repro.core.encoding import forest_tables, make_random_forest

    tables = forest_tables(make_random_forest(300, 10, 10_000))
    run = make_distributed_anotherme(
        mesh, plan, tables=tables, k=3, num_types=300, betas=default_betas(3),
        dedup=cfg_over.get("dedup", True),
    )
    places = jax.ShapeDtypeStruct((n_traj, L), jnp.int32,
                                  sharding=NamedSharding(mesh, P("ex", None)))
    lengths = jax.ShapeDtypeStruct((n_traj,), jnp.int32,
                                   sharding=NamedSharding(mesh, P("ex")))
    compiled = jax.jit(run).lower(places, lengths).compile()
    ca = compiled.cost_analysis()
    coll = H.collective_bytes(compiled.as_text())
    mem = H.memory_summary(compiled)
    return {
        "compute_s": float(ca.get("flops", 0)) / H.PEAK_FLOPS,
        "memory_s": float(ca.get("bytes accessed", 0)) / H.HBM_BW,
        "collective_s": coll["total_bytes"] / H.ICI_BW,
        "coll_by_kind": coll["bytes"],
        "mem_per_dev": mem["peak_bytes_est"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="experiments/perf.json")
    args = ap.parse_args()

    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    records = json.loads(out_path.read_text()) if out_path.exists() else []
    done = {r["name"] for r in records if r.get("status") == "ok"}

    for v in VARIANTS:
        if args.only and args.only not in v["name"]:
            continue
        if v["name"] in done:
            print(f"CACHED {v['name']}")
            continue
        print(f"=== {v['name']} ===", flush=True)
        for k, val in v["env"].items():
            os.environ[k] = val
        t0 = time.time()
        try:
            if v["arch"] == "anotherme":
                res = probe_anotherme(v["cfg"])
            else:
                res = probe_lm(v["arch"], v["shape"], v["cfg"], v["grad_accum"])
            rec = {"name": v["name"], "hypothesis": v["hypothesis"],
                   "status": "ok", "elapsed_s": time.time() - t0, **res}
        except Exception as e:
            import traceback
            traceback.print_exc()
            rec = {"name": v["name"], "status": f"error: {str(e)[:300]}"}
        for k in v["env"]:
            os.environ.pop(k, None)
        records.append(rec)
        out_path.write_text(json.dumps(records, indent=1))
        print(json.dumps({k: rec.get(k) for k in
                          ("compute_s", "memory_s", "collective_s",
                           "mem_per_dev")}, indent=1), flush=True)


if __name__ == "__main__":
    main()
