import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell (skip rules: DESIGN.md section Arch-applicability)
this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the right step function against ShapeDtypeStruct inputs —
     train_step (fwd+bwd+AdamW) for train_4k, last-token forward for
     prefill_32k, decode_step (one token + cache) for decode_32k/long_500k,
  3. compiles, prints memory_analysis / cost_analysis, parses collective
     bytes from the HLO, derives the three roofline terms,
  4. appends the record to experiments/dryrun.json.

Also lowers the PAPER's own workload ("anotherme": the distributed SSH +
similarity pipeline on the flat 512-executor mesh) so the technique itself
gets a roofline row.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                # all cells
  ... --arch qwen1.5-110b --shape train_4k --mesh multi       # one cell
  ... --list                                                  # enumerate
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_archs, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import hlo_analysis as H
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_executor_mesh, make_production_mesh
from repro.models.model import (
    active_param_count, param_shape_structs, param_shardings,
)

RESULTS = pathlib.Path("experiments")


def _opt_bits(cfg: ModelConfig) -> int:
    from repro.models.model import param_count
    return 8 if param_count(cfg) > 50e9 else 32


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D train, 2*N_active*D inference."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def _layer_period(cfg: ModelConfig) -> int:
    """The homogeneous repeat unit (hybrid: a group of `every` layers)."""
    return cfg.shared_attn_every if cfg.family == "hybrid" else 1


def pick_grad_accum(cfg: ModelConfig, shape: ShapeConfig, chips_dp: int) -> int:
    """Grad accumulation so each microbatch holds <=8k tokens per dp shard
    (bounds the scan-carry activation memory; see EXPERIMENTS.md)."""
    per_shard_tokens = shape.global_batch * shape.seq_len // chips_dp
    accum = max(1, per_shard_tokens // 8192)
    while shape.global_batch % (accum * chips_dp) and accum > 1:
        accum //= 2
    return accum


def _params_for(cfg, mesh):
    p_sds = param_shape_structs(cfg)
    p_sh = param_shardings(cfg, mesh)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        p_sds, p_sh,
    )


def _lower_step(cfg, shape, mesh, *, unroll: bool, grad_accum: int,
                with_opt: bool):
    """Lower the cell's step fn for config `cfg` (possibly depth-reduced)."""
    import dataclasses as dc
    p_in = _params_for(cfg, mesh)

    if shape.kind == "train":
        from repro.train.optimizer import OptConfig, init_opt_state
        from repro.train.train_step import TrainConfig, make_train_step
        from repro.models.model import loss_fn

        if with_opt:
            tcfg = TrainConfig(
                opt=OptConfig(state_bits=_opt_bits(cfg)), grad_accum=grad_accum
            )
            step = make_train_step(cfg, tcfg, mesh, unroll=unroll)
            state_sds = jax.eval_shape(
                lambda p: {"opt": init_opt_state(p, tcfg.opt)},
                jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), p_in),
            )
            state_sh = _state_shardings(state_sds, param_shardings(cfg, mesh), mesh)
            state_in = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
                state_sds, state_sh,
            )
            ins = input_specs(cfg, shape, mesh)
            return jax.jit(step, donate_argnums=(0, 1)).lower(p_in, state_in, ins)
        # grad-only probe at microbatch size
        micro = dc.replace(shape, global_batch=shape.global_batch // grad_accum)
        ins = input_specs(cfg, micro, mesh)
        fn = jax.jit(
            jax.grad(
                lambda p, i: loss_fn(p, i, cfg, mesh, unroll=unroll)[0]
            )
        )
        return fn.lower(p_in, ins)
    if shape.kind == "prefill":
        from repro.models.model import forward

        ins = input_specs(cfg, shape, mesh)
        return jax.jit(
            lambda p, i: forward(p, i, cfg, mesh, last_only=True,
                                 unroll=unroll)[0]
        ).lower(p_in, ins)
    # decode
    from repro.serve.kvcache import cache_shape_structs
    from repro.serve.serve_step import make_decode_step
    from repro.models.layers import dp_axes, resolve_spec
    from jax.sharding import NamedSharding

    step = make_decode_step(cfg, mesh, unroll=unroll)
    cache_in = cache_shape_structs(cfg, shape.global_batch, shape.seq_len, mesh)
    tok_shape = (shape.global_batch, 1)
    tok_sh = NamedSharding(
        mesh, resolve_spec(mesh, tok_shape, (dp_axes(mesh), None))
    )
    tok_in = jax.ShapeDtypeStruct(tok_shape, jnp.int32, sharding=tok_sh)
    return jax.jit(step, donate_argnums=(1,)).lower(p_in, cache_in, tok_in)


def _probe_costs(cfg, shape, mesh, grad_accum):
    """Exact per-cell cost reconstruction from shallow UNROLLED lowers.

    XLA's cost_analysis counts while-loop bodies once, so the production
    scan under-reports by ~L.  We lower depth k1 and k2 (in layer periods)
    unrolled; costs are affine in depth: cost(k) = base + k*layer.
    total(L) = base + L*layer, and for train cells the fwd+bwd part is
    multiplied by grad_accum while the optimizer part (probed separately via
    with_opt on depth k1) is counted once.
    """
    import dataclasses as dc

    period = _layer_period(cfg)
    k1, k2 = period, 2 * period
    costs = {}
    for tag, k in (("k1", k1), ("k2", k2)):
        cfg_k = dc.replace(cfg, num_layers=k)
        lowered = _lower_step(cfg_k, shape, mesh, unroll=True,
                              grad_accum=grad_accum, with_opt=False)
        compiled = lowered.compile()
        ca = compiled.cost_analysis()
        coll = H.collective_bytes(compiled.as_text())
        costs[tag] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"]),
            "coll_by_kind": coll["bytes"],
        }

    n_periods = cfg.num_layers // period
    out = {}
    for key in ("flops", "bytes", "coll"):
        layer = costs["k2"][key] - costs["k1"][key]
        base = costs["k1"][key] - layer
        out[key] = base + n_periods * layer
    out["coll_by_kind"] = {
        kind: (costs["k2"]["coll_by_kind"][kind] - costs["k1"]["coll_by_kind"][kind])
        * n_periods
        + 2 * costs["k1"]["coll_by_kind"][kind]
        - costs["k2"]["coll_by_kind"][kind]
        for kind in costs["k1"]["coll_by_kind"]
    }

    if shape.kind == "train":
        # optimizer-only probe: full-depth AdamW update (no loops inside)
        from repro.train.optimizer import OptConfig, adamw_update, init_opt_state

        ocfg = OptConfig(state_bits=_opt_bits(cfg))
        p_in = _params_for(cfg, mesh)
        p_plain = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), p_in
        )
        state_sds = jax.eval_shape(lambda p: init_opt_state(p, ocfg), p_plain)
        state_sh = _state_shardings(
            {"opt": state_sds}, param_shardings(cfg, mesh), mesh
        )["opt"]
        state_in = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            state_sds, state_sh,
        )
        g_in = p_in  # grads shaped/sharded like params
        opt_l = jax.jit(
            lambda p, g, s: adamw_update(p, g, s, ocfg), donate_argnums=(0, 2)
        ).lower(p_in, g_in, state_in)
        opt_c = opt_l.compile()
        oca = opt_c.cost_analysis()
        ocoll = H.collective_bytes(opt_c.as_text())
        for key, val in (
            ("flops", float(oca.get("flops", 0.0))),
            ("bytes", float(oca.get("bytes accessed", 0.0))),
            ("coll", float(ocoll["total_bytes"])),
        ):
            out[key] = out[key] * grad_accum + val
        out["grad_accum"] = grad_accum
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    from repro.models.layers import dp_axes, axis_size
    dp_n = axis_size(mesh, dp_axes(mesh))
    grad_accum = pick_grad_accum(cfg, shape, dp_n) if shape.kind == "train" else 1

    # 1. PRODUCTION compile (scan form) — proves shardability, gives memory
    t0 = time.time()
    lowered = _lower_step(cfg, shape, mesh, unroll=False,
                          grad_accum=grad_accum, with_opt=True)
    compiled = lowered.compile()
    compile_s = time.time() - t0
    print(compiled.memory_analysis())
    print({k: compiled.cost_analysis().get(k) for k in ("flops", "bytes accessed")})

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "compile_s": compile_s,
        "grad_accum": grad_accum,
        "memory": H.memory_summary(compiled),
        "status": "ok",
    }

    # 2. cost probes (unrolled shallow) — exact roofline totals.
    # The roofline table is single-pod only (assignment spec); the multi-pod
    # pass proves pod-axis shardability via the production compile above.
    if not multi_pod:
        probe = _probe_costs(cfg, shape, mesh, grad_accum)
        mf = model_flops(cfg, shape)
        roof = H.Roofline(
            compute_s=probe["flops"] / H.PEAK_FLOPS,
            memory_s=probe["bytes"] / H.HBM_BW,
            collective_s=probe["coll"] / H.ICI_BW,
            hlo_flops=probe["flops"], hlo_bytes=probe["bytes"],
            coll_bytes=probe["coll"], model_flops=mf, chips=chips,
        )
        rec["collectives_by_kind"] = probe.get("coll_by_kind")
        rec["roofline"] = roof.as_dict()
    return rec


def _state_shardings(state_sds, p_sh, mesh):
    """Opt-state shardings: moments inherit the parameter sharding; int8
    block scales drop the (blocked) last-dim partitioning; step replicated."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    rep = NamedSharding(mesh, P())

    def build(sds, sh):
        # sds mirrors {"opt": {"step":..., "moments": <tree like params>}}
        out = {"step": rep, "moments": {}}

        def walk(m_sds, p_sharding):
            if isinstance(m_sds, dict) and "m" in m_sds and "v" in m_sds:
                def one(x):
                    if isinstance(x, dict):  # int8 {"q","scale"}: the block
                        # scales drop the (blocked) last-dim partitioning
                        spec = p_sharding.spec
                        return {
                            "q": p_sharding,
                            "scale": NamedSharding(mesh, P(*spec[:-1], None))
                            if len(spec) > 0 else rep,
                        }
                    return p_sharding
                return {"m": one(m_sds["m"]), "v": one(m_sds["v"])}
            return {
                k: walk(m_sds[k], p_sharding[k]) for k in m_sds
            }

        out["moments"] = walk(sds["opt"]["moments"], sh)
        return {"opt": out}

    return build(state_sds, p_sh)


def lower_anotherme(multi_pod: bool, n_traj: int = 1_048_576, L: int = 16):
    """The paper's own workload on the flat executor mesh (512 devices).

    Uses the engine API's sharded building blocks directly (the capacity
    plan is hand-set for the 1M-trajectory shape, so no data pass is
    needed); the "ssh" registry backend supplies the on-device key_fn.
    """
    from repro.api import (
        BackendContext, DistributedPlan, get_backend, make_sharded_pipeline,
    )
    from repro.core.similarity import default_betas

    mesh = make_executor_mesh(512 if multi_pod else 256)
    n_shards = mesh.size
    local_n = n_traj // n_shards
    S = 560  # C(16,3)
    plan = DistributedPlan(
        n_shards=n_shards, local_n=local_n,
        shingle_route_cap=int(local_n * S / n_shards * 1.3) + 64,
        local_pair_cap=1 << 18, pair_route_cap=1 << 12, scored_cap=1 << 18,
    )
    backend = get_backend("ssh")
    key_fn = backend.shard_key_fn(BackendContext(k=3, num_types=300))
    run = make_sharded_pipeline(
        mesh, plan, betas=default_betas(3), key_fn=key_fn
    )
    from jax.sharding import NamedSharding, PartitionSpec as P

    places = jax.ShapeDtypeStruct(
        (n_shards * local_n, L), jnp.int32,
        sharding=NamedSharding(mesh, P("ex", None)),
    )
    lengths = jax.ShapeDtypeStruct(
        (n_shards * local_n,), jnp.int32, sharding=NamedSharding(mesh, P("ex")),
    )
    # the semantic forest (replicated; encoding runs in-mesh from it —
    # the [N, 3, L] code table never exists as a program input)
    tables = jax.ShapeDtypeStruct(
        (3, 10_000), jnp.int32, sharding=NamedSharding(mesh, P()),
    )
    lowered = jax.jit(run).lower(places, places, lengths, tables)
    t0 = time.time()
    compiled = lowered.compile()
    print(compiled.memory_analysis())
    roof = H.roofline_from_compiled(compiled, chips=n_shards, model_flops=0.0)
    return {
        "arch": "anotherme-1M", "shape": f"N={n_traj},L={L}",
        "mesh": f"ex{n_shards}", "chips": n_shards,
        "compile_s": time.time() - t0,
        "memory": H.memory_summary(compiled),
        "collectives": H.collective_bytes(compiled.as_text()),
        "roofline": roof.as_dict(),
        "status": "ok",
    }


def enumerate_cells():
    cells = []
    for arch in all_archs():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            cells.append((arch, sname, ok, why))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--anotherme", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()

    cells = enumerate_cells()
    if args.list:
        for arch, sname, ok, why in cells:
            print(f"{arch:20s} {sname:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return

    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    records = []
    if out_path.exists():
        records = json.loads(out_path.read_text())

    def done(arch, shape, mesh):
        return any(
            r["arch"] == arch and r["shape"] == shape and r["mesh"] == mesh
            and r["status"] == "ok"
            for r in records
        )

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.anotherme:
        for mp in meshes:
            rec = lower_anotherme(mp)
            records.append(rec)
            out_path.write_text(json.dumps(records, indent=1))
            print(json.dumps(rec["roofline"], indent=1))
        return

    for arch, sname, ok, why in cells:
        if args.arch and arch != args.arch:
            continue
        if args.shape and sname != args.shape:
            continue
        if not ok:
            print(f"SKIP {arch} {sname}: {why}")
            continue
        for mp in meshes:
            mname = "2x16x16" if mp else "16x16"
            if done(arch, sname, mname):
                print(f"CACHED {arch} {sname} {mname}")
                continue
            print(f"=== {arch} {sname} {mname} ===", flush=True)
            try:
                rec = lower_cell(arch, sname, mp)
            except Exception as e:
                traceback.print_exc()
                rec = {
                    "arch": arch, "shape": sname, "mesh": mname,
                    "status": f"error: {type(e).__name__}: {str(e)[:500]}",
                }
            records.append(rec)
            out_path.write_text(json.dumps(records, indent=1))
            if rec["status"] == "ok" and "roofline" in rec:
                print(json.dumps(rec["roofline"], indent=1), flush=True)


if __name__ == "__main__":
    main()
