"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state; the dry-run sets
XLA_FLAGS before any jax import and only then builds meshes.

Mesh shapes (assignment spec): single pod = (data=16, model=16) — 256 chips
(one v5e pod); multi-pod = (pod=2, data=16, model=16) — 512 chips.  The
"pod" axis carries data-parallel replication across the DCN boundary; all
model collectives stay inside a pod.

``make_executor_mesh`` flattens every axis into one "ex" axis for the
AnotherMe analytics plane (trajectory shards == Spark executors).
"""
from __future__ import annotations

import jax

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_executor_mesh(n_devices: int | None = None):
    n = n_devices or len(jax.devices())
    return compat.make_mesh((n,), ("ex",))


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return compat.make_mesh((1, 1), ("data", "model"))
