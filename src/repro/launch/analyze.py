"""Analytics driver CLI: run AnotherMe over synthetic or GeoLife-surrogate
trajectories and report communities + phase timings.

  PYTHONPATH=src python -m repro.launch.analyze --n 5000
  PYTHONPATH=src python -m repro.launch.analyze --dataset geolife
"""
from __future__ import annotations

import argparse

from repro.core import AnotherMeConfig, run_anotherme
from repro.data import geolife_surrogate, synthetic_setup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synthetic",
                    choices=["synthetic", "geolife"])
    ap.add_argument("--n", type=int, default=5_000)
    ap.add_argument("--num-types", type=int, default=30)
    ap.add_argument("--rho", type=float, default=2.0)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--communities", default="cliques",
                    choices=["cliques", "components"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.dataset == "geolife":
        batch, forest = geolife_surrogate(seed=args.seed)
    else:
        batch, forest = synthetic_setup(
            args.n, num_types=args.num_types, seed=args.seed
        )
    cfg = AnotherMeConfig(
        k=args.k, rho=args.rho, community_mode=args.communities
    )
    res = run_anotherme(batch, forest, cfg)
    print(f"trajectories          : {batch.num_trajectories}")
    for key, val in res.stats.items():
        if isinstance(val, float):
            print(f"{key:22s}: {val:.3f}")
        else:
            print(f"{key:22s}: {val}")
    sizes = sorted((len(c) for c in res.communities), reverse=True)[:10]
    print(f"largest communities   : {sizes}")


if __name__ == "__main__":
    main()
