"""Post-compile HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` gives per-device FLOPs/bytes but no collective traffic;
we parse the post-SPMD HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(and ragged-all-to-all) op, per the assignment's roofline recipe.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s/link (per-chip injection, one link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"\b(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(rhs: str) -> int:
    """Bytes of the result type(s) at the start of an HLO instruction RHS."""
    # result type is everything before the op name; shapes after the first
    # op-paren belong to operands in some dialects — cut at the first
    # lowercase-word+'(' that is NOT a dtype token.
    cut = len(rhs)
    m = re.search(r"[a-z][a-z0-9\-]*\(", rhs)
    if m:
        cut = m.start()
    total = 0
    for dt, dims in _SHAPE_RE.findall(rhs[:cut]):
        total += _shape_bytes(dt, dims)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum of operand bytes per collective kind (per-device shard shapes).

    CPU-backend HLO references operands by name only, so we first build a
    name -> result-bytes symbol table, then resolve each collective's
    operand list against it.  Async pairs (-start/-done) count once.
    """
    table: dict[str, int] = {}
    coll_lines: list[tuple[str, str]] = []
    for line in hlo_text.splitlines():
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rhs = d.group(1), d.group(2)
        table[name] = _result_bytes(rhs)
        m = _OPNAME_RE.search(rhs)
        if m and m.group(2) != "-done":
            args = rhs[m.end():]
            # cut at the closing paren of the operand list (before attrs)
            depth = 1
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        args = args[:i]
                        break
            coll_lines.append((m.group(1), args))

    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for kind, args in coll_lines:
        total = 0
        for op in _OPERAND_RE.findall(args):
            total += table.get(op, 0)
        # inline-shaped operands (TPU-style HLO)
        for dt, dims in _SHAPE_RE.findall(args):
            total += _shape_bytes(dt, dims)
        out[kind] += total
        counts[kind] += 1
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    coll_bytes: float           # per device
    model_flops: float          # analytic 6*N*D (global)
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline lower bound: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """MODEL_FLOPS / (chips * peak * step_time) at the roofline bound."""
        if self.step_time_s == 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * self.step_time_s)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "step_time_bound_s": self.step_time_s,
            "mfu_bound": self.mfu,
            "chips": self.chips,
        }


def roofline_from_compiled(compiled, *, chips: int, model_flops: float) -> Roofline:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    coll_b = float(coll["total_bytes"])
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_acc / HBM_BW,
        collective_s=coll_b / ICI_BW,
        hlo_flops=flops,
        hlo_bytes=bytes_acc,
        coll_bytes=coll_b,
        model_flops=model_flops,
        chips=chips,
    )


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_bytes_est": ma.argument_size_in_bytes
        + ma.output_size_in_bytes
        + ma.temp_size_in_bytes
        - ma.alias_size_in_bytes,
        "generated_code_bytes": ma.generated_code_size_in_bytes,
    }
