"""End-to-end training driver.

Wires together: config registry, SSH-dedup data pipeline, jitted train step
(grad accumulation + optional int8-EF gradient compression), async atomic
checkpointing with resume, elastic resharding (resume on a different device
count/mesh), and the straggler watchdog.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tiny-100m --steps 300
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --reduced \\
      --steps 20 --ckpt-dir /tmp/ck --resume
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.data.tokens import TokenDataset, ssh_dedup, synthetic_corpus
from repro.launch.mesh import make_smoke_mesh
from repro.models.model import init_params, param_count, param_shardings
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.train.optimizer import OptConfig
from repro.train.straggler import StragglerWatchdog
from repro.train.train_step import TrainConfig, make_train_step, make_train_state

TINY_100M = ModelConfig(
    name="tiny-100m", family="dense", num_layers=8, d_model=512,
    num_heads=8, num_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=32_000,
    attn="gqa",
)


def resolve_config(name: str, reduced: bool) -> ModelConfig:
    if name == "tiny-100m":
        return TINY_100M
    cfg = get_config(name)
    return cfg.reduced() if reduced else cfg


def make_mesh_for_devices():
    n = len(jax.devices())
    from repro.core import compat

    return compat.make_mesh((n, 1), ("data", "model"))


def train(args) -> dict:
    cfg = resolve_config(args.arch, args.reduced)
    mesh = make_mesh_for_devices()
    print(f"arch={cfg.name} params={param_count(cfg)/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    # ----- data (with the paper's SSH dedup) ------------------------------
    corpus, _ = synthetic_corpus(
        args.num_docs, args.seq_len + 1, cfg.vocab_size,
        dup_fraction=args.dup_fraction, seed=args.seed,
    )
    if args.dedup == "ssh":
        keep, stats = ssh_dedup(corpus, vocab_size=cfg.vocab_size)
        print(f"ssh-dedup: {stats}")
        corpus = corpus[keep]
    ds = TokenDataset(corpus, global_batch=args.global_batch, seed=args.seed)

    # ----- state -----------------------------------------------------------
    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=args.warmup),
        grad_accum=args.grad_accum,
        compress_grads=args.compress_grads,
    )
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    state = make_train_state(params, tcfg)
    start_step = 0
    if args.resume and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            shardings = {"params": param_shardings(cfg, mesh)}
            tree = restore_checkpoint(
                args.ckpt_dir, last, {"params": params, "state": state},
                shardings=None,
            )
            params, state = tree["params"], tree["state"]
            start_step = last
            print(f"resumed from step {last} (elastic reshard onto "
                  f"{len(jax.devices())} devices)")

    step_fn = jax.jit(make_train_step(cfg, tcfg, mesh), donate_argnums=(0, 1))
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    watchdog = StragglerWatchdog(
        threshold=args.straggler_threshold,
        on_event=lambda ev: print(
            f"[straggler] step={ev.step} host={ev.host} "
            f"{ev.duration*1e3:.0f}ms vs median {ev.median*1e3:.0f}ms"
        ),
    )

    losses = []
    for step in range(start_step, args.steps):
        batch = ds.batch(step)
        watchdog.step_start()
        params, state, metrics = step_fn(params, state, batch)
        jax.tree.leaves(metrics)[0].block_until_ready()
        flagged = watchdog.step_end(step)
        if flagged and ckpt is not None:
            ckpt.save(step + 1, {"params": params, "state": state})
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}")
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "state": state})
    if ckpt is not None:
        ckpt.save(args.steps, {"params": params, "state": state})
        ckpt.wait()
    return {"losses": losses, "params": params, "state": state,
            "straggler_events": len(watchdog.events)}


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--num-docs", type=int, default=2048)
    ap.add_argument("--dup-fraction", type=float, default=0.2)
    ap.add_argument("--dedup", default="ssh", choices=["ssh", "none"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=50)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler-threshold", type=float, default=8.0)
    return ap


if __name__ == "__main__":
    train(build_parser().parse_args())
