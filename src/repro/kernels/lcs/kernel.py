"""Pallas TPU kernel: batched LCS via anti-diagonal wavefront.

TPU-native rewrite of the paper's CPU dynamic program (section IV.3).  The
classic dp[i][j] recurrence is re-laid along anti-diagonals t = i + j so the
inner dimension vectorizes on the VPU:

    d_t[i] = d_{t-2}[i-1] + 1                      if a[i-1] == b[t-i-1]
             max(d_{t-1}[i-1], d_{t-1}[i])         otherwise

Two rolling diagonals of shape [TB, L+1] live in VREGs; the b-operand is
accessed through a **rolling window**: a sentinel-padded reversed copy of b
is rolled right by one lane per step, so the wavefront's diagonal gather
becomes a static [:, :L+1] slice — no dynamic lane indexing, no gathers, no
data-dependent control flow.  2L-1 steps total.

Sentinels: the wrapper pads side A with -1, side B with -2; the window pad
is -3 and the a-shift pad is -4, so no padding combination ever "matches"
and out-of-range wavefront cells provably stay at 0 (see DESIGN.md).

Block shape: [TB, L] int32 tiles of both operands in VMEM; VMEM footprint
is ~5 * TB * (3L) * 4 bytes (a, window, two diagonals, scratch) — for the
default TB=512, L=32: ~1 MB, far under the ~16 MB/core budget, letting the
grid pipeline overlap HBM loads with compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SENT_WINDOW = -3
SENT_SHIFT = -4


def _lcs_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]  # [TB, L] int32
    b = b_ref[...]
    tb, L = a.shape

    # a_ext[i] = a[i-1] with sentinel shift-in: [TB, L+1]
    a_ext = jnp.concatenate(
        [jnp.full((tb, 1), SENT_SHIFT, jnp.int32), a], axis=1
    )
    # rolling window over reversed b: width W = 3L-1; at step t the live
    # slice [:, :L+1] equals b[t-1-i] for i = 0..L (sentinel out of range).
    window = jnp.concatenate(
        [
            jnp.full((tb, L), SENT_WINDOW, jnp.int32),
            b[:, ::-1],
            jnp.full((tb, L - 1), SENT_WINDOW, jnp.int32),
        ],
        axis=1,
    )
    # pre-align for t = 2: roll left by (2L - 2)
    window = jnp.roll(window, -(2 * L - 2), axis=1)

    zeros = jnp.zeros((tb, L + 1), jnp.int32)

    def shift_right(x):  # x[i-1] with 0 fill
        return jnp.concatenate([jnp.zeros((tb, 1), jnp.int32), x[:, :-1]], axis=1)

    def step(_, carry):
        d2, d1, win = carry
        bj = win[:, : L + 1]
        match = a_ext == bj
        up = d1
        left = shift_right(d1)
        diag = shift_right(d2)
        new = jnp.where(match, diag + 1, jnp.maximum(up, left))
        return d1, new, jnp.roll(win, 1, axis=1)

    _, d1, _ = jax.lax.fori_loop(0, 2 * L - 1, step, (zeros, zeros, window))
    o_ref[...] = d1[:, L:]  # dp[L, L], shape [TB, 1]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def lcs_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_b: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """a, b: int32 [B, L] (pre-padded, distinct sentinels) -> int32 [B].

    Any batch size works: a trailing partial tile is padded up to the next
    ``block_b`` multiple with the standard (-1, -2) sentinels — which can
    never match each other — and the result is sliced back to ``B``, so
    callers no longer over-pad pair buffers to tile multiples themselves.
    """
    B, L = a.shape
    assert b.shape == (B, L)
    pad = (-B) % block_b
    if pad:
        a = jnp.concatenate([a, jnp.full((pad, L), -1, jnp.int32)])
        b = jnp.concatenate([b, jnp.full((pad, L), -2, jnp.int32)])
    grid = ((B + pad) // block_b,)
    out = pl.pallas_call(
        _lcs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, L), lambda i: (i, 0)),
            pl.BlockSpec((block_b, L), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pad, 1), jnp.int32),
        interpret=interpret,
    )(a, b)
    return out[:B, 0]
