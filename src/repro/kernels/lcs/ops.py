"""Public jit'd wrapper for the batched LCS kernel.

Pads the batch to the block size and dispatches to the Pallas kernel
(interpret=True off-TPU so CPU tests execute the same kernel body).  The
wrapper is shard-local-shape aware: it is traceable inside a shard_map
program, where the batch is the per-shard pair buffer — the block size
shrinks to the (power-of-two) batch size so a small shard never pads up to
a full 512-row tile, and any remainder rows are sentinel-padded so they
can never contribute a match.

``mode`` selects the dispatch policy:

  "auto"       wavefront for tiny batches off-TPU (kernel launch overhead
               dominates), Pallas otherwise — the production default.
  "pallas"     always the Pallas kernel (interpret off-TPU); used by parity
               tests that must prove the kernel really runs.
  "interpret"  always the Pallas kernel with interpret=True, even on TPU.
  "wavefront"  always the jnp anti-diagonal wavefront.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lcs.kernel import lcs_pallas
from repro.core.similarity import lcs_wavefront, wavefront_dtype_from_env


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _block_for(batch: int, block_b: int) -> int:
    """Largest power-of-two block <= block_b that does not over-pad batch."""
    b = 1
    while b < batch and b < block_b:
        b *= 2
    return b


def lcs(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_b: int = 512,
    mode: str = "auto",
    wavefront_dtype: jnp.dtype | None = None,
) -> jnp.ndarray:
    """Batched LCS: int32 [B, L] x2 -> int32 [B].

    Inputs must be sentinel-padded (side A: -1, side B: -2) as produced by
    repro.core.similarity.repad.

    This wrapper is deliberately NOT jitted: it is pure dispatch (the kernel
    and the wavefront are jitted themselves), and it is the call boundary
    where the REPRO_LCS_DTYPE probe is resolved into the wavefront's static
    ``dtype`` argument (``wavefront_dtype=None`` -> read the env var here,
    never inside a trace).
    """
    if mode not in ("auto", "pallas", "interpret", "wavefront"):
        raise ValueError(
            f"unknown lcs dispatch mode {mode!r}; "
            "valid: ['auto', 'pallas', 'interpret', 'wavefront']"
        )
    B, L = a.shape
    assert b.shape == (B, L)
    if mode == "wavefront" or (mode == "auto" and B < block_b and not _on_tpu()):
        if wavefront_dtype is None:
            wavefront_dtype = wavefront_dtype_from_env()
        return lcs_wavefront(a, b, dtype=wavefront_dtype)
    interpret = True if mode == "interpret" else not _on_tpu()
    # lcs_pallas auto-pads any remainder rows up to the block multiple
    return lcs_pallas(a, b, block_b=_block_for(B, block_b), interpret=interpret)
