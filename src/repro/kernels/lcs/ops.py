"""Public jit'd wrapper for the batched LCS kernel.

Pads the batch to the block size and dispatches to the Pallas kernel
(interpret=True off-TPU so CPU tests execute the same kernel body).  The
wrapper is shard-local-shape aware: it is traceable inside a shard_map
program, where the batch is the per-shard pair buffer — the block size
shrinks to the (power-of-two) batch size so a small shard never pads up to
a full 512-row tile, and any remainder rows are sentinel-padded so they
can never contribute a match.

``mode`` selects the dispatch policy:

  "auto"       wavefront for tiny batches off-TPU (kernel launch overhead
               dominates), Pallas otherwise — the production default.
  "pallas"     always the Pallas kernel (interpret off-TPU); used by parity
               tests that must prove the kernel really runs.
  "interpret"  always the Pallas kernel with interpret=True, even on TPU.
  "wavefront"  always the jnp anti-diagonal wavefront.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lcs.kernel import lcs_pallas
from repro.core.similarity import lcs_wavefront


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _block_for(batch: int, block_b: int) -> int:
    """Largest power-of-two block <= block_b that does not over-pad batch."""
    b = 1
    while b < batch and b < block_b:
        b *= 2
    return b


@functools.partial(jax.jit, static_argnames=("block_b", "mode"))
def lcs(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_b: int = 512,
    mode: str = "auto",
) -> jnp.ndarray:
    """Batched LCS: int32 [B, L] x2 -> int32 [B].

    Inputs must be sentinel-padded (side A: -1, side B: -2) as produced by
    repro.core.similarity.repad.
    """
    if mode not in ("auto", "pallas", "interpret", "wavefront"):
        raise ValueError(
            f"unknown lcs dispatch mode {mode!r}; "
            "valid: ['auto', 'pallas', 'interpret', 'wavefront']"
        )
    B, L = a.shape
    if mode == "wavefront" or (mode == "auto" and B < block_b and not _on_tpu()):
        return lcs_wavefront(a, b)
    interpret = True if mode == "interpret" else not _on_tpu()
    bb = _block_for(B, block_b)
    pad = (-B) % bb
    if pad:
        a = jnp.concatenate([a, jnp.full((pad, L), -1, jnp.int32)])
        b = jnp.concatenate([b, jnp.full((pad, L), -2, jnp.int32)])
    out = lcs_pallas(a, b, block_b=bb, interpret=interpret)
    return out[:B]
