"""Public jit'd wrapper for the batched LCS kernel.

Pads the batch to the block size and dispatches to the Pallas kernel
(interpret=True off-TPU so CPU tests execute the same kernel body).  The
wrapper is shard-local-shape aware: it is traceable inside a shard_map
program, where the batch is the per-shard pair buffer — the block size is
chosen to minimize padded waste (see :func:`_block_for`) so a small or
just-past-a-boundary shard never pads up to a full 512-row tile, and any
remainder rows are sentinel-padded so they can never contribute a match.

``mode`` selects the dispatch policy:

  "auto"       wavefront for tiny batches off-TPU (kernel launch overhead
               dominates), Pallas otherwise — the production default.
  "pallas"     always the Pallas kernel (interpret off-TPU); used by parity
               tests that must prove the kernel really runs.
  "interpret"  always the Pallas kernel with interpret=True, even on TPU.
  "wavefront"  always the jnp anti-diagonal wavefront.

``block_b`` is the tile-size CAP, not the tile size: the dispatcher picks
the waste-minimizing power of two at or under it.  Callers holding a tuned
block size (repro.perf's autotune table, resolved eagerly at the call
boundary — never inside a trace) pass it here and the same waste rule
applies under the tuned cap.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.compat import on_tpu as _on_tpu
from repro.core.encoding import PAD_CODE_A, PAD_CODE_B
from repro.kernels.lcs.kernel import lcs_pallas
from repro.core.similarity import lcs_wavefront, wavefront_dtype_from_env

# smallest tile worth launching a grid step for: below this, per-block
# launch overhead dominates the padded-row waste the block would save
_BLOCK_FLOOR = 128


def _block_for(batch: int, block_b: int, *, floor: int = _BLOCK_FLOOR) -> int:
    """Power-of-two block <= block_b minimizing padded rows, over a floor.

    The old rule ("largest power of two <= batch") over-pads just past a
    boundary: B=513 picked block 512, padding to 1024 (~50% wasted rows),
    when block 128 pads only to 640.  Instead, every candidate power of two
    in [min(floor, block_b), block_b] is scored by its padded batch size
    ``ceil(B / b) * b``; the smallest padding wins, and ties go to the
    LARGER block (fewer grid steps for the same rows).
    """
    cap = max(1, block_b)
    lo = min(floor, cap)
    best_b, best_padded = None, None
    b = 1
    while b <= cap:
        if b >= lo:
            padded = -(-batch // b) * b  # ceil(batch / b) * b
            if best_padded is None or padded <= best_padded:
                best_b, best_padded = b, padded
        b *= 2
    return best_b


def lcs(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_b: int = 512,
    mode: str = "auto",
    wavefront_dtype: jnp.dtype | None = None,
) -> jnp.ndarray:
    """Batched LCS: int32 [B, L] x2 -> int32 [B].

    Inputs must be sentinel-padded (side A: -1, side B: -2) as produced by
    repro.core.similarity.repad.

    This wrapper is deliberately NOT jitted: it is pure dispatch (the kernel
    and the wavefront are jitted themselves), and it is the call boundary
    where the REPRO_LCS_DTYPE probe is resolved into the wavefront's static
    ``dtype`` argument (``wavefront_dtype=None`` -> read the env var here,
    never inside a trace).  Tuned parameters flow in the same way: the
    engine resolves the autotune table eagerly and passes ``block_b`` /
    ``wavefront_dtype`` as static arguments.
    """
    if mode not in ("auto", "pallas", "interpret", "wavefront"):
        raise ValueError(
            f"unknown lcs dispatch mode {mode!r}; "
            "valid: ['auto', 'pallas', 'interpret', 'wavefront']"
        )
    B, L = a.shape
    assert b.shape == (B, L)
    if mode == "wavefront" or (mode == "auto" and B < block_b and not _on_tpu()):
        if wavefront_dtype is None:
            wavefront_dtype = wavefront_dtype_from_env()
        return lcs_wavefront(a, b, dtype=wavefront_dtype)
    interpret = True if mode == "interpret" else not _on_tpu()
    # lcs_pallas auto-pads any remainder rows up to the block multiple
    return lcs_pallas(a, b, block_b=_block_for(B, block_b), interpret=interpret)


def lcs_windowed(
    a: jnp.ndarray,
    b: jnp.ndarray,
    off_a: jnp.ndarray,
    off_b: jnp.ndarray,
    len_a: jnp.ndarray,
    len_b: jnp.ndarray,
    *,
    window: int,
    block_b: int = 512,
    mode: str = "auto",
    wavefront_dtype: jnp.dtype | None = None,
) -> jnp.ndarray:
    """Subtrajectory LCS: full rows + per-row window coordinates -> [B].

    a/b int32 [B, L] code rows with the table's native padding (no repad
    needed), off_a/off_b [B] window start offsets, len_a/len_b [B] the
    rows' TRUE lengths.  Each row is sliced to its
    ``[off, off + clip(len - off, 0, window))`` window, sentinel-repadded
    to width ``min(window, L)``, and dispatched through :func:`lcs` — so
    the batched kernel runs 2W-1 wavefront steps over width-W tiles
    instead of 2L-1 over the full rows, and the same ``mode``/``block_b``
    tuning surface applies.
    """
    B, L = a.shape
    W = min(window, L)
    pos = jnp.arange(W, dtype=jnp.int32)

    def slice_side(x, off, length, pad_code):
        wlen = jnp.clip(length - off, 0, W)
        p = jnp.clip(off[:, None] + pos[None, :], 0, L - 1)
        win = jnp.take_along_axis(x, p, axis=1)
        return jnp.where(pos[None, :] < wlen[:, None], win, pad_code)

    return lcs(
        slice_side(a, off_a, len_a, PAD_CODE_A),
        slice_side(b, off_b, len_b, PAD_CODE_B),
        block_b=block_b, mode=mode, wavefront_dtype=wavefront_dtype,
    )
