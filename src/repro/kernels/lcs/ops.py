"""Public jit'd wrapper for the batched LCS kernel.

Pads the batch to the block size, dispatches to the Pallas kernel
(interpret=True off-TPU so CPU tests execute the same kernel body), and
falls back to the jnp wavefront for tiny batches where kernel launch
overhead dominates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lcs.kernel import lcs_pallas
from repro.core.similarity import lcs_wavefront


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_b",))
def lcs(a: jnp.ndarray, b: jnp.ndarray, *, block_b: int = 512) -> jnp.ndarray:
    """Batched LCS: int32 [B, L] x2 -> int32 [B].

    Inputs must be sentinel-padded (side A: -1, side B: -2) as produced by
    repro.core.similarity.repad.
    """
    B, L = a.shape
    if B < block_b and not _on_tpu():
        return lcs_wavefront(a, b)
    pad = (-B) % block_b
    if pad:
        a = jnp.concatenate([a, jnp.full((pad, L), -1, jnp.int32)])
        b = jnp.concatenate([b, jnp.full((pad, L), -2, jnp.int32)])
    out = lcs_pallas(a, b, block_b=block_b, interpret=not _on_tpu())
    return out[:B]
