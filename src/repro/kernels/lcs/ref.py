"""Pure-jnp oracle for the LCS kernel: the textbook row DP."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.similarity import lcs_ref


def lcs(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a, b int32 [B, L] (sentinel-padded) -> int32 [B]."""
    return lcs_ref(a, b)
