"""Fused gather-and-score Pallas TPU kernel: table -> (level_lcs, MSS).

The hot path of the pipeline is exact pair scoring: for every surviving
candidate pair (l, r), the LCS of the two trajectories' encodings at every
semantic level, beta-combined into the MSS (paper section IV.3).  The
baseline path (``score_pairs`` -> ``multi_level_lcs``) first materializes
TWO full ``[P, H, L]`` gathered-and-repadded operand copies in HBM before
any kernel runs, so scoring is memory-bound long before it is compute-bound.

This kernel makes scoring gather-free and level-fused:

* **Scalar-prefetched gather** — the pair index arrays ``left/right [P]``
  (plus the length tables) ride in SMEM via
  ``pltpu.PrefetchScalarGridSpec``; the operand BlockSpec index maps read
  ``left[p]`` / ``right[p]`` so grid block ``p`` DMAs its own two
  ``[H, L]`` rows straight out of the resident code table.  The gathered
  ``[P, H, L]`` copies never exist in HBM, and the grid pipeline overlaps
  each block's row DMA with the previous block's wavefront.
* **In-register repad** — rows arrive with whatever padding the table
  carries; the kernel masks positions ``>= length`` to the standard
  sentinels (side A: -1, side B: -2, exactly ``similarity.repad``) in
  VREGs, so the host-side repad round trip disappears too.
* **Level fusion** — all H levels of a pair run through the rolling-window
  wavefront (see kernels/lcs/kernel.py for the window scheme) in ONE block
  as an [H, L+1] tile, with the two rolling diagonals carried in int8
  (LCS <= L < 127).
* **Fused MSS** — the block emits ``level_lcs [1, H]`` AND the
  beta-weighted ``mss [1, 1]`` (``sum_h beta_h * |M_h|``), fusing
  ``mss_scores`` into the kernel epilogue.  The in-block float32 sum can
  differ from the XLA lowering of ``mss_scores`` by 1 ulp (XLA may
  FMA-contract the batched multiply+reduce), so the dispatch wrapper
  recomputes the authoritative ``mss`` from the integer ``level_lcs``
  through ``mss_scores`` itself by default (``exact_mss=True``) — an O(PH)
  epilogue that keeps every ``lcs_impl`` bit-identical — and returns the
  kernel's own epilogue with ``exact_mss=False`` (the pure-throughput
  path, e.g. benchmarking).

Two tables are taken (``table_a``/``table_b``) so the same kernel serves
both sharded score modes: "replicate" passes the all_gathered code table
twice with real pair indices, "shuffle" passes the two per-shard gathered
operand stacks with iota indices (the gather there already happened via
the owner hops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import on_tpu as _on_tpu
from repro.core.encoding import PAD_CODE_A, PAD_CODE_B
from repro.kernels.lcs.kernel import SENT_SHIFT, SENT_WINDOW

# the canonical lcs_impl-name -> dispatch-mode mapping for the fused family;
# every registration point (stages, score_pairs, the sharded pipeline)
# imports THIS dict so a new variant is added in exactly one place
FUSED_IMPL_MODES = {
    "fused": "auto",
    "fused-pallas": "pallas",
    "fused-interpret": "interpret",
}

_DISPATCH_MODES = ("auto", "pallas", "interpret", "ref")


def _masked_rows_lcs(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """In-block multi-level LCS of sentinel-masked [H, L] rows -> [H] int8.

    Rolling-window wavefront over all H levels at once (kernel.py scheme),
    diagonals carried in int8 (LCS values <= L < 127).  The DP is position
    agnostic: any masked-out entry (the side sentinels never equal each
    other or a valid code) simply cannot contribute a match, so the LCS of
    masked full rows equals the LCS of the surviving subsequences — which
    is what lets the windowed kernel score a mid-row slice without moving
    it to the front.
    """
    H, L = a.shape
    a_ext = jnp.concatenate(
        [jnp.full((H, 1), SENT_SHIFT, jnp.int32), a], axis=1
    )
    window = jnp.concatenate(
        [
            jnp.full((H, L), SENT_WINDOW, jnp.int32),
            b[:, ::-1],
            jnp.full((H, L - 1), SENT_WINDOW, jnp.int32),
        ],
        axis=1,
    )
    window = jnp.roll(window, -(2 * L - 2), axis=1)
    zeros = jnp.zeros((H, L + 1), jnp.int8)

    def shift_right(x):
        return jnp.concatenate([jnp.zeros((H, 1), jnp.int8), x[:, :-1]], axis=1)

    def step(_, carry):
        d2, d1, win = carry
        match = a_ext == win[:, : L + 1]
        new = jnp.where(
            match, shift_right(d2) + jnp.ones((), jnp.int8),
            jnp.maximum(d1, shift_right(d1)),
        )
        return d1, new, jnp.roll(win, 1, axis=1)

    _, d1, _ = jax.lax.fori_loop(0, 2 * L - 1, step, (zeros, zeros, window))
    return d1[:, L]  # dp[L, L] per level


def _fused_kernel(li_ref, ri_ref, lena_ref, lenb_ref,
                  a_ref, b_ref, betas_ref, lvl_ref, mss_ref):
    p = pl.program_id(0)
    la = lena_ref[li_ref[p]]
    lb = lenb_ref[ri_ref[p]]
    a = a_ref[0]  # [H, L] int32 — our pair's left row, DMA'd by index map
    b = b_ref[0]
    H, L = a.shape

    # in-register repad: positions >= length become the side sentinels
    pos = jax.lax.broadcasted_iota(jnp.int32, (H, L), 1)
    a = jnp.where(pos < la, a, PAD_CODE_A)
    b = jnp.where(pos < lb, b, PAD_CODE_B)

    lvl = _masked_rows_lcs(a, b).astype(jnp.int32)
    lvl_ref[0, :] = lvl
    # fused mss_scores epilogue: sum_h beta_h * |M_h| in float32
    mss_ref[0, 0] = jnp.sum(lvl.astype(jnp.float32) * betas_ref[0])


def _fused_windowed_kernel(li_ref, ri_ref, lena_ref, lenb_ref,
                           offa_ref, offb_ref, a_ref, b_ref, betas_ref,
                           lvl_ref, mss_ref, *, window):
    """Subtrajectory variant: the scalar-prefetch tuple grows from
    ``(left, right, len_a, len_b)`` to include per-side window offsets.

    BlockSpec index maps are block granular, so the windowed [H, W] slice
    cannot be DMA'd at an element offset directly — instead the block DMAs
    its pair's full [H, L] rows (same traffic as the whole-trajectory
    kernel) and masks everything OUTSIDE ``[off, off + wlen)`` to the side
    sentinels in VREGs.  Sentinels never match, so the masked full-row LCS
    IS the windowed LCS (see :func:`_masked_rows_lcs`), the wavefront
    stays 2L-1 steps, and the gathered windowed operand copies never
    exist in HBM.
    """
    p = pl.program_id(0)
    la = lena_ref[li_ref[p]]
    lb = lenb_ref[ri_ref[p]]
    oa = offa_ref[p]
    ob = offb_ref[p]
    a = a_ref[0]
    b = b_ref[0]
    H, L = a.shape
    # window lengths in-kernel: clip(len - off, 0, W) with W static
    wla = jnp.clip(la - oa, 0, window)
    wlb = jnp.clip(lb - ob, 0, window)

    pos = jax.lax.broadcasted_iota(jnp.int32, (H, L), 1)
    a = jnp.where((pos >= oa) & (pos < oa + wla), a, PAD_CODE_A)
    b = jnp.where((pos >= ob) & (pos < ob + wlb), b, PAD_CODE_B)

    lvl = _masked_rows_lcs(a, b).astype(jnp.int32)
    lvl_ref[0, :] = lvl
    mss_ref[0, 0] = jnp.sum(lvl.astype(jnp.float32) * betas_ref[0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_gather_score(
    table_a: jnp.ndarray,
    len_a: jnp.ndarray,
    table_b: jnp.ndarray,
    len_b: jnp.ndarray,
    left: jnp.ndarray,
    right: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The raw kernel call: tables + pair indices -> (level_lcs, mss).

    table_a [Na, H, L] int32, len_a [Na] int32 (idem _b), left/right [P]
    int32 indices into the respective tables (pre-clamped: no PAD_ID), betas
    [H] float32 -> (level_lcs [P, H] int32, mss [P] float32).
    """
    P = left.shape[0]
    _, H, L = table_a.shape
    assert L < 127 and table_b.shape[1:] == (H, L)
    betas_row = betas.reshape(1, H).astype(jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,  # left, right, len_a, len_b
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, H, L), lambda p, li, ri, la, lb: (li[p], 0, 0)),
            pl.BlockSpec((1, H, L), lambda p, li, ri, la, lb: (ri[p], 0, 0)),
            pl.BlockSpec((1, H), lambda p, li, ri, la, lb: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H), lambda p, li, ri, la, lb: (p, 0)),
            pl.BlockSpec((1, 1), lambda p, li, ri, la, lb: (p, 0)),
        ],
    )
    lvl, mss = pl.pallas_call(
        _fused_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((P, H), jnp.int32),
            jax.ShapeDtypeStruct((P, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        left.astype(jnp.int32), right.astype(jnp.int32),
        len_a.astype(jnp.int32), len_b.astype(jnp.int32),
        table_a, table_b, betas_row,
    )
    return lvl, mss[:, 0]


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def fused_windowed_gather_score(
    table_a: jnp.ndarray,
    len_a: jnp.ndarray,
    table_b: jnp.ndarray,
    len_b: jnp.ndarray,
    left: jnp.ndarray,
    right: jnp.ndarray,
    off_a: jnp.ndarray,
    off_b: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    window: int,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The raw windowed kernel call: tables + (traj, offset) coordinates.

    Identical to :func:`fused_gather_score` except pairs carry per-side
    window offsets: left/right [P] are TRAJECTORY indices into the tables,
    off_a/off_b [P] the window start offsets, and the scored operand is
    the [H, W] slice ``rows[:, off : off + clip(len - off, 0, window)]``.
    The prefetch tuple is (left, right, len_a, len_b, off_a, off_b); each
    grid block still DMAs its pair's [H, L] rows straight off the resident
    table and windows them in-register.
    """
    P = left.shape[0]
    _, H, L = table_a.shape
    assert L < 127 and table_b.shape[1:] == (H, L)
    betas_row = betas.reshape(1, H).astype(jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,  # left, right, len_a, len_b, off_a, off_b
        grid=(P,),
        in_specs=[
            pl.BlockSpec(
                (1, H, L), lambda p, li, ri, la, lb, oa, ob: (li[p], 0, 0)
            ),
            pl.BlockSpec(
                (1, H, L), lambda p, li, ri, la, lb, oa, ob: (ri[p], 0, 0)
            ),
            pl.BlockSpec((1, H), lambda p, li, ri, la, lb, oa, ob: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H), lambda p, li, ri, la, lb, oa, ob: (p, 0)),
            pl.BlockSpec((1, 1), lambda p, li, ri, la, lb, oa, ob: (p, 0)),
        ],
    )
    lvl, mss = pl.pallas_call(
        functools.partial(_fused_windowed_kernel, window=min(window, L)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((P, H), jnp.int32),
            jax.ShapeDtypeStruct((P, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        left.astype(jnp.int32), right.astype(jnp.int32),
        len_a.astype(jnp.int32), len_b.astype(jnp.int32),
        off_a.astype(jnp.int32), off_b.astype(jnp.int32),
        table_a, table_b, betas_row,
    )
    return lvl, mss[:, 0]


def fused_score_ref(
    table_a, len_a, table_b, len_b, left, right, betas
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """jnp oracle for the fused kernel: the baseline gather-then-score path
    (``multi_level_lcs`` + ``mss_scores``), bit-identical by construction to
    ``score_pairs(..., impl_name="wavefront")``."""
    from repro.core.similarity import mss_scores, multi_level_lcs

    lvl = multi_level_lcs(
        table_a[left], len_a[left], table_b[right], len_b[right]
    )
    return lvl, mss_scores(lvl, betas)


def fused_score(
    table_a: jnp.ndarray,
    len_a: jnp.ndarray,
    table_b: jnp.ndarray,
    len_b: jnp.ndarray,
    left: jnp.ndarray,
    right: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    mode: str = "auto",
    exact_mss: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch wrapper mirroring kernels/lcs/ops.lcs:

      "auto"       the kernel on TPU, the jnp reference elsewhere (the
                   interpreter would be orders of magnitude slower than the
                   wavefront on CPU) — the production default.
      "pallas"     always the kernel (interpret off-TPU); parity tests that
                   must prove the kernel really runs.
      "interpret"  always the kernel with interpret=True, even on TPU.
      "ref"        always the jnp gather-then-score reference.

    ``exact_mss=True`` (default) recomputes the returned mss from the
    kernel's integer level_lcs through ``mss_scores`` — the same lowering
    every other lcs_impl uses, so scores stay bit-identical across impls.
    ``exact_mss=False`` returns the kernel's fused in-block epilogue
    (within 1 ulp; saves the O(PH) recompute on the throughput path).
    """
    if mode not in _DISPATCH_MODES:
        raise ValueError(
            f"unknown fused dispatch mode {mode!r}; "
            f"valid: {list(_DISPATCH_MODES)}"
        )
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return fused_score_ref(table_a, len_a, table_b, len_b, left, right, betas)
    interpret = True if mode == "interpret" else not _on_tpu()
    lvl, mss = fused_gather_score(
        table_a, len_a, table_b, len_b, left, right, betas, interpret=interpret
    )
    if exact_mss:
        from repro.core.similarity import mss_scores

        mss = mss_scores(lvl, betas)
    return lvl, mss


def fused_windowed_score_ref(
    table_a, len_a, table_b, len_b, left, right, off_a, off_b, betas,
    *, window: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """jnp oracle for the windowed kernel: gather the [P, H, W] window
    slices (``similarity.gather_windows``) and run the baseline
    gather-then-score path over length-W rows — bit-identical by
    construction to ``score_windowed_pairs(..., impl_name="wavefront")``."""
    from repro.core.similarity import (
        gather_windows, mss_scores, multi_level_lcs,
    )

    W = min(window, table_a.shape[-1])
    wla = jnp.clip(len_a[left] - off_a, 0, W)
    wlb = jnp.clip(len_b[right] - off_b, 0, W)
    lvl = multi_level_lcs(
        gather_windows(table_a[left], off_a, W), wla,
        gather_windows(table_b[right], off_b, W), wlb,
    )
    return lvl, mss_scores(lvl, betas)


def fused_windowed_score(
    table_a: jnp.ndarray,
    len_a: jnp.ndarray,
    table_b: jnp.ndarray,
    len_b: jnp.ndarray,
    left: jnp.ndarray,
    right: jnp.ndarray,
    off_a: jnp.ndarray,
    off_b: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    window: int,
    mode: str = "auto",
    exact_mss: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Windowed twin of :func:`fused_score`: same dispatch modes, same
    ``exact_mss`` contract, pairs carry (traj, offset) coordinates."""
    if mode not in _DISPATCH_MODES:
        raise ValueError(
            f"unknown fused dispatch mode {mode!r}; "
            f"valid: {list(_DISPATCH_MODES)}"
        )
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return fused_windowed_score_ref(
            table_a, len_a, table_b, len_b, left, right, off_a, off_b,
            betas, window=window,
        )
    interpret = True if mode == "interpret" else not _on_tpu()
    lvl, mss = fused_windowed_gather_score(
        table_a, len_a, table_b, len_b, left, right, off_a, off_b, betas,
        window=window, interpret=interpret,
    )
    if exact_mss:
        from repro.core.similarity import mss_scores

        mss = mss_scores(lvl, betas)
    return lvl, mss
