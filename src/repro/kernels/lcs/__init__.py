from repro.kernels.lcs.ops import lcs
from repro.kernels.lcs.fused import fused_gather_score, fused_score
