from repro.kernels.lcs.ops import lcs
