from repro.kernels.shingle.ops import shingle_keys
