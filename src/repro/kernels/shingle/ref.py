"""Pure-jnp oracle: gather-based shingling (no dedup, no sort)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.shingling import shingles_from_types


def shingle_keys(types, lengths, *, k: int, num_types: int) -> jnp.ndarray:
    """Distinct-per-row semantics NOT applied: raw combination keys, sorted
    ascending for comparability with the kernel output."""
    keys = shingles_from_types(
        types, lengths, k=k, num_types=num_types, dedup=False
    )
    return jnp.sort(keys, axis=-1)
