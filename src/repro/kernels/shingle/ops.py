"""Public wrapper: Pallas shingle keys + XLA-side dedup (sort + mask).

The kernel produces the raw C(L,k) combination keys; the distinct-per-row
set semantics (paper joins on DISTINCT shingles) are restored here with a
row sort + duplicate masking, exactly as core/shingling.py does.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compat import on_tpu as _on_tpu
from repro.core.shingling import num_shingles
from repro.core.types import PAD_KEY
from repro.kernels.shingle.kernel import shingle_pallas


@functools.partial(
    jax.jit, static_argnames=("k", "num_types", "block_b", "dedup")
)
def shingle_keys(
    types: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    k: int,
    num_types: int,
    block_b: int = 256,
    dedup: bool = True,
) -> jnp.ndarray:
    """int32 [N, L] types + [N] lengths -> int32 [N, S_pad] distinct keys."""
    N, L = types.shape
    s = num_shingles(L, k)
    s_pad = -(-s // 128) * 128  # lane-aligned output width
    pad = (-N) % block_b
    if pad:
        types = jnp.concatenate([types, jnp.zeros((pad, L), jnp.int32)])
        lengths = jnp.concatenate([lengths, jnp.zeros((pad,), jnp.int32)])
    keys = shingle_pallas(
        types, lengths, k=k, num_types=num_types, s_pad=s_pad,
        block_b=block_b, interpret=not _on_tpu(),
    )[:N]
    if dedup:
        n = keys.shape[0]
        keys = jnp.sort(keys, axis=-1)
        dup = jnp.concatenate(
            [jnp.zeros((n, 1), bool), keys[:, 1:] == keys[:, :-1]], axis=1
        )
        keys = jnp.where(dup, PAD_KEY, keys)
        keys = jnp.sort(keys, axis=-1)
    return keys
