"""Pallas TPU kernel: k-sequential shingle key generation.

The paper's Algorithm 1 is a k-deep nested loop per trajectory — a gather on
CPU.  The MXU-native rewrite: selecting the j-th member of every combination
is a matmul with a static 0/1 selection matrix E_j [L, S] (E_j[l, s] = 1 iff
combination s takes position l as its j-th element), so the whole shingle
tensor is k small matmuls

    c_j = types_f32 @ E_j          (exact in f32: codes < Q <= 2^24)

followed by an integer base-Q pack key = ((c_0*Q)+c_1)*Q+c_2 on the VPU.
This replaces an irregular gather with systolic-array work — the
"rethink for the MXU" adaptation called out in DESIGN.md.

Block shape: [TB, L] type codes + [TB, 1] lengths in VMEM; outputs
[TB, S] keys.  The selection matrices are compile-time constants that the
Mosaic compiler keeps in VMEM across grid steps.  VMEM footprint
TB*(L + S)*4 + k*L*S*4 bytes — TB=256, L=16, S=560: ~2.8 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.shingling import shingle_indices
from repro.core.types import PAD_KEY


def _selection_matrices(L: int, k: int, S_pad: int) -> tuple[np.ndarray, np.ndarray]:
    """E [k, L, S_pad] f32 one-hot selectors + last index per combo [S_pad]."""
    idx = shingle_indices(L, k)  # [S, k]
    S = idx.shape[0]
    E = np.zeros((k, L, S_pad), np.float32)
    for j in range(k):
        E[j, idx[:, j], np.arange(S)] = 1.0
    last = np.full((S_pad,), L + 1, np.int32)
    last[:S] = idx[:, -1]
    return E, last


def _make_kernel(k: int, num_types: int):
    def kernel(types_ref, len_ref, e_ref, last_ref, out_ref):
        types = types_ref[...].astype(jnp.float32)  # [TB, L]
        lengths = len_ref[...]  # [TB, 1]
        key = jnp.zeros(out_ref.shape, jnp.int32)
        for j in range(k):
            cj = jax.lax.dot(
                types, e_ref[j], precision=jax.lax.Precision.HIGHEST
            )
            key = key * num_types + cj.astype(jnp.int32)
        valid = last_ref[...] < lengths  # [1, S] vs [TB, 1] -> [TB, S]
        out_ref[...] = jnp.where(valid, key, PAD_KEY)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("k", "num_types", "s_pad", "block_b", "interpret")
)
def shingle_pallas(
    types: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    k: int,
    num_types: int,
    s_pad: int,
    block_b: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """types int32 [N, L], lengths int32 [N] -> keys int32 [N, s_pad]."""
    N, L = types.shape
    assert N % block_b == 0
    E_np, last_np = _selection_matrices(L, k, s_pad)
    kernel = _make_kernel(k, num_types)
    return pl.pallas_call(
        kernel,
        grid=(N // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, L), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((k, L, s_pad), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, s_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, s_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, s_pad), jnp.int32),
        interpret=interpret,
    )(types, lengths[:, None], jnp.asarray(E_np), jnp.asarray(last_np)[None, :])
