"""Pallas TPU kernel: Mamba-2 SSD intra-chunk block.

Per grid cell (batch*chunk, head): the chunk-local quadratic —

  scores[i,j] = (C_i . B_j)                     (MXU [Q,N]x[N,Q])
  M[i,j]      = tril * scores * exp(cum_i-cum_j) * dt_j
  y_intra     = M @ x                            (MXU [Q,Q]x[Q,P])
  state       = x^T @ (B * exp(cum_last-cum_j) * dt_j)   ([P,Q]x[Q,N])
  cdecay      = exp(cum_last)

The decay/score matrices live only in VREGs/VMEM — the HBM traffic that
dominates the zamba2/mamba2 memory roofline term in the XLA fallback
(§Perf) never happens.  The inter-chunk associative scan (tiny [H,P,N]
states) stays in XLA (ops.py), mirroring how the CUDA SSD splits work.

B/C are per-GROUP (G=1 for the assigned archs): their BlockSpec index maps
ignore the head index, so no H-fold replication is materialized.

VMEM per cell: x [Q,P] + B/C [Q,N] + M [Q,Q] f32 ~ Q=128,P=64,N=128:
~200 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, cum_ref, dt_ref, b_ref, c_ref, y_ref, st_ref, cd_ref):
    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [Q, P]
    cum = cum_ref[0, :, 0:1].astype(jnp.float32)     # [Q, 1]
    dt = dt_ref[0, :, 0:1].astype(jnp.float32)       # [Q, 1]
    B_ = b_ref[0].astype(jnp.float32)                # [Q, N]
    C_ = c_ref[0].astype(jnp.float32)                # [Q, N]
    Q = x.shape[0]

    scores = jax.lax.dot_general(
        C_, B_, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, Q] = C_i . B_j
    decay = jnp.exp(cum - cum.T)                     # exp(cum_i - cum_j)
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    )
    M = jnp.where(tri, scores * decay, 0.0) * dt.T
    y = jax.lax.dot(M, x, preferred_element_type=jnp.float32)

    last = cum[Q - 1 :, :]                           # [1, 1]
    w = jnp.exp(last - cum) * dt                     # [Q, 1]
    state = jax.lax.dot_general(
        x, B_ * w, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [P, N]
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    st_ref[0, 0] = state
    cd_ref[0, 0] = jnp.exp(last)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_pallas(x, cum, dt, B_, C_, *, interpret: bool = False):
    """x [BC, Q, H, P], cum/dt [BC, Q, H], B_/C_ [BC, Q, N] (G=1 group)
    -> (y [BC, Q, H, P] f32-accurate, state [BC, H, P, N] f32,
        cdecay [BC, H, 1, 1] f32)."""
    BC, Q, H, P = x.shape
    N = B_.shape[-1]
    grid = (BC, H)
    y, st, cd = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda bc, h: (bc, 0, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda bc, h: (bc, 0, h)),
            pl.BlockSpec((1, Q, 1), lambda bc, h: (bc, 0, h)),
            pl.BlockSpec((1, Q, N), lambda bc, h: (bc, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda bc, h: (bc, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda bc, h: (bc, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bc, h: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda bc, h: (bc, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BC, Q, H, P), x.dtype),
            jax.ShapeDtypeStruct((BC, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((BC, H, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, cum, dt, B_, C_)
    return y, st, cd
