"""Public wrapper: Pallas intra-chunk + XLA inter-chunk scan.

Same signature/semantics as models.mamba._ssd_chunked; the quadratic
intra-chunk work runs in the kernel, the [H,P,N] state recurrence in a
lax.associative_scan, and the (rank-1-per-token) inter-chunk contribution
as one einsum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compat import on_tpu as _on_tpu
from repro.kernels.ssd.kernel import ssd_intra_pallas


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked(x, dt, A, B_, C_, D, *, chunk: int = 128):
    """x [B,S,H,P], dt [B,S,H] (>0), A [H] (<0), B_/C_ [B,S,G=1,N], D [H]
    -> (y [B,S,H,P], final_state [B,H,P,N])."""
    Bz, S, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    assert G == 1, "assigned SSM archs use one B/C group"
    chunk = min(chunk, S)
    nc = S // chunk

    dA = dt * A
    cum = jnp.cumsum(dA.reshape(Bz, nc, chunk, H), axis=2)

    xc = x.reshape(Bz * nc, chunk, H, Pd)
    cumf = cum.reshape(Bz * nc, chunk, H)
    dtc = dt.reshape(Bz * nc, chunk, H)
    Bc = B_.reshape(Bz * nc, chunk, N)
    Cc = C_.reshape(Bz * nc, chunk, N)

    y_intra, states, cdecay = ssd_intra_pallas(
        xc, cumf, dtc, Bc, Cc, interpret=not _on_tpu()
    )
    states = states.reshape(Bz, nc, H, Pd, N)
    chunk_decay = cdecay.reshape(Bz, nc, H)

    def combine(a, b):
        d1, s1 = a
        d2, s2 = b
        return d1 * d2, s1 * d2[..., None, None] + s2

    _, sscan = jax.lax.associative_scan(
        combine, (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1)), axis=0
    )
    s_incl = sscan.swapaxes(0, 1)
    s_prev = jnp.concatenate(
        [jnp.zeros_like(s_incl[:, :1]), s_incl[:, :-1]], axis=1
    )

    Ch = jnp.broadcast_to(
        C_.reshape(Bz, nc, chunk, 1, N).astype(jnp.float32),
        (Bz, nc, chunk, H, N),
    )
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Ch * jnp.exp(cum)[..., None], s_prev)
    y = y_intra.reshape(Bz, nc, chunk, H, Pd).astype(jnp.float32) + y_inter
    y = y.reshape(Bz, S, H, Pd)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), s_incl[:, -1]
