"""Pure-jnp oracle: the chunked SSD from models/mamba.py."""
from repro.models.mamba import _ssd_chunked


def ssd_chunked(x, dt, A, B_, C_, D, *, chunk=128):
    return _ssd_chunked(x, dt, A, B_, C_, D, chunk=chunk)
