"""Pure-jnp oracle for the minhash kernel: core/minhash.py signatures."""
from __future__ import annotations

from repro.core.minhash import minhash_signatures as _sig


def minhash_signatures(types, lengths, *, num_perm: int = 16, seed: int = 0):
    return _sig(types, lengths, num_perm=num_perm, seed=seed)
