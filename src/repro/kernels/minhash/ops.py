"""Public wrapper for the minhash signature kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compat import on_tpu as _on_tpu
from repro.core.minhash import _hash_params
from repro.kernels.minhash.kernel import minhash_pallas


@functools.partial(jax.jit, static_argnames=("num_perm", "seed", "block_b"))
def minhash_signatures(
    types: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    num_perm: int = 16,
    seed: int = 0,
    block_b: int = 512,
) -> jnp.ndarray:
    """int32 [N, L] + [N] -> int32 [N, num_perm] minhash signatures."""
    N, L = types.shape
    a, b = _hash_params(num_perm, seed)
    ab = jnp.stack([a.astype(jnp.int32), b.astype(jnp.int32)], axis=1)
    pad = (-N) % block_b
    if pad:
        types = jnp.concatenate([types, jnp.zeros((pad, L), jnp.int32)])
        lengths = jnp.concatenate([lengths, jnp.zeros((pad,), jnp.int32)])
    sig = minhash_pallas(
        types, lengths, ab, block_b=block_b, interpret=not _on_tpu()
    )
    return sig[:N]
