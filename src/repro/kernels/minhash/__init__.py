from repro.kernels.minhash.ops import minhash_signatures
