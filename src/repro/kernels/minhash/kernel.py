"""Pallas TPU kernel: MinHash signatures over type-presence sets.

One pass over the [TB, L] type-code tile computes all ``num_perm``
signatures: for each permutation p, h_p(x) = (a_p * x + b_p) mod M with
M = 2^31 - 1, evaluated in int32 via 16-bit limb splitting (no int64 on
the VPU), masked to valid positions, then lane-min-reduced.  The (a, b)
parameters arrive as a [num_perm, 2] VMEM operand broadcast to every grid
step.  Output [TB, num_perm].

VMEM: TB*(L + num_perm)*4 + small — TB=512, L=16, P=16: ~70 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_M = (1 << 31) - 1
_INT_MAX = jnp.iinfo(jnp.int32).max


def _kernel(types_ref, len_ref, ab_ref, out_ref):
    x = types_ref[...]           # [TB, L] int32
    lengths = len_ref[...]       # [TB, 1]
    ab = ab_ref[...]             # [P, 2]
    tb, L = x.shape
    P = ab.shape[0]
    pos_valid = jax.lax.broadcasted_iota(jnp.int32, (tb, L), 1) < lengths

    def mod_fold(v):
        return jnp.where(v >= _M, v - _M, v)

    def one_perm(p, acc):
        a = ab[p, 0]
        b = ab[p, 1]
        a_hi, a_lo = a >> 16, a & 0xFFFF
        lo = (a_lo * x) % _M
        hi = (a_hi * x) % _M
        hi = (hi * 256) % _M
        hi = (hi * 256) % _M
        h = mod_fold(mod_fold(lo + hi) + b)
        h = jnp.where(pos_valid, h, _INT_MAX)
        return acc.at[:, p].set(jnp.min(h, axis=1))

    out = jnp.full((tb, P), _INT_MAX, jnp.int32)
    out = jax.lax.fori_loop(0, P, one_perm, out, unroll=True)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def minhash_pallas(
    types: jnp.ndarray,
    lengths: jnp.ndarray,
    ab: jnp.ndarray,
    *,
    block_b: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """types [N, L], lengths [N], ab [P, 2] -> signatures int32 [N, P]."""
    N, L = types.shape
    P = ab.shape[0]
    assert N % block_b == 0
    return pl.pallas_call(
        _kernel,
        grid=(N // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, L), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((P, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, P), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, P), jnp.int32),
        interpret=interpret,
    )(types, lengths[:, None], ab)
