"""Pallas TPU kernel: flash attention (fwd), causal + GQA.

Grid (B*Hq, Sq/blk_q, Skv/blk_k); the kv axis is innermost and TPU grids
execute sequentially, so the online-softmax state (m, l, acc) lives in VMEM
scratch carried across kv steps; the output tile is emitted on the last kv
step.  GQA is handled in the BlockSpec index maps: the kv block for query
head h is h // (Hq // Hkv) — no materialized head replication.

Block shapes: q [blk_q, D], k/v [blk_k, D] in VMEM; scores [blk_q, blk_k]
f32 in VREGs.  Defaults blk_q = blk_k = 512, D <= 256: ~1.8 MB VMEM,
MXU-aligned (multiples of 128 both dims).

This kernel removes the score-matrix HBM round-trip that dominates the
memory roofline term of every prefill/train cell in the XLA fallback
(EXPERIMENTS.md §Perf): scores never leave VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, blk_q: int, blk_k: int, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # [blk_q, D]
    k = k_ref[0]  # [blk_k, D]
    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale  # [blk_q, blk_k]

    if causal:
        qi = pl.program_id(1)
        q_pos = qi * blk_q + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 0
        )
        k_pos = ki * blk_k + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 1
        )
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _emit():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("rep", "batch", "causal", "blk_q", "blk_k", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,     # [BH, Sq, D]  (batch*q-heads leading)
    k: jnp.ndarray,     # [BKH, Skv, D]
    v: jnp.ndarray,
    *,
    rep: int,           # q-heads per kv-head (GQA)
    batch: int,         # B (to invert the bh = b*Hq + h flattening)
    causal: bool = True,
    blk_q: int = 512,
    blk_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    BH, Sq, D = q.shape
    _, Skv, _ = k.shape
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Skv)
    assert Sq % blk_q == 0 and Skv % blk_k == 0
    n_k = Skv // blk_k
    scale = 1.0 / math.sqrt(D)
    grid = (BH, Sq // blk_q, n_k)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, blk_q=blk_q, blk_k=blk_k, n_k=n_k
    )
    # bh = b*Hq + h; the kv row for query head h is b*KH + h // rep
    Hq = BH // batch
    KH = k.shape[0] // batch

    def kv_index(bh, qi, ki):
        b = bh // Hq
        h = bh % Hq
        return (b * KH + h // rep, ki, 0)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, blk_k, D), kv_index),
            pl.BlockSpec((1, blk_k, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, blk_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
