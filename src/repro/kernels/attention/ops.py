"""Public wrapper: [B,S,H,D] layout -> per-head kernel layout, interpret
fallback off-TPU, and drop-in compatibility with models.layers'
chunked_attention signature."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compat import on_tpu as _on_tpu
from repro.kernels.attention.kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k"))
def flash_attention(q, k, v, *, causal: bool = True, blk_q: int = 512,
                    blk_k: int = 512):
    """q [B,Sq,H,D], k/v [B,Skv,KH,D] -> [B,Sq,H,D]."""
    B, Sq, H, D = q.shape
    _, Skv, KH, _ = k.shape
    rep = H // KH
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KH, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KH, Skv, D)
    o = flash_attention_pallas(
        qf, kf, vf, rep=rep, batch=B, causal=causal, blk_q=blk_q,
        blk_k=blk_k, interpret=not _on_tpu(),
    )
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
