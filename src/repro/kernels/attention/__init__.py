from repro.kernels.attention.ops import flash_attention
