"""Pure-jnp oracle: full-softmax attention with GQA and causal masking."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def attention(q, k, v, *, causal: bool = True):
    """q [B,Sq,H,D], k/v [B,Skv,KH,D] -> [B,Sq,H,D] (f32 softmax)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    rep = H // KH
    qg = q.reshape(B, Sq, KH, rep, D).astype(jnp.float32)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)
