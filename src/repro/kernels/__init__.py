"""Pallas TPU kernels for the compute hot spots.

Each kernel package ships three files:
  kernel.py — pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (padding, interpret fallback on CPU)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Paper hot spots (section IV): lcs (phase iii similarity DP), shingle
(phase ii, the O(N*L^3) hash), minhash (the Spark-builtin baseline).
Model-plane hot spots: attention (flash, GQA/causal), ssd (Mamba-2 chunk
scan) for the assigned architectures.
"""
