"""Model assembly: parameter specs, init, forward, loss for all 10 archs.

Parameters are plain nested dicts; per-layer tensors are stacked on a
leading [L] axis and consumed by one ``lax.scan`` (rematerialized per layer)
so the HLO stays compact at 80 layers and the dry-run compiles fast.

Every leaf is declared once as a ``PS(shape, axes, init)`` spec; the same
tree generates (a) ShapeDtypeStructs for the dry-run, (b) NamedShardings via
the divisibility-aware resolver, (c) real initialized arrays for the smoke
tests and the 100M-scale training example.

Vocab padding: embedding/lm_head vocab dims are padded to a multiple of 512
when sharded (Megatron convention) — granite's 49155, minicpm3's 73448 and
mamba2's 50280 are not divisible by the 16-way model axis.  Padded logits
are masked with -1e30 before the softmax so the loss is exact.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import attention_block
from repro.models.mamba import mamba_block
from repro.models.moe import moe_block

FSDP = "data"      # parameter/optimizer sharding axis (ZeRO-3 style)
TP = "model"       # tensor-parallel axis
AUX_LOSS_COEF = 0.01
VOCAB_PAD = 512


@dataclasses.dataclass(frozen=True)
class PS:
    """Parameter spec: shape + partition axes + init recipe."""
    shape: tuple
    axes: tuple
    init: str = "normal"
    scale: float = 0.02


def padded_vocab(cfg: ModelConfig) -> int:
    if cfg.vocab_size < 8192:
        return cfg.vocab_size  # tiny head (hubert): replicated, no padding
    return -(-cfg.vocab_size // VOCAB_PAD) * VOCAB_PAD


# ---------------------------------------------------------------------------
# spec construction
# ---------------------------------------------------------------------------
def _attn_specs(cfg: ModelConfig, nl: int) -> dict:
    d = cfg.d_model
    if cfg.attn == "mla":
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        H = cfg.num_heads
        s: dict[str, PS] = {
            "wkv_a": PS((nl, d, cfg.kv_lora_rank + dr), (None, FSDP, None)),
            "kv_norm": PS((nl, cfg.kv_lora_rank), (None, None), "zeros"),
            "wk_b": PS((nl, cfg.kv_lora_rank, H * dn), (None, FSDP, TP)),
            "wv_b": PS((nl, cfg.kv_lora_rank, H * dv), (None, FSDP, TP)),
            "wo": PS((nl, H * dv, d), (None, TP, FSDP), scale=0.02 / math.sqrt(2 * cfg.num_layers)),
        }
        if cfg.q_lora_rank:
            s["wq_a"] = PS((nl, d, cfg.q_lora_rank), (None, FSDP, None))
            s["q_norm"] = PS((nl, cfg.q_lora_rank), (None, None), "zeros")
            s["wq_b"] = PS((nl, cfg.q_lora_rank, H * (dn + dr)), (None, FSDP, TP))
        else:
            s["wq"] = PS((nl, d, H * (dn + dr)), (None, FSDP, TP))
        return s
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    wo = PS((nl, H * hd, d), (None, TP, FSDP),
            scale=0.02 / math.sqrt(2 * cfg.num_layers))
    if cfg.fused_qkv:
        s = {
            "wqkv": PS((nl, d, (H + 2 * KH) * hd), (None, FSDP, TP)),
            "wo": wo,
        }
        if cfg.qkv_bias:
            s["bqkv"] = PS((nl, (H + 2 * KH) * hd), (None, TP), "zeros")
        return s
    s = {
        "wq": PS((nl, d, H * hd), (None, FSDP, TP)),
        "wk": PS((nl, d, KH * hd), (None, FSDP, TP)),
        "wv": PS((nl, d, KH * hd), (None, FSDP, TP)),
        "wo": wo,
    }
    if cfg.qkv_bias:
        s["bq"] = PS((nl, H * hd), (None, TP), "zeros")
        s["bk"] = PS((nl, KH * hd), (None, TP), "zeros")
        s["bv"] = PS((nl, KH * hd), (None, TP), "zeros")
    return s


def _mlp_specs(d: int, ff: int, nl: int, cfg: ModelConfig) -> dict:
    down_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
    if cfg.fused_gate_up:
        return {
            "w_gateup": PS((nl, d, 2, ff), (None, FSDP, None, TP)),
            "w_down": PS((nl, ff, d), (None, TP, FSDP), scale=down_scale),
        }
    return {
        "w_gate": PS((nl, d, ff), (None, FSDP, TP)),
        "w_up": PS((nl, d, ff), (None, FSDP, TP)),
        "w_down": PS((nl, ff, d), (None, TP, FSDP), scale=down_scale),
    }


def _moe_specs(cfg: ModelConfig, nl: int) -> dict:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    down_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
    s = {
        "router": PS((nl, d, E), (None, FSDP, None)),
        "w_gate": PS((nl, E, d, f), (None, TP, FSDP, None)),
        "w_up": PS((nl, E, d, f), (None, TP, FSDP, None)),
        "w_down": PS((nl, E, f, d), (None, TP, None, FSDP), scale=down_scale),
    }
    if cfg.num_shared_experts:
        sf = f * cfg.num_shared_experts
        if cfg.fused_gate_up:
            s["shared_w_gateup"] = PS((nl, d, 2, sf), (None, FSDP, None, TP))
        else:
            s["shared_w_gate"] = PS((nl, d, sf), (None, FSDP, TP))
            s["shared_w_up"] = PS((nl, d, sf), (None, FSDP, TP))
        s["shared_w_down"] = PS((nl, sf, d), (None, TP, FSDP), scale=down_scale)
    return s


def _mamba_specs(cfg: ModelConfig, nl: int) -> dict:
    d, din = cfg.d_model, cfg.ssm_d_inner
    H, G, N, W = cfg.ssm_heads, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    return {
        "w_zx": PS((nl, d, 2 * din), (None, FSDP, TP)),
        "w_bc": PS((nl, d, 2 * G * N), (None, FSDP, None)),
        "w_dt": PS((nl, d, H), (None, FSDP, TP)),
        "dt_bias": PS((nl, H), (None, TP), "dt_bias"),
        "A_log": PS((nl, H), (None, TP), "A_log"),
        "D": PS((nl, H), (None, TP), "ones_raw"),
        "conv_x": PS((nl, W, din), (None, None, TP), scale=0.2),
        "conv_bc": PS((nl, W, 2 * G * N), (None, None, None), scale=0.2),
        "norm": PS((nl, din), (None, TP), "zeros"),
        "w_out": PS((nl, din, d), (None, TP, FSDP),
                    scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def build_param_specs(cfg: ModelConfig) -> dict:
    d, nl = cfg.d_model, cfg.num_layers
    vp = padded_vocab(cfg)
    specs: dict[str, Any] = {}
    if cfg.frontend != "audio":
        specs["embed"] = PS((vp, d), (TP, FSDP), scale=1.0)
    blocks: dict[str, Any] = {"ln1": PS((nl, d), (None, None), "zeros")}
    if cfg.family in ("ssm",):
        blocks["mamba"] = _mamba_specs(cfg, nl)
    elif cfg.family == "hybrid":
        blocks["mamba"] = _mamba_specs(cfg, nl)
        specs["shared"] = {
            "ln1": PS((d,), (None,), "zeros"),
            "attn": {k: PS(v.shape[1:], v.axes[1:], v.init, v.scale)
                     for k, v in _attn_specs(cfg, 1).items()},
            "ln2": PS((d,), (None,), "zeros"),
            "mlp": {k: PS(v.shape[1:], v.axes[1:], v.init, v.scale)
                    for k, v in _mlp_specs(d, cfg.d_ff, 1, cfg).items()},
        }
        # strip the leading stacked dim the helpers added
        for grp in ("attn", "mlp"):
            specs["shared"][grp] = {
                k: PS(v.shape, v.axes, v.init, v.scale)
                for k, v in specs["shared"][grp].items()
            }
    else:
        blocks["attn"] = _attn_specs(cfg, nl)
        blocks["ln2"] = PS((nl, d), (None, None), "zeros")
        if cfg.family == "moe":
            blocks["moe"] = _moe_specs(cfg, nl)
        else:
            blocks["mlp"] = _mlp_specs(d, cfg.d_ff, nl, cfg)
    specs["blocks"] = blocks
    specs["final_norm"] = PS((d,), (None,), "zeros")
    specs["lm_head"] = PS((d, vp), (FSDP, TP))
    return specs


# --- helpers stripping the stacked dim for the hybrid's shared block -------
def _unstack(spec: PS) -> PS:
    return PS(spec.shape[1:], spec.axes[1:], spec.init, spec.scale)


# fix the hybrid shared specs built above (leading (1, ...) from helpers)
def _fix_shared(specs: dict, cfg: ModelConfig):
    if "shared" not in specs:
        return specs
    sh = specs["shared"]
    sh["attn"] = {k: _unstack(v) if v.shape[0] == 1 else v for k, v in sh["attn"].items()}
    sh["mlp"] = {k: _unstack(v) if v.shape[0] == 1 else v for k, v in sh["mlp"].items()}
    return specs


# ---------------------------------------------------------------------------
# spec consumers
# ---------------------------------------------------------------------------
def param_shape_structs(cfg: ModelConfig, dtype=jnp.bfloat16):
    specs = _fix_shared(build_param_specs(cfg), cfg)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, _leaf_dtype(s, dtype)),
        specs, is_leaf=lambda x: isinstance(x, PS),
    )


def _leaf_dtype(s: PS, dtype):
    # SSD dynamics + norms stay f32 for numerical safety
    return jnp.float32 if s.init in ("A_log", "dt_bias", "ones_raw", "zeros") else dtype


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    specs = _fix_shared(build_param_specs(cfg), cfg)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, L.resolve_spec(mesh, s.shape, s.axes)),
        specs, is_leaf=lambda x: isinstance(x, PS),
    )


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    specs = _fix_shared(build_param_specs(cfg), cfg)
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, PS)
    )
    keys = jax.random.split(key, len(leaves))

    def init_one(s: PS, k):
        dt = _leaf_dtype(s, dtype)
        if s.init == "zeros":
            return jnp.zeros(s.shape, dt)
        if s.init == "ones_raw":
            return jnp.ones(s.shape, dt)
        if s.init == "A_log":
            u = jax.random.uniform(k, s.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dt)
        if s.init == "dt_bias":
            u = jax.random.uniform(k, s.shape, jnp.float32, 1e-3, 1e-1)
            return (u + jnp.log(-jnp.expm1(-u))).astype(dt)  # softplus^-1
        return L.normal_init(k, s.shape, dt, s.scale)

    return jax.tree.unflatten(treedef, [init_one(s, k) for s, k in zip(leaves, keys)])


def param_count(cfg: ModelConfig) -> int:
    specs = _fix_shared(build_param_specs(cfg), cfg)
    return sum(
        int(np.prod(s.shape))
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, PS))
    )


def active_param_count(cfg: ModelConfig) -> int:
    """Per-token active parameters (MoE: top-k + shared experts only)."""
    total = param_count(cfg)
    if not cfg.num_experts:
        return total
    E, k = cfg.num_experts, cfg.experts_per_token
    expert_p = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_layers
    return total - (E - k) * expert_p // 1  # routed experts not hit


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _apply_shared_block(x, sp, cfg, mesh, positions):
    h = L.rmsnorm(x, sp["ln1"], cfg.norm_eps)
    x = x + attention_block(h, sp["attn"], cfg, mesh, positions)
    h = L.rmsnorm(x, sp["ln2"], cfg.norm_eps)
    x = x + L.swiglu_mlp(
        h, sp["mlp"], mesh=mesh, dp=L.dp_axes(mesh) if mesh else ("data",),
    )
    return x


def _block_body(cfg: ModelConfig, mesh, shared_params=None):
    """fn(carry=(x, aux), layer/group params) -> (carry, None).

    For the hybrid family the scanned unit is a GROUP of ``every`` mamba
    layers followed by one shared attention+MLP block — no lax.cond in the
    hot path, and the scanned unit is homogeneous (compact HLO, exact
    cost extrapolation).
    """

    def body(carry, lp):
        x, aux = carry
        B, S, _ = x.shape
        positions = jnp.arange(S, dtype=jnp.int32)
        if cfg.family == "hybrid":
            every = cfg.shared_attn_every
            for j in range(every):
                ljp = jax.tree.map(lambda a: a[j], lp)
                h = L.rmsnorm(x, ljp["ln1"], cfg.norm_eps)
                x = x + mamba_block(h, ljp["mamba"], cfg, mesh)
            x = _apply_shared_block(x, shared_params, cfg, mesh, positions)
        elif cfg.family == "ssm":
            h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            x = x + mamba_block(h, lp["mamba"], cfg, mesh)
        else:
            h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            x = x + attention_block(h, lp["attn"], cfg, mesh, positions)
            h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                mo, a = moe_block(h, lp["moe"], cfg, mesh)
                x = x + mo
                aux = aux + a
            else:
                x = x + L.swiglu_mlp(
                    h, lp["mlp"], mesh=mesh,
                    dp=L.dp_axes(mesh) if mesh else ("data",),
                )
        return (x, aux), None

    return body


def _group_blocks(cfg: ModelConfig, blocks):
    """Hybrid: restack [L, ...] block params as [G, every, ...]."""
    if cfg.family != "hybrid":
        return blocks
    every = cfg.shared_attn_every
    g = cfg.num_layers // every
    return jax.tree.map(
        lambda a: a.reshape((g, every) + a.shape[1:]), blocks
    )


def forward(params, inputs: dict, cfg: ModelConfig, mesh: Mesh | None,
            *, last_only: bool = False, unroll: bool = False):
    """-> (logits [B, S, V_pad] (f32), aux_loss scalar).

    ``last_only`` computes logits for the final position only — the
    serving-prefill shape (the lm_head matmul over all 32k positions would
    otherwise dominate prefill cost and memory).
    ``unroll`` replaces the layer scan with a python loop; used by the
    dry-run cost probes (XLA's cost_analysis counts a while-loop body once,
    so exact totals need unrolled shallow lowers; see launch/dryrun.py).
    """
    dp = L.dp_axes(mesh) if mesh is not None else ("data",)
    if cfg.frontend == "audio":
        x = inputs["features"].astype(L.COMPUTE_DTYPE)
    else:
        tokens = inputs["tokens"]
        emb = params["embed"]
        x = emb.astype(L.COMPUTE_DTYPE)[tokens]
        if cfg.frontend == "vision":
            vis = inputs["vis_embed"].astype(L.COMPUTE_DTYPE)
            x = jnp.concatenate([vis, x], axis=1)
    x = L.shard(x, mesh, dp, None, None)

    shared = params.get("shared")
    import os
    policy = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[os.environ.get("REPRO_REMAT", "nothing")]
    body = jax.checkpoint(_block_body(cfg, mesh, shared), policy=policy)
    blocks = _group_blocks(cfg, params["blocks"])
    carry = (x, jnp.zeros((), jnp.float32))
    if unroll:
        n = jax.tree.leaves(blocks)[0].shape[0]
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], blocks)
            carry, _ = body(carry, lp)
        x, aux = carry
    else:
        (x, aux), _ = jax.lax.scan(body, carry, blocks)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:, :]
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["lm_head"].astype(x.dtype)
    ).astype(jnp.float32)
    logits = L.shard(logits, mesh, dp, None, TP)
    vp = padded_vocab(cfg)
    if vp != cfg.vocab_size:
        mask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(mask[None, None, :], logits, -1e30)
    return logits, aux


def loss_fn(params, inputs: dict, cfg: ModelConfig, mesh: Mesh | None,
            *, unroll: bool = False):
    """Mean CE over labels >= 0 (+ MoE aux).  Returns (loss, metrics)."""
    logits, aux = forward(params, inputs, cfg, mesh, unroll=unroll)
    labels = inputs["labels"]
    if cfg.frontend == "vision":
        pad = jnp.full(
            (labels.shape[0], cfg.vis_tokens), -1, labels.dtype
        )
        labels = jnp.concatenate([pad, labels], axis=1)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = jnp.where(mask, lse - gold, 0.0)
    ntok = jnp.maximum(mask.sum(), 1)
    loss = ce.sum() / ntok
    total = loss + AUX_LOSS_COEF * aux
    return total, {"ce": loss, "aux": aux, "ntok": ntok}
