"""Attention blocks: GQA (Llama/Qwen/Granite style) and MLA (DeepSeek-V2 /
MiniCPM3 style), training/prefill paths.

Sharding: all projections are Megatron column->row pairs — the flattened
head*dim output dimension is sharded over 'model' (this stays divisible even
when the head COUNT is not, e.g. MiniCPM3's 40 heads on a 16-way axis), the
output projection contracts it back, and XLA inserts exactly one all-reduce
per attention block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import chunked_attention, dp_axes, rope, shard


def _qkv_proj(x, p, cfg: ModelConfig):
    """q/k/v projections, fused (one matmul, one bwd dx psum) or split."""
    H, KH, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if "wqkv" in p:
        qkv = jnp.einsum("bsd,dh->bsh", x, p["wqkv"].astype(x.dtype))
        if cfg.qkv_bias:
            qkv = qkv + p["bqkv"].astype(x.dtype)
        return jnp.split(qkv, [H * D, (H + KH) * D], axis=-1)
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def gqa_attention(x, p, cfg: ModelConfig, mesh, positions):
    """x [B,S,d] -> [B,S,d].  p: wqkv|wq,wk,wv + wo (+biases)."""
    B, S, _ = x.shape
    H, KH, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dp = dp_axes(mesh) if mesh is not None else ("data",)

    q, k, v = _qkv_proj(x, p, cfg)
    q = shard(q, mesh, dp, None, "model").reshape(B, S, H, D)
    k = shard(k, mesh, dp, None, "model").reshape(B, S, KH, D)
    v = shard(v, mesh, dp, None, "model").reshape(B, S, KH, D)

    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    out = chunked_attention(q, k, v, causal=cfg.causal)
    out = out.reshape(B, S, H * D)
    out = shard(out, mesh, dp, None, "model")
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))


def mla_attention(x, p, cfg: ModelConfig, mesh, positions):
    """Multi-head Latent Attention (DeepSeek-V2 eq. 1-11), training path.

    KV is compressed to a rank-``kv_lora_rank`` latent c_kv plus one shared
    RoPE key head; during decode only (c_kv, k_rope) is cached — the paper's
    93% KV-cache reduction (see serve/kvcache.py).
    """
    from repro.models.layers import rmsnorm

    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dp = dp_axes(mesh) if mesh is not None else ("data",)

    # --- queries (optionally through a low-rank bottleneck) ---------------
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype))
        cq = rmsnorm(cq, p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rh->bsh", cq, p["wq_b"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    q = shard(q, mesh, dp, None, "model").reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    # --- compressed KV latent + shared rope key ---------------------------
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    c_kv, k_rope = ckv[..., : cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank :]
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # [B,S,1,dr]

    k_nope = jnp.einsum("bsr,rh->bsh", c_kv, p["wk_b"].astype(x.dtype))
    k_nope = shard(k_nope, mesh, dp, None, "model").reshape(B, S, H, dn)
    v = jnp.einsum("bsr,rh->bsh", c_kv, p["wv_b"].astype(x.dtype))
    v = shard(v, mesh, dp, None, "model").reshape(B, S, H, dv)

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1
    )
    # pad v up to qk head dim so the flash core sees one uniform D, then
    # slice back (cheap relative to attention itself)
    if dv < dn + dr:
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
    else:
        v_pad = v
    out = chunked_attention(q_full, k_full, v_pad, causal=cfg.causal)
    out = out[..., :dv].reshape(B, S, H * dv)
    out = shard(out, mesh, dp, None, "model")
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))


def attention_block(x, p, cfg: ModelConfig, mesh, positions):
    if cfg.attn == "mla":
        return mla_attention(x, p, cfg, mesh, positions)
    return gqa_attention(x, p, cfg, mesh, positions)


# ---------------------------------------------------------------------------
# decode paths (one new token against a cache)
# ---------------------------------------------------------------------------
def gqa_decode(x, p, cfg: ModelConfig, k_cache, v_cache, pos):
    """x [B,1,d]; k/v_cache [B,Smax,KH,hd]; pos scalar.
    Returns (out [B,1,d], new k_cache, new v_cache).

    The cache's Smax dim is sequence-sharded over 'model' (kvcache.py); the
    contraction over it makes XLA emit the split-K partial-softmax combine.
    """
    B = x.shape[0]
    H, KH, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    posv = jnp.full((B, 1), pos, jnp.int32)

    q, k, v = _qkv_proj(x[:, :1], p, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    q = rope(q.reshape(B, 1, H, D), posv, cfg.rope_theta)
    k = rope(k.reshape(B, 1, KH, D), posv, cfg.rope_theta)
    v = v.reshape(B, 1, KH, D)

    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))

    rep = H // KH
    qg = q.reshape(B, KH, rep, D)
    s = jnp.einsum("bhrd,bshd->bhrs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / jnp.sqrt(float(D))
    mask = jnp.arange(k_cache.shape[1]) <= pos
    s = jnp.where(mask[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrs,bshd->bhrd", w, v_cache.astype(jnp.float32))
    o = o.reshape(B, 1, H * D).astype(x.dtype)
    return o @ p["wo"].astype(x.dtype), k_cache, v_cache


def mla_decode(x, p, cfg: ModelConfig, ckv_cache, krope_cache, pos):
    """MLA decode with matrix absorption (DeepSeek-V2 appendix): scores are
    computed directly against the cached latent c_kv — W_uk is absorbed into
    the query and W_uv into the output, so the per-step FLOPs and the cache
    bytes both scale with kv_lora_rank instead of H*hd.

    x [B,1,d]; ckv_cache [B,Smax,r]; krope_cache [B,Smax,dr].
    """
    from repro.models.layers import rmsnorm

    B = x.shape[0]
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    posv = jnp.full((B, 1), pos, jnp.int32)
    xt = x[:, 0]

    if cfg.q_lora_rank:
        cq = rmsnorm(xt @ p["wq_a"].astype(x.dtype), p["q_norm"], cfg.norm_eps)
        q = cq @ p["wq_b"].astype(x.dtype)
    else:
        q = xt @ p["wq"].astype(x.dtype)
    q = q.reshape(B, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope[:, None], posv, cfg.rope_theta)[:, 0]

    ckv = xt @ p["wkv_a"].astype(x.dtype)
    c_new = rmsnorm(ckv[..., :r], p["kv_norm"], cfg.norm_eps)
    kr_new = rope(ckv[..., r:][:, None, None, :], posv, cfg.rope_theta)[:, :, 0]

    ckv_cache = jax.lax.dynamic_update_slice(
        ckv_cache, c_new[:, None, :], (0, pos, 0)
    )
    krope_cache = jax.lax.dynamic_update_slice(krope_cache, kr_new, (0, pos, 0))

    wk_b = p["wk_b"].astype(jnp.float32).reshape(r, H, dn)
    wv_b = p["wv_b"].astype(jnp.float32).reshape(r, H, dv)
    # absorb W_uk into q:  [B,H,r]
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32), wk_b)
    s = jnp.einsum("bhr,bsr->bhs", q_c, ckv_cache.astype(jnp.float32))
    s = s + jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32),
                       krope_cache.astype(jnp.float32))
    s = s / jnp.sqrt(float(dn + dr))
    mask = jnp.arange(ckv_cache.shape[1]) <= pos
    s = jnp.where(mask[None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhs,bsr->bhr", w, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bhr,rhd->bhd", o_c, wv_b).reshape(B, 1, H * dv)
    return o.astype(x.dtype) @ p["wo"].astype(x.dtype), ckv_cache, krope_cache
