"""Mixture-of-Experts FFN with explicit expert parallelism via shard_map.

Collective schedule (DESIGN.md section 5): activations enter replicated over
'model' (they are, after the attention all-reduce); every model-rank owns
E/tp experts and FSDP-gathers their weights over 'data' at the shard_map
boundary; routing/top-k is computed redundantly (deterministic) on every
rank; each rank sort-dispatches only the assignments that hit ITS experts
into a capacity-bounded [E_local, C, d] buffer, runs the grouped SwiGLU
GEMMs, scatter-adds gated outputs back to token slots, and ONE psum over
'model' combines the top-k partial sums.  No all_to_all, no partitioner
surprises — the dry-run HLO shows exactly L all-reduces for L MoE layers.

Token dropping: capacity C = ceil(T*k/E * capacity_factor); dropped
assignments simply contribute nothing (their gate weight is lost), standard
GShard-style behaviour.  Aux load-balance loss is returned for training.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import dp_axes


def _local_dispatch_compute(x_flat, router_w, w_gate, w_up, w_down, *,
                            cfg: ModelConfig, tp: int, my_rank):
    """Per-rank MoE math. x_flat [T, d] (model-replicated local tokens);
    w_* [E_loc, d|f, f|d] local expert weights."""
    T, d = x_flat.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    E_loc = E // tp
    capacity = int(T * K / E * cfg.capacity_factor) + 1

    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # aux load-balance loss (Switch eq. 4), computed on full routing
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # flatten assignments, keep only local experts
    flat_e = expert_idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = gate_vals.reshape(-1)
    local = (flat_e >= my_rank * E_loc) & (flat_e < (my_rank + 1) * E_loc)
    e_loc = jnp.where(local, flat_e - my_rank * E_loc, E_loc)  # E_loc = drop

    # rank within expert via sort (stable) + run-rank
    order = jnp.argsort(e_loc, stable=True)
    e_sorted = e_loc[order]
    idx = jnp.arange(e_sorted.shape[0], dtype=jnp.int32)
    start = jnp.concatenate(
        [jnp.ones((1,), bool), e_sorted[1:] != e_sorted[:-1]]
    )
    run_start = jax.lax.cummax(jnp.where(start, idx, -1))
    pos = idx - run_start
    ok = (e_sorted < E_loc) & (pos < capacity)
    slot = jnp.where(ok, e_sorted * capacity + pos, E_loc * capacity)

    # gather tokens into the capacity buffer [E_loc*C, d]
    buf = jnp.zeros((E_loc * capacity + 1, d), x_flat.dtype)
    buf = buf.at[slot].set(x_flat[flat_t[order]], mode="drop")
    buf = buf[:-1].reshape(E_loc, capacity, d)

    # grouped SwiGLU
    h = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(buf.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, w_down.astype(buf.dtype))

    # scatter-add gated outputs back to tokens
    y_flat = y.reshape(E_loc * capacity, d)
    out = jnp.zeros((T, d), jnp.float32)
    contrib = jnp.where(ok[:, None], y_flat[jnp.where(ok, slot, 0)], 0.0)
    out = out.at[flat_t[order]].add(
        contrib.astype(jnp.float32) * flat_g[order][:, None], mode="drop"
    )
    return out.astype(x_flat.dtype), aux


def moe_block(x, p, cfg: ModelConfig, mesh: Mesh):
    """x [B,S,d] -> ([B,S,d], aux_loss).  p: router [d,E], w_gate/w_up
    [E,d,f], w_down [E,f,d] (+ shared expert SwiGLU if configured)."""
    B, S, d = x.shape
    dp = dp_axes(mesh)
    tp = mesh.shape["model"]

    def shard_fn(xl, router_w, w_gate, w_up, w_down):
        my_rank = jax.lax.axis_index("model")
        T = xl.shape[0] * xl.shape[1]
        out, aux = _local_dispatch_compute(
            xl.reshape(T, d), router_w, w_gate, w_up, w_down,
            cfg=cfg, tp=tp, my_rank=my_rank,
        )
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, "model")
        return out.reshape(xl.shape), aux

    from repro.core import compat

    fn = compat.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            P(dp, None, None), P(None, None),
            P("model", None, None), P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(dp, None, None), P()),
    )
    out, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if cfg.num_shared_experts:
        from repro.models.layers import swiglu_mlp

        out = out + swiglu_mlp(x, p, mesh=mesh, dp=dp, prefix="shared_")
    return out, aux
