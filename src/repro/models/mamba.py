"""Mamba-2 (SSD, state-space duality) mixer — chunked training path +
single-token decode recurrence [arXiv:2405.21060].

The SSD chunked algorithm: split the sequence into chunks of Q tokens;
within a chunk the recurrence is computed as a (quadratic-in-Q) masked
attention-like einsum; across chunks only the [H, P, N] states flow through
an associative scan — O(S*Q) work, O(S/Q) scan depth, MXU-friendly einsums
throughout.  This is the TPU-native formulation (the CUDA kernel's
split-scan maps onto lax.associative_scan + batched GEMMs).

Sharding: d_inner (and so SSD heads) over 'model'; B/C (group) projections
are tiny and stay replicated; the inter-chunk scan carries [B, H_loc, P, N]
states with no cross-device communication at all — the mixer needs exactly
one psum (from the out_proj row-parallel matmul).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dp_axes, rmsnorm, shard

CHUNK = 128


def _ssd_chunked(x, dt, A, B_, C_, D, *, chunk=CHUNK, bf16_intra=False):
    """SSD core. x [B,S,H,P], dt [B,S,H] (>0), A [H] (<0), B_/C_ [B,S,G,N],
    D [H] -> y [B,S,H,P].

    ``chunk`` trades intra-chunk quadratic bytes (prop. to S*chunk) against
    scan depth; ``bf16_intra`` keeps the decay/score matrices in bf16 (the
    log-cumsum stays f32) — §Perf iterations on the SSM cells."""
    Bz, S, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    assert G == 1, "assigned SSM archs use a single B/C group"
    chunk = min(chunk, S)
    nc = S // chunk
    rep = H // G
    mm_dt = jnp.bfloat16 if bf16_intra else jnp.float32

    xc = x.reshape(Bz, nc, chunk, H, Pd)
    dtc = dt.reshape(Bz, nc, chunk, H)
    Bc = B_.reshape(Bz, nc, chunk, G, N)
    Cc = C_.reshape(Bz, nc, chunk, G, N)

    dA = dtc * A  # [B,nc,Q,H] negative
    cum = jnp.cumsum(dA, axis=2)  # inclusive within-chunk log decay

    # intra-chunk: y[i] = sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i . B_j) x_j
    # B/C are per-GROUP — scores are computed once per group and the head
    # dimension enters only through the decay, so nothing [.., H, N]-shaped
    # is ever materialized (§Perf zamba2/v4: the jnp.repeat over H in f32
    # was the dominant HBM term, ~2x the Q^2 matrices themselves)
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", Cc.astype(mm_dt),
                        Bc.astype(mm_dt))
    scores = jnp.repeat(scores, rep, axis=2)  # [B,nc,H,Q,Q] (group->head)
    decay = jnp.exp(
        cum.transpose(0, 1, 3, 2)[..., :, None]
        - cum.transpose(0, 1, 3, 2)[..., None, :]
    ).astype(mm_dt)  # [B,nc,H,Q,Q]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.where(tri, scores * decay, jnp.zeros((), mm_dt)) \
        * dtc.transpose(0, 1, 3, 2)[..., None, :].astype(mm_dt)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", M, xc.astype(mm_dt),
                         preferred_element_type=jnp.float32)

    # chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    w = (jnp.exp(cum[:, :, -1:, :] - cum) * dtc).astype(mm_dt)  # [B,nc,Q,H]
    states = jnp.einsum("bckn,bckh,bckhp->bchpn",
                        Bc[..., 0, :].astype(mm_dt), w, xc.astype(mm_dt),
                        preferred_element_type=jnp.float32)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B,nc,H]

    # inter-chunk associative scan, then shift to exclusive (state BEFORE c)
    def combine(a, b):
        d1, s1 = a
        d2, s2 = b
        return d1 * d2, s1 * d2[..., None, None] + s2

    dscan, sscan = jax.lax.associative_scan(
        combine, (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1)), axis=0
    )
    s_incl = sscan.swapaxes(0, 1)  # [B,nc,H,P,N] state AFTER chunk c
    s_prev = jnp.concatenate(
        [jnp.zeros_like(s_incl[:, :1]), s_incl[:, :-1]], axis=1
    )

    y_inter = jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp",
        Cc[..., 0, :].astype(jnp.float32), jnp.exp(cum), s_prev,
    )
    y = (y_intra + y_inter).reshape(Bz, S, H, Pd)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), s_incl[:, -1]  # final state for serving


def mamba_block(x, p, cfg: ModelConfig, mesh):
    """Full Mamba-2 mixer: in-proj (z,x,B,C,dt) -> causal depthwise conv ->
    SSD -> gated RMSNorm -> out-proj.  x [B,S,d] -> [B,S,d]."""
    Bz, S, d = x.shape
    din = cfg.ssm_d_inner
    H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    dp = dp_axes(mesh) if mesh is not None else ("data",)

    zx = jnp.einsum("bsd,de->bse", x, p["w_zx"].astype(x.dtype))
    zx = shard(zx, mesh, dp, None, "model")
    z, xin = zx[..., :din], zx[..., din:]
    bc = jnp.einsum("bsd,de->bse", x, p["w_bc"].astype(x.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(x.dtype))

    # causal depthwise conv (width W) on x / B / C channels
    def causal_conv(u, w):  # u [B,S,C], w [W,C]
        W = w.shape[0]
        u_pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
        out = sum(
            u_pad[:, i : i + S, :] * w[i][None, None, :] for i in range(W)
        )
        return out

    xin = jax.nn.silu(
        causal_conv(xin, p["conv_x"].astype(x.dtype)).astype(jnp.float32)
    ).astype(x.dtype)
    bc = jax.nn.silu(
        causal_conv(bc, p["conv_bc"].astype(x.dtype)).astype(jnp.float32)
    ).astype(x.dtype)
    B_ = bc[..., : G * N].reshape(Bz, S, G, N)
    C_ = bc[..., G * N :].reshape(Bz, S, G, N)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, _ = _ssd_chunked(
        xin.reshape(Bz, S, H, Pd), dt, A, B_, C_, p["D"].astype(jnp.float32),
        chunk=cfg.ssm_chunk, bf16_intra=cfg.ssm_bf16_intra,
    )
    y = y.reshape(Bz, S, din)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    y = shard(y, mesh, dp, None, "model")
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))


def mamba_prefill(x, p, cfg: ModelConfig, mesh):
    """Like mamba_block but also returns the serving state after the prompt:
    conv histories (last W-1 pre-conv channel inputs) + final SSM state."""
    Bz, S, d = x.shape
    din = cfg.ssm_d_inner
    H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    W = cfg.ssm_conv
    dp = dp_axes(mesh) if mesh is not None else ("data",)

    zx = jnp.einsum("bsd,de->bse", x, p["w_zx"].astype(x.dtype))
    z, xin_raw = zx[..., :din], zx[..., din:]
    bc_raw = jnp.einsum("bsd,de->bse", x, p["w_bc"].astype(x.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(x.dtype))

    def causal_conv(u, w):
        Wd = w.shape[0]
        u_pad = jnp.pad(u, ((0, 0), (Wd - 1, 0), (0, 0)))
        return sum(u_pad[:, i : i + S, :] * w[i][None, None, :] for i in range(Wd))

    xin = jax.nn.silu(
        causal_conv(xin_raw, p["conv_x"].astype(x.dtype)).astype(jnp.float32)
    ).astype(x.dtype)
    bc = jax.nn.silu(
        causal_conv(bc_raw, p["conv_bc"].astype(x.dtype)).astype(jnp.float32)
    ).astype(x.dtype)
    B_ = bc[..., : G * N].reshape(Bz, S, G, N)
    C_ = bc[..., G * N :].reshape(Bz, S, G, N)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final_state = _ssd_chunked(
        xin.reshape(Bz, S, H, Pd), dt, A, B_, C_, p["D"].astype(jnp.float32),
        chunk=cfg.ssm_chunk, bf16_intra=cfg.ssm_bf16_intra,
    )
    y = y.reshape(Bz, S, din)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))

    # conv histories: last W-1 raw (pre-conv) inputs, left-padded for S<W-1
    def hist(u):
        u_pad = jnp.pad(u, ((0, 0), (max(0, W - 1 - S), 0), (0, 0)))
        return u_pad[:, -(W - 1) :, :]

    state = {
        "conv_x": hist(xin_raw),
        "conv_bc": hist(bc_raw),
        "ssm": final_state,  # [B, H, P, N] from _ssd_chunked
    }
    return out, state


def mamba_decode_step(x, state, p, cfg: ModelConfig):
    """Single-token recurrence.  x [B,1,d]; state dict with
    conv_x [B,W-1,din], conv_bc [B,W-1,2GN], ssm [B,H,P,N] (f32).
    Returns (y [B,1,d], new state)."""
    Bz = x.shape[0]
    din = cfg.ssm_d_inner
    H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    W = cfg.ssm_conv
    xt = x[:, 0, :]

    zx = xt @ p["w_zx"].astype(xt.dtype)
    z, xin = zx[..., :din], zx[..., din:]
    bc = xt @ p["w_bc"].astype(xt.dtype)
    dt_raw = xt @ p["w_dt"].astype(xt.dtype)

    def conv_step(u, hist, w):  # u [B,C], hist [B,W-1,C], w [W,C]
        full = jnp.concatenate([hist, u[:, None, :]], axis=1)  # [B,W,C]
        out = jnp.einsum("bwc,wc->bc", full, w)
        return out, full[:, 1:, :]

    xin_c, conv_x_new = conv_step(
        xin, state["conv_x"], p["conv_x"].astype(xt.dtype)
    )
    bc_c, conv_bc_new = conv_step(
        bc, state["conv_bc"], p["conv_bc"].astype(xt.dtype)
    )
    xin_c = jax.nn.silu(xin_c.astype(jnp.float32))
    bc_c = jax.nn.silu(bc_c.astype(jnp.float32))
    B_ = jnp.repeat(bc_c[..., : G * N].reshape(Bz, G, N), H // G, axis=1)
    C_ = jnp.repeat(bc_c[..., G * N :].reshape(Bz, G, N), H // G, axis=1)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)  # [B,H]

    xh = xin_c.reshape(Bz, H, Pd)
    ssm = state["ssm"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, B_
    )
    y = jnp.einsum("bhpn,bhn->bhp", ssm, C_)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bz, din)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    out = y @ p["w_out"].astype(x.dtype)
    return out[:, None, :], {
        "conv_x": conv_x_new, "conv_bc": conv_bc_new, "ssm": ssm
    }
