"""Shared model layers: norms, RoPE, chunked-flash attention, MLP.

All functions are pure; parameters are plain pytrees.  Sharding is expressed
with ``shard(x, mesh, axes...)`` constraints that silently skip any dim not
evenly divisible by its mesh axes (the divisibility-aware analogue of
logical axis rules; see sharding/partition.py for the rule table).

Attention is a two-level chunked online-softmax scan (flash attention
expressed in XLA): the outer q-chunk loop is rematerialized per chunk so the
backward pass never holds more than one q-chunk of score-sized residuals —
this is what makes prefill_32k compile inside HBM for every arch.  The TPU
Pallas flash kernel (kernels/attention) slots in behind the same interface
on real hardware.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

COMPUTE_DTYPE = jnp.bfloat16
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------
def axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        return math.prod(mesh.shape[a] for a in ax)
    return mesh.shape[ax]


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def resolve_spec(mesh: Mesh, shape: Sequence[int], axes: Sequence[Any]) -> P:
    """PartitionSpec with non-divisible or absent axes dropped per-dim."""
    spec = []
    for dim, ax in zip(shape, axes):
        if ax is None:
            spec.append(None)
            continue
        names = ax if isinstance(ax, (tuple, list)) else (ax,)
        names = tuple(a for a in names if a in mesh.axis_names)
        if not names:
            spec.append(None)
            continue
        if dim % axis_size(mesh, names) == 0:
            spec.append(names if len(names) > 1 else names[0])
        else:
            spec.append(None)
    return P(*spec)


def shard(x: jnp.ndarray, mesh: Mesh | None, *axes) -> jnp.ndarray:
    if mesh is None:
        return x
    spec = resolve_spec(mesh, x.shape, axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# basic layers
# ---------------------------------------------------------------------------
def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Plain autodiff rmsnorm.  Its f32 internals leak f32 cotangents into
    the backward graph, which XLA then all-reduces at f32 — 2x the TP
    collective bytes (EXPERIMENTS.md §Perf iteration 1)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm_fused(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm with a hand-written VJP: f32 math stays LOCAL to the op and
    both cotangents leave in the storage dtypes, so the partitioner's psums
    on the residual stream run in bf16 (the fused-norm-kernel convention)."""
    return rmsnorm_ref(x, w, eps)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Dispatcher: REPRO_RMSNORM=ref selects the plain-autodiff baseline
    (used by the §Perf A/B probes); default is the custom-VJP version."""
    import os

    if os.environ.get("REPRO_RMSNORM", "fused") == "ref":
        return rmsnorm_ref(x, w, eps)
    return rmsnorm_fused(x, w, eps)


def _rmsnorm_fwd(x, w, eps):
    xf = x.astype(jnp.float32)
    rstd = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    y = (xf * rstd * (1.0 + w.astype(jnp.float32))).astype(x.dtype)
    return y, (x, w, rstd)


def _rmsnorm_bwd(eps, res, g):
    x, w, rstd = res
    xf = x.astype(jnp.float32)
    xhat = xf * rstd
    gw = g.astype(jnp.float32) * (1.0 + w.astype(jnp.float32))
    mean_gx = jnp.mean(gw * xhat, axis=-1, keepdims=True)
    dx = rstd * (gw - xhat * mean_gx)
    dw = jnp.sum(
        g.astype(jnp.float32) * xhat,
        axis=tuple(range(x.ndim - 1)),
    )
    return dx.astype(x.dtype), dw.astype(w.dtype)


rmsnorm_fused.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, H, D] (D even), positions [..., S] -> rotated x."""
    d_half = x.shape[-1] // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(d_half, dtype=jnp.float32) / d_half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu_mlp(x, p, mesh=None, dp=("data",), prefix=""):
    """Megatron column->row parallel SwiGLU: one psum on the way out.

    ``p`` carries either fused ``w_gateup`` [d, 2f] (one column matmul, one
    backward dx psum — §Perf iteration 2) or split w_gate/w_up; ``prefix``
    selects the MoE shared-expert key names.
    """
    if prefix + "w_gateup" in p:
        # [d, 2, f] layout: the TP-sharded dim (f) is untouched by the
        # gate/up split, so no resharding is introduced
        gu = jnp.einsum("bsd,dcf->bscf", x, p[prefix + "w_gateup"].astype(x.dtype))
        h, u = gu[:, :, 0, :], gu[:, :, 1, :]
    else:
        h = jnp.einsum("bsd,df->bsf", x, p[prefix + "w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p[prefix + "w_up"].astype(x.dtype))
    h = shard(jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u,
              mesh, dp, None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p[prefix + "w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# chunked-flash attention (training / prefill)
# ---------------------------------------------------------------------------
def _attn_one_q_chunk(q, k, v, q_pos, kv_pos, scale, causal):
    """q [B,Qc,H,D] vs full k/v [B,S,KH,D] -> [B,Qc,H,D] (f32 accum)."""
    B, Qc, H, D = q.shape
    S, KH = k.shape[1], k.shape[2]
    rep = H // KH
    kv_chunk = min(1024, S)
    n_chunks = S // kv_chunk
    qg = q.reshape(B, Qc, KH, rep, D)

    def step(carry, inputs):
        m, l, acc = carry
        kc, vc, kpos = inputs  # [B,kv_chunk,KH,D], ..., [kv_chunk]
        s = jnp.einsum(
            "bqhrd,bkhd->bhrqk", qg.astype(jnp.float32), kc.astype(jnp.float32)
        ) * scale
        if causal:
            mask = q_pos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhrqk,bkhd->bhrqd", p, vc.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    ks = k.reshape(B, n_chunks, kv_chunk, KH, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n_chunks, kv_chunk, KH, D).transpose(1, 0, 2, 3, 4)
    kps = kv_pos.reshape(n_chunks, kv_chunk)
    init = (
        jnp.full((B, KH, rep, Qc), NEG_INF, jnp.float32),
        jnp.zeros((B, KH, rep, Qc), jnp.float32),
        jnp.zeros((B, KH, rep, Qc, D), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (ks, vs, kps))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Qc, H, D)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool,
    q_chunk: int = 1024,
    pos_offset: int = 0,
) -> jnp.ndarray:
    """Flash-style attention: q [B,Sq,H,D], k/v [B,Skv,KH,D] -> [B,Sq,H,D].

    Sq must be divisible by q_chunk (callers use model seq lens, all pow-2).
    """
    B, Sq, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    q_chunk = min(q_chunk, Sq)
    n_q = Sq // q_chunk
    kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    q_pos_all = jnp.arange(Sq, dtype=jnp.int32) + pos_offset

    if n_q == 1:
        out = _attn_one_q_chunk(q, k, v, q_pos_all, kv_pos, scale, causal)
        return out.astype(q.dtype)

    body = jax.checkpoint(
        lambda qc, qp: _attn_one_q_chunk(qc, k, v, qp, kv_pos, scale, causal)
    )

    def step(_, inputs):
        qc, qp = inputs
        return None, body(qc, qp)

    qs = q.reshape(B, n_q, q_chunk, H, D).transpose(1, 0, 2, 3, 4)
    qps = q_pos_all.reshape(n_q, q_chunk)
    _, outs = jax.lax.scan(step, None, (qs, qps))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def normal_init(key, shape, dtype, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
