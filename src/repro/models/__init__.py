from repro.models.model import (
    build_param_specs, init_params, param_shape_structs, param_shardings,
    forward, loss_fn, padded_vocab,
)
