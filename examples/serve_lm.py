"""Serving example: batched prefill + greedy decode with per-family caches
(GQA KV / MLA latent / SSM state), on reduced configs of three assigned
architectures.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models.model import init_params
from repro.serve.kvcache import cache_bytes
from repro.serve.serve_step import make_decode_step, prefill_with_cache


def serve(arch: str, *, batch=4, prompt_len=12, gen_len=16, max_len=64):
    cfg = get_config(arch).reduced()
    if cfg.frontend != "none":
        import dataclasses
        cfg = dataclasses.replace(cfg, frontend="none")
    mesh = make_smoke_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32
    )

    t0 = time.perf_counter()
    logits, cache = prefill_with_cache(params, prompts, cfg, mesh, max_len)
    next_tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0

    dstep = jax.jit(make_decode_step(cfg, mesh))
    out_tokens = [next_tok]
    t0 = time.perf_counter()
    for _ in range(gen_len - 1):
        logits, cache = dstep(params, cache, next_tok)
        next_tok = jnp.argmax(
            logits[:, :, : cfg.vocab_size], axis=-1
        ).astype(jnp.int32)
        out_tokens.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"{arch:22s} family={cfg.family:7s} "
          f"cache={cache_bytes(cfg, batch, max_len)/1e6:7.2f}MB  "
          f"prefill={t_prefill*1e3:7.1f}ms  "
          f"decode={t_decode/max(gen_len-1,1)*1e3:6.1f}ms/tok  "
          f"sample={gen[0, :8].tolist()}")
    assert gen.shape == (batch, gen_len)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()


def main():
    print("batched serving across cache families (reduced configs):")
    for arch in ("granite-3-8b", "deepseek-v2-236b", "mamba2-1.3b",
                 "zamba2-2.7b"):
        serve(arch)
    print("all families served ✓")


if __name__ == "__main__":
    main()
