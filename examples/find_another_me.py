"""Find Another Me — the paper's Fig. 1 scenario, end to end.

Carol lives in Sydney, Dave in Chicago; their trajectories never overlap
geographically, yet both are frequent flyers visiting
lodging -> airports -> company -> dining -> airports -> lodging.  The
pipeline must place them in the same community while keeping the
stay-at-home neighbour out.

    PYTHONPATH=src python examples/find_another_me.py
"""
import numpy as np

from repro.api import AnotherMeEngine, EngineConfig
from repro.core.encoding import encode_places, forest_tables
from repro.data.fig1 import PEOPLE, fig1_world


def main():
    batch, forest = fig1_world()
    tables = forest_tables(forest)
    for (who, traj), ids, length in zip(
        PEOPLE.items(), np.asarray(batch.places), np.asarray(batch.lengths)
    ):
        print(f"{who}:")
        for p, enc in zip(traj, encode_places(ids[:length], np.asarray(tables))):
            print(f"    {enc:10s} {p}")

    engine = AnotherMeEngine(forest, EngineConfig(rho=3.0))
    res = engine.run(batch)
    names = list(PEOPLE)
    print("\nsimilar pairs (MSS > 3):")
    for a, b in sorted(res.similar_pairs):
        print(f"    {names[a]}  <->  {names[b]}")
    print("communities of interest:")
    for c in res.communities:
        print("    {" + ", ".join(names[i] for i in sorted(c)) + "}")
    assert (0, 1) in res.similar_pairs, "Carol should find her other me!"
    print("\nCarol found another her across the world ✓")


if __name__ == "__main__":
    main()
