"""Find Another Me — the paper's Fig. 1 scenario, end to end.

Carol lives in Sydney, Dave in Chicago; their trajectories never overlap
geographically, yet both are frequent flyers visiting
lodging -> airports -> company -> dining -> airports -> lodging.  The
pipeline must place them in the same community while keeping the
stay-at-home neighbour out.

    PYTHONPATH=src python examples/find_another_me.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import AnotherMeConfig, run_anotherme
from repro.core.encoding import SemanticForest, encode_places, forest_tables
from repro.core.types import PAD_PLACE, TrajectoryBatch

TYPES = ["lodging", "transportation", "business", "dining"]
CLASSES = ["apartment", "hotel", "airport", "station", "company",
           "fast_food", "fine_dinner"]
NAMES = ["Maris Apartment", "Windy Apartment", "Beach House",
         "Sydney Airport", "O'Hare Airport", "Tokyo Airport",
         "Paris-CDG", "Facebook Japan", "Microsoft France", "KFC Tokyo",
         "Restaurant Goude"]
CLASS_TO_TYPE = np.array([0, 0, 1, 1, 2, 3, 3], np.int32)
NAME_TO_CLASS = np.array([0, 0, 0, 2, 2, 2, 2, 4, 4, 5, 6], np.int32)

PEOPLE = {
    "Carol (Sydney)": ["Maris Apartment", "Sydney Airport", "O'Hare Airport",
                       "Tokyo Airport", "Facebook Japan", "KFC Tokyo",
                       "Tokyo Airport", "Sydney Airport", "Maris Apartment"],
    "Dave (Chicago)": ["Windy Apartment", "O'Hare Airport", "Paris-CDG",
                       "Microsoft France", "Restaurant Goude", "Paris-CDG",
                       "O'Hare Airport", "Windy Apartment"],
    "Homebody": ["Beach House", "KFC Tokyo", "Beach House", "KFC Tokyo",
                 "Beach House"],
}


def main():
    forest = SemanticForest(
        parents=(CLASS_TO_TYPE, NAME_TO_CLASS),
        sizes=(len(TYPES), len(CLASSES), len(NAMES)),
    )
    tables = forest_tables(forest)
    name_id = {n: i for i, n in enumerate(NAMES)}
    L = max(len(t) for t in PEOPLE.values())
    rows, lens = [], []
    for who, traj in PEOPLE.items():
        ids = [name_id[p] for p in traj]
        print(f"{who}:")
        for p, enc in zip(traj, encode_places(ids, np.asarray(tables))):
            print(f"    {enc:10s} {p}")
        rows.append(ids + [PAD_PLACE] * (L - len(ids)))
        lens.append(len(ids))

    batch = TrajectoryBatch(
        places=jnp.asarray(np.asarray(rows, np.int32)),
        lengths=jnp.asarray(np.asarray(lens, np.int32)),
        user_id=jnp.arange(len(PEOPLE), dtype=jnp.int32),
    )
    res = run_anotherme(batch, forest, AnotherMeConfig(rho=3.0))
    names = list(PEOPLE)
    print("\nsimilar pairs (MSS > 3):")
    for a, b in sorted(res.similar_pairs):
        print(f"    {names[a]}  <->  {names[b]}")
    print("communities of interest:")
    for c in res.communities:
        print("    {" + ", ".join(names[i] for i in sorted(c)) + "}")
    assert (0, 1) in res.similar_pairs, "Carol should find her other me!"
    print("\nCarol found another her across the world ✓")


if __name__ == "__main__":
    main()
