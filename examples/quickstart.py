"""Quickstart: the full AnotherMe pipeline in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import AnotherMeEngine, EngineConfig
from repro.core import (
    centralized_similar_pairs, encode_batch, forest_tables, maximal_cliques,
    qa1, qa2,
)
from repro.data import synthetic_setup


def main():
    # 1. data: 2,000 synthetic trajectories over the paper's world
    #    (30 types x 10 classes x 10,000 places, lengths 5..10)
    batch, forest = synthetic_setup(2_000, seed=0)
    print(f"trajectories: {batch.num_trajectories}, "
          f"semantic forest sizes: {forest.sizes}")

    # 2. run AnotherMe: encode -> SSH join -> similarity -> communities.
    #    EngineConfig(backend=...) swaps the candidate join by name:
    #    "ssh" (the paper's lossless join), "minhash", "brp", "udf".
    engine = AnotherMeEngine(forest, EngineConfig(backend="ssh", rho=2.0))
    result = engine.run(batch)
    s = result.stats
    print(f"candidates from SSH join : {s['num_candidates']:>8d}")
    print(f"similar pairs (MSS > 2)  : {s['num_similar']:>8d}")
    print(f"communities of interest  : {s['num_communities']:>8d}")
    print(f"phase times: encode {s['t_encode']:.2f}s  "
          f"candidates {s['t_candidates']:.2f}s  score {s['t_score']:.2f}s")

    # 3. validate against the centralized ground truth on a subsample
    sub, _ = synthetic_setup(400, seed=0)
    res_small = engine.run(sub)
    enc = encode_batch(sub, forest_tables(forest))
    cl, cr, _ = centralized_similar_pairs(enc, rho=2.0)
    cen = {(int(a), int(b)) for a, b in zip(cl, cr)}
    print(f"QA1 = {qa1(res_small.communities, maximal_cliques(cen)):.3f}  "
          f"QA2 = {qa2(res_small.similar_pairs, cen):.3f}  (paper: 1.000)")


if __name__ == "__main__":
    main()
