"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the paper's SSH near-duplicate detection running in the data pipeline.

    PYTHONPATH=src python examples/train_lm.py            # 300 steps
    PYTHONPATH=src python examples/train_lm.py --steps 50 # quicker
"""
import sys

from repro.launch.train import build_parser, train


def main():
    argv = sys.argv[1:]
    defaults = [
        "--arch", "tiny-100m", "--steps", "300", "--global-batch", "8",
        "--seq-len", "256", "--num-docs", "4096", "--dedup", "ssh",
        "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "100",
        "--log-every", "20",
    ]
    args = build_parser().parse_args(defaults + argv)
    out = train(args)
    losses = out["losses"]
    print(f"\nfirst-10 mean loss {sum(losses[:10])/10:.3f} -> "
          f"last-10 mean loss {sum(losses[-10:])/10:.3f}")
    assert sum(losses[-10:]) < sum(losses[:10]), "model did not learn"
    print("training improved the loss ✓")


if __name__ == "__main__":
    main()
