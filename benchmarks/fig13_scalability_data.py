"""Fig. 13 — scalability with data size (paper: up to 1M trajectories,
300 place types).  AnotherMe vs MinHash only (the quadratic baselines
cannot run at these sizes)."""
from __future__ import annotations

from benchmarks.common import Row, make_engine, timeit
from repro.data import synthetic_setup

GRID_QUICK = (5_000, 20_000)
GRID_FULL = (50_000, 200_000, 1_000_000)


def run(full: bool = False) -> list[Row]:
    rows = []
    for n in (GRID_FULL if full else GRID_QUICK):
        batch, forest = synthetic_setup(n, num_types=300, seed=0)
        for name, backend in (("anotherme", "ssh"), ("minhash", "minhash")):
            engine = make_engine(forest, backend, community_mode="components")
            t, res = timeit(lambda: engine.run(batch))
            rows.append(Row(f"fig13/{name}/N={n}", t * 1e6,
                            f"similar={len(res.similar_pairs)}"))
    return rows
