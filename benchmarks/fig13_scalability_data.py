"""Fig. 13 — scalability with data size (paper: up to 1M trajectories,
300 place types).  AnotherMe vs MinHash only (the quadratic baselines
cannot run at these sizes)."""
from __future__ import annotations

from benchmarks.common import Row, timeit
from repro.core import AnotherMeConfig, minhash_candidates, run_anotherme, type_codes
from repro.data import synthetic_setup

GRID_QUICK = (5_000, 20_000)
GRID_FULL = (50_000, 200_000, 1_000_000)


def run(full: bool = False) -> list[Row]:
    rows = []
    for n in (GRID_FULL if full else GRID_QUICK):
        batch, forest = synthetic_setup(n, num_types=300, seed=0)
        cfg = AnotherMeConfig(community_mode="components")
        t, res = timeit(lambda: run_anotherme(batch, forest, cfg))
        rows.append(Row(f"fig13/anotherme/N={n}", t * 1e6,
                        f"similar={len(res.similar_pairs)}"))
        t, r2 = timeit(lambda: run_anotherme(
            batch, forest, cfg,
            candidate_fn=lambda e, b: minhash_candidates(
                type_codes(e), b.lengths, num_perm=16, bands=4,
                pair_capacity=1 << 22),
        ))
        rows.append(Row(f"fig13/minhash/N={n}", t * 1e6,
                        f"similar={len(r2.similar_pairs)}"))
    return rows
