"""Query-serving benchmark: top-k "find another me" latency and throughput.

Feeds a resident world to a :class:`StreamingEngine`, then drives a
:class:`QueryEngine` with a steady stream of query micro-batches — the
online half of the paper's workload ("pose one trajectory, get the most
similar users back") where LATENCY, not ingest throughput, is the
scoreboard.  The grid sweeps query batch size Q against world size N;
each cell reports per-batch wall-time percentiles and queries/sec for
both the plain path and the REPOSE-pruned path, plus the serving-shape
evidence: one compiled program pair for the whole run (``serve_traces``
plateaus after warmup) and driver traffic that scales with [Q, k] + the
query batch — never with the world.

Writes ``BENCH_serve.json`` next to ``BENCH_score.json`` /
``BENCH_stream.json``; the tier-1 CI workflow runs ``--smoke`` and
uploads the JSON as an artifact per PR.

JSON schema (``schema: bench_serve/v1``)::

    {
      "schema": "bench_serve/v1",
      "backend": "cpu" | "tpu" | ...,
      "jax_version": "...",
      "smoke": bool,
      "grids": [
        {"N": int, "Q": int, "k": int, "batches": int,
         "serve": {"batch_wall_s": [...], "p50_ms": float, "p99_ms": float,
                   "mean_ms": float, "queries_per_sec": float,
                   "candidates_per_batch": float,
                   "driver_bytes_per_batch": float,
                   "serve_traces": int, "probe_traces": int,
                   "steady_state_recompiles": int},
         "serve_pruned": {... same fields, plus "cells_skipped": int,
                          "rounds_skipped": int},
         "pruned_vs_plain": float}, ...   # plain p50 / pruned p50
      ]
    }
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_HERE = os.path.dirname(os.path.abspath(__file__))
_REPO = os.path.dirname(_HERE)
for p in (_REPO, os.path.join(_REPO, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import jax
import jax.numpy as jnp
import numpy as np


def _query_batch(places, lengths, sel):
    from repro.core.types import TrajectoryBatch

    return TrajectoryBatch(
        places=jnp.asarray(places[sel]),
        lengths=jnp.asarray(lengths[sel]),
        user_id=jnp.arange(len(sel), dtype=jnp.int32),
    )


def _serve_run(stream, places, lengths, *, Q, k, batches, prune, seed):
    """Drive one QueryEngine with ``batches`` steady-shape micro-batches
    (a warm pass over the same cycle is excluded from the timings)."""
    from repro.api import QueryEngine

    rng = np.random.default_rng(seed)
    qe = QueryEngine(stream, k=k, serve_prune=prune)
    # warm pass over the exact batch cycle we will time: compiles the
    # program pair and ratchets the pow2-sticky caps to the max any batch
    # needs, so the timed pass measures the steady state the
    # zero-recompile contract covers
    sels = [rng.integers(0, places.shape[0], Q) for _ in range(batches)]
    for sel in sels:
        res = qe.query(_query_batch(places, lengths, sel))
    warm_traces = res.stats["serve_traces"] + res.stats["probe_traces"]
    walls, cands, bytes_in = [], [], []
    skipped_cells = skipped_rounds = 0
    for sel in sels:
        qb = _query_batch(places, lengths, sel)
        t0 = time.perf_counter()
        res = qe.query(qb)
        np.asarray(res.match_ids)  # materialize before stopping the clock
        walls.append(time.perf_counter() - t0)
        cands.append(res.stats["candidates"])
        bytes_in.append(res.stats["driver_bytes_in"])
        skipped_cells += res.stats["cells_skipped"]
        skipped_rounds += res.stats["rounds_skipped"]
    out = {
        "batch_wall_s": [round(w, 6) for w in walls],
        "p50_ms": round(float(np.percentile(walls, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(walls, 99)) * 1e3, 3),
        "mean_ms": round(float(np.mean(walls)) * 1e3, 3),
        "queries_per_sec": round(Q * len(walls) / sum(walls), 1),
        "candidates_per_batch": round(float(np.mean(cands)), 1),
        "driver_bytes_per_batch": round(float(np.mean(bytes_in)), 1),
        "serve_traces": int(res.stats["serve_traces"]),
        "probe_traces": int(res.stats["probe_traces"]),
        # compiles after the warmup batch; 0 = the production contract
        "steady_state_recompiles": int(
            res.stats["serve_traces"] + res.stats["probe_traces"]
            - warm_traces
        ),
    }
    if prune:
        out["cells_skipped"] = int(skipped_cells)
        out["rounds_skipped"] = int(skipped_rounds)
    return out


def bench_cell(N, Q, *, k=10, batches=16, rho=2.0, seed=0):
    """One grid cell: resident world of N rows, ``batches`` query
    micro-batches of Q trajectories each, plain and pruned."""
    from repro.api import EngineConfig, StreamingEngine
    from repro.data import synthetic_setup

    batch, forest = synthetic_setup(
        N, num_types=30, classes_per_type=10, num_places=1000, seed=seed
    )
    places = np.asarray(batch.places)
    lengths = np.asarray(batch.lengths)
    stream = StreamingEngine(
        forest, EngineConfig(rho=rho, community_mode="components"),
        world_capacity=N,
    )
    stream.update(batch)
    plain = _serve_run(stream, places, lengths, Q=Q, k=k, batches=batches,
                       prune=False, seed=seed + 1)
    pruned = _serve_run(stream, places, lengths, Q=Q, k=k, batches=batches,
                        prune=True, seed=seed + 1)
    return {
        "N": N, "Q": Q, "k": k, "batches": batches,
        "serve": plain, "serve_pruned": pruned,
        "pruned_vs_plain": round(
            plain["p50_ms"] / max(pruned["p50_ms"], 1e-6), 3
        ),
    }


def _grid(smoke, full):
    if smoke:
        return [(128, 4), (256, 16)]
    grid = [(512, 8), (512, 64), (2048, 8), (2048, 64)]
    if full:
        grid += [(8192, 8), (8192, 64), (8192, 256)]
    return grid


def bench(*, smoke=False, full=False, out_path=None):
    grids = [bench_cell(N, Q, batches=8 if smoke else 16)
             for N, Q in _grid(smoke, full)]
    report = {
        "schema": "bench_serve/v1",
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "smoke": bool(smoke),
        "grids": grids,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
    return report


def run(full: bool = False, smoke: bool | None = None):
    """benchmarks/run.py entry point: CSV rows + BENCH_serve.json."""
    from benchmarks.common import Row

    report = bench(smoke=(not full) if smoke is None else smoke, full=full,
                   out_path=os.path.join(_REPO, "BENCH_serve.json"))
    for cell in report["grids"]:
        tag = f"N{cell['N']}_Q{cell['Q']}"
        s, p = cell["serve"], cell["serve_pruned"]
        yield Row(
            f"bench_serve/serve/{tag}",
            s["mean_ms"] * 1e3,
            f"p50={s['p50_ms']}ms p99={s['p99_ms']}ms "
            f"{s['queries_per_sec']:.0f} q/s "
            f"[recompiles={s['steady_state_recompiles']}]",
        )
        yield Row(
            f"bench_serve/serve_pruned/{tag}",
            p["mean_ms"] * 1e3,
            f"p50={p['p50_ms']}ms p99={p['p99_ms']}ms "
            f"{p['queries_per_sec']:.0f} q/s "
            f"[skipped={p.get('cells_skipped', 0)} cells, "
            f"x{cell['pruned_vs_plain']} vs plain]",
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (seconds, not minutes)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grid (adds N=8192 cells)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    report = bench(smoke=args.smoke, full=args.full, out_path=args.out)
    print(f"# backend={report['backend']} jax={report['jax_version']}")
    for cell in report["grids"]:
        s, p = cell["serve"], cell["serve_pruned"]
        print(f"N={cell['N']:<6d} Q={cell['Q']:<4d} "
              f"plain p50 {s['p50_ms']:8.2f} ms  p99 {s['p99_ms']:8.2f} ms "
              f"{s['queries_per_sec']:9.0f} q/s | "
              f"pruned p50 {p['p50_ms']:8.2f} ms "
              f"{p['queries_per_sec']:9.0f} q/s "
              f"recompiles={s['steady_state_recompiles']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
