"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Default sizes are CPU-quick;
``--full`` runs the paper-scale grids (minutes to hours).
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback

MODULES = [
    "bench_score",
    "bench_serve",
    "bench_stream",
    "fig7_processing_time",
    "fig8_pairs_compared",
    "fig9_hash_overhead",
    "fig10_accuracy",
    "fig11_12_real_dataset",
    "fig13_scalability_data",
    "fig14_scalability_nodes",
    "fig15_semantic_levels",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grids (modules that support it)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module substrings")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for modname in MODULES:
        if args.only and not any(s in modname for s in args.only.split(",")):
            continue
        try:
            mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
            kwargs = {"full": args.full}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                kwargs["smoke"] = True
            for row in mod.run(**kwargs):
                print(row.csv(), flush=True)
        except Exception:
            failures += 1
            print(f"{modname},ERROR,", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
