"""Shared benchmark utilities: timing, CSV rows, standard worlds."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timeit(fn: Callable, *, repeats: int = 1) -> tuple[float, object]:
    """(seconds, last result) — single-shot by default (pipelines are
    seconds-scale; jit warmup dominates the first call and is included once
    per approach, matching how the paper measures end-to-end time)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn()
    return (time.perf_counter() - t0) / repeats, out


def centralized_truth(batch, forest, rho=2.0):
    from repro.core import centralized_similar_pairs, encode_batch, forest_tables
    from repro.core.communities import maximal_cliques

    enc = encode_batch(batch, forest_tables(forest))
    cl, cr, _ = centralized_similar_pairs(enc, rho=rho)
    pairs = {(int(a), int(b)) for a, b in zip(cl, cr)}
    return pairs, maximal_cliques(pairs)


# The paper's hash-based approaches, by candidate-backend registry name
# ("anotherme" is the paper's label for the SSH join).  Centralized and the
# whole-pipeline UDF baseline are not candidate backends and are benchmarked
# separately where a figure calls for them.
APPROACHES = {"anotherme": "ssh", "minhash": "minhash", "brp": "brp"}


def make_engine(forest, backend: str = "ssh", n_shards: int = 1, **config_kw):
    """An AnotherMeEngine with the named candidate backend."""
    from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan

    return AnotherMeEngine(
        forest, EngineConfig(backend=backend, **config_kw),
        ExecutionPlan(n_shards=n_shards),
    )
