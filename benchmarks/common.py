"""Shared benchmark utilities: timing, CSV rows, standard worlds."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timeit(fn: Callable, *, repeats: int = 1) -> tuple[float, object]:
    """(seconds, last result) — single-shot by default (pipelines are
    seconds-scale; jit warmup dominates the first call and is included once
    per approach, matching how the paper measures end-to-end time)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn()
    return (time.perf_counter() - t0) / repeats, out


def centralized_truth(batch, forest, rho=2.0):
    from repro.core import centralized_similar_pairs, encode_batch, forest_tables
    from repro.core.communities import maximal_cliques

    enc = encode_batch(batch, forest_tables(forest))
    cl, cr, _ = centralized_similar_pairs(enc, rho=rho)
    pairs = {(int(a), int(b)) for a, b in zip(cl, cr)}
    return pairs, maximal_cliques(pairs)


def windowed_truth(batch, forest, *, window, stride=1, rho=2.0, chunk=1 << 15):
    """Brute-force subtrajectory truth set: (pairs, communities).

    Trajectories (a, b) are similar iff ANY length-W window of a scores
    MSS > rho against ANY length-W window of b — every window pair scored
    exactly with the reference multi-level LCS, no candidate generation,
    the max-over-windows implied by the existential check.  O((N * nw)^2)
    window pairs, scored in fixed-size device chunks; truth-grid worlds
    only.
    """
    import jax.numpy as jnp

    from repro.core import encode_batch, forest_tables
    from repro.core.communities import maximal_cliques
    from repro.core.similarity import (
        default_betas, gather_windows, mss_scores, multi_level_lcs,
    )
    from repro.core.subtraj import num_windows, window_lengths

    enc = encode_batch(batch, forest_tables(forest))
    codes = jnp.asarray(enc.codes)
    _, n_levels, L = codes.shape
    nw = num_windows(L, window, stride)
    wlen = np.asarray(window_lengths(
        np.asarray(enc.lengths), max_len=L, window=window, stride=stride))
    W = min(window, L)
    betas = default_betas(n_levels)

    wid = np.nonzero(wlen > 0)[0].astype(np.int32)
    traj = wid // nw
    ii, jj = np.meshgrid(
        np.arange(wid.size), np.arange(wid.size), indexing="ij")
    sel = traj[ii] < traj[jj]
    li, ri = wid[ii[sel]], wid[jj[sel]]

    pairs: set[tuple[int, int]] = set()
    for s in range(0, li.size, chunk):
        wl, wr = li[s:s + chunk], ri[s:s + chunk]
        ta, tb = wl // nw, wr // nw
        oa, ob = (wl % nw) * stride, (wr % nw) * stride
        lvl = multi_level_lcs(
            gather_windows(codes[ta], jnp.asarray(oa), W),
            jnp.asarray(wlen[wl]),
            gather_windows(codes[tb], jnp.asarray(ob), W),
            jnp.asarray(wlen[wr]),
        )
        ms = np.asarray(mss_scores(lvl, betas))
        hit = ms > rho
        pairs.update((int(a), int(b)) for a, b in zip(ta[hit], tb[hit]))
    return pairs, maximal_cliques(pairs)


# The paper's hash-based approaches, by candidate-backend registry name
# ("anotherme" is the paper's label for the SSH join).  Centralized and the
# whole-pipeline UDF baseline are not candidate backends and are benchmarked
# separately where a figure calls for them.
APPROACHES = {"anotherme": "ssh", "minhash": "minhash", "brp": "brp"}


def make_engine(forest, backend: str = "ssh", n_shards: int = 1, **config_kw):
    """An AnotherMeEngine with the named candidate backend."""
    from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan

    return AnotherMeEngine(
        forest, EngineConfig(backend=backend, **config_kw),
        ExecutionPlan(n_shards=n_shards),
    )
