"""Figs. 11-12 — processing time + accuracy on the real dataset (GeoLife
surrogate, 182 users / 17,621 trajectories at full scale).  BRP excluded as
in the paper ('not able to correctly detect most communities')."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, centralized_truth, make_engine, timeit
from repro.core import qa1, qa2, udf_pipeline
from repro.data import geolife_surrogate


def run(full: bool = False) -> list[Row]:
    rows = []
    if full:
        batch, forest = geolife_surrogate(num_users=182, num_traj=17_621, seed=0)
    else:
        batch, forest = geolife_surrogate(num_users=60, num_traj=1_200, seed=0)
    rho = 3.0
    small_enough_for_truth = batch.places.shape[0] <= 3_000
    if small_enough_for_truth:
        cen_pairs, cen_comms = centralized_truth(batch, forest, rho=rho)

    for name, backend in (("anotherme", "ssh"), ("minhash", "minhash")):
        engine = make_engine(forest, backend, rho=rho)
        t, res = timeit(lambda: engine.run(batch))
        d = ""
        if small_enough_for_truth:
            d = (f"QA1={qa1(res.communities, cen_comms):.3f};"
                 f"QA2={qa2(res.similar_pairs, cen_pairs):.3f}")
        rows.append(Row(f"fig11/{name}", t * 1e6, d))

    if small_enough_for_truth:
        t, _ = timeit(lambda: udf_pipeline(
            np.asarray(batch.places), np.asarray(batch.lengths), forest,
            rho=rho))
        rows.append(Row("fig11/udf", t * 1e6, "QA1=1.000;QA2=1.000"))
    return rows
