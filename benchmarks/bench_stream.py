"""Streaming-ingestion benchmark: per-update latency vs one-shot re-runs.

Feeds one synthetic world to a :class:`StreamingEngine` in micro-batches
and, for the SAME prefix sizes, re-runs a one-shot ``AnotherMeEngine.run``
over the growing concatenation — the two strategies an operator of the
paper's continuously-collected LBS workload could choose between.  Writes
``BENCH_stream.json`` so this and later PRs leave a recorded trajectory
next to ``BENCH_score.json``; the tier-1 CI workflow runs ``--smoke`` and
uploads the JSON as an artifact per PR.

What the numbers mean (CPU smoke runs document the harness; the shape of
the win — delta-proportional vs world-proportional updates — is backend
independent):

  stream        StreamingEngine.update with delta_join="host": incremental
                bucket probes on the DRIVER + delta-only scoring against
                the resident table (the pair list ships host->device)
  stream_device StreamingEngine.update with delta_join="device": the
                bucket state is key-sharded into device-resident slabs
                and the delta join runs in-mesh — only the new rows' key
                occurrences cross the host->device boundary
  oneshot       AnotherMeEngine.run over the full prefix, per micro-batch
                (re-encode, re-join, re-score, re-cluster the world)

Delta-only evidence is recorded per update: ``pairs_examined`` (pre-dedup
collisions probed by the incremental index) against ``full_world_pairs``
(the pre-dedup join size a one-shot re-run enumerates at that prefix) —
the acceptance bound requires examined < full for every steady-state
update, and the per-update counts sum exactly to the final full join.

Driver-transfer evidence compares the two delta-join paths per update:
``driver_bytes_in`` (bytes that crossed host->device through the
ingest + join + score input path), ``driver_pair_rows`` (candidate-pair
rows shipped by the driver — 0 on the device path, where the pair list
never materializes on the host), ``driver_key_rows`` (delta key
occurrences shipped into the in-mesh join — nonzero only on the device
path) and ``host_index_entries`` (world-key state resident on the
driver's ``BucketIndex`` — nonzero only on the host path).

Bounded-memory evidence (schema v3): each cell additionally streams the
SAME pieces through a sliding-window engine (``window=W`` updates, no
preallocated world — capacity starts at zero and must PLATEAU instead of
growing with total ingested rows).  Per update the windowed sections
record ``resident_bytes`` (device-resident world + slab bytes, the
quantity ``max_resident_bytes`` bounds), ``world_live``,
``dead_fraction`` (tombstones awaiting compaction) and ``num_expired``;
per run they record ``compactions``, ``compact_ms_total`` and
``compact_stall_ms_max`` (the worst single-update wall that absorbed a
compaction — the graceful-degradation latency spike).
``resident_bounded`` is the boundedness proof: after a ``2 * W``-update
warm-up the resident-byte series never exceeds its warm-up peak.

JSON schema (``schema: bench_stream/v3``)::

    {
      "schema": "bench_stream/v3",
      "backend": "cpu" | "tpu" | ...,
      "jax_version": "...",
      "smoke": bool,
      "grids": [
        {"N": int, "updates": int, "batch": int, "backend": "ssh",
         "stream": {"update_wall_s": [...], "updates_per_sec": float,
                    "mean_update_s": float, "p50_update_s": float,
                    "max_update_s": float,
                    "pairs_examined": [...], "full_world_pairs": [...],
                    "delta_only": bool, "delta_join": "host",
                    "driver_bytes_in": [...], "driver_pair_rows": [...],
                    "driver_key_rows": [...],
                    "host_index_entries": int,
                    "mean_driver_bytes_in": float},
         "stream_device": {... same fields, "delta_join": "device" ...},
         "windowed": {"window": int, "delta_join": "host",
                      "update_wall_s": [...], "mean_update_s": float,
                      "resident_bytes": [...], "world_live": [...],
                      "dead_fraction": [...], "num_expired": [...],
                      "retired_total": int, "compactions": int,
                      "compact_ms_total": float,
                      "compact_stall_ms_max": float,
                      "resident_bounded": bool},
         "windowed_device": {... same fields, "delta_join": "device" ...},
         "oneshot": {"update_wall_s": [...], "updates_per_sec": float,
                     "mean_update_s": float},
         "stream_vs_oneshot": float,
         "device_vs_host": float,          # host / device mean update s
         "device_driver_bytes_vs_host": float}, ...
      ]
    }
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_HERE = os.path.dirname(os.path.abspath(__file__))
_REPO = os.path.dirname(_HERE)
for p in (_REPO, os.path.join(_REPO, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import jax
import jax.numpy as jnp
import numpy as np


def _pieces(batch, k):
    from repro.core.types import TrajectoryBatch

    places = np.asarray(batch.places)
    lengths = np.asarray(batch.lengths)
    cuts = np.linspace(0, places.shape[0], k + 1).astype(int)
    return [
        TrajectoryBatch(
            places=jnp.asarray(places[a:b]),
            lengths=jnp.asarray(lengths[a:b]),
            user_id=jnp.arange(b - a, dtype=jnp.int32),
        )
        for a, b in zip(cuts[:-1], cuts[1:])
    ], cuts[1:]


def _prefix(batch, end):
    from repro.core.types import TrajectoryBatch

    return TrajectoryBatch(
        places=jnp.asarray(np.asarray(batch.places)[:end]),
        lengths=jnp.asarray(np.asarray(batch.lengths)[:end]),
        user_id=jnp.arange(end, dtype=jnp.int32),
    )


def _stream_run(forest, cfg, pieces, N, delta_join):
    """Stream one world through a StreamingEngine; return the summary."""
    from repro.api import ExecutionPlan, StreamingEngine

    stream = StreamingEngine(
        forest, cfg, ExecutionPlan(delta_join=delta_join),
        world_capacity=N, join_slab_capacity=16 * N,
    )
    walls, examined, full = [], [], []
    bytes_in, pair_rows, key_rows = [], [], []
    for piece in pieces:
        t0 = time.perf_counter()
        res = stream.update(piece)
        walls.append(time.perf_counter() - t0)
        examined.append(int(res.stats["pairs_examined"]))
        full.append(int(res.stats["full_world_pairs"]))
        bytes_in.append(int(res.stats["driver_bytes_in"]))
        pair_rows.append(int(res.stats["driver_pair_rows"]))
        key_rows.append(int(res.stats["driver_key_rows"]))
    s = {
        "update_wall_s": [round(w, 6) for w in walls],
        "updates_per_sec": round(len(walls) / sum(walls), 3),
        "mean_update_s": round(float(np.mean(walls)), 6),
        "p50_update_s": round(float(np.median(walls)), 6),
        "max_update_s": round(float(np.max(walls)), 6),
        "pairs_examined": examined,
        "full_world_pairs": full,
        # steady state (every update past the first): the incremental index
        # must examine strictly fewer pairs than a full-world re-join
        "delta_only": all(
            e < f for e, f in zip(examined[1:], full[1:]) if f
        ) and sum(examined) == full[-1],
        "delta_join": delta_join,
        "driver_bytes_in": bytes_in,
        "driver_pair_rows": pair_rows,
        "driver_key_rows": key_rows,
        "host_index_entries": int(res.stats["host_index_entries"]),
        "driver_mirror_keys": int(res.stats["driver_mirror_keys"]),
        "mean_driver_bytes_in": round(float(np.mean(bytes_in)), 1),
    }
    return s


def _windowed_run(forest, cfg, pieces, delta_join, window):
    """Sliding-window stream over the same pieces: the bounded-memory
    evidence run.  No preallocated capacity — the resident footprint has
    to plateau on its own once expiry + compaction reach steady state."""
    from repro.api import ExecutionPlan, StreamingEngine

    stream = StreamingEngine(
        forest, cfg, ExecutionPlan(delta_join=delta_join), window=window,
    )
    walls, rb, live, dead, expired = [], [], [], [], []
    stall_ms = 0.0
    seen_compactions = 0
    for piece in pieces:
        t0 = time.perf_counter()
        res = stream.update(piece)
        w = time.perf_counter() - t0
        walls.append(w)
        st = res.stats
        rb.append(int(st["resident_bytes"]))
        live.append(int(st["world_live"]))
        dead.append(float(st["dead_fraction"]))
        expired.append(int(st["num_expired"]))
        if stream.compactions > seen_compactions:
            # this update absorbed >= 1 compaction: its whole wall is the
            # worst-case stall an operator would observe
            stall_ms = max(stall_ms, w * 1e3)
            seen_compactions = stream.compactions
    warm = 2 * window
    tail = rb[warm:]
    bounded = (max(tail) <= max(rb[: warm + 1])) if tail else True
    return {
        "window": window,
        "delta_join": delta_join,
        "update_wall_s": [round(w, 6) for w in walls],
        "mean_update_s": round(float(np.mean(walls)), 6),
        "resident_bytes": rb,
        "world_live": live,
        "dead_fraction": [round(x, 4) for x in dead],
        "num_expired": expired,
        "retired_total": stream.retired_total,
        "compactions": stream.compactions,
        "compact_ms_total": round(stream.compact_ms_total, 3),
        "compact_stall_ms_max": round(stall_ms, 3),
        "resident_bounded": bounded,
    }


def bench_cell(N, updates, *, backend="ssh", rho=2.0, seed=0):
    """One grid cell: stream the world in ``updates`` micro-batches over
    BOTH delta-join paths and re-run one-shot over every prefix; returns
    the cell report dict."""
    from repro.api import AnotherMeEngine, EngineConfig
    from repro.data import synthetic_setup

    batch, forest = synthetic_setup(
        N, num_types=30, classes_per_type=10, num_places=1000, seed=seed
    )
    cfg = EngineConfig(backend=backend, rho=rho,
                       community_mode="components")
    pieces, ends = _pieces(batch, updates)

    s = _stream_run(forest, cfg, pieces, N, "host")
    dev = _stream_run(forest, cfg, pieces, N, "device")
    window = max(1, updates // 4)
    win = _windowed_run(forest, cfg, pieces, "host", window)
    win_dev = _windowed_run(forest, cfg, pieces, "device", window)

    engine = AnotherMeEngine(forest, cfg)
    o_walls = []
    for end in ends:
        prefix = _prefix(batch, int(end))
        t0 = time.perf_counter()
        engine.run(prefix)
        o_walls.append(time.perf_counter() - t0)
    o = {
        "update_wall_s": [round(w, 6) for w in o_walls],
        "updates_per_sec": round(len(o_walls) / sum(o_walls), 3),
        "mean_update_s": round(float(np.mean(o_walls)), 6),
    }
    return {
        "N": N, "updates": updates, "batch": N // updates,
        "backend": backend,
        "stream": s, "stream_device": dev,
        "windowed": win, "windowed_device": win_dev, "oneshot": o,
        "stream_vs_oneshot": round(
            o["mean_update_s"] / max(s["mean_update_s"], 1e-9), 3
        ),
        "device_vs_host": round(
            s["mean_update_s"] / max(dev["mean_update_s"], 1e-9), 3
        ),
        "device_driver_bytes_vs_host": round(
            dev["mean_driver_bytes_in"] / max(s["mean_driver_bytes_in"], 1.0),
            3,
        ),
    }


def _grid(smoke, full):
    if smoke:
        return [(128, 4), (256, 8)]
    grid = [(512, 8), (1024, 16)]
    if full:
        grid += [(4096, 32), (16384, 64)]
    return grid


def bench(*, smoke=False, full=False, out_path=None):
    grids = [bench_cell(N, u) for N, u in _grid(smoke, full)]
    report = {
        "schema": "bench_stream/v3",
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "smoke": bool(smoke),
        "grids": grids,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
    return report


def run(full: bool = False, smoke: bool | None = None):
    """benchmarks/run.py entry point: CSV rows + BENCH_stream.json."""
    from benchmarks.common import Row

    report = bench(smoke=(not full) if smoke is None else smoke, full=full,
                   out_path=os.path.join(_REPO, "BENCH_stream.json"))
    for cell in report["grids"]:
        tag = f"N{cell['N']}_u{cell['updates']}"
        yield Row(
            f"bench_stream/stream/{tag}",
            cell["stream"]["mean_update_s"] * 1e6,
            f"{cell['stream']['updates_per_sec']:.1f} upd/s "
            f"[delta_only={cell['stream']['delta_only']}] "
            f"[{cell['stream']['mean_driver_bytes_in']:.0f} B/upd]",
        )
        yield Row(
            f"bench_stream/stream_device/{tag}",
            cell["stream_device"]["mean_update_s"] * 1e6,
            f"{cell['stream_device']['updates_per_sec']:.1f} upd/s "
            f"[pair_rows=0, "
            f"{cell['stream_device']['mean_driver_bytes_in']:.0f} B/upd, "
            f"x{cell['device_driver_bytes_vs_host']} bytes vs host]",
        )
        win = cell["windowed_device"]
        yield Row(
            f"bench_stream/windowed_device/{tag}",
            win["mean_update_s"] * 1e6,
            f"W={win['window']} "
            f"[bounded={win['resident_bounded']}, "
            f"{max(win['resident_bytes'])} B peak, "
            f"{win['compactions']} compactions, "
            f"stall<={win['compact_stall_ms_max']:.1f} ms]",
        )
        yield Row(
            f"bench_stream/oneshot/{tag}",
            cell["oneshot"]["mean_update_s"] * 1e6,
            f"{cell['oneshot']['updates_per_sec']:.1f} upd/s "
            f"[x{cell['stream_vs_oneshot']} vs stream]",
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (seconds, not minutes)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grid (adds N=4096, 16384)")
    ap.add_argument("--out", default="BENCH_stream.json")
    args = ap.parse_args()
    report = bench(smoke=args.smoke, full=args.full, out_path=args.out)
    print(f"# backend={report['backend']} jax={report['jax_version']}")
    for cell in report["grids"]:
        s, d, o = cell["stream"], cell["stream_device"], cell["oneshot"]
        print(f"N={cell['N']:<6d} updates={cell['updates']:<3d} "
              f"host {s['mean_update_s']*1e3:8.2f} ms/upd "
              f"({s['mean_driver_bytes_in']:9.0f} B) "
              f"device {d['mean_update_s']*1e3:8.2f} ms/upd "
              f"({d['mean_driver_bytes_in']:9.0f} B) "
              f"oneshot {o['mean_update_s']*1e3:8.2f} ms/upd "
              f"x{cell['stream_vs_oneshot']:<7} "
              f"delta_only={s['delta_only'] and d['delta_only']}")
        for key in ("windowed", "windowed_device"):
            w = cell[key]
            print(f"  {key:<16s} W={w['window']:<3d} "
                  f"{w['mean_update_s']*1e3:8.2f} ms/upd "
                  f"resident<= {max(w['resident_bytes']):9d} B "
                  f"bounded={w['resident_bounded']} "
                  f"compactions={w['compactions']} "
                  f"stall<={w['compact_stall_ms_max']:.1f} ms")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
