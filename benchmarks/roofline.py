"""Roofline summary: reads experiments/dryrun.json (produced by
launch/dryrun.py) and emits the per-(arch x shape x mesh) table for
EXPERIMENTS.md §Roofline, plus a validation row comparing HLO flops against
the analytic 6*N*D model."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import Row


def load(path="experiments/dryrun.json"):
    p = pathlib.Path(path)
    if not p.exists():
        return []
    return json.loads(p.read_text())


def run(full: bool = False) -> list[Row]:
    rows = []
    recs = load()
    if not recs:
        return [Row("roofline/missing", 0.0,
                    "run: python -m repro.launch.dryrun first")]
    for r in recs:
        if r.get("status") != "ok":
            rows.append(Row(
                f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", -1.0,
                str(r.get("status"))[:80],
            ))
            continue
        mem = r.get("memory", {})
        if "roofline" not in r:
            rows.append(Row(
                f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}",
                r.get("compile_s", 0) * 1e6,
                f"bytes_per_dev={mem.get('peak_bytes_est', 0):.3e}",
            ))
            continue
        rf = r["roofline"]
        rows.append(Row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            rf["step_time_bound_s"] * 1e6,
            f"dom={rf['dominant']};compute={rf['compute_s']:.3g}s;"
            f"memory={rf['memory_s']:.3g}s;coll={rf['collective_s']:.3g}s;"
            f"mfu_bound={rf['mfu_bound']:.3f};"
            f"useful={rf['useful_flops_ratio']:.2f};"
            f"mem_per_dev={mem.get('peak_bytes_est', 0):.3e}",
        ))
    return rows


def summarize(path="experiments/dryrun.json"):
    """Human-readable table (used to draft EXPERIMENTS.md)."""
    recs = [r for r in load(path) if r.get("status") == "ok"]
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'dom':10s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'MFU@bound':>9s} {'useful':>7s} {'mem/dev':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        rf = r.get("roofline")
        mem = r.get("memory", {}).get("peak_bytes_est", 0)
        if rf is None:
            lines.append(
                f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
                f"{'(multi-pod)':10s} {'-':>10s} {'-':>10s} {'-':>10s} "
                f"{'-':>9s} {'-':>7s} {mem/1e9:8.2f}G"
            )
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
            f"{rf['dominant']:10s} {rf['compute_s']:10.4f} "
            f"{rf['memory_s']:10.4f} {rf['collective_s']:10.4f} "
            f"{rf['mfu_bound']:9.4f} {rf['useful_flops_ratio']:7.2f} "
            f"{mem/1e9:8.2f}G"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(summarize())
