"""Roofline summary: reads experiments/dryrun.json (produced by
launch/dryrun.py) and emits the per-(arch x shape x mesh) table for
EXPERIMENTS.md §Roofline, plus a validation row comparing HLO flops against
the analytic 6*N*D model.

``python -m benchmarks.roofline --tune`` additionally runs the LCS
autotune sweep: for each (P, H, L) cell it measures every candidate
``block_b`` x diagonal-dtype combination of the score-stage kernel,
asserts each candidate's LCS matrix is bit-identical to the untuned
default, and records the throughput winner into the
:mod:`repro.perf` tuning table (``TUNING.json`` or
``$REPRO_TUNING_PATH``).  The engine consults that table when
``ExecutionPlan(autotune=True)``.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import Row


def load(path="experiments/dryrun.json"):
    p = pathlib.Path(path)
    if not p.exists():
        return []
    return json.loads(p.read_text())


def run(full: bool = False) -> list[Row]:
    rows = []
    recs = load()
    if not recs:
        return [Row("roofline/missing", 0.0,
                    "run: python -m repro.launch.dryrun first")]
    for r in recs:
        if r.get("status") != "ok":
            rows.append(Row(
                f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", -1.0,
                str(r.get("status"))[:80],
            ))
            continue
        mem = r.get("memory", {})
        if "roofline" not in r:
            rows.append(Row(
                f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}",
                r.get("compile_s", 0) * 1e6,
                f"bytes_per_dev={mem.get('peak_bytes_est', 0):.3e}",
            ))
            continue
        rf = r["roofline"]
        rows.append(Row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            rf["step_time_bound_s"] * 1e6,
            f"dom={rf['dominant']};compute={rf['compute_s']:.3g}s;"
            f"memory={rf['memory_s']:.3g}s;coll={rf['collective_s']:.3g}s;"
            f"mfu_bound={rf['mfu_bound']:.3f};"
            f"useful={rf['useful_flops_ratio']:.2f};"
            f"mem_per_dev={mem.get('peak_bytes_est', 0):.3e}",
        ))
    return rows


def summarize(path="experiments/dryrun.json"):
    """Human-readable table (used to draft EXPERIMENTS.md)."""
    recs = [r for r in load(path) if r.get("status") == "ok"]
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'dom':10s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'MFU@bound':>9s} {'useful':>7s} {'mem/dev':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        rf = r.get("roofline")
        mem = r.get("memory", {}).get("peak_bytes_est", 0)
        if rf is None:
            lines.append(
                f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
                f"{'(multi-pod)':10s} {'-':>10s} {'-':>10s} {'-':>10s} "
                f"{'-':>9s} {'-':>7s} {mem/1e9:8.2f}G"
            )
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
            f"{rf['dominant']:10s} {rf['compute_s']:10.4f} "
            f"{rf['memory_s']:10.4f} {rf['collective_s']:10.4f} "
            f"{rf['mfu_bound']:9.4f} {rf['useful_flops_ratio']:7.2f} "
            f"{mem/1e9:8.2f}G"
        )
    return "\n".join(lines)


def _tune_grid(smoke: bool):
    """(P, H, L) cells to tune.  Smoke covers the shapes the smoke bench
    and the parity tests hit; full adds the paper-scale cells."""
    if smoke:
        return [(1024, 3, 16), (4096, 3, 32)]
    return [
        (1024, 3, 16), (4096, 3, 16), (4096, 3, 32),
        (16384, 3, 32), (4096, 5, 32),
    ]


def tune(*, smoke=False, full=False, repeats=3, out_path=None):
    """Sweep LCS kernel parameters and persist the winners.

    For every grid cell the sweep builds one synthetic score-stage
    workload (same generator as bench_score), computes the untuned
    reference LCS matrix once, then measures every candidate:

      block_b          batch-tile cap — only swept where the auto
                       dispatch actually runs the Pallas kernel (TPU);
                       on CPU the wavefront ignores it, so the default
                       is kept rather than recording a meaningless win
      wavefront_dtype  int8 vs int32 anti-diagonal carries (int8 only
                       where L < 127, where the two are bit-identical)

    Every candidate's output is asserted ``np.array_equal`` to the
    reference BEFORE it may win — the table can never hold a tuning
    that changes results.  Winners merge into the existing table (a
    stale table was already invalidated wholesale by ``load``).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.bench_score import _make_inputs, _time_call
    from repro.core.compat import on_tpu
    from repro.core.encoding import PAD_CODE_A, PAD_CODE_B
    from repro.core.similarity import repad
    from repro.kernels.lcs import ops as lcs_ops
    from repro.perf import LCSTuning, TuningTable, tuning_path

    path = pathlib.Path(out_path) if out_path else tuning_path()
    table = TuningTable.load(path)
    block_candidates = (128, 256, 512) if on_tpu() else (512,)
    results = []
    for P, H, L in _tune_grid(smoke and not full):
        codes, lengths, left, right, betas = _make_inputs(P, H, L)
        a = repad(codes[left], lengths[left], PAD_CODE_A).reshape(P * H, L)
        b = repad(codes[right], lengths[right], PAD_CODE_B).reshape(P * H, L)
        ref = np.asarray(jax.jit(lcs_ops.lcs)(a, b))
        dtype_candidates = ("int8", "int32") if L < 127 else ("int32",)
        best = None
        for bb in block_candidates:
            for dt_name in dtype_candidates:
                dt = jnp.int8 if dt_name == "int8" else jnp.int32

                @jax.jit
                def call(a=a, b=b, bb=bb, dt=dt):
                    return lcs_ops.lcs(a, b, block_b=bb, wavefront_dtype=dt)

                got = np.asarray(call())
                if not np.array_equal(got, ref):
                    raise AssertionError(
                        f"candidate block_b={bb} dtype={dt_name} diverges "
                        f"from the untuned default at P={P} H={H} L={L} — "
                        "refusing to record it"
                    )
                wall = _time_call(call, repeats)
                pps = P / wall
                if best is None or pps > best[0]:
                    best = (pps, bb, dt_name)
        pps, bb, dt_name = best
        winner = LCSTuning(block_b=bb, wavefront_dtype=dt_name,
                           pairs_per_sec=round(pps, 1))
        table.record(P, H, L, winner)
        results.append((P, H, L, winner))
    table.save(path)
    return path, results


def _main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tune", action="store_true",
                    help="run the LCS autotune sweep and write the "
                         "tuning table")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny tune grid for CI (seconds, not minutes)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale tune grid")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="tuning-table path (default: $REPRO_TUNING_PATH "
                         "or <repo>/TUNING.json)")
    args = ap.parse_args()
    if not args.tune:
        print(summarize())
        return
    path, results = tune(smoke=args.smoke, full=args.full,
                         repeats=args.repeats, out_path=args.out)
    for P, H, L, t in results:
        print(f"P={P:<6d} H={H} L={L:<3d} -> block_b={t.block_b:<4d} "
              f"dtype={t.wavefront_dtype:<5s} "
              f"{t.pairs_per_sec:>12.0f} pairs/s")
    print(f"wrote {path}")


if __name__ == "__main__":
    _main()
