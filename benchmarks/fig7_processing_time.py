"""Fig. 7 — overall processing time vs number of trajectories, all five
approaches (Centralized, MinHash, BRP, User-defined, AnotherMe).

The paper sweeps 10k..60k on a Xeon cluster; on this single CPU core we
sweep a scaled grid (the asymptotics, not the constants, are the claim:
Centralized/UDF grow ~quadratically, hash-based approaches stay near-linear,
and UDF falls behind Centralized as N grows).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import APPROACHES, Row, make_engine, timeit
from repro.core import udf_pipeline
from repro.core.centralized import centralized_similar_pairs
from repro.core.encoding import encode_batch, forest_tables
from repro.data import synthetic_setup

GRID_QUICK = (500, 1000, 2000)
GRID_FULL = (2_000, 5_000, 10_000, 20_000)
CENTRAL_CAP = 2_500   # beyond this the quadratic baselines need minutes
UDF_CAP = 1_500


def run(full: bool = False) -> list[Row]:
    rows = []
    grid = GRID_FULL if full else GRID_QUICK
    for n in grid:
        batch, forest = synthetic_setup(n, seed=0)
        for name, backend in APPROACHES.items():
            engine = make_engine(forest, backend, community_mode="components")
            t, res = timeit(lambda: engine.run(batch))
            rows.append(Row(f"fig7/{name}/N={n}", t * 1e6,
                            f"similar={len(res.similar_pairs)}"))
        if n <= CENTRAL_CAP:
            enc = encode_batch(batch, forest_tables(forest))
            t, _ = timeit(lambda: centralized_similar_pairs(enc, rho=2.0))
            rows.append(Row(f"fig7/centralized/N={n}", t * 1e6, ""))
        if n <= UDF_CAP:
            t, _ = timeit(lambda: udf_pipeline(
                np.asarray(batch.places), np.asarray(batch.lengths), forest))
            rows.append(Row(f"fig7/udf/N={n}", t * 1e6, ""))
    return rows
