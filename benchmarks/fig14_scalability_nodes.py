"""Fig. 14 — scalability with worker count (paper: 1..20 nodes, 1M
trajectories).  Here: the sharded engine on 1..8 virtual executors
(subprocesses, since device count binds at jax init).  Speedup saturates
as shuffle overhead grows — the paper's observed knee.
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import Row

_CODE = r"""
import time, jax
from repro.api import AnotherMeEngine, EngineConfig, ExecutionPlan
from repro.data import synthetic_setup

N = int({N})
n_shards = len(jax.devices())
batch, forest = synthetic_setup(N, num_types=300, seed=0)
engine = AnotherMeEngine(
    forest, EngineConfig(community_mode="components"),
    ExecutionPlan(n_shards=n_shards))
engine.run(batch)                     # compile + plan + run once
t0 = time.perf_counter()
# warm end-to-end run: the shard_map runner and capacity plan are cached,
# but host-side encode/key transfer/communities are included — this is the
# wall time a user of engine.run sees (the paper also times end-to-end)
engine.run(batch)
print("TIME", time.perf_counter() - t0)
"""


def run(full: bool = False) -> list[Row]:
    n = 20_000 if full else 4_000
    rows = []
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for workers in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={workers}"
        env["PYTHONPATH"] = os.path.join(repo, "src")
        proc = subprocess.run(
            [sys.executable, "-c", _CODE.format(N=n)],
            capture_output=True, text=True, env=env, timeout=1800,
        )
        if proc.returncode != 0:
            rows.append(Row(f"fig14/anotherme/workers={workers}", -1,
                            f"error:{proc.stderr[-120:]}"))
            continue
        t = float(proc.stdout.strip().split()[-1])
        rows.append(Row(f"fig14/anotherme/workers={workers}", t * 1e6,
                        f"N={n}"))
    return rows
