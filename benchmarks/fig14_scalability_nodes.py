"""Fig. 14 — scalability with worker count (paper: 1..20 nodes, 1M
trajectories).  Here: the distributed shard_map pipeline on 1..8 virtual
executors (subprocesses, since device count binds at jax init).  Speedup
saturates as shuffle overhead grows — the paper's observed knee.
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import Row

_CODE = r"""
import time, numpy as np, jax, jax.numpy as jnp
from repro.core import default_betas
from repro.core.distributed import (
    make_distributed_anotherme, plan_capacities, pad_to_shards)
from repro.core.encoding import encode_batch, forest_tables
from repro.core.shingling import shingles_from_types
from repro.core.types import TrajectoryBatch
from repro.data import synthetic_setup

N = int({N})
n_shards = len(jax.devices())
batch, forest = synthetic_setup(N, num_types=300, seed=0)
tables = forest_tables(forest)
places, lengths = pad_to_shards(
    np.asarray(batch.places), np.asarray(batch.lengths), n_shards)
bp = TrajectoryBatch(jnp.asarray(places), jnp.asarray(lengths),
                     jnp.arange(places.shape[0]))
enc = encode_batch(bp, tables)
keys_np = np.asarray(shingles_from_types(
    enc.codes[:, 0, :], bp.lengths, k=3, num_types=300))
plan = plan_capacities(keys_np, n_shards)
mesh = jax.make_mesh((n_shards,), ("ex",),
                     axis_types=(jax.sharding.AxisType.Auto,))
run = make_distributed_anotherme(mesh, plan, k=3, num_types=300,
                                 betas=default_betas(3))
out = run(bp.places, bp.lengths, enc.codes)   # compile + run once
jax.tree.leaves(out)[0].block_until_ready()
t0 = time.perf_counter()
out = run(bp.places, bp.lengths, enc.codes)
jax.tree.leaves(out)[0].block_until_ready()
print("TIME", time.perf_counter() - t0)
"""


def run(full: bool = False) -> list[Row]:
    n = 20_000 if full else 4_000
    rows = []
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for workers in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={workers}"
        env["PYTHONPATH"] = os.path.join(repo, "src")
        proc = subprocess.run(
            [sys.executable, "-c", _CODE.format(N=n)],
            capture_output=True, text=True, env=env, timeout=1800,
        )
        if proc.returncode != 0:
            rows.append(Row(f"fig14/anotherme/workers={workers}", -1,
                            f"error:{proc.stderr[-120:]}"))
            continue
        t = float(proc.stdout.strip().split()[-1])
        rows.append(Row(f"fig14/anotherme/workers={workers}", t * 1e6,
                        f"N={n}"))
    return rows
