"""Score-stage benchmark: pairs/sec for wavefront vs pallas vs fused.

Measures the exact-similarity hot path (``score_pairs`` and its kernels)
in isolation over a grid of pair counts P, level counts H, sequence
lengths L, and MSS-prune rates, and writes a machine-readable
``BENCH_score.json`` so this and every later perf PR leaves a recorded
trajectory (ISSUE 3).  The tier-1 CI workflow runs ``--smoke`` and uploads
the JSON as an artifact per PR.

Implementations measured (dispatch recorded per row — on CPU the Pallas
kernels run under the interpreter and "fused" auto-dispatches to its jnp
reference, so CPU ratios document the harness, not the TPU win):

  wavefront    gather + repad + jnp anti-diagonal wavefront + mss_scores
               (the baseline ``score_pairs`` path)
  pallas       gather + repad + the blocked Pallas LCS kernel
  fused        the gather-free fused kernel: scalar-prefetch gather from
               the resident table, level-fused wavefront, in-block MSS
               (``exact_mss=False``: the pure-throughput epilogue)
  fused+prune  MSS upper-bound prune (compaction included in the timing)
               then fused scoring of the survivors only; pairs/sec still
               counts ALL P pairs — the prune win shows up as throughput

JSON schema (``schema: bench_score/v2``)::

    {
      "schema": "bench_score/v2",
      "backend": "cpu" | "tpu" | ...,
      "jax_version": "...",
      "device_count": int,
      "smoke": bool,
      "rows": [
        {"impl": "fused", "dispatch": "kernel" | "interpret" | "ref"
                          | "wavefront",
         "P": int, "H": int, "L": int, "prune_rate": float,
         "tuned": false, "block_b": int | null,
         "wavefront_dtype": "int8" | "int32" | null,
         "wall_s": float, "pairs_per_sec": float, "repeats": int}, ...
      ],
      "ratios": {"fused_vs_wavefront": {"P=4096,H=3,L=32": float, ...},
                 "pallas_vs_wavefront": {...}},
      "autotune": {   # tuned params vs library defaults, per tuned cell
        "cells": [{"P": ..., "H": ..., "L": ...,
                   "default": {"block_b": 512, "wavefront_dtype": "..."},
                   "tuned": {"block_b": ..., "wavefront_dtype": "..."},
                   "bit_identical": true, "tuned_vs_default": float}, ...]
      },
      "overlap": {    # shuffle-mode hop/score pipelining on vs off
        "skipped": str | null,   # single-device -> reason string
        "cells": [{"n_shards": ..., "cap_local": ..., "H": ..., "L": ...,
                   "pairs": ..., "overlap_chunks": ...,
                   "pairs_per_sec_nc1": float, "pairs_per_sec": float,
                   "overlap_vs_serial": float, "bit_identical": true,
                   "overflow": 0, "steady_state_recompiles": 0}, ...]
      }
    }

The ``autotune`` section compares the :mod:`repro.perf` table winners
(swept fresh by ``benchmarks.roofline.tune`` into a throwaway path)
against the library's built-in defaults — every tuned cell is asserted
bit-identical before its ratio is reported.  On CPU the default diagonal
dtype is already int8 and ``block_b`` only reaches the Pallas kernel, so
the ratio sits near 1.0 there; the section's CPU value is the end-to-end
sweep -> table -> lookup -> dispatch proof, the ratios matter on TPU.

The ``overlap`` section measures the double-buffered owner-hop pipeline
(``overlap_chunks``) of the sharded shuffle score path against the serial
nc=1 program on the same inputs: score maps must match exactly, overflow
must be zero (exact per-chunk planning), and the trace counter must show
zero steady-state recompiles.  Needs >= 2 devices — run under ``run.sh``
(which fakes 8 host devices on CPU); skipped with a reason otherwise.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_HERE = os.path.dirname(os.path.abspath(__file__))
_REPO = os.path.dirname(_HERE)
for p in (_REPO, os.path.join(_REPO, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import jax
import jax.numpy as jnp
import numpy as np

IMPLS = ("wavefront", "pallas", "fused", "fused+prune")


def _make_inputs(P, H, L, *, n_rows=None, seed=0):
    """A synthetic score-stage workload: resident code table + pair list.

    Lengths are skewed (heavy short head) so prune rates are controllable
    via a quantile threshold, matching real trajectory length
    distributions.
    """
    rng = np.random.default_rng(seed)
    N = n_rows or max(256, P // 8)
    w = 1.0 / np.arange(1, L + 1)
    lengths = rng.choice(np.arange(1, L + 1), size=N, p=w / w.sum())
    lengths = lengths.astype(np.int32)
    codes = rng.integers(0, 30, size=(N, H, L)).astype(np.int32)
    pad = np.arange(L)[None, None, :] >= lengths[:, None, None]
    codes = np.where(pad, -1, codes)
    left = rng.integers(0, N, size=P).astype(np.int32)
    right = rng.integers(0, N, size=P).astype(np.int32)
    betas = np.full((H,), 1.0 / H, np.float32)
    return (jnp.asarray(codes), jnp.asarray(lengths), jnp.asarray(left),
            jnp.asarray(right), jnp.asarray(betas))


def _tau_for_rate(lengths, left, right, betas, prune_rate):
    """The tau whose upper-bound prune drops ~prune_rate of the pairs."""
    if prune_rate <= 0.0:
        return None
    from repro.core.similarity import mss_upper_bound

    lengths, left, right = map(np.asarray, (lengths, left, right))
    ub = mss_upper_bound(
        lengths[left], lengths[right], float(np.asarray(betas).sum())
    )
    return float(np.quantile(ub, prune_rate))


def _build_call(impl, codes, lengths, left, right, betas, tau):
    """(callable returning mss, dispatch label) for one measured impl."""
    from repro.core.similarity import (
        PRUNE_EPS, mss_scores, mss_upper_bound, repad, score_pairs,
    )
    from repro.core.encoding import PAD_CODE_A, PAD_CODE_B
    from repro.kernels.lcs import ops as lcs_ops
    from repro.kernels.lcs.fused import fused_score

    from repro.core.compat import on_tpu as _on_tpu

    on_tpu = _on_tpu()
    P = left.shape[0]
    H, L = codes.shape[1], codes.shape[2]

    if impl == "wavefront":
        def call():
            _, mss = score_pairs(codes, lengths, left, right, betas,
                                 impl_name="wavefront")
            return mss

        return call, "wavefront"

    if impl == "pallas":
        @jax.jit
        def call():
            a = repad(codes[left], lengths[left], PAD_CODE_A)
            b = repad(codes[right], lengths[right], PAD_CODE_B)
            lv = lcs_ops.lcs(a.reshape(P * H, L), b.reshape(P * H, L),
                             mode="pallas").reshape(P, H)
            return mss_scores(lv, betas)

        return call, ("kernel" if on_tpu else "interpret")

    if impl == "fused":
        @jax.jit
        def call():
            _, mss = fused_score(codes, lengths, codes, lengths, left, right,
                                 betas, mode="auto", exact_mss=False)
            return mss

        return call, ("kernel" if on_tpu else "ref")

    if impl == "fused+prune":
        t = 0.0 if tau is None else tau
        bsum = jnp.sum(betas)
        # host-planned post-prune capacity, as CapacityPlanner sizes it:
        # exact scoring then runs over the survivor buffer only
        ub_host = mss_upper_bound(
            np.asarray(lengths)[np.asarray(left)],
            np.asarray(lengths)[np.asarray(right)],
            float(np.asarray(betas).sum()),
        )
        cap = max(1, int((ub_host > np.float32(t - PRUNE_EPS)).sum()))

        @jax.jit
        def call():
            ub = mss_upper_bound(lengths[left], lengths[right], bsum)
            keep = ub > t - PRUNE_EPS
            order = jnp.argsort(jnp.logical_not(keep), stable=True)
            n_keep = jnp.minimum(jnp.sum(keep), cap)
            sl, sr = left[order][:cap], right[order][:cap]
            _, mss = fused_score(codes, lengths, codes, lengths, sl, sr,
                                 betas, mode="auto", exact_mss=False)
            return jnp.where(jnp.arange(cap) < n_keep, mss, -1.0)

        return call, ("kernel" if on_tpu else "ref")

    raise ValueError(f"unknown impl {impl!r}")


def _time_call(call, repeats):
    call().block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = call()
    out.block_until_ready()
    return (time.perf_counter() - t0) / repeats


def _default_params(impl):
    """The (block_b, wavefront_dtype) an UNTUNED row actually ran with."""
    from repro.core.similarity import wavefront_dtype_from_env

    if impl == "wavefront":
        return None, np.dtype(wavefront_dtype_from_env()).name
    if impl == "pallas":
        return 512, None  # kernels/lcs/ops.lcs block_b default
    return None, None     # fused paths tile internally


def run_grid(grid, *, repeats=3, impls=IMPLS):
    """Measure every (P, H, L, prune_rate) cell; returns the rows list."""
    rows = []
    for P, H, L, prune_rate in grid:
        codes, lengths, left, right, betas = _make_inputs(P, H, L)
        tau = _tau_for_rate(lengths, left, right, betas, prune_rate)
        for impl in impls:
            if impl == "fused+prune" and prune_rate <= 0.0:
                continue
            if impl != "fused+prune" and prune_rate > 0.0:
                continue  # prune rates only vary the fused+prune rows
            call, dispatch = _build_call(
                impl, codes, lengths, left, right, betas, tau
            )
            wall = _time_call(call, repeats)
            block_b, wf_dtype = _default_params(impl)
            rows.append({
                "impl": impl, "dispatch": dispatch,
                "P": P, "H": H, "L": L, "prune_rate": prune_rate,
                "tuned": False, "block_b": block_b,
                "wavefront_dtype": wf_dtype,
                "wall_s": wall, "pairs_per_sec": P / wall,
                "repeats": repeats,
            })
    return rows


def _ratios(rows):
    base = {(r["P"], r["H"], r["L"]): r["pairs_per_sec"]
            for r in rows if r["impl"] == "wavefront"}
    out = {}
    for impl in ("pallas", "fused", "fused+prune"):
        rs = {}
        for r in rows:
            if r["impl"] != impl:
                continue
            key = (r["P"], r["H"], r["L"])
            if key not in base:
                continue
            tag = f"P={key[0]},H={key[1]},L={key[2]}"
            if impl == "fused+prune":
                tag += f",prune={r['prune_rate']}"
            rs[tag] = round(r["pairs_per_sec"] / base[key], 3)
        if rs:
            out[f"{impl.replace('+', '_')}_vs_wavefront"] = rs
    return out


def _grid(smoke, full):
    if smoke:
        return [(256, 3, 16, 0.0), (1024, 3, 16, 0.0), (1024, 3, 16, 0.7)]
    grid = []
    for P in (1024, 4096) + ((16384,) if full else ()):
        for L in (16, 32):
            grid.append((P, 3, L, 0.0))
            grid.append((P, 3, L, 0.5))
            grid.append((P, 3, L, 0.9))
    if full:
        grid.append((4096, 5, 32, 0.0))
    return grid


def _bench_autotune(*, repeats=2):
    """Tuned-vs-default section: sweep -> table -> lookup -> dispatch.

    Runs the real ``benchmarks.roofline.tune`` sweep into a throwaway
    table path, loads it back through :class:`repro.perf.TuningTable`,
    and re-measures each tuned cell against the library defaults.  Every
    tuned cell is asserted ``np.array_equal`` to the default's LCS matrix
    before its throughput ratio is reported — the committed benchmark is
    itself the bit-identity regression check.
    """
    import tempfile

    from benchmarks.roofline import _tune_grid, tune
    from repro.core.encoding import PAD_CODE_A, PAD_CODE_B
    from repro.core.similarity import repad
    from repro.kernels.lcs import ops as lcs_ops
    from repro.perf import TuningTable, resolve_wavefront_dtype

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "TUNING.json")
        tune(smoke=True, repeats=repeats, out_path=path)
        table = TuningTable.load(path)
        cells = []
        for P, H, L in _tune_grid(True):
            t = table.lookup(P, H, L)
            if t is None:
                continue
            codes, lengths, left, right, _ = _make_inputs(P, H, L)
            a = repad(codes[left], lengths[left], PAD_CODE_A)
            b = repad(codes[right], lengths[right], PAD_CODE_B)
            a, b = a.reshape(P * H, L), b.reshape(P * H, L)
            default = jax.jit(lcs_ops.lcs)

            tuned_dt = resolve_wavefront_dtype(t)

            @jax.jit
            def tuned(a=a, b=b, t=t, dt=tuned_dt):
                return lcs_ops.lcs(a, b, block_b=t.block_b,
                                   wavefront_dtype=dt)

            ident = bool(np.array_equal(np.asarray(default(a, b)),
                                        np.asarray(tuned())))
            assert ident, f"tuned params diverge at P={P} H={H} L={L}"
            w_def = _time_call(lambda: default(a, b), repeats)
            w_tun = _time_call(tuned, repeats)
            dflt_bb, dflt_dt = 512, _default_params("wavefront")[1]
            cells.append({
                "P": P, "H": H, "L": L,
                "default": {"block_b": dflt_bb, "wavefront_dtype": dflt_dt},
                "tuned": {"block_b": t.block_b,
                          "wavefront_dtype": np.dtype(tuned_dt).name},
                "bit_identical": ident,
                "tuned_vs_default": round(w_def / w_tun, 3),
            })
    return {"cells": cells}


# overlap cells: (n_shards, cap_local, H, L, pairs, overlap_chunks) —
# L=32 with ~4-8k-pair sub-chunks is where the hop/score pipeline's cache
# blocking pays on CPU; on real meshes the win is hop/compute overlap
_OVERLAP_CELLS = (
    (2, 4096, 3, 32, 65536, 8),
    (4, 2048, 3, 32, 65536, 8),
)


def _bench_overlap(*, repeats=3, cells=_OVERLAP_CELLS):
    """Overlap-on vs overlap-off for the sharded shuffle score path.

    Builds the real :func:`repro.api.sharded.make_streaming_score_pipeline`
    (the hop+score program, no join) over a synthetic resident world and
    measures the identical delta-pair workload at ``overlap_chunks=1`` vs
    the cell's chunk count.  Per cell it asserts the (left, right) -> mss
    score map matches exactly (chunking only reorders output slots),
    overflow stays zero (exact per-chunk capacity planning) and the trace
    counter records zero steady-state recompiles after the first call.
    """
    from jax.sharding import Mesh

    from repro.api.sharded import (
        make_streaming_score_pipeline, plan_stream_capacities,
    )
    from repro.core.types import PAD_ID

    n_dev = jax.device_count()
    if n_dev < 2:
        return {
            "skipped": f"needs >= 2 devices, have {n_dev} "
                       "(run under ./run.sh to fake 8 host devices)",
            "cells": [],
        }

    def world(n_shards, cap_local, H, L, num_places=64, seed=0):
        rng = np.random.default_rng(seed)
        N = n_shards * cap_local
        w = 1.0 / np.arange(1, L + 1)
        lens = rng.choice(np.arange(1, L + 1), size=N, p=w / w.sum())
        places = np.full((N, L), -1, np.int32)
        for i in range(N):
            places[i, :lens[i]] = rng.integers(0, num_places, lens[i])
        g = np.arange(N)  # round-robin physical world layout
        phys = (g % n_shards) * cap_local + g // n_shards
        places_phys = np.empty_like(places)
        places_phys[phys] = places
        tables = rng.integers(0, 30, size=(H, num_places)).astype(np.int32)
        return places_phys, tables

    def pair_buffers(lo, hi, n_shards, pair_cap):
        # contiguous source chunks, front slots — the layout
        # plan_stream_capacities sizes the per-chunk hops for
        P = lo.shape[0]
        chunk = -(-P // n_shards)
        bl = np.full((n_shards * pair_cap,), PAD_ID, np.int32)
        br = np.full((n_shards * pair_cap,), PAD_ID, np.int32)
        for s in range(n_shards):
            a, b = s * chunk, min((s + 1) * chunk, P)
            bl[s * pair_cap: s * pair_cap + (b - a)] = lo[a:b]
            br[s * pair_cap: s * pair_cap + (b - a)] = hi[a:b]
        return bl, br

    out_cells = []
    for n_shards, cap_local, H, L, P, nc in cells:
        if n_dev < n_shards:
            continue
        rng = np.random.default_rng(1)
        N = n_shards * cap_local
        places, tables = world(n_shards, cap_local, H, L)
        lo = rng.integers(0, N, size=P).astype(np.int64)
        hi = rng.integers(0, N, size=P).astype(np.int64)
        betas = jnp.full((H,), 1.0 / H, jnp.float32)
        mesh = Mesh(np.array(jax.devices()[:n_shards]), ("ex",))
        res = {}
        for chunks in (1, nc):
            plan = plan_stream_capacities(
                lo, hi, n_shards, cap_local,
                score_mode="shuffle", overlap_chunks=chunks,
            )
            bl, br = pair_buffers(lo, hi, n_shards, plan.pair_cap)
            tc = [0]
            fn = make_streaming_score_pipeline(
                mesh, plan, betas=betas, score_mode="shuffle",
                lcs_impl="wavefront", trace_counter=tc,
            )
            args = (jnp.asarray(places), jnp.asarray(bl), jnp.asarray(br),
                    jnp.asarray(tables))
            r = fn(*args)
            jax.block_until_ready(r)
            traces_warm = tc[0]
            wall = _time_call(lambda: fn(*args)["mss"], repeats)
            r = fn(*args)
            ovf = int(np.asarray(r["overflow"]).sum())
            l = np.asarray(r["left"]).ravel()
            rr = np.asarray(r["right"]).ravel()
            m = np.asarray(r["mss"]).ravel()
            keep = l != PAD_ID
            smap = dict(zip(zip(l[keep].tolist(), rr[keep].tolist()),
                            m[keep].tolist()))
            res[chunks] = (wall, smap, ovf, tc[0] - traces_warm)
        w1, s1, o1, rc1 = res[1]
        wn, sn, on, rcn = res[nc]
        ident = s1 == sn
        assert ident, f"chunked scores diverge at {(n_shards, L, P, nc)}"
        out_cells.append({
            "n_shards": n_shards, "cap_local": cap_local, "H": H, "L": L,
            "pairs": P, "overlap_chunks": nc,
            "pairs_per_sec_nc1": round(P / w1, 1),
            "pairs_per_sec": round(P / wn, 1),
            "overlap_vs_serial": round(w1 / wn, 3),
            "bit_identical": ident,
            "overflow": on + o1,
            "steady_state_recompiles": rcn + rc1,
        })
    return {"skipped": None, "cells": out_cells}


def bench(*, smoke=False, full=False, repeats=None, out_path=None):
    repeats = repeats or (2 if smoke else 5)
    rows = run_grid(_grid(smoke, full), repeats=repeats)
    report = {
        "schema": "bench_score/v2",
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "device_count": jax.device_count(),
        "smoke": bool(smoke),
        "rows": rows,
        "ratios": _ratios(rows),
        "autotune": _bench_autotune(repeats=repeats),
        "overlap": _bench_overlap(repeats=max(repeats, 3)),
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
    return report


def run(full: bool = False):
    """benchmarks/run.py entry point: CSV rows + BENCH_score.json."""
    from benchmarks.common import Row

    report = bench(smoke=not full, full=full,
                   out_path=os.path.join(_REPO, "BENCH_score.json"))
    for r in report["rows"]:
        name = (f"bench_score/{r['impl']}/P{r['P']}_H{r['H']}_L{r['L']}"
                f"_prune{r['prune_rate']}")
        yield Row(name, r["wall_s"] * 1e6,
                  f"{r['pairs_per_sec']:.0f} pairs/s [{r['dispatch']}]")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (seconds, not minutes)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grid (adds P=16384, H=5)")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--out", default="BENCH_score.json")
    args = ap.parse_args()
    report = bench(smoke=args.smoke, full=args.full, repeats=args.repeats,
                   out_path=args.out)
    print(f"# backend={report['backend']} jax={report['jax_version']}")
    for r in report["rows"]:
        print(f"{r['impl']:12s} P={r['P']:<6d} H={r['H']} L={r['L']:<3d} "
              f"prune={r['prune_rate']:.1f} [{r['dispatch']:9s}] "
              f"{r['pairs_per_sec']:>12.0f} pairs/s")
    for name, rs in report["ratios"].items():
        for tag, v in rs.items():
            print(f"# {name} {tag}: {v}x")
    for c in report["autotune"]["cells"]:
        print(f"# autotune P={c['P']},H={c['H']},L={c['L']}: "
              f"block_b={c['tuned']['block_b']} "
              f"dtype={c['tuned']['wavefront_dtype']} "
              f"tuned_vs_default={c['tuned_vs_default']}x "
              f"bit_identical={c['bit_identical']}")
    ov = report["overlap"]
    if ov["skipped"]:
        print(f"# overlap: skipped ({ov['skipped']})")
    for c in ov["cells"]:
        print(f"# overlap sh={c['n_shards']} L={c['L']} P={c['pairs']} "
              f"nc={c['overlap_chunks']}: {c['overlap_vs_serial']}x "
              f"(ovf={c['overflow']}, "
              f"recompiles={c['steady_state_recompiles']})")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
