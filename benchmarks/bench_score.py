"""Score-stage benchmark: pairs/sec for wavefront vs pallas vs fused.

Measures the exact-similarity hot path (``score_pairs`` and its kernels)
in isolation over a grid of pair counts P, level counts H, sequence
lengths L, and MSS-prune rates, and writes a machine-readable
``BENCH_score.json`` so this and every later perf PR leaves a recorded
trajectory (ISSUE 3).  The tier-1 CI workflow runs ``--smoke`` and uploads
the JSON as an artifact per PR.

Implementations measured (dispatch recorded per row — on CPU the Pallas
kernels run under the interpreter and "fused" auto-dispatches to its jnp
reference, so CPU ratios document the harness, not the TPU win):

  wavefront    gather + repad + jnp anti-diagonal wavefront + mss_scores
               (the baseline ``score_pairs`` path)
  pallas       gather + repad + the blocked Pallas LCS kernel
  fused        the gather-free fused kernel: scalar-prefetch gather from
               the resident table, level-fused wavefront, in-block MSS
               (``exact_mss=False``: the pure-throughput epilogue)
  fused+prune  MSS upper-bound prune (compaction included in the timing)
               then fused scoring of the survivors only; pairs/sec still
               counts ALL P pairs — the prune win shows up as throughput

JSON schema (``schema: bench_score/v1``)::

    {
      "schema": "bench_score/v1",
      "backend": "cpu" | "tpu" | ...,
      "jax_version": "...",
      "smoke": bool,
      "rows": [
        {"impl": "fused", "dispatch": "kernel" | "interpret" | "ref"
                          | "wavefront",
         "P": int, "H": int, "L": int, "prune_rate": float,
         "wall_s": float, "pairs_per_sec": float, "repeats": int}, ...
      ],
      "ratios": {"fused_vs_wavefront": {"P=4096,H=3,L=32": float, ...},
                 "pallas_vs_wavefront": {...}}
    }
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_HERE = os.path.dirname(os.path.abspath(__file__))
_REPO = os.path.dirname(_HERE)
for p in (_REPO, os.path.join(_REPO, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import jax
import jax.numpy as jnp
import numpy as np

IMPLS = ("wavefront", "pallas", "fused", "fused+prune")


def _make_inputs(P, H, L, *, n_rows=None, seed=0):
    """A synthetic score-stage workload: resident code table + pair list.

    Lengths are skewed (heavy short head) so prune rates are controllable
    via a quantile threshold, matching real trajectory length
    distributions.
    """
    rng = np.random.default_rng(seed)
    N = n_rows or max(256, P // 8)
    w = 1.0 / np.arange(1, L + 1)
    lengths = rng.choice(np.arange(1, L + 1), size=N, p=w / w.sum())
    lengths = lengths.astype(np.int32)
    codes = rng.integers(0, 30, size=(N, H, L)).astype(np.int32)
    pad = np.arange(L)[None, None, :] >= lengths[:, None, None]
    codes = np.where(pad, -1, codes)
    left = rng.integers(0, N, size=P).astype(np.int32)
    right = rng.integers(0, N, size=P).astype(np.int32)
    betas = np.full((H,), 1.0 / H, np.float32)
    return (jnp.asarray(codes), jnp.asarray(lengths), jnp.asarray(left),
            jnp.asarray(right), jnp.asarray(betas))


def _tau_for_rate(lengths, left, right, betas, prune_rate):
    """The tau whose upper-bound prune drops ~prune_rate of the pairs."""
    if prune_rate <= 0.0:
        return None
    from repro.core.similarity import mss_upper_bound

    lengths, left, right = map(np.asarray, (lengths, left, right))
    ub = mss_upper_bound(
        lengths[left], lengths[right], float(np.asarray(betas).sum())
    )
    return float(np.quantile(ub, prune_rate))


def _build_call(impl, codes, lengths, left, right, betas, tau):
    """(callable returning mss, dispatch label) for one measured impl."""
    from repro.core.similarity import (
        PRUNE_EPS, mss_scores, mss_upper_bound, repad, score_pairs,
    )
    from repro.core.encoding import PAD_CODE_A, PAD_CODE_B
    from repro.kernels.lcs import ops as lcs_ops
    from repro.kernels.lcs.fused import fused_score

    on_tpu = jax.default_backend() == "tpu"
    P = left.shape[0]
    H, L = codes.shape[1], codes.shape[2]

    if impl == "wavefront":
        def call():
            _, mss = score_pairs(codes, lengths, left, right, betas,
                                 impl_name="wavefront")
            return mss

        return call, "wavefront"

    if impl == "pallas":
        @jax.jit
        def call():
            a = repad(codes[left], lengths[left], PAD_CODE_A)
            b = repad(codes[right], lengths[right], PAD_CODE_B)
            lv = lcs_ops.lcs(a.reshape(P * H, L), b.reshape(P * H, L),
                             mode="pallas").reshape(P, H)
            return mss_scores(lv, betas)

        return call, ("kernel" if on_tpu else "interpret")

    if impl == "fused":
        @jax.jit
        def call():
            _, mss = fused_score(codes, lengths, codes, lengths, left, right,
                                 betas, mode="auto", exact_mss=False)
            return mss

        return call, ("kernel" if on_tpu else "ref")

    if impl == "fused+prune":
        t = 0.0 if tau is None else tau
        bsum = jnp.sum(betas)
        # host-planned post-prune capacity, as CapacityPlanner sizes it:
        # exact scoring then runs over the survivor buffer only
        ub_host = mss_upper_bound(
            np.asarray(lengths)[np.asarray(left)],
            np.asarray(lengths)[np.asarray(right)],
            float(np.asarray(betas).sum()),
        )
        cap = max(1, int((ub_host > np.float32(t - PRUNE_EPS)).sum()))

        @jax.jit
        def call():
            ub = mss_upper_bound(lengths[left], lengths[right], bsum)
            keep = ub > t - PRUNE_EPS
            order = jnp.argsort(jnp.logical_not(keep), stable=True)
            n_keep = jnp.minimum(jnp.sum(keep), cap)
            sl, sr = left[order][:cap], right[order][:cap]
            _, mss = fused_score(codes, lengths, codes, lengths, sl, sr,
                                 betas, mode="auto", exact_mss=False)
            return jnp.where(jnp.arange(cap) < n_keep, mss, -1.0)

        return call, ("kernel" if on_tpu else "ref")

    raise ValueError(f"unknown impl {impl!r}")


def _time_call(call, repeats):
    call().block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = call()
    out.block_until_ready()
    return (time.perf_counter() - t0) / repeats


def run_grid(grid, *, repeats=3, impls=IMPLS):
    """Measure every (P, H, L, prune_rate) cell; returns the rows list."""
    rows = []
    for P, H, L, prune_rate in grid:
        codes, lengths, left, right, betas = _make_inputs(P, H, L)
        tau = _tau_for_rate(lengths, left, right, betas, prune_rate)
        for impl in impls:
            if impl == "fused+prune" and prune_rate <= 0.0:
                continue
            if impl != "fused+prune" and prune_rate > 0.0:
                continue  # prune rates only vary the fused+prune rows
            call, dispatch = _build_call(
                impl, codes, lengths, left, right, betas, tau
            )
            wall = _time_call(call, repeats)
            rows.append({
                "impl": impl, "dispatch": dispatch,
                "P": P, "H": H, "L": L, "prune_rate": prune_rate,
                "wall_s": wall, "pairs_per_sec": P / wall,
                "repeats": repeats,
            })
    return rows


def _ratios(rows):
    base = {(r["P"], r["H"], r["L"]): r["pairs_per_sec"]
            for r in rows if r["impl"] == "wavefront"}
    out = {}
    for impl in ("pallas", "fused", "fused+prune"):
        rs = {}
        for r in rows:
            if r["impl"] != impl:
                continue
            key = (r["P"], r["H"], r["L"])
            if key not in base:
                continue
            tag = f"P={key[0]},H={key[1]},L={key[2]}"
            if impl == "fused+prune":
                tag += f",prune={r['prune_rate']}"
            rs[tag] = round(r["pairs_per_sec"] / base[key], 3)
        if rs:
            out[f"{impl.replace('+', '_')}_vs_wavefront"] = rs
    return out


def _grid(smoke, full):
    if smoke:
        return [(256, 3, 16, 0.0), (1024, 3, 16, 0.0), (1024, 3, 16, 0.7)]
    grid = []
    for P in (1024, 4096) + ((16384,) if full else ()):
        for L in (16, 32):
            grid.append((P, 3, L, 0.0))
            grid.append((P, 3, L, 0.5))
            grid.append((P, 3, L, 0.9))
    if full:
        grid.append((4096, 5, 32, 0.0))
    return grid


def bench(*, smoke=False, full=False, repeats=None, out_path=None):
    repeats = repeats or (2 if smoke else 5)
    rows = run_grid(_grid(smoke, full), repeats=repeats)
    report = {
        "schema": "bench_score/v1",
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "smoke": bool(smoke),
        "rows": rows,
        "ratios": _ratios(rows),
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
    return report


def run(full: bool = False):
    """benchmarks/run.py entry point: CSV rows + BENCH_score.json."""
    from benchmarks.common import Row

    report = bench(smoke=not full, full=full,
                   out_path=os.path.join(_REPO, "BENCH_score.json"))
    for r in report["rows"]:
        name = (f"bench_score/{r['impl']}/P{r['P']}_H{r['H']}_L{r['L']}"
                f"_prune{r['prune_rate']}")
        yield Row(name, r["wall_s"] * 1e6,
                  f"{r['pairs_per_sec']:.0f} pairs/s [{r['dispatch']}]")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (seconds, not minutes)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grid (adds P=16384, H=5)")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--out", default="BENCH_score.json")
    args = ap.parse_args()
    report = bench(smoke=args.smoke, full=args.full, repeats=args.repeats,
                   out_path=args.out)
    print(f"# backend={report['backend']} jax={report['jax_version']}")
    for r in report["rows"]:
        print(f"{r['impl']:12s} P={r['P']:<6d} H={r['H']} L={r['L']:<3d} "
              f"prune={r['prune_rate']:.1f} [{r['dispatch']:9s}] "
              f"{r['pairs_per_sec']:>12.0f} pairs/s")
    for name, rs in report["ratios"].items():
        for tag, v in rs.items():
            print(f"# {name} {tag}: {v}x")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
