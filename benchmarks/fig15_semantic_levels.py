"""Fig. 15 — effect of the number of semantic levels (2..6) on accuracy
(stays 100%) and processing time (grows with levels)."""
from __future__ import annotations

from benchmarks.common import Row, centralized_truth, make_engine, timeit
from repro.core import qa1, qa2
from repro.data import synthetic_setup


def run(full: bool = False) -> list[Row]:
    n = 1_000 if full else 300
    rows = []
    for n_levels in (2, 3, 4, 5, 6):
        batch, forest = synthetic_setup(
            n, num_types=10, classes_per_type=5, num_places=400,
            n_levels=n_levels, seed=0,
        )
        cen_pairs, cen_comms = centralized_truth(batch, forest)
        engine = make_engine(forest, "ssh")
        t, res = timeit(lambda: engine.run(batch))
        rows.append(Row(
            f"fig15/anotherme/levels={n_levels}", t * 1e6,
            f"QA1={qa1(res.communities, cen_comms):.3f};"
            f"QA2={qa2(res.similar_pairs, cen_pairs):.3f}",
        ))
    return rows
