"""Soft-fail perf-regression gate over BENCH_score.json.

Compares a freshly measured score benchmark against the committed
baseline (``git show HEAD:BENCH_score.json`` by default) and WARNS —
never fails — when any matched cell moved more than the threshold in
either direction.  Shared CI runners are far too noisy for a hard gate
(the committed baseline was measured on a different machine entirely),
but a 5x cliff that would previously sail through unnoticed now leaves a
``::warning::`` annotation on the PR with the exact cell that moved.

Rows match on (impl, P, H, L, prune_rate) and compare pairs/sec; overlap
cells match on (n_shards, cap_local, pairs, overlap_chunks) and compare
the overlap-vs-serial ratio.  Baselines with a different schema, backend
or device count are skipped outright — a cross-machine comparison is not
a regression signal.  Exit code is always 0; ``--hard`` exists for local
use where the machine IS comparable.

Usage::

    ./run.sh -m benchmarks.bench_score --smoke --out BENCH_fresh.json
    ./run.sh -m benchmarks.check_regression BENCH_fresh.json
    ./run.sh -m benchmarks.check_regression BENCH_fresh.json \
        --baseline BENCH_score.json --threshold 0.2
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys


def _load_baseline(spec: str):
    """A baseline spec: 'git:<rev>' (committed file) or a plain path."""
    if spec.startswith("git:"):
        try:
            out = subprocess.run(
                ["git", "show", f"{spec[4:]}:BENCH_score.json"],
                capture_output=True, text=True, check=True,
            ).stdout
        except (OSError, subprocess.CalledProcessError):
            return None
        return json.loads(out)
    try:
        with open(spec) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _row_key(r):
    return (r["impl"], r["P"], r["H"], r["L"], r["prune_rate"])


def _overlap_key(c):
    return (c["n_shards"], c["cap_local"], c["pairs"], c["overlap_chunks"])


def compare(fresh: dict, base: dict, threshold: float) -> list[str]:
    """Warning strings for every matched cell past the threshold."""
    warnings = []
    for field in ("schema", "backend", "device_count"):
        if fresh.get(field) != base.get(field):
            return [
                f"baseline not comparable ({field}: "
                f"{base.get(field)!r} vs {fresh.get(field)!r}) — skipping"
            ]
    base_rows = {_row_key(r): r for r in base.get("rows", [])}
    for r in fresh.get("rows", []):
        b = base_rows.get(_row_key(r))
        if b is None or not b.get("pairs_per_sec"):
            continue
        ratio = r["pairs_per_sec"] / b["pairs_per_sec"]
        if abs(ratio - 1.0) > threshold:
            verb = "slowdown" if ratio < 1.0 else "speedup"
            warnings.append(
                f"{r['impl']} P={r['P']} H={r['H']} L={r['L']} "
                f"prune={r['prune_rate']}: {ratio:.2f}x {verb} "
                f"({b['pairs_per_sec']:.0f} -> {r['pairs_per_sec']:.0f} "
                f"pairs/s)"
            )
    base_ov = {_overlap_key(c): c
               for c in base.get("overlap", {}).get("cells", [])}
    for c in fresh.get("overlap", {}).get("cells", []):
        b = base_ov.get(_overlap_key(c))
        if b is None or not b.get("overlap_vs_serial"):
            continue
        ratio = c["overlap_vs_serial"] / b["overlap_vs_serial"]
        if abs(ratio - 1.0) > threshold:
            warnings.append(
                f"overlap sh={c['n_shards']} P={c['pairs']} "
                f"nc={c['overlap_chunks']}: overlap_vs_serial "
                f"{b['overlap_vs_serial']} -> {c['overlap_vs_serial']}"
            )
    return warnings


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="freshly measured BENCH_score.json")
    ap.add_argument("--baseline", default="git:HEAD",
                    help="'git:<rev>' or a path (default: git:HEAD)")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="warn when |ratio - 1| exceeds this (default 0.20)")
    ap.add_argument("--hard", action="store_true",
                    help="exit 1 on warnings (local comparable machines)")
    args = ap.parse_args(argv)

    with open(args.fresh) as f:
        fresh = json.load(f)
    base = _load_baseline(args.baseline)
    if base is None:
        print(f"no baseline at {args.baseline!r} — nothing to compare")
        return 0
    warnings = compare(fresh, base, args.threshold)
    if not warnings:
        print(f"perf check: all matched cells within "
              f"+/-{args.threshold:.0%} of {args.baseline}")
        return 0
    for w in warnings:
        print(f"::warning title=perf drift::{w}")
    print(f"{len(warnings)} cell(s) drifted past +/-{args.threshold:.0%} "
          f"(soft-fail: informational on shared runners)")
    return 1 if args.hard else 0


if __name__ == "__main__":
    sys.exit(main())
