"""Fig. 10 — QA1/QA2 accuracy vs N per approach (synthetic).

The reproduction target: AnotherMe == 100% on both metrics at every N;
MinHash/BRP degrade (BRP worst)."""
from __future__ import annotations

from benchmarks.common import APPROACHES, Row, centralized_truth, make_engine
from repro.core import qa1, qa2
from repro.data import synthetic_setup

GRID_QUICK = (300, 600)
GRID_FULL = (1_000, 2_000)


def run(full: bool = False) -> list[Row]:
    rows = []
    for n in (GRID_FULL if full else GRID_QUICK):
        batch, forest = synthetic_setup(
            n, num_types=10, classes_per_type=5, num_places=500, seed=0
        )
        cen_pairs, cen_comms = centralized_truth(batch, forest)
        for name, backend in APPROACHES.items():
            res = make_engine(forest, backend).run(batch)
            rows.append(Row(
                f"fig10/{name}/N={n}", 0.0,
                f"QA1={qa1(res.communities, cen_comms):.3f};"
                f"QA2={qa2(res.similar_pairs, cen_pairs):.3f}",
            ))
    return rows
