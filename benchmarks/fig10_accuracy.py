"""Fig. 10 — QA1/QA2 accuracy vs N per approach (synthetic).

The reproduction target: AnotherMe == 100% on both metrics at every N;
MinHash/BRP degrade (BRP worst).

``--subtraj`` runs the subtrajectory variant of the same figure: engines
in windowed-candidate mode (``EngineConfig(subtraj_window=W)``) against a
brute-force windowed truth that scores EVERY window pair — the exact
backends must stay at 100% while the approximate hashes degrade, now on
"find a matching hour" instead of "find a matching life".  Completeness
of the exact backends holds because the defaults satisfy
``rho >= (k - 1) * sum(betas)``: any window pair with MSS > rho has a
type-LCS >= k at some level, hence shares a k-shingle and is a candidate.
"""
from __future__ import annotations

from benchmarks.common import (
    APPROACHES, Row, centralized_truth, make_engine, windowed_truth,
)
from repro.core import qa1, qa2
from repro.data import synthetic_setup

GRID_QUICK = (300, 600)
GRID_FULL = (1_000, 2_000)

# Subtrajectory grids are smaller: the truth is O((N * nw)^2) window pairs.
SUBTRAJ_GRID_QUICK = (100, 200)
SUBTRAJ_GRID_FULL = (300, 600)
SUBTRAJ_WINDOW = 8


def _run_subtraj(full: bool) -> list[Row]:
    rows = []
    for n in (SUBTRAJ_GRID_FULL if full else SUBTRAJ_GRID_QUICK):
        # longer rows than the whole-trajectory grid so windows are real
        # subtrajectories (nw = L - W + 1 = 13 windows per row), same
        # forest shape as the base figure
        batch, forest = synthetic_setup(
            n, num_types=10, classes_per_type=5, num_places=500, seed=0,
            min_len=10, max_len=20,
        )
        cen_pairs, cen_comms = windowed_truth(
            batch, forest, window=SUBTRAJ_WINDOW
        )
        for name, backend in APPROACHES.items():
            res = make_engine(
                forest, backend, subtraj_window=SUBTRAJ_WINDOW
            ).run(batch)
            rows.append(Row(
                f"fig10-subtraj/{name}/N={n}/W={SUBTRAJ_WINDOW}", 0.0,
                f"QA1={qa1(res.communities, cen_comms):.3f};"
                f"QA2={qa2(res.similar_pairs, cen_pairs):.3f}",
            ))
    return rows


def run(full: bool = False, subtraj: bool = False) -> list[Row]:
    if subtraj:
        return _run_subtraj(full)
    rows = []
    for n in (GRID_FULL if full else GRID_QUICK):
        batch, forest = synthetic_setup(
            n, num_types=10, classes_per_type=5, num_places=500, seed=0
        )
        cen_pairs, cen_comms = centralized_truth(batch, forest)
        for name, backend in APPROACHES.items():
            res = make_engine(forest, backend).run(batch)
            rows.append(Row(
                f"fig10/{name}/N={n}", 0.0,
                f"QA1={qa1(res.communities, cen_comms):.3f};"
                f"QA2={qa2(res.similar_pairs, cen_pairs):.3f}",
            ))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        description="Fig. 10 accuracy table (CSV: name,us,QA1;QA2)"
    )
    ap.add_argument("--full", action="store_true", help="paper-size grid")
    ap.add_argument(
        "--subtraj", action="store_true",
        help="subtrajectory variant: windowed engines vs windowed truth",
    )
    args = ap.parse_args()
    for row in run(full=args.full, subtraj=args.subtraj):
        print(row.csv())
