"""Fig. 8 — number of trajectory pairs actually compared per approach.

Centralized = C(N,2); hash approaches compare only their candidate sets.
MinHash/BRP 'look faster' partly because they find FEWER candidates — the
paper's point that speed without the accuracy column is misleading.
"""
from __future__ import annotations

from benchmarks.common import APPROACHES, Row, make_engine
from repro.data import synthetic_setup

GRID_QUICK = (500, 1000, 2000)
GRID_FULL = (2_000, 5_000, 10_000, 20_000)


def run(full: bool = False) -> list[Row]:
    rows = []
    for n in (GRID_FULL if full else GRID_QUICK):
        batch, forest = synthetic_setup(n, seed=0)
        rows.append(Row(f"fig8/centralized/N={n}", 0.0,
                        f"pairs={n*(n-1)//2}"))
        for name, backend in APPROACHES.items():
            engine = make_engine(forest, backend, community_mode="components")
            res = engine.run(batch)
            rows.append(Row(f"fig8/{name}/N={n}", 0.0,
                            f"pairs={res.stats['num_candidates']}"))
    return rows
