"""Fig. 9 — time taken by the hash function itself per approach, plus the
paper's collision-rate model C(L,k)/Q^k validated empirically."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timeit
from repro.core import (
    encode_batch, forest_tables, minhash_signatures, type_codes,
)
from repro.core.brp import brp_bucket_keys
from repro.core.shingling import expected_collision_rate, shingles_from_types
from repro.core.types import PAD_KEY
from repro.data import synthetic_setup

GRID_QUICK = (1000, 2000)
GRID_FULL = (10_000, 50_000, 100_000)


def run(full: bool = False) -> list[Row]:
    rows = []
    for n in (GRID_FULL if full else GRID_QUICK):
        batch, forest = synthetic_setup(n, seed=0)
        enc = encode_batch(batch, forest_tables(forest))
        tc = type_codes(enc)

        t, keys = timeit(
            lambda: shingles_from_types(
                tc, batch.lengths, k=3, num_types=forest.num_types
            ).block_until_ready()
        )
        rows.append(Row(f"fig9/ssh/N={n}", t * 1e6, ""))
        t, _ = timeit(
            lambda: minhash_signatures(tc, batch.lengths, num_perm=16)
            .block_until_ready()
        )
        rows.append(Row(f"fig9/minhash/N={n}", t * 1e6, ""))
        t, _ = timeit(
            lambda: brp_bucket_keys(
                tc, batch.lengths, num_types=forest.num_types
            ).block_until_ready()
        )
        rows.append(Row(f"fig9/brp/N={n}", t * 1e6, ""))

        # collision-rate model (section IV.2)
        k_np = np.asarray(keys)
        valid = k_np[k_np != PAD_KEY]
        shingles_per_traj = (k_np != PAD_KEY).sum(axis=1).mean()
        model = expected_collision_rate(7, 3, forest.num_types)
        rows.append(Row(
            f"fig9/collision_model/N={n}", 0.0,
            f"model={model:.2e};shingles_per_traj={shingles_per_traj:.1f};"
            f"distinct_keys={len(np.unique(valid))}",
        ))
    return rows
